"""AOT compile path: lower the DLRM train/predict graphs to HLO **text**.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out ../artifacts [--preset mini kaggle_like ...]

Emits, per preset:
    artifacts/<preset>/train_step.hlo.txt
    artifacts/<preset>/predict.hlo.txt
    artifacts/<preset>/manifest.json      # the artifact ABI for Rust

This runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (PRESETS, ModelConfig, init_params, make_predict,
                    make_train_step)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    `as_hlo_text(True)` = print_large_constants: without it the printer
    elides big constant literals as `{...}`, which the consuming text
    parser silently materializes as ZEROS — the interaction backward's
    triu-unpack matrix came back all-zero and killed every embedding
    gradient before this flag was set.

    `return_tuple=False` keeps the entry's outputs untupled: PJRT then
    returns one device buffer per output, so the Rust hot path can keep
    the updated MLP parameters resident on device between steps (and use
    `execute_b`, whose literal-input sibling `execute` leaks the temporary
    device buffers in xla 0.1.6 — ~240 KB/step before this change).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text(True)


def specs_for(cfg: ModelConfig):
    """ShapeDtypeStructs for (dense, emb, labels, lr, *params)."""
    f32 = jnp.float32
    dense = jax.ShapeDtypeStruct((cfg.batch, cfg.num_dense), f32)
    emb = jax.ShapeDtypeStruct((cfg.batch, cfg.num_sparse, cfg.emb_dim), f32)
    labels = jax.ShapeDtypeStruct((cfg.batch,), f32)
    lr = jax.ShapeDtypeStruct((), f32)
    params = []
    for name, fan_in, fan_out in cfg.layer_dims():
        params.append((f"{name}.w", jax.ShapeDtypeStruct((fan_in, fan_out), f32)))
        params.append((f"{name}.b", jax.ShapeDtypeStruct((fan_out,), f32)))
    return dense, emb, labels, lr, params


def manifest_for(cfg: ModelConfig, params) -> dict:
    return {
        "name": cfg.name,
        "batch": cfg.batch,
        "num_dense": cfg.num_dense,
        "num_sparse": cfg.num_sparse,
        "emb_dim": cfg.emb_dim,
        "num_pairs": cfg.num_pairs,
        "params": [{"name": n, "shape": list(s.shape)} for n, s in params],
        "train_step": {
            "file": "train_step.hlo.txt",
            "inputs": ["dense", "emb", "labels", "lr"] + [n for n, _ in params],
            "outputs": ["loss", "emb_grad"] + [n for n, _ in params],
        },
        "predict": {
            "file": "predict.hlo.txt",
            "inputs": ["dense", "emb"] + [n for n, _ in params],
            "outputs": ["logits"],
        },
    }


def write_golden(cfg: ModelConfig, out_dir: str) -> None:
    """Golden numerics for the Rust runtime: fixed inputs + the jax-computed
    outputs of train_step and predict. The Rust integration test replays
    the AOT artifact on the same inputs and asserts allclose — this is the
    end-to-end guard against silent HLO round-trip corruption (e.g. the
    elided-large-constants bug this repo hit: see to_hlo_text).

    Binary format: u32 section count; per section u32 name_len, name,
    u32 f32_count, f32 LE data.
    """
    rng = np.random.default_rng(20200701)
    b, nd, ns, d = cfg.batch, cfg.num_dense, cfg.num_sparse, cfg.emb_dim
    dense = rng.standard_normal((b, nd)).astype(np.float32)
    emb = (0.05 * rng.standard_normal((b, ns, d))).astype(np.float32)
    labels = rng.integers(0, 2, (b,)).astype(np.float32)
    lr = np.float32(0.05)
    params = init_params(cfg, seed=77)

    step = jax.jit(make_train_step(cfg))
    out = step(jnp.asarray(dense), jnp.asarray(emb), jnp.asarray(labels),
               jnp.asarray(lr), *params)
    loss, emb_grad = np.asarray(out[0]), np.asarray(out[1])
    pred = jax.jit(make_predict(cfg))
    (logits,) = pred(jnp.asarray(dense), jnp.asarray(emb), *params)

    sections = [("dense", dense), ("emb", emb), ("labels", labels),
                ("lr", np.asarray([lr])), ("loss", np.asarray([loss])),
                ("emb_grad", emb_grad), ("logits", np.asarray(logits))]
    sections += [(f"param{i}", np.asarray(p)) for i, p in enumerate(params)]
    sections += [(f"new_param{i}", np.asarray(p))
                 for i, p in enumerate(out[2:])]
    with open(os.path.join(out_dir, "golden.bin"), "wb") as f:
        f.write(struct.pack("<I", len(sections)))
        for name, arr in sections:
            data = arr.astype(np.float32).ravel()
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", data.size))
            f.write(data.tobytes())


def build_preset(cfg: ModelConfig, out_dir: str) -> None:
    cfg.validate()
    os.makedirs(out_dir, exist_ok=True)
    dense, emb, labels, lr, params = specs_for(cfg)
    pspecs = [s for _, s in params]

    lowered = jax.jit(make_train_step(cfg)).lower(
        dense, emb, labels, lr, *pspecs)
    with open(os.path.join(out_dir, "train_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(make_predict(cfg)).lower(dense, emb, *pspecs)
    with open(os.path.join(out_dir, "predict.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest_for(cfg, params), f, indent=2)
    write_golden(cfg, out_dir)
    print(f"[aot] {cfg.name}: wrote train_step/predict/manifest/golden to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts root directory")
    ap.add_argument("--preset", nargs="*", default=list(PRESETS),
                    help=f"presets to build (default: all of {list(PRESETS)})")
    args = ap.parse_args()
    for name in args.preset:
        build_preset(PRESETS[name], os.path.join(args.out, name))


if __name__ == "__main__":
    main()
