"""L2: DLRM forward/backward in JAX, calling the Pallas kernels.

The model follows Naumov et al. (2019) as configured by MLPerf for the
Criteo datasets (paper §5.1): bottom MLP over 13 dense features, 26
embedding lookups, dot-product feature interaction, top MLP to a CTR
logit, BCE loss, plain SGD.

Split of responsibilities with the Rust coordinator (L3):
  * the embedding *tables* live in Rust, sharded across emulated Emb PS
    nodes — that is where CPR's checkpointing/partial-recovery happens;
  * this graph receives the already-gathered embedding rows
    `emb:[B, S, D]` and returns `d(loss)/d(emb)` so Rust can apply the
    sparse SGD update to the owning shard rows.

Forward hot-spots run as Pallas kernels via jax.custom_vjp: Pallas calls
are not differentiable by themselves, so each wrapper pairs the Pallas
forward with an analytic jnp backward (fused by XLA into the same
train-step HLO — Python is never on the request path).

Everything here is lowered ONCE by aot.py to HLO text; the Rust runtime
loads and executes the artifacts.
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import interaction as pallas_interaction
from .kernels import mlp_layer as pallas_mlp_layer
from .kernels.ref import triu_indices


# ---------------------------------------------------------------------------
# Model configuration (mirrored by rust/src/config presets)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Architecture of one DLRM variant.

    bottom_mlp[-1] must equal emb_dim (the bottom output joins the
    interaction as the 27th feature vector).
    """
    name: str
    num_dense: int = 13
    num_sparse: int = 26
    emb_dim: int = 16
    bottom_mlp: Tuple[int, ...] = (512, 256, 64, 16)
    top_mlp: Tuple[int, ...] = (512, 256, 1)
    batch: int = 128

    @property
    def num_feats(self) -> int:
        return self.num_sparse + 1

    @property
    def num_pairs(self) -> int:
        f = self.num_feats
        return f * (f - 1) // 2

    @property
    def top_in(self) -> int:
        return self.emb_dim + self.num_pairs

    def layer_dims(self) -> List[Tuple[str, int, int]]:
        """(name, fan_in, fan_out) for every linear layer, in param order."""
        dims = []
        fan_in = self.num_dense
        for i, width in enumerate(self.bottom_mlp):
            dims.append((f"bot{i}", fan_in, width))
            fan_in = width
        fan_in = self.top_in
        for i, width in enumerate(self.top_mlp):
            dims.append((f"top{i}", fan_in, width))
            fan_in = width
        return dims

    def validate(self):
        assert self.bottom_mlp[-1] == self.emb_dim, (
            "bottom MLP output must match emb_dim for the interaction concat")
        assert self.top_mlp[-1] == 1, "top MLP must end in a single logit"


# Presets mirrored by rust/src/config/presets.rs. `mini` is the fast config
# used by the many-run accuracy experiments (Figs 2/9/10/11/12 at default
# scale); kaggle_like / terabyte_like follow the paper's §5.1 layer sizes.
PRESETS = {
    "mini": ModelConfig(name="mini", emb_dim=8,
                        bottom_mlp=(64, 32, 8), top_mlp=(64, 1), batch=128),
    "kaggle_like": ModelConfig(name="kaggle_like", emb_dim=16,
                               bottom_mlp=(512, 256, 64, 16),
                               top_mlp=(512, 256, 1), batch=128),
    "terabyte_like": ModelConfig(name="terabyte_like", emb_dim=64,
                                 bottom_mlp=(512, 256, 64),
                                 top_mlp=(512, 512, 256, 1), batch=128),
}


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    """Xavier-uniform weights + zero biases, flattened [w0, b0, w1, b1, ...].

    The flat ordering is the artifact ABI: aot.py records it in
    manifest.json and the Rust runtime feeds/receives params in this order.
    """
    rng = np.random.RandomState(seed)
    params = []
    for _, fan_in, fan_out in cfg.layer_dims():
        bound = np.sqrt(6.0 / (fan_in + fan_out))
        params.append(jnp.asarray(
            rng.uniform(-bound, bound, (fan_in, fan_out)), jnp.float32))
        params.append(jnp.zeros((fan_out,), jnp.float32))
    return params


# ---------------------------------------------------------------------------
# custom_vjp wrappers: Pallas forward, analytic jnp backward
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _linear_relu(x, w, b):
    return pallas_mlp_layer(x, w, b, relu=True)


def _linear_relu_fwd(x, w, b):
    y = pallas_mlp_layer(x, w, b, relu=True)
    return y, (x, w, y)


def _linear_relu_bwd(res, dy):
    x, w, y = res
    dz = dy * (y > 0.0)                 # ReLU mask from the saved output
    return (dz @ w.T, x.T @ dz, jnp.sum(dz, axis=0))


_linear_relu.defvjp(_linear_relu_fwd, _linear_relu_bwd)


@jax.custom_vjp
def _linear(x, w, b):
    return pallas_mlp_layer(x, w, b, relu=False)


def _linear_fwd(x, w, b):
    return pallas_mlp_layer(x, w, b, relu=False), (x, w)


def _linear_bwd(res, dy):
    x, w = res
    return (dy @ w.T, x.T @ dy, jnp.sum(dy, axis=0))


_linear.defvjp(_linear_fwd, _linear_bwd)


@jax.custom_vjp
def _interact(feats):
    return pallas_interaction(feats)


def _interact_fwd(feats):
    return pallas_interaction(feats), (feats,)


def _unpack_matrix(f: int) -> np.ndarray:
    """Constant [P, F*F] 0/1 matrix: packed-triu index k -> flat (i, j).

    Used to express the triu scatter/gather as a dense matmul: the
    `scatter` HLO op produced by `.at[...].set()` silently evaluates to
    zeros after the HLO-text round-trip through xla_extension 0.5.1, so the
    backward pass avoids it entirely (P and F are tiny; the matmul is
    negligible and XLA folds the constant).
    """
    iu0, iu1 = triu_indices(f)
    p = len(iu0)
    m = np.zeros((p, f * f), np.float32)
    m[np.arange(p), iu0 * f + iu1] = 1.0
    return m


def _interact_bwd(res, dz):
    # Z = triu(X X^T)  =>  dX = (dG + dG^T) X with dG the triu unpack of dz.
    (feats,) = res
    b, f, _ = feats.shape
    m = jnp.asarray(_unpack_matrix(f))
    dg = (dz @ m).reshape(b, f, f)
    return (jnp.einsum("bfg,bgd->bfd", dg + jnp.swapaxes(dg, 1, 2), feats),)


_interact.defvjp(_interact_fwd, _interact_bwd)


# ---------------------------------------------------------------------------
# DLRM forward / loss / train step
# ---------------------------------------------------------------------------

def _split_params(cfg: ModelConfig, params: List[jnp.ndarray]):
    nb = len(cfg.bottom_mlp)
    bottom = [(params[2 * i], params[2 * i + 1]) for i in range(nb)]
    top = [(params[2 * (nb + i)], params[2 * (nb + i) + 1])
           for i in range(len(cfg.top_mlp))]
    return bottom, top


def forward(cfg: ModelConfig, params: List[jnp.ndarray],
            dense: jnp.ndarray, emb: jnp.ndarray) -> jnp.ndarray:
    """DLRM forward. dense:[B,num_dense] emb:[B,num_sparse,D] -> logits:[B]."""
    bottom, top = _split_params(cfg, params)
    x = dense
    for w, b in bottom:                  # all bottom layers ReLU (DLRM ref)
        x = _linear_relu(x, w, b)
    feats = jnp.concatenate([x[:, None, :], emb], axis=1)   # [B, F, D]
    z = _interact(feats)                                    # [B, P]
    t = jnp.concatenate([x, z], axis=1)                     # [B, D+P]
    for w, b in top[:-1]:
        t = _linear_relu(t, w, b)
    w, b = top[-1]
    return _linear(t, w, b)[:, 0]                           # [B]


def bce_with_logits(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Numerically stable mean binary cross-entropy from logits."""
    return jnp.mean(jnp.maximum(logits, 0.0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def make_train_step(cfg: ModelConfig):
    """(dense, emb, labels, lr, *params) -> (loss, emb_grad, *new_params).

    MLP params are SGD-updated in-graph; the embedding gradient is returned
    for the Rust Emb PS cluster to apply (and for the CPR trackers to see).
    """

    def loss_fn(params, emb, dense, labels):
        return bce_with_logits(forward(cfg, params, dense, emb), labels)

    grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1))

    def train_step(dense, emb, labels, lr, *params):
        loss, (gp, gemb) = grad_fn(list(params), emb, dense, labels)
        new_params = [p - lr * g for p, g in zip(params, gp)]
        return (loss, gemb, *new_params)

    return train_step


def make_predict(cfg: ModelConfig):
    """(dense, emb, *params) -> (logits,). Eval-only forward pass."""

    def predict(dense, emb, *params):
        return (forward(cfg, list(params), dense, emb),)

    return predict
