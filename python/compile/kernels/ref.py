"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
checks the Pallas (interpret=True) output against these under shape/dtype
sweeps (hypothesis). The references are also used for the backward passes
of the custom_vjp wrappers in model.py: the forward is the Pallas kernel,
the backward is plain jnp (XLA fuses it into the same train-step HLO).
"""

import jax.numpy as jnp
import numpy as np


def mlp_layer_ref(x, w, b, relu: bool):
    """y = x @ w + b, optionally ReLU. x:[B,I] w:[I,O] b:[O] -> [B,O]."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    return jnp.maximum(y, 0.0) if relu else y


def triu_indices(f: int):
    """Static strict-upper-triangle index pairs for F features (row-major)."""
    iu = np.triu_indices(f, k=1)
    return iu[0].astype(np.int32), iu[1].astype(np.int32)


def interaction_ref(feats):
    """DLRM dot-product feature interaction.

    feats: [B, F, D]  ->  packed strict upper triangle of the per-sample
    Gram matrix feats @ feats^T, shape [B, F*(F-1)//2].
    """
    b, f, _ = feats.shape
    gram = jnp.einsum("bfd,bgd->bfg", feats, feats,
                      preferred_element_type=jnp.float32)
    iu0, iu1 = triu_indices(f)
    return gram[:, iu0, iu1]


def embedding_bag_ref(bag):
    """Multi-hot sum pooling. bag: [B, P, D] -> [B, D]."""
    return jnp.sum(bag, axis=1)
