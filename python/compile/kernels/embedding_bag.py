"""Pallas multi-hot sum-pooling kernel: [B, P, D] -> [B, D].

Feature pooling is bandwidth-bound (one pass over the gathered rows, a
P-way add per output element). The TPU mapping streams [bB, P, D] tiles
HBM->VMEM via BlockSpec and reduces on the VPU; there is no reuse to
exploit, so the only lever is keeping the tile resident for the whole
reduction (vs. the GPU version's per-warp partial sums in shared memory).

The Criteo-style configs in this repo are single-hot (P folds into the
gather on the Rust side), so this kernel is exercised by the kernel tests
and by multi-hot model configs (hotness > 1).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sumpool_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...], axis=1)


def _block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_b",))
def embedding_bag(bag, block_b: int = 128):
    """Sum-pool the hotness axis. bag: [B, P, D] -> [B, D] (f32)."""
    bsz, p, d = bag.shape
    bb = _block(bsz, block_b)
    return pl.pallas_call(
        _sumpool_kernel,
        grid=(bsz // bb,),
        in_specs=[pl.BlockSpec((bb, p, d), lambda ib: (ib, 0, 0))],
        out_specs=pl.BlockSpec((bb, d), lambda ib: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, d), jnp.float32),
        interpret=True,
    )(bag)
