"""L1: Pallas kernels for the DLRM compute hot-spots.

All kernels run with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); BlockSpecs are written for real-TPU VMEM/MXU shapes, see
DESIGN.md §Hardware-Adaptation.
"""

from .embedding_bag import embedding_bag
from .interaction import interaction
from .mlp import mlp_layer

__all__ = ["embedding_bag", "interaction", "mlp_layer"]
