"""Pallas dot-product feature-interaction kernel (the DLRM hot-spot).

Per sample the interaction is the strict upper triangle of the Gram matrix
G = X X^T with X:[F, D] (F = 1 bottom-MLP vector + 26 embeddings). A GPU
implementation assigns one threadblock per sample (tiny GEMMs); that shape
is hostile to the MXU, so the TPU adaptation blocks over the *batch* axis
instead: one grid step loads a [bB, F, D] tile into VMEM, computes all bB
Gram matrices with a single batched MXU matmul, and packs the triangle
in-register with static gather indices (VPU) before a single HBM write of
the packed [bB, P] tile.

VMEM per step (bB=128, F=27, D=64): 128*27*64*4 = 864 KiB in +
128*351*4 = 176 KiB out, well under budget.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import triu_indices


def _interaction_kernel(x_ref, iu0_ref, iu1_ref, o_ref):
    x = x_ref[...]                      # [bB, F, D]
    gram = jnp.einsum("bfd,bgd->bfg", x, x,
                      preferred_element_type=jnp.float32)
    # Strict-upper-triangle gather; the index vectors are loop-invariant
    # kernel inputs (Pallas forbids captured constants), so this lowers to
    # a fixed permutation on the VPU.
    o_ref[...] = gram[:, iu0_ref[...], iu1_ref[...]]


def _block(dim: int, want: int) -> int:
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_b",))
def interaction(feats, block_b: int = 128):
    """feats: [B, F, D] -> packed triu of per-sample Gram, [B, F*(F-1)//2]."""
    bsz, f, d = feats.shape
    p = f * (f - 1) // 2
    iu0, iu1 = triu_indices(f)
    bb = _block(bsz, block_b)
    return pl.pallas_call(
        _interaction_kernel,
        grid=(bsz // bb,),
        in_specs=[
            pl.BlockSpec((bb, f, d), lambda ib: (ib, 0, 0)),
            pl.BlockSpec((p,), lambda ib: (0,)),
            pl.BlockSpec((p,), lambda ib: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, p), lambda ib: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, p), jnp.float32),
        interpret=True,
    )(feats, jnp.asarray(iu0), jnp.asarray(iu1))
