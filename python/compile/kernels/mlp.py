"""Pallas fused MLP-layer kernel: y = relu?(x @ w + b).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the GPU version of this
hot-spot is a cuBLAS GEMM with a fused epilogue; on TPU we tile the GEMM
for the 128x128 MXU and fuse bias+activation into the final k-step so the
activation tile never round-trips through HBM.

Grid is (batch blocks, out blocks, in blocks); the in (k) axis is the
innermost, sequential axis and accumulates into the output VMEM tile.
VMEM footprint per step = bB*bK + bK*bO + bB*bO floats; with the default
128/128/128 blocks that is 3 * 64 KiB = 192 KiB << 4 MiB budget.

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; the BlockSpec structure is still what a real TPU would get.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...]
        o_ref[...] = jnp.maximum(y, 0.0) if relu else y


def _block(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= want (keeps grids exact)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("relu", "block_b", "block_o",
                                             "block_k"))
def mlp_layer(x, w, b, relu: bool = True, block_b: int = 128,
              block_o: int = 512, block_k: int = 512):
    # Default blocks cover the full GEMM for every DLRM layer width in
    # this repo (<= 512): one grid step per pallas_call. interpret=True
    # lowers each grid step into an XLA while-loop iteration with dynamic
    # slicing, so extra grid steps are pure overhead on the CPU artifacts
    # (measured 215 ms -> 5 ms per train step on kaggle_like; see
    # EXPERIMENTS.md §Perf). On a real TPU the same kernel would be built
    # with 128x128x128 blocks to fit VMEM/MXU — the BlockSpec machinery is
    # exercised by the kernel tests at many block shapes.
    """Fused linear layer. x:[B,I] w:[I,O] b:[O] -> [B,O] (f32)."""
    bsz, i = x.shape
    i2, o = w.shape
    assert i == i2 and b.shape == (o,)
    bb, bo, bk = _block(bsz, block_b), _block(o, block_o), _block(i, block_k)
    nk = i // bk
    grid = (bsz // bb, o // bo, nk)
    return pl.pallas_call(
        functools.partial(_mlp_kernel, relu=relu, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda ib, io, ik: (ib, ik)),
            pl.BlockSpec((bk, bo), lambda ib, io, ik: (ik, io)),
            pl.BlockSpec((bo,), lambda ib, io, ik: (io,)),
        ],
        out_specs=pl.BlockSpec((bb, bo), lambda ib, io, ik: (ib, io)),
        out_shape=jax.ShapeDtypeStruct((bsz, o), jnp.float32),
        interpret=True,
    )(x, w, b)
