"""L2 model tests: shapes, loss semantics, gradient correctness, SGD step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (PRESETS, ModelConfig, bce_with_logits, forward,
                           init_params, make_predict, make_train_step)

jax.config.update("jax_platform_name", "cpu")

MINI = PRESETS["mini"]


def batch_for(cfg, b=None, seed=0):
    rng = np.random.default_rng(seed)
    b = b or cfg.batch
    dense = jnp.asarray(rng.standard_normal((b, cfg.num_dense)), jnp.float32)
    emb = jnp.asarray(
        0.1 * rng.standard_normal((b, cfg.num_sparse, cfg.emb_dim)),
        jnp.float32)
    labels = jnp.asarray(rng.integers(0, 2, (b,)), jnp.float32)
    return dense, emb, labels


def test_preset_configs_validate():
    for cfg in PRESETS.values():
        cfg.validate()
        assert cfg.bottom_mlp[-1] == cfg.emb_dim


def test_forward_shape_and_finite():
    params = init_params(MINI)
    dense, emb, _ = batch_for(MINI, b=32)
    logits = forward(MINI, params, dense, emb)
    assert logits.shape == (32,)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_param_count_and_order():
    params = init_params(MINI)
    dims = MINI.layer_dims()
    assert len(params) == 2 * len(dims)
    for i, (_, fan_in, fan_out) in enumerate(dims):
        assert params[2 * i].shape == (fan_in, fan_out)
        assert params[2 * i + 1].shape == (fan_out,)


def test_bce_matches_manual():
    logits = jnp.asarray([0.0, 2.0, -3.0], jnp.float32)
    labels = jnp.asarray([1.0, 0.0, 1.0], jnp.float32)
    p = 1.0 / (1.0 + np.exp(-np.asarray(logits)))
    want = -np.mean(np.asarray(labels) * np.log(p)
                    + (1 - np.asarray(labels)) * np.log(1 - p))
    np.testing.assert_allclose(bce_with_logits(logits, labels), want,
                               rtol=1e-6)


def test_bce_extreme_logits_stable():
    logits = jnp.asarray([80.0, -80.0], jnp.float32)
    labels = jnp.asarray([0.0, 1.0], jnp.float32)
    assert bool(jnp.isfinite(bce_with_logits(logits, labels)))


def test_grads_match_numerical():
    """Backward through the custom_vjp Pallas wrappers vs finite differences."""
    cfg = ModelConfig(name="tiny", num_dense=4, num_sparse=3, emb_dim=4,
                      bottom_mlp=(8, 4), top_mlp=(8, 1), batch=8)
    cfg.validate()
    params = init_params(cfg, seed=1)
    dense, emb, labels = batch_for(cfg, b=8, seed=1)

    def loss_of_emb(e):
        return bce_with_logits(forward(cfg, params, dense, e), labels)

    def loss_of_w0(w0):
        p = [w0] + params[1:]
        return bce_with_logits(forward(cfg, p, dense, emb), labels)

    for fn, x in [(loss_of_emb, emb), (loss_of_w0, params[0])]:
        g = jax.grad(fn)(x)
        xf = np.asarray(x, np.float64).ravel()
        rng = np.random.default_rng(0)
        for idx in rng.choice(xf.size, size=8, replace=False):
            eps = 1e-3
            xp, xm = xf.copy(), xf.copy()
            xp[idx] += eps
            xm[idx] -= eps
            num = (fn(jnp.asarray(xp.reshape(x.shape), jnp.float32))
                   - fn(jnp.asarray(xm.reshape(x.shape), jnp.float32)))
            num = float(num) / (2 * eps)
            np.testing.assert_allclose(np.asarray(g).ravel()[idx], num,
                                       rtol=2e-2, atol=2e-3)


def test_train_step_decreases_loss():
    cfg = PRESETS["mini"]
    step = jax.jit(make_train_step(cfg))
    params = init_params(cfg, seed=2)
    dense, emb, labels = batch_for(cfg, seed=2)
    lr = jnp.float32(0.1)
    out = step(dense, emb, labels, lr, *params)
    loss0, gemb, new_params = out[0], out[1], list(out[2:])
    assert gemb.shape == emb.shape
    # Re-evaluating the SAME batch after one SGD step must reduce the loss
    # (embedding rows updated too, as the Rust PS would).
    emb2 = emb - lr * gemb
    out2 = step(dense, emb2, labels, lr, *new_params)
    assert float(out2[0]) < float(loss0)


def test_train_step_param_shapes_preserved():
    step = jax.jit(make_train_step(MINI))
    params = init_params(MINI)
    dense, emb, labels = batch_for(MINI)
    out = step(dense, emb, labels, jnp.float32(0.01), *params)
    assert len(out) == 2 + len(params)
    for p, q in zip(params, out[2:]):
        assert p.shape == q.shape


def test_predict_matches_forward():
    pred = jax.jit(make_predict(MINI))
    params = init_params(MINI)
    dense, emb, _ = batch_for(MINI)
    (logits,) = pred(dense, emb, *params)
    np.testing.assert_allclose(logits, forward(MINI, params, dense, emb),
                               rtol=1e-5, atol=1e-5)


def test_zero_lr_is_identity():
    step = jax.jit(make_train_step(MINI))
    params = init_params(MINI)
    dense, emb, labels = batch_for(MINI)
    out = step(dense, emb, labels, jnp.float32(0.0), *params)
    for p, q in zip(params, out[2:]):
        np.testing.assert_allclose(p, q, rtol=0, atol=0)
