"""AOT artifact emission tests: manifest ABI, HLO text hygiene, golden."""

import json
import os
import struct
import tempfile

import jax
import numpy as np
import pytest

from compile.aot import build_preset, manifest_for, specs_for, to_hlo_text
from compile.model import PRESETS, make_predict, make_train_step

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def mini_dir():
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "mini")
        build_preset(PRESETS["mini"], out)
        yield out


def test_emits_all_artifacts(mini_dir):
    for f in ["train_step.hlo.txt", "predict.hlo.txt", "manifest.json",
              "golden.bin"]:
        assert os.path.exists(os.path.join(mini_dir, f)), f


def test_manifest_matches_config(mini_dir):
    cfg = PRESETS["mini"]
    m = json.load(open(os.path.join(mini_dir, "manifest.json")))
    assert m["batch"] == cfg.batch
    assert m["num_sparse"] == cfg.num_sparse
    assert m["emb_dim"] == cfg.emb_dim
    assert m["num_pairs"] == cfg.num_pairs
    # params: (w, b) per layer, ordered bottom then top
    dims = cfg.layer_dims()
    assert len(m["params"]) == 2 * len(dims)
    for i, (name, fan_in, fan_out) in enumerate(dims):
        assert m["params"][2 * i]["name"] == f"{name}.w"
        assert m["params"][2 * i]["shape"] == [fan_in, fan_out]
        assert m["params"][2 * i + 1]["shape"] == [fan_out]
    # the IO lists must line up with the ABI the Rust runtime assumes
    assert m["train_step"]["inputs"][:4] == ["dense", "emb", "labels", "lr"]
    assert m["train_step"]["outputs"][:2] == ["loss", "emb_grad"]


def test_hlo_text_has_no_elided_constants(mini_dir):
    """`{...}` in HLO text re-parses as ZEROS downstream — never emit it."""
    for f in ["train_step.hlo.txt", "predict.hlo.txt"]:
        text = open(os.path.join(mini_dir, f)).read()
        assert "{...}" not in text, f"{f} contains elided constants"
        assert "ENTRY" in text


def test_hlo_entry_parameter_count(mini_dir):
    cfg = PRESETS["mini"]
    text = open(os.path.join(mini_dir, "train_step.hlo.txt")).read()
    entry = text[text.index("ENTRY"):]
    n_params = entry.count(" parameter(")
    assert n_params == 4 + 2 * len(cfg.layer_dims())


def test_golden_sections_complete(mini_dir):
    cfg = PRESETS["mini"]
    with open(os.path.join(mini_dir, "golden.bin"), "rb") as f:
        (n,) = struct.unpack("<I", f.read(4))
        names = []
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            names.append(f.read(ln).decode())
            (cnt,) = struct.unpack("<I", f.read(4))
            data = np.frombuffer(f.read(4 * cnt), np.float32)
            assert data.size == cnt
            assert np.isfinite(data).all(), names[-1]
        assert f.read() == b""  # no trailing bytes
    for want in ["dense", "emb", "labels", "lr", "loss", "emb_grad", "logits"]:
        assert want in names
    n_params = 2 * len(cfg.layer_dims())
    assert sum(1 for x in names if x.startswith("param")) == n_params
    assert sum(1 for x in names if x.startswith("new_param")) == n_params


def test_hlo_text_roundtrip_is_stable():
    """Lowering the same config twice gives identical HLO text (the
    artifact build is reproducible)."""
    cfg = PRESETS["mini"]
    dense, emb, labels, lr, params = specs_for(cfg)
    pspecs = [s for _, s in params]
    a = to_hlo_text(jax.jit(make_predict(cfg)).lower(dense, emb, *pspecs))
    b = to_hlo_text(jax.jit(make_predict(cfg)).lower(dense, emb, *pspecs))
    assert a == b


def test_manifest_for_is_json_serializable():
    cfg = PRESETS["kaggle_like"]
    _, _, _, _, params = specs_for(cfg)
    m = manifest_for(cfg, params)
    text = json.dumps(m)
    assert json.loads(text)["name"] == "kaggle_like"
