"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes (batch/feature/dim and block sizes); every case
asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import embedding_bag, interaction, mlp_layer
from compile.kernels.ref import (embedding_bag_ref, interaction_ref,
                                 mlp_layer_ref, triu_indices)

jax.config.update("jax_platform_name", "cpu")


def rnd(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# mlp_layer
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 64), i=st.integers(1, 96), o=st.integers(1, 96),
       relu=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_mlp_layer_matches_ref(b, i, o, relu, seed):
    rng = np.random.default_rng(seed)
    x, w = rnd(rng, b, i), rnd(rng, i, o)
    bias = rnd(rng, o)
    got = mlp_layer(x, w, bias, relu=relu)
    want = mlp_layer_ref(x, w, bias, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 32, 64), (128, 128, 128),
                                    (1, 1, 1), (37, 13, 7)])
def test_mlp_layer_block_shape_invariance(blocks):
    """The k-accumulation grid must not change the numerics."""
    rng = np.random.default_rng(0)
    x, w, bias = rnd(rng, 48, 56), rnd(rng, 56, 40), rnd(rng, 40)
    bb, bo, bk = blocks
    got = mlp_layer(x, w, bias, relu=True, block_b=bb, block_o=bo, block_k=bk)
    np.testing.assert_allclose(got, mlp_layer_ref(x, w, bias, True),
                               rtol=1e-5, atol=1e-5)


def test_mlp_layer_relu_clamps():
    x = jnp.asarray([[-1.0, 2.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    assert mlp_layer(x, w, b, relu=True).min() >= 0.0
    assert mlp_layer(x, w, b, relu=False)[0, 0] == -1.0


# ---------------------------------------------------------------------------
# interaction
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 64), f=st.integers(2, 32), d=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
def test_interaction_matches_ref(b, f, d, seed):
    rng = np.random.default_rng(seed)
    feats = rnd(rng, b, f, d)
    np.testing.assert_allclose(interaction(feats), interaction_ref(feats),
                               rtol=1e-4, atol=1e-4)


def test_interaction_output_is_pairwise_dots():
    """Spot-check packing order against explicit per-pair dot products."""
    rng = np.random.default_rng(3)
    feats = rnd(rng, 4, 5, 7)
    z = np.asarray(interaction(feats))
    iu0, iu1 = triu_indices(5)
    for s in range(4):
        for k, (i, j) in enumerate(zip(iu0, iu1)):
            want = float(np.dot(np.asarray(feats)[s, i],
                                np.asarray(feats)[s, j]))
            np.testing.assert_allclose(z[s, k], want, rtol=1e-4, atol=1e-4)


def test_interaction_batch_blocking_invariance():
    rng = np.random.default_rng(1)
    feats = rnd(rng, 60, 27, 16)
    a = interaction(feats, block_b=128)   # single block
    b = interaction(feats, block_b=4)     # 15 blocks
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 64), p=st.integers(1, 16), d=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
def test_embedding_bag_matches_ref(b, p, d, seed):
    rng = np.random.default_rng(seed)
    bag = rnd(rng, b, p, d)
    np.testing.assert_allclose(embedding_bag(bag), embedding_bag_ref(bag),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_single_hot_is_identity():
    rng = np.random.default_rng(2)
    bag = rnd(rng, 8, 1, 16)
    np.testing.assert_allclose(embedding_bag(bag), bag[:, 0, :], rtol=0,
                               atol=0)
