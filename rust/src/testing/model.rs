//! Explicit-state exhaustive interleaving explorer (vendored mini-loom;
//! the real `loom` crate is unavailable in the offline image, and the
//! protocols under test are small enough for plain state-space search).
//!
//! A model is a `Clone + Eq + Hash` shared state plus a vector of thread
//! state machines ([`ModelThread`]). [`explore`] runs a memoized DFS over
//! every reachable `(shared, threads)` configuration, invoking a checker
//! on each one — so a safety property ("no torn read ever escapes", "a
//! reader never observes half-written data") is verified over **all**
//! interleavings, not the few a scheduler happens to produce.
//!
//! Scope and honesty: the exploration enumerates *sequentially
//! consistent* interleavings at the granularity the model encodes (one
//! shared-memory access per [`ModelThread::step`]). That exhausts the
//! protocol-logic state space — torn epochs, stuck-odd sequences, poison
//! conversion, turnstile ordering — which is where seqlock/lock bugs
//! live. Weak-memory effects (are the fences in the *real* code strong
//! enough?) are NOT modeled here; they are discharged by the Miri and
//! ThreadSanitizer CI lanes running the real implementation.
//!
//! Rules for writing a model:
//!
//! * one shared access per `step` (finer splits = more interleavings =
//!   more coverage, at state-space cost);
//! * a step returning [`Step::Blocked`] must leave both the shared state
//!   and the thread unchanged (checked in debug builds) — it models a
//!   condvar wait / turnstile park;
//! * keep local counters bounded (saturate retry counts) so the state
//!   space stays finite.

use std::collections::HashSet;
use std::hash::Hash;

/// Result of giving one thread a scheduling slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// The thread performed its next atomic action.
    Ran,
    /// The thread cannot run now (parked on a condition); nothing changed.
    Blocked,
    /// The thread already finished; nothing changed.
    Done,
}

/// One modeled thread: a hashable state machine advanced by `step`.
pub trait ModelThread<S>: Clone + Eq + Hash {
    /// Perform the thread's next atomic action against `shared`.
    fn step(&mut self, shared: &mut S) -> Step;
}

/// Aggregate results of an exploration.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Outcome {
    /// Distinct `(shared, threads)` configurations visited.
    pub states: usize,
    /// States where every thread reported [`Step::Done`].
    pub terminals: usize,
    /// States where no thread could run but not all were done — a
    /// protocol deadlock (e.g. a turnstile ticket that never arrives).
    pub deadlocks: usize,
}

/// Exhaustively explore every interleaving of `threads` over `shared`,
/// calling `check` on each distinct reachable state (terminal or not).
/// Panics in `check` are the property-failure mechanism.
pub fn explore<S, T>(
    shared: S,
    threads: Vec<T>,
    mut check: impl FnMut(&S, &[T]),
) -> Outcome
where
    S: Clone + Eq + Hash,
    T: ModelThread<S>,
{
    let mut visited: HashSet<(S, Vec<T>)> = HashSet::new();
    let mut stack = vec![(shared, threads)];
    let mut out = Outcome::default();
    while let Some((s, ts)) = stack.pop() {
        if !visited.insert((s.clone(), ts.clone())) {
            continue;
        }
        out.states += 1;
        check(&s, &ts);
        let mut ran_any = false;
        let mut all_done = true;
        for i in 0..ts.len() {
            let mut s2 = s.clone();
            let mut ts2 = ts.clone();
            match ts2[i].step(&mut s2) {
                Step::Ran => {
                    ran_any = true;
                    all_done = false;
                    stack.push((s2, ts2));
                }
                Step::Blocked => {
                    debug_assert!(
                        s2 == s && ts2 == ts,
                        "a Blocked step must not mutate the model"
                    );
                    all_done = false;
                }
                Step::Done => {
                    debug_assert!(
                        s2 == s && ts2 == ts,
                        "a Done step must not mutate the model"
                    );
                }
            }
        }
        if all_done {
            out.terminals += 1;
        } else if !ran_any {
            out.deadlocks += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two threads each do: load counter; store counter+1. The classic
    /// lost-update race: exhaustive exploration must find BOTH outcomes
    /// (final == 2 on serialized schedules, final == 1 on interleaved
    /// ones) — proving the explorer actually interleaves.
    #[derive(Clone, PartialEq, Eq, Hash)]
    enum Incr {
        Load,
        Store(u8),
        End,
    }

    impl ModelThread<u8> for Incr {
        fn step(&mut self, shared: &mut u8) -> Step {
            match *self {
                Incr::Load => {
                    *self = Incr::Store(*shared);
                    Step::Ran
                }
                Incr::Store(v) => {
                    *shared = v + 1;
                    *self = Incr::End;
                    Step::Ran
                }
                Incr::End => Step::Done,
            }
        }
    }

    #[test]
    fn explorer_finds_the_lost_update() {
        let mut finals = std::collections::HashSet::new();
        let out = explore(0u8, vec![Incr::Load, Incr::Load], |s, ts| {
            if ts.iter().all(|t| *t == Incr::End) {
                finals.insert(*s);
            }
        });
        assert_eq!(finals, [1u8, 2].into_iter().collect());
        assert!(out.terminals >= 2);
        assert_eq!(out.deadlocks, 0);
    }

    /// A thread parked on a condition nobody signals is a deadlock the
    /// explorer must report, not loop on.
    #[derive(Clone, PartialEq, Eq, Hash)]
    struct WaitForever;

    impl ModelThread<u8> for WaitForever {
        fn step(&mut self, _shared: &mut u8) -> Step {
            Step::Blocked
        }
    }

    #[test]
    fn explorer_reports_deadlock() {
        let out = explore(0u8, vec![WaitForever], |_, _| {});
        assert_eq!(out.deadlocks, 1);
        assert_eq!(out.terminals, 0);
    }
}
