//! [`CountingAlloc`] — a counting wrapper around the system allocator,
//! for the zero-allocation contract on the planned data-plane hot path.
//!
//! The planned step promises: after warmup (one `PlanArena::build` at the
//! batch's final shape), a steady-state in-process step — plan build,
//! planned gather, turnstile-ordered planned applies, planned access
//! recording — performs **zero heap allocations**. A promise like that
//! rots silently unless a test counts, so the integration suite
//! (`tests/plan_equiv.rs`) and the bench harness install this allocator
//! via `#[global_allocator]` and read the counter around the audited
//! region.
//!
//! Design constraints, in order:
//! * **Never allocate while counting.** The counter is a `const`-init
//!   thread-local `Cell` — no lazy init, no locks, no heap.
//! * **Safe during thread teardown.** `LocalKey::try_with` is used
//!   everywhere: allocations from TLS destructors (or before TLS init)
//!   fall through to the raw system allocator uncounted rather than
//!   aborting.
//! * **Count per thread, not per process.** The audited region runs on
//!   one thread; background threads (PS workers, checkpoint writer) may
//!   allocate concurrently and must not pollute the audit. Threaded-
//!   backend audits therefore bound only the *caller-side* allocations —
//!   exactly the ones the plan's buffer pooling eliminates.
//!
//! This module is compiled into the library (so unit tests and benches
//! share one definition) but changes nothing unless a binary opts in with
//! `#[global_allocator] static A: CountingAlloc = CountingAlloc;` — the
//! library itself never installs it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    /// Audit switch for the current thread. `const` init: reading it can
    /// never itself allocate.
    static TRACK: Cell<bool> = const { Cell::new(false) };
    /// Allocations (malloc + realloc) observed while `TRACK` was set.
    static COUNT: Cell<u64> = const { Cell::new(0) };
}

/// A `#[global_allocator]` that delegates to [`System`] and counts
/// allocations on threads that opted in via [`start_counting`].
pub struct CountingAlloc;

impl CountingAlloc {
    #[inline]
    fn note(&self) {
        // try_with: never panic (and never allocate) if TLS is gone —
        // e.g. allocations from other TLS destructors at thread exit
        let _ = TRACK.try_with(|t| {
            if t.get() {
                let _ = COUNT.try_with(|c| c.set(c.get() + 1));
            }
        });
    }
}

// SAFETY: every method delegates directly to `System`, which upholds the
// GlobalAlloc contract; the counting side effect touches only plain
// thread-local Cells (no allocation, no reentrancy into the allocator).
unsafe impl GlobalAlloc for CountingAlloc {
    #[inline]
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.note();
        // SAFETY: forwarded verbatim; caller upholds the layout contract
        unsafe { System.alloc(layout) }
    }

    #[inline]
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // frees are not counted: the contract is "no new heap memory on
        // the hot path", and a free implies a counted earlier alloc
        // SAFETY: forwarded verbatim; caller upholds the ptr/layout pair
        unsafe { System.dealloc(ptr, layout) }
    }

    #[inline]
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // a grow-in-place is still a heap interaction the pooling is
        // supposed to eliminate, so realloc counts like alloc
        self.note();
        // SAFETY: forwarded verbatim; caller upholds the realloc contract
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    #[inline]
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        self.note();
        // SAFETY: forwarded verbatim; caller upholds the layout contract
        unsafe { System.alloc_zeroed(layout) }
    }
}

/// Zero the current thread's counter and start counting its allocations.
pub fn start_counting() {
    let _ = COUNT.try_with(|c| c.set(0));
    let _ = TRACK.try_with(|t| t.set(true));
}

/// Stop counting on the current thread and return the number of
/// allocations (alloc + realloc + alloc_zeroed) since [`start_counting`].
pub fn stop_counting() -> u64 {
    let _ = TRACK.try_with(|t| t.set(false));
    COUNT.try_with(|c| c.get()).unwrap_or(0)
}

/// Count the allocations `f` performs on this thread. Only meaningful in
/// a binary that installed [`CountingAlloc`] as its global allocator —
/// otherwise it returns 0 (nothing notes into the counter), which is why
/// the zero-alloc assertions live in `tests/plan_equiv.rs` (which
/// installs it) and not in `cargo test --lib`.
pub fn count_allocs<R>(f: impl FnOnce() -> R) -> (u64, R) {
    start_counting();
    let out = f();
    (stop_counting(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The lib-test binary does NOT install CountingAlloc, so the counter
    // never increments here — these tests pin the harness mechanics
    // (reset-on-start, off-by-default), not the counting itself, which
    // tests/plan_equiv.rs exercises under the real #[global_allocator].

    #[test]
    fn counter_resets_on_start_and_reads_back() {
        start_counting();
        let n = stop_counting();
        assert_eq!(n, 0, "no CountingAlloc installed → nothing counted");
    }

    #[test]
    fn count_allocs_returns_closure_output() {
        let (n, v) = count_allocs(|| vec![1u8; 64].len());
        assert_eq!(v, 64);
        assert_eq!(n, 0, "lib tests run on the plain system allocator");
    }
}
