//! Mini property-testing framework (proptest is unavailable offline).
//!
//! [`forall`] runs a predicate over `cases` seeded random inputs; on the
//! first failure it panics with the *case seed*, so `forall_case(seed, f)`
//! reproduces it exactly. Generators are plain closures over [`Rng`].

pub mod alloc;
pub mod model;

use crate::util::rng::Rng;

/// Run `f` for `cases` randomized cases. `f` gets a per-case RNG and
/// returns `Err(msg)` to fail the property.
pub fn forall<F>(root_seed: u64, cases: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = root_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case);
        if let Err(msg) = f(&mut Rng::new(case_seed)) {
            panic!(
                "property failed (case {case}/{cases}, case_seed={case_seed:#x}): {msg}\n\
                 reproduce with testing::forall_case({case_seed:#x}, f)"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn forall_case<F>(case_seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    if let Err(msg) = f(&mut Rng::new(case_seed)) {
        panic!("case {case_seed:#x} failed: {msg}");
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Common generators.
pub mod gen {
    use crate::util::rng::Rng;

    /// usize in [lo, hi] inclusive.
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.usize_below(hi - lo + 1)
    }

    /// f64 in [lo, hi).
    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        rng.range_f64(lo, hi)
    }

    /// Vec of f32 in [-1, 1).
    pub fn f32_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    /// Random 0/1 labels.
    pub fn labels(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| (rng.f64() < 0.5) as u32 as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::sync::atomic::AtomicU64::new(0);
        forall(1, 50, |rng| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let x = rng.f64();
            prop_assert!((0.0..1.0).contains(&x), "x={x}");
            Ok(())
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        forall(2, 100, |rng| {
            let x = rng.f64();
            prop_assert!(x < 0.9, "x={x}");
            Ok(())
        });
    }

    #[test]
    fn generators_respect_bounds() {
        forall(3, 100, |rng| {
            let n = gen::usize_in(rng, 5, 10);
            prop_assert!((5..=10).contains(&n));
            let v = gen::f32_vec(rng, n);
            prop_assert!(v.len() == n);
            prop_assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
            Ok(())
        });
    }
}
