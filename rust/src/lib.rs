//! # CPR: failure-tolerant training for deep-learning recommendation
//!
//! Reproduction of *"CPR: Understanding and Improving Failure Tolerant
//! Training for Deep Learning Recommendation with Partial Recovery"*
//! (Maeng et al., 2020) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordination contribution: an emulated
//!   distributed DLRM training job (sharded Emb PS cluster, N synchronous
//!   data-parallel trainers), checkpoint manager with full/partial recovery and the
//!   SCAR/MFU/SSU priority schemes, PLS-driven interval selection, failure
//!   injection, and the paper's full evaluation harness.
//! * **L2** — the DLRM forward/backward as a JAX graph, AOT-lowered to HLO
//!   text at build time (`python/compile/`), executed here via PJRT.
//! * **L1** — Pallas kernels for the compute hot-spots, lowered into the
//!   same HLO.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

// Every unsafe block/impl must carry a `// SAFETY:` contract; combined
// with the `invariant-lint` workspace tool (which confines `unsafe` to an
// allowlisted module set) and the Miri/TSan/loom CI lanes, this keeps the
// crate's unsafe surface enumerable — see DESIGN.md "Concurrency model &
// unsafe inventory".
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod analysis;
pub mod bench;
pub mod checkpoint;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod embedding;
pub mod failure;
pub mod metrics;
pub mod pls;
pub mod policy;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod telemetry;
pub mod testing;
pub mod trace;
pub mod trainer;
pub mod util;
