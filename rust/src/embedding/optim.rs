//! Sparse-embedding optimizers.
//!
//! Production DLRM trains embeddings with row-wise AdaGrad (Naumov et al.
//! 2019); checkpoints must then include the optimizer state (paper §2.2:
//! "checkpoints usually include the model parameters, iteration/epoch
//! counts, and the state of the optimizer"), which partial recovery must
//! restore consistently with the rows. [`EmbOptimizer`] selects the rule;
//! the per-row accumulator lives next to the shard in
//! [`crate::embedding::PsCluster`] and rides through
//! [`crate::checkpoint::CheckpointStore`] with the rows.

/// Update rule for embedding rows.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EmbOptimizer {
    /// plain SGD: w -= lr * g
    Sgd,
    /// row-wise AdaGrad: a += mean(g²); w -= lr / sqrt(a + eps) * g
    /// (one f32 accumulator per row — the DLRM production choice)
    RowAdagrad { eps: f32 },
}

impl EmbOptimizer {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "sgd" => Ok(EmbOptimizer::Sgd),
            "adagrad" | "rowwise-adagrad" => {
                Ok(EmbOptimizer::RowAdagrad { eps: 1e-8 })
            }
            _ => anyhow::bail!("unknown embedding optimizer {s:?} (sgd|adagrad)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EmbOptimizer::Sgd => "sgd",
            EmbOptimizer::RowAdagrad { .. } => "rowwise-adagrad",
        }
    }

    /// Does this optimizer carry per-row state that checkpoints must save?
    pub fn has_state(&self) -> bool {
        matches!(self, EmbOptimizer::RowAdagrad { .. })
    }

    /// Apply the update for one row. `w` is the row slice, `g` the gradient
    /// slice, `a` the row's accumulator cell (ignored for SGD). Returns the
    /// effective step scale used (for tests/diagnostics).
    #[inline]
    pub fn apply(&self, w: &mut [f32], g: &[f32], a: &mut f32, lr: f32) -> f32 {
        match *self {
            EmbOptimizer::Sgd => {
                for (wi, gi) in w.iter_mut().zip(g) {
                    *wi -= lr * gi;
                }
                lr
            }
            EmbOptimizer::RowAdagrad { eps } => {
                let mean_sq: f32 =
                    g.iter().map(|x| x * x).sum::<f32>() / g.len() as f32;
                *a += mean_sq;
                let scale = lr / (a.sqrt() + eps);
                for (wi, gi) in w.iter_mut().zip(g) {
                    *wi -= scale * gi;
                }
                scale
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_applies_plain_step() {
        let mut w = vec![1.0f32, 2.0];
        let mut a = 0.0;
        EmbOptimizer::Sgd.apply(&mut w, &[0.5, -0.5], &mut a, 0.1);
        assert_eq!(w, vec![0.95, 2.05]);
        assert_eq!(a, 0.0, "SGD must not touch the accumulator");
    }

    #[test]
    fn adagrad_shrinks_effective_lr_over_hits() {
        let opt = EmbOptimizer::RowAdagrad { eps: 1e-8 };
        let mut w = vec![0.0f32; 4];
        let mut a = 0.0;
        let g = vec![1.0f32; 4];
        let s1 = opt.apply(&mut w, &g, &mut a, 1.0);
        let s2 = opt.apply(&mut w, &g, &mut a, 1.0);
        let s3 = opt.apply(&mut w, &g, &mut a, 1.0);
        assert!(s1 > s2 && s2 > s3, "{s1} {s2} {s3}");
        assert!((s1 - 1.0).abs() < 1e-4); // first step ≈ lr/sqrt(1)
    }

    #[test]
    fn adagrad_accumulates_mean_square() {
        let opt = EmbOptimizer::RowAdagrad { eps: 1e-8 };
        let mut w = vec![0.0f32; 2];
        let mut a = 0.0;
        opt.apply(&mut w, &[3.0, 4.0], &mut a, 0.0); // lr 0: state only
        assert!((a - 12.5).abs() < 1e-6); // (9+16)/2
        assert_eq!(w, vec![0.0, 0.0]);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(EmbOptimizer::parse("sgd").unwrap(), EmbOptimizer::Sgd);
        assert!(EmbOptimizer::parse("adagrad").unwrap().has_state());
        assert!(EmbOptimizer::parse("momentum").is_err());
    }
}
