//! Emulated embedding parameter-server (Emb PS) cluster.
//!
//! Production DLRM shards its embedding tables across many Emb PS nodes
//! (paper §2.1, model parallelism). We emulate the same topology inside one
//! process: every table is row-sharded round-robin across `n_nodes`
//! [`EmbPsNode`]s — global row `r` of any table lives on node `r % n_nodes`
//! at local row `r / n_nodes`. A node failure therefore wipes a ~1/n slice
//! of EVERY table, exactly the paper's failure unit.
//!
//! Concurrency model (machine-checked since PR 9; see DESIGN.md
//! "Concurrency model & unsafe inventory"):
//!
//! * every node's *non-shard* state sits behind its own
//!   [`crate::cluster::lock::NodeLock`], so the whole data plane
//!   (gather / sparse update / row reads) is `&self` — two trainers
//!   touching rows owned by *different* nodes never contend, and a
//!   trainer that panics mid-update fails only the node it was writing
//!   (the lock converts poison into a node kill; see `cluster::lock`);
//! * the shard floats themselves live in [`AtomicF32s`] word stores
//!   (`shard_words`, outside the lock), so the guard-free serving
//!   seqlock reads race the writers with *defined* behavior — no
//!   `read_volatile`, no raw pointers, no `unsafe` anywhere in this
//!   file. Writers still only mutate a node's words while holding its
//!   write guard (or dead-node exclusivity during respawn), which is
//!   what makes the [`SeqLock`] epoch protocol sound;
//! * ordering of same-node updates across trainers is the caller's
//!   contract (`cluster::ShardedPs` sequences them with per-node
//!   turnstiles).
//!
//! The trainer gathers rows for a minibatch, runs the AOT train-step (L2),
//! and scatters the returned embedding gradient back as a sparse SGD
//! update. CPR's checkpoint trackers observe the same access stream.

pub mod optim;

pub use optim::EmbOptimizer;

use crate::cluster::lock::{NodeLock, NodeReadGuard, NodeWriteGuard};
use crate::cluster::plan::{BatchPlan, NodeSet, PlanScratch};
use crate::cluster::seqlock::{AtomicF32s, SeqLock};
use crate::cluster::{ServeError, StatCounters};
use crate::util::rng::SplitMix64;
use crate::util::threads::{parallel_chunks, parallel_chunks_mut};

/// Row-count + vector width of one logical embedding table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TableInfo {
    pub rows: usize,
    pub dim: usize,
}

/// One emulated Emb PS node's lock-guarded state: the per-row optimizer
/// accumulators (row-wise AdaGrad). The embedding words themselves live
/// outside the lock in `PsCluster::shard_words` so guard-free serving
/// readers never alias a writer's `&mut` — the write guard still
/// serializes every mutation of both halves.
#[derive(Debug)]
pub struct EmbPsNode {
    /// per-table optimizer accumulators, one f32 per local row
    opt_state: Vec<Vec<f32>>,
}

/// The sharded Emb PS cluster (in-process backend).
#[derive(Debug)]
pub struct PsCluster {
    pub tables: Vec<TableInfo>,
    pub n_nodes: usize,
    nodes: Vec<NodeLock<EmbPsNode>>,
    /// per-node per-table embedding words, local_row-major
    /// [local_rows * dim]; atomic so seqlock readers race writers without
    /// UB. INVARIANT: stores only while holding the node's write guard
    /// (or dead-node exclusivity inside respawn).
    shard_words: Vec<Vec<AtomicF32s>>,
    /// serving-plane seqlocks, one per node (same indexing as `nodes`)
    serve: Vec<SeqLock>,
    seed: u64,
    /// operation counters for the `PsBackend` trait view
    pub(crate) stats: StatCounters,
}

/// Rows of a table owned by `node_id` under the fixed round-robin sharding
/// (global % n_nodes == node_id). Shared with the threaded backend.
#[inline]
pub fn shard_rows(rows: usize, n_nodes: usize, node_id: usize) -> usize {
    rows / n_nodes + usize::from(rows % n_nodes > node_id)
}

/// Deterministic init value for (table, global_row, d): uniform in
/// [-0.05, 0.05]. Pure function so failure recovery "from scratch" and
/// golden tests agree without storing the init.
#[inline]
pub fn init_value(seed: u64, table: usize, row: usize, d: usize) -> f32 {
    let mut h = SplitMix64::new(
        seed ^ ((table as u64) << 48) ^ ((row as u64) << 16) ^ d as u64,
    );
    ((h.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64) * 0.1 - 0.05) as f32
}

impl PsCluster {
    pub fn new(tables: Vec<TableInfo>, n_nodes: usize, seed: u64) -> Self {
        assert!(n_nodes >= 1);
        let mut nodes = Vec::with_capacity(n_nodes);
        let mut shard_words = Vec::with_capacity(n_nodes);
        for id in 0..n_nodes {
            let (shards, opt_state) =
                crate::cluster::init_node_state(&tables, n_nodes, id, seed);
            shard_words
                .push(shards.iter().map(|s| AtomicF32s::from_f32s(s)).collect());
            nodes.push(NodeLock::new(EmbPsNode { opt_state }));
        }
        let serve = (0..n_nodes).map(|_| SeqLock::new()).collect();
        Self { tables, n_nodes, nodes, shard_words, serve, seed,
               stats: StatCounters::default() }
    }

    #[inline]
    fn local_rows_static(rows: usize, n_nodes: usize, node_id: usize) -> usize {
        shard_rows(rows, n_nodes, node_id)
    }

    /// (owner node, local row) of a global row.
    #[inline]
    pub fn route(&self, global_row: usize) -> (usize, usize) {
        crate::cluster::route_row(global_row, self.n_nodes)
    }

    pub fn local_rows(&self, table: usize, node_id: usize) -> usize {
        Self::local_rows_static(self.tables[table].rows, self.n_nodes, node_id)
    }

    /// Is the node serving? `false` after a kill or a poison-converted
    /// writer panic, until [`PsCluster::respawn_node`].
    pub fn alive(&self, node: usize) -> bool {
        !self.nodes[node].is_dead()
    }

    fn node_read(&self, node: usize) -> NodeReadGuard<'_, EmbPsNode> {
        self.nodes[node].read().unwrap_or_else(|_| {
            panic!("Emb PS node {node} is dead (killed or failed, not respawned)")
        })
    }

    fn node_write(&self, node: usize) -> NodeWriteGuard<'_, EmbPsNode> {
        self.nodes[node].write().unwrap_or_else(|_| {
            panic!("Emb PS node {node} is dead (killed or failed, not respawned)")
        })
    }

    /// Seqlock writer entry for `node`. Caller must hold the node's write
    /// guard (or, for respawn, the dead-node exclusivity of
    /// [`NodeLock::revive_with`]) — see [`SeqLock::write_begin`].
    #[inline]
    fn serve_write_begin(&self, node: usize) {
        self.serve[node].write_begin();
    }

    /// Seqlock writer exit for `node`: republish an even sequence.
    #[inline]
    fn serve_write_end(&self, node: usize) {
        self.serve[node].write_end();
    }

    /// Serving-plane single-hot gather (`indices` [B, T] row-major, `out`
    /// [B, T, dim]): per-row seqlock reads, no `NodeLock` guard, no
    /// quiesce. Rows of a dead node return [`ServeError::NodeDown`]
    /// instead of blocking on recovery; `out` is unspecified on `Err`.
    pub fn serve_gather(&self, indices: &[u32], out: &mut [f32]) -> Result<(), ServeError> {
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        debug_assert!(self.tables.iter().all(|i| i.dim == dim));
        debug_assert_eq!(out.len(), indices.len() * dim);
        let mut retries = 0u64;
        for (slot, &row) in indices.iter().enumerate() {
            let tab = slot % t;
            let (node, local) = self.route(row as usize);
            let dst = &mut out[slot * dim..(slot + 1) * dim];
            match self.serve_row_into(node, tab, local, dst) {
                Ok(r) => retries += r,
                Err(e) => {
                    self.stats.add_serve_retries(retries);
                    return Err(e);
                }
            }
        }
        self.stats.bump_serve_read();
        self.stats.add_serve_retries(retries);
        Ok(())
    }

    /// One seqlock-validated row copy; returns the retries paid. The copy
    /// races writers by construction — the word loads are atomic (no UB)
    /// and the copy only escapes when the sequence counter proves no
    /// writer overlapped it.
    fn serve_row_into(
        &self,
        node: usize,
        table: usize,
        local: usize,
        dst: &mut [f32],
    ) -> Result<u64, ServeError> {
        let words = &self.shard_words[node][table];
        let off = local * dst.len();
        self.serve[node]
            .read(
                || words.load_into(off, &mut *dst),
                || self.nodes[node].is_dead(),
            )
            .map_err(|_| ServeError::NodeDown { node })
    }

    /// Which nodes a routed index batch touches. A stack bitset — the old
    /// `vec![false; n_nodes]` allocated on every gather *and* apply of the
    /// same batch; planned callers skip even this scan by reusing the
    /// plan's bitset.
    fn touched_nodes(&self, indices: &[u32]) -> NodeSet {
        let mut touched = NodeSet::new();
        for &row in indices {
            touched.insert(row as usize % self.n_nodes);
        }
        touched
    }

    /// Read one row into `out` (len == dim).
    #[inline]
    pub fn read_row(&self, table: usize, global_row: usize, out: &mut [f32]) {
        let (node, local) = self.route(global_row);
        let dim = self.tables[table].dim;
        // guard excludes writers; the word loads then happen-after every
        // prior writer's guard release
        let _g = self.node_read(node);
        self.shard_words[node][table].load_into(local * dim, out);
    }

    /// Copy of one node's shard of `table` (checkpoint/test inspection).
    pub fn shard(&self, node: usize, table: usize) -> Vec<f32> {
        let _g = self.node_read(node);
        self.shard_words[node][table].to_vec()
    }

    /// Copy of one node's optimizer accumulators for `table`.
    pub fn opt_shard(&self, node: usize, table: usize) -> Vec<f32> {
        self.node_read(node).opt_state[table].clone()
    }

    /// Batched row fetch for priority checkpointing: rows' embedding data
    /// ([rows.len() * dim], in `rows` order) + their optimizer
    /// accumulators. Takes each needed node's read guard once, in
    /// ascending node order — the same lock order every multi-node path
    /// uses, so concurrent readers and appliers cannot deadlock.
    pub fn read_rows(&self, table: usize, rows: &[u32]) -> (Vec<f32>, Vec<f32>) {
        let dim = self.tables[table].dim;
        let mut data = vec![0.0f32; rows.len() * dim];
        let mut opt = vec![0.0f32; rows.len()];
        let touched = self.touched_nodes(rows);
        let guards: Vec<Option<NodeReadGuard<'_, EmbPsNode>>> = (0..self.n_nodes)
            .map(|n| touched.get(n).then(|| self.node_read(n)))
            .collect();
        for (i, &row) in rows.iter().enumerate() {
            let (node, local) = self.route(row as usize);
            let g = guards[node].as_ref().unwrap();
            self.shard_words[node][table]
                .load_into(local * dim, &mut data[i * dim..(i + 1) * dim]);
            opt[i] = g.opt_state[table][local];
        }
        (data, opt)
    }

    /// Gather a minibatch: `indices` is [B, T] row-major (T = #tables);
    /// `out` is filled as [B, T, dim] row-major. All tables share `dim`.
    pub fn gather(&self, indices: &[u32], out: &mut [f32]) {
        self.gather_pooled(indices, 1, out);
    }

    /// Multi-hot gather with sum pooling: `indices` is [B, T, H] row-major
    /// (H = hotness); `out` is [B, T, dim] with out[b,t] = Σ_h row(idx_h).
    /// This is the Rust-side counterpart of the L1 `embedding_bag` kernel
    /// (the pooled vector is what the L2 graph receives).
    ///
    /// Concurrency: takes read guards only on the nodes the batch touches,
    /// so gathers against disjoint nodes (and any number of gathers
    /// against the same node) run fully in parallel. The guards are held
    /// on the calling thread for the whole fan-out (excluding writers);
    /// worker threads read the atomic shard words directly and write
    /// disjoint `&mut` output chunks handed out by
    /// [`parallel_chunks_mut`] — the old `SendPtr` raw-pointer escape
    /// hatch is gone.
    pub fn gather_pooled(&self, indices: &[u32], hotness: usize, out: &mut [f32]) {
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        debug_assert!(self.tables.iter().all(|i| i.dim == dim));
        let b = indices.len() / (t * hotness);
        debug_assert_eq!(out.len(), b * t * dim);
        let touched = self.touched_nodes(indices);
        let _guards: Vec<Option<NodeReadGuard<'_, EmbPsNode>>> = (0..self.n_nodes)
            .map(|n| touched.get(n).then(|| self.node_read(n)))
            .collect();
        // Thread spawn costs ~50 µs; below ~2k samples a serial gather is
        // faster than fanning out (measured: 18 µs serial vs 55 µs across
        // 2 threads at B=128) — see EXPERIMENTS.md §Perf #5.
        if hotness == 1 {
            // specialized single-hot path: a straight row copy per slot
            // (the generic loop costs 2× at Criteo shapes — §Perf #5)
            parallel_chunks_mut(out, b, t * dim, 8, 2048, |lo, hi, chunk| {
                for (off, &row) in indices[lo * t..hi * t].iter().enumerate() {
                    let tab = (lo * t + off) % t;
                    let (node, local) = self.route(row as usize);
                    self.shard_words[node][tab].load_into(
                        local * dim,
                        &mut chunk[off * dim..(off + 1) * dim],
                    );
                }
            });
            return;
        }
        parallel_chunks_mut(out, b, t * dim, 8, 2048, |lo, hi, chunk| {
            for s in lo..hi {
                for tab in 0..t {
                    let dst = &mut chunk[((s - lo) * t + tab) * dim..][..dim];
                    for h in 0..hotness {
                        let row = indices[(s * t + tab) * hotness + h] as usize;
                        let (node_id, local) = self.route(row);
                        let words = &self.shard_words[node_id][tab];
                        if h == 0 {
                            words.load_into(local * dim, dst);
                        } else {
                            words.add_into(local * dim, dst);
                        }
                    }
                }
            }
        });
    }

    /// Sparse SGD convenience wrapper (hotness 1).
    pub fn sgd_update(&self, indices: &[u32], grads: &[f32], lr: f32) {
        self.apply_grads(indices, 1, grads, lr, EmbOptimizer::Sgd);
    }

    /// Load one row into `buf`, run the optimizer on it, and store it
    /// back — the scatter unit of every apply path. The load/store
    /// round-trip through the atomic words is bit-exact, so the result is
    /// identical floats to the old in-place slice mutation.
    #[inline]
    fn apply_row(
        words: &AtomicF32s,
        local: usize,
        g: &[f32],
        acc: &mut f32,
        lr: f32,
        opt: EmbOptimizer,
        buf: &mut [f32],
    ) {
        let dim = buf.len();
        words.load_into(local * dim, buf);
        opt.apply(buf, g, acc, lr);
        words.store_from(local * dim, buf);
    }

    /// Sparse update: apply `opt` to every (sample, table, hot) slot's row
    /// with the slot's pooled gradient (sum-pool backward broadcasts the
    /// [B, T, dim] gradient to each of the H contributing rows).
    /// Duplicate rows accumulate, matching a dense scatter-add.
    ///
    /// Per-node write guards are taken only for the nodes the batch
    /// touches (ascending node order, so concurrent appliers cannot
    /// deadlock); large batches parallelize over *nodes* so all writes
    /// stay owner-local. Same-node updates are applied in sample order —
    /// identical floats to the pre-refactor global scatter.
    pub fn apply_grads(
        &self,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        let b = indices.len() / (t * hotness);
        debug_assert_eq!(grads.len(), b * t * dim);
        let n_nodes = self.n_nodes;
        let touched = self.touched_nodes(indices);
        // Small batches: one thread applying updates directly beats the
        // per-node fan-out (each parallel worker must scan the whole
        // index list; at B=128 that costs 285 µs vs 30 µs serial —
        // EXPERIMENTS.md §Perf #5). Large batches amortize the scan.
        if b * t * hotness < 16_384 {
            let mut guards: Vec<Option<NodeWriteGuard<'_, EmbPsNode>>> =
                (0..n_nodes)
                    .map(|n| touched.get(n).then(|| self.node_write(n)))
                    .collect();
            for n in 0..n_nodes {
                if touched.get(n) {
                    self.serve_write_begin(n);
                }
            }
            let mut buf = vec![0.0f32; dim];
            for s in 0..b {
                for tab in 0..t {
                    let g = &grads[(s * t + tab) * dim..(s * t + tab + 1) * dim];
                    for h in 0..hotness {
                        let row = indices[(s * t + tab) * hotness + h] as usize;
                        let node_id = row % n_nodes;
                        let local = row / n_nodes;
                        let node = &mut **guards[node_id].as_mut().unwrap();
                        Self::apply_row(&self.shard_words[node_id][tab], local,
                                        g, &mut node.opt_state[tab][local], lr,
                                        opt, &mut buf);
                    }
                }
            }
            for n in 0..n_nodes {
                if touched.get(n) {
                    self.serve_write_end(n);
                }
            }
            return;
        }
        // Each worker thread owns a disjoint set of nodes → disjoint locks.
        parallel_chunks(n_nodes, 8, 1, |nlo, nhi| {
            for node_id in nlo..nhi {
                if touched.get(node_id) {
                    self.apply_grads_node(node_id, indices, hotness, grads, lr, opt);
                }
            }
        });
    }

    /// Apply only the updates owned by `node`, in sample order, under that
    /// node's write guard. This is the sharded data plane's unit of
    /// contention: callers updating different nodes never serialize.
    pub fn apply_grads_node(
        &self,
        node: usize,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        let b = indices.len() / (t * hotness);
        debug_assert_eq!(grads.len(), b * t * dim);
        let n_nodes = self.n_nodes;
        let mut g_node = self.node_write(node);
        self.serve_write_begin(node);
        let mut buf = vec![0.0f32; dim];
        for s in 0..b {
            for tab in 0..t {
                let g = &grads[(s * t + tab) * dim..(s * t + tab + 1) * dim];
                for h in 0..hotness {
                    let row = indices[(s * t + tab) * hotness + h] as usize;
                    if row % n_nodes != node {
                        continue;
                    }
                    let local = row / n_nodes;
                    let n = &mut *g_node;
                    Self::apply_row(&self.shard_words[node][tab], local, g,
                                    &mut n.opt_state[tab][local], lr, opt,
                                    &mut buf);
                }
            }
        }
        self.serve_write_end(node);
    }

    /// Plan-driven pooled gather: fetch each distinct `(table, row)` once
    /// into `scratch.unique_vals`, then reassemble `out` by walking the
    /// plan's slot-placement map in ascending flat-slot order — copy at
    /// `slot % hotness == 0`, add otherwise — which is the *exact*
    /// float-op sequence of [`PsCluster::gather_pooled`], so the result is
    /// bit-identical while hot rows are read from the shard words only
    /// once.
    ///
    /// Allocation discipline: deliberately sequential (the unplanned
    /// path's `parallel_chunks_mut` spawns scoped threads, which
    /// allocates); all storage is the caller's pooled scratch, so the
    /// steady-state call performs zero heap allocations. Lock discipline:
    /// one node read guard at a time, ascending node order, released
    /// before reassembly (reassembly only touches the private scratch).
    pub(crate) fn gather_planned_impl(
        &self,
        plan: &BatchPlan,
        scratch: &mut PlanScratch,
        out: &mut [f32],
    ) {
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        debug_assert!(self.tables.iter().all(|i| i.dim == dim));
        debug_assert_eq!(plan.num_tables(), t);
        debug_assert_eq!(plan.n_nodes(), self.n_nodes);
        let hotness = plan.hotness();
        debug_assert_eq!(out.len() * hotness, plan.n_slots() * dim);
        scratch.unique_vals.resize(plan.n_unique() * dim, 0.0);
        for node in 0..self.n_nodes {
            let range = plan.unique_range(node);
            if range.is_empty() {
                continue;
            }
            let _g = self.node_read(node);
            for u in range {
                let tab = plan.unique_table(u);
                let local = plan.unique_local(u);
                self.shard_words[node][tab]
                    .load_into(local * dim, &mut scratch.unique_vals[u * dim..(u + 1) * dim]);
            }
        }
        for (slot, &u) in plan.slot_unique().iter().enumerate() {
            let src = &scratch.unique_vals[u as usize * dim..(u as usize + 1) * dim];
            let dst = &mut out[(slot / hotness) * dim..][..dim];
            if slot % hotness == 0 {
                dst.copy_from_slice(src);
            } else {
                for (o, s) in dst.iter_mut().zip(src) {
                    *o += s;
                }
            }
        }
    }

    /// Plan-driven sibling of [`PsCluster::apply_grads_node`]: walk the
    /// plan's per-node ascending flat-slot list instead of scanning and
    /// filtering the whole index list. Visits exactly the same slots in
    /// the same order with the same per-slot arithmetic — bit-identical —
    /// and uses `scratch.row_buf` instead of allocating the per-call row
    /// buffer. Applies deliberately do NOT dedup: duplicate rows must
    /// accumulate their gradients slot by slot in sample order.
    pub(crate) fn apply_grads_planned_node_impl(
        &self,
        node: usize,
        plan: &BatchPlan,
        scratch: &mut PlanScratch,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        let hotness = plan.hotness();
        debug_assert_eq!(plan.num_tables(), t);
        debug_assert_eq!(grads.len() * hotness, plan.n_slots() * dim);
        let n_nodes = self.n_nodes;
        let indices = plan.indices();
        let mut g_node = self.node_write(node);
        self.serve_write_begin(node);
        scratch.row_buf.resize(dim, 0.0);
        let buf = &mut scratch.row_buf;
        for &slot in plan.apply_slots(node) {
            let slot = slot as usize;
            let row = indices[slot] as usize;
            debug_assert_eq!(row % n_nodes, node);
            let local = row / n_nodes;
            let tab = (slot / hotness) % t;
            let g = &grads[(slot / hotness) * dim..(slot / hotness + 1) * dim];
            let n = &mut *g_node;
            Self::apply_row(&self.shard_words[node][tab], local, g,
                            &mut n.opt_state[tab][local], lr, opt, buf);
        }
        self.serve_write_end(node);
    }

    /// Reset a node's shards to their deterministic initial values
    /// (recovery when no checkpoint exists yet). Refills the existing
    /// word buffers instead of installing fresh ones — [`AtomicF32s`]
    /// never reallocates, so in-flight guard-free seqlock readers stay
    /// valid across the refill (the odd sequence keeps them from
    /// validating a half-reset row).
    pub fn reset_node_to_init(&self, node_id: usize) {
        let (shards, opt) =
            crate::cluster::init_node_state(&self.tables, self.n_nodes, node_id, self.seed);
        let mut g = self.node_write(node_id);
        self.serve_write_begin(node_id);
        for t in 0..self.tables.len() {
            self.shard_words[node_id][t].copy_from(&shards[t]);
            g.opt_state[t].copy_from_slice(&opt[t]);
        }
        self.serve_write_end(node_id);
    }

    /// A failure hits this node: it stops serving (reads/writes panic with
    /// a "dead" diagnostic) until [`PsCluster::respawn_node`]. The same
    /// transition is taken automatically when a writer panics mid-update
    /// (lock poison → node kill; see `cluster::lock`).
    pub fn kill_node(&self, node: usize) {
        // fail the serving fast path first so a reader cannot start a
        // fresh seqlock attempt against a node already declared dead
        self.serve[node].set_alive(false);
        self.nodes[node].kill();
    }

    /// Bring a dead node back at deterministic init (blank replacement;
    /// the recovery protocol then restores its rows). Panics if the node
    /// is alive — same contract as the threaded backend, so a
    /// respawn-without-kill bug cannot pass on one backend and abort on
    /// the other.
    pub fn respawn_node(&self, node: usize) {
        assert!(self.nodes[node].is_dead(), "node {node} is already alive");
        let (shards, opt) =
            crate::cluster::init_node_state(&self.tables, self.n_nodes, node, self.seed);
        // seqlock epoch around the refill: the word stores happen while
        // the node is still dead (no write guard can exist), and the odd
        // sequence keeps any reader that races the refill from
        // validating a half-initialized row. `revive_with` refills the
        // opt state in place and clears the dead flag last.
        self.serve_write_begin(node);
        for t in 0..shards.len() {
            self.shard_words[node][t].copy_from(&shards[t]);
        }
        self.nodes[node].revive_with(|n| {
            for t in 0..opt.len() {
                n.opt_state[t].copy_from_slice(&opt[t]);
            }
        });
        self.serve_write_end(node);
        self.serve[node].set_alive(true);
    }

    /// Overwrite one node's full state (checkpoint restore path).
    pub fn load_node(&self, node: usize, shards: &[Vec<f32>], opt: &[Vec<f32>]) {
        let mut g = self.node_write(node);
        self.serve_write_begin(node);
        for t in 0..self.tables.len() {
            self.shard_words[node][t].copy_from(&shards[t]);
            g.opt_state[t].copy_from_slice(&opt[t]);
        }
        self.serve_write_end(node);
    }

    /// Clone one node's full state out as (shards, opt) — one copy, taken
    /// under the node's read guard (checkpoint save path).
    pub(crate) fn snapshot_parts(&self, node: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let g = self.node_read(node);
        let shards = self.shard_words[node].iter().map(AtomicF32s::to_vec).collect();
        (shards, g.opt_state.clone())
    }

    /// Export `local_rows` of `table` on `node` under a single node read
    /// guard — the dirty-set (delta-capture) slice of `snapshot_parts`.
    pub(crate) fn snapshot_node_rows_local(
        &self,
        node: usize,
        table: usize,
        local_rows: &[u32],
    ) -> (Vec<f32>, Vec<f32>) {
        let dim = self.tables[table].dim;
        let g = self.node_read(node);
        let words = &self.shard_words[node][table];
        let acc = &g.opt_state[table];
        let mut data = vec![0.0f32; local_rows.len() * dim];
        let mut opt = vec![0.0f32; local_rows.len()];
        for (i, &lr) in local_rows.iter().enumerate() {
            let lr = lr as usize;
            words.load_into(lr * dim, &mut data[i * dim..(i + 1) * dim]);
            opt[i] = acc[lr];
        }
        (data, opt)
    }

    /// Total parameter count across all tables.
    pub fn total_params(&self) -> usize {
        self.tables.iter().map(|t| t.rows * t.dim).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cluster(n_nodes: usize) -> PsCluster {
        PsCluster::new(
            vec![TableInfo { rows: 10, dim: 4 }, TableInfo { rows: 7, dim: 4 }],
            n_nodes,
            42,
        )
    }

    #[test]
    fn routing_is_a_bijection() {
        let c = small_cluster(3);
        for table in 0..2 {
            let rows = c.tables[table].rows;
            let mut seen = std::collections::HashSet::new();
            for r in 0..rows {
                let (node, local) = c.route(r);
                assert!(node < 3);
                assert!(local < c.local_rows(table, node));
                assert!(seen.insert((node, local)));
            }
            // every local slot is hit
            let total: usize = (0..3).map(|n| c.local_rows(table, n)).sum();
            assert_eq!(total, rows);
        }
    }

    #[test]
    fn init_is_deterministic_and_node_count_invariant() {
        // The same (table,row) must hold the same vector regardless of how
        // many PS nodes shard it — failure experiments vary n_nodes.
        let a = small_cluster(2);
        let b = small_cluster(5);
        let mut ra = vec![0.0; 4];
        let mut rb = vec![0.0; 4];
        for t in 0..2 {
            for r in 0..a.tables[t].rows {
                a.read_row(t, r, &mut ra);
                b.read_row(t, r, &mut rb);
                assert_eq!(ra, rb, "table {t} row {r}");
            }
        }
    }

    #[test]
    fn gather_matches_read_row() {
        let c = small_cluster(3);
        let indices: Vec<u32> = vec![0, 1, 9, 6, 3, 2]; // 3 samples x 2 tables
        let mut out = vec![0.0; 3 * 2 * 4];
        c.gather(&indices, &mut out);
        let mut row = vec![0.0; 4];
        for s in 0..3 {
            for t in 0..2 {
                c.read_row(t, indices[s * 2 + t] as usize, &mut row);
                assert_eq!(&out[(s * 2 + t) * 4..(s * 2 + t + 1) * 4], &row[..]);
            }
        }
    }

    #[test]
    fn sgd_update_applies_lr_times_grad() {
        let c = small_cluster(2);
        let indices = vec![5, 2]; // 1 sample, 2 tables
        let mut before = vec![0.0; 4];
        c.read_row(0, 5, &mut before);
        let grads = vec![1.0f32; 8];
        c.sgd_update(&indices, &grads, 0.1);
        let mut after = vec![0.0; 4];
        c.read_row(0, 5, &mut after);
        for d in 0..4 {
            assert!((after[d] - (before[d] - 0.1)).abs() < 1e-6);
        }
    }

    #[test]
    fn duplicate_rows_accumulate() {
        let c = small_cluster(2);
        // two samples hitting the SAME row of table 0
        let indices = vec![4, 0, 4, 1];
        let mut before = vec![0.0; 4];
        c.read_row(0, 4, &mut before);
        let grads = vec![0.5f32; 16];
        c.sgd_update(&indices, &grads, 1.0);
        let mut after = vec![0.0; 4];
        c.read_row(0, 4, &mut after);
        for d in 0..4 {
            assert!((after[d] - (before[d] - 1.0)).abs() < 1e-6, "{d}");
        }
    }

    #[test]
    fn apply_grads_node_covers_exactly_the_owned_rows() {
        // applying node-by-node must equal the whole-batch apply
        let a = small_cluster(3);
        let b = small_cluster(3);
        let indices = vec![0, 1, 4, 5, 8, 2, 3, 6]; // 4 samples x 2 tables
        let grads: Vec<f32> = (0..4 * 2 * 4).map(|i| 0.01 * i as f32).collect();
        a.apply_grads(&indices, 1, &grads, 0.5, EmbOptimizer::Sgd);
        for node in 0..3 {
            b.apply_grads_node(node, &indices, 1, &grads, 0.5, EmbOptimizer::Sgd);
        }
        for node in 0..3 {
            for t in 0..2 {
                assert_eq!(a.shard(node, t), b.shard(node, t), "node {node}");
            }
        }
    }

    #[test]
    fn reset_node_restores_init() {
        let c = small_cluster(3);
        let indices = vec![3, 3];
        let grads = vec![1.0f32; 8];
        c.sgd_update(&indices, &grads, 1.0);
        // row 3 lives on node 0 (3 % 3)
        c.reset_node_to_init(0);
        let fresh = small_cluster(3);
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        c.read_row(0, 3, &mut a);
        fresh.read_row(0, 3, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn reset_does_not_touch_other_nodes() {
        let c = small_cluster(3);
        let indices = vec![4, 4]; // node 1
        let grads = vec![1.0f32; 8];
        c.sgd_update(&indices, &grads, 1.0);
        let mut before = vec![0.0; 4];
        c.read_row(0, 4, &mut before);
        c.reset_node_to_init(0);
        let mut after = vec![0.0; 4];
        c.read_row(0, 4, &mut after);
        assert_eq!(before, after);
    }

    #[test]
    fn total_params() {
        let c = small_cluster(2);
        assert_eq!(c.total_params(), (10 + 7) * 4);
    }

    #[test]
    fn gather_pooled_sums_hot_rows() {
        let c = small_cluster(2);
        // 1 sample, 2 tables, hotness 2
        let indices = vec![1, 3, 0, 2];
        let mut pooled = vec![0.0; 2 * 4];
        c.gather_pooled(&indices, 2, &mut pooled);
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        c.read_row(0, 1, &mut a);
        c.read_row(0, 3, &mut b);
        for d in 0..4 {
            assert!((pooled[d] - (a[d] + b[d])).abs() < 1e-6);
        }
    }

    #[test]
    fn multi_hot_grad_broadcasts_to_all_rows() {
        let c = small_cluster(2);
        let indices = vec![1, 3, 0, 2]; // table0: rows 1,3; table1: rows 0,2
        let mut r1 = vec![0.0; 4];
        let mut r3 = vec![0.0; 4];
        c.read_row(0, 1, &mut r1);
        c.read_row(0, 3, &mut r3);
        let grads = vec![1.0f32; 2 * 4]; // [B=1, T=2, dim=4]
        c.apply_grads(&indices, 2, &grads, 0.5, EmbOptimizer::Sgd);
        let mut a1 = vec![0.0; 4];
        let mut a3 = vec![0.0; 4];
        c.read_row(0, 1, &mut a1);
        c.read_row(0, 3, &mut a3);
        for d in 0..4 {
            assert!((a1[d] - (r1[d] - 0.5)).abs() < 1e-6);
            assert!((a3[d] - (r3[d] - 0.5)).abs() < 1e-6);
        }
    }

    #[test]
    fn adagrad_state_accumulates_and_damps() {
        let c = small_cluster(2);
        let opt = EmbOptimizer::RowAdagrad { eps: 1e-8 };
        let indices = vec![5, 2];
        let grads = vec![1.0f32; 8];
        let mut before = vec![0.0; 4];
        c.read_row(0, 5, &mut before);
        c.apply_grads(&indices, 1, &grads, 1.0, opt);
        let (node, local) = c.route(5);
        assert!(c.opt_shard(node, 0)[local] > 0.0, "accumulator untouched");
        let mut after1 = vec![0.0; 4];
        c.read_row(0, 5, &mut after1);
        let step1 = (before[0] - after1[0]).abs();
        c.apply_grads(&indices, 1, &grads, 1.0, opt);
        let mut after2 = vec![0.0; 4];
        c.read_row(0, 5, &mut after2);
        let step2 = (after1[0] - after2[0]).abs();
        assert!(step2 < step1, "adagrad must damp: {step1} -> {step2}");
    }

    #[test]
    fn reset_node_clears_optimizer_state() {
        let c = small_cluster(3);
        let opt = EmbOptimizer::RowAdagrad { eps: 1e-8 };
        c.apply_grads(&[3, 3], 1, &[1.0f32; 8], 1.0, opt);
        let (node, local) = c.route(3);
        assert!(c.opt_shard(node, 0)[local] > 0.0);
        c.reset_node_to_init(node);
        assert_eq!(c.opt_shard(node, 0)[local], 0.0);
    }

    #[test]
    fn serve_gather_matches_locked_gather() {
        let c = small_cluster(3);
        c.apply_grads(&[4, 2, 7, 5], 1, &[0.7f32; 16], 1.0,
                      EmbOptimizer::RowAdagrad { eps: 1e-8 });
        let indices = vec![0u32, 1, 9, 6, 3, 2]; // 3 samples x 2 tables
        let mut locked = vec![0.0; 3 * 2 * 4];
        let mut served = vec![0.0; 3 * 2 * 4];
        c.gather(&indices, &mut locked);
        c.serve_gather(&indices, &mut served).unwrap();
        assert_eq!(locked, served);
        let s = c.stats.read();
        assert_eq!(s.serve_reads, 1);
        assert_eq!(s.serve_retries, 0, "uncontended serve must not retry");
    }

    #[test]
    fn serve_gather_on_dead_node_errors_not_hangs() {
        let c = small_cluster(3);
        c.kill_node(1);
        // row 4 lives on node 1 (4 % 3)
        let mut out = vec![0.0; 2 * 4];
        let err = c.serve_gather(&[4, 2], &mut out).unwrap_err();
        assert_eq!(err, ServeError::NodeDown { node: 1 });
        // survivors still serve
        c.serve_gather(&[3, 2], &mut out).unwrap();
        // recovery restores service for the victim's rows
        c.respawn_node(1);
        c.serve_gather(&[4, 2], &mut out).unwrap();
        let mut want = vec![0.0; 4];
        c.read_row(0, 4, &mut want);
        assert_eq!(&out[..4], &want[..]);
    }

    #[test]
    fn serve_gather_survives_reset_and_load() {
        let c = small_cluster(2);
        c.apply_grads(&[5, 2], 1, &[1.0f32; 8], 0.5, EmbOptimizer::Sgd);
        let (shards, opt) = c.snapshot_parts(1);
        c.reset_node_to_init(1);
        let mut out = vec![0.0; 2 * 4];
        c.serve_gather(&[5, 2], &mut out).unwrap();
        let fresh = small_cluster(2);
        let mut want = vec![0.0; 4];
        fresh.read_row(0, 5, &mut want);
        assert_eq!(&out[..4], &want[..], "reset must serve init values");
        c.load_node(1, &shards, &opt);
        c.serve_gather(&[5, 2], &mut out).unwrap();
        c.read_row(0, 5, &mut want);
        assert_eq!(&out[..4], &want[..], "load must serve restored values");
    }

    #[test]
    fn serve_gather_after_writer_panic_errors_within_spin_budget() {
        // A writer that dies mid-update leaves the victim's sequence
        // counter odd forever; the reader must convert that into
        // NodeDown via its spin-budget fallback instead of spinning.
        let c = small_cluster(3);
        let victim_batch = vec![9999u32, 0]; // OOB local slot on node 0
        let panicked = std::thread::scope(|s| {
            s.spawn(|| c.apply_grads(&victim_batch, 1, &[0.1f32; 8], 1.0,
                                     EmbOptimizer::Sgd))
                .join()
        });
        assert!(panicked.is_err());
        let mut out = vec![0.0; 2 * 4];
        let err = c.serve_gather(&[3, 2], &mut out).unwrap_err(); // row 3 → node 0
        assert_eq!(err, ServeError::NodeDown { node: 0 });
    }

    #[test]
    fn poisoned_node_reads_as_failed_not_corrupt() {
        // THE lock-poisoning contract (satellite): a trainer that panics
        // mid-apply fails exactly the node it was writing. Survivors keep
        // serving, readers of the victim see "dead" (never half-written
        // floats), and the standard kill/respawn/restore protocol revives
        // it.
        let c = small_cluster(3);
        // row 9999 routes to node 0 (9999 % 3 == 0) but its local slot is
        // out of bounds → the apply panics while holding node 0's write
        // guard, exactly like a trainer dying mid-update. The second slot
        // also routes to node 0, so no other node's guard is held at the
        // panic (a held guard conservatively fails its node).
        let victim_batch = vec![9999u32, 0]; // 1 sample x 2 tables
        let panicked = std::thread::scope(|s| {
            s.spawn(|| c.apply_grads(&victim_batch, 1, &[0.1f32; 8], 1.0,
                                     EmbOptimizer::Sgd))
                .join()
        });
        assert!(panicked.is_err(), "OOB apply should have panicked");
        assert!(!c.alive(0), "poisoned node must read as failed");
        assert!(c.alive(1) && c.alive(2), "survivors must stay alive");
        // reading the failed node panics with a 'dead' diagnostic...
        let read = std::thread::scope(|s| {
            s.spawn(|| {
                let mut out = vec![0.0; 4];
                c.read_row(0, 3, &mut out); // row 3 lives on node 0
            })
            .join()
        });
        assert!(read.is_err(), "reading a failed node must not succeed");
        // ...while survivors serve normally
        let mut out = vec![0.0; 4];
        c.read_row(0, 4, &mut out); // row 4 lives on node 1
        // recovery: kill (idempotent) + respawn brings the node back at
        // deterministic init — bit-identical to a fresh cluster
        c.kill_node(0);
        c.respawn_node(0);
        assert!(c.alive(0));
        let fresh = small_cluster(3);
        let mut got = vec![0.0; 4];
        let mut want = vec![0.0; 4];
        c.read_row(0, 3, &mut got);
        fresh.read_row(0, 3, &mut want);
        assert_eq!(got, want, "respawned node must be at clean init");
    }
}
