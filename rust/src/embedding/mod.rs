//! Emulated embedding parameter-server (Emb PS) cluster.
//!
//! Production DLRM shards its embedding tables across many Emb PS nodes
//! (paper §2.1, model parallelism). We emulate the same topology inside one
//! process: every table is row-sharded round-robin across `n_nodes`
//! [`EmbPsNode`]s — global row `r` of any table lives on node `r % n_nodes`
//! at local row `r / n_nodes`. A node failure therefore wipes a ~1/n slice
//! of EVERY table, exactly the paper's failure unit.
//!
//! The trainer gathers rows for a minibatch, runs the AOT train-step (L2),
//! and scatters the returned embedding gradient back as a sparse SGD
//! update. CPR's checkpoint trackers observe the same access stream.

pub mod optim;

pub use optim::EmbOptimizer;

use crate::cluster::StatCounters;
use crate::util::rng::SplitMix64;
use crate::util::threads::parallel_chunks;

/// Row-count + vector width of one logical embedding table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TableInfo {
    pub rows: usize,
    pub dim: usize,
}

/// One emulated Emb PS node: the local shard of every table plus the
/// per-row optimizer state (row-wise AdaGrad accumulator).
#[derive(Clone, Debug)]
pub struct EmbPsNode {
    /// per-table storage, local_row-major [local_rows * dim]
    shards: Vec<Vec<f32>>,
    /// per-table optimizer accumulators, one f32 per local row
    opt_state: Vec<Vec<f32>>,
}

/// The sharded Emb PS cluster.
#[derive(Clone, Debug)]
pub struct PsCluster {
    pub tables: Vec<TableInfo>,
    pub n_nodes: usize,
    nodes: Vec<EmbPsNode>,
    seed: u64,
    /// operation counters for the `PsBackend` trait view
    pub(crate) stats: StatCounters,
}

/// Rows of a table owned by `node_id` under the fixed round-robin sharding
/// (global % n_nodes == node_id). Shared with the threaded backend.
#[inline]
pub fn shard_rows(rows: usize, n_nodes: usize, node_id: usize) -> usize {
    rows / n_nodes + usize::from(rows % n_nodes > node_id)
}

/// Deterministic init value for (table, global_row, d): uniform in
/// [-0.05, 0.05]. Pure function so failure recovery "from scratch" and
/// golden tests agree without storing the init.
#[inline]
pub fn init_value(seed: u64, table: usize, row: usize, d: usize) -> f32 {
    let mut h = SplitMix64::new(
        seed ^ ((table as u64) << 48) ^ ((row as u64) << 16) ^ d as u64,
    );
    ((h.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64) * 0.1 - 0.05) as f32
}

impl PsCluster {
    pub fn new(tables: Vec<TableInfo>, n_nodes: usize, seed: u64) -> Self {
        assert!(n_nodes >= 1);
        let mut nodes = Vec::with_capacity(n_nodes);
        for node_id in 0..n_nodes {
            let mut shards = Vec::with_capacity(tables.len());
            for (t, info) in tables.iter().enumerate() {
                let local_rows = Self::local_rows_static(info.rows, n_nodes, node_id);
                let mut shard = vec![0.0f32; local_rows * info.dim];
                for lr in 0..local_rows {
                    let global = node_id + lr * n_nodes;
                    for d in 0..info.dim {
                        shard[lr * info.dim + d] = init_value(seed, t, global, d);
                    }
                }
                shards.push(shard);
            }
            let opt_state = tables
                .iter()
                .enumerate()
                .map(|(_, info)| {
                    vec![0.0f32; Self::local_rows_static(info.rows, n_nodes, node_id)]
                })
                .collect();
            nodes.push(EmbPsNode { shards, opt_state });
        }
        Self { tables, n_nodes, nodes, seed, stats: StatCounters::default() }
    }

    #[inline]
    fn local_rows_static(rows: usize, n_nodes: usize, node_id: usize) -> usize {
        shard_rows(rows, n_nodes, node_id)
    }

    /// (owner node, local row) of a global row.
    #[inline]
    pub fn route(&self, global_row: usize) -> (usize, usize) {
        crate::cluster::route_row(global_row, self.n_nodes)
    }

    pub fn local_rows(&self, table: usize, node_id: usize) -> usize {
        Self::local_rows_static(self.tables[table].rows, self.n_nodes, node_id)
    }

    /// Read one row into `out` (len == dim).
    #[inline]
    pub fn read_row(&self, table: usize, global_row: usize, out: &mut [f32]) {
        let (node, local) = self.route(global_row);
        let dim = self.tables[table].dim;
        let shard = &self.nodes[node].shards[table];
        out.copy_from_slice(&shard[local * dim..(local + 1) * dim]);
    }

    /// Raw shard access (checkpoint save path).
    pub fn shard(&self, node: usize, table: usize) -> &[f32] {
        &self.nodes[node].shards[table]
    }

    /// Mutable shard access (checkpoint restore path).
    pub fn shard_mut(&mut self, node: usize, table: usize) -> &mut [f32] {
        &mut self.nodes[node].shards[table]
    }

    /// Optimizer-state shard access (one f32 per local row).
    pub fn opt_shard(&self, node: usize, table: usize) -> &[f32] {
        &self.nodes[node].opt_state[table]
    }

    pub fn opt_shard_mut(&mut self, node: usize, table: usize) -> &mut [f32] {
        &mut self.nodes[node].opt_state[table]
    }

    /// Gather a minibatch: `indices` is [B, T] row-major (T = #tables);
    /// `out` is filled as [B, T, dim] row-major. All tables share `dim`.
    pub fn gather(&self, indices: &[u32], out: &mut [f32]) {
        self.gather_pooled(indices, 1, out);
    }

    /// Multi-hot gather with sum pooling: `indices` is [B, T, H] row-major
    /// (H = hotness); `out` is [B, T, dim] with out[b,t] = Σ_h row(idx_h).
    /// This is the Rust-side counterpart of the L1 `embedding_bag` kernel
    /// (the pooled vector is what the L2 graph receives).
    pub fn gather_pooled(&self, indices: &[u32], hotness: usize, out: &mut [f32]) {
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        debug_assert!(self.tables.iter().all(|i| i.dim == dim));
        let b = indices.len() / (t * hotness);
        debug_assert_eq!(out.len(), b * t * dim);
        // Thread spawn costs ~50 µs; below ~2k samples a serial gather is
        // faster than fanning out (measured: 18 µs serial vs 55 µs across
        // 2 threads at B=128) — see EXPERIMENTS.md §Perf #5.
        let out_ptr = SendPtr(out.as_mut_ptr());
        if hotness == 1 {
            // specialized single-hot path: a straight row copy per slot
            // (the generic loop costs 2× at Criteo shapes — §Perf #5)
            parallel_chunks(b, 8, 2048, |lo, hi| {
                let out_ptr = &out_ptr;
                for (off, &row) in indices[lo * t..hi * t].iter().enumerate() {
                    let slot = lo * t + off;
                    let tab = slot % t;
                    let row = row as usize;
                    let shard = &self.nodes[row % self.n_nodes].shards[tab];
                    let local = row / self.n_nodes;
                    unsafe {
                        std::ptr::copy_nonoverlapping(
                            shard.as_ptr().add(local * dim),
                            out_ptr.0.add(slot * dim),
                            dim,
                        );
                    }
                }
            });
            return;
        }
        parallel_chunks(b, 8, 2048, |lo, hi| {
            let out_ptr = &out_ptr;
            for s in lo..hi {
                for tab in 0..t {
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(
                            out_ptr.0.add((s * t + tab) * dim), dim)
                    };
                    for h in 0..hotness {
                        let row = indices[(s * t + tab) * hotness + h] as usize;
                        let (node, local) = self.route(row);
                        let shard = &self.nodes[node].shards[tab];
                        let src = &shard[local * dim..(local + 1) * dim];
                        if h == 0 {
                            dst.copy_from_slice(src);
                        } else {
                            for (d, v) in dst.iter_mut().zip(src) {
                                *d += v;
                            }
                        }
                    }
                }
            }
        });
    }

    /// Sparse SGD convenience wrapper (hotness 1).
    pub fn sgd_update(&mut self, indices: &[u32], grads: &[f32], lr: f32) {
        self.apply_grads(indices, 1, grads, lr, EmbOptimizer::Sgd);
    }

    /// Sparse update: apply `opt` to every (sample, table, hot) slot's row
    /// with the slot's pooled gradient (sum-pool backward broadcasts the
    /// [B, T, dim] gradient to each of the H contributing rows).
    /// Duplicate rows accumulate, matching a dense scatter-add.
    /// Parallelized over *nodes* so all writes are owner-local.
    pub fn apply_grads(
        &mut self,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        let b = indices.len() / (t * hotness);
        debug_assert_eq!(grads.len(), b * t * dim);
        let n_nodes = self.n_nodes;
        // Small batches: one thread applying updates directly beats the
        // per-node fan-out (each parallel worker must scan the whole
        // index list; at B=128 that costs 285 µs vs 30 µs serial —
        // EXPERIMENTS.md §Perf #5). Large batches amortize the scan.
        if b * t * hotness < 16_384 {
            for s in 0..b {
                for tab in 0..t {
                    let g = &grads[(s * t + tab) * dim..(s * t + tab + 1) * dim];
                    for h in 0..hotness {
                        let row = indices[(s * t + tab) * hotness + h] as usize;
                        let node_id = row % n_nodes;
                        let local = row / n_nodes;
                        let node = &mut self.nodes[node_id];
                        let dst =
                            &mut node.shards[tab][local * dim..(local + 1) * dim];
                        let acc = &mut node.opt_state[tab][local];
                        opt.apply(dst, g, acc, lr);
                    }
                }
            }
            return;
        }
        let nodes = &mut self.nodes;
        // Each thread owns a disjoint set of nodes → disjoint storage.
        let node_refs: Vec<std::sync::Mutex<&mut EmbPsNode>> =
            nodes.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_chunks(n_nodes, 8, 1, |nlo, nhi| {
            for node_id in nlo..nhi {
                let mut node = node_refs[node_id].lock().unwrap();
                for s in 0..b {
                    for tab in 0..t {
                        let g = &grads[(s * t + tab) * dim..(s * t + tab + 1) * dim];
                        for h in 0..hotness {
                            let row =
                                indices[(s * t + tab) * hotness + h] as usize;
                            if row % n_nodes != node_id {
                                continue;
                            }
                            let local = row / n_nodes;
                            let node = &mut *node;
                            let dst = &mut node.shards[tab]
                                [local * dim..(local + 1) * dim];
                            let acc = &mut node.opt_state[tab][local];
                            opt.apply(dst, g, acc, lr);
                        }
                    }
                }
            }
        });
    }

    /// Reset a node's shards to their deterministic initial values
    /// (recovery when no checkpoint exists yet).
    pub fn reset_node_to_init(&mut self, node_id: usize) {
        let tables = self.tables.clone();
        let n_nodes = self.n_nodes;
        let seed = self.seed;
        for (t, info) in tables.iter().enumerate() {
            let local_rows = Self::local_rows_static(info.rows, n_nodes, node_id);
            let shard = &mut self.nodes[node_id].shards[t];
            for lr in 0..local_rows {
                let global = node_id + lr * n_nodes;
                for d in 0..info.dim {
                    shard[lr * info.dim + d] = init_value(seed, t, global, d);
                }
            }
            for a in self.nodes[node_id].opt_state[t].iter_mut() {
                *a = 0.0;
            }
        }
    }

    /// Total parameter count across all tables.
    pub fn total_params(&self) -> usize {
        self.tables.iter().map(|t| t.rows * t.dim).sum()
    }
}

struct SendPtr(*mut f32);
unsafe impl Sync for SendPtr {}
unsafe impl Send for SendPtr {}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cluster(n_nodes: usize) -> PsCluster {
        PsCluster::new(
            vec![TableInfo { rows: 10, dim: 4 }, TableInfo { rows: 7, dim: 4 }],
            n_nodes,
            42,
        )
    }

    #[test]
    fn routing_is_a_bijection() {
        let c = small_cluster(3);
        for table in 0..2 {
            let rows = c.tables[table].rows;
            let mut seen = std::collections::HashSet::new();
            for r in 0..rows {
                let (node, local) = c.route(r);
                assert!(node < 3);
                assert!(local < c.local_rows(table, node));
                assert!(seen.insert((node, local)));
            }
            // every local slot is hit
            let total: usize = (0..3).map(|n| c.local_rows(table, n)).sum();
            assert_eq!(total, rows);
        }
    }

    #[test]
    fn init_is_deterministic_and_node_count_invariant() {
        // The same (table,row) must hold the same vector regardless of how
        // many PS nodes shard it — failure experiments vary n_nodes.
        let a = small_cluster(2);
        let b = small_cluster(5);
        let mut ra = vec![0.0; 4];
        let mut rb = vec![0.0; 4];
        for t in 0..2 {
            for r in 0..a.tables[t].rows {
                a.read_row(t, r, &mut ra);
                b.read_row(t, r, &mut rb);
                assert_eq!(ra, rb, "table {t} row {r}");
            }
        }
    }

    #[test]
    fn gather_matches_read_row() {
        let c = small_cluster(3);
        let indices: Vec<u32> = vec![0, 1, 9, 6, 3, 2]; // 3 samples x 2 tables
        let mut out = vec![0.0; 3 * 2 * 4];
        c.gather(&indices, &mut out);
        let mut row = vec![0.0; 4];
        for s in 0..3 {
            for t in 0..2 {
                c.read_row(t, indices[s * 2 + t] as usize, &mut row);
                assert_eq!(&out[(s * 2 + t) * 4..(s * 2 + t + 1) * 4], &row[..]);
            }
        }
    }

    #[test]
    fn sgd_update_applies_lr_times_grad() {
        let mut c = small_cluster(2);
        let indices = vec![5, 2]; // 1 sample, 2 tables
        let mut before = vec![0.0; 4];
        c.read_row(0, 5, &mut before);
        let grads = vec![1.0f32; 8];
        c.sgd_update(&indices, &grads, 0.1);
        let mut after = vec![0.0; 4];
        c.read_row(0, 5, &mut after);
        for d in 0..4 {
            assert!((after[d] - (before[d] - 0.1)).abs() < 1e-6);
        }
    }

    #[test]
    fn duplicate_rows_accumulate() {
        let mut c = small_cluster(2);
        // two samples hitting the SAME row of table 0
        let indices = vec![4, 0, 4, 1];
        let mut before = vec![0.0; 4];
        c.read_row(0, 4, &mut before);
        let grads = vec![0.5f32; 16];
        c.sgd_update(&indices, &grads, 1.0);
        let mut after = vec![0.0; 4];
        c.read_row(0, 4, &mut after);
        for d in 0..4 {
            assert!((after[d] - (before[d] - 1.0)).abs() < 1e-6, "{d}");
        }
    }

    #[test]
    fn reset_node_restores_init() {
        let mut c = small_cluster(3);
        let indices = vec![3, 3];
        let grads = vec![1.0f32; 8];
        c.sgd_update(&indices, &grads, 1.0);
        // row 3 lives on node 0 (3 % 3)
        c.reset_node_to_init(0);
        let fresh = small_cluster(3);
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        c.read_row(0, 3, &mut a);
        fresh.read_row(0, 3, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn reset_does_not_touch_other_nodes() {
        let mut c = small_cluster(3);
        let indices = vec![4, 4]; // node 1
        let grads = vec![1.0f32; 8];
        c.sgd_update(&indices, &grads, 1.0);
        let mut before = vec![0.0; 4];
        c.read_row(0, 4, &mut before);
        c.reset_node_to_init(0);
        let mut after = vec![0.0; 4];
        c.read_row(0, 4, &mut after);
        assert_eq!(before, after);
    }

    #[test]
    fn total_params() {
        let c = small_cluster(2);
        assert_eq!(c.total_params(), (10 + 7) * 4);
    }

    #[test]
    fn gather_pooled_sums_hot_rows() {
        let c = small_cluster(2);
        // 1 sample, 2 tables, hotness 2
        let indices = vec![1, 3, 0, 2];
        let mut pooled = vec![0.0; 2 * 4];
        c.gather_pooled(&indices, 2, &mut pooled);
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        c.read_row(0, 1, &mut a);
        c.read_row(0, 3, &mut b);
        for d in 0..4 {
            assert!((pooled[d] - (a[d] + b[d])).abs() < 1e-6);
        }
    }

    #[test]
    fn multi_hot_grad_broadcasts_to_all_rows() {
        let mut c = small_cluster(2);
        let indices = vec![1, 3, 0, 2]; // table0: rows 1,3; table1: rows 0,2
        let mut r1 = vec![0.0; 4];
        let mut r3 = vec![0.0; 4];
        c.read_row(0, 1, &mut r1);
        c.read_row(0, 3, &mut r3);
        let grads = vec![1.0f32; 2 * 4]; // [B=1, T=2, dim=4]
        c.apply_grads(&indices, 2, &grads, 0.5, EmbOptimizer::Sgd);
        let mut a1 = vec![0.0; 4];
        let mut a3 = vec![0.0; 4];
        c.read_row(0, 1, &mut a1);
        c.read_row(0, 3, &mut a3);
        for d in 0..4 {
            assert!((a1[d] - (r1[d] - 0.5)).abs() < 1e-6);
            assert!((a3[d] - (r3[d] - 0.5)).abs() < 1e-6);
        }
    }

    #[test]
    fn adagrad_state_accumulates_and_damps() {
        let mut c = small_cluster(2);
        let opt = EmbOptimizer::RowAdagrad { eps: 1e-8 };
        let indices = vec![5, 2];
        let grads = vec![1.0f32; 8];
        let mut before = vec![0.0; 4];
        c.read_row(0, 5, &mut before);
        c.apply_grads(&indices, 1, &grads, 1.0, opt);
        let (node, local) = c.route(5);
        assert!(c.opt_shard(node, 0)[local] > 0.0, "accumulator untouched");
        let mut after1 = vec![0.0; 4];
        c.read_row(0, 5, &mut after1);
        let step1 = (before[0] - after1[0]).abs();
        c.apply_grads(&indices, 1, &grads, 1.0, opt);
        let mut after2 = vec![0.0; 4];
        c.read_row(0, 5, &mut after2);
        let step2 = (after1[0] - after2[0]).abs();
        assert!(step2 < step1, "adagrad must damp: {step1} -> {step2}");
    }

    #[test]
    fn reset_node_clears_optimizer_state() {
        let mut c = small_cluster(3);
        let opt = EmbOptimizer::RowAdagrad { eps: 1e-8 };
        c.apply_grads(&[3, 3], 1, &[1.0f32; 8], 1.0, opt);
        let (node, local) = c.route(3);
        assert!(c.opt_shard(node, 0)[local] > 0.0);
        c.reset_node_to_init(node);
        assert_eq!(c.opt_shard(node, 0)[local], 0.0);
    }
}
