//! PLS — *portion of lost samples* (paper §4.1) — and the CPR controller
//! built on it: expected-PLS interval selection (Eq. 4), the overhead
//! models for full (Eq. 1) and partial (Eq. 2) recovery, and the benefit
//! analysis that decides when CPR falls back to full recovery.

use crate::config::ClusterConfig;

/// Running PLS accumulator (Eq. 3). Track `samples` processed; on a failure
/// of `victims` Emb PS nodes, the effect of the samples since the last
/// checkpoint is lost on a 1/N_emb slice of the model per victim.
#[derive(Clone, Debug, Default)]
pub struct PlsAccumulator {
    pls: f64,
}

impl PlsAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a failure at `samples_now`, with the last checkpoint taken at
    /// `samples_last_ckpt`, out of `total_samples` planned, on a cluster of
    /// `n_emb` Emb PS nodes, killing `victims` of them.
    pub fn on_failure(
        &mut self,
        samples_now: u64,
        samples_last_ckpt: u64,
        total_samples: u64,
        n_emb: usize,
        victims: usize,
    ) {
        debug_assert!(samples_now >= samples_last_ckpt);
        let lost = (samples_now - samples_last_ckpt) as f64;
        self.pls +=
            victims as f64 * lost / (total_samples as f64 * n_emb as f64);
    }

    pub fn value(&self) -> f64 {
        self.pls
    }
}

/// Expected PLS for a checkpoint interval (Eq. 4):
/// E[PLS] = 0.5 T_save / (T_fail · N_emb).
pub fn expected_pls(t_save_h: f64, t_fail_h: f64, n_emb: usize) -> f64 {
    expected_pls_with_trainers(t_save_h, t_fail_h, n_emb, 0)
}

/// Interval that achieves a target PLS (inverse of Eq. 4):
/// T_save = 2 · PLS · N_emb · T_fail.
pub fn t_save_for_target_pls(target_pls: f64, t_fail_h: f64, n_emb: usize) -> f64 {
    t_save_for_target_pls_with_trainers(target_pls, t_fail_h, n_emb, 0)
}

/// Fraction of job failures that strike an Emb PS node rather than a
/// trainer, assuming a uniform per-node hazard over the N_emb + N_tr
/// machines of the job (paper §3.1: the fleet MTBF counts both
/// populations). 1.0 when there are no trainers in the pool.
pub fn emb_failure_share(n_emb: usize, n_trainers: usize) -> f64 {
    if n_emb == 0 {
        return 0.0;
    }
    n_emb as f64 / (n_emb + n_trainers) as f64
}

/// Eq. 4 extended with the trainer term: only Emb PS failures lose
/// embedding updates, so E[PLS] = share · 0.5 · T_save / (T_fail · N_emb)
/// with share = N_emb / (N_emb + N_tr). Returns 0 for a failure-free
/// cluster (`t_fail_h` infinite) or a cluster without Emb PS nodes.
pub fn expected_pls_with_trainers(
    t_save_h: f64,
    t_fail_h: f64,
    n_emb: usize,
    n_trainers: usize,
) -> f64 {
    if n_emb == 0 || !t_fail_h.is_finite() {
        return 0.0;
    }
    emb_failure_share(n_emb, n_trainers) * 0.5 * t_save_h / (t_fail_h * n_emb as f64)
}

/// Inverse of the extended Eq. 4. The trainer share cancels neatly:
/// T_save = 2 · PLS · T_fail · N_emb / share = 2 · PLS · T_fail · (N_emb + N_tr).
pub fn t_save_for_target_pls_with_trainers(
    target_pls: f64,
    t_fail_h: f64,
    n_emb: usize,
    n_trainers: usize,
) -> f64 {
    2.0 * target_pls * (n_emb + n_trainers) as f64 * t_fail_h
}

/// Online MTBF re-estimate from the failures observed so far
/// (Chameleon-style adaptivity; used by `policy::AdaptiveInterval`). The
/// configured `prior_t_fail_h` acts as one pseudo-failure spread over its
/// own duration, so at `elapsed_h = 0` the estimate IS the prior, and as
/// events accrue it converges to the empirical rate
/// `elapsed_h / failures`. A degenerate (non-finite or non-positive)
/// prior falls straight back to the empirical rate.
pub fn estimate_mtbf(prior_t_fail_h: f64, elapsed_h: f64, failures: u64) -> f64 {
    if !(prior_t_fail_h.is_finite() && prior_t_fail_h > 0.0) {
        return if failures == 0 {
            f64::INFINITY
        } else {
            elapsed_h / failures as f64
        };
    }
    (prior_t_fail_h + elapsed_h) / (failures as f64 + 1.0)
}

/// `events per job` = T_total / T_fail, with the zero-failure-rate edge
/// handled explicitly (an infinite MTBF means no failure terms, not NaN).
fn failure_rate(c: &ClusterConfig) -> f64 {
    if c.t_fail_h.is_finite() && c.t_fail_h > 0.0 {
        c.t_total_h / c.t_fail_h
    } else {
        0.0
    }
}

/// Eq. 1 — total overhead (hours) of FULL recovery over a run of
/// `t_total_h`, saving every `t_save_h`.
pub fn overhead_full_h(c: &ClusterConfig, t_save_h: f64) -> f64 {
    let rate = failure_rate(c);
    let per_failure = if rate > 0.0 {
        (c.o_load_h + t_save_h / 2.0 + c.o_res_h) * rate
    } else {
        0.0
    };
    c.o_save_h * (c.t_total_h / t_save_h) + per_failure
}

/// Eq. 2 — total overhead (hours) of PARTIAL recovery (no lost
/// computation term).
pub fn overhead_partial_h(c: &ClusterConfig, t_save_h: f64) -> f64 {
    c.o_save_h * (c.t_total_h / t_save_h)
        + (c.o_load_h + c.o_res_h) * failure_rate(c)
}

/// What the CPR controller decided for this job.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CprPlan {
    /// chosen checkpoint interval, hours
    pub t_save_h: f64,
    /// estimated overhead of the chosen scheme, hours
    pub est_overhead_h: f64,
    /// estimated overhead had we used full recovery at its optimum, hours
    pub est_full_overhead_h: f64,
    /// true = run partial recovery; false = fall back to full recovery
    pub use_partial: bool,
    /// expected PLS under the plan (0 for full recovery)
    pub expected_pls: f64,
}

/// The CPR decision procedure (paper §4.2, Fig. 5):
/// 1. compute T_save,part from the target PLS;
/// 2. estimate partial-recovery overhead at that interval (Eq. 2);
/// 3. compare against full recovery at its optimal interval (Eq. 1);
/// 4. fall back to full recovery when partial shows no benefit.
///
/// The interval selection carries the cluster's `n_trainers` term: with
/// N_tr trainers in the failure pool, only N_emb/(N_emb + N_tr) of
/// failures lose embedding updates, so the interval that achieves a
/// target PLS stretches to 2 · PLS · T_fail · (N_emb + N_tr) — Fig. 4/13
/// projections therefore reflect trainer count.
///
/// NOTE on emulation coherence: `t_fail_h` is the *job-level* MTBF and
/// the share assumes failures strike the N_emb + N_tr machine pool
/// uniformly. An injected schedule should therefore mix PS and trainer
/// events in the n_emb : n_trainers ratio (`--failures` +
/// `--trainer-failures`); a PS-only schedule at the same event rate
/// makes measured PLS overshoot the target by (N_emb + N_tr)/N_emb.
/// At the preset default (n_trainers = 1) that bias is 1/N_emb.
///
/// The partial interval is clamped to the job length (saving less often
/// than once per job is just "save once").
pub fn plan(c: &ClusterConfig, target_pls: f64) -> CprPlan {
    let t_save_full = c.t_save_full_h();
    let full_h = overhead_full_h(c, t_save_full);
    let t_save_part = t_save_for_target_pls_with_trainers(
        target_pls, c.t_fail_h, c.n_emb_ps, c.n_trainers,
    )
    .min(c.t_total_h);
    let part_h = overhead_partial_h(c, t_save_part);
    let use_partial = part_h < full_h;
    CprPlan {
        t_save_h: if use_partial { t_save_part } else { t_save_full },
        est_overhead_h: if use_partial { part_h } else { full_h },
        est_full_overhead_h: full_h,
        use_partial,
        expected_pls: if use_partial {
            expected_pls_with_trainers(t_save_part, c.t_fail_h, c.n_emb_ps,
                                       c.n_trainers)
        } else {
            0.0
        },
    }
}

/// [`plan`] with a **bandwidth-derived save cost**: when the cluster
/// carries a checkpoint write bandwidth (`ClusterConfig::save_bw_gb_h`)
/// and the caller knows the checkpoint size (`CheckpointStore::size_bytes`
/// or the registry's table-derived estimate), the per-save cost becomes
/// `bytes / bandwidth` instead of the flat `o_save_h` constant — so the
/// planned interval tracks the actual I/O volume a save moves
/// (Check-N-Run sizes its checkpoint budget the same way). With no
/// bandwidth configured (every preset) this is exactly [`plan`].
///
/// `ckpt_bytes` must be the **encoded** size when the checkpoint writer
/// runs a payload codec (format v2 + `[checkpoint] codec`): the policy
/// registry and `cpr plan` both pre-scale the raw fp32 size by
/// `checkpoint::codec::estimated_ratio`, which is how quantized
/// checkpoints narrow the planned interval.
pub fn plan_with_bytes(
    c: &ClusterConfig,
    target_pls: f64,
    ckpt_bytes: Option<u64>,
) -> CprPlan {
    let mut eff = c.clone();
    eff.o_save_h = c.o_save_eff_h(ckpt_bytes);
    plan(&eff, target_pls)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::{forall, gen};

    fn cluster(n_emb: usize, t_fail: f64) -> ClusterConfig {
        ClusterConfig {
            backend: crate::config::PsBackendKind::InProc,
            n_emb_ps: n_emb,
            n_trainers: 8,
            t_total_h: 56.0,
            t_fail_h: t_fail,
            o_save_h: 0.094,
            o_load_h: 0.042,
            o_res_h: 0.042,
            save_bw_gb_h: None,
        }
    }

    #[test]
    fn eq4_and_inverse_are_consistent() {
        forall(10, 200, |rng| {
            let target = gen::f64_in(rng, 0.001, 0.5);
            let t_fail = gen::f64_in(rng, 1.0, 100.0);
            let n_emb = gen::usize_in(rng, 1, 64);
            let t_save = t_save_for_target_pls(target, t_fail, n_emb);
            let back = expected_pls(t_save, t_fail, n_emb);
            prop_assert!((back - target).abs() < 1e-12,
                         "target {target} came back as {back}");
            Ok(())
        });
    }

    #[test]
    fn accumulator_matches_eq3() {
        let mut a = PlsAccumulator::new();
        // 1 victim, lost 1000 of 10_000 samples, 8 nodes
        a.on_failure(5_000, 4_000, 10_000, 8, 1);
        assert!((a.value() - 1000.0 / (10_000.0 * 8.0)).abs() < 1e-15);
        // second failure with 2 victims accumulates
        a.on_failure(8_000, 8_000, 10_000, 8, 2);
        assert!((a.value() - 1000.0 / 80_000.0).abs() < 1e-15); // no new loss
        a.on_failure(9_000, 8_000, 10_000, 8, 2);
        let want = 1000.0 / 80_000.0 + 2.0 * 1000.0 / 80_000.0;
        assert!((a.value() - want).abs() < 1e-15);
    }

    #[test]
    fn pls_nonnegative_and_monotone() {
        forall(11, 200, |rng| {
            let mut a = PlsAccumulator::new();
            let total = 100_000u64;
            let n_emb = gen::usize_in(rng, 1, 32);
            let mut prev = 0.0;
            let mut last_ckpt = 0u64;
            let mut now = 0u64;
            for _ in 0..20 {
                now += rng.below(5_000);
                if rng.bool_with(0.3) {
                    last_ckpt = now;
                }
                a.on_failure(now, last_ckpt, total, n_emb,
                             gen::usize_in(rng, 1, n_emb));
                prop_assert!(a.value() >= prev, "PLS decreased");
                prev = a.value();
            }
            Ok(())
        });
    }

    #[test]
    fn full_overhead_minimized_at_optimal_interval() {
        let c = cluster(8, 28.0);
        let opt = c.t_save_full_h();
        let at_opt = overhead_full_h(&c, opt);
        for mult in [0.25, 0.5, 0.8, 1.25, 2.0, 4.0] {
            assert!(overhead_full_h(&c, opt * mult) >= at_opt - 1e-9,
                    "interval {} beats optimum", opt * mult);
        }
    }

    #[test]
    fn partial_overhead_decreases_with_interval() {
        let c = cluster(8, 28.0);
        assert!(overhead_partial_h(&c, 10.0) < overhead_partial_h(&c, 5.0));
    }

    #[test]
    fn emulation_constants_match_paper_headline() {
        // Fig. 7 bars: full ≈ 8.5%, partial-naive ≈ 4.4%, CPR ≈ 0.53%
        let c = cluster(8, 28.0);
        let full = overhead_full_h(&c, c.t_save_full_h()) / c.t_total_h;
        assert!((full - 0.085).abs() < 0.01, "full {full}");
        let naive = overhead_partial_h(&c, c.t_save_full_h()) / c.t_total_h;
        assert!((naive - 0.044).abs() < 0.006, "naive {naive}");
        let p = plan(&c, 0.1);
        assert!(p.use_partial);
        let cpr = p.est_overhead_h / c.t_total_h;
        assert!((cpr - 0.0055).abs() < 0.003, "cpr {cpr}");
    }

    #[test]
    fn falls_back_to_full_when_failures_frequent() {
        // T_fail tiny → partial interval shrinks → save overhead explodes
        let c = cluster(2, 0.05);
        let p = plan(&c, 0.02);
        assert!(!p.use_partial, "should fall back: {p:?}");
        assert_eq!(p.expected_pls, 0.0);
    }

    #[test]
    fn plan_interval_clamped_to_job_length() {
        let c = cluster(64, 28.0); // huge N_emb → enormous raw interval
        let p = plan(&c, 0.2);
        assert!(p.t_save_h <= c.t_total_h + 1e-9);
    }

    #[test]
    fn plan_interval_round_trips_to_target_pls() {
        // property: whenever the plan chooses partial recovery and its
        // interval is not clamped by the job length, the planned interval
        // must achieve the requested PLS exactly (within fp tolerance) —
        // including the n_trainers term.
        forall(12, 300, |rng| {
            let mut c = cluster(gen::usize_in(rng, 1, 32),
                                gen::f64_in(rng, 5.0, 100.0));
            c.n_trainers = gen::usize_in(rng, 0, 32);
            let target = gen::f64_in(rng, 0.001, 0.3);
            let p = plan(&c, target);
            if p.use_partial && p.t_save_h < c.t_total_h - 1e-9 {
                prop_assert!((p.expected_pls - target).abs() < 1e-9,
                             "target {target} planned as {}", p.expected_pls);
                let back = expected_pls_with_trainers(
                    p.t_save_h, c.t_fail_h, c.n_emb_ps, c.n_trainers);
                prop_assert!((back - target).abs() < 1e-9,
                             "interval {} gives PLS {back}", p.t_save_h);
            }
            Ok(())
        });
    }

    #[test]
    fn zero_failure_rate_plans_full_with_zero_overhead() {
        // T_fail = ∞ (a job that never fails): no failure terms, no NaN;
        // partial shows no benefit so the plan falls back to full with
        // zero estimated overhead.
        let c = cluster(8, f64::INFINITY);
        assert_eq!(overhead_full_h(&c, c.t_save_full_h()), 0.0);
        assert_eq!(overhead_partial_h(&c, c.t_total_h), c.o_save_h);
        let p = plan(&c, 0.1);
        assert!(!p.use_partial, "never-failing job must not pick partial");
        assert_eq!(p.est_overhead_h, 0.0);
        assert_eq!(p.est_full_overhead_h, 0.0);
        assert_eq!(p.expected_pls, 0.0);
        assert_eq!(expected_pls(10.0, f64::INFINITY, 8), 0.0);
    }

    #[test]
    fn n_emb_zero_is_finite_and_loses_nothing() {
        // a degenerate cluster without Emb PS nodes: nothing to lose, so
        // every PLS quantity is 0 and the plan stays finite (no div0/NaN)
        assert_eq!(emb_failure_share(0, 8), 0.0);
        assert_eq!(expected_pls_with_trainers(10.0, 28.0, 0, 8), 0.0);
        let mut c = cluster(0, 28.0);
        c.n_trainers = 8;
        let p = plan(&c, 0.1);
        assert!(p.est_overhead_h.is_finite());
        assert!(p.est_full_overhead_h.is_finite());
        assert!(p.t_save_h > 0.0);
        assert_eq!(p.expected_pls, 0.0);
        let mut acc = PlsAccumulator::new();
        acc.on_failure(100, 50, 1000, 8, 0); // zero victims: no loss
        assert_eq!(acc.value(), 0.0);
    }

    #[test]
    fn trainer_term_stretches_interval_at_same_pls() {
        // more trainers in the failure pool → fewer failures hit the Emb
        // PS → the same target PLS tolerates a longer save interval, at
        // identical expected PLS (the share cancels).
        let base = cluster(8, 28.0);
        let mut with_tr = base.clone();
        with_tr.n_trainers = 24;
        let target = 0.01; // small enough that neither plan clamps
        let p0 = plan(&base, target);
        let p1 = plan(&with_tr, target);
        assert!(p0.use_partial && p1.use_partial);
        assert!(p1.t_save_h > p0.t_save_h,
                "trainers must stretch the interval: {} !> {}",
                p1.t_save_h, p0.t_save_h);
        assert!((p1.t_save_h / p0.t_save_h - 32.0 / 16.0).abs() < 1e-9);
        assert!((p0.expected_pls - target).abs() < 1e-12);
        assert!((p1.expected_pls - target).abs() < 1e-12);
        // and the cheaper save cadence shows up as lower overhead
        assert!(p1.est_overhead_h <= p0.est_overhead_h + 1e-12);
    }

    #[test]
    fn mtbf_estimate_starts_at_prior_and_converges_to_empirical() {
        // no time, no failures: the prior
        assert_eq!(estimate_mtbf(28.0, 0.0, 0), 28.0);
        // empirical rate exactly matching the prior reproduces it
        assert!((estimate_mtbf(28.0, 2800.0, 100) - 28.0).abs() < 1e-12);
        // heavy evidence dominates: 100 failures in 100 h → ≈ 1.27 h
        let est = estimate_mtbf(28.0, 100.0, 100);
        assert!(est < 2.0 && est > 1.0, "est {est}");
        // degenerate priors fall back to the empirical rate
        assert_eq!(estimate_mtbf(f64::INFINITY, 10.0, 0), f64::INFINITY);
        assert_eq!(estimate_mtbf(f64::INFINITY, 10.0, 5), 2.0);
        assert_eq!(estimate_mtbf(0.0, 12.0, 4), 3.0);
    }

    #[test]
    fn mtbf_estimate_monotone_in_failures_and_elapsed() {
        forall(13, 200, |rng| {
            let prior = gen::f64_in(rng, 1.0, 100.0);
            let elapsed = gen::f64_in(rng, 0.0, 200.0);
            let k = rng.below(50);
            // one more observed failure can only lower the estimate
            prop_assert!(estimate_mtbf(prior, elapsed, k + 1)
                             <= estimate_mtbf(prior, elapsed, k),
                         "more failures must not raise the MTBF estimate");
            // more failure-free time can only raise it
            prop_assert!(estimate_mtbf(prior, elapsed + 1.0, k)
                             >= estimate_mtbf(prior, elapsed, k),
                         "more elapsed time must not lower the MTBF estimate");
            Ok(())
        });
    }

    #[test]
    fn bandwidth_derived_plan_tracks_checkpoint_size() {
        let c = cluster(8, 28.0);
        // no bandwidth → identical to the flat-constant plan
        assert_eq!(plan_with_bytes(&c, 0.1, Some(123_456_789)), plan(&c, 0.1));
        assert_eq!(plan_with_bytes(&c, 0.1, None), plan(&c, 0.1));
        let mut bw = c.clone();
        bw.save_bw_gb_h = Some(100.0);
        // a 9.4 GB checkpoint at 100 GB/h reproduces o_save_h = 0.094
        let same = plan_with_bytes(&bw, 0.1, Some(9_400_000_000));
        assert!((same.est_overhead_h - plan(&c, 0.1).est_overhead_h).abs() < 1e-12);
        // a 10× larger checkpoint costs 10× per save: the full-recovery
        // optimum stretches by √10 and estimated overheads grow
        let big = plan_with_bytes(&bw, 0.1, Some(94_000_000_000));
        assert!(big.est_full_overhead_h > same.est_full_overhead_h);
        // a tiny checkpoint makes saving nearly free
        let tiny = plan_with_bytes(&bw, 0.1, Some(1_000_000));
        assert!(tiny.est_overhead_h < same.est_overhead_h);
    }

    #[test]
    fn plan_monotone_in_target_pls() {
        // looser PLS target → larger interval → no more overhead
        let c = cluster(8, 28.0);
        let mut prev = f64::INFINITY;
        for target in [0.02, 0.05, 0.1, 0.2] {
            let p = plan(&c, target);
            assert!(p.est_overhead_h <= prev + 1e-12);
            prev = p.est_overhead_h;
        }
    }
}
