//! Evaluation metrics and training-time accounting.
//!
//! * exact ROC-AUC (Mann-Whitney U with tie correction) — the paper's model
//!   quality metric (§5.1);
//! * binary-cross-entropy log-loss;
//! * `OverheadLedger`: the four checkpoint-related overheads of §2.2
//!   (save / load / lost computation / reschedule) accumulated in emulated
//!   hours and reported as a fraction of total training time.

/// Exact ROC-AUC. `scores` need not be probabilities (any monotone score).
/// Ties receive the standard midrank treatment. Returns 0.5 when one class
/// is absent.
pub fn auc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    let npos = labels.iter().filter(|&&l| l > 0.5).count();
    let nneg = n - npos;
    if npos == 0 || nneg == 0 {
        return 0.5;
    }
    let mut order: Vec<usize> = (0..n).collect();
    // total_cmp, not partial_cmp().unwrap(): a NaN score (a diverged
    // model is exactly when you evaluate) must degrade the ranking, not
    // panic the evaluation
    order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
    // midranks over tied groups
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        let midrank = (i + j) as f64 / 2.0 + 1.0; // 1-based
        for &k in &order[i..=j] {
            if labels[k] > 0.5 {
                rank_sum_pos += midrank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - (npos as f64) * (npos as f64 + 1.0) / 2.0;
    u / (npos as f64 * nneg as f64)
}

/// Mean binary cross-entropy from logits (matches the L2 graph's loss).
pub fn logloss_from_logits(logits: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(logits.len(), labels.len());
    let mut s = 0.0f64;
    for (&l, &y) in logits.iter().zip(labels) {
        let l = l as f64;
        let y = y as f64;
        s += l.max(0.0) - l * y + (-l.abs()).exp().ln_1p();
    }
    s / logits.len() as f64
}

/// The four overheads of paper §2.2, in emulated hours.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OverheadLedger {
    pub save_h: f64,
    pub load_h: f64,
    pub lost_h: f64,
    pub reschedule_h: f64,
    /// count of checkpoint saves / failures, for reporting
    pub n_saves: u64,
    pub n_failures: u64,
    /// logical checkpoint bytes captured for persistence (row payloads +
    /// per-row ids + dense params — `checkpoint::rows_io_bytes` /
    /// `full_content_io_bytes`), charged at capture time so I/O volume is
    /// visible even for in-memory-only runs. Format v2 delta captures
    /// charge only the touched rows; v1 full saves charge the whole store.
    pub bytes_written: u64,
    /// logical checkpoint bytes read back by restores (per-node content
    /// for partial recovery, the whole store + dense params for a rewind)
    pub bytes_restored: u64,
    /// online interval re-plans by the adaptive save policy
    /// (`policy::AdaptiveInterval`): `(emulated hour, new T_save)` per
    /// accepted re-plan. Empty for every static-interval policy.
    pub replans: Vec<(f64, f64)>,
}

impl OverheadLedger {
    pub fn total_h(&self) -> f64 {
        self.save_h + self.load_h + self.lost_h + self.reschedule_h
    }

    /// Overhead as a fraction of useful training time `t_total_h`
    /// (the paper reports overhead / total training time). A zero-length
    /// job has zero overhead fraction — not NaN (0/0) or inf (x/0),
    /// which would poison every downstream report that averages it.
    pub fn fraction_of(&self, t_total_h: f64) -> f64 {
        if t_total_h == 0.0 {
            return 0.0;
        }
        self.total_h() / t_total_h
    }

    /// Machine-hours wasted: checkpoint overhead stalls the whole
    /// synchronous job, so every overhead hour idles all `n_emb + n_trainers`
    /// machines (the paper's "1,156 machine-years" accounting, §3.2).
    pub fn machine_hours(&self, n_emb: usize, n_trainers: usize) -> f64 {
        self.total_h() * (n_emb + n_trainers) as f64
    }

    pub fn add(&mut self, other: &OverheadLedger) {
        self.save_h += other.save_h;
        self.load_h += other.load_h;
        self.lost_h += other.lost_h;
        self.reschedule_h += other.reschedule_h;
        self.n_saves += other.n_saves;
        self.n_failures += other.n_failures;
        self.bytes_written += other.bytes_written;
        self.bytes_restored += other.bytes_restored;
        self.replans.extend_from_slice(&other.replans);
    }
}

/// A recorded (step, value) training curve.
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub points: Vec<(u64, f64)>,
}

impl Curve {
    pub fn push(&mut self, step: u64, value: f64) {
        self.points.push((step, value));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    pub fn best_max(&self) -> Option<f64> {
        self.points.iter().map(|&(_, v)| v).fold(None, |acc, v| {
            Some(acc.map_or(v, |a: f64| a.max(v)))
        })
    }

    pub fn to_csv(&self, header: &str) -> String {
        let mut s = format!("step,{header}\n");
        for (step, v) in &self.points {
            s.push_str(&format!("{step},{v}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auc_perfect_ranking_is_one() {
        let scores = [0.1, 0.4, 0.35, 0.8f32];
        let labels = [0.0, 0.0, 0.0, 1.0f32];
        assert_eq!(auc(&scores, &labels), 1.0);
    }

    #[test]
    fn auc_reversed_is_zero() {
        let scores = [0.9, 0.1f32];
        let labels = [0.0, 1.0f32];
        assert_eq!(auc(&scores, &labels), 0.0);
    }

    #[test]
    fn auc_random_is_half() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(1);
        let n = 20_000;
        let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let labels: Vec<f32> = (0..n).map(|_| (rng.f64() < 0.5) as u32 as f32).collect();
        let a = auc(&scores, &labels);
        assert!((a - 0.5).abs() < 0.02, "auc={a}");
    }

    #[test]
    fn auc_known_value_with_ties() {
        // scores: pos {0.5, 0.5}, neg {0.5, 0.2}
        // pairs: (0.5>0.2)x2 correct, (0.5 vs 0.5)x2 ties → (2 + 2*0.5)/4 = 0.75
        let scores = [0.5, 0.5, 0.5, 0.2f32];
        let labels = [1.0, 1.0, 0.0, 0.0f32];
        assert!((auc(&scores, &labels) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn auc_invariant_to_monotone_transform() {
        let scores = [0.1, 0.7, 0.3, 0.9, 0.5f32];
        let labels = [0.0, 1.0, 0.0, 1.0, 1.0f32];
        let transformed: Vec<f32> = scores.iter().map(|s| s * 100.0 - 3.0).collect();
        assert_eq!(auc(&scores, &labels), auc(&transformed, &labels));
    }

    #[test]
    fn auc_single_class_is_half() {
        assert_eq!(auc(&[0.3, 0.6], &[1.0, 1.0]), 0.5);
    }

    #[test]
    fn auc_tolerates_nan_scores() {
        // regression: the sort used partial_cmp().unwrap(), so a single
        // NaN score (diverged model) panicked the whole evaluation
        let scores = [0.1, f32::NAN, 0.8, 0.4f32];
        let labels = [0.0, 0.0, 1.0, 1.0f32];
        let a = auc(&scores, &labels);
        assert!(a.is_finite(), "NaN scores must yield a finite AUC, got {a}");
        assert!((0.0..=1.0).contains(&a));
        // all-NaN scores: still a finite ranking under total_cmp
        let a = auc(&[f32::NAN, f32::NAN], &[0.0, 1.0]);
        assert!(a.is_finite());
    }

    #[test]
    fn fraction_of_zero_total_time_is_zero() {
        // regression: 0-hour jobs divided by zero (0/0 = NaN with an
        // empty ledger, x/0 = inf otherwise)
        let empty = OverheadLedger::default();
        assert_eq!(empty.fraction_of(0.0), 0.0);
        let l = OverheadLedger { save_h: 1.0, ..Default::default() };
        assert_eq!(l.fraction_of(0.0), 0.0);
        assert!(l.fraction_of(0.0).is_finite());
    }

    #[test]
    fn logloss_matches_manual() {
        let logits = [0.0f32];
        let labels = [1.0f32];
        assert!((logloss_from_logits(&logits, &labels) - 2f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn ledger_accumulates() {
        let mut a = OverheadLedger {
            save_h: 1.0,
            n_saves: 2,
            bytes_written: 100,
            ..Default::default()
        };
        let b = OverheadLedger {
            lost_h: 3.0,
            n_failures: 1,
            bytes_written: 50,
            bytes_restored: 30,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.total_h(), 4.0);
        assert_eq!(a.fraction_of(40.0), 0.1);
        assert_eq!((a.n_saves, a.n_failures), (2, 1));
        assert_eq!((a.bytes_written, a.bytes_restored), (150, 30),
                   "I/O volume must accumulate like the time charges");
    }

    #[test]
    fn machine_hours_scale_with_trainer_count() {
        let l = OverheadLedger { save_h: 1.5, load_h: 0.5, ..Default::default() };
        // the paper's production shape: 18 Emb PS + 20 trainers
        assert_eq!(l.machine_hours(18, 20), 2.0 * 38.0);
        assert_eq!(l.machine_hours(8, 0), 16.0);
        assert!(l.machine_hours(8, 8) > l.machine_hours(8, 0),
                "trainers must add to the idle pool");
    }

    #[test]
    fn curve_csv_and_best() {
        let mut c = Curve::default();
        c.push(0, 0.5);
        c.push(10, 0.8);
        c.push(20, 0.7);
        assert_eq!(c.best_max(), Some(0.8));
        assert_eq!(c.last(), Some(0.7));
        assert!(c.to_csv("auc").starts_with("step,auc\n0,0.5\n"));
    }
}
