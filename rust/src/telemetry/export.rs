//! Telemetry exporters: Chrome Trace Event Format JSON, the metrics
//! snapshot (JSON + CSV), and the export-time span→histogram fold.
//!
//! The trace artifact follows the Trace Event Format's JSON-object form:
//! `{"traceEvents": [...]}` with one complete (`"ph": "X"`) event per
//! span (timestamps/durations in microseconds), instant (`"ph": "i"`)
//! events for zero-duration records, and `thread_name` metadata events
//! so every recorded thread gets a named track in `chrome://tracing` /
//! Perfetto. Everything rides the crate's own [`crate::util::json`] —
//! no serde in the offline image.

use std::collections::BTreeMap;

use super::hist::Registry;
use super::{SpanRec, NO_NODE};
use crate::util::json::{num, obj, s, Json};

/// Fold span durations into per-`(name, node)` latency histograms. This
/// runs once at export, which is why the hot path never touches the
/// registry for latency: the journal already has every sample.
pub fn fold_spans(reg: &mut Registry, spans: &[SpanRec]) {
    use super::hist::MetricKey;
    for sp in spans {
        let key = if sp.node == NO_NODE {
            MetricKey::plain(sp.name)
        } else {
            MetricKey::node(sp.name, sp.node as usize)
        };
        reg.observe(key, sp.dur_us);
    }
}

/// Build the Chrome Trace Event Format document.
pub fn chrome_trace(
    spans: &[SpanRec],
    threads: &BTreeMap<u64, String>,
    dropped: u64,
) -> Json {
    let mut events = Vec::with_capacity(threads.len() + spans.len());
    for (tid, name) in threads {
        events.push(obj(vec![
            ("ph", s("M")),
            ("name", s("thread_name")),
            ("pid", num(1.0)),
            ("tid", num(*tid as f64)),
            ("args", obj(vec![("name", s(name))])),
        ]));
    }
    for sp in spans {
        let mut fields = vec![
            ("name", s(sp.name)),
            ("cat", s("cpr")),
            ("ts", num(sp.t_start_us as f64)),
            ("pid", num(1.0)),
            ("tid", num(sp.tid as f64)),
        ];
        if sp.dur_us == 0 {
            fields.push(("ph", s("i")));
            fields.push(("s", s("t"))); // thread-scoped instant
        } else {
            fields.push(("ph", s("X")));
            fields.push(("dur", num(sp.dur_us as f64)));
        }
        if sp.node != NO_NODE {
            fields.push(("args", obj(vec![("node", num(sp.node as f64))])));
        }
        events.push(obj(fields));
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", s("ms")),
        ("droppedSpans", num(dropped as f64)),
    ])
}

fn obj_owned(pairs: Vec<(String, Json)>) -> Json {
    Json::Obj(pairs.into_iter().collect())
}

/// The metrics snapshot document: counters, gauges, and histogram
/// summaries (count/min/max/mean/p50/p95/p99/p999) keyed by rendered
/// metric name.
pub fn metrics_json(reg: &Registry) -> Json {
    let counters = obj_owned(
        reg.counters.iter().map(|(k, v)| (k.render(), num(*v as f64))).collect(),
    );
    let gauges =
        obj_owned(reg.gauges.iter().map(|(k, v)| (k.render(), num(*v))).collect());
    let hists = obj_owned(
        reg.hists
            .iter()
            .map(|(k, h)| {
                (
                    k.render(),
                    obj(vec![
                        ("count", num(h.count() as f64)),
                        ("sum", num(h.sum())),
                        ("min", num(h.min() as f64)),
                        ("max", num(h.max() as f64)),
                        ("mean", num(h.mean())),
                        ("p50", num(h.quantile(0.50) as f64)),
                        ("p95", num(h.quantile(0.95) as f64)),
                        ("p99", num(h.quantile(0.99) as f64)),
                        ("p999", num(h.quantile(0.999) as f64)),
                    ]),
                )
            })
            .collect(),
    );
    obj(vec![("counters", counters), ("gauges", gauges), ("histograms", hists)])
}

/// Flat CSV rendering of the same snapshot, one metric per row.
pub fn metrics_csv(reg: &Registry) -> String {
    let mut out =
        String::from("metric,kind,value,count,min,max,mean,p50,p95,p99,p999\n");
    for (k, v) in &reg.counters {
        out.push_str(&format!("{},counter,{v},,,,,,,,\n", k.render()));
    }
    for (k, v) in &reg.gauges {
        out.push_str(&format!("{},gauge,{v},,,,,,,,\n", k.render()));
    }
    for (k, h) in &reg.hists {
        out.push_str(&format!(
            "{},histogram,,{},{},{},{},{},{},{},{}\n",
            k.render(),
            h.count(),
            h.min(),
            h.max(),
            h.mean(),
            h.quantile(0.50),
            h.quantile(0.95),
            h.quantile(0.99),
            h.quantile(0.999),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::hist::MetricKey;

    fn spans() -> Vec<SpanRec> {
        vec![
            SpanRec { name: "gather", node: NO_NODE, tid: 1, t_start_us: 10, dur_us: 40 },
            SpanRec { name: "apply_node", node: 2, tid: 1, t_start_us: 60, dur_us: 25 },
            SpanRec { name: "failure", node: NO_NODE, tid: 2, t_start_us: 99, dur_us: 0 },
        ]
    }

    #[test]
    fn chrome_trace_shape_is_loadable() {
        let mut threads = BTreeMap::new();
        threads.insert(1u64, "trainer-0".to_string());
        threads.insert(2u64, "ckpt-writer".to_string());
        let doc = chrome_trace(&spans(), &threads, 5);
        // round-trip through the writer+parser like a real consumer
        let text = crate::util::json::JsonWriter::write(&doc);
        let back = Json::parse(&text).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 5, "2 metadata + 3 span events");
        let meta: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "M")
            .collect();
        assert_eq!(meta.len(), 2);
        let complete: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
            .collect();
        assert_eq!(complete.len(), 2);
        let apply = complete
            .iter()
            .find(|e| e.get("name").unwrap().as_str().unwrap() == "apply_node")
            .unwrap();
        assert_eq!(apply.get("dur").unwrap().as_f64().unwrap(), 25.0);
        assert_eq!(
            apply.get("args").unwrap().get("node").unwrap().as_usize().unwrap(),
            2
        );
        let instants: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "i")
            .collect();
        assert_eq!(instants.len(), 1);
        assert_eq!(back.get("droppedSpans").unwrap().as_usize().unwrap(), 5);
    }

    #[test]
    fn fold_groups_by_name_and_node() {
        let mut reg = Registry::default();
        fold_spans(&mut reg, &spans());
        assert_eq!(reg.hists[&MetricKey::plain("gather")].count(), 1);
        assert_eq!(reg.hists[&MetricKey::node("apply_node", 2)].count(), 1);
        assert_eq!(reg.hists[&MetricKey::node("apply_node", 2)].max(), 25);
        assert_eq!(reg.hists.len(), 3);
    }

    #[test]
    fn metrics_snapshot_json_and_csv_agree() {
        let mut reg = Registry::default();
        reg.counter_add(MetricKey::plain("saves"), 4);
        reg.gauge_set(MetricKey::plain("in_flight"), 1.0);
        for v in [10u64, 20, 30] {
            reg.observe(MetricKey::node("apply_node", 0), v);
        }
        let j = metrics_json(&reg);
        let h = j.get("histograms").unwrap().get("apply_node{node=0}").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize().unwrap(), 3);
        assert_eq!(h.get("min").unwrap().as_usize().unwrap(), 10);
        assert_eq!(h.get("max").unwrap().as_usize().unwrap(), 30);
        assert_eq!(j.get("counters").unwrap().get("saves").unwrap().as_usize().unwrap(), 4);
        let csv = metrics_csv(&reg);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 4, "header + 3 metrics");
        assert!(lines.iter().any(|l| l.starts_with("saves,counter,4")));
        assert!(lines.iter().any(|l| l.starts_with("apply_node{node=0},histogram")));
    }
}
