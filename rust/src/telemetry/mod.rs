//! Telemetry plane: wall-clock spans, metrics, and trace export.
//!
//! Everything the crate's other instruments measure is *emulated* time
//! (the `OverheadLedger`'s hours). This module measures what the real
//! threads do: how long a gather or a per-node apply takes, how long a
//! trainer parks on the gather barrier or a turnstile, how long each
//! stage of a durable checkpoint publish runs. It is strictly read-only
//! with respect to training state — no RNG stream, no ordering, no
//! ledgered quantity is touched, so every golden bit-equality suite
//! passes with telemetry enabled (asserted by
//! `tests/telemetry_neutrality.rs`).
//!
//! ## Recording model
//!
//! * A process-global `AtomicBool` gates everything. **The entire cost of
//!   the disabled path is one relaxed atomic load** — no clock read, no
//!   allocation, no lock.
//! * [`span`] / [`span_node`] return a guard that stamps a monotonic
//!   start time ([`Instant`] against a process-wide epoch) and records a
//!   `(name, node, t_start, t_end)` [`SpanRec`] into a **per-thread
//!   buffer** when dropped. Buffers drain into the global journal every
//!   [`FLUSH_THRESHOLD`] spans and on thread exit (a thread-local `Drop`
//!   — this is what captures the writer pool's unnamed scoped workers),
//!   so the hot path takes the journal lock ~1/64th of the time.
//! * [`counter_add`] / [`gauge_set`] / [`observe`] feed the metrics
//!   [`Registry`] directly — used only at low-frequency sites (rows per
//!   step, queue depth, bytes per publish). High-frequency per-node
//!   latency histograms are *not* fed on the hot path: they are folded
//!   out of the span journal at export time ([`export::fold_spans`]).
//! * The journal is capped at [`MAX_JOURNAL_SPANS`]; overflow increments
//!   a dropped-count surfaced in the trace artifact rather than growing
//!   without bound.
//!
//! ## Lifecycle
//!
//! The coordinator builds a [`TelemetrySink`] from `[telemetry]` config
//! at run start and calls [`TelemetrySink::export`] after the trainer
//! pool stops: the journal + registry are drained, span durations are
//! folded into per-`(name, node)` histograms, and — when a directory is
//! configured — `trace.json` (Chrome Trace Event Format, loadable in
//! `chrome://tracing` / Perfetto, one track per thread), `metrics.json`,
//! and `metrics.csv` are written. Export failures must never fail
//! training; the coordinator logs and continues.

pub mod export;
pub mod hist;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};
use std::time::Instant;

use anyhow::{Context, Result};

pub use hist::{Histogram, MetricKey, Registry};

use crate::config::TelemetryConfig;

/// Spans buffered per thread before draining into the global journal.
const FLUSH_THRESHOLD: usize = 64;
/// Journal cap: beyond this, spans are counted as dropped, not stored
/// (4M spans ≈ a few hundred MB worst case — plenty for any smoke run).
const MAX_JOURNAL_SPANS: usize = 4_000_000;
/// Sentinel node id for spans without a node label.
pub(crate) const NO_NODE: u32 = u32::MAX;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is the telemetry plane recording? One relaxed load — this is the
/// entire disabled-path cost at every instrumentation site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The process-wide monotonic epoch. Set once on first use and never
/// reset, so span timestamps from different threads and different sinks
/// share one timeline.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// One completed span: a named wall-clock interval on one thread, with
/// an optional PS-node label.
#[derive(Clone, Debug)]
pub struct SpanRec {
    pub name: &'static str,
    /// Labeled node id, or [`NO_NODE`].
    pub node: u32,
    /// Journal-assigned thread id (chrome-trace track).
    pub tid: u64,
    pub t_start_us: u64,
    pub dur_us: u64,
}

/// The global journal: drained per-thread buffers + the thread-name
/// table (tid → name) for the chrome-trace metadata track.
#[derive(Default)]
struct Journal {
    spans: Vec<SpanRec>,
    threads: BTreeMap<u64, String>,
    dropped: u64,
}

fn journal() -> &'static Mutex<Journal> {
    static J: OnceLock<Mutex<Journal>> = OnceLock::new();
    J.get_or_init(|| Mutex::new(Journal::default()))
}

fn registry() -> &'static Mutex<Registry> {
    static R: OnceLock<Mutex<Registry>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Registry::default()))
}

struct ThreadBuf {
    tid: u64,
    name: String,
    /// thread name already registered in the journal
    named: bool,
    spans: Vec<SpanRec>,
}

impl ThreadBuf {
    fn new() -> Self {
        static NEXT_TID: AtomicU64 = AtomicU64::new(1);
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let name = std::thread::current()
            .name()
            .map(str::to_string)
            .unwrap_or_else(|| format!("thread-{tid}"));
        Self { tid, name, named: false, spans: Vec::new() }
    }

    fn flush(&mut self) {
        if self.spans.is_empty() {
            return;
        }
        let mut j = journal().lock().unwrap_or_else(PoisonError::into_inner);
        if !self.named {
            j.threads.insert(self.tid, self.name.clone());
            self.named = true;
        }
        let room = MAX_JOURNAL_SPANS.saturating_sub(j.spans.len());
        if self.spans.len() > room {
            j.dropped += (self.spans.len() - room) as u64;
            self.spans.truncate(room);
        }
        j.spans.append(&mut self.spans);
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static BUF: RefCell<ThreadBuf> = RefCell::new(ThreadBuf::new());
}

fn record(mut rec: SpanRec) {
    // try_with: a span dropped during thread teardown (after the TLS
    // buffer is destroyed) is silently lost rather than panicking
    let _ = BUF.try_with(|b| {
        let mut b = b.borrow_mut();
        rec.tid = b.tid;
        b.spans.push(rec);
        if b.spans.len() >= FLUSH_THRESHOLD {
            b.flush();
        }
    });
}

/// Drain this thread's span buffer into the journal. Long-lived threads
/// that outlive the sink (the coordinator itself, the pipeline writer at
/// its flush barrier, trainers on `Stop`) call this so their tail spans
/// make the export.
pub fn flush_thread() {
    let _ = BUF.try_with(|b| b.borrow_mut().flush());
}

/// RAII span guard: records the interval from construction to drop.
#[must_use = "a span records the interval until it is dropped"]
pub struct Span {
    name: &'static str,
    node: u32,
    start_us: u64,
    live: bool,
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        let end = now_us();
        record(SpanRec {
            name: self.name,
            node: self.node,
            tid: 0,
            t_start_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
        });
    }
}

/// Open a span. `name` must be `'static` (span names are a fixed
/// taxonomy, not formatted strings — see DESIGN.md "Telemetry plane").
#[inline]
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span { name, node: NO_NODE, start_us: 0, live: false };
    }
    Span { name, node: NO_NODE, start_us: now_us(), live: true }
}

/// Open a span labeled with a PS node id (per-node latency families).
#[inline]
pub fn span_node(name: &'static str, node: usize) -> Span {
    if !enabled() {
        return Span { name, node: NO_NODE, start_us: 0, live: false };
    }
    Span { name, node: node as u32, start_us: now_us(), live: true }
}

/// Record a zero-duration instant (exported as a chrome-trace instant
/// event): failures, re-plans, kills.
#[inline]
pub fn event(name: &'static str) {
    if !enabled() {
        return;
    }
    let t = now_us();
    record(SpanRec { name, node: NO_NODE, tid: 0, t_start_us: t, dur_us: 0 });
}

// ---------------------------------------------------------------------------
// metrics (direct registry feeds — low-frequency sites only)
// ---------------------------------------------------------------------------

#[inline]
pub fn counter_add(name: &'static str, delta: u64) {
    if !enabled() {
        return;
    }
    registry()
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .counter_add(MetricKey::plain(name), delta);
}

#[inline]
pub fn gauge_set(name: &'static str, v: f64) {
    if !enabled() {
        return;
    }
    registry()
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .gauge_set(MetricKey::plain(name), v);
}

/// Feed one sample into the named histogram (unit is the caller's:
/// bytes, rows, microseconds).
#[inline]
pub fn observe(name: &'static str, v: u64) {
    if !enabled() {
        return;
    }
    registry()
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .observe(MetricKey::plain(name), v);
}

#[inline]
pub fn observe_node(name: &'static str, node: usize, v: u64) {
    if !enabled() {
        return;
    }
    registry()
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .observe(MetricKey::node(name, node), v);
}

fn reset() {
    let mut j = journal().lock().unwrap_or_else(PoisonError::into_inner);
    j.spans.clear();
    j.threads.clear();
    j.dropped = 0;
    *registry().lock().unwrap_or_else(PoisonError::into_inner) = Registry::default();
}

// ---------------------------------------------------------------------------
// sink
// ---------------------------------------------------------------------------

/// What an export drained (for the coordinator's closing log line).
#[derive(Debug, Default, Clone)]
pub struct ExportStats {
    pub spans: usize,
    pub dropped: u64,
    pub dir: Option<PathBuf>,
}

/// Handle tying the global recorder to one training run. Construction
/// from an enabled config clears any prior journal/registry content and
/// turns recording on; [`TelemetrySink::export`] (or drop) turns it off.
/// A sink built from a disabled config is a pure no-op — this is the
/// only switch, so an uninstrumented run never pays more than the
/// per-site relaxed load.
pub struct TelemetrySink {
    active: bool,
    dir: Option<PathBuf>,
}

impl TelemetrySink {
    /// The no-op sink (recording stays off).
    pub fn disabled() -> Self {
        Self { active: false, dir: None }
    }

    /// Build from `[telemetry]` config. A configured export dir implies
    /// enablement.
    pub fn from_config(cfg: &TelemetryConfig) -> Self {
        let dir = cfg.dir.as_ref().map(PathBuf::from);
        if !cfg.enabled && dir.is_none() {
            return Self::disabled();
        }
        reset();
        ENABLED.store(true, Ordering::Relaxed);
        Self { active: true, dir }
    }

    pub fn enabled(&self) -> bool {
        self.active
    }

    /// Stop recording, drain the journal + registry, fold span durations
    /// into per-`(name, node)` latency histograms, and write the trace +
    /// metrics artifacts when an export dir is configured. Idempotent;
    /// callers treat an `Err` as a warning (training already succeeded).
    pub fn export(&mut self) -> Result<ExportStats> {
        if !self.active {
            return Ok(ExportStats::default());
        }
        self.active = false;
        ENABLED.store(false, Ordering::Relaxed);
        flush_thread();
        let (spans, threads, dropped) = {
            let mut j = journal().lock().unwrap_or_else(PoisonError::into_inner);
            (std::mem::take(&mut j.spans), std::mem::take(&mut j.threads), {
                let d = j.dropped;
                j.dropped = 0;
                d
            })
        };
        let mut reg = std::mem::take(
            &mut *registry().lock().unwrap_or_else(PoisonError::into_inner),
        );
        export::fold_spans(&mut reg, &spans);
        let stats = ExportStats { spans: spans.len(), dropped, dir: self.dir.clone() };
        if let Some(dir) = &self.dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating telemetry dir {}", dir.display()))?;
            let trace = export::chrome_trace(&spans, &threads, dropped);
            std::fs::write(dir.join("trace.json"), crate::util::json::JsonWriter::write(&trace))
                .context("writing trace.json")?;
            let metrics = export::metrics_json(&reg);
            std::fs::write(
                dir.join("metrics.json"),
                crate::util::json::JsonWriter::write(&metrics),
            )
            .context("writing metrics.json")?;
            std::fs::write(dir.join("metrics.csv"), export::metrics_csv(&reg))
                .context("writing metrics.csv")?;
        }
        Ok(stats)
    }
}

impl Drop for TelemetrySink {
    fn drop(&mut self) {
        if self.active {
            // dropped without export (early error path): just stop
            // recording; the next sink's reset clears the leftovers
            self.active = false;
            ENABLED.store(false, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests here toggle the process-global enable; serialize them so
    /// they cannot observe each other's journals. (Other unit tests in
    /// the binary never enable telemetry, and all assertions below are
    /// containment-based, so concurrent foreign spans are harmless.)
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn on() -> TelemetrySink {
        TelemetrySink::from_config(&TelemetryConfig {
            enabled: true,
            dir: None,
            progress_steps: 0,
        })
    }

    fn drain_names() -> Vec<&'static str> {
        flush_thread();
        journal()
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .spans
            .iter()
            .map(|s| s.name)
            .collect()
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        let mut sink = TelemetrySink::disabled();
        assert!(!sink.enabled());
        {
            let _s = span("tm_disabled_probe");
        }
        event("tm_disabled_probe");
        counter_add("tm_disabled_probe", 1);
        assert!(!drain_names().contains(&"tm_disabled_probe"));
        let stats = sink.export().unwrap();
        assert_eq!(stats.spans, 0);
    }

    #[test]
    fn spans_and_events_reach_the_journal() {
        let _g = TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        let mut sink = on();
        {
            let _s = span("tm_probe_span");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        {
            let _s = span_node("tm_probe_node", 3);
        }
        event("tm_probe_event");
        // a worker thread's buffer flushes on thread exit (TLS Drop)
        std::thread::Builder::new()
            .name("tm-worker".into())
            .spawn(|| {
                let _s = span("tm_probe_worker");
            })
            .unwrap()
            .join()
            .unwrap();
        flush_thread();
        {
            let j = journal().lock().unwrap_or_else(PoisonError::into_inner);
            let find = |n: &str| j.spans.iter().find(|s| s.name == n).cloned();
            let main_span = find("tm_probe_span").expect("span recorded");
            assert!(main_span.dur_us >= 1_000, "slept 1ms inside the span");
            assert_eq!(find("tm_probe_node").unwrap().node, 3);
            assert_eq!(find("tm_probe_event").unwrap().dur_us, 0);
            let worker = find("tm_probe_worker").expect("worker span flushed on exit");
            assert_ne!(worker.tid, main_span.tid);
            assert_eq!(j.threads[&worker.tid], "tm-worker");
        }
        let stats = sink.export().unwrap();
        assert!(stats.spans >= 4);
        assert!(!enabled(), "export turns recording off");
    }

    #[test]
    fn export_folds_spans_and_writes_artifacts() {
        let _g = TEST_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        let dir = std::env::temp_dir().join("cpr_telemetry_test");
        std::fs::remove_dir_all(&dir).ok();
        let mut sink = TelemetrySink::from_config(&TelemetryConfig {
            enabled: true,
            dir: Some(dir.to_str().unwrap().to_string()),
            progress_steps: 0,
        });
        for node in 0..2usize {
            for _ in 0..3 {
                let _s = span_node("tm_fold_apply", node);
            }
        }
        counter_add("tm_fold_counter", 7);
        gauge_set("tm_fold_gauge", 2.5);
        observe("tm_fold_bytes", 4096);
        sink.export().unwrap();
        let trace =
            crate::util::json::Json::parse(&std::fs::read_to_string(dir.join("trace.json")).unwrap())
                .unwrap();
        let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(events.iter().any(|e| {
            e.get("name").map(|n| n.as_str().unwrap_or("")) == Ok("tm_fold_apply")
        }));
        let metrics = crate::util::json::Json::parse(
            &std::fs::read_to_string(dir.join("metrics.json")).unwrap(),
        )
        .unwrap();
        // span durations folded into per-node histogram families
        let hists = metrics.get("histograms").unwrap();
        for node in 0..2 {
            let h = hists.get(&format!("tm_fold_apply{{node={node}}}")).unwrap();
            assert_eq!(h.get("count").unwrap().as_usize().unwrap(), 3);
            assert!(h.get("p99").is_ok() && h.get("p50").is_ok());
        }
        assert_eq!(
            metrics.get("counters").unwrap().get("tm_fold_counter").unwrap()
                .as_usize().unwrap(),
            7
        );
        let csv = std::fs::read_to_string(dir.join("metrics.csv")).unwrap();
        assert!(csv.lines().next().unwrap().starts_with("metric,kind"));
        assert!(csv.contains("tm_fold_gauge"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
