//! Log-bucketed histograms + the metrics registry.
//!
//! [`Histogram`] is an HdrHistogram-style log-linear sketch: values below
//! 16 get exact unit buckets; above that each power-of-two octave is split
//! into 8 sub-buckets, so any recorded value lands in a bucket whose width
//! is at most 1/8 of its magnitude (~12.5 % relative quantile error,
//! constant 4 KB memory per histogram, O(1) insert). That is the right
//! trade for latency telemetry: p50/p95/p99/p999 of microsecond spans,
//! never exact percentiles.
//!
//! [`Registry`] is the plain-data map of counters / gauges / histograms
//! keyed by [`MetricKey`] (metric name + optional static label, e.g.
//! `apply_node{node=3}`). It has no locking and no global state — the
//! process-wide instance and its enabled-gating live in
//! [`super`](crate::telemetry); this file stays purely computational so
//! the bucket math is unit-testable in isolation.

use std::collections::BTreeMap;

const SUB_BITS: u32 = 3;
/// Sub-buckets per power-of-two octave.
const SUB: usize = 1 << SUB_BITS;
/// Values below this get exact unit buckets.
const EXACT: u64 = 2 * SUB as u64;
/// 16 exact buckets + 8 sub-buckets for each octave 2^4 ..= 2^63.
pub const N_BUCKETS: usize = EXACT as usize + (63 - SUB_BITS as usize) * SUB;

fn bucket_index(v: u64) -> usize {
    if v < EXACT {
        return v as usize;
    }
    let exp = 63 - v.leading_zeros(); // >= SUB_BITS + 1
    let sub = ((v >> (exp - SUB_BITS)) as usize) & (SUB - 1);
    EXACT as usize + (exp - SUB_BITS - 1) as usize * SUB + sub
}

/// Smallest value that lands in bucket `idx` (inverse of `bucket_index`).
fn bucket_floor(idx: usize) -> u64 {
    if idx < EXACT as usize {
        return idx as u64;
    }
    let b = idx - EXACT as usize;
    let exp = SUB_BITS + 1 + (b / SUB) as u32;
    let sub = (b % SUB) as u64;
    (1u64 << exp) + (sub << (exp - SUB_BITS))
}

/// Representative value reported for bucket `idx`: exact for the unit
/// buckets, bucket midpoint above (half the ~12.5 % bucket width off at
/// worst).
fn bucket_rep(idx: usize) -> u64 {
    if idx < EXACT as usize {
        return idx as u64;
    }
    let b = idx - EXACT as usize;
    let exp = SUB_BITS + 1 + (b / SUB) as u32;
    let width = 1u64 << (exp - SUB_BITS);
    bucket_floor(idx) + width / 2
}

/// Fixed-memory log-bucketed histogram of non-negative integer samples
/// (microseconds, bytes, rows — unit is the caller's convention).
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self { counts: vec![0; N_BUCKETS], total: 0, sum: 0.0, min: u64::MAX, max: 0 }
    }
}

impl Histogram {
    pub fn observe(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.total += 1;
        self.sum += v as f64;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 { 0 } else { self.min }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.sum / self.total as f64 }
    }

    /// Fold another histogram's samples into this one (identical fixed
    /// bucket layout, so the merge is a plain per-bucket add). Lets
    /// serving load-generator clients record into thread-local histograms
    /// contention-free and combine them once at shutdown.
    pub fn merge(&mut self, other: &Histogram) {
        for (dst, &src) in self.counts.iter_mut().zip(&other.counts) {
            *dst += src;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// The q-quantile (q in [0, 1]) to within the bucket resolution,
    /// clamped to the observed [min, max] so small samples report sane
    /// tails (p999 of 3 samples is the max, not a bucket ceiling).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_rep(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

/// A metric's identity: name + at most one static label (node id, rank —
/// all-`'static` so hot-path keying allocates nothing).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct MetricKey {
    pub name: &'static str,
    pub label: Option<(&'static str, u64)>,
}

impl MetricKey {
    pub fn plain(name: &'static str) -> Self {
        Self { name, label: None }
    }

    pub fn node(name: &'static str, node: usize) -> Self {
        Self { name, label: Some(("node", node as u64)) }
    }

    /// Prometheus-flavoured rendering: `name` or `name{node=3}`.
    pub fn render(&self) -> String {
        match self.label {
            None => self.name.to_string(),
            Some((k, v)) => format!("{}{{{k}={v}}}", self.name),
        }
    }
}

/// Plain-data metric store: monotonically increasing counters, last-value
/// gauges, and log-bucketed histograms.
#[derive(Default, Clone, Debug)]
pub struct Registry {
    pub counters: BTreeMap<MetricKey, u64>,
    pub gauges: BTreeMap<MetricKey, f64>,
    pub hists: BTreeMap<MetricKey, Histogram>,
}

impl Registry {
    pub fn counter_add(&mut self, key: MetricKey, delta: u64) {
        *self.counters.entry(key).or_insert(0) += delta;
    }

    pub fn gauge_set(&mut self, key: MetricKey, v: f64) {
        self.gauges.insert(key, v);
    }

    pub fn observe(&mut self, key: MetricKey, v: u64) {
        self.hists.entry(key).or_default().observe(v);
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_and_floor_are_consistent() {
        // every bucket's floor maps back to that bucket, and indices are
        // monotone in the value
        for idx in 0..N_BUCKETS {
            assert_eq!(bucket_index(bucket_floor(idx)), idx, "idx {idx}");
        }
        let mut last = 0;
        for v in [0u64, 1, 7, 15, 16, 17, 31, 32, 100, 1000, 65_535,
                  1 << 20, (1 << 40) + 12345, u64::MAX] {
            let idx = bucket_index(v);
            assert!(idx >= last, "index must be monotone at v={v}");
            assert!(idx < N_BUCKETS);
            assert!(bucket_floor(idx) <= v, "floor exceeds value at v={v}");
            last = idx;
        }
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = Histogram::default();
        for v in [0u64, 1, 2, 3, 5, 15] {
            h.observe(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 15);
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(0.5), 2);
        assert_eq!(h.quantile(1.0), 15);
    }

    #[test]
    fn quantiles_within_bucket_resolution() {
        let mut h = Histogram::default();
        for v in 1..=10_000u64 {
            h.observe(v);
        }
        for (q, want) in [(0.5, 5_000.0), (0.95, 9_500.0), (0.99, 9_900.0)] {
            let got = h.quantile(q) as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.13, "q={q}: got {got}, want ~{want} (rel {rel})");
        }
        assert!((h.mean() - 5_000.5).abs() < 1e-6);
        assert_eq!(h.quantile(1.0), 10_000);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.99), 0);
    }

    #[test]
    fn merge_equals_observing_everything_in_one() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        let mut all = Histogram::default();
        for v in [3u64, 17, 250, 9_000] {
            a.observe(v);
            all.observe(v);
        }
        for v in [1u64, 40_000] {
            b.observe(v);
            all.observe(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        assert_eq!(a.sum(), all.sum());
        for q in [0.0, 0.5, 0.95, 0.99, 0.999, 1.0] {
            assert_eq!(a.quantile(q), all.quantile(q), "q={q}");
        }
        // merging an empty histogram is a no-op either direction
        let before = a.count();
        a.merge(&Histogram::default());
        assert_eq!(a.count(), before);
        let mut empty = Histogram::default();
        empty.merge(&a);
        assert_eq!(empty.min(), a.min());
        assert_eq!(empty.quantile(0.5), a.quantile(0.5));
    }

    #[test]
    fn metric_key_renders_labels() {
        assert_eq!(MetricKey::plain("gather").render(), "gather");
        assert_eq!(MetricKey::node("apply_node", 3).render(), "apply_node{node=3}");
        // keys order by name then label, so per-node families group
        assert!(MetricKey::node("a", 1) < MetricKey::node("a", 2));
        assert!(MetricKey::node("a", 9) < MetricKey::plain("b"));
    }

    #[test]
    fn registry_accumulates() {
        let mut r = Registry::default();
        assert!(r.is_empty());
        r.counter_add(MetricKey::plain("c"), 2);
        r.counter_add(MetricKey::plain("c"), 3);
        r.gauge_set(MetricKey::plain("g"), 1.5);
        r.gauge_set(MetricKey::plain("g"), 2.5);
        r.observe(MetricKey::node("h", 0), 100);
        assert_eq!(r.counters[&MetricKey::plain("c")], 5);
        assert_eq!(r.gauges[&MetricKey::plain("g")], 2.5);
        assert_eq!(r.hists[&MetricKey::node("h", 0)].count(), 1);
    }
}
