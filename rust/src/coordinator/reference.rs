//! The pre-refactor single-trainer step loop, preserved verbatim.
//!
//! When the coordinator was refactored into a driver over the
//! data-parallel [`crate::trainer::TrainerPool`], this module kept the
//! original inline loop (one trainer, one batch per step, no pool, no
//! allreduce) as an executable specification: the integration suite runs
//! the same job through both paths and asserts the N = 1 multi-trainer
//! run is **bit-identical** (final AUC / logloss / PLS / loss curve) on
//! both cluster backends. If the driver ever diverges from this loop at
//! N = 1, that test fails.
//!
//! Differences from the driver are intentional and minimal:
//! * exactly one trainer: params live in device buffers across steps,
//!   there is no replica averaging;
//! * [`crate::failure::FailureEvent::trainer_victims`] predates this
//!   loop and is ignored (events still charge load/reschedule, exactly
//!   as the pre-refactor code charged every event).
//!
//! Quiesce contract: with exactly one trainer — this thread — every point
//! in the loop is trivially a step barrier, so the control-plane calls
//! below (`kill_node`/`respawn_node` on failure injection, checkpoint
//! save/restore) need no [`crate::cluster::PsQuiesce`] token: the sole
//! writer is the caller itself.

use anyhow::{ensure, Result};

use crate::checkpoint::async_pipeline::CheckpointPipeline;
use crate::checkpoint::tracker::{priority_mask, MfuTracker, ScarTracker, SsuTracker};
use crate::checkpoint::{
    full_content_io_bytes, mlp_io_bytes, node_content_io_bytes, rows_io_bytes,
    CheckpointOptions, CheckpointStore,
};
use crate::cluster::{PsBackend, ThreadedCluster};
use crate::config::{JobConfig, PsBackendKind, Strategy};
use crate::data::{Batch, SyntheticDataset};
use crate::embedding::{init_value, PsCluster, TableInfo};
use crate::metrics::{Curve, OverheadLedger};
use crate::pls::{self, PlsAccumulator};
use crate::runtime::{ModelExe, PjRtBuffer};

use super::{evaluate, RowStats, RunOptions, TrainReport};

/// Run one emulated training job through the preserved single-trainer
/// loop. `cfg.cluster.n_trainers` is ignored (always 1).
pub fn run_training_reference(
    model: &ModelExe,
    cfg: &JobConfig,
    opts: &RunOptions,
) -> Result<TrainReport> {
    let tables: Vec<TableInfo> = cfg
        .data
        .table_rows
        .iter()
        .map(|&rows| TableInfo { rows, dim: model.manifest.emb_dim })
        .collect();
    let n_emb = cfg.cluster.n_emb_ps;
    let seed = cfg.data.seed ^ 0xEB;
    match cfg.cluster.backend {
        PsBackendKind::InProc => {
            run_reference_core(model, cfg, opts, PsCluster::new(tables, n_emb, seed))
        }
        PsBackendKind::Threaded => {
            run_reference_core(model, cfg, opts, ThreadedCluster::new(tables, n_emb, seed))
        }
    }
}

fn run_reference_core<B: PsBackend>(
    model: &ModelExe,
    cfg: &JobConfig,
    opts: &RunOptions,
    cluster: B,
) -> Result<TrainReport> {
    let m = &model.manifest;
    ensure!(m.batch == cfg.model.batch, "artifact batch mismatch");
    ensure!(m.num_sparse == cfg.model.num_sparse, "artifact num_sparse mismatch");
    ensure!(m.emb_dim == cfg.model.emb_dim, "artifact emb_dim mismatch");
    ensure!(
        cfg.data.train_samples % m.batch == 0
            && cfg.data.eval_samples % m.batch == 0,
        "sample counts must be batch multiples"
    );

    let wall_start = std::time::Instant::now();
    let strategy = cfg.checkpoint.strategy.clone();
    let n_emb = cfg.cluster.n_emb_ps;
    let batch = m.batch;
    let total_steps = (cfg.data.train_samples / batch) as u64;
    let dt_h = cfg.cluster.t_total_h / total_steps as f64;

    // --- build the job state ------------------------------------------------
    let dataset = SyntheticDataset::new(m.num_dense, &cfg.data);
    let mut params: Vec<PjRtBuffer> = model.init_params(cfg.train.seed);
    // the reference path stays on the v1 monolithic format: no codec,
    // no delta chains — it is the bit-for-bit baseline the strategy
    // goldens are anchored to
    let pipeline = CheckpointPipeline::with_options(
        CheckpointStore::initial(&cluster, model.params_to_host(&params)?),
        &CheckpointOptions::default().dir(cfg.checkpoint.dir.as_deref()),
    )?;
    let mut marked_step: u64 = 0;
    let mut marked_samples: u64 = 0;

    // --- the CPR controller decides the plan --------------------------------
    let (plan, use_partial, mut t_save_h) = match strategy {
        Strategy::Full => (None, false, cfg.cluster.t_save_full_h()),
        Strategy::PartialNaive => (None, true, cfg.cluster.t_save_full_h()),
        _ => {
            let p = pls::plan(&cfg.cluster, cfg.checkpoint.target_pls);
            let partial = p.use_partial;
            let t = p.t_save_h;
            (Some(p), partial, t)
        }
    };
    if let Some(t) = cfg.checkpoint.t_save_override_h {
        t_save_h = t;
    }
    let fell_back = matches!(
        strategy,
        Strategy::CprVanilla | Strategy::CprScar | Strategy::CprMfu | Strategy::CprSsu
    ) && !use_partial;

    // --- priority trackers ----------------------------------------------------
    let priority = strategy.priority() && use_partial;
    let mask = priority_mask(&cfg.data.table_rows, cfg.checkpoint.priority_tables);
    let r = cfg.checkpoint.r;
    let mut mfu = match strategy {
        Strategy::CprMfu if priority => {
            Some(MfuTracker::new(&cfg.data.table_rows, &mask))
        }
        _ => None,
    };
    let mut ssu = match strategy {
        Strategy::CprSsu if priority => {
            let caps: Vec<usize> = cfg
                .data
                .table_rows
                .iter()
                .map(|&n| ((n as f64 * r).ceil() as usize).max(1))
                .collect();
            Some(SsuTracker::new(&caps, &mask, cfg.checkpoint.ssu_period,
                                 cfg.data.seed ^ 0x55))
        }
        _ => None,
    };
    let mut scar = match strategy {
        Strategy::CprScar if priority => Some(ScarTracker::new(&cluster, &mask)),
        _ => None,
    };
    let mut stat_counts = if opts.collect_row_stats {
        Some(MfuTracker::new(&cfg.data.table_rows,
                             &vec![true; cfg.data.table_rows.len()]))
    } else {
        None
    };

    // --- save cadence -----------------------------------------------------------
    let save_interval_h = if priority { r * t_save_h } else { t_save_h };
    let minors_per_major = if priority { (1.0 / r).round() as u64 } else { 1 };
    let mut next_save_h = save_interval_h;
    let mut minor_count: u64 = 0;

    // --- failure schedule (consumed in order of useful-progress time) --------
    let mut schedule = opts.schedule.clone();
    schedule.sort_by(|a, b| a.time_h.partial_cmp(&b.time_h).unwrap());
    let mut next_event = 0usize;

    // --- main loop ----------------------------------------------------------------
    let mut ledger = OverheadLedger::default();
    let mut pls_acc = PlsAccumulator::new();
    let mut train_loss = Curve::default();
    let mut eval_auc_curve = Curve::default();
    let log_every = if opts.log_every == 0 { 50 } else { opts.log_every };

    let hotness = cfg.data.hotness;
    let mut batch_buf =
        Batch::zeros_hot(batch, m.num_dense, m.num_sparse, hotness);
    let mut emb_buf = vec![0.0f32; batch * m.num_sparse * m.emb_dim];
    let mut step: u64 = 0;
    let mut steps_executed: u64 = 0;

    while step < total_steps {
        // gather (pooled over hotness) → train step → scatter
        dataset.fill_train_batch(step * batch as u64, &mut batch_buf);
        cluster.gather_pooled(&batch_buf.indices, hotness, &mut emb_buf);
        let out = model.train_step(
            &batch_buf.dense,
            &emb_buf,
            &batch_buf.labels,
            cfg.train.lr,
            &mut params,
        )?;
        cluster.apply_grads(&batch_buf.indices, hotness, &out.emb_grad,
                            cfg.train.emb_lr, cfg.train.emb_optimizer);

        // trackers observe the access stream
        if let Some(t) = mfu.as_mut() {
            t.record_batch_hot(&batch_buf.indices, m.num_sparse, hotness);
        }
        if let Some(t) = ssu.as_mut() {
            t.record_batch_hot(&batch_buf.indices, m.num_sparse, hotness);
        }
        if let Some(t) = stat_counts.as_mut() {
            t.record_batch_hot(&batch_buf.indices, m.num_sparse, hotness);
        }

        step += 1;
        steps_executed += 1;
        let clock_h = step as f64 * dt_h;

        if step % log_every as u64 == 0 || step == total_steps {
            train_loss.push(step, out.loss as f64);
        }
        if opts.eval_every > 0 && step % opts.eval_every as u64 == 0 {
            let (a, _) = evaluate(model, cfg, &dataset, &cluster, &params)?;
            eval_auc_curve.push(step, a);
        }

        // ---- checkpoint saves up to the current clock ----
        while clock_h >= next_save_h && next_save_h <= cfg.cluster.t_total_h {
            minor_count += 1;
            if priority {
                ledger.save_h += r * cfg.cluster.o_save_h;
                for t in 0..cluster.tables().len() {
                    let dim = cluster.tables()[t].dim;
                    if mask[t] {
                        let rows_in_table = cluster.tables()[t].rows;
                        let k = ((rows_in_table as f64 * r).ceil() as usize).max(1);
                        let rows: Vec<u32> = if let Some(tr) = mfu.as_mut() {
                            let sel = tr.top_k(t, k);
                            tr.clear_rows(t, &sel);
                            sel
                        } else if let Some(tr) = ssu.as_mut() {
                            tr.drain(t)
                        } else if let Some(tr) = scar.as_mut() {
                            tr.top_k(&cluster, t, k)
                        } else {
                            unreachable!()
                        };
                        ledger.bytes_written += rows_io_bytes(rows.len(), dim);
                        pipeline.save_rows(&cluster, t, &rows);
                        if let Some(tr) = scar.as_mut() {
                            tr.mark_saved(&cluster, t, &rows);
                        }
                    } else {
                        ledger.bytes_written +=
                            rows_io_bytes(cluster.tables()[t].rows, dim);
                        pipeline.save_table(&cluster, t);
                    }
                }
                if minor_count % minors_per_major == 0 {
                    let host = model.params_to_host(&params)?;
                    ledger.bytes_written += mlp_io_bytes(&host);
                    pipeline.mark_position(host, step, step * batch as u64);
                    marked_step = step;
                    marked_samples = step * batch as u64;
                    ledger.n_saves += 1;
                }
            } else {
                ledger.save_h += cfg.cluster.o_save_h;
                ledger.n_saves += 1;
                let host = model.params_to_host(&params)?;
                ledger.bytes_written += full_content_io_bytes(cluster.tables(), &host);
                pipeline.full_save(&cluster, host, step, step * batch as u64);
                marked_step = step;
                marked_samples = step * batch as u64;
            }
            next_save_h += save_interval_h;
        }

        // ---- failures that fire at/before the current clock ----
        while next_event < schedule.len() && schedule[next_event].time_h <= clock_h {
            let ev = schedule[next_event].clone();
            next_event += 1;
            ledger.n_failures += 1;
            ledger.load_h += cfg.cluster.o_load_h;
            ledger.reschedule_h += cfg.cluster.o_res_h;
            if use_partial {
                pls_acc.on_failure(
                    step * batch as u64,
                    marked_samples,
                    cfg.data.train_samples as u64,
                    n_emb,
                    ev.victims.len(),
                );
                for &v in &ev.victims {
                    ledger.bytes_restored +=
                        node_content_io_bytes(cluster.tables(), n_emb, v);
                    cluster.kill_node(v);
                    cluster.respawn_node(v);
                    pipeline.restore_node(&cluster, v);
                }
            } else {
                let t_last = marked_step as f64 * dt_h;
                ledger.lost_h += (clock_h - t_last).max(0.0);
                let (mlp, ckpt_step, _samples) = pipeline.restore_all(&cluster);
                ledger.bytes_restored +=
                    full_content_io_bytes(cluster.tables(), &mlp);
                params = model.params_from_host(&mlp);
                step = ckpt_step;
            }
        }
    }

    pipeline.flush()?;

    // --- final evaluation --------------------------------------------------------
    let (final_auc, final_logloss) =
        evaluate(model, cfg, &dataset, &cluster, &params)?;
    eval_auc_curve.push(total_steps, final_auc);

    // --- Fig. 6 stats ---------------------------------------------------------------
    let row_stats = stat_counts.map(|counts| {
        let mut rows = Vec::new();
        let dim = m.emb_dim;
        for t in 0..cluster.tables().len() {
            if !mask[t] {
                continue;
            }
            let info = cluster.tables()[t];
            let ids: Vec<u32> = (0..info.rows as u32).collect();
            let (data, _) = cluster.read_rows(t, &ids);
            for rrow in 0..info.rows {
                let cur = &data[rrow * dim..(rrow + 1) * dim];
                let mut change = 0.0f64;
                for (d, &c) in cur.iter().enumerate() {
                    let init = init_value(cfg.data.seed ^ 0xEB, t, rrow, d);
                    change += ((c - init) as f64).powi(2);
                }
                rows.push((t, rrow as u32, counts.count(t, rrow as u32),
                           change.sqrt()));
            }
        }
        RowStats { rows }
    });

    // the reference loop never plans, so unique_rows/dedup_hits stay 0
    let ps_stats = crate::cluster::PsControlPlane::stats(&cluster);
    Ok(TrainReport {
        strategy: strategy.name().to_string(),
        backend: cluster.name().to_string(),
        n_trainers: 1,
        final_auc,
        final_logloss,
        train_loss,
        eval_auc: eval_auc_curve,
        overhead_frac: ledger.fraction_of(cfg.cluster.t_total_h),
        ledger,
        pls: pls_acc.value(),
        plan,
        fell_back,
        steps_executed,
        failures_seen: next_event as u64,
        wall_secs: wall_start.elapsed().as_secs_f64(),
        row_stats,
        serving: None,
        ps_stats,
    })
}
