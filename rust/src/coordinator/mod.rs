//! The training coordinator — CPR's L3 contribution.
//!
//! Owns the whole emulated job: the N data-parallel trainer replicas
//! (each a [`crate::trainer::TrainerPool`] worker thread with its own
//! `ModelExe`), the sharded Emb PS cluster, the synthetic dataset, the
//! checkpoint-policy engine, the failure schedule, and the PLS
//! controller. One call to [`run_training`] executes a full
//! single-epoch job under a chosen `config::Strategy` and returns a
//! [`TrainReport`] with model quality + the overhead ledger.
//!
//! ## The policy engine
//! Every checkpoint/recovery decision lives behind the
//! [`crate::policy`] traits: the registry maps the configured strategy
//! to a [`crate::policy::JobPolicies`] bundle up front, and the step
//! loop is a strategy-free driver — it feeds the access streams to
//! `SavePolicy::on_step`, captures whenever the clock reaches
//! `SavePolicy::next_save_h`, and routes failure events through
//! `RecoveryPolicy::on_failure`, applying the returned
//! [`crate::policy::RecoveryAction`] to its own state (dense params,
//! step counter). No `Strategy` or tracker-variant branching remains in
//! the loop; new policies (like the online-replanned
//! `policy::AdaptiveInterval`) plug in at the registry.
//!
//! ## Multi-trainer driver
//! `run_training` is a *driver* over the trainer pool: each global step,
//! the N trainers gather concurrently from the shared [`PsBackend`]
//! (straight through the [`ShardedPs`] data plane — per-node interior
//! locks, no global lock), hit a gather barrier, compute their local
//! train step, apply sparse updates through per-node turnstiles in
//! trainer-rank order, and report back.
//! The driver then performs the emulated allreduce (replica parameter
//! averaging — exactly gradient averaging, and the identity at N = 1),
//! feeds the access streams to the priority trackers in rank order, and
//! handles saves and failures. The N = 1 path is bit-identical to the
//! pre-refactor single-trainer loop, which is preserved in
//! [`reference`] and asserted equal by the integration suite.
//!
//! ## Cluster backends
//! The step loop is generic over [`PsBackend`]: `JobConfig.cluster.backend`
//! selects the in-process emulation or the concurrent [`ThreadedCluster`]
//! (one worker thread per Emb PS node behind mpsc channels). Failure
//! events are injected *live*: the victim node is killed (on the threaded
//! backend its worker really dies and is joined), a blank replacement is
//! respawned, and partial recovery restores its rows from the checkpoint
//! mirror while the surviving nodes keep serving. Both backends produce
//! bit-identical training trajectories at any trainer count.
//!
//! ## Trainer failures
//! `FailureEvent::trainer_victims` kills trainer worker threads (the
//! thread really exits and is joined). Recovery matrix:
//!
//! * **partial, N > 1** — dense params are replicated, so the respawned
//!   trainer re-joins from the survivors' replica at the next step
//!   barrier; nothing is lost beyond the load/reschedule overheads.
//! * **partial, N = 1** — no surviving replica: dense params reload
//!   (stale) from the last checkpoint marker while the Emb PS keeps its
//!   progress; no rewind, no PLS accrual (PLS counts lost *embedding*
//!   updates).
//! * **full** — everyone reloads from the checkpoint and training
//!   rewinds, exactly like an Emb PS loss under full recovery.
//!
//! ## Asynchronous checkpointing
//! Saves no longer stall the step loop: node/row snapshots are captured at
//! the save step and handed to the [`CheckpointPipeline`] writer thread,
//! which applies them to the mirror and publishes durable files while
//! training proceeds. Capture is a **cross-trainer consistency point**:
//! it happens between global steps, when every trainer is quiesced at the
//! step barrier (idle, waiting for the next step command), and the driver
//! materializes that fact by acquiring the PS control plane's exclusive
//! **quiesce token** ([`ShardedPs::quiesce`]) — so a snapshot can never
//! interleave with a half-applied sparse update. A durable
//! checkpoint is only *published* once the writer has fsynced the data
//! file and then the `LATEST` manifest (crash-consistency rule — see
//! `checkpoint::disk`). Restores flow through the same FIFO channel, so
//! they always observe every save submitted before the failure.
//!
//! ## Emulated clock
//! Real training here takes minutes; the paper's jobs take days. Following
//! the paper's emulation methodology (§5.1), each global step advances an
//! *emulated* clock by `t_total_h / total_steps` (one global step consumes
//! `batch × n_trainers` samples), failure events fire at emulated times,
//! and checkpoint overheads are charged to an [`OverheadLedger`] from the
//! production-calibrated constants — while the model/state effects of
//! failures and recoveries are executed **for real** (workers killed,
//! checkpoints restored, steps re-run).

pub mod reference;

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::checkpoint::async_pipeline::CheckpointPipeline;
use crate::checkpoint::tracker::{priority_mask, MfuTracker};
use crate::checkpoint::{CheckpointOptions, CheckpointStore};
use crate::cluster::{
    BackendStats, PsBackend, PsDataPlane, PsServePlane, ShardedPs, ThreadedCluster,
};
use crate::config::{JobConfig, PsBackendKind};
use crate::data::{Batch, SyntheticDataset};
use crate::embedding::{init_value, PsCluster, TableInfo};
use crate::failure::FailureEvent;
use crate::metrics::{auc, logloss_from_logits, Curve, OverheadLedger};
use crate::pls::CprPlan;
use crate::policy::{
    registry, FailureCtx, PsView, RecoveryAction, RecoveryPolicy, SaveCtx, SavePolicy,
};
use crate::runtime::{ModelExe, PjRtBuffer};
use crate::trainer::{TrainerPool, TrainerStep};

/// Per-row statistics for Fig. 6 (access count vs. update magnitude).
#[derive(Clone, Debug)]
pub struct RowStats {
    /// (table, row, access count, L2 norm of total change)
    pub rows: Vec<(usize, u32, u32, f64)>,
}

/// What one training run produced.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub strategy: String,
    /// which PS backend executed the job ("inproc" | "threaded")
    pub backend: String,
    /// data-parallel trainer count the job ran with
    pub n_trainers: usize,
    pub final_auc: f64,
    pub final_logloss: f64,
    pub train_loss: Curve,
    pub eval_auc: Curve,
    pub ledger: OverheadLedger,
    /// checkpoint-related overhead as a fraction of t_total
    pub overhead_frac: f64,
    /// final accumulated PLS (Eq. 3); 0 under full recovery
    pub pls: f64,
    /// the CPR controller's decision, for CPR strategies
    pub plan: Option<CprPlan>,
    /// true if a CPR strategy fell back to full recovery
    pub fell_back: bool,
    pub steps_executed: u64,
    pub failures_seen: u64,
    pub wall_secs: f64,
    pub row_stats: Option<RowStats>,
    /// serving-plane latency report when `[serving]` was enabled (the
    /// load generator is strictly read-only, so every other field is
    /// bit-identical with serving on or off — asserted by
    /// tests/serving.rs)
    pub serving: Option<crate::serving::ServeReport>,
    /// final backend operation counters — under the planned step path
    /// `unique_rows`/`dedup_hits` carry the measured within-batch dedup
    /// ratio of the workload (the CLI prints it)
    pub ps_stats: BackendStats,
}

/// Options beyond the JobConfig.
#[derive(Clone, Debug, Default)]
pub struct RunOptions {
    /// failure schedule in emulated hours (empty = failure-free run)
    pub schedule: Vec<FailureEvent>,
    /// collect per-row access/update stats (Fig. 6)
    pub collect_row_stats: bool,
    /// record train loss every n steps (0 = every 50)
    pub log_every: usize,
    /// evaluate AUC on the eval split every n steps (0 = final only)
    pub eval_every: usize,
}

/// Run one emulated training job. `model` must be the compiled artifact
/// whose manifest matches `cfg.model`. The Emb PS backend is selected by
/// `cfg.cluster.backend`, the data-parallel trainer count by
/// `cfg.cluster.n_trainers`.
///
/// Contract: `cfg.artifacts_dir` + `cfg.model.preset` must name the SAME
/// artifact as `model` — each trainer thread loads its own replica from
/// there (the pjrt client is not `Sync`, so replicas cannot be cloned
/// from the passed handle), while `model` itself performs evaluation.
/// Every in-repo caller loads `model` from exactly those cfg fields.
pub fn run_training(
    model: &ModelExe,
    cfg: &JobConfig,
    opts: &RunOptions,
) -> Result<TrainReport> {
    let tables: Vec<TableInfo> = cfg
        .data
        .table_rows
        .iter()
        .map(|&rows| TableInfo { rows, dim: model.manifest.emb_dim })
        .collect();
    let n_emb = cfg.cluster.n_emb_ps;
    let seed = cfg.data.seed ^ 0xEB;
    match cfg.cluster.backend {
        PsBackendKind::InProc => {
            run_training_core(model, cfg, opts, PsCluster::new(tables, n_emb, seed))
        }
        PsBackendKind::Threaded => {
            run_training_core(model, cfg, opts, ThreadedCluster::new(tables, n_emb, seed))
        }
    }
}

/// Emulated allreduce: elementwise mean over the N dense replicas. Every
/// replica started the step from the same params, so averaging after one
/// local SGD step equals gradient-averaged SGD; at N = 1 it is the
/// identity, keeping the single-trainer path bit-exact.
fn allreduce_mean(mut results: Vec<TrainerStep>) -> Vec<Vec<f32>> {
    if results.len() == 1 {
        return results.pop().unwrap().params; // N = 1: a true move, no copy
    }
    let n = results.len() as f64;
    results[0]
        .params
        .iter()
        .enumerate()
        .map(|(p, p0)| {
            p0.iter()
                .enumerate()
                .map(|(i, &v)| {
                    let mut s = v as f64;
                    for r in &results[1..] {
                        s += r.params[p][i] as f64;
                    }
                    (s / n) as f32
                })
                .collect()
        })
        .collect()
}

fn run_training_core<B: PsBackend + 'static>(
    model: &ModelExe,
    cfg: &JobConfig,
    opts: &RunOptions,
    cluster: B,
) -> Result<TrainReport> {
    let m = &model.manifest;
    ensure!(m.batch == cfg.model.batch, "artifact batch mismatch");
    ensure!(m.num_sparse == cfg.model.num_sparse, "artifact num_sparse mismatch");
    ensure!(m.emb_dim == cfg.model.emb_dim, "artifact emb_dim mismatch");
    let n_trainers = cfg.cluster.n_trainers.max(1);
    ensure!(
        cfg.data.train_samples % (m.batch * n_trainers) == 0,
        "train samples must be a multiple of batch × n_trainers"
    );
    ensure!(
        cfg.data.eval_samples % m.batch == 0,
        "eval samples must be a batch multiple"
    );

    let wall_start = std::time::Instant::now();
    // telemetry is observation-only: a no-op sink unless [telemetry] (or
    // --telemetry/--telemetry-dir) turned it on, and strictly read-only
    // w.r.t. training state either way — golden suites run bit-identical
    // with it enabled (asserted by tests/telemetry_neutrality.rs)
    let mut sink = crate::telemetry::TelemetrySink::from_config(&cfg.telemetry);
    let n_emb = cfg.cluster.n_emb_ps;
    let batch = m.batch;
    // one global step = one batch per trainer
    let samples_per_step = (batch * n_trainers) as u64;
    let total_steps = cfg.data.train_samples as u64 / samples_per_step;
    let dt_h = cfg.cluster.t_total_h / total_steps as f64;

    // --- build the job state ------------------------------------------------
    let dataset = SyntheticDataset::new(m.num_dense, &cfg.data);
    // the driver's host-side master copy of the dense params (what the
    // emulated allreduce produces; trainers receive it as the step input)
    let mut host_params: Vec<Vec<f32>> =
        model.params_to_host(&model.init_params(cfg.train.seed))?;
    let shared = ShardedPs::new(cluster);
    // the async checkpoint pipeline owns the mirror store on its writer
    // thread; durable publication is enabled when a dir is configured,
    // in the configured on-disk format (v1 monolithic files or v2
    // per-node base+delta chains behind the parallel writer pool,
    // optionally codec-encoded)
    let pipeline = CheckpointPipeline::with_options(
        CheckpointStore::initial(&*shared.quiesce(), host_params.clone()),
        &CheckpointOptions::from_config(&cfg.checkpoint),
    )?;
    let mut pool = TrainerPool::new(cfg, shared.clone());
    // the serving plane: an open-loop Zipfian load generator hammering
    // the read-only PsServePlane concurrently with training. Strictly
    // read-only — it owns its own rng and never touches trainer state,
    // so the training trajectory is bit-identical with it on or off.
    let loadgen = if cfg.serving.enabled {
        Some(crate::serving::LoadGen::start(
            Arc::new(shared.clone()),
            shared.tables().to_vec(),
            n_emb,
            cfg.serving.qps,
            cfg.serving.clients,
            cfg.serving.zipf_s,
            cfg.data.seed ^ 0x5EE,
        ))
    } else {
        None
    };
    // the coordinator's view of the last position-marking save (the
    // pipeline applies it asynchronously; these are the submitted values)
    let mut marked_step: u64 = 0;
    let mut marked_samples: u64 = 0;

    // --- the policy engine -------------------------------------------------
    // The registry runs the CPR controller, applies the sweep override,
    // decides fallback, and wires save cadence + tracker + recovery into
    // one bundle; the step loop below never branches on the strategy.
    // (SCAR reads its initial mirror through the quiesce token here.)
    let mut policies = registry::build_policies(cfg, PsView::new(&*shared.quiesce()));

    // Fig. 6 instrumentation: full access counters over every table (not
    // a policy — plain measurement, independent of the strategy)
    let mut stat_counts = if opts.collect_row_stats {
        Some(MfuTracker::new(&cfg.data.table_rows,
                             &vec![true; cfg.data.table_rows.len()]))
    } else {
        None
    };
    // mask of the priority (large) tables, for the Fig. 6 report filter
    let mask = priority_mask(&cfg.data.table_rows, cfg.checkpoint.priority_tables);

    // --- failure schedule (consumed in order of useful-progress time) --------
    // validate victim ids up front: schedules can come from hand-written
    // trace CSVs, and an out-of-range rank would otherwise panic mid-run
    for ev in &opts.schedule {
        ensure!(
            ev.victims.iter().all(|&v| v < n_emb),
            "failure event at {:.2} h targets Emb PS node out of range (n_emb = {n_emb})",
            ev.time_h
        );
        ensure!(
            ev.trainer_victims.iter().all(|&t| t < n_trainers),
            "failure event at {:.2} h targets trainer rank out of range (n_trainers = {n_trainers})",
            ev.time_h
        );
    }
    let mut schedule = opts.schedule.clone();
    schedule.sort_by(|a, b| a.time_h.partial_cmp(&b.time_h).unwrap());
    let mut next_event = 0usize;

    // --- main loop ----------------------------------------------------------------
    let mut ledger = OverheadLedger::default();
    let mut train_loss = Curve::default();
    let mut eval_auc_curve = Curve::default();
    let log_every = if opts.log_every == 0 { 50 } else { opts.log_every };

    let hotness = cfg.data.hotness;
    let mut step: u64 = 0;
    let mut steps_executed: u64 = 0;

    while step < total_steps {
        // one global step: every trainer gathers concurrently, hits the
        // gather barrier, computes on its replica, then applies its sparse
        // update in rank order (see the trainer module)
        // `_step_span` lives to the end of the iteration, so the "step"
        // span encloses compute, captures, and any failure handling
        let _step_span = crate::telemetry::span("step");
        let step_params = Arc::new(std::mem::take(&mut host_params));
        let results = pool.step(step, step_params)?;
        let mean_loss =
            results.iter().map(|t| t.loss as f64).sum::<f64>() / n_trainers as f64;
        // the save policy observes the concatenated access stream in rank
        // order (its tracker records it; tracker-less policies ignore it).
        // Each trainer already deduplicated its batch into the step plan's
        // access list, so weighted recorders (MFU, the delta-capture
        // bitmaps, the Fig. 6 counters) consume the compact stream — one
        // entry per distinct row — while order-sensitive recorders (SSU)
        // fall back to the raw indices inside on_step_planned's default.
        for res in &results {
            policies.save.on_step_planned(&res.indices, &res.accesses,
                                          m.num_sparse, hotness);
            if let Some(t) = stat_counts.as_mut() {
                t.record_accesses(&res.accesses);
            }
        }
        host_params = allreduce_mean(results);
        // the threaded backend's serving views swap here, at the step
        // barrier — its staleness bound is exactly one global step (the
        // in-proc backend's seqlock readers always see live rows, so
        // publish is a no-op there)
        if loadgen.is_some() {
            shared.publish_serve_view();
        }

        step += 1;
        steps_executed += 1;
        let clock_h = step as f64 * dt_h;

        if step % log_every as u64 == 0 || step == total_steps {
            train_loss.push(step, mean_loss);
        }
        if opts.eval_every > 0 && step % opts.eval_every as u64 == 0 {
            let params = model.params_from_host(&host_params);
            let (a, _) = evaluate(model, cfg, &dataset, &shared, &params)?;
            eval_auc_curve.push(step, a);
        }
        if sink.enabled()
            && cfg.telemetry.progress_steps > 0
            && step % cfg.telemetry.progress_steps as u64 == 0
        {
            // one-line live progress report (stderr, like the run logs)
            eprintln!(
                "[telemetry] step {step}/{total_steps}  loss {mean_loss:.4}  \
                 sim clock {clock_h:.3} h  ckpt in-flight {}",
                pipeline.in_flight()
            );
        }

        // ---- checkpoint saves up to the current clock ----
        // (captures happen here — the cross-trainer consistency point:
        // every trainer is quiesced at the step barrier, which the driver
        // materializes by holding the control plane's exclusive quiesce
        // token for the duration of the capture; the pipeline's writer
        // thread applies and persists the captured data while training
        // goes on. The save policy owns cadence, content selection, and
        // the ledger's save charges.)
        while clock_h >= policies.save.next_save_h()
            && policies.save.next_save_h() <= cfg.cluster.t_total_h
        {
            // serving requests issued while the saver holds the quiesce
            // token land in the "capture" latency bucket
            if let Some(lg) = &loadgen {
                lg.set_regime(crate::serving::Regime::Capture);
            }
            let q = shared.quiesce();
            let marker = policies.save.capture(
                PsView::new(&*q),
                &pipeline,
                &mut ledger,
                &SaveCtx {
                    step,
                    samples: step * samples_per_step,
                    clock_h,
                    host_params: &host_params,
                },
            );
            if let Some(mark) = marker {
                marked_step = mark.step;
                marked_samples = mark.samples;
            }
        }
        if let Some(lg) = &loadgen {
            lg.set_regime(crate::serving::Regime::Steady);
        }
        crate::telemetry::gauge_set("ckpt_in_flight", pipeline.in_flight() as f64);

        // ---- failures that fire at/before the current clock ----
        while next_event < schedule.len() && schedule[next_event].time_h <= clock_h {
            let ev = schedule[next_event].clone();
            next_event += 1;
            // serving requests racing the kill → respawn → restore window
            // land in the "recovery" latency bucket (dead-node refusals
            // included)
            if let Some(lg) = &loadgen {
                lg.set_regime(crate::serving::Regime::Recovery);
            }
            crate::telemetry::event("failure");
            // adaptive save policies re-estimate the MTBF from these
            policies.save.observe_failure(clock_h);
            // the recovery policy charges the ledger, runs the PS-side
            // kill/respawn/restore behind the quiesce token (trainers are
            // parked at the step barrier, so the exclusive epoch is free
            // and no gather can observe a half-restored node), and
            // accrues PLS; the returned action carries the driver-side
            // effects.
            let action = {
                let q = shared.quiesce();
                policies.recovery.on_failure(
                    &ev,
                    PsView::new(&*q),
                    &pipeline,
                    &mut ledger,
                    &FailureCtx {
                        clock_h,
                        dt_h,
                        samples: step * samples_per_step,
                        marked_step,
                        marked_samples,
                    },
                )
            };
            // trainer loss: the worker thread really dies and is joined;
            // the replacement re-joins at the next step barrier with
            // whatever dense params the driver broadcasts (identical for
            // both recovery modes — what it receives differs below)
            for &t in &ev.trainer_victims {
                pool.kill_trainer(t);
                pool.respawn_trainer(t);
            }
            match action {
                RecoveryAction::Continue { reload_dense_from_marker } => {
                    // partial recovery: no rewind. With a single trainer
                    // and a trainer loss there is no surviving replica:
                    // dense params reload (stale) from the last marker
                    // while the Emb PS keeps its progress.
                    if reload_dense_from_marker {
                        let (mlp, _step, _samples) = pipeline.marked_state();
                        host_params = mlp;
                    }
                }
                RecoveryAction::Rewind { mlp, step: ckpt_step } => {
                    // full recovery: everyone reloads, training rewinds
                    host_params = mlp;
                    step = ckpt_step;
                }
            }
            if let Some(lg) = &loadgen {
                lg.set_regime(crate::serving::Regime::Steady);
            }
        }
    }

    // quiesce the pool before the final drain/eval
    pool.stop();

    // join the serving clients (before the telemetry export below, so
    // their final `serve_gather{node=N}` samples are in the registry)
    let serving = loadgen.map(|lg| lg.stop());

    // drain the pipeline: every capture applied + published (surfaces any
    // writer IO error, like the old synchronous path did)
    pipeline.flush()?;

    // export the telemetry journal now — after the pool has stopped and the
    // writer drained (both flush their thread-local buffers on those paths)
    // and before the final evaluation, so eval-time gathers don't pollute
    // the training trace. Export failure is a warning, never a train error.
    if let Err(e) = sink.export() {
        eprintln!("warning: telemetry export failed: {e:#}");
    }

    // --- final evaluation --------------------------------------------------------
    let params = model.params_from_host(&host_params);
    let (final_auc, final_logloss) =
        evaluate(model, cfg, &dataset, &shared, &params)?;
    eval_auc_curve.push(total_steps, final_auc);

    // --- Fig. 6 stats ---------------------------------------------------------------
    let row_stats = stat_counts.map(|counts| {
        let mut rows = Vec::new();
        let dim = m.emb_dim;
        for t in 0..shared.tables().len() {
            if !mask[t] {
                continue; // report the large tables, like the paper
            }
            let info = shared.tables()[t];
            // one batched read per table (a per-row read_row would be a
            // channel round trip per row on the threaded backend)
            let ids: Vec<u32> = (0..info.rows as u32).collect();
            let (data, _) = shared.read_rows(t, &ids);
            for rrow in 0..info.rows {
                let cur = &data[rrow * dim..(rrow + 1) * dim];
                let mut change = 0.0f64;
                for (d, &cv) in cur.iter().enumerate() {
                    let init = init_value(cfg.data.seed ^ 0xEB, t, rrow, d);
                    change += ((cv - init) as f64).powi(2);
                }
                rows.push((t, rrow as u32, counts.count(t, rrow as u32),
                           change.sqrt()));
            }
        }
        RowStats { rows }
    });

    let backend = shared.name().to_string();
    let ps_stats = shared.stats();
    Ok(TrainReport {
        strategy: cfg.checkpoint.strategy.name().to_string(),
        backend,
        n_trainers,
        final_auc,
        final_logloss,
        train_loss,
        eval_auc: eval_auc_curve,
        overhead_frac: ledger.fraction_of(cfg.cluster.t_total_h),
        ledger,
        pls: policies.recovery.pls(),
        plan: policies.plan,
        fell_back: policies.fell_back,
        steps_executed,
        failures_seen: next_event as u64,
        wall_secs: wall_start.elapsed().as_secs_f64(),
        row_stats,
        serving,
        ps_stats,
    })
}

/// AUC + logloss over the held-out eval split. Needs only the PS data
/// plane (gathers), so it accepts a raw backend or a [`ShardedPs`] handle.
pub fn evaluate<B: PsDataPlane>(
    model: &ModelExe,
    cfg: &JobConfig,
    dataset: &SyntheticDataset,
    cluster: &B,
    params: &[PjRtBuffer],
) -> Result<(f64, f64)> {
    let m = &model.manifest;
    let batch = m.batch;
    let n_batches = cfg.data.eval_samples / batch;
    let hotness = cfg.data.hotness;
    let mut batch_buf =
        Batch::zeros_hot(batch, m.num_dense, m.num_sparse, hotness);
    let mut emb_buf = vec![0.0f32; batch * m.num_sparse * m.emb_dim];
    let mut scores = Vec::with_capacity(cfg.data.eval_samples);
    let mut labels = Vec::with_capacity(cfg.data.eval_samples);
    for b in 0..n_batches {
        dataset.fill_eval_batch((b * batch) as u64, &mut batch_buf);
        cluster.gather_pooled(&batch_buf.indices, hotness, &mut emb_buf);
        let logits = model.predict(&batch_buf.dense, &emb_buf, params)?;
        scores.extend_from_slice(&logits);
        labels.extend_from_slice(&batch_buf.labels);
    }
    Ok((auc(&scores, &labels), logloss_from_logits(&scores, &labels)))
}
