//! Typed configuration for the whole system: model architecture (must match
//! the AOT artifact ABI), synthetic dataset, emulated cluster constants,
//! and checkpoint/recovery policy. Presets mirror `python/compile/model.py`
//! PRESETS; users can override any field from a TOML file via
//! [`JobConfig::from_toml_file`].

pub mod toml;

use anyhow::{bail, Context, Result};

use self::toml::{get, Doc, Value};
use crate::embedding::EmbOptimizer;

/// DLRM architecture — MUST agree with the AOT artifact for `preset`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub preset: String,
    pub num_dense: usize,
    pub num_sparse: usize,
    pub emb_dim: usize,
    pub bottom_mlp: Vec<usize>,
    pub top_mlp: Vec<usize>,
    pub batch: usize,
}

impl ModelConfig {
    pub fn num_feats(&self) -> usize {
        self.num_sparse + 1
    }

    pub fn num_pairs(&self) -> usize {
        let f = self.num_feats();
        f * (f - 1) / 2
    }

    pub fn validate(&self) -> Result<()> {
        if *self.bottom_mlp.last().unwrap() != self.emb_dim {
            bail!("bottom MLP output must equal emb_dim");
        }
        if *self.top_mlp.last().unwrap() != 1 {
            bail!("top MLP must end in one logit");
        }
        Ok(())
    }
}

/// Synthetic click-log generator parameters (see `data::SyntheticDataset`).
#[derive(Clone, Debug, PartialEq)]
pub struct DataConfig {
    /// rows per embedding table (len == model.num_sparse)
    pub table_rows: Vec<usize>,
    /// Zipf exponent per table (same length)
    pub zipf_s: Vec<f64>,
    pub train_samples: usize,
    pub eval_samples: usize,
    /// lookups per sparse feature (1 = single-hot Criteo-style; > 1
    /// exercises the sum-pooling path of the L1 embedding_bag kernel)
    pub hotness: usize,
    pub seed: u64,
    /// scale of the hidden teacher's embedding contribution
    pub teacher_emb_scale: f64,
    /// label noise: logit noise stddev
    pub label_noise: f64,
}

impl DataConfig {
    pub fn total_rows(&self) -> usize {
        self.table_rows.iter().sum()
    }
}

/// Which Emb PS cluster runtime executes the job (see `crate::cluster`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PsBackendKind {
    /// in-process synchronous emulation (the reference backend)
    #[default]
    InProc,
    /// one worker thread per PS node behind mpsc channels; failures
    /// really kill workers while survivors keep serving
    Threaded,
}

impl PsBackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "inproc" => PsBackendKind::InProc,
            "threaded" => PsBackendKind::Threaded,
            _ => bail!("unknown PS backend {s:?} (inproc|threaded)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PsBackendKind::InProc => "inproc",
            PsBackendKind::Threaded => "threaded",
        }
    }
}

/// On-disk checkpoint layout (see `checkpoint::disk` and `checkpoint::v2`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CkptFormat {
    /// format v1: one monolithic store file per publish + `LATEST` pointer
    #[default]
    V1,
    /// format v2: per-node base+delta chains behind a `MANIFEST`, written
    /// in parallel by the writer pool; minor saves publish row deltas,
    /// priority majors re-base, chains compact when deltas outgrow the base
    V2,
}

impl CkptFormat {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "v1" => CkptFormat::V1,
            "v2" => CkptFormat::V2,
            _ => bail!("unknown checkpoint format {s:?} (v1|v2)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CkptFormat::V1 => "v1",
            CkptFormat::V2 => "v2",
        }
    }
}

/// Default v2 chain-compaction threshold (`compact_frac`): re-base a
/// node once its pending delta bytes exceed half the base. The single
/// source of truth — `CheckpointOptions` and every constructor shim
/// derive from here.
pub const DEFAULT_COMPACT_FRAC: f64 = 0.5;

/// Payload codec for format-v2 checkpoint files (see
/// `checkpoint::codec`; Check-N-Run style quantization). Ignored under
/// format v1, which always writes raw fp32 stores.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CkptCodec {
    /// raw little-endian fp32 — byte-identical to pre-codec format v2
    #[default]
    None,
    /// 8-bit per-chunk uniform quantization of embedding rows
    /// (per-chunk `min`/`scale`, fp32 fallback for optimizer state)
    Q8,
    /// 4-bit per-chunk uniform quantization (two codes per byte)
    Q4,
    /// lossless byte-level run-length coding of the fp32 stream
    Rle,
}

impl CkptCodec {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => CkptCodec::None,
            "q8" => CkptCodec::Q8,
            "q4" => CkptCodec::Q4,
            "rle" => CkptCodec::Rle,
            _ => bail!("unknown checkpoint codec {s:?} (none|q8|q4|rle)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CkptCodec::None => "none",
            CkptCodec::Q8 => "q8",
            CkptCodec::Q4 => "q4",
            CkptCodec::Rle => "rle",
        }
    }

    /// Every registered codec, in the order the CI codec matrix runs
    /// them.
    pub fn all() -> [CkptCodec; 4] {
        [CkptCodec::None, CkptCodec::Q8, CkptCodec::Q4, CkptCodec::Rle]
    }

    /// True when decoding does not reproduce the written values
    /// bit-exactly (the quantizers) — the golden suites compare such
    /// runs under an epsilon instead of exact equality.
    pub fn lossy(&self) -> bool {
        matches!(self, CkptCodec::Q8 | CkptCodec::Q4)
    }
}

/// Emulated production-cluster constants (paper §3 / §5.1). All times in
/// *hours of emulated wall-clock*; each training step advances the clock by
/// `t_total / total_steps` so overhead percentages match the paper's frame.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Emb PS cluster runtime (`inproc` | `threaded`)
    pub backend: PsBackendKind,
    /// number of embedding parameter-server nodes (paper: N_emb)
    pub n_emb_ps: usize,
    /// number of data-parallel MLP trainers (paper: N_tr; the production
    /// job runs 20). This is the REAL trainer-thread count of the runtime
    /// (`crate::trainer::TrainerPool`) — each trainer owns a model replica
    /// and a disjoint stream shard — and also the trainer term in the PLS
    /// controller's failure-share math. `train_samples` must divide by
    /// `batch × n_trainers`.
    pub n_trainers: usize,
    /// emulated total training time, hours (paper: 56 h)
    pub t_total_h: f64,
    /// mean time between failures, hours (paper: 28 h for the 56-h job)
    pub t_fail_h: f64,
    /// checkpoint save cost, hours (derived so full recovery ≈ 8.5%)
    pub o_save_h: f64,
    /// checkpoint load cost, hours
    pub o_load_h: f64,
    /// rescheduling cost, hours
    pub o_res_h: f64,
    /// checkpoint write bandwidth in GB per emulated hour. When set, the
    /// PLS controller derives the save cost from the measured checkpoint
    /// *size* (`bytes / bandwidth`) instead of the flat `o_save_h`
    /// constant — see [`ClusterConfig::o_save_eff_h`]. `None` (the
    /// default, and every preset) keeps the paper's calibrated constant.
    pub save_bw_gb_h: Option<f64>,
}

impl ClusterConfig {
    /// Optimal full-recovery interval √(2·O_save·T_fail) (paper §2.2).
    pub fn t_save_full_h(&self) -> f64 {
        (2.0 * self.o_save_h * self.t_fail_h).sqrt()
    }

    /// The effective per-save cost: bandwidth-derived when both a write
    /// bandwidth and a checkpoint size are known, the flat `o_save_h`
    /// otherwise.
    pub fn o_save_eff_h(&self, ckpt_bytes: Option<u64>) -> f64 {
        match (self.save_bw_gb_h, ckpt_bytes) {
            (Some(bw), Some(b)) if bw > 0.0 => b as f64 / 1e9 / bw,
            _ => self.o_save_h,
        }
    }
}

/// Recovery strategy + checkpoint policy (paper §4).
#[derive(Clone, Debug, PartialEq)]
pub enum Strategy {
    /// full recovery at the optimal interval √(2 O_save T_fail)
    Full,
    /// partial recovery, naively reusing the full-recovery interval
    PartialNaive,
    /// CPR with PLS-chosen interval, no priority saving
    CprVanilla,
    /// CPR + SCAR update-magnitude priority (100% memory overhead)
    CprScar,
    /// CPR + most-frequently-used counters (paper's CPR-MFU)
    CprMfu,
    /// CPR + sub-sampled-used list (paper's CPR-SSU)
    CprSsu,
    /// CPR re-planning its interval online from the observed failure
    /// rate (`policy::AdaptiveInterval`; Chameleon-style adaptivity)
    CprAdaptive,
}

impl Strategy {
    /// Parse a registry key (see `policy::registry::names`). The error
    /// for an unknown key lists every valid name.
    pub fn parse(s: &str) -> Result<Strategy> {
        Ok(match s {
            "full" => Strategy::Full,
            "partial" => Strategy::PartialNaive,
            "cpr" | "cpr-vanilla" => Strategy::CprVanilla,
            "cpr-scar" => Strategy::CprScar,
            "cpr-mfu" => Strategy::CprMfu,
            "cpr-ssu" => Strategy::CprSsu,
            "cpr-adaptive" => Strategy::CprAdaptive,
            _ => bail!(
                "unknown strategy {s:?} (valid: full|partial|cpr|cpr-vanilla|\
                 cpr-scar|cpr-mfu|cpr-ssu|cpr-adaptive)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Full => "full",
            Strategy::PartialNaive => "partial",
            Strategy::CprVanilla => "cpr-vanilla",
            Strategy::CprScar => "cpr-scar",
            Strategy::CprMfu => "cpr-mfu",
            Strategy::CprSsu => "cpr-ssu",
            Strategy::CprAdaptive => "cpr-adaptive",
        }
    }

    pub fn is_partial(&self) -> bool {
        !matches!(self, Strategy::Full)
    }

    /// One of the CPR family (runs the PLS controller; may fall back).
    pub fn is_cpr(&self) -> bool {
        matches!(
            self,
            Strategy::CprVanilla
                | Strategy::CprScar
                | Strategy::CprMfu
                | Strategy::CprSsu
                | Strategy::CprAdaptive
        )
    }

    pub fn priority(&self) -> bool {
        matches!(self, Strategy::CprScar | Strategy::CprMfu | Strategy::CprSsu)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointConfig {
    pub strategy: Strategy,
    /// user-specified target PLS (paper default 0.1)
    pub target_pls: f64,
    /// priority fraction r (paper: 0.125)
    pub r: f64,
    /// SSU sampling period (paper: 2)
    pub ssu_period: usize,
    /// number of largest tables the priority schemes apply to (paper: 7)
    pub priority_tables: usize,
    /// directory for on-disk snapshots (None = in-memory only)
    pub dir: Option<String>,
    /// on-disk layout: v1 monolithic files or v2 incremental base+delta
    /// chains (`--ckpt-format`, `[checkpoint] format`)
    pub format: CkptFormat,
    /// v2 chain-compaction threshold: re-base a node when its pending
    /// delta bytes exceed `compact_frac × base_bytes`
    pub compact_frac: f64,
    /// payload codec for v2 checkpoint files (`--ckpt-codec`,
    /// `[checkpoint] codec`): none | q8 | q4 | rle
    pub codec: CkptCodec,
    /// force a checkpoint interval (hours), bypassing the strategy's
    /// default — used by the Fig. 11/12 sweeps that explore the PLS range
    pub t_save_override_h: Option<f64>,
}

/// Training hyper-parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub lr: f32,
    /// embedding-row learning rate (sparse update)
    pub emb_lr: f32,
    /// embedding update rule (sgd | rowwise-adagrad)
    pub emb_optimizer: EmbOptimizer,
    pub seed: u64,
    /// evaluate AUC every n steps (0 = only at the end)
    pub eval_every: usize,
}

/// Telemetry plane switches (see `crate::telemetry`). Off by default:
/// the instrumented hot path then costs one relaxed atomic load per
/// site, and telemetry is strictly read-only w.r.t. training state, so
/// enabling it cannot move any trained float (asserted by
/// `tests/telemetry_neutrality.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryConfig {
    /// record spans + metrics (`--telemetry`, `[telemetry] enabled`)
    pub enabled: bool,
    /// export directory for `trace.json` / `metrics.json` /
    /// `metrics.csv`; setting it implies `enabled`
    /// (`--telemetry-dir`, `[telemetry] dir`)
    pub dir: Option<String>,
    /// print a one-line live progress report every n global steps
    /// (0 = never; only when telemetry is enabled)
    pub progress_steps: usize,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        Self { enabled: false, dir: None, progress_steps: 0 }
    }
}

/// Online serving plane switches (see `crate::serving`). Off by default:
/// when enabled, the coordinator runs the open-loop load generator
/// concurrently with training against the read-only
/// `cluster::PsServePlane`, which is strictly read-only w.r.t. training
/// state (asserted by `tests/serving.rs` bit-identity).
#[derive(Clone, Debug, PartialEq)]
pub struct ServingConfig {
    /// run the serving load generator during training
    /// (`--serve-qps`, `[serving] enabled`)
    pub enabled: bool,
    /// aggregate target requests/second across all clients
    /// (`--serve-qps`, `[serving] qps`; setting it implies `enabled`)
    pub qps: f64,
    /// closed serving worker threads (`--serve-clients`, `[serving] clients`)
    pub clients: usize,
    /// Zipf skew of key popularity (`[serving] zipf_s`)
    pub zipf_s: f64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self { enabled: false, qps: 20_000.0, clients: 2, zipf_s: 1.1 }
    }
}

/// Everything a training job needs.
#[derive(Clone, Debug, PartialEq)]
pub struct JobConfig {
    pub model: ModelConfig,
    pub data: DataConfig,
    pub cluster: ClusterConfig,
    pub checkpoint: CheckpointConfig,
    pub train: TrainConfig,
    pub telemetry: TelemetryConfig,
    pub serving: ServingConfig,
    /// root dir holding AOT artifacts (default "artifacts")
    pub artifacts_dir: String,
}

// ---------------------------------------------------------------------------
// presets
// ---------------------------------------------------------------------------

/// Kaggle-skew table layout: 7 large tables carrying ~99.6% of all rows
/// (paper §5.1), the remaining 19 small. `unit` scales the whole layout.
pub fn skewed_tables(num_sparse: usize, unit: usize) -> (Vec<usize>, Vec<f64>) {
    assert!(num_sparse >= 8);
    let large = [50 * unit, 40 * unit, 30 * unit, 25 * unit, 20 * unit,
                 15 * unit, 12 * unit];
    let mut rows = Vec::with_capacity(num_sparse);
    let mut zipf = Vec::with_capacity(num_sparse);
    for (i, r) in large.iter().enumerate() {
        rows.push((*r).max(4));
        zipf.push(1.05 + 0.02 * i as f64);
    }
    for i in 7..num_sparse {
        rows.push(4 + (i * 13) % 60); // tiny tables, 4..64 rows
        zipf.push(1.1);
    }
    (rows, zipf)
}

fn cluster_emulation(n_emb_ps: usize) -> ClusterConfig {
    // Constants chosen so the full-recovery overhead decomposes exactly as
    // the paper's emulation (§6.1): T_fail = 28 h (2 failures / 56 h),
    // O_save = T_save²/(2 T_fail) at T_save ≈ 2.3 h → save ≈ lost ≈ 4.1%,
    // load + reschedule ≈ 0.3%, total ≈ 8.5%.
    ClusterConfig {
        backend: PsBackendKind::InProc,
        n_emb_ps,
        // presets default to one trainer so the out-of-the-box run is the
        // paper's single-trainer emulation; the N = 1 driver path is
        // bit-identical to the preserved reference loop (note the CPR
        // controller's interval now carries this n_trainers term — see
        // pls::plan). Scale with --trainers / [cluster] n_trainers.
        n_trainers: 1,
        t_total_h: 56.0,
        t_fail_h: 28.0,
        o_save_h: 0.094,
        o_load_h: 0.042,
        o_res_h: 0.042,
        save_bw_gb_h: None,
    }
}

fn base_checkpoint() -> CheckpointConfig {
    CheckpointConfig {
        strategy: Strategy::Full,
        target_pls: 0.1,
        r: 0.125,
        ssu_period: 2,
        priority_tables: 7,
        dir: None,
        format: CkptFormat::V1,
        compact_frac: DEFAULT_COMPACT_FRAC,
        codec: CkptCodec::None,
        t_save_override_h: None,
    }
}

/// Named presets. `mini` is the fast config used by many-run experiments;
/// `kaggle_like`/`terabyte_like` follow the paper's §5.1 architecture;
/// `large_100m` is the ≈100M-parameter end-to-end validation config.
pub fn preset(name: &str) -> Result<JobConfig> {
    let (model, unit, train_samples, eval_samples) = match name {
        "mini" => (ModelConfig {
            preset: "mini".into(),
            num_dense: 13,
            num_sparse: 26,
            emb_dim: 8,
            bottom_mlp: vec![64, 32, 8],
            top_mlp: vec![64, 1],
            batch: 128,
        }, 400, 96_000, 16_000),
        "kaggle_like" => (ModelConfig {
            preset: "kaggle_like".into(),
            num_dense: 13,
            num_sparse: 26,
            emb_dim: 16,
            bottom_mlp: vec![512, 256, 64, 16],
            top_mlp: vec![512, 256, 1],
            batch: 128,
        }, 1000, 192_000, 32_000),
        "terabyte_like" => (ModelConfig {
            preset: "terabyte_like".into(),
            num_dense: 13,
            num_sparse: 26,
            emb_dim: 64,
            bottom_mlp: vec![512, 256, 64],
            top_mlp: vec![512, 512, 256, 1],
            batch: 128,
        }, 2000, 192_000, 32_000),
        // ~100M params: 6.25M embedding rows × dim 16 ≈ 100M + MLPs
        "large_100m" => (ModelConfig {
            preset: "kaggle_like".into(), // reuses the kaggle_like artifact
            num_dense: 13,
            num_sparse: 26,
            emb_dim: 16,
            bottom_mlp: vec![512, 256, 64, 16],
            top_mlp: vec![512, 256, 1],
            batch: 128,
        }, 32_500, 64_000, 16_000),
        _ => bail!("unknown preset {name:?} (mini|kaggle_like|terabyte_like|large_100m)"),
    };
    model.validate()?;
    let (table_rows, zipf_s) = skewed_tables(model.num_sparse, unit);
    Ok(JobConfig {
        data: DataConfig {
            table_rows,
            zipf_s,
            train_samples,
            eval_samples,
            hotness: 1,
            seed: 1234,
            teacher_emb_scale: 3.0,
            label_noise: 0.4,
        },
        cluster: cluster_emulation(8),
        checkpoint: base_checkpoint(),
        train: TrainConfig {
            lr: 0.05,
            emb_lr: 8.0,
            emb_optimizer: EmbOptimizer::Sgd,
            seed: 99,
            eval_every: 0,
        },
        telemetry: TelemetryConfig::default(),
        serving: ServingConfig::default(),
        artifacts_dir: "artifacts".into(),
        model,
    })
}

impl JobConfig {
    /// Load a preset then apply TOML overrides:
    /// `preset = "mini"` at top level, then `[model]`, `[data]`,
    /// `[cluster]`, `[checkpoint]`, `[train]` sections.
    pub fn from_toml(text: &str) -> Result<JobConfig> {
        let doc: Doc = toml::parse(text)?;
        let preset_name = get(&doc, "", "preset")
            .map(|v| v.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_else(|| "mini".to_string());
        let mut cfg = preset(&preset_name)?;
        cfg.apply_overrides(&doc)?;
        cfg.model.validate()?;
        Ok(cfg)
    }

    pub fn from_toml_file(path: &str) -> Result<JobConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_toml(&text)
    }

    fn apply_overrides(&mut self, doc: &Doc) -> Result<()> {
        macro_rules! set {
            ($sec:literal, $key:literal, $dst:expr, $conv:ident) => {
                if let Some(v) = get(doc, $sec, $key) {
                    $dst = v.$conv()?;
                }
            };
        }
        set!("model", "batch", self.model.batch, as_usize);
        set!("model", "emb_dim", self.model.emb_dim, as_usize);
        set!("model", "bottom_mlp", self.model.bottom_mlp, as_usize_vec);
        set!("model", "top_mlp", self.model.top_mlp, as_usize_vec);
        set!("data", "train_samples", self.data.train_samples, as_usize);
        set!("data", "eval_samples", self.data.eval_samples, as_usize);
        set!("data", "table_rows", self.data.table_rows, as_usize_vec);
        set!("data", "hotness", self.data.hotness, as_usize);
        set!("data", "seed", self.data.seed, as_usize_u64);
        set!("data", "label_noise", self.data.label_noise, as_f64);
        if let Some(v) = get(doc, "cluster", "backend") {
            self.cluster.backend = PsBackendKind::parse(v.as_str()?)?;
        }
        set!("cluster", "n_emb_ps", self.cluster.n_emb_ps, as_usize);
        set!("cluster", "n_trainers", self.cluster.n_trainers, as_usize);
        set!("cluster", "t_total_h", self.cluster.t_total_h, as_f64);
        set!("cluster", "t_fail_h", self.cluster.t_fail_h, as_f64);
        set!("cluster", "o_save_h", self.cluster.o_save_h, as_f64);
        set!("cluster", "o_load_h", self.cluster.o_load_h, as_f64);
        set!("cluster", "o_res_h", self.cluster.o_res_h, as_f64);
        if let Some(v) = get(doc, "cluster", "save_bw_gb_h") {
            self.cluster.save_bw_gb_h = Some(v.as_f64()?);
        }
        set!("checkpoint", "target_pls", self.checkpoint.target_pls, as_f64);
        set!("checkpoint", "r", self.checkpoint.r, as_f64);
        set!("checkpoint", "ssu_period", self.checkpoint.ssu_period, as_usize);
        set!("checkpoint", "priority_tables", self.checkpoint.priority_tables, as_usize);
        if let Some(v) = get(doc, "checkpoint", "strategy") {
            self.checkpoint.strategy = Strategy::parse(v.as_str()?)?;
        }
        if let Some(v) = get(doc, "checkpoint", "format") {
            self.checkpoint.format = CkptFormat::parse(v.as_str()?)?;
        }
        if let Some(v) = get(doc, "checkpoint", "codec") {
            self.checkpoint.codec = CkptCodec::parse(v.as_str()?)?;
        }
        set!("checkpoint", "compact_frac", self.checkpoint.compact_frac, as_f64);
        if let Some(v) = get(doc, "checkpoint", "dir") {
            self.checkpoint.dir = Some(v.as_str()?.to_string());
        }
        if let Some(v) = get(doc, "train", "lr") {
            self.train.lr = v.as_f64()? as f32;
        }
        if let Some(v) = get(doc, "train", "emb_lr") {
            self.train.emb_lr = v.as_f64()? as f32;
        }
        if let Some(v) = get(doc, "train", "emb_optimizer") {
            self.train.emb_optimizer = EmbOptimizer::parse(v.as_str()?)?;
        }
        set!("train", "eval_every", self.train.eval_every, as_usize);
        if let Some(v) = get(doc, "telemetry", "enabled") {
            self.telemetry.enabled = v.as_bool()?;
        }
        if let Some(v) = get(doc, "telemetry", "dir") {
            self.telemetry.dir = Some(v.as_str()?.to_string());
            self.telemetry.enabled = true;
        }
        set!("telemetry", "progress_steps", self.telemetry.progress_steps, as_usize);
        if let Some(v) = get(doc, "serving", "enabled") {
            self.serving.enabled = v.as_bool()?;
        }
        if let Some(v) = get(doc, "serving", "qps") {
            self.serving.qps = v.as_f64()?;
            self.serving.enabled = true;
        }
        set!("serving", "clients", self.serving.clients, as_usize);
        set!("serving", "zipf_s", self.serving.zipf_s, as_f64);
        Ok(())
    }
}

// small helper so the macro can read u64 from toml ints
trait AsU64 {
    fn as_usize_u64(&self) -> Result<u64>;
}

impl AsU64 for Value {
    fn as_usize_u64(&self) -> Result<u64> {
        Ok(self.as_i64()? as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for name in ["mini", "kaggle_like", "terabyte_like", "large_100m"] {
            let cfg = preset(name).unwrap();
            cfg.model.validate().unwrap();
            assert_eq!(cfg.data.table_rows.len(), cfg.model.num_sparse);
            assert_eq!(cfg.data.zipf_s.len(), cfg.model.num_sparse);
        }
        assert!(preset("nope").is_err());
    }

    #[test]
    fn skew_concentrates_rows_in_seven_tables() {
        let (rows, _) = skewed_tables(26, 1000);
        let total: usize = rows.iter().sum();
        let top7: usize = rows[..7].iter().sum();
        assert!(top7 as f64 / total as f64 > 0.99,
                "top-7 share {}", top7 as f64 / total as f64);
    }

    #[test]
    fn large_preset_is_about_100m_params() {
        let cfg = preset("large_100m").unwrap();
        let emb_params = cfg.data.total_rows() * cfg.model.emb_dim;
        assert!(emb_params > 80_000_000 && emb_params < 130_000_000,
                "emb params = {emb_params}");
    }

    #[test]
    fn toml_overrides_apply() {
        let cfg = JobConfig::from_toml(r#"
            preset = "mini"
            [cluster]
            n_emb_ps = 4
            n_trainers = 4
            t_fail_h = 14.0
            [checkpoint]
            strategy = "cpr-ssu"
            target_pls = 0.05
            [train]
            lr = 0.1
        "#).unwrap();
        assert_eq!(cfg.cluster.n_emb_ps, 4);
        assert_eq!(cfg.cluster.n_trainers, 4);
        assert_eq!(cfg.cluster.t_fail_h, 14.0);
        assert_eq!(cfg.checkpoint.strategy, Strategy::CprSsu);
        assert_eq!(cfg.checkpoint.target_pls, 0.05);
        assert_eq!(cfg.train.lr, 0.1);
    }

    #[test]
    fn presets_default_to_one_trainer() {
        // the single-trainer default keeps preset runs bit-identical to
        // the pre-refactor coordinator and divisibility trivially satisfied
        for name in ["mini", "kaggle_like", "terabyte_like", "large_100m"] {
            let cfg = preset(name).unwrap();
            assert_eq!(cfg.cluster.n_trainers, 1, "{name}");
            assert_eq!(cfg.data.train_samples % cfg.model.batch, 0, "{name}");
        }
    }

    #[test]
    fn invalid_override_fails_validation() {
        // emb_dim mismatch with bottom MLP output must be rejected
        assert!(JobConfig::from_toml(r#"
            preset = "mini"
            [model]
            emb_dim = 12
        "#).is_err());
    }

    #[test]
    fn optimal_full_interval_formula() {
        let c = cluster_emulation(8);
        let t = c.t_save_full_h();
        assert!((t * t - 2.0 * c.o_save_h * c.t_fail_h).abs() < 1e-9);
    }

    #[test]
    fn backend_parse_and_toml_override() {
        assert_eq!(PsBackendKind::parse("inproc").unwrap(), PsBackendKind::InProc);
        assert_eq!(PsBackendKind::parse("threaded").unwrap().name(), "threaded");
        assert!(PsBackendKind::parse("rpc").is_err());
        let cfg = JobConfig::from_toml(r#"
            preset = "mini"
            [cluster]
            backend = "threaded"
        "#).unwrap();
        assert_eq!(cfg.cluster.backend, PsBackendKind::Threaded);
        assert_eq!(preset("mini").unwrap().cluster.backend, PsBackendKind::InProc);
    }

    #[test]
    fn ckpt_format_parse_and_toml_override() {
        assert_eq!(CkptFormat::parse("v1").unwrap(), CkptFormat::V1);
        assert_eq!(CkptFormat::parse("v2").unwrap().name(), "v2");
        assert!(CkptFormat::parse("v3").is_err());
        let cfg = JobConfig::from_toml(r#"
            preset = "mini"
            [checkpoint]
            format = "v2"
            compact_frac = 0.25
            dir = "/tmp/ckpts"
        "#).unwrap();
        assert_eq!(cfg.checkpoint.format, CkptFormat::V2);
        assert_eq!(cfg.checkpoint.compact_frac, 0.25);
        assert_eq!(cfg.checkpoint.dir.as_deref(), Some("/tmp/ckpts"));
        let base = preset("mini").unwrap();
        assert_eq!(base.checkpoint.format, CkptFormat::V1,
                   "presets stay on v1 by default");
        assert_eq!(base.checkpoint.compact_frac, DEFAULT_COMPACT_FRAC);
    }

    #[test]
    fn ckpt_codec_parse_and_toml_override() {
        for kind in CkptCodec::all() {
            assert_eq!(CkptCodec::parse(kind.name()).unwrap(), kind,
                       "codec name must round-trip through parse");
        }
        assert!(CkptCodec::parse("zstd").is_err(), "unknown codecs are errors");
        assert!(CkptCodec::Q8.lossy() && CkptCodec::Q4.lossy());
        assert!(!CkptCodec::None.lossy() && !CkptCodec::Rle.lossy());
        let cfg = JobConfig::from_toml(r#"
            preset = "mini"
            [checkpoint]
            format = "v2"
            codec = "q8"
        "#).unwrap();
        assert_eq!(cfg.checkpoint.codec, CkptCodec::Q8);
        assert_eq!(preset("mini").unwrap().checkpoint.codec, CkptCodec::None,
                   "presets write raw fp32 by default");
    }

    #[test]
    fn save_cost_is_bandwidth_derived_only_when_configured() {
        let mut c = cluster_emulation(8);
        // no bandwidth: the flat paper constant, regardless of size
        assert_eq!(c.o_save_eff_h(Some(10_000_000_000)), c.o_save_h);
        assert_eq!(c.o_save_eff_h(None), c.o_save_h);
        // 100 GB/h writing a 10 GB checkpoint = 0.1 h per save
        c.save_bw_gb_h = Some(100.0);
        assert!((c.o_save_eff_h(Some(10_000_000_000)) - 0.1).abs() < 1e-12);
        // bandwidth set but size unknown: fall back to the constant
        assert_eq!(c.o_save_eff_h(None), c.o_save_h);
        // degenerate bandwidth never divides by zero
        c.save_bw_gb_h = Some(0.0);
        assert_eq!(c.o_save_eff_h(Some(1)), c.o_save_h);
        // TOML override path
        let cfg = JobConfig::from_toml(r#"
            preset = "mini"
            [cluster]
            save_bw_gb_h = 250.0
        "#).unwrap();
        assert_eq!(cfg.cluster.save_bw_gb_h, Some(250.0));
    }

    #[test]
    fn telemetry_defaults_off_and_toml_overrides() {
        let base = preset("mini").unwrap();
        assert!(!base.telemetry.enabled, "telemetry must default off");
        assert_eq!(base.telemetry.dir, None);
        assert_eq!(base.telemetry.progress_steps, 0);
        let cfg = JobConfig::from_toml(r#"
            preset = "mini"
            [telemetry]
            enabled = true
            progress_steps = 50
        "#).unwrap();
        assert!(cfg.telemetry.enabled);
        assert_eq!(cfg.telemetry.progress_steps, 50);
        // setting the export dir implies enablement
        let cfg = JobConfig::from_toml(r#"
            preset = "mini"
            [telemetry]
            dir = "/tmp/telemetry"
        "#).unwrap();
        assert!(cfg.telemetry.enabled);
        assert_eq!(cfg.telemetry.dir.as_deref(), Some("/tmp/telemetry"));
    }

    #[test]
    fn serving_defaults_off_and_toml_overrides() {
        let base = preset("mini").unwrap();
        assert!(!base.serving.enabled, "serving must default off");
        assert_eq!(base.serving.qps, 20_000.0);
        assert_eq!(base.serving.clients, 2);
        assert_eq!(base.serving.zipf_s, 1.1);
        // setting the target qps implies enablement (like telemetry.dir)
        let cfg = JobConfig::from_toml(r#"
            preset = "mini"
            [serving]
            qps = 100000.0
            clients = 4
            zipf_s = 0.9
        "#).unwrap();
        assert!(cfg.serving.enabled);
        assert_eq!(cfg.serving.qps, 100_000.0);
        assert_eq!(cfg.serving.clients, 4);
        assert_eq!(cfg.serving.zipf_s, 0.9);
        let cfg = JobConfig::from_toml(r#"
            preset = "mini"
            [serving]
            enabled = true
        "#).unwrap();
        assert!(cfg.serving.enabled);
        assert_eq!(cfg.serving.qps, 20_000.0, "qps keeps its default");
    }

    #[test]
    fn strategy_parse_roundtrip() {
        for s in ["full", "partial", "cpr-vanilla", "cpr-scar", "cpr-mfu",
                  "cpr-ssu", "cpr-adaptive"] {
            assert_eq!(Strategy::parse(s).unwrap().name(), s);
        }
        assert!(Strategy::parse("bogus").is_err());
        assert!(Strategy::CprAdaptive.is_cpr() && !Strategy::CprAdaptive.priority());
    }
}
