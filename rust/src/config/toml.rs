//! TOML-subset parser for user config files (the real `toml` crate is not
//! in the offline image). Supported: `[section]` headers, `key = value`
//! with string / integer / float / bool / homogeneous array values, `#`
//! comments, and bare or quoted keys. This covers every config file the
//! launcher accepts; anything fancier fails loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        if i < 0 {
            bail!("expected non-negative integer, got {i}");
        }
        Ok(i as usize)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        match self {
            Value::Arr(v) => v.iter().map(|x| x.as_usize()).collect(),
            _ => bail!("expected array, got {self:?}"),
        }
    }
}

/// section name -> key -> value; keys before any `[section]` live in "".
pub type Doc = BTreeMap<String, BTreeMap<String, Value>>;

pub fn parse(text: &str) -> Result<Doc> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    doc.insert(section.clone(), BTreeMap::new());
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']')
                .with_context(|| format!("line {}: bad section header", lineno + 1))?
                .trim();
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, val) = line.split_once('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim().trim_matches('"').to_string();
        let value = parse_value(val.trim())
            .with_context(|| format!("line {}: bad value", lineno + 1))?;
        doc.get_mut(&section).unwrap().insert(key, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: '#' inside quoted strings is not supported
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').context("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '[' => depth += 1,
            ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Fetch `doc[section][key]`, if present.
pub fn get<'a>(doc: &'a Doc, section: &str, key: &str) -> Option<&'a Value> {
    doc.get(section).and_then(|m| m.get(key))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_scalars_arrays() {
        let doc = parse(r#"
            # top comment
            seed = 42
            [model]
            preset = "mini"
            emb_dim = 8
            bottom_mlp = [64, 32, 8]
            lr = 0.05          # inline comment
            [checkpoint]
            enabled = true
        "#).unwrap();
        assert_eq!(get(&doc, "", "seed").unwrap().as_i64().unwrap(), 42);
        assert_eq!(get(&doc, "model", "preset").unwrap().as_str().unwrap(), "mini");
        assert_eq!(get(&doc, "model", "bottom_mlp").unwrap()
                   .as_usize_vec().unwrap(), vec![64, 32, 8]);
        assert_eq!(get(&doc, "model", "lr").unwrap().as_f64().unwrap(), 0.05);
        assert!(get(&doc, "checkpoint", "enabled").unwrap().as_bool().unwrap());
    }

    #[test]
    fn int_coerces_to_f64_but_not_reverse() {
        let doc = parse("x = 3\ny = 3.5").unwrap();
        assert_eq!(get(&doc, "", "x").unwrap().as_f64().unwrap(), 3.0);
        assert!(get(&doc, "", "y").unwrap().as_i64().is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = [1, 2").is_err());
        assert!(parse("k = \"unterminated").is_err());
    }

    #[test]
    fn empty_array_and_nested_not_needed_but_safe() {
        let doc = parse("k = []").unwrap();
        assert_eq!(get(&doc, "", "k").unwrap(), &Value::Arr(vec![]));
    }
}
