//! Production-cluster overhead simulator (paper §3.2, Fig. 4).
//!
//! Simulates a population of training jobs against the checkpoint-overhead
//! model of §2.2: each job draws a duration and a sequence of failures; the
//! simulator charges O_save per checkpoint, and O_load + lost-computation +
//! rescheduling per failure, then reports the per-job overhead breakdown
//! distribution (the paper's Fig. 4 percentiles) and the total
//! machine-time wasted (the "1,156 machine-years" estimate).

use crate::metrics::OverheadLedger;
use crate::util::dist::{exponential, gamma};
use crate::util::rng::Rng;
use crate::util::stats;

/// Population-level simulation parameters.
#[derive(Clone, Debug)]
pub struct FleetSimConfig {
    pub jobs: usize,
    /// job duration: gamma(shape, scale) hours, clamped to >= min_duration
    pub duration_shape: f64,
    pub duration_scale_h: f64,
    pub min_duration_h: f64,
    /// per-job MTBF, hours (failures are memoryless within a job)
    pub t_fail_h: f64,
    /// checkpoint constants, hours
    pub o_save_h: f64,
    pub o_load_h: f64,
    /// rescheduling: exponential with this mean, heavy tail via queueing
    /// spikes (prob `res_spike_p` of multiplying by `res_spike_x`) —
    /// reproduces the paper's p95 being rescheduling-dominated
    pub o_res_mean_h: f64,
    pub res_spike_p: f64,
    pub res_spike_x: f64,
    /// job shape for machine-hour accounting: overhead idles the Emb PS
    /// fleet AND the data-parallel trainers (paper: 18 + 20)
    pub emb_ps_per_job: usize,
    pub trainers_per_job: usize,
}

impl Default for FleetSimConfig {
    fn default() -> Self {
        // tuned so the population statistics land on the paper's §3.2
        // aggregates: mean overhead ≈ 12%, save-dominated at p75,
        // lost-computation at p90, rescheduling at p95.
        Self {
            jobs: 17_000,
            duration_shape: 2.0,
            duration_scale_h: 30.0,
            min_duration_h: 10.0,
            t_fail_h: 22.0,
            o_save_h: 0.1,
            o_load_h: 0.15,
            o_res_mean_h: 0.3,
            res_spike_p: 0.08,
            res_spike_x: 12.0,
            emb_ps_per_job: 18,
            trainers_per_job: 20,
        }
    }
}

/// Per-job simulation output.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub duration_h: f64,
    pub ledger: OverheadLedger,
}

impl JobOutcome {
    pub fn overhead_frac(&self) -> f64 {
        self.ledger.fraction_of(self.duration_h)
    }
}

/// Simulate one job under FULL recovery at interval `t_save_h`.
pub fn simulate_job_full(
    rng: &mut Rng,
    duration_h: f64,
    t_save_h: f64,
    cfg: &FleetSimConfig,
) -> JobOutcome {
    let mut ledger = OverheadLedger::default();
    // checkpoint saves over the job
    let n_saves = (duration_h / t_save_h).floor();
    ledger.save_h = cfg.o_save_h * n_saves;
    ledger.n_saves = n_saves as u64;
    // failures: Poisson with rate duration/t_fail
    let mut t = exponential(rng, cfg.t_fail_h);
    let mut last_ckpt = 0.0f64;
    while t < duration_h {
        let since_ckpt = t - (t / t_save_h).floor() * t_save_h;
        let _ = last_ckpt;
        last_ckpt = t;
        ledger.lost_h += since_ckpt;
        ledger.load_h += cfg.o_load_h;
        let mut res = exponential(rng, cfg.o_res_mean_h);
        if rng.bool_with(cfg.res_spike_p) {
            res *= cfg.res_spike_x;
        }
        ledger.reschedule_h += res;
        ledger.n_failures += 1;
        t += exponential(rng, cfg.t_fail_h);
    }
    JobOutcome { duration_h, ledger }
}

/// Simulate one job under PARTIAL recovery at interval `t_save_h`
/// (no lost-computation term; paper Eq. 2).
pub fn simulate_job_partial(
    rng: &mut Rng,
    duration_h: f64,
    t_save_h: f64,
    cfg: &FleetSimConfig,
) -> JobOutcome {
    let mut ledger = OverheadLedger::default();
    let n_saves = (duration_h / t_save_h).floor();
    ledger.save_h = cfg.o_save_h * n_saves;
    ledger.n_saves = n_saves as u64;
    let mut t = exponential(rng, cfg.t_fail_h);
    while t < duration_h {
        ledger.load_h += cfg.o_load_h;
        let mut res = exponential(rng, cfg.o_res_mean_h);
        if rng.bool_with(cfg.res_spike_p) {
            res *= cfg.res_spike_x;
        }
        ledger.reschedule_h += res;
        ledger.n_failures += 1;
        t += exponential(rng, cfg.t_fail_h);
    }
    JobOutcome { duration_h, ledger }
}

/// Fleet-level aggregates for Fig. 4.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub mean_overhead_frac: f64,
    /// (percentile, save, load, lost, reschedule, total) as fractions
    pub breakdown: Vec<(f64, f64, f64, f64, f64, f64)>,
    pub machine_years_wasted: f64,
}

/// Run the fleet simulation under full recovery at each job's optimal
/// interval √(2 O_save T_fail).
pub fn simulate_fleet(rng: &mut Rng, cfg: &FleetSimConfig) -> FleetReport {
    let t_save = (2.0 * cfg.o_save_h * cfg.t_fail_h).sqrt();
    let mut fracs = Vec::with_capacity(cfg.jobs);
    let mut outcomes = Vec::with_capacity(cfg.jobs);
    let mut machine_hours = 0.0;
    for _ in 0..cfg.jobs {
        let duration = gamma(rng, cfg.duration_shape, cfg.duration_scale_h)
            .max(cfg.min_duration_h);
        let out = simulate_job_full(rng, duration, t_save, cfg);
        machine_hours +=
            out.ledger.machine_hours(cfg.emb_ps_per_job, cfg.trainers_per_job);
        fracs.push(out.overhead_frac());
        outcomes.push(out);
    }
    // percentile breakdown: order jobs by total overhead fraction, then
    // report the component split of the job at each percentile
    let mut order: Vec<usize> = (0..outcomes.len()).collect();
    order.sort_by(|&a, &b| fracs[a].partial_cmp(&fracs[b]).unwrap());
    let pick = |p: f64| -> (f64, f64, f64, f64, f64, f64) {
        let i = order[((p / 100.0) * (order.len() - 1) as f64).round() as usize];
        let o = &outcomes[i];
        let d = o.duration_h;
        (
            p,
            o.ledger.save_h / d,
            o.ledger.load_h / d,
            o.ledger.lost_h / d,
            o.ledger.reschedule_h / d,
            o.overhead_frac(),
        )
    };
    FleetReport {
        mean_overhead_frac: stats::mean(&fracs),
        breakdown: vec![pick(50.0), pick(75.0), pick(90.0), pick(95.0)],
        machine_years_wasted: machine_hours / (24.0 * 365.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_job_charges_all_four_overheads() {
        let cfg = FleetSimConfig { t_fail_h: 5.0, ..Default::default() };
        let mut rng = Rng::new(1);
        // long job so failures certainly occur
        let out = simulate_job_full(&mut rng, 200.0, 2.0, &cfg);
        assert!(out.ledger.n_saves == 100);
        assert!(out.ledger.n_failures > 10);
        assert!(out.ledger.save_h > 0.0 && out.ledger.load_h > 0.0);
        assert!(out.ledger.lost_h > 0.0 && out.ledger.reschedule_h > 0.0);
    }

    #[test]
    fn partial_job_has_no_lost_computation() {
        let cfg = FleetSimConfig { t_fail_h: 5.0, ..Default::default() };
        let mut rng = Rng::new(2);
        let out = simulate_job_partial(&mut rng, 200.0, 2.0, &cfg);
        assert_eq!(out.ledger.lost_h, 0.0);
        assert!(out.ledger.n_failures > 10);
    }

    #[test]
    fn lost_computation_bounded_by_interval() {
        let cfg = FleetSimConfig::default();
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let out = simulate_job_full(&mut rng, 100.0, 3.0, &cfg);
            assert!(out.ledger.lost_h <= 3.0 * out.ledger.n_failures as f64 + 1e-9);
        }
    }

    #[test]
    fn fleet_statistics_land_in_paper_band() {
        // paper §3.2: average overhead ≈ 12%, ~1,156 machine-years over
        // 17k jobs; we assert the same order of magnitude
        let cfg = FleetSimConfig { jobs: 4000, ..Default::default() };
        let mut rng = Rng::new(4);
        let rep = simulate_fleet(&mut rng, &cfg);
        assert!((0.06..0.20).contains(&rep.mean_overhead_frac),
                "mean overhead {}", rep.mean_overhead_frac);
        // percentiles monotone in total
        for w in rep.breakdown.windows(2) {
            assert!(w[1].5 >= w[0].5);
        }
        let scaled_years = rep.machine_years_wasted * (17_000.0 / 4000.0);
        assert!((300.0..4000.0).contains(&scaled_years),
                "machine-years {scaled_years}");
    }

    #[test]
    fn partial_beats_full_on_average_at_same_interval() {
        let cfg = FleetSimConfig::default();
        let mut rng = Rng::new(5);
        let (mut full, mut part) = (0.0, 0.0);
        for _ in 0..300 {
            let d = 80.0;
            full += simulate_job_full(&mut rng, d, 3.0, &cfg).ledger.total_h();
            part += simulate_job_partial(&mut rng, d, 3.0, &cfg).ledger.total_h();
        }
        assert!(part < full, "partial {part} !< full {full}");
    }
}
