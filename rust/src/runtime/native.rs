//! Pure-Rust reference executor for the DLRM train-step/predict graphs.
//!
//! Implements exactly the math of `python/compile/model.py` — bottom MLP
//! (all-ReLU), dot-product feature interaction over the strict upper
//! triangle in row-major order, top MLP (ReLU except the last layer), mean
//! BCE-with-logits loss, analytic backward, in-graph SGD on the MLP params,
//! and the embedding gradient returned for the Emb PS cluster to scatter.
//!
//! The backward formulas are validated against finite differences and the
//! unit tests below pin the numbers to a NumPy golden of the same graph
//! (see the test module), so this executor is a drop-in stand-in for the
//! PJRT artifacts wherever the XLA toolchain is unavailable. When the
//! artifact directory is missing entirely, the model ABI (the manifest) is
//! synthesized from the config presets, keeping the full training system
//! hermetic.

use anyhow::{ensure, Context, Result};

use super::manifest::{Manifest, ParamSpec};

/// Host-side tensor standing in for a PJRT device buffer. Exported as
/// `runtime::PjRtBuffer` so all callers are source-identical across the
/// native and pjrt runtimes.
#[derive(Clone, Debug, PartialEq)]
pub struct HostBuffer {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl HostBuffer {
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }
}

/// The native "runtime": no client state needed.
pub struct Runtime;

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self)
    }

    pub fn platform(&self) -> String {
        "native-cpu (pure-Rust reference executor)".to_string()
    }

    /// Load `<artifacts_dir>/<preset>/manifest.json` when present (shapes
    /// from a real AOT artifact), else synthesize the ABI from the config
    /// preset of the same name.
    pub fn load_model(&self, artifacts_dir: &str, preset: &str) -> Result<ModelExe> {
        let path = std::path::Path::new(artifacts_dir).join(preset).join("manifest.json");
        let manifest = if path.exists() {
            Manifest::load(&path)?
        } else {
            synth_manifest(preset)?
        };
        ModelExe::from_manifest(manifest)
    }
}

/// Build the artifact ABI straight from a config preset (layer dims in the
/// same flat [w0, b0, w1, b1, ...] order aot.py records).
fn synth_manifest(preset: &str) -> Result<Manifest> {
    let cfg = crate::config::preset(preset)
        .with_context(|| format!("no artifacts on disk and no preset named {preset:?}"))?;
    let m = cfg.model;
    let mut params = Vec::new();
    let mut fan_in = m.num_dense;
    for (i, &width) in m.bottom_mlp.iter().enumerate() {
        params.push(ParamSpec { name: format!("bot{i}.w"), shape: vec![fan_in, width] });
        params.push(ParamSpec { name: format!("bot{i}.b"), shape: vec![width] });
        fan_in = width;
    }
    let mut fan_in = m.emb_dim + m.num_pairs();
    for (i, &width) in m.top_mlp.iter().enumerate() {
        params.push(ParamSpec { name: format!("top{i}.w"), shape: vec![fan_in, width] });
        params.push(ParamSpec { name: format!("top{i}.b"), shape: vec![width] });
        fan_in = width;
    }
    Ok(Manifest {
        name: m.preset.clone(),
        batch: m.batch,
        num_dense: m.num_dense,
        num_sparse: m.num_sparse,
        emb_dim: m.emb_dim,
        num_pairs: m.num_pairs(),
        params,
        train_file: "<native>".to_string(),
        predict_file: "<native>".to_string(),
    })
}

/// The output of one training step.
pub struct StepOutput {
    pub loss: f32,
    /// d(loss)/d(gathered embeddings), [B, num_sparse, emb_dim] row-major
    pub emb_grad: Vec<f32>,
}

/// Executable model: the manifest ABI plus the derived layer structure.
pub struct ModelExe {
    pub manifest: Manifest,
    /// number of bottom-MLP layers (params [0, 2*n_bottom) are bottom)
    n_bottom: usize,
    /// strict-upper-triangle (i, j) pairs in row-major order
    pairs: Vec<(usize, usize)>,
}

impl ModelExe {
    fn from_manifest(manifest: Manifest) -> Result<Self> {
        ensure!(manifest.params.len() % 2 == 0, "params must be (w, b) pairs");
        let n_layers = manifest.params.len() / 2;
        let n_bottom = manifest
            .params
            .iter()
            .filter(|p| p.name.starts_with("bot") && p.shape.len() == 2)
            .count();
        ensure!(n_bottom >= 1 && n_layers > n_bottom,
                "need at least one bottom and one top layer");
        let f = manifest.num_sparse + 1;
        let pairs: Vec<(usize, usize)> =
            (0..f).flat_map(|i| (i + 1..f).map(move |j| (i, j))).collect();
        ensure!(pairs.len() == manifest.num_pairs, "num_pairs mismatch");
        // ABI sanity: bottom output feeds the interaction as feature 0
        let bottom_out = manifest.params[2 * (n_bottom - 1)].shape[1];
        ensure!(bottom_out == manifest.emb_dim,
                "bottom MLP output {} must equal emb_dim {}", bottom_out, manifest.emb_dim);
        let top_in = manifest.params[2 * n_bottom].shape[0];
        ensure!(top_in == manifest.emb_dim + manifest.num_pairs,
                "top MLP input {} must equal emb_dim + num_pairs", top_in);
        ensure!(manifest.params[manifest.params.len() - 2].shape[1] == 1,
                "top MLP must end in one logit");
        Ok(Self { manifest, n_bottom, pairs })
    }

    /// (w, b, fan_in, fan_out) of flat layer `l`.
    fn layer<'a>(&self, params: &'a [HostBuffer], l: usize) -> (&'a [f32], &'a [f32], usize, usize) {
        let w = &params[2 * l];
        let b = &params[2 * l + 1];
        (&w.data, &b.data, w.shape[0], w.shape[1])
    }

    pub fn buffer(&self, data: &[f32], shape: &[usize]) -> Result<HostBuffer> {
        ensure!(data.len() == shape.iter().product::<usize>(),
                "buffer of {} elements does not match shape {:?}", data.len(), shape);
        Ok(HostBuffer { data: data.to_vec(), shape: shape.to_vec() })
    }

    /// Initialize MLP parameters (Xavier-uniform weights, zero biases),
    /// identical to the pjrt runtime's init so runs are comparable.
    pub fn init_params(&self, seed: u64) -> Vec<HostBuffer> {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        self.manifest
            .params
            .iter()
            .map(|p| {
                let n: usize = p.shape.iter().product();
                let data: Vec<f32> = if p.shape.len() == 2 {
                    let bound =
                        (6.0 / (p.shape[0] + p.shape[1]) as f64).sqrt() as f32;
                    (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * bound).collect()
                } else {
                    vec![0.0; n] // biases
                };
                HostBuffer { data, shape: p.shape.clone() }
            })
            .collect()
    }

    /// Forward through the bottom MLP; returns every activation
    /// (acts[0] = dense input, acts[n_bottom] = the D-wide bottom output).
    fn bottom_forward(&self, params: &[HostBuffer], dense: &[f32], b: usize) -> Vec<Vec<f32>> {
        let mut acts = Vec::with_capacity(self.n_bottom + 1);
        acts.push(dense.to_vec());
        for l in 0..self.n_bottom {
            let (w, bias, i_dim, o_dim) = self.layer(params, l);
            let y = linear(acts.last().unwrap(), w, bias, b, i_dim, o_dim, true);
            acts.push(y);
        }
        acts
    }

    /// feats [B, F, D] (bottom output as feature 0, then the S embeddings)
    /// and the packed interaction z [B, P].
    fn interact(&self, x: &[f32], emb: &[f32], b: usize) -> (Vec<f32>, Vec<f32>) {
        let m = &self.manifest;
        let (s, d, f, p) = (m.num_sparse, m.emb_dim, m.num_sparse + 1, m.num_pairs);
        let mut feats = vec![0.0f32; b * f * d];
        for r in 0..b {
            feats[r * f * d..r * f * d + d].copy_from_slice(&x[r * d..(r + 1) * d]);
            feats[r * f * d + d..(r + 1) * f * d]
                .copy_from_slice(&emb[r * s * d..(r + 1) * s * d]);
        }
        let mut z = vec![0.0f32; b * p];
        for r in 0..b {
            let fr = &feats[r * f * d..(r + 1) * f * d];
            for (k, &(i, j)) in self.pairs.iter().enumerate() {
                let fi = &fr[i * d..(i + 1) * d];
                let fj = &fr[j * d..(j + 1) * d];
                z[r * p + k] = fi.iter().zip(fj).map(|(a, c)| a * c).sum();
            }
        }
        (feats, z)
    }

    /// Top-MLP forward; tacts[0] = concat(x, z), tacts.last() = [B, 1].
    fn top_forward(&self, params: &[HostBuffer], x: &[f32], z: &[f32], b: usize) -> Vec<Vec<f32>> {
        let m = &self.manifest;
        let (d, p) = (m.emb_dim, m.num_pairs);
        let ti = d + p;
        let n_layers = m.params.len() / 2;
        let n_top = n_layers - self.n_bottom;
        let mut t0 = vec![0.0f32; b * ti];
        for r in 0..b {
            t0[r * ti..r * ti + d].copy_from_slice(&x[r * d..(r + 1) * d]);
            t0[r * ti + d..(r + 1) * ti].copy_from_slice(&z[r * p..(r + 1) * p]);
        }
        let mut tacts = Vec::with_capacity(n_top + 1);
        tacts.push(t0);
        for l in 0..n_top {
            let (w, bias, i_dim, o_dim) = self.layer(params, self.n_bottom + l);
            let relu = l < n_top - 1;
            let y = linear(tacts.last().unwrap(), w, bias, b, i_dim, o_dim, relu);
            tacts.push(y);
        }
        tacts
    }

    /// One training step: forward, mean BCE loss, analytic backward,
    /// in-place SGD on the MLP params. Returns the loss and the embedding
    /// gradient for the Emb PS cluster.
    pub fn train_step(
        &self,
        dense: &[f32],
        emb: &[f32],
        labels: &[f32],
        lr: f32,
        params: &mut Vec<HostBuffer>,
    ) -> Result<StepOutput> {
        let m = &self.manifest;
        let (b, s, d, p) = (m.batch, m.num_sparse, m.emb_dim, m.num_pairs);
        let f = s + 1;
        let ti = d + p;
        ensure!(dense.len() == b * m.num_dense, "dense shape mismatch");
        ensure!(emb.len() == b * s * d, "emb shape mismatch");
        ensure!(labels.len() == b, "labels shape mismatch");
        let n_layers = m.params.len() / 2;
        let n_top = n_layers - self.n_bottom;

        // ---- forward --------------------------------------------------
        let acts = self.bottom_forward(params, dense, b);
        let x = acts.last().unwrap();
        let (feats, z) = self.interact(x, emb, b);
        let tacts = self.top_forward(params, x, &z, b);
        let logits: Vec<f32> = tacts.last().unwrap().clone(); // o_dim == 1

        let mut loss_acc = 0.0f64;
        for r in 0..b {
            let l = logits[r] as f64;
            let y = labels[r] as f64;
            loss_acc += l.max(0.0) - l * y + (-l.abs()).exp().ln_1p();
        }
        let loss = (loss_acc / b as f64) as f32;

        // ---- backward -------------------------------------------------
        // d(loss)/d(logit) = (sigmoid(logit) - label) / B
        let mut dy: Vec<f32> = (0..b)
            .map(|r| {
                let sig = 1.0 / (1.0 + (-logits[r]).exp());
                (sig - labels[r]) / b as f32
            })
            .collect();
        let mut grads: Vec<(Vec<f32>, Vec<f32>)> = vec![(Vec::new(), Vec::new()); n_layers];
        for l in (0..n_top).rev() {
            let (w, _, i_dim, o_dim) = self.layer(params, self.n_bottom + l);
            let relu = l < n_top - 1;
            let (dx, dw, db) =
                linear_bwd(&tacts[l], w, &tacts[l + 1], &dy, b, i_dim, o_dim, relu);
            grads[self.n_bottom + l] = (dw, db);
            dy = dx;
        }
        let dt0 = dy; // [B, TI] = [dx_from_top | dz]

        // interaction backward: dX = (dG + dG^T) X over the packed triu
        let mut dfeats = vec![0.0f32; b * f * d];
        for r in 0..b {
            let fr = &feats[r * f * d..(r + 1) * f * d];
            let dfr = &mut dfeats[r * f * d..(r + 1) * f * d];
            for (k, &(i, j)) in self.pairs.iter().enumerate() {
                let g = dt0[r * ti + d + k];
                for dd in 0..d {
                    dfr[i * d + dd] += g * fr[j * d + dd];
                    dfr[j * d + dd] += g * fr[i * d + dd];
                }
            }
        }
        let mut emb_grad = vec![0.0f32; b * s * d];
        for r in 0..b {
            emb_grad[r * s * d..(r + 1) * s * d]
                .copy_from_slice(&dfeats[r * f * d + d..(r + 1) * f * d]);
        }
        // feature 0 gradient joins the top MLP's direct path into x
        let mut dx = vec![0.0f32; b * d];
        for r in 0..b {
            for dd in 0..d {
                dx[r * d + dd] = dt0[r * ti + dd] + dfeats[r * f * d + dd];
            }
        }
        for l in (0..self.n_bottom).rev() {
            let (w, _, i_dim, o_dim) = self.layer(params, l);
            let (dx2, dw, db) = linear_bwd(&acts[l], w, &acts[l + 1], &dx, b, i_dim, o_dim, true);
            grads[l] = (dw, db);
            dx = dx2;
        }

        // ---- in-graph SGD ---------------------------------------------
        for (l, (dw, db)) in grads.iter().enumerate() {
            for (wv, g) in params[2 * l].data.iter_mut().zip(dw) {
                *wv -= lr * g;
            }
            for (bv, g) in params[2 * l + 1].data.iter_mut().zip(db) {
                *bv -= lr * g;
            }
        }
        Ok(StepOutput { loss, emb_grad })
    }

    /// Forward-only logits for an eval batch.
    pub fn predict(
        &self,
        dense: &[f32],
        emb: &[f32],
        params: &[HostBuffer],
    ) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let b = m.batch;
        ensure!(dense.len() == b * m.num_dense, "dense shape mismatch");
        ensure!(emb.len() == b * m.num_sparse * m.emb_dim, "emb shape mismatch");
        let acts = self.bottom_forward(params, dense, b);
        let x = acts.last().unwrap();
        let (_, z) = self.interact(x, emb, b);
        let tacts = self.top_forward(params, x, &z, b);
        Ok(tacts.last().unwrap().clone())
    }

    /// Copy MLP params to the host (checkpointing path).
    pub fn params_to_host(&self, params: &[HostBuffer]) -> Result<Vec<Vec<f32>>> {
        Ok(params.iter().map(|p| p.data.clone()).collect())
    }

    /// Rebuild param buffers from host copies (restore path).
    pub fn params_from_host(&self, host: &[Vec<f32>]) -> Vec<HostBuffer> {
        host.iter()
            .zip(&self.manifest.params)
            .map(|(data, spec)| HostBuffer { data: data.clone(), shape: spec.shape.clone() })
            .collect()
    }
}

/// y = x @ w + b, optionally ReLU. x:[B,I] w:[I,O] b:[O] -> [B,O].
fn linear(x: &[f32], w: &[f32], b: &[f32], bsz: usize, i_dim: usize, o_dim: usize, relu: bool) -> Vec<f32> {
    let mut y = vec![0.0f32; bsz * o_dim];
    for r in 0..bsz {
        let yr = &mut y[r * o_dim..(r + 1) * o_dim];
        yr.copy_from_slice(b);
        let xr = &x[r * i_dim..(r + 1) * i_dim];
        for (k, &xv) in xr.iter().enumerate() {
            if xv != 0.0 {
                for (yo, &wv) in yr.iter_mut().zip(&w[k * o_dim..(k + 1) * o_dim]) {
                    *yo += xv * wv;
                }
            }
        }
        if relu {
            for v in yr.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }
    y
}

/// Backward through y = [relu](x @ w + b): `dy` is the gradient w.r.t. y,
/// `y` the saved forward output (the ReLU mask source, matching the
/// custom_vjp in model.py). Returns (dx, dw, db).
#[allow(clippy::too_many_arguments)]
fn linear_bwd(
    x: &[f32],
    w: &[f32],
    y: &[f32],
    dy: &[f32],
    bsz: usize,
    i_dim: usize,
    o_dim: usize,
    relu: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dz = dy.to_vec();
    if relu {
        for (g, &yo) in dz.iter_mut().zip(y) {
            if yo <= 0.0 {
                *g = 0.0;
            }
        }
    }
    let mut dw = vec![0.0f32; i_dim * o_dim];
    let mut db = vec![0.0f32; o_dim];
    let mut dx = vec![0.0f32; bsz * i_dim];
    for r in 0..bsz {
        let dzr = &dz[r * o_dim..(r + 1) * o_dim];
        let xr = &x[r * i_dim..(r + 1) * i_dim];
        for (o, &g) in dzr.iter().enumerate() {
            db[o] += g;
        }
        for k in 0..i_dim {
            let xv = xr[k];
            let wk = &w[k * o_dim..(k + 1) * o_dim];
            let dwk = &mut dw[k * o_dim..(k + 1) * o_dim];
            let mut acc = 0.0f32;
            for o in 0..o_dim {
                let g = dzr[o];
                dwk[o] += xv * g;
                acc += g * wk[o];
            }
            dx[r * i_dim + k] = acc;
        }
    }
    (dx, dw, db)
}

#[cfg(test)]
mod tests {
    use super::*;

    // -- golden test ---------------------------------------------------
    // Tiny DLRM (B=2, dense=3, sparse=2, D=2, bottom=[4,2], top=[3,1])
    // with deterministic sin/cos-patterned weights and inputs. Expected
    // numbers generated by a NumPy float32 implementation of the same
    // graph whose analytic gradients were checked against central finite
    // differences to 8e-11 (see git history of this PR for the script).

    fn tiny_manifest() -> Manifest {
        Manifest {
            name: "tiny".into(),
            batch: 2,
            num_dense: 3,
            num_sparse: 2,
            emb_dim: 2,
            num_pairs: 3,
            params: vec![
                ParamSpec { name: "bot0.w".into(), shape: vec![3, 4] },
                ParamSpec { name: "bot0.b".into(), shape: vec![4] },
                ParamSpec { name: "bot1.w".into(), shape: vec![4, 2] },
                ParamSpec { name: "bot1.b".into(), shape: vec![2] },
                ParamSpec { name: "top0.w".into(), shape: vec![5, 3] },
                ParamSpec { name: "top0.b".into(), shape: vec![3] },
                ParamSpec { name: "top1.w".into(), shape: vec![3, 1] },
                ParamSpec { name: "top1.b".into(), shape: vec![1] },
            ],
            train_file: "<native>".into(),
            predict_file: "<native>".into(),
        }
    }

    fn tiny_params(model: &ModelExe) -> Vec<HostBuffer> {
        let mut k = 0.0f64;
        model
            .manifest
            .params
            .iter()
            .map(|spec| {
                let n: usize = spec.shape.iter().product();
                let data: Vec<f32> = (0..n)
                    .map(|_| {
                        k += 1.0;
                        if spec.shape.len() == 2 {
                            (k.sin() * 0.4) as f32
                        } else {
                            (k.cos() * 0.1) as f32
                        }
                    })
                    .collect();
                HostBuffer { data, shape: spec.shape.clone() }
            })
            .collect()
    }

    fn tiny_inputs() -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut k = 0.0f64;
        let dense: Vec<f32> = (0..2 * 3)
            .map(|_| {
                k += 1.0;
                ((0.7 * k).sin() * 0.9) as f32
            })
            .collect();
        let emb: Vec<f32> = (0..2 * 2 * 2)
            .map(|_| {
                k += 1.0;
                ((0.3 * k).cos() * 0.8) as f32
            })
            .collect();
        (dense, emb, vec![1.0, 0.0])
    }

    fn assert_close(name: &str, got: &[f32], want: &[f32], atol: f32) {
        assert_eq!(got.len(), want.len(), "{name}: length");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!((g - w).abs() <= atol, "{name}[{i}]: got {g}, want {w}");
        }
    }

    #[test]
    fn golden_forward_matches_numpy() {
        let model = ModelExe::from_manifest(tiny_manifest()).unwrap();
        let params = tiny_params(&model);
        let (dense, emb, _) = tiny_inputs();
        let logits = model.predict(&dense, &emb, &params).unwrap();
        assert_close("logits", &logits, &[0.1374279, 0.1036706], 2e-5);
    }

    #[test]
    fn golden_train_step_matches_numpy() {
        let model = ModelExe::from_manifest(tiny_manifest()).unwrap();
        let mut params = tiny_params(&model);
        let (dense, emb, labels) = tiny_inputs();
        let out = model.train_step(&dense, &emb, &labels, 0.5, &mut params).unwrap();
        assert!((out.loss - 0.6865587).abs() < 2e-5, "loss {}", out.loss);
        assert_close(
            "emb_grad",
            &out.emb_grad,
            &[0.03631461, 0.04142572, 0.02581278, 0.03455934,
              -0.03031038, -0.01737285, -0.05510302, -0.05197629],
            2e-5,
        );
        let want_new: [&[f32]; 8] = [
            &[0.3320858, 0.3669156, 0.05476995, -0.302721, -0.3879256,
              -0.1151135, 0.2645518, 0.3957433, 0.1626868, -0.2259254,
              -0.3956301, -0.2146292],
            &[0.08141461, 0.02427647, -0.08153466, -0.09576595],
            &[-0.3855446, -0.2956459, 0.05458447, 0.3756205, 0.3318619,
              0.001908737, -0.3384882, -0.3622313],
            &[0.1101092, 0.08168355],
            &[0.3806279, 0.1063249, -0.2657327, -0.3993028, -0.1659499,
              0.2199767, 0.4057151, 0.2177273, -0.170438, -0.3950641,
              -0.2556694, 0.1187867, 0.3824863, 0.2948321, -0.06388938],
            &[-0.04512075, 0.05008279, 0.09924045],
            &[0.3449551, 0.3608847, 0.04501858],
            &[-0.0790638],
        ];
        for (i, want) in want_new.iter().enumerate() {
            assert_close(&format!("new_param{i}"), &params[i].data, want, 2e-5);
        }
    }

    // -- behavioural tests ---------------------------------------------

    #[test]
    fn repeated_steps_on_one_batch_reduce_loss() {
        let model = ModelExe::from_manifest(tiny_manifest()).unwrap();
        let mut params = tiny_params(&model);
        let (dense, mut emb, labels) = tiny_inputs();
        let first = model.train_step(&dense, &emb, &labels, 0.1, &mut params).unwrap();
        for _ in 0..50 {
            let out = model.train_step(&dense, &emb, &labels, 0.1, &mut params).unwrap();
            for (e, g) in emb.iter_mut().zip(&out.emb_grad) {
                *e -= 0.1 * g;
            }
        }
        let last = model.train_step(&dense, &emb, &labels, 0.0, &mut params).unwrap().loss;
        assert!(last < first.loss - 0.05, "loss stuck: {} -> {last}", first.loss);
    }

    #[test]
    fn load_model_without_artifacts_synthesizes_presets() {
        let rt = Runtime::cpu().unwrap();
        let model = rt.load_model("/nonexistent-artifacts", "mini").unwrap();
        let m = &model.manifest;
        assert_eq!((m.batch, m.num_dense, m.num_sparse, m.emb_dim), (128, 13, 26, 8));
        assert_eq!(m.num_pairs, 27 * 26 / 2);
        // mini: bottom [64, 32, 8] + top [64, 1] = 5 layers, 10 params
        assert_eq!(m.params.len(), 10);
        assert_eq!(m.params[0].shape, vec![13, 64]);
        assert_eq!(m.params[6].shape, vec![8 + 351, 64]);
        assert!(rt.load_model("/nonexistent-artifacts", "nope").is_err());
    }

    #[test]
    fn predict_matches_across_param_roundtrip() {
        let rt = Runtime::cpu().unwrap();
        let model = rt.load_model("/nonexistent-artifacts", "mini").unwrap();
        let m = &model.manifest;
        let params = model.init_params(3);
        let dense = vec![0.25f32; m.batch * m.num_dense];
        let emb = vec![0.01f32; m.batch * m.num_sparse * m.emb_dim];
        let a = model.predict(&dense, &emb, &params).unwrap();
        let host = model.params_to_host(&params).unwrap();
        let params2 = model.params_from_host(&host);
        let b = model.predict(&dense, &emb, &params2).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), m.batch);
    }

    #[test]
    fn buffer_validates_shape() {
        let model = ModelExe::from_manifest(tiny_manifest()).unwrap();
        assert!(model.buffer(&[1.0, 2.0], &[2]).is_ok());
        assert!(model.buffer(&[1.0, 2.0], &[3]).is_err());
        assert!(model.buffer(&[1.0], &[]).is_ok()); // scalar
    }
}
