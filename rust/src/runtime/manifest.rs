//! The artifact ABI: parsed form of `manifest.json` written by aot.py.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Name + shape of one MLP parameter, in artifact input order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Everything Rust needs to marshal literals for one compiled model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub name: String,
    pub batch: usize,
    pub num_dense: usize,
    pub num_sparse: usize,
    pub emb_dim: usize,
    pub num_pairs: usize,
    pub params: Vec<ParamSpec>,
    pub train_file: String,
    pub predict_file: String,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p.get("shape")?.as_arr()?
                        .iter().map(|d| d.as_usize()).collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if params.is_empty() {
            bail!("manifest has no params");
        }
        Ok(Manifest {
            params,
            name: j.get("name")?.as_str()?.to_string(),
            batch: j.get("batch")?.as_usize()?,
            num_dense: j.get("num_dense")?.as_usize()?,
            num_sparse: j.get("num_sparse")?.as_usize()?,
            emb_dim: j.get("emb_dim")?.as_usize()?,
            num_pairs: j.get("num_pairs")?.as_usize()?,
            train_file: j.get("train_step")?.get("file")?.as_str()?.to_string(),
            predict_file: j.get("predict")?.get("file")?.as_str()?.to_string(),
        })
    }

    /// Total MLP parameter count.
    pub fn mlp_params(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "mini", "batch": 128, "num_dense": 13, "num_sparse": 26,
      "emb_dim": 8, "num_pairs": 351,
      "params": [
        {"name": "bot0.w", "shape": [13, 64]},
        {"name": "bot0.b", "shape": [64]}
      ],
      "train_step": {"file": "train_step.hlo.txt", "inputs": [], "outputs": []},
      "predict": {"file": "predict.hlo.txt", "inputs": [], "outputs": []}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "mini");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].shape, vec![13, 64]);
        assert_eq!(m.mlp_params(), 13 * 64 + 64);
        assert_eq!(m.train_file, "train_step.hlo.txt");
    }

    #[test]
    fn missing_field_errors() {
        assert!(Manifest::parse("{}").is_err());
    }
}
