//! PJRT runtime: load the AOT artifacts (HLO text + manifest ABI) emitted
//! by `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute_b`.
//! HLO **text** is the interchange format (xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos). Python never runs at training time.
//!
//! Hot-path design (see EXPERIMENTS.md §Perf):
//!  * artifacts are lowered with untupled outputs, so PJRT hands back one
//!    device buffer per output — the updated MLP parameters stay resident
//!    on device between steps and are never copied to the host except for
//!    checkpointing;
//!  * `execute_b` (buffer inputs) is used exclusively: the literal-input
//!    `execute` in xla 0.1.6 leaks the temporary device buffers it creates
//!    (~240 KB per call, an OOM after a few thousand steps).

use super::manifest::Manifest;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Shared PJRT client (CPU). One per process.
pub struct Runtime {
    client: PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, hlo_path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
        let proto = HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", hlo_path.display()))
    }

    /// Load one model preset's artifacts from `<artifacts_dir>/<preset>/`.
    pub fn load_model(&self, artifacts_dir: &str, preset: &str) -> Result<ModelExe> {
        let dir = std::path::Path::new(artifacts_dir).join(preset);
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let train_step = self.compile(&dir.join(&manifest.train_file))?;
        let predict = self.compile(&dir.join(&manifest.predict_file))?;
        Ok(ModelExe { manifest, train_step, predict, client: self.client.clone() })
    }
}

/// Compiled train-step + predict executables for one model preset, plus the
/// ABI metadata needed to marshal literals.
pub struct ModelExe {
    pub manifest: Manifest,
    train_step: PjRtLoadedExecutable,
    predict: PjRtLoadedExecutable,
    client: PjRtClient,
}

/// The output of one training step.
pub struct StepOutput {
    pub loss: f32,
    /// d(loss)/d(gathered embeddings), [B, num_sparse, emb_dim] row-major
    pub emb_grad: Vec<f32>,
}

impl ModelExe {
    /// Upload host data as a device buffer.
    pub fn buffer(&self, data: &[f32], shape: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, shape, None)?)
    }

    /// Initialize MLP parameters (Xavier-uniform weights, zero biases)
    /// as device-resident buffers, per the manifest shapes.
    pub fn init_params(&self, seed: u64) -> Vec<PjRtBuffer> {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        self.manifest
            .params
            .iter()
            .map(|p| {
                let n: usize = p.shape.iter().product();
                let data: Vec<f32> = if p.shape.len() == 2 {
                    let bound =
                        (6.0 / (p.shape[0] + p.shape[1]) as f64).sqrt() as f32;
                    (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * bound).collect()
                } else {
                    vec![0.0; n] // biases
                };
                self.buffer(&data, &p.shape).expect("param upload")
            })
            .collect()
    }

    /// Execute one train step. `dense` [B*num_dense], `emb` [B*S*D],
    /// `labels` [B]; `params` is replaced in place by the device-resident
    /// updated MLP weights (no host round-trip).
    pub fn train_step(
        &self,
        dense: &[f32],
        emb: &[f32],
        labels: &[f32],
        lr: f32,
        params: &mut Vec<PjRtBuffer>,
    ) -> Result<StepOutput> {
        let m = &self.manifest;
        debug_assert_eq!(dense.len(), m.batch * m.num_dense);
        debug_assert_eq!(emb.len(), m.batch * m.num_sparse * m.emb_dim);
        debug_assert_eq!(labels.len(), m.batch);
        let d = self.buffer(dense, &[m.batch, m.num_dense])?;
        let e = self.buffer(emb, &[m.batch, m.num_sparse, m.emb_dim])?;
        let l = self.buffer(labels, &[m.batch])?;
        let lrb = self.buffer(&[lr], &[])?;
        let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(4 + params.len());
        inputs.push(&d);
        inputs.push(&e);
        inputs.push(&l);
        inputs.push(&lrb);
        inputs.extend(params.iter());

        let mut result = self.train_step.execute_b::<&PjRtBuffer>(&inputs)?;
        let mut outs = result.pop().context("no replica outputs")?;
        let expected = 2 + self.manifest.params.len();
        if outs.len() == expected {
            // untupled outputs: params stay device-resident
            let new_params = outs.split_off(2);
            let emb_grad =
                outs.pop().unwrap().to_literal_sync()?.to_vec::<f32>()?;
            let loss =
                outs.pop().unwrap().to_literal_sync()?.to_vec::<f32>()?[0];
            *params = new_params;
            return Ok(StepOutput { loss, emb_grad });
        }
        if outs.len() != 1 {
            bail!("train_step returned {} outputs, expected {expected} or 1",
                  outs.len());
        }
        // XLA tuples multi-output roots: download once, decompose, and
        // re-upload the params (leak-free paths only — see module docs)
        let mut parts = outs.pop().unwrap().to_literal_sync()?.to_tuple()?;
        if parts.len() != expected {
            bail!("train_step tuple has {} parts, expected {expected}",
                  parts.len());
        }
        let new_params = parts
            .split_off(2)
            .iter()
            .zip(&self.manifest.params)
            .map(|(l, spec)| self.buffer(&l.to_vec::<f32>()?, &spec.shape))
            .collect::<Result<Vec<_>>>()?;
        let emb_grad = parts.pop().unwrap().to_vec::<f32>()?;
        let loss = parts.pop().unwrap().to_vec::<f32>()?[0];
        *params = new_params;
        Ok(StepOutput { loss, emb_grad })
    }

    /// Forward-only logits for an eval batch.
    pub fn predict(
        &self,
        dense: &[f32],
        emb: &[f32],
        params: &[PjRtBuffer],
    ) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let d = self.buffer(dense, &[m.batch, m.num_dense])?;
        let e = self.buffer(emb, &[m.batch, m.num_sparse, m.emb_dim])?;
        let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(2 + params.len());
        inputs.push(&d);
        inputs.push(&e);
        inputs.extend(params.iter());
        let mut result = self.predict.execute_b::<&PjRtBuffer>(&inputs)?;
        let mut outs = result.pop().context("no replica outputs")?;
        let logits = outs.pop().context("predict returned no outputs")?;
        Ok(logits.to_literal_sync()?.to_vec::<f32>()?)
    }

    /// Copy MLP params to the host (checkpointing path only).
    pub fn params_to_host(&self, params: &[PjRtBuffer]) -> Result<Vec<Vec<f32>>> {
        params.iter()
            .map(|p| Ok(p.to_literal_sync()?.to_vec::<f32>()?))
            .collect()
    }

    /// Re-upload host copies as device buffers (restore path).
    pub fn params_from_host(&self, host: &[Vec<f32>]) -> Vec<PjRtBuffer> {
        host.iter()
            .zip(&self.manifest.params)
            .map(|(data, spec)| self.buffer(data, &spec.shape).expect("upload"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        std::path::Path::new("artifacts/mini/manifest.json").exists()
    }

    #[test]
    fn mini_train_step_runs_and_learns_a_batch() -> Result<()> {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return Ok(());
        }
        let rt = Runtime::cpu()?;
        let model = rt.load_model("artifacts", "mini")?;
        let m = &model.manifest;
        assert_eq!((m.batch, m.num_dense, m.num_sparse, m.emb_dim),
                   (128, 13, 26, 8));

        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        let dense: Vec<f32> = (0..m.batch * m.num_dense)
            .map(|_| rng.f32() - 0.5).collect();
        let emb: Vec<f32> = (0..m.batch * m.num_sparse * m.emb_dim)
            .map(|_| 0.1 * (rng.f32() - 0.5)).collect();
        let labels: Vec<f32> = (0..m.batch)
            .map(|_| (rng.f64() < 0.5) as u32 as f32).collect();

        let mut params = model.init_params(1);
        let out1 = model.train_step(&dense, &emb, &labels, 0.1, &mut params)?;
        assert!(out1.loss.is_finite());
        assert_eq!(out1.emb_grad.len(), emb.len());

        // apply the embedding SGD like the PS would, retrain same batch:
        // loss must drop (params + embeddings both moved downhill)
        let emb2: Vec<f32> = emb.iter().zip(&out1.emb_grad)
            .map(|(e, g)| e - 0.1 * g).collect();
        let out2 = model.train_step(&dense, &emb2, &labels, 0.1, &mut params)?;
        assert!(out2.loss < out1.loss, "{} !< {}", out2.loss, out1.loss);
        Ok(())
    }

    #[test]
    fn predict_matches_across_param_roundtrip() -> Result<()> {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return Ok(());
        }
        let rt = Runtime::cpu()?;
        let model = rt.load_model("artifacts", "mini")?;
        let m = &model.manifest;
        let params = model.init_params(3);
        let dense = vec![0.25f32; m.batch * m.num_dense];
        let emb = vec![0.01f32; m.batch * m.num_sparse * m.emb_dim];
        let a = model.predict(&dense, &emb, &params)?;
        // round-trip params through host copies (checkpoint path)
        let host = model.params_to_host(&params)?;
        let params2 = model.params_from_host(&host);
        let b = model.predict(&dense, &emb, &params2)?;
        assert_eq!(a, b);
        assert_eq!(a.len(), m.batch);
        Ok(())
    }
}
