//! Model execution runtimes for the AOT DLRM artifacts.
//!
//! Two interchangeable implementations behind one API surface
//! (`Runtime` / `ModelExe` / `PjRtBuffer` / `StepOutput`):
//!
//! * **pjrt** (cargo feature `pjrt`, needs the vendored `xla` bindings):
//!   loads the HLO-text artifacts emitted by `python/compile/aot.py` and
//!   executes them through PJRT — the L2/L1 path of the paper repro.
//! * **native** (default): a pure-Rust reference executor implementing the
//!   same DLRM forward/backward/SGD math (`python/compile/model.py`) with
//!   no external dependencies, so `cargo build && cargo test` are hermetic
//!   in images without the XLA toolchain. When the artifact directory is
//!   absent it synthesizes the model ABI from the config presets.
//!
//! The coordinator, examples, and tests are source-identical across both.

mod manifest;

pub use manifest::{Manifest, ParamSpec};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{ModelExe, Runtime, StepOutput};
#[cfg(feature = "pjrt")]
pub use xla::PjRtBuffer;

#[cfg(not(feature = "pjrt"))]
mod native;
#[cfg(not(feature = "pjrt"))]
pub use native::{HostBuffer as PjRtBuffer, ModelExe, Runtime, StepOutput};
