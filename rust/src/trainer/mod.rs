//! The data-parallel trainer layer: N trainer threads driving one shared
//! Emb PS cluster (paper §2.1 — many synchronous MLP trainers hammer the
//! sharded Emb PS fleet; ECRM and Check-N-Run both evaluate fault
//! tolerance under exactly this concurrent-trainer load).
//!
//! Each trainer thread owns a full [`crate::runtime::ModelExe`] replica
//! (and its own runtime handle — the pjrt client is not `Sync`) plus a
//! disjoint round-robin shard of the synthetic click-log stream: at
//! global step `s`, trainer `r` of `N` consumes samples
//! `[(s·N + r)·B, (s·N + r + 1)·B)`. One global step is:
//!
//! 1. **gather** — every trainer gathers its batch's embedding rows
//!    straight through the [`ShardedPs`] data plane (per-node interior
//!    locks; true concurrent load on both backends, no global lock);
//! 2. **gather barrier** — nobody applies until everyone has gathered, so
//!    all replicas observe the *pre-step* PS state;
//! 3. **compute** — each replica runs its local train step (in-graph SGD
//!    on its dense params);
//! 4. **sharded ordered scatter** — sparse updates go through
//!    [`ShardedPs::apply_grads_ordered`]: same-node updates are sequenced
//!    by trainer rank on that node's own turnstile, node-disjoint updates
//!    run in parallel. The PS floats are reproducible run-to-run and
//!    identical across the inproc and threaded backends;
//! 5. **allreduce (driver)** — the coordinator averages the N dense
//!    replicas at the step barrier. Since every replica started the step
//!    from the same params, parameter averaging after one local SGD step
//!    *is* gradient averaging; at N = 1 it degenerates to the identity,
//!    keeping the single-trainer path bit-identical to the pre-refactor
//!    coordinator (asserted against `coordinator::reference` by the
//!    integration suite).
//!
//! Each [`TrainerStep`] also carries its batch's embedding access stream
//! (`indices`, `[B, T, H]` row-major): the driver feeds the streams in
//! rank order to the checkpoint policy engine
//! (`policy::SavePolicy::on_step`), which is how the priority trackers
//! observe the concatenated multi-trainer access sequence.
//!
//! The step barrier is also where the driver acquires the PS control
//! plane's quiesce token ([`ShardedPs::quiesce`]) for checkpoint capture
//! and failure injection — every trainer is parked on its command
//! channel, so the token is free and no data-plane call is in flight.
//!
//! Trainer failures are real here: [`TrainerPool::kill_trainer`] joins
//! the worker thread (its dense replica is gone), and
//! [`TrainerPool::respawn_trainer`] brings a fresh one up — which re-joins
//! at the next step barrier with whatever dense params the driver hands
//! out (a survivor's replica under partial recovery, the checkpoint's
//! under full recovery). See `coordinator` for the recovery matrix.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Barrier};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, ensure, Result};

use crate::cluster::{PlanAccess, PlanArena, PsBackend, PsDataPlane, ShardedPs};
use crate::config::JobConfig;
use crate::data::{Batch, SyntheticDataset};
use crate::runtime::Runtime;

/// What one trainer hands back at the step barrier.
pub struct TrainerStep {
    pub rank: usize,
    /// mean BCE loss of this trainer's local batch
    pub loss: f32,
    /// locally updated dense params (host layout), pre-allreduce
    pub params: Vec<Vec<f32>>,
    /// the batch's embedding access stream [B, T, H] — the driver feeds it
    /// to the priority trackers in rank order
    pub indices: Vec<u32>,
    /// the batch's *deduplicated* access list (one entry per distinct
    /// `(table, row)` with its hit count), exported from the step's
    /// [`PlanArena`] so the driver's policy/tracker recording and dirty-row
    /// capture reuse the plan instead of re-scanning `indices`
    pub accesses: Vec<PlanAccess>,
}

enum TrainerCmd {
    /// run global step `step`, applying the sparse update at turnstile
    /// order `ticket`, starting from the broadcast dense `params`
    Step { step: u64, ticket: u64, params: Arc<Vec<Vec<f32>>> },
    Stop,
}

type StepReply = Result<TrainerStep, String>;

/// Upper bound on one trainer's step. The pool keeps a clone of the reply
/// sender (needed for respawns), so a worker that dies *without replying*
/// (a panic) would never close the channel — the timeout turns that
/// silent hang into an error. Generous: a real step is sub-second.
const STEP_TIMEOUT: Duration = Duration::from_secs(600);

struct TrainerHandle {
    tx: Sender<TrainerCmd>,
    join: JoinHandle<()>,
}

struct WorkerCtx<B: PsBackend> {
    rank: usize,
    cfg: JobConfig,
    shared: ShardedPs<B>,
    gather_barrier: Arc<Barrier>,
    rx: Receiver<TrainerCmd>,
    done: Sender<StepReply>,
}

fn worker_loop<B: PsBackend>(ctx: WorkerCtx<B>) {
    let WorkerCtx { rank, cfg, shared, gather_barrier, rx, done } = ctx;
    let n = cfg.cluster.n_trainers.max(1) as u64;
    let hotness = cfg.data.hotness;
    // the replica: this trainer's own executor + dataset view + reusable
    // step buffers (allocated once, not per step)
    let mut state = match Runtime::cpu()
        .and_then(|rt| rt.load_model(&cfg.artifacts_dir, &cfg.model.preset))
    {
        Ok(model) => {
            let m = &model.manifest;
            let dataset = SyntheticDataset::new(m.num_dense, &cfg.data);
            let batch_buf =
                Batch::zeros_hot(m.batch, m.num_dense, m.num_sparse, hotness);
            let emb_buf = vec![0.0f32; m.batch * m.num_sparse * m.emb_dim];
            // route-once batch plan + pooled scratch, reused across steps:
            // one index scan feeds the gather, the ordered applies, and the
            // policy access stream
            let arena = PlanArena::new();
            Ok((model, dataset, batch_buf, emb_buf, arena))
        }
        Err(e) => Err(format!("trainer {rank}: loading model replica: {e:#}")),
    };
    while let Ok(cmd) = rx.recv() {
        let (step, ticket, params) = match cmd {
            TrainerCmd::Step { step, ticket, params } => (step, ticket, params),
            TrainerCmd::Stop => break,
        };
        let reply = match state.as_mut() {
            Err(e) => {
                // keep the barrier/ticket protocol alive so the other
                // trainers don't deadlock, then surface the error
                gather_barrier.wait();
                shared.skip_ordered(ticket);
                Err(e.clone())
            }
            Ok((model, dataset, batch_buf, emb_buf, arena)) => {
                // Stateless-replica protocol: dense params arrive by
                // broadcast and leave by reply every step. The two host
                // conversions this costs (cheap next to the train step's
                // matmuls) buy trivially correct allreduce, rewind, and
                // trainer respawn — a replica never holds cross-step
                // state that recovery would have to reconstruct.
                let mut bufs = model.params_from_host(&params);
                // this trainer's stream shard: round-robin interleaved
                dataset.fill_train_batch(
                    (step * n + rank as u64) * model.manifest.batch as u64,
                    batch_buf,
                );
                crate::telemetry::observe("rows_per_step", batch_buf.indices.len() as u64);
                // build the step's route-once plan: dedup + routing +
                // touched nodes, computed in ONE scan of the index list and
                // shared by the gather, the ordered applies, and the access
                // stream reply (the unplanned path scanned it four times)
                {
                    let _p = crate::telemetry::span("gather_plan");
                    arena.build(
                        &batch_buf.indices,
                        hotness,
                        model.manifest.num_sparse,
                        shared.n_nodes(),
                    );
                }
                crate::telemetry::observe(
                    "unique_rows_per_step",
                    arena.plan().n_unique() as u64,
                );
                let (plan, scratch) = arena.parts_mut();
                shared.gather_planned(plan, scratch, emb_buf);
                // every replica must observe the PRE-step PS state: nobody
                // applies until everyone has gathered
                {
                    let _b = crate::telemetry::span("barrier_wait");
                    gather_barrier.wait();
                }
                let out = {
                    let _t = crate::telemetry::span("train_step");
                    model.train_step(
                        &batch_buf.dense,
                        emb_buf,
                        &batch_buf.labels,
                        cfg.train.lr,
                        &mut bufs,
                    )
                };
                // sharded rank-ordered sparse update → deterministic PS
                // floats without a global lock: same-node updates apply in
                // ticket order, node-disjoint updates in parallel
                match &out {
                    Ok(o) => shared.apply_grads_ordered_planned(
                        ticket,
                        plan,
                        scratch,
                        &o.emb_grad,
                        cfg.train.emb_lr,
                        cfg.train.emb_optimizer,
                    ),
                    Err(_) => shared.skip_ordered(ticket),
                }
                match out {
                    Ok(o) => match model.params_to_host(&bufs) {
                        Ok(host) => Ok(TrainerStep {
                            rank,
                            loss: o.loss,
                            params: host,
                            indices: batch_buf.indices.clone(),
                            accesses: plan.collect_accesses(),
                        }),
                        Err(e) => Err(format!("trainer {rank}: params_to_host: {e:#}")),
                    },
                    Err(e) => Err(format!("trainer {rank}: train_step: {e:#}")),
                }
            }
        };
        if done.send(reply).is_err() {
            break; // driver went away
        }
    }
    // hand any buffered spans to the journal before the thread exits, so
    // an export after pool.stop() sees every trainer's records
    crate::telemetry::flush_thread();
}

/// N trainer worker threads behind a step/reply protocol (see module
/// docs). The driver broadcasts one global step at a time and blocks for
/// all N replies — the natural quiesce point for checkpoint capture and
/// failure injection.
pub struct TrainerPool<B: PsBackend + 'static> {
    cfg: JobConfig,
    shared: ShardedPs<B>,
    gather_barrier: Arc<Barrier>,
    /// `None` = the trainer is dead (killed, not yet respawned)
    workers: Vec<Option<TrainerHandle>>,
    done_tx: Sender<StepReply>,
    done_rx: Receiver<StepReply>,
    next_ticket: u64,
    kills: u64,
    respawns: u64,
    /// a step timed out: some worker is presumed dead/stuck (likely at
    /// the gather barrier) — joining on stop() would hang forever, so
    /// the pool detaches instead
    wedged: bool,
}

impl<B: PsBackend + 'static> TrainerPool<B> {
    pub fn new(cfg: &JobConfig, shared: ShardedPs<B>) -> Self {
        let n = cfg.cluster.n_trainers.max(1);
        let (done_tx, done_rx) = mpsc::channel();
        let mut pool = Self {
            cfg: cfg.clone(),
            shared,
            gather_barrier: Arc::new(Barrier::new(n)),
            workers: (0..n).map(|_| None).collect(),
            done_tx,
            done_rx,
            next_ticket: 0,
            kills: 0,
            respawns: 0,
            wedged: false,
        };
        for rank in 0..n {
            let w = pool.spawn(rank);
            pool.workers[rank] = Some(w);
        }
        pool
    }

    fn spawn(&self, rank: usize) -> TrainerHandle {
        let (tx, rx) = mpsc::channel();
        let ctx = WorkerCtx {
            rank,
            cfg: self.cfg.clone(),
            shared: self.shared.clone(),
            gather_barrier: Arc::clone(&self.gather_barrier),
            rx,
            done: self.done_tx.clone(),
        };
        let join = std::thread::Builder::new()
            .name(format!("trainer-{rank}"))
            .spawn(move || worker_loop(ctx))
            .expect("spawning trainer worker");
        TrainerHandle { tx, join }
    }

    pub fn n_trainers(&self) -> usize {
        self.workers.len()
    }

    pub fn alive(&self, rank: usize) -> bool {
        self.workers[rank].is_some()
    }

    /// Trainer-loss failures injected so far.
    pub fn kills(&self) -> u64 {
        self.kills
    }

    pub fn respawns(&self) -> u64 {
        self.respawns
    }

    /// Run one global data-parallel step from the broadcast dense params.
    /// Blocks until every trainer has gathered, computed, and applied its
    /// sparse update; returns the per-trainer results sorted by rank.
    /// Every trainer must be alive (respawn after a kill before stepping).
    pub fn step(&mut self, step: u64, params: Arc<Vec<Vec<f32>>>) -> Result<Vec<TrainerStep>> {
        ensure!(
            self.workers.iter().all(Option::is_some),
            "cannot step: a trainer is dead (respawn it first)"
        );
        let n = self.workers.len();
        for (rank, w) in self.workers.iter().enumerate() {
            let w = w.as_ref().unwrap();
            w.tx.send(TrainerCmd::Step {
                step,
                ticket: self.next_ticket + rank as u64,
                params: Arc::clone(&params),
            })
            .map_err(|_| anyhow!("trainer {rank} hung up"))?;
        }
        self.next_ticket += n as u64;
        // collect ALL n replies before propagating any error — a partial
        // drain would leave this step's remaining replies queued and
        // mis-pair them with the next step's results
        let mut out = Vec::with_capacity(n);
        let mut first_err: Option<String> = None;
        for _ in 0..n {
            match self.done_rx.recv_timeout(STEP_TIMEOUT) {
                Ok(Ok(step_result)) => out.push(step_result),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e); // keep the first error only
                    }
                }
                Err(_) => {
                    // timeout (a worker died without replying — likely a
                    // panic) or a closed channel: no more replies coming.
                    // Survivors may be stuck at the gather barrier or a
                    // node turnstile, so mark the pool wedged — stop()
                    // must not join them.
                    self.wedged = true;
                    if first_err.is_none() {
                        first_err = Some(format!(
                            "trainer step produced no reply within {STEP_TIMEOUT:?} \
                             (worker thread died?)"
                        ));
                    }
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            return Err(anyhow!(e));
        }
        out.sort_by_key(|r| r.rank);
        Ok(out)
    }

    /// A trainer-loss failure event: the worker thread really exits and is
    /// joined; its dense replica is gone.
    pub fn kill_trainer(&mut self, rank: usize) {
        self.kills += 1;
        if let Some(w) = self.workers[rank].take() {
            let _ = w.tx.send(TrainerCmd::Stop);
            let _ = w.join.join();
        }
    }

    /// Bring a fresh replacement up; it re-joins at the next step barrier
    /// with whatever dense params the driver broadcasts.
    pub fn respawn_trainer(&mut self, rank: usize) {
        assert!(self.workers[rank].is_none(), "trainer {rank} is already alive");
        self.respawns += 1;
        self.workers[rank] = Some(self.spawn(rank));
    }

    /// Join every worker (end of training). If a step previously timed
    /// out, surviving workers may be blocked forever at the gather
    /// barrier — then the pool detaches them (the process will reap the
    /// threads) instead of hanging in `join`.
    pub fn stop(&mut self) {
        let wedged = self.wedged;
        for w in self.workers.iter_mut().filter_map(Option::take) {
            let _ = w.tx.send(TrainerCmd::Stop);
            if !wedged {
                let _ = w.join.join();
            }
        }
    }
}

impl<B: PsBackend + 'static> Drop for TrainerPool<B> {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::embedding::{PsCluster, TableInfo};

    fn small_cfg(n_trainers: usize) -> JobConfig {
        let mut cfg = preset("mini").unwrap();
        cfg.cluster.n_trainers = n_trainers;
        cfg.data.train_samples = 128 * 8;
        cfg.data.eval_samples = 128;
        cfg
    }

    fn shared_for(cfg: &JobConfig) -> ShardedPs<PsCluster> {
        let tables: Vec<TableInfo> = cfg
            .data
            .table_rows
            .iter()
            .map(|&rows| TableInfo { rows, dim: cfg.model.emb_dim })
            .collect();
        ShardedPs::new(PsCluster::new(tables, cfg.cluster.n_emb_ps, cfg.data.seed ^ 0xEB))
    }

    fn init_host(cfg: &JobConfig) -> Vec<Vec<f32>> {
        let rt = Runtime::cpu().unwrap();
        let model = rt.load_model(&cfg.artifacts_dir, &cfg.model.preset).unwrap();
        model.params_to_host(&model.init_params(cfg.train.seed)).unwrap()
    }

    #[test]
    fn pool_runs_a_step_on_every_rank() {
        let cfg = small_cfg(2);
        let shared = shared_for(&cfg);
        let mut pool = TrainerPool::new(&cfg, shared.clone());
        let results = pool.step(0, Arc::new(init_host(&cfg))).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!((results[0].rank, results[1].rank), (0, 1));
        assert!(results.iter().all(|r| r.loss.is_finite()));
        assert!(results.iter().all(|r| !r.params.is_empty()));
        // both trainers issued a gather and applied their sparse update
        let stats = shared.stats();
        assert_eq!((stats.gathers, stats.applies), (2, 2));
        pool.stop();
    }

    #[test]
    fn kill_and_respawn_keep_the_pool_stepping() {
        let cfg = small_cfg(2);
        let shared = shared_for(&cfg);
        let mut pool = TrainerPool::new(&cfg, shared);
        let host = init_host(&cfg);
        pool.step(0, Arc::new(host.clone())).unwrap();
        pool.kill_trainer(1);
        assert!(!pool.alive(1));
        pool.respawn_trainer(1);
        assert!(pool.alive(1));
        let r2 = pool.step(1, Arc::new(host)).unwrap();
        assert_eq!(r2.len(), 2);
        assert_eq!((pool.kills(), pool.respawns()), (1, 1));
        pool.stop();
    }

    #[test]
    fn stepping_with_a_dead_trainer_errors() {
        let cfg = small_cfg(2);
        let shared = shared_for(&cfg);
        let mut pool = TrainerPool::new(&cfg, shared);
        pool.kill_trainer(0);
        let err = pool.step(0, Arc::new(init_host(&cfg)));
        assert!(err.is_err(), "step with a dead trainer must fail");
        pool.respawn_trainer(0);
        pool.stop();
    }
}
