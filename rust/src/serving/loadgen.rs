//! Open-loop Zipfian load generator over a [`PsServePlane`].
//!
//! Each of the M client threads owns a fixed request schedule: client c's
//! k-th request is *intended* at `anchor + k * (clients / qps)` seconds.
//! The client waits for the intended time (sleep down to ~1 ms out, then
//! spin), issues one single-sample `serve_gather`, and records
//! `completion - intended` as the latency — the coordinated-omission-safe
//! definition: when the serving plane stalls (e.g. a reader briefly
//! retries behind a hot writer), requests queue up behind their intended
//! times and every queued request's delay lands in the histogram, instead
//! of the generator quietly re-anchoring and hiding the stall.
//!
//! Clients record into thread-local per-regime histograms (no shared
//! state on the request path beyond the backend itself) and the results
//! are merged once at [`LoadGen::stop`]. Per-request telemetry goes to
//! the existing registry: `serve_gather{node=N}` latency histograms and
//! the `serve_nodedown` counter (both no-ops when telemetry is off).
//!
//! Quiesce contract: the generator itself is strictly read-only
//! (`serve_gather` only — lock-free, no [`crate::cluster::PsQuiesce`]
//! needed); the one control-plane call in this module is a unit test
//! killing a node to assert dead-node requests are classified as
//! `NodeDown`, on a cluster that test owns exclusively.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cluster::{PsServePlane, ServeError};
use crate::embedding::TableInfo;
use crate::telemetry;
use crate::telemetry::hist::Histogram;
use crate::util::dist::Zipf;
use crate::util::rng::Rng;

use super::{Regime, RegimeLatency, ServeReport};

/// One client's thread-local results, merged at stop.
struct ClientStats {
    hists: [Histogram; 3],
    node_down: [u64; 3],
}

/// Running load generator; create with [`LoadGen::start`], flip regimes
/// with [`LoadGen::set_regime`], and collect the [`ServeReport`] with
/// [`LoadGen::stop`].
pub struct LoadGen {
    stop: Arc<AtomicBool>,
    regime: Arc<AtomicUsize>,
    clients: Vec<JoinHandle<ClientStats>>,
    anchor: Instant,
    target_qps: f64,
    zipf_s: f64,
}

impl LoadGen {
    /// Spawn `clients` worker threads driving `backend` at an aggregate
    /// `qps` with Zipf(`zipf_s`) key popularity over each table's rows.
    ///
    /// Key ranks map directly to row ids (rank 0 → row 0), so the hottest
    /// keys concentrate on the low node ids under the fixed `r % n`
    /// routing — a deliberate skew: it makes the contention experiments
    /// show a *hot node*, which is the hard case for the serving plane.
    pub fn start(
        backend: Arc<dyn PsServePlane>,
        tables: Vec<TableInfo>,
        n_nodes: usize,
        qps: f64,
        clients: usize,
        zipf_s: f64,
        seed: u64,
    ) -> Self {
        assert!(qps > 0.0, "serving qps must be positive");
        assert!(clients >= 1, "serving needs at least one client");
        let stop = Arc::new(AtomicBool::new(false));
        let regime = Arc::new(AtomicUsize::new(Regime::Steady as usize));
        let anchor = Instant::now();
        let interval_s = clients as f64 / qps;
        let handles = (0..clients)
            .map(|c| {
                let backend = Arc::clone(&backend);
                let tables = tables.clone();
                let stop = Arc::clone(&stop);
                let regime = Arc::clone(&regime);
                std::thread::Builder::new()
                    .name(format!("serve-client-{c}"))
                    .spawn(move || {
                        client_loop(
                            &*backend,
                            &tables,
                            n_nodes,
                            anchor,
                            interval_s,
                            zipf_s,
                            seed ^ (0x5E11 + c as u64),
                            &stop,
                            &regime,
                        )
                    })
                    .expect("spawning serving client")
            })
            .collect();
        Self {
            stop,
            regime,
            clients: handles,
            anchor,
            target_qps: qps,
            zipf_s,
        }
    }

    /// Tag subsequent requests with `regime` (monotonic flag flip; an
    /// in-flight request keeps the regime it started under).
    pub fn set_regime(&self, regime: Regime) {
        self.regime.store(regime as usize, Ordering::Release);
    }

    /// Stop the clients, merge their histograms, and summarize.
    pub fn stop(self) -> ServeReport {
        self.stop.store(true, Ordering::Release);
        let wall_secs = self.anchor.elapsed().as_secs_f64();
        let n_clients = self.clients.len();
        let mut hists: [Histogram; 3] = std::array::from_fn(|_| Histogram::default());
        let mut node_down = [0u64; 3];
        for h in self.clients {
            let stats = h.join().expect("serving client panicked");
            for (i, hist) in stats.hists.iter().enumerate() {
                hists[i].merge(hist);
                node_down[i] += stats.node_down[i];
            }
        }
        let regimes: Vec<RegimeLatency> = Regime::ALL
            .iter()
            .enumerate()
            .map(|(i, &r)| RegimeLatency {
                regime: r.name().to_string(),
                requests: hists[i].count(),
                node_down: node_down[i],
                p50_us: hists[i].quantile(0.50),
                p95_us: hists[i].quantile(0.95),
                p99_us: hists[i].quantile(0.99),
                p999_us: hists[i].quantile(0.999),
                mean_us: hists[i].mean(),
                max_us: hists[i].max(),
            })
            .collect();
        let total_requests: u64 = regimes.iter().map(|r| r.requests).sum();
        let total_node_down: u64 = regimes.iter().map(|r| r.node_down).sum();
        ServeReport {
            target_qps: self.target_qps,
            clients: n_clients,
            zipf_s: self.zipf_s,
            wall_secs,
            total_requests,
            total_node_down,
            achieved_qps: if wall_secs > 0.0 {
                total_requests as f64 / wall_secs
            } else {
                0.0
            },
            regimes,
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn client_loop(
    backend: &dyn PsServePlane,
    tables: &[TableInfo],
    n_nodes: usize,
    anchor: Instant,
    interval_s: f64,
    zipf_s: f64,
    seed: u64,
    stop: &AtomicBool,
    regime: &AtomicUsize,
) -> ClientStats {
    let t = tables.len();
    let dim = tables[0].dim;
    let mut rng = Rng::new(seed);
    let zipfs: Vec<Zipf> = tables.iter().map(|info| Zipf::new(info.rows, zipf_s)).collect();
    let mut stats = ClientStats {
        hists: std::array::from_fn(|_| Histogram::default()),
        node_down: [0u64; 3],
    };
    let mut indices = vec![0u32; t];
    let mut out = vec![0.0f32; t * dim];
    let mut k = 0u64;
    loop {
        // open-loop wait for the request's intended time; never
        // re-anchored, so a stalled backend accumulates queued requests
        // whose full delay is charged to the latency below
        let intended_s = k as f64 * interval_s;
        loop {
            if stop.load(Ordering::Acquire) {
                return stats;
            }
            let now_s = anchor.elapsed().as_secs_f64();
            if now_s >= intended_s {
                break;
            }
            let remaining = intended_s - now_s;
            if remaining > 0.001 {
                // sleep most of it, spin the last stretch (sleep wakes
                // late by scheduler quanta; the spin keeps the schedule)
                std::thread::sleep(Duration::from_secs_f64(remaining - 0.0005));
            } else {
                std::hint::spin_loop();
            }
        }
        for (tab, z) in zipfs.iter().enumerate() {
            indices[tab] = z.sample(&mut rng) as u32;
        }
        let reg = regime.load(Ordering::Acquire).min(2);
        let result = backend.serve_gather(&indices, &mut out);
        // coordinated-omission-safe latency: completion minus *intended*
        let latency_s = anchor.elapsed().as_secs_f64() - intended_s;
        let latency_us = (latency_s * 1e6).max(0.0) as u64;
        match result {
            Ok(()) => {
                stats.hists[reg].observe(latency_us);
                // per-node attribution keyed on the first table's owner
                let node = indices[0] as usize % n_nodes;
                telemetry::observe_node("serve_gather", node, latency_us);
            }
            Err(ServeError::NodeDown { .. }) => {
                stats.node_down[reg] += 1;
                telemetry::counter_add("serve_nodedown", 1);
            }
        }
        k += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::PsCluster;

    const TABLES: [TableInfo; 2] =
        [TableInfo { rows: 40, dim: 4 }, TableInfo { rows: 17, dim: 4 }];

    fn run_for(
        cluster: Arc<PsCluster>,
        millis: u64,
        qps: f64,
        clients: usize,
    ) -> ServeReport {
        let lg = LoadGen::start(cluster, TABLES.to_vec(), 3, qps, clients, 1.1, 7);
        std::thread::sleep(Duration::from_millis(millis));
        lg.stop()
    }

    #[test]
    fn loadgen_hits_roughly_the_target_qps() {
        let cluster = Arc::new(PsCluster::new(TABLES.to_vec(), 3, 7));
        let report = run_for(cluster, 200, 2_000.0, 2);
        assert!(report.total_requests > 50,
                "too few requests: {}", report.total_requests);
        assert_eq!(report.total_node_down, 0);
        assert_eq!(report.clients, 2);
        let steady = report.regime("steady").unwrap();
        assert_eq!(steady.requests, report.total_requests,
                   "all traffic should be steady-regime");
        assert!(steady.p999_us >= steady.p50_us);
        // open loop at 2k qps for 200 ms ≈ 400 intended requests; allow a
        // generous band for CI-runner jitter
        assert!(report.achieved_qps > 200.0,
                "achieved {} qps", report.achieved_qps);
    }

    #[test]
    fn regime_flips_bucket_traffic_separately() {
        let cluster = Arc::new(PsCluster::new(TABLES.to_vec(), 3, 7));
        let lg = LoadGen::start(cluster, TABLES.to_vec(), 3, 2_000.0, 2, 1.1, 9);
        std::thread::sleep(Duration::from_millis(80));
        lg.set_regime(Regime::Capture);
        std::thread::sleep(Duration::from_millis(80));
        lg.set_regime(Regime::Recovery);
        std::thread::sleep(Duration::from_millis(80));
        let report = lg.stop();
        for name in ["steady", "capture", "recovery"] {
            let r = report.regime(name).unwrap();
            assert!(r.requests > 0, "regime {name} saw no traffic");
        }
        let sum: u64 = report.regimes.iter().map(|r| r.requests).sum();
        assert_eq!(sum, report.total_requests);
    }

    #[test]
    fn dead_node_requests_count_as_node_down_not_latency() {
        let cluster = Arc::new(PsCluster::new(TABLES.to_vec(), 2, 7));
        cluster.kill_node(0);
        // rank→row mapping means row 0 (node 0) is the hottest key, so a
        // short run is guaranteed to hit the dead node
        let lg = LoadGen::start(cluster, TABLES.to_vec(), 2, 2_000.0, 2, 1.1, 11);
        std::thread::sleep(Duration::from_millis(150));
        let report = lg.stop();
        assert!(report.total_node_down > 0, "dead node never surfaced");
        // live-node traffic still completed
        assert!(report.total_requests > 0, "survivors saw no traffic");
    }
}
