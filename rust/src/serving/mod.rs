//! Online serving plane: the open-loop load generator and its report.
//!
//! CPR's setting is a production recommendation model that keeps
//! *serving* while it trains and while nodes fail (paper §1; Check-N-Run
//! makes the same coupling explicit — checkpoints exist to feed the
//! online model). This module drives the read-only
//! [`crate::cluster::PsServePlane`] the way an inference tier would:
//! `clients` closed worker threads issue single-sample gathers with
//! Zipfian key popularity against a fixed open-loop schedule at a target
//! aggregate QPS, and latency is measured **coordinated-omission-safe**
//! (from each request's *intended* send time, never re-anchored when the
//! generator falls behind), so a serving stall shows up in the tail
//! instead of silently thinning the load.
//!
//! Requests are bucketed into the three regimes the paper cares about —
//! steady training, during checkpoint capture, and across a node failure
//! + partial recovery — via a regime flag the coordinator flips around
//! its save and failure blocks. Dead nodes surface as typed
//! [`crate::cluster::ServeError::NodeDown`] counts per regime, never as
//! a hang.

pub mod loadgen;

pub use loadgen::LoadGen;

/// Which phase of the training run a serving request landed in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// normal training steps
    Steady = 0,
    /// a checkpoint capture is in progress (quiesce held by the saver)
    Capture = 1,
    /// a failure was injected and partial recovery is running
    Recovery = 2,
}

impl Regime {
    pub const ALL: [Regime; 3] = [Regime::Steady, Regime::Capture, Regime::Recovery];

    pub fn name(self) -> &'static str {
        match self {
            Regime::Steady => "steady",
            Regime::Capture => "capture",
            Regime::Recovery => "recovery",
        }
    }
}

/// Latency summary of one regime's serving traffic (all times in
/// microseconds of coordinated-omission-safe latency).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RegimeLatency {
    /// regime name ("steady" | "capture" | "recovery")
    pub regime: String,
    /// completed requests recorded in this regime
    pub requests: u64,
    /// requests refused with `ServeError::NodeDown` in this regime
    pub node_down: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub mean_us: f64,
    pub max_us: u64,
}

/// End-of-run summary of the serving load generator, attached to the
/// coordinator's `TrainReport` when the `[serving]` block is enabled.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeReport {
    /// configured aggregate target QPS across all clients
    pub target_qps: f64,
    /// number of closed serving worker threads
    pub clients: usize,
    /// Zipf skew parameter of the key popularity distribution
    pub zipf_s: f64,
    /// wall-clock seconds the generator ran
    pub wall_secs: f64,
    /// completed requests across all regimes
    pub total_requests: u64,
    /// requests refused with `ServeError::NodeDown` across all regimes
    pub total_node_down: u64,
    /// completed requests / wall_secs
    pub achieved_qps: f64,
    /// per-regime latency tables, in [`Regime::ALL`] order (regimes with
    /// zero traffic report zeroed quantiles)
    pub regimes: Vec<RegimeLatency>,
}

impl ServeReport {
    /// The regime row by name, for tests and report printing.
    pub fn regime(&self, name: &str) -> Option<&RegimeLatency> {
        self.regimes.iter().find(|r| r.regime == name)
    }
}
