//! [`SeqLock`] + [`AtomicF32s`] — the serving plane's guard-free read
//! protocol over racing embedding storage, with **no data-race UB**.
//!
//! PR 8 implemented this protocol inside `embedding/mod.rs` with
//! `ptr::read_volatile` over `&`-reachable floats. That is *observably*
//! correct (the sequence validation discards every torn copy) but it is
//! still a data race — and therefore undefined behavior — under the Rust
//! memory model: volatile is an I/O primitive, not a synchronization
//! primitive, and Miri/TSan rightly flag it. This module fixes the class
//! at its root:
//!
//! * the racing payload is [`AtomicU32`]-per-word ([`AtomicF32s`],
//!   bitcast to/from `f32` — exact, `to_bits`/`from_bits` round-trip
//!   every bit pattern including NaNs), so concurrent reads and writes
//!   are *defined* (relaxed atomics), and
//! * the [`SeqLock`] sequence protocol orders them: a reader's copy only
//!   escapes when two loads of the sequence counter bracket it with the
//!   same even value, with the writer's `Release` bump ordering the word
//!   stores against the counter.
//!
//! The protocol itself is bit-for-bit the PR 8 one (same parity-safe
//! bump, same spin budget, same `NodeDown` semantics):
//!
//! * **writer** (already mutually excluded by the node's write guard):
//!   [`SeqLock::write_begin`] makes the counter odd — `s + 1` from even,
//!   `s + 2` from odd, so a counter left odd by a writer that *panicked*
//!   mid-update still CHANGES and no stale reader can ever validate
//!   against the new epoch — then [`SeqLock::write_end`] republishes an
//!   even value with `Release` ordering;
//! * **reader**: [`SeqLock::read`] snapshots the payload between two
//!   counter loads, retries on any mismatch or odd value, and converts a
//!   stuck-odd counter (dead writer) or a cleared liveness flag into a
//!   typed [`SeqLockDown`] after each [`SPIN_CHECK_INTERVAL`] retries
//!   instead of spinning forever.
//!
//! Model coverage: the interleaving-level properties (no torn read ever
//! escapes; stuck-odd always yields `SeqLockDown`) are exhaustively
//! checked by `cluster::models::seqlock` under `--features loom`; the
//! memory-ordering level (these fences, on the real code) is covered by
//! the Miri and TSan CI lanes. This file contains **zero** `unsafe`.

use std::sync::atomic::{fence, AtomicBool, AtomicU32, AtomicU64, Ordering};

/// Retries between dead-node polls in [`SeqLock::read`]: the reader
/// spin-waits this many attempts on the fast path before paying the
/// (mutex-guarded) `is_dead` check and a scheduler yield.
pub const SPIN_CHECK_INTERVAL: u64 = 128;

/// Typed failure of [`SeqLock::read`]: the instance was (or became) dead
/// — killed via [`SeqLock::set_alive`] or stuck odd with the caller's
/// `dead` probe confirming the writer died. The cluster layer maps this
/// to `ServeError::NodeDown`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeqLockDown;

/// Sequence counter + liveness flag for one seqlock-protected node.
///
/// The payload is *not* owned by the lock: callers pair one `SeqLock`
/// with whatever [`AtomicF32s`] (or other always-shareable) storage the
/// epoch protects, which is what lets one per-node `SeqLock` cover every
/// table shard of that node.
#[derive(Debug)]
pub struct SeqLock {
    seq: AtomicU64,
    /// `false` between an injected kill and the matching respawn. A
    /// writer *panic* does not clear this (nobody is left to), which is
    /// why [`SeqLock::read`] also polls the caller's `dead` probe once
    /// its spin budget runs out.
    alive: AtomicBool,
}

impl Default for SeqLock {
    fn default() -> Self {
        Self::new()
    }
}

impl SeqLock {
    /// A live lock at sequence 0 (even: readers may validate immediately).
    pub fn new() -> Self {
        Self { seq: AtomicU64::new(0), alive: AtomicBool::new(true) }
    }

    /// Writer entry. The caller must hold whatever exclusion serializes
    /// writers (the node's write guard, or dead-node exclusivity during
    /// revive) — writers are mutually excluded, so a plain load/store
    /// pair is enough.
    #[inline]
    pub fn write_begin(&self) {
        let s = self.seq.load(Ordering::Relaxed);
        // s even (normal) → s+1, odd; s odd (residue of a writer that
        // panicked mid-update and never reached `write_end`) → s+2:
        // still odd but CHANGED, so a reader that snapshotted before the
        // death can never validate against the new epoch.
        self.seq.store(s.wrapping_add(1 + (s & 1)), Ordering::Relaxed);
        fence(Ordering::Release);
    }

    /// Writer exit: republish an even sequence. Not reached when the
    /// writer panics — the residue case `write_begin` and the reader's
    /// dead-probe fallback handle.
    #[inline]
    pub fn write_end(&self) {
        let s = self.seq.load(Ordering::Relaxed);
        self.seq.store(s.wrapping_add(1), Ordering::Release);
    }

    /// Flip the fast-path liveness flag (kill: `false`, respawn: `true`).
    #[inline]
    pub fn set_alive(&self, alive: bool) {
        self.alive.store(alive, Ordering::Release);
    }

    #[inline]
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }

    /// Current raw sequence value (tests/diagnostics only).
    pub fn raw_seq(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// One validated read: run `copy` (which must re-read the protected
    /// payload into caller storage each call — it may run several times,
    /// and all but the last run may observe torn state, which is fine
    /// *because the payload is atomic* and the result is discarded) until
    /// a pass is bracketed by two identical even sequence values. Returns
    /// the retries paid, or [`SeqLockDown`] once the lock is not alive or
    /// the caller's `dead` probe reports the writer gone while the
    /// sequence is unvalidatable.
    pub fn read(
        &self,
        mut copy: impl FnMut(),
        dead: impl Fn() -> bool,
    ) -> Result<u64, SeqLockDown> {
        if !self.is_alive() {
            return Err(SeqLockDown);
        }
        let mut retries = 0u64;
        loop {
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 & 1 == 0 {
                copy();
                fence(Ordering::Acquire);
                if self.seq.load(Ordering::Relaxed) == s1 {
                    return Ok(retries);
                }
            }
            retries += 1;
            if retries % SPIN_CHECK_INTERVAL == 0 {
                // Spin budget exhausted: either a writer died mid-update
                // (sequence stuck odd, node poisoned → dead) or the node
                // was killed between our liveness check and now. Surface
                // the typed error rather than spinning forever.
                if dead() || !self.is_alive() {
                    return Err(SeqLockDown);
                }
                std::thread::yield_now();
            } else {
                std::hint::spin_loop();
            }
        }
    }
}

/// A fixed-length `f32` buffer whose every word is an [`AtomicU32`]
/// (bitcast with `to_bits`/`from_bits`, which round-trips every bit
/// pattern exactly — goldens stay bit-identical).
///
/// All accesses are `Relaxed`: this type provides race-*freedom*, not
/// ordering. Callers get consistency either from a surrounding
/// [`SeqLock`] epoch (serving reads) or from lock acquire/release edges
/// (data-plane reads under a `NodeLock` guard happen-after the writer's
/// guard release).
///
/// The buffer never reallocates — only interior stores — so in-flight
/// guard-free readers stay valid across `load/reset/respawn` refills,
/// which is the pointer-stability contract `NodeLock::revive_with`
/// used to carry for the volatile path.
#[derive(Debug)]
pub struct AtomicF32s {
    words: Box<[AtomicU32]>,
}

impl AtomicF32s {
    /// An atomic copy of `src`.
    pub fn from_f32s(src: &[f32]) -> Self {
        Self { words: src.iter().map(|v| AtomicU32::new(v.to_bits())).collect() }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        f32::from_bits(self.words[i].load(Ordering::Relaxed))
    }

    #[inline]
    pub fn set(&self, i: usize, v: f32) {
        self.words[i].store(v.to_bits(), Ordering::Relaxed);
    }

    /// Copy `dst.len()` words starting at `offset` into `dst`. Panics if
    /// the range is out of bounds (same contract as slice indexing — the
    /// cluster's OOB-row poison tests rely on it).
    #[inline]
    pub fn load_into(&self, offset: usize, dst: &mut [f32]) {
        let words = &self.words[offset..offset + dst.len()];
        for (d, w) in dst.iter_mut().zip(words) {
            *d = f32::from_bits(w.load(Ordering::Relaxed));
        }
    }

    /// `dst[i] += self[offset + i]` — the sum-pooling accumulate step.
    #[inline]
    pub fn add_into(&self, offset: usize, dst: &mut [f32]) {
        let words = &self.words[offset..offset + dst.len()];
        for (d, w) in dst.iter_mut().zip(words) {
            *d += f32::from_bits(w.load(Ordering::Relaxed));
        }
    }

    /// Store `src` into the words starting at `offset`. Panics on OOB.
    #[inline]
    pub fn store_from(&self, offset: usize, src: &[f32]) {
        let words = &self.words[offset..offset + src.len()];
        for (w, v) in words.iter().zip(src) {
            w.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    /// Whole-buffer refill (load/reset/respawn paths). Panics unless
    /// `src.len()` matches exactly.
    pub fn copy_from(&self, src: &[f32]) {
        assert_eq!(src.len(), self.words.len(), "refill length mismatch");
        self.store_from(0, src);
    }

    /// Plain-`Vec` copy of the whole buffer (checkpoint/test inspection).
    pub fn to_vec(&self) -> Vec<f32> {
        self.words.iter().map(|w| f32::from_bits(w.load(Ordering::Relaxed))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn bitcast_roundtrip_is_exact() {
        let vals = [0.0f32, -0.0, 1.5, -3.25e-7, f32::MAX, f32::MIN_POSITIVE,
                    f32::INFINITY, f32::NEG_INFINITY, f32::NAN];
        let a = AtomicF32s::from_f32s(&vals);
        for (i, v) in vals.iter().enumerate() {
            assert_eq!(a.get(i).to_bits(), v.to_bits(), "word {i}");
        }
        let back = a.to_vec();
        for (b, v) in back.iter().zip(&vals) {
            assert_eq!(b.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn load_store_windows() {
        let a = AtomicF32s::from_f32s(&[0.0; 8]);
        a.store_from(2, &[1.0, 2.0, 3.0]);
        let mut out = [0.0f32; 3];
        a.load_into(2, &mut out);
        assert_eq!(out, [1.0, 2.0, 3.0]);
        let mut acc = [10.0f32; 3];
        a.add_into(2, &mut acc);
        assert_eq!(acc, [11.0, 12.0, 13.0]);
        assert_eq!(a.get(0), 0.0);
        a.set(0, 9.0);
        assert_eq!(a.to_vec(), vec![9.0, 0.0, 1.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn load_into_out_of_bounds_panics() {
        let a = AtomicF32s::from_f32s(&[0.0; 4]);
        let mut out = [0.0f32; 2];
        a.load_into(3, &mut out);
    }

    #[test]
    fn uncontended_read_validates_first_try() {
        let sl = SeqLock::new();
        let data = AtomicF32s::from_f32s(&[4.0, 5.0]);
        let mut out = [0.0f32; 2];
        let retries = sl.read(|| data.load_into(0, &mut out), || false).unwrap();
        assert_eq!(retries, 0);
        assert_eq!(out, [4.0, 5.0]);
    }

    #[test]
    fn write_epoch_forces_retry_then_validates() {
        let sl = SeqLock::new();
        // an in-progress write (odd seq) keeps the reader retrying;
        // closing the epoch lets the next pass validate
        sl.write_begin();
        assert_eq!(sl.raw_seq() & 1, 1);
        sl.write_end();
        assert_eq!(sl.raw_seq() & 1, 0);
        let mut copies = 0u32;
        let retries = sl.read(|| copies += 1, || false).unwrap();
        assert_eq!((retries, copies), (0, 1));
    }

    #[test]
    fn stuck_odd_sequence_reports_down_once_dead() {
        let sl = SeqLock::new();
        sl.write_begin(); // writer "dies" here: seq stuck odd
        let mut copies = 0u32;
        let err = sl.read(|| copies += 1, || true).unwrap_err();
        assert_eq!(err, SeqLockDown);
        assert_eq!(copies, 0, "no copy may escape an odd epoch");
    }

    #[test]
    fn begin_from_odd_still_changes_the_epoch() {
        let sl = SeqLock::new();
        sl.write_begin();
        let stuck = sl.raw_seq();
        sl.write_begin(); // parity-safe bump: +2 from odd
        assert_eq!(sl.raw_seq(), stuck + 2);
        assert_eq!(sl.raw_seq() & 1, 1);
        sl.write_end();
        assert_eq!(sl.raw_seq() & 1, 0);
    }

    #[test]
    fn not_alive_fails_fast() {
        let sl = SeqLock::new();
        sl.set_alive(false);
        assert!(!sl.is_alive());
        let err = sl.read(|| panic!("must not copy"), || false).unwrap_err();
        assert_eq!(err, SeqLockDown);
        sl.set_alive(true);
        assert!(sl.read(|| {}, || false).is_ok());
    }

    /// Concurrent hammer: sentinel-pattern writers vs readers — every
    /// escaped copy must be uniform. Also runs under the Miri CI lane
    /// (iterations shrunk there: interleaving exploration is loom's job,
    /// Miri's is the memory model).
    #[test]
    fn concurrent_reads_are_never_torn() {
        let writes: usize = if cfg!(miri) { 40 } else { 2_000 };
        let sl = Arc::new(SeqLock::new());
        let data = Arc::new(AtomicF32s::from_f32s(&[0.0; 8]));
        let stop = Arc::new(AtomicBool::new(false));
        std::thread::scope(|s| {
            {
                let (sl, data, stop) = (sl.clone(), data.clone(), stop.clone());
                s.spawn(move || {
                    for i in 1..=writes {
                        sl.write_begin();
                        data.copy_from(&[i as f32; 8]);
                        sl.write_end();
                    }
                    stop.store(true, Ordering::Release);
                });
            }
            for _ in 0..2 {
                let (sl, data, stop) = (sl.clone(), data.clone(), stop.clone());
                s.spawn(move || {
                    let mut out = [0.0f32; 8];
                    while !stop.load(Ordering::Acquire) {
                        sl.read(|| data.load_into(0, &mut out), || false).unwrap();
                        let first = out[0];
                        assert!(out.iter().all(|&v| v == first),
                                "torn read escaped validation: {out:?}");
                    }
                });
            }
        });
        assert_eq!(data.get(0), writes as f32);
    }
}
