//! [`ThreadedCluster`] — the concurrent Emb PS runtime.
//!
//! Every node is a worker thread owning its shards (per-table row slices +
//! optimizer accumulators), served over an mpsc request/reply channel. The
//! router (the [`PsDataPlane`] methods on [`ThreadedCluster`]) shards each
//! batched request by row ownership, fans the per-node slices out to all
//! live workers, and reassembles the replies **in slot order** so results
//! are bit-identical to the in-process backend regardless of which worker
//! answers first.
//!
//! The per-node channels *are* the data plane: every router method takes
//! `&self` (senders are cloned out of per-node slots), so N trainers can
//! drive the cluster concurrently with no global lock — requests to
//! different nodes land on different worker threads and proceed in
//! parallel; requests to the same node serialize in that node's queue.
//! A trainer panic cannot corrupt a worker (state never leaves the worker
//! thread). A panic *inside* a worker (e.g. a malformed request indexing
//! out of bounds) unwinds only that worker's thread: the wrapper in
//! [`ThreadedCluster::spawn`] raises the node's `panicked` flag as the
//! unwind escapes, which [`PsServePlane::serve_gather`] and `alive()`
//! convert to [`ServeError::NodeDown`] — the threaded backend's analogue
//! of the in-process backend's poison→KILL conversion.
//!
//! Failure injection is real here: [`super::PsControlPlane::kill_node`]
//! sends `Kill` and joins the worker — its state is gone, exactly like a
//! production PS node loss — while the other workers keep serving.
//! `respawn_node` brings up a blank replacement at deterministic init, and
//! the partial recovery protocol (coordinator + checkpoint pipeline)
//! restores its rows from the last checkpoint.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex, PoisonError, RwLock};
use std::thread::JoinHandle;

use super::plan::{BatchPlan, PlanScratch, PlannedReply};
use super::{
    init_node_state, route_row, NodeSnapshot, PsControlPlane, PsDataPlane,
    PsServePlane, ServeError, StatCounters,
};
use crate::embedding::{EmbOptimizer, TableInfo};

/// One routed gather slot: read `local` of `table`.
struct SlotReq {
    table: u32,
    local: u32,
}

/// One routed update: apply grad slice `grad_slot` to `local` of `table`.
struct UpdateReq {
    table: u32,
    local: u32,
    grad_slot: u32,
}

enum NodeMsg {
    Gather { reqs: Vec<SlotReq>, reply: Sender<(usize, Vec<f32>)> },
    Apply {
        reqs: Vec<UpdateReq>,
        grads: Arc<Vec<f32>>,
        lr: f32,
        opt: EmbOptimizer,
        ack: Sender<usize>,
    },
    /// Plan-driven gather: `reqs` are packed `(table << 32) | local` keys,
    /// each a *distinct* row (the plan deduplicated them), and `vals` is
    /// the caller's pooled value buffer — both travel back in the reply so
    /// the router returns them to its [`PlanScratch`] pool instead of
    /// allocating per call.
    GatherPlanned { reqs: Vec<u64>, vals: Vec<f32>, reply: Sender<PlannedReply> },
    /// Plan-driven apply: grad slice `i` applies to packed req `i`, in
    /// order (the router packed them in ascending flat-slot order, so
    /// duplicates accumulate in sample order — bit-identical to the
    /// filtered scan). Buffers travel back for pooling, doubling as the
    /// completion ack.
    ApplyPlanned {
        reqs: Vec<u64>,
        grads: Vec<f32>,
        lr: f32,
        opt: EmbOptimizer,
        reply: Sender<PlannedReply>,
    },
    ReadRows { table: u32, locals: Vec<u32>, reply: Sender<(usize, Vec<f32>, Vec<f32>)> },
    Snapshot { reply: Sender<NodeSnapshot> },
    /// shards-only clone for the serving view (no optimizer state)
    ServeView { reply: Sender<(usize, Vec<Vec<f32>>)> },
    Load { shards: Vec<Vec<f32>>, opt: Vec<Vec<f32>>, ack: Sender<()> },
    Reset { ack: Sender<()> },
    Kill,
}

struct Worker {
    tx: Sender<NodeMsg>,
    join: JoinHandle<()>,
}

/// Concurrent message-passing Emb PS cluster (see module docs).
pub struct ThreadedCluster {
    tables: Vec<TableInfo>,
    n_nodes: usize,
    seed: u64,
    /// per-node worker slot; `None` = the node is dead (killed, not yet
    /// respawned). Slots are independently locked so kill/respawn of one
    /// node never blocks routing to another.
    workers: Vec<Mutex<Option<Worker>>>,
    /// Published per-node serving views (shards only): serving readers
    /// clone the `Arc` under a briefly-held read lock and copy rows from
    /// the immutable snapshot — they never touch a worker channel, so
    /// they never queue behind trainer traffic or checkpoint ops. The
    /// coordinator republishes at the step barrier
    /// ([`PsServePlane::publish_serve_view`]); staleness is therefore
    /// bounded by one step. `None` = the node is dead ⇒
    /// [`ServeError::NodeDown`].
    serve_views: Vec<RwLock<Option<Arc<Vec<Vec<f32>>>>>>,
    /// Per-node worker-crash flags, raised by the worker thread itself as
    /// a panic unwinds off its loop (see [`Self::spawn`]). Serving checks
    /// the flag before trusting a published view and `alive()` folds it
    /// in, so a crashed worker reads as a dead node (`NodeDown`) instead
    /// of silently serving the stale last-published snapshot forever.
    /// Cleared by `respawn_node`.
    panicked: Vec<Arc<AtomicBool>>,
    stats: StatCounters,
}

fn worker_loop(
    node_id: usize,
    tables: Vec<TableInfo>,
    n_nodes: usize,
    seed: u64,
    rx: Receiver<NodeMsg>,
) {
    let (mut shards, mut opt_state) = init_node_state(&tables, n_nodes, node_id, seed);
    while let Ok(msg) = rx.recv() {
        match msg {
            NodeMsg::Gather { reqs, reply } => {
                let dim = tables[0].dim; // gather path: uniform dim
                let mut vals = vec![0.0f32; reqs.len() * dim];
                for (i, r) in reqs.iter().enumerate() {
                    let local = r.local as usize;
                    vals[i * dim..(i + 1) * dim].copy_from_slice(
                        &shards[r.table as usize][local * dim..(local + 1) * dim],
                    );
                }
                let _ = reply.send((node_id, vals));
            }
            NodeMsg::Apply { reqs, grads, lr, opt, ack } => {
                let dim = tables[0].dim;
                for r in &reqs {
                    let t = r.table as usize;
                    let local = r.local as usize;
                    let g = &grads[r.grad_slot as usize * dim..(r.grad_slot as usize + 1) * dim];
                    let dst = &mut shards[t][local * dim..(local + 1) * dim];
                    opt.apply(dst, g, &mut opt_state[t][local], lr);
                }
                let _ = ack.send(node_id);
            }
            NodeMsg::GatherPlanned { reqs, mut vals, reply } => {
                let dim = tables[0].dim; // gather path: uniform dim
                vals.clear();
                vals.resize(reqs.len() * dim, 0.0);
                for (i, &key) in reqs.iter().enumerate() {
                    let t = (key >> 32) as usize;
                    let local = (key & 0xFFFF_FFFF) as usize;
                    vals[i * dim..(i + 1) * dim]
                        .copy_from_slice(&shards[t][local * dim..(local + 1) * dim]);
                }
                let _ = reply.send((node_id, reqs, vals));
            }
            NodeMsg::ApplyPlanned { reqs, grads, lr, opt, reply } => {
                let dim = tables[0].dim;
                for (i, &key) in reqs.iter().enumerate() {
                    let t = (key >> 32) as usize;
                    let local = (key & 0xFFFF_FFFF) as usize;
                    let g = &grads[i * dim..(i + 1) * dim];
                    let dst = &mut shards[t][local * dim..(local + 1) * dim];
                    opt.apply(dst, g, &mut opt_state[t][local], lr);
                }
                let _ = reply.send((node_id, reqs, grads));
            }
            NodeMsg::ReadRows { table, locals, reply } => {
                let t = table as usize;
                let dim = tables[t].dim;
                let mut data = vec![0.0f32; locals.len() * dim];
                let mut acc = vec![0.0f32; locals.len()];
                for (i, &l) in locals.iter().enumerate() {
                    let l = l as usize;
                    data[i * dim..(i + 1) * dim]
                        .copy_from_slice(&shards[t][l * dim..(l + 1) * dim]);
                    acc[i] = opt_state[t][l];
                }
                let _ = reply.send((node_id, data, acc));
            }
            NodeMsg::Snapshot { reply } => {
                let _ = reply.send(NodeSnapshot {
                    node: node_id,
                    shards: shards.clone(),
                    opt: opt_state.clone(),
                });
            }
            NodeMsg::ServeView { reply } => {
                let _ = reply.send((node_id, shards.clone()));
            }
            NodeMsg::Load { shards: s, opt: o, ack } => {
                shards = s;
                opt_state = o;
                let _ = ack.send(());
            }
            NodeMsg::Reset { ack } => {
                let (s, o) = init_node_state(&tables, n_nodes, node_id, seed);
                shards = s;
                opt_state = o;
                let _ = ack.send(());
            }
            NodeMsg::Kill => break,
        }
    }
}

impl ThreadedCluster {
    pub fn new(tables: Vec<TableInfo>, n_nodes: usize, seed: u64) -> Self {
        assert!(n_nodes >= 1);
        let panicked: Vec<Arc<AtomicBool>> =
            (0..n_nodes).map(|_| Arc::new(AtomicBool::new(false))).collect();
        let workers = (0..n_nodes)
            .map(|node_id| {
                Mutex::new(Some(Self::spawn(
                    &tables,
                    n_nodes,
                    node_id,
                    seed,
                    Arc::clone(&panicked[node_id]),
                )))
            })
            .collect();
        let serve_views = (0..n_nodes)
            .map(|node_id| {
                let (shards, _) = init_node_state(&tables, n_nodes, node_id, seed);
                RwLock::new(Some(Arc::new(shards)))
            })
            .collect();
        Self {
            tables,
            n_nodes,
            seed,
            workers,
            serve_views,
            panicked,
            stats: StatCounters::default(),
        }
    }

    fn spawn(
        tables: &[TableInfo],
        n_nodes: usize,
        node_id: usize,
        seed: u64,
        panicked: Arc<AtomicBool>,
    ) -> Worker {
        let (tx, rx) = mpsc::channel();
        let tables = tables.to_vec();
        let join = std::thread::Builder::new()
            .name(format!("emb-ps-{node_id}"))
            .spawn(move || {
                // worker_loop owns only this node's state and channel ends,
                // all of which die with the thread, so observing them after
                // a caught unwind is fine (AssertUnwindSafe); the flag must
                // be raised BEFORE the unwind continues so serving can
                // never observe "thread gone, flag clear" — the Release
                // pairs with the Acquire loads in serve_gather/alive.
                let run = catch_unwind(AssertUnwindSafe(|| {
                    worker_loop(node_id, tables, n_nodes, seed, rx)
                }));
                if let Err(payload) = run {
                    panicked.store(true, Ordering::Release);
                    resume_unwind(payload);
                }
            })
            .expect("spawning Emb PS worker");
        Worker { tx, join }
    }

    fn slot(&self, node: usize) -> std::sync::MutexGuard<'_, Option<Worker>> {
        // the slot holds only channel handles; a poisoned slot mutex means
        // a router thread died mid-clone, which cannot corrupt the Option
        self.workers[node].lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn alive(&self, node: usize) -> bool {
        self.slot(node).is_some() && !self.panicked[node].load(Ordering::Acquire)
    }

    /// Clone the node's request sender (cheap: an `Arc` bump) so routing
    /// never holds the slot lock across a channel send.
    fn sender(&self, node: usize) -> Sender<NodeMsg> {
        match &*self.slot(node) {
            Some(w) => w.tx.clone(),
            None => panic!("Emb PS node {node} is dead (killed, not respawned)"),
        }
    }

    /// Swap one node's published serving view (`None` = dead).
    fn set_serve_view(&self, node: usize, view: Option<Arc<Vec<Vec<f32>>>>) {
        *self.serve_views[node]
            .write()
            .unwrap_or_else(PoisonError::into_inner) = view;
    }

    /// Republish a node's view at its deterministic init (respawn/reset
    /// paths — keeps the view in lockstep with the worker's state without
    /// a round-trip).
    fn set_serve_view_init(&self, node: usize) {
        let (shards, _) = init_node_state(&self.tables, self.n_nodes, node, self.seed);
        self.set_serve_view(node, Some(Arc::new(shards)));
    }
}

impl PsDataPlane for ThreadedCluster {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn tables(&self) -> &[TableInfo] {
        &self.tables
    }

    fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    fn counters(&self) -> &StatCounters {
        &self.stats
    }

    fn gather_pooled(&self, indices: &[u32], hotness: usize, out: &mut [f32]) {
        self.stats.bump_gather();
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        debug_assert!(self.tables.iter().all(|i| i.dim == dim));
        debug_assert_eq!(out.len() * hotness, indices.len() * dim);
        // route: per-node request lists + where each flat slot's value lands
        let mut per_node: Vec<Vec<SlotReq>> = (0..self.n_nodes).map(|_| Vec::new()).collect();
        let mut place: Vec<(u32, u32)> = Vec::with_capacity(indices.len());
        for (slot, &row) in indices.iter().enumerate() {
            let (node, local) = route_row(row as usize, self.n_nodes);
            place.push((node as u32, per_node[node].len() as u32));
            per_node[node].push(SlotReq {
                table: ((slot / hotness) % t) as u32,
                local: local as u32,
            });
        }
        // fan out to live nodes, collect replies (any order)
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut expected = 0usize;
        for (node, reqs) in per_node.into_iter().enumerate() {
            if reqs.is_empty() {
                continue;
            }
            expected += 1;
            self.sender(node)
                .send(NodeMsg::Gather { reqs, reply: reply_tx.clone() })
                .expect("Emb PS worker hung up");
        }
        drop(reply_tx);
        let mut replies: Vec<Vec<f32>> = (0..self.n_nodes).map(|_| Vec::new()).collect();
        for _ in 0..expected {
            let (node, vals) = reply_rx.recv().expect("Emb PS worker died mid-gather");
            replies[node] = vals;
        }
        // reassemble in slot order: identical pooling order to the
        // in-process backend (copy at h == 0, add for h = 1..H), so the
        // floats are bit-identical
        for (slot, &(node, off)) in place.iter().enumerate() {
            let src = &replies[node as usize][off as usize * dim..(off as usize + 1) * dim];
            let dst = &mut out[(slot / hotness) * dim..(slot / hotness + 1) * dim];
            if slot % hotness == 0 {
                dst.copy_from_slice(src);
            } else {
                for (d, v) in dst.iter_mut().zip(src) {
                    *d += v;
                }
            }
        }
    }

    /// Plan-driven pooled gather: ship each touched node one compact,
    /// *deduplicated* request message (packed `(table << 32) | local`
    /// keys) through the scratch's persistent reply channel, landing the
    /// replies directly in the pooled `unique_vals` buffer — no fresh
    /// channel, no per-node reply `Vec`s, no duplicate row shipping.
    /// Reassembly walks the plan's slot-placement map in ascending slot
    /// order, the exact pooling order of the unplanned path, so the
    /// output floats are bit-identical. Remaining steady-state
    /// allocations are mpsc queue blocks only (bounded; see DESIGN.md).
    fn gather_planned(&self, plan: &BatchPlan, scratch: &mut PlanScratch, out: &mut [f32]) {
        self.stats.bump_gather();
        self.stats.add_unique_rows(plan.n_unique() as u64);
        self.stats.add_dedup_hits(plan.dedup_hits() as u64);
        let dim = self.tables[0].dim;
        debug_assert!(self.tables.iter().all(|i| i.dim == dim));
        debug_assert_eq!(plan.n_nodes(), self.n_nodes);
        let hotness = plan.hotness();
        debug_assert_eq!(out.len() * hotness, plan.n_slots() * dim);
        scratch.ensure_nodes(self.n_nodes);
        scratch.unique_vals.resize(plan.n_unique() * dim, 0.0);
        let mut expected = 0usize;
        for node in 0..self.n_nodes {
            let range = plan.unique_range(node);
            if range.is_empty() {
                continue;
            }
            let (mut reqs, vals) = scratch.take_gather_bufs(node);
            for u in range {
                reqs.push(((plan.unique_table(u) as u64) << 32) | plan.unique_local(u) as u64);
            }
            self.sender(node)
                .send(NodeMsg::GatherPlanned { reqs, vals, reply: scratch.reply_sender() })
                .expect("Emb PS worker hung up");
            expected += 1;
        }
        for _ in 0..expected {
            let (node, reqs, vals) = scratch.recv_reply();
            let range = plan.unique_range(node);
            scratch.unique_vals[range.start * dim..range.end * dim].copy_from_slice(&vals);
            scratch.put_gather_bufs(node, reqs, vals);
        }
        for (slot, &u) in plan.slot_unique().iter().enumerate() {
            let u = u as usize;
            let src = &scratch.unique_vals[u * dim..(u + 1) * dim];
            let dst = &mut out[(slot / hotness) * dim..(slot / hotness + 1) * dim];
            if slot % hotness == 0 {
                dst.copy_from_slice(src);
            } else {
                for (d, v) in dst.iter_mut().zip(src) {
                    *d += v;
                }
            }
        }
    }

    fn apply_grads(
        &self,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        self.stats.bump_apply();
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        debug_assert_eq!(grads.len() * hotness, indices.len() * dim);
        // slot order is (sample, table, hot) ascending → each node applies
        // its updates in sample order, matching the in-process backend
        let mut per_node: Vec<Vec<UpdateReq>> = (0..self.n_nodes).map(|_| Vec::new()).collect();
        for (slot, &row) in indices.iter().enumerate() {
            let (node, local) = route_row(row as usize, self.n_nodes);
            per_node[node].push(UpdateReq {
                table: ((slot / hotness) % t) as u32,
                local: local as u32,
                grad_slot: (slot / hotness) as u32,
            });
        }
        let grads = Arc::new(grads.to_vec());
        let (ack_tx, ack_rx) = mpsc::channel();
        let mut expected = 0usize;
        for (node, reqs) in per_node.into_iter().enumerate() {
            if reqs.is_empty() {
                continue;
            }
            expected += 1;
            self.sender(node)
                .send(NodeMsg::Apply {
                    reqs,
                    grads: Arc::clone(&grads),
                    lr,
                    opt,
                    ack: ack_tx.clone(),
                })
                .expect("Emb PS worker hung up");
        }
        drop(ack_tx);
        for _ in 0..expected {
            ack_rx.recv().expect("Emb PS worker died mid-update");
        }
    }

    fn apply_grads_node(
        &self,
        node: usize,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        debug_assert_eq!(grads.len() * hotness, indices.len() * dim);
        // ship only this node's gradient slices: the per-node compact
        // buffer re-indexes grad_slot to the request's own position, so an
        // 8-node ordered scatter does not copy the full gradient 8 times
        let mut reqs: Vec<UpdateReq> = Vec::new();
        let mut compact: Vec<f32> = Vec::new();
        for (slot, &row) in indices.iter().enumerate() {
            let (owner, local) = route_row(row as usize, self.n_nodes);
            if owner != node {
                continue;
            }
            let src_slot = slot / hotness;
            reqs.push(UpdateReq {
                table: (src_slot % t) as u32,
                local: local as u32,
                grad_slot: (compact.len() / dim) as u32,
            });
            compact.extend_from_slice(&grads[src_slot * dim..(src_slot + 1) * dim]);
        }
        if reqs.is_empty() {
            return;
        }
        let (ack_tx, ack_rx) = mpsc::channel();
        self.sender(node)
            .send(NodeMsg::Apply {
                reqs,
                grads: Arc::new(compact),
                lr,
                opt,
                ack: ack_tx,
            })
            .expect("Emb PS worker hung up");
        ack_rx.recv().expect("Emb PS worker died mid-update");
    }

    /// Plan-driven sibling of [`apply_grads_node`](Self::apply_grads_node):
    /// walks the plan's per-node ascending flat-slot list (no full index
    /// scan) into the scratch's pooled request/compact-gradient buffers and
    /// ships them through the persistent reply channel; the returning
    /// buffers double as the completion ack. Same per-slot arithmetic in
    /// the same sample order — bit-identical.
    fn apply_grads_planned_node(
        &self,
        node: usize,
        plan: &BatchPlan,
        scratch: &mut PlanScratch,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        let hotness = plan.hotness();
        debug_assert_eq!(grads.len() * hotness, plan.n_slots() * dim);
        let slots = plan.apply_slots(node);
        if slots.is_empty() {
            // same contract as the unplanned path: an untouched (possibly
            // dead) node is never routed to
            return;
        }
        let indices = plan.indices();
        let n_nodes = self.n_nodes;
        let (mut reqs, mut compact) = scratch.take_apply_bufs();
        for &slot in slots {
            let slot = slot as usize;
            let local = indices[slot] as usize / n_nodes;
            let src_slot = slot / hotness;
            reqs.push((((src_slot % t) as u64) << 32) | local as u64);
            compact.extend_from_slice(&grads[src_slot * dim..(src_slot + 1) * dim]);
        }
        self.sender(node)
            .send(NodeMsg::ApplyPlanned {
                reqs,
                grads: compact,
                lr,
                opt,
                reply: scratch.reply_sender(),
            })
            .expect("Emb PS worker hung up");
        let (rnode, reqs, compact) = scratch.recv_reply();
        debug_assert_eq!(rnode, node);
        scratch.put_apply_bufs(reqs, compact);
    }

    fn read_row(&self, table: usize, global_row: usize, out: &mut [f32]) {
        let (data, _) = self.read_rows(table, &[global_row as u32]);
        out.copy_from_slice(&data);
    }

    fn read_rows(&self, table: usize, rows: &[u32]) -> (Vec<f32>, Vec<f32>) {
        let dim = self.tables[table].dim;
        let mut per_node: Vec<Vec<u32>> = (0..self.n_nodes).map(|_| Vec::new()).collect();
        let mut place: Vec<(u32, u32)> = Vec::with_capacity(rows.len());
        for &row in rows {
            let (node, local) = route_row(row as usize, self.n_nodes);
            place.push((node as u32, per_node[node].len() as u32));
            per_node[node].push(local as u32);
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut expected = 0usize;
        for (node, locals) in per_node.into_iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            expected += 1;
            self.sender(node)
                .send(NodeMsg::ReadRows { table: table as u32, locals, reply: reply_tx.clone() })
                .expect("Emb PS worker hung up");
        }
        drop(reply_tx);
        let mut parts: Vec<(Vec<f32>, Vec<f32>)> =
            (0..self.n_nodes).map(|_| (Vec::new(), Vec::new())).collect();
        for _ in 0..expected {
            let (node, data, acc) = reply_rx.recv().expect("Emb PS worker died mid-read");
            parts[node] = (data, acc);
        }
        let mut data = vec![0.0f32; rows.len() * dim];
        let mut opt = vec![0.0f32; rows.len()];
        for (i, &(node, off)) in place.iter().enumerate() {
            let (d, a) = &parts[node as usize];
            data[i * dim..(i + 1) * dim]
                .copy_from_slice(&d[off as usize * dim..(off as usize + 1) * dim]);
            opt[i] = a[off as usize];
        }
        (data, opt)
    }
}

impl PsControlPlane for ThreadedCluster {
    fn snapshot_node(&self, node: usize) -> NodeSnapshot {
        let _t = crate::telemetry::span_node("ps_snapshot", node);
        self.stats.bump_snapshot();
        let (reply_tx, reply_rx) = mpsc::channel();
        self.sender(node)
            .send(NodeMsg::Snapshot { reply: reply_tx })
            .expect("Emb PS worker hung up");
        reply_rx.recv().expect("Emb PS worker died mid-snapshot")
    }

    fn load_node(&self, node: usize, shards: &[Vec<f32>], opt: &[Vec<f32>]) {
        let _t = crate::telemetry::span_node("ps_load", node);
        let (ack_tx, ack_rx) = mpsc::channel();
        self.sender(node)
            .send(NodeMsg::Load { shards: shards.to_vec(), opt: opt.to_vec(), ack: ack_tx })
            .expect("Emb PS worker hung up");
        ack_rx.recv().expect("Emb PS worker died mid-restore");
        // serving resumes on the restored values right away, not at the
        // next barrier publish — recovery should shrink the NodeDown
        // window, not stretch it by a step
        self.set_serve_view(node, Some(Arc::new(shards.to_vec())));
    }

    fn reset_node_to_init(&self, node: usize) {
        let (ack_tx, ack_rx) = mpsc::channel();
        self.sender(node)
            .send(NodeMsg::Reset { ack: ack_tx })
            .expect("Emb PS worker hung up");
        ack_rx.recv().expect("Emb PS worker died mid-reset");
        self.set_serve_view_init(node);
    }

    fn kill_node(&self, node: usize) {
        self.stats.bump_kill();
        // fail serving first: a read racing the kill gets NodeDown, never
        // a view for a node the control plane already declared dead
        self.set_serve_view(node, None);
        if let Some(w) = self.slot(node).take() {
            let _ = w.tx.send(NodeMsg::Kill);
            let _ = w.join.join();
        }
    }

    fn respawn_node(&self, node: usize) {
        self.stats.bump_respawn();
        let mut slot = self.slot(node);
        assert!(slot.is_none(), "node {node} is already alive");
        // clear the crash flag before the replacement goes live: the old
        // worker is joined (kill_node), so no stale store can race this
        self.panicked[node].store(false, Ordering::Release);
        *slot = Some(Self::spawn(
            &self.tables,
            self.n_nodes,
            node,
            self.seed,
            Arc::clone(&self.panicked[node]),
        ));
        drop(slot);
        self.set_serve_view_init(node);
    }

    fn alive(&self, node: usize) -> bool {
        ThreadedCluster::alive(self, node)
    }
}

impl PsServePlane for ThreadedCluster {
    fn serve_gather(&self, indices: &[u32], out: &mut [f32]) -> Result<(), ServeError> {
        let t = self.tables.len();
        let dim = self.tables[0].dim;
        debug_assert!(self.tables.iter().all(|i| i.dim == dim));
        debug_assert_eq!(out.len(), indices.len() * dim);
        // clone each touched node's view Arc once; the RwLock is held only
        // for the clone, so a concurrent publish never blocks readers for
        // longer than a pointer swap
        let mut views: Vec<Option<Arc<Vec<Vec<f32>>>>> = vec![None; self.n_nodes];
        for (slot, &row) in indices.iter().enumerate() {
            let tab = slot % t;
            let (node, local) = route_row(row as usize, self.n_nodes);
            if views[node].is_none() {
                // a crashed worker never unpublishes its view (kill_node
                // does that for orderly kills) — fold the panic flag in so
                // a crashed node fails fast instead of serving its stale
                // last-published snapshot forever
                if self.panicked[node].load(Ordering::Acquire) {
                    return Err(ServeError::NodeDown { node });
                }
                let g = self.serve_views[node]
                    .read()
                    .unwrap_or_else(PoisonError::into_inner);
                match &*g {
                    Some(v) => views[node] = Some(Arc::clone(v)),
                    None => return Err(ServeError::NodeDown { node }),
                }
            }
            let shard = &views[node].as_ref().unwrap()[tab];
            out[slot * dim..(slot + 1) * dim]
                .copy_from_slice(&shard[local * dim..(local + 1) * dim]);
        }
        self.stats.bump_serve_read();
        Ok(())
    }

    /// Double-buffer swap at the step barrier: ask every live worker for a
    /// shards-only clone and publish it. Dead nodes keep their `None`
    /// view. Readers keep serving the old `Arc` until their in-flight
    /// request finishes — no reader ever observes a half-swapped view.
    fn publish_serve_view(&self) {
        let (reply_tx, reply_rx) = mpsc::channel();
        for node in 0..self.n_nodes {
            if self.panicked[node].load(Ordering::Acquire) {
                continue; // crashed worker: serving already fails NodeDown
            }
            let tx = match &*self.slot(node) {
                Some(w) => w.tx.clone(),
                None => continue,
            };
            // a worker may crash between the flag check and this send (or
            // while holding the request) — both simply mean fewer replies,
            // which the drain below tolerates; the raised flag converts
            // subsequent serving to NodeDown
            let _ = tx.send(NodeMsg::ServeView { reply: reply_tx.clone() });
        }
        drop(reply_tx);
        while let Ok((node, shards)) = reply_rx.recv() {
            self.set_serve_view(node, Some(Arc::new(shards)));
        }
    }
}

impl Drop for ThreadedCluster {
    fn drop(&mut self) {
        for slot in &self.workers {
            if let Some(w) = slot.lock().unwrap_or_else(PoisonError::into_inner).take() {
                let _ = w.tx.send(NodeMsg::Kill);
                let _ = w.join.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::PsCluster;
    use crate::util::rng::Rng;

    const TABLES: [TableInfo; 2] =
        [TableInfo { rows: 40, dim: 4 }, TableInfo { rows: 17, dim: 4 }];

    fn both(n_nodes: usize, seed: u64) -> (PsCluster, ThreadedCluster) {
        (
            PsCluster::new(TABLES.to_vec(), n_nodes, seed),
            ThreadedCluster::new(TABLES.to_vec(), n_nodes, seed),
        )
    }

    fn rand_indices(rng: &mut Rng, b: usize, hotness: usize) -> Vec<u32> {
        let mut idx = Vec::with_capacity(b * 2 * hotness);
        for _ in 0..b {
            for t in 0..2 {
                for _ in 0..hotness {
                    idx.push(rng.below(TABLES[t].rows as u64) as u32);
                }
            }
        }
        idx
    }

    #[test]
    fn gather_is_bit_identical_to_inproc() {
        let (a, b) = both(3, 11);
        let mut rng = Rng::new(1);
        for hotness in [1usize, 3] {
            let idx = rand_indices(&mut rng, 16, hotness);
            let mut out_a = vec![0.0f32; 16 * 2 * 4];
            let mut out_b = vec![0.0f32; 16 * 2 * 4];
            PsDataPlane::gather_pooled(&a, &idx, hotness, &mut out_a);
            b.gather_pooled(&idx, hotness, &mut out_b);
            assert_eq!(out_a, out_b, "hotness {hotness}");
        }
    }

    #[test]
    fn apply_grads_is_bit_identical_to_inproc() {
        let (a, b) = both(4, 9);
        let mut rng = Rng::new(2);
        for (step, opt) in [(0usize, EmbOptimizer::Sgd),
                            (1, EmbOptimizer::RowAdagrad { eps: 1e-8 }),
                            (2, EmbOptimizer::RowAdagrad { eps: 1e-8 })] {
            let hotness = 1 + step % 2;
            let idx = rand_indices(&mut rng, 8, hotness);
            let grads: Vec<f32> = (0..8 * 2 * 4).map(|_| rng.f32() - 0.5).collect();
            PsDataPlane::apply_grads(&a, &idx, hotness, &grads, 0.7, opt);
            b.apply_grads(&idx, hotness, &grads, 0.7, opt);
        }
        for node in 0..4 {
            let sa = PsControlPlane::snapshot_node(&a, node);
            let sb = b.snapshot_node(node);
            assert_eq!(sa.shards, sb.shards, "node {node} shards diverged");
            assert_eq!(sa.opt, sb.opt, "node {node} optimizer state diverged");
        }
    }

    #[test]
    fn apply_grads_node_is_bit_identical_to_whole_batch() {
        let (a, b) = both(3, 21);
        let mut rng = Rng::new(7);
        for hotness in [1usize, 2] {
            let idx = rand_indices(&mut rng, 8, hotness);
            let grads: Vec<f32> = (0..8 * 2 * 4).map(|_| rng.f32() - 0.5).collect();
            PsDataPlane::apply_grads(&a, &idx, hotness, &grads, 0.7,
                                     EmbOptimizer::RowAdagrad { eps: 1e-8 });
            for node in 0..3 {
                b.apply_grads_node(node, &idx, hotness, &grads, 0.7,
                                   EmbOptimizer::RowAdagrad { eps: 1e-8 });
            }
        }
        for node in 0..3 {
            let sa = PsControlPlane::snapshot_node(&a, node);
            let sb = b.snapshot_node(node);
            assert_eq!(sa.shards, sb.shards, "node {node} shards diverged");
            assert_eq!(sa.opt, sb.opt, "node {node} optimizer state diverged");
        }
    }

    #[test]
    fn read_rows_matches_read_row() {
        let c = ThreadedCluster::new(TABLES.to_vec(), 3, 5);
        let mut rng = Rng::new(3);
        let idx = rand_indices(&mut rng, 8, 1);
        let grads: Vec<f32> = (0..8 * 2 * 4).map(|_| rng.f32()).collect();
        c.apply_grads(&idx, 1, &grads, 0.5, EmbOptimizer::RowAdagrad { eps: 1e-8 });
        let rows = vec![0u32, 5, 39, 7];
        let (data, _opt) = c.read_rows(0, &rows);
        let mut row = vec![0.0f32; 4];
        for (i, &r) in rows.iter().enumerate() {
            c.read_row(0, r as usize, &mut row);
            assert_eq!(&data[i * 4..(i + 1) * 4], &row[..]);
        }
    }

    #[test]
    fn survivors_serve_while_a_node_is_dead() {
        let c = ThreadedCluster::new(TABLES.to_vec(), 3, 7);
        c.kill_node(1);
        assert!(!c.alive(1));
        // every row routes to node 0 (all ids ≡ 0 mod 3) — dead node 1 is
        // never touched
        let idx = vec![0u32, 3, 9, 6]; // 2 samples x 2 tables
        let mut out = vec![0.0f32; 2 * 2 * 4];
        c.gather_pooled(&idx, 1, &mut out); // must not panic or hang
        let reference = PsCluster::new(TABLES.to_vec(), 3, 7);
        let mut want = vec![0.0f32; 2 * 2 * 4];
        PsDataPlane::gather_pooled(&reference, &idx, 1, &mut want);
        assert_eq!(out, want);
        c.respawn_node(1);
        assert!(c.alive(1));
    }

    #[test]
    #[should_panic(expected = "is dead")]
    fn routing_to_a_dead_node_panics() {
        let c = ThreadedCluster::new(TABLES.to_vec(), 3, 7);
        c.kill_node(1);
        let mut out = vec![0.0f32; 4 * 2];
        c.gather_pooled(&[1, 1], 1, &mut out); // row 1 lives on dead node 1
    }

    #[test]
    fn kill_respawn_load_runs_full_recovery_protocol() {
        let c = ThreadedCluster::new(TABLES.to_vec(), 3, 7);
        let mut rng = Rng::new(4);
        let idx = rand_indices(&mut rng, 8, 1);
        let grads: Vec<f32> = (0..8 * 2 * 4).map(|_| rng.f32()).collect();
        c.apply_grads(&idx, 1, &grads, 1.0, EmbOptimizer::Sgd);
        let checkpoint = c.snapshot_node(2);
        // more training, then the node dies
        c.apply_grads(&idx, 1, &grads, 1.0, EmbOptimizer::Sgd);
        c.kill_node(2);
        c.respawn_node(2);
        // blank replacement is at init
        let fresh = ThreadedCluster::new(TABLES.to_vec(), 3, 7);
        assert_eq!(c.snapshot_node(2).shards, fresh.snapshot_node(2).shards);
        // restore from the checkpoint
        c.load_node(2, &checkpoint.shards, &checkpoint.opt);
        assert_eq!(c.snapshot_node(2).shards, checkpoint.shards);
        let s = c.stats();
        assert_eq!((s.kills, s.respawns), (1, 1));
    }

    #[test]
    fn reset_restores_init_values() {
        let c = ThreadedCluster::new(TABLES.to_vec(), 2, 13);
        c.apply_grads(&[2, 2], 1, &[1.0f32; 8], 1.0, EmbOptimizer::Sgd);
        c.reset_node_to_init(0); // row 2 lives on node 0
        let fresh = ThreadedCluster::new(TABLES.to_vec(), 2, 13);
        assert_eq!(c.snapshot_node(0), fresh.snapshot_node(0));
    }

    #[test]
    fn serve_view_is_stale_until_published() {
        let c = ThreadedCluster::new(TABLES.to_vec(), 3, 7);
        let idx = vec![0u32, 3]; // 1 sample x 2 tables, both rows on node 0
        let mut init = vec![0.0f32; 2 * 4];
        c.serve_gather(&idx, &mut init).unwrap();
        c.apply_grads(&idx, 1, &[1.0f32; 8], 1.0, EmbOptimizer::Sgd);
        // before the barrier publish, serving still sees the old view
        let mut stale = vec![0.0f32; 2 * 4];
        c.serve_gather(&idx, &mut stale).unwrap();
        assert_eq!(stale, init, "view must not move before publish");
        c.publish_serve_view();
        let mut fresh = vec![0.0f32; 2 * 4];
        c.serve_gather(&idx, &mut fresh).unwrap();
        let mut want = vec![0.0f32; 2 * 4];
        c.gather_pooled(&idx, 1, &mut want);
        assert_eq!(fresh, want, "published view must match live state");
        let s = c.stats();
        assert_eq!(s.serve_reads, 3);
        assert_eq!(s.serve_retries, 0, "snapshot reads never retry");
    }

    #[test]
    fn serve_dead_node_errors_and_recovery_restores_service() {
        let c = ThreadedCluster::new(TABLES.to_vec(), 3, 7);
        let idx = vec![1u32, 4]; // both rows on node 1
        c.apply_grads(&idx, 1, &[1.0f32; 8], 1.0, EmbOptimizer::Sgd);
        c.publish_serve_view();
        let checkpoint = c.snapshot_node(1);
        c.kill_node(1);
        let mut out = vec![0.0f32; 2 * 4];
        assert_eq!(c.serve_gather(&idx, &mut out),
                   Err(ServeError::NodeDown { node: 1 }));
        // survivors keep serving (rows on nodes 0 and 2)
        c.serve_gather(&[0, 2], &mut out).unwrap();
        // respawn serves init immediately, load serves the restored rows
        c.respawn_node(1);
        c.serve_gather(&idx, &mut out).unwrap();
        let fresh = ThreadedCluster::new(TABLES.to_vec(), 3, 7);
        let mut want = vec![0.0f32; 2 * 4];
        fresh.gather_pooled(&idx, 1, &mut want);
        assert_eq!(out, want, "respawned view must be at init");
        c.load_node(1, &checkpoint.shards, &checkpoint.opt);
        c.serve_gather(&idx, &mut out).unwrap();
        c.gather_pooled(&idx, 1, &mut want);
        assert_eq!(out, want, "restored view must match live state");
    }

    #[test]
    fn worker_panic_reads_as_dead_and_respawn_recovers() {
        let c = ThreadedCluster::new(TABLES.to_vec(), 3, 7);
        // row 4000 routes to node 1 (4000 % 3 == 1) at local 1333 — far
        // outside every table's shard, so the worker panics mid-apply;
        // the router observes the loss as a recv failure (its own panic)
        let bad = vec![4000u32, 4000];
        let routed = std::thread::scope(|s| {
            s.spawn(|| c.apply_grads(&bad, 1, &[0.0f32; 8], 1.0, EmbOptimizer::Sgd))
                .join()
        });
        assert!(routed.is_err(), "router must observe the worker loss");
        // the crash flag is raised as the unwind escapes the worker loop,
        // which can land just after the router's recv failure — wait it out
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while c.alive(1) {
            assert!(
                std::time::Instant::now() < deadline,
                "worker crash never flipped alive() to false"
            );
            std::thread::yield_now();
        }
        // serving converts the crash to NodeDown (the stale published view
        // must not be served) while survivors keep answering
        let mut out = vec![0.0f32; 2 * 4];
        assert_eq!(
            c.serve_gather(&[1, 4], &mut out),
            Err(ServeError::NodeDown { node: 1 })
        );
        c.serve_gather(&[0, 2], &mut out).unwrap();
        // publish skips the crashed node instead of hanging on its channel
        c.publish_serve_view();
        // orderly kill reaps the crashed slot; respawn clears the flag
        c.kill_node(1);
        c.respawn_node(1);
        assert!(c.alive(1));
        c.serve_gather(&[1, 4], &mut out).unwrap();
    }

    #[test]
    fn planned_paths_are_bit_identical_to_unplanned() {
        use crate::cluster::PlanArena;
        let (a, b) = both(3, 19);
        let mut rng = Rng::new(5);
        let mut arena = PlanArena::new();
        let opt = EmbOptimizer::RowAdagrad { eps: 1e-8 };
        for hotness in [1usize, 3] {
            let idx = rand_indices(&mut rng, 12, hotness);
            arena.build(&idx, hotness, 2, 3);
            let (plan, scratch) = arena.parts_mut();
            let mut want = vec![0.0f32; 12 * 2 * 4];
            let mut got = vec![0.0f32; 12 * 2 * 4];
            PsDataPlane::gather_pooled(&a, &idx, hotness, &mut want);
            b.gather_planned(plan, scratch, &mut got);
            assert_eq!(want, got, "hotness {hotness}");
            let grads: Vec<f32> = (0..12 * 2 * 4).map(|_| rng.f32() - 0.5).collect();
            PsDataPlane::apply_grads(&a, &idx, hotness, &grads, 0.7, opt);
            for node in 0..3 {
                if plan.touched().get(node) {
                    b.apply_grads_planned_node(node, plan, scratch, &grads, 0.7, opt);
                }
            }
        }
        for node in 0..3 {
            let sa = PsControlPlane::snapshot_node(&a, node);
            let sb = b.snapshot_node(node);
            assert_eq!(sa.shards, sb.shards, "node {node} shards diverged");
            assert_eq!(sa.opt, sb.opt, "node {node} optimizer state diverged");
        }
        let s = b.stats();
        assert!(s.unique_rows > 0);
        assert_eq!(s.unique_rows + s.dedup_hits, (12 * 2 * 1 + 12 * 2 * 3) as u64);
    }

    #[test]
    fn planned_gather_skips_dead_untouched_nodes() {
        use crate::cluster::PlanArena;
        let c = ThreadedCluster::new(TABLES.to_vec(), 3, 7);
        c.kill_node(1);
        // every row ≡ 0 mod 3 — dead node 1 is never routed to
        let idx = vec![0u32, 3, 9, 6];
        let mut arena = PlanArena::new();
        arena.build(&idx, 1, 2, 3);
        let (plan, scratch) = arena.parts_mut();
        let mut out = vec![0.0f32; 2 * 2 * 4];
        c.gather_planned(plan, scratch, &mut out); // must not panic or hang
        let reference = PsCluster::new(TABLES.to_vec(), 3, 7);
        let mut want = vec![0.0f32; 2 * 2 * 4];
        PsDataPlane::gather_pooled(&reference, &idx, 1, &mut want);
        assert_eq!(out, want);
        // a planned apply to the dead, untouched node is a no-op
        c.apply_grads_planned_node(1, plan, scratch, &[0.0f32; 16], 1.0, EmbOptimizer::Sgd);
    }

    #[test]
    fn concurrent_routers_share_the_cluster() {
        // the data plane is &self: many threads gather + apply at once
        // with no external lock, and the result matches a serial run
        let c = ThreadedCluster::new(TABLES.to_vec(), 4, 31);
        let idx = vec![0u32, 1, 5, 2, 8, 3, 13, 4]; // 4 samples x 2 tables
        let mut want = vec![0.0f32; 4 * 2 * 4];
        c.gather_pooled(&idx, 1, &mut want);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = &c;
                let idx = idx.clone();
                let want = want.clone();
                s.spawn(move || {
                    for _ in 0..25 {
                        let mut out = vec![0.0f32; 4 * 2 * 4];
                        c.gather_pooled(&idx, 1, &mut out);
                        assert_eq!(out, want);
                    }
                });
            }
        });
    }
}
