//! Route-once batch plans: per-batch dedup + routing computed a single time.
//!
//! CPR's thesis is Zipfian access skew: a skewed batch carries its hottest
//! rows dozens of times. The unplanned hot path routes, fetches, and ships
//! every duplicate slot independently, and re-scans the full index list up
//! to four times per step (touched-node discovery in gather *and* apply,
//! policy access recording, v2 dirty-row capture). A [`BatchPlan`] collapses
//! all of that into one pass over the batch:
//!
//! - **dedup**: each distinct `(table, global_row)` pair becomes one *unique
//!   entry*, grouped by owning node, with an access count;
//! - **placement**: `slot_unique` maps every flat slot back to its unique
//!   entry so reassembly can reproduce the *exact* float-op order of the
//!   unplanned pooled gather (copy at `slot % hotness == 0`, add otherwise,
//!   in ascending slot order) — bit-identical by construction;
//! - **touched nodes**: a stack [`NodeSet`] bitset replaces the
//!   `vec![false; n_nodes]` the unplanned path used to allocate per call;
//! - **apply order**: per-node ascending flat-slot lists so a planned apply
//!   visits exactly the slots the filtered full scan would, in the same
//!   order. Applies deliberately do **not** dedup: duplicate rows must
//!   accumulate their gradients in sample order to stay bit-identical.
//!
//! A [`PlanArena`] owns one plan plus a [`PlanScratch`] of pooled reply and
//! message buffers, so the steady-state planned step performs zero heap
//! allocations on the in-proc backend (the threaded backend is bounded by
//! mpsc queue-block amortization; see DESIGN.md).
//!
//! All storage is `Vec`s that are cleared and refilled in place; after a
//! few warmup steps every buffer has reached its high-water capacity and
//! `build` allocates nothing.

use std::ops::Range;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Duration;

/// Sentinel for an empty hash bucket. Valid keys always have the table in
/// the high 32 bits and a row index below `u32::MAX` in the low bits, so
/// `u64::MAX` (table `u32::MAX`, row `u32::MAX`) never collides with a real
/// key at realistic table counts.
const EMPTY: u64 = u64::MAX;

/// One deduplicated access: `count` slots of the batch hit `(table, row)`.
///
/// `row` is the *global* row id (pre-routing); consumers that need the
/// node-local id derive it via `row / n_nodes` as usual.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanAccess {
    pub table: u32,
    pub row: u32,
    pub count: u32,
}

/// Fixed-size touched-node bitset (up to 256 nodes — far beyond the
/// emulated clusters this repo runs). Lives on the stack / inline in the
/// plan; replaces the per-call `vec![false; n_nodes]` allocation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NodeSet {
    words: [u64; 4],
}

impl NodeSet {
    pub fn new() -> Self {
        Self { words: [0; 4] }
    }

    pub fn clear(&mut self) {
        self.words = [0; 4];
    }

    #[inline]
    pub fn insert(&mut self, node: usize) {
        assert!(node < 256, "NodeSet supports at most 256 nodes, got {node}");
        self.words[node / 64] |= 1u64 << (node % 64);
    }

    #[inline]
    pub fn get(&self, node: usize) -> bool {
        node < 256 && self.words[node / 64] >> (node % 64) & 1 == 1
    }

    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }
}

#[inline]
fn mix(mut h: u64) -> u64 {
    // splitmix64-style finalizer: cheap, good avalanche for packed keys.
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    h ^= h >> 33;
    h
}

/// A batch plan: routing, dedup, and placement for one `(indices, hotness)`
/// batch, built once and shared by the gather, the per-node applies, and
/// policy access recording. All storage is pooled across `build` calls.
#[derive(Debug, Default)]
pub struct BatchPlan {
    /// Pooled copy of the batch's flat index list (slot order).
    indices: Vec<u32>,
    hotness: usize,
    num_tables: usize,
    n_nodes: usize,
    touched: NodeSet,
    n_unique: usize,

    // Open-addressing dedup hash: key = (table << 32) | global_row.
    hash_keys: Vec<u64>,
    hash_vals: Vec<u32>,

    /// flat slot -> final (node-grouped) unique id.
    slot_unique: Vec<u32>,
    /// Packed key of each unique entry, grouped by owning node.
    unique_key: Vec<u64>,
    /// Number of slots referencing each unique entry.
    access_count: Vec<u32>,
    /// Per-node offsets into `unique_key`/`access_count` (len n_nodes + 1).
    node_off: Vec<u32>,

    /// Flat slot ids grouped by owning node, ascending within each node —
    /// exactly the slots the filtered full scan of `apply_grads_node` would
    /// visit, in the same order.
    apply_slots: Vec<u32>,
    apply_off: Vec<u32>,

    // Build scratch (pooled).
    remap: Vec<u32>,
    prov_key: Vec<u64>,
    prov_count: Vec<u32>,
    node_unique_count: Vec<u32>,
    node_slot_count: Vec<u32>,
    cursor: Vec<u32>,
}

impl BatchPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build the plan for one batch. `indices` is the flat
    /// `batch * num_tables * hotness` slot list produced by the dataset.
    ///
    /// Steady state (capacities warmed up): zero heap allocations.
    pub fn build(&mut self, indices: &[u32], hotness: usize, num_tables: usize, n_nodes: usize) {
        assert!(hotness > 0, "hotness must be positive");
        assert!(num_tables > 0, "num_tables must be positive");
        assert!(n_nodes > 0, "n_nodes must be positive");
        let n_slots = indices.len();
        assert!(
            n_slots % (num_tables * hotness) == 0,
            "index list length {n_slots} not a multiple of num_tables*hotness"
        );

        self.hotness = hotness;
        self.num_tables = num_tables;
        self.n_nodes = n_nodes;
        self.touched.clear();
        self.indices.clear();
        self.indices.extend_from_slice(indices);

        self.slot_unique.clear();
        self.prov_key.clear();
        self.prov_count.clear();

        self.node_unique_count.clear();
        self.node_unique_count.resize(n_nodes, 0);
        self.node_slot_count.clear();
        self.node_slot_count.resize(n_nodes, 0);

        if n_slots == 0 {
            self.n_unique = 0;
            self.unique_key.clear();
            self.access_count.clear();
            self.apply_slots.clear();
            self.node_off.clear();
            self.node_off.resize(n_nodes + 1, 0);
            self.apply_off.clear();
            self.apply_off.resize(n_nodes + 1, 0);
            return;
        }

        // Hash capacity: power of two >= 2 * n_slots keeps load factor <= 0.5.
        let cap = (2 * n_slots).next_power_of_two();
        if self.hash_keys.len() != cap {
            self.hash_keys.clear();
            self.hash_keys.resize(cap, EMPTY);
            self.hash_vals.clear();
            self.hash_vals.resize(cap, 0);
        } else {
            self.hash_keys.fill(EMPTY);
        }
        let mask = cap - 1;

        // Pass 1: dedup into provisional ids (first-seen order), count
        // per-node uniques and slots, record touched nodes.
        for (slot, &row) in indices.iter().enumerate() {
            let table = (slot / hotness) % num_tables;
            let node = row as usize % n_nodes;
            let key = ((table as u64) << 32) | row as u64;
            let mut pos = mix(key) as usize & mask;
            let uid = loop {
                let k = self.hash_keys[pos];
                if k == EMPTY {
                    let uid = self.prov_key.len() as u32;
                    self.hash_keys[pos] = key;
                    self.hash_vals[pos] = uid;
                    self.prov_key.push(key);
                    self.prov_count.push(1);
                    self.node_unique_count[node] += 1;
                    self.touched.insert(node);
                    break uid;
                }
                if k == key {
                    let uid = self.hash_vals[pos];
                    self.prov_count[uid as usize] += 1;
                    break uid;
                }
                pos = (pos + 1) & mask;
            };
            self.slot_unique.push(uid);
            self.node_slot_count[node] += 1;
        }
        let n_unique = self.prov_key.len();
        self.n_unique = n_unique;

        // Prefix sums: per-node unique and apply-slot ranges.
        self.node_off.clear();
        self.node_off.push(0);
        let mut acc = 0u32;
        for &c in &self.node_unique_count {
            acc += c;
            self.node_off.push(acc);
        }
        self.apply_off.clear();
        self.apply_off.push(0);
        let mut acc = 0u32;
        for &c in &self.node_slot_count {
            acc += c;
            self.apply_off.push(acc);
        }

        // Remap provisional -> final node-grouped unique ids. Within a node,
        // uniques stay in first-seen order (stable, deterministic).
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.node_off[..n_nodes]);
        self.remap.clear();
        self.remap.resize(n_unique, 0);
        self.unique_key.clear();
        self.unique_key.resize(n_unique, 0);
        self.access_count.clear();
        self.access_count.resize(n_unique, 0);
        for uid in 0..n_unique {
            let key = self.prov_key[uid];
            let node = (key & 0xFFFF_FFFF) as usize % n_nodes;
            let fin = self.cursor[node];
            self.cursor[node] += 1;
            self.remap[uid] = fin;
            self.unique_key[fin as usize] = key;
            self.access_count[fin as usize] = self.prov_count[uid];
        }

        // Pass 2: remap slot_unique in place and fill per-node apply-slot
        // lists (ascending within each node because slots are visited in
        // ascending order).
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.apply_off[..n_nodes]);
        self.apply_slots.clear();
        self.apply_slots.resize(n_slots, 0);
        for slot in 0..n_slots {
            let uid = self.slot_unique[slot] as usize;
            self.slot_unique[slot] = self.remap[uid];
            let node = self.indices[slot] as usize % n_nodes;
            let c = self.cursor[node];
            self.apply_slots[c as usize] = slot as u32;
            self.cursor[node] = c + 1;
        }
    }

    /// The flat slot index list the plan was built from.
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    pub fn hotness(&self) -> usize {
        self.hotness
    }

    pub fn num_tables(&self) -> usize {
        self.num_tables
    }

    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    pub fn n_slots(&self) -> usize {
        self.indices.len()
    }

    /// Number of distinct `(table, row)` pairs in the batch.
    pub fn n_unique(&self) -> usize {
        self.n_unique
    }

    /// Slots minus uniques: how many row fetches dedup saved this batch.
    pub fn dedup_hits(&self) -> usize {
        self.indices.len() - self.n_unique
    }

    pub fn touched(&self) -> &NodeSet {
        &self.touched
    }

    /// Range of unique-entry ids owned by `node`.
    pub fn unique_range(&self, node: usize) -> Range<usize> {
        self.node_off[node] as usize..self.node_off[node + 1] as usize
    }

    /// Packed `(table << 32) | row` key of unique entry `u`.
    #[inline]
    pub fn unique_key(&self, u: usize) -> u64 {
        self.unique_key[u]
    }

    #[inline]
    pub fn unique_table(&self, u: usize) -> usize {
        (self.unique_key[u] >> 32) as usize
    }

    /// Global row id of unique entry `u`.
    #[inline]
    pub fn unique_row(&self, u: usize) -> usize {
        (self.unique_key[u] & 0xFFFF_FFFF) as usize
    }

    /// Node-local row id of unique entry `u`.
    #[inline]
    pub fn unique_local(&self, u: usize) -> usize {
        self.unique_row(u) / self.n_nodes
    }

    /// flat slot -> final unique id, for bit-identical reassembly.
    pub fn slot_unique(&self) -> &[u32] {
        &self.slot_unique
    }

    /// Flat slot ids owned by `node`, ascending — the exact visit order of
    /// the unplanned filtered scan in `apply_grads_node`.
    pub fn apply_slots(&self, node: usize) -> &[u32] {
        let r = self.apply_off[node] as usize..self.apply_off[node + 1] as usize;
        &self.apply_slots[r]
    }

    /// Deduplicated access record for unique entry `u`.
    #[inline]
    pub fn access(&self, u: usize) -> PlanAccess {
        PlanAccess {
            table: (self.unique_key[u] >> 32) as u32,
            row: (self.unique_key[u] & 0xFFFF_FFFF) as u32,
            count: self.access_count[u],
        }
    }

    /// Collect all accesses into a fresh Vec (for shipping across the
    /// trainer reply channel; allocates, so not part of the zero-alloc
    /// data-plane contract).
    pub fn collect_accesses(&self) -> Vec<PlanAccess> {
        (0..self.n_unique).map(|u| self.access(u)).collect()
    }
}

/// Reply message for planned threaded-backend operations: `(node, reqs,
/// vals)` — the request/value buffers travel back so the router can return
/// them to the pool.
pub type PlannedReply = (usize, Vec<u64>, Vec<f32>);

/// Pooled scratch buffers for planned data-plane calls. One per trainer
/// (or test/bench) handle; buffers grow to a high-water mark during warmup
/// and are reused forever after.
#[derive(Debug)]
pub struct PlanScratch {
    /// Dense unique-row value buffer: `n_unique * dim` floats, node-grouped.
    pub unique_vals: Vec<f32>,
    /// One-row working buffer (dim floats) for in-proc applies.
    pub row_buf: Vec<f32>,

    // Per-node pooled message buffers for the threaded backend.
    gather_reqs: Vec<Vec<u64>>,
    gather_vals: Vec<Vec<f32>>,
    apply_reqs: Vec<u64>,
    apply_grads: Vec<f32>,

    // Persistent reply path: replaces the fresh mpsc::channel() per call.
    reply_tx: Sender<PlannedReply>,
    reply_rx: Receiver<PlannedReply>,
}

impl Default for PlanScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl PlanScratch {
    pub fn new() -> Self {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        Self {
            unique_vals: Vec::new(),
            row_buf: Vec::new(),
            gather_reqs: Vec::new(),
            gather_vals: Vec::new(),
            apply_reqs: Vec::new(),
            apply_grads: Vec::new(),
            reply_tx,
            reply_rx,
        }
    }

    /// Ensure per-node buffer pools cover `n_nodes` nodes.
    pub fn ensure_nodes(&mut self, n_nodes: usize) {
        while self.gather_reqs.len() < n_nodes {
            self.gather_reqs.push(Vec::new());
            self.gather_vals.push(Vec::new());
        }
    }

    /// Take node `node`'s pooled gather buffers (cleared).
    pub fn take_gather_bufs(&mut self, node: usize) -> (Vec<u64>, Vec<f32>) {
        let mut reqs = std::mem::take(&mut self.gather_reqs[node]);
        let mut vals = std::mem::take(&mut self.gather_vals[node]);
        reqs.clear();
        vals.clear();
        (reqs, vals)
    }

    /// Return node `node`'s gather buffers to the pool.
    pub fn put_gather_bufs(&mut self, node: usize, reqs: Vec<u64>, vals: Vec<f32>) {
        self.gather_reqs[node] = reqs;
        self.gather_vals[node] = vals;
    }

    /// Take the pooled apply buffers (cleared).
    pub fn take_apply_bufs(&mut self) -> (Vec<u64>, Vec<f32>) {
        let mut reqs = std::mem::take(&mut self.apply_reqs);
        let mut grads = std::mem::take(&mut self.apply_grads);
        reqs.clear();
        grads.clear();
        (reqs, grads)
    }

    /// Return the apply buffers to the pool.
    pub fn put_apply_bufs(&mut self, reqs: Vec<u64>, grads: Vec<f32>) {
        self.apply_reqs = reqs;
        self.apply_grads = grads;
    }

    /// Clone the persistent reply sender for attaching to a node message.
    pub fn reply_sender(&self) -> Sender<PlannedReply> {
        self.reply_tx.clone()
    }

    /// Receive one planned reply. The scratch itself holds a live sender,
    /// so a plain `recv()` would hang forever if a worker died mid-op;
    /// a generous timeout converts that hang into a diagnosable panic.
    pub fn recv_reply(&self) -> PlannedReply {
        match self.reply_rx.recv_timeout(Duration::from_secs(600)) {
            Ok(r) => r,
            Err(e) => panic!("planned reply lost — PS worker died mid-planned-op? ({e})"),
        }
    }
}

/// Owns one [`BatchPlan`] and its [`PlanScratch`]; the unit a trainer (or
/// bench loop) keeps across steps so all plan storage is pooled.
#[derive(Debug, Default)]
pub struct PlanArena {
    plan: BatchPlan,
    scratch: PlanScratch,
}

impl PlanArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild the plan for a new batch (pooled; steady-state alloc-free).
    pub fn build(&mut self, indices: &[u32], hotness: usize, num_tables: usize, n_nodes: usize) {
        self.plan.build(indices, hotness, num_tables, n_nodes);
        self.scratch.ensure_nodes(n_nodes);
    }

    pub fn plan(&self) -> &BatchPlan {
        &self.plan
    }

    /// Split borrow: the plan (shared) and the scratch (mutable) at once.
    pub fn parts_mut(&mut self) -> (&BatchPlan, &mut PlanScratch) {
        (&self.plan, &mut self.scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nodeset_basics() {
        let mut s = NodeSet::new();
        assert!(!s.get(0));
        s.insert(0);
        s.insert(63);
        s.insert(64);
        s.insert(255);
        assert!(s.get(0) && s.get(63) && s.get(64) && s.get(255));
        assert!(!s.get(1) && !s.get(200));
        assert_eq!(s.count(), 4);
        s.clear();
        assert_eq!(s.count(), 0);
    }

    #[test]
    #[should_panic(expected = "at most 256 nodes")]
    fn nodeset_overflow_panics() {
        NodeSet::new().insert(256);
    }

    #[test]
    fn plan_dedup_and_placement() {
        // 2 tables, hotness 2, batch 2, 3 nodes.
        // sample 0: t0 rows [5, 5], t1 rows [5, 7]
        // sample 1: t0 rows [5, 9], t1 rows [7, 7]
        let indices = [5u32, 5, 5, 7, 5, 9, 7, 7];
        let mut plan = BatchPlan::new();
        plan.build(&indices, 2, 2, 3);

        assert_eq!(plan.n_slots(), 8);
        // Uniques: (t0,5), (t1,5), (t1,7), (t0,9)  -> 4
        assert_eq!(plan.n_unique(), 4);
        assert_eq!(plan.dedup_hits(), 4);
        // Nodes touched: 5%3=2, 7%3=1, 9%3=0.
        assert!(plan.touched().get(0) && plan.touched().get(1) && plan.touched().get(2));
        assert_eq!(plan.touched().count(), 3);

        // Node-grouped uniques: node0 owns (t0,9); node1 owns (t1,7);
        // node2 owns (t0,5),(t1,5) in first-seen order.
        assert_eq!(plan.unique_range(0), 0..1);
        assert_eq!(plan.unique_range(1), 1..2);
        assert_eq!(plan.unique_range(2), 2..4);
        assert_eq!((plan.unique_table(0), plan.unique_row(0)), (0, 9));
        assert_eq!((plan.unique_table(1), plan.unique_row(1)), (1, 7));
        assert_eq!((plan.unique_table(2), plan.unique_row(2)), (0, 5));
        assert_eq!((plan.unique_table(3), plan.unique_row(3)), (1, 5));
        assert_eq!(plan.unique_local(1), 2); // row 7 on 3 nodes -> local 2

        // Access counts: (t0,5) hit 3x, (t1,7) hit 3x, others once.
        assert_eq!(plan.access(2), PlanAccess { table: 0, row: 5, count: 3 });
        assert_eq!(plan.access(1), PlanAccess { table: 1, row: 7, count: 3 });
        assert_eq!(plan.access(0).count, 1);
        assert_eq!(plan.access(3).count, 1);
        let total: u32 = (0..plan.n_unique()).map(|u| plan.access(u).count).sum();
        assert_eq!(total as usize, plan.n_slots());

        // Placement: every slot maps to the unique entry with its key.
        for (slot, &u) in plan.slot_unique().iter().enumerate() {
            let table = (slot / 2) % 2;
            let key = ((table as u64) << 32) | indices[slot] as u64;
            assert_eq!(plan.unique_key(u as usize), key, "slot {slot}");
        }

        // Apply slots: grouped by node, ascending, covering all slots once.
        assert_eq!(plan.apply_slots(0), &[5]); // row 9
        assert_eq!(plan.apply_slots(1), &[3, 6, 7]); // row 7 slots
        assert_eq!(plan.apply_slots(2), &[0, 1, 2, 4]); // row 5 slots
    }

    #[test]
    fn plan_rebuild_is_alloc_stable_and_correct() {
        let mut plan = BatchPlan::new();
        plan.build(&[1, 2, 3, 4], 1, 4, 2);
        assert_eq!(plan.n_unique(), 4);
        // Rebuild with a different shape: state fully reset.
        plan.build(&[6u32, 6, 6, 6, 6, 6], 3, 2, 4);
        assert_eq!(plan.n_slots(), 6);
        // (t0,6) and (t1,6) are distinct uniques (cross-table duplicate rows).
        assert_eq!(plan.n_unique(), 2);
        assert_eq!(plan.dedup_hits(), 4);
        assert_eq!(plan.touched().count(), 1);
        assert!(plan.touched().get(6 % 4));
        assert_eq!(plan.apply_slots(2), &[0, 1, 2, 3, 4, 5]);
        assert_eq!(plan.apply_slots(0).len(), 0);
    }

    #[test]
    fn empty_batch_plan() {
        let mut plan = BatchPlan::new();
        plan.build(&[], 2, 3, 4);
        assert_eq!(plan.n_slots(), 0);
        assert_eq!(plan.n_unique(), 0);
        assert_eq!(plan.touched().count(), 0);
        for n in 0..4 {
            assert!(plan.unique_range(n).is_empty());
            assert!(plan.apply_slots(n).is_empty());
        }
    }

    #[test]
    fn arena_split_borrow() {
        let mut arena = PlanArena::new();
        arena.build(&[0u32, 1, 2, 3], 1, 2, 2);
        let (plan, scratch) = arena.parts_mut();
        assert_eq!(plan.n_unique(), 4);
        scratch.unique_vals.resize(plan.n_unique() * 4, 0.0);
        let (reqs, vals) = scratch.take_gather_bufs(0);
        assert!(reqs.is_empty() && vals.is_empty());
        scratch.put_gather_bufs(0, reqs, vals);
    }

    #[test]
    fn scratch_reply_roundtrip() {
        let scratch = PlanScratch::new();
        let tx = scratch.reply_sender();
        tx.send((3, vec![1u64], vec![2.0f32])).unwrap();
        let (node, reqs, vals) = scratch.recv_reply();
        assert_eq!(node, 3);
        assert_eq!(reqs, vec![1u64]);
        assert_eq!(vals, vec![2.0f32]);
    }
}
