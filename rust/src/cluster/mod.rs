//! The Emb PS cluster runtime seam, split into two planes:
//!
//! * [`PsDataPlane`] — the training hot path (`gather*` / `apply_grads*` /
//!   `read_rows`). Every method takes `&self` and is safe to call from N
//!   trainer threads at once: backends synchronize *per node* internally
//!   (the in-process backend keeps each node behind a
//!   [`lock::NodeLock`]; the threaded backend's per-node worker channels
//!   are the natural data plane), so two trainers touching rows owned by
//!   different PS nodes never contend.
//! * [`PsControlPlane`] — checkpoint capture/restore and failure
//!   injection (`snapshot_node` / `load_node` / `reset` / `kill` /
//!   `respawn` / `stats`). In the shared-runtime these run behind an
//!   exclusive *quiesce token* ([`ShardedPs::quiesce`]) that the driver
//!   acquires at the step barrier, preserving the documented checkpoint
//!   consistency point.
//! * [`PsServePlane`] — the online-serving read path. `serve_gather`
//!   never takes a node's lock and never waits on the quiesce token: the
//!   in-process backend reads through a per-node seqlock (retry on a torn
//!   row, bounded spin, then a typed [`ServeError::NodeDown`]), the
//!   threaded backend reads a double-buffered shard view republished at
//!   the step barrier. Serving a dead node is an *error*, never a hang.
//!
//! [`PsBackend`] is the all-planes alias the checkpoint store, the
//! coordinator driver, and the reference loop bound on.
//!
//! Two implementations:
//!
//! * [`crate::embedding::PsCluster`] — the original in-process emulation:
//!   gathers/scatters run inline on the calling thread under per-node
//!   locks. Fast, simple, and the reference for numerical equivalence.
//! * [`ThreadedCluster`] — a concurrent message-passing runtime: every Emb
//!   PS node is its own worker thread owning its shards, served over mpsc
//!   request/reply channels behind a sharded router. Nodes can *actually*
//!   die (worker joined) and respawn while the survivors keep serving —
//!   the systems behaviour the paper emulates (ECRM-style concurrent
//!   recovery, Check-N-Run-style decoupled checkpointing) becomes real.
//!
//! Both backends are **bit-identical**: requests are reassembled in
//! deterministic slot order and per-row updates are applied in sample
//! order per node, so a training run produces the same floats on either
//! backend (the integration suite asserts identical final AUC/logloss).
//! The coordinator is generic over the seam and selects the backend from
//! `JobConfig` / `--backend inproc|threaded`.

pub mod lock;
#[cfg(feature = "loom")]
pub mod models;
pub mod plan;
pub mod seqlock;
pub mod sharded;
pub mod threaded;

pub use plan::{BatchPlan, NodeSet, PlanAccess, PlanArena, PlanScratch};
pub use seqlock::{AtomicF32s, SeqLock};
pub use sharded::{PsQuiesce, ShardedPs, Turnstile};
pub use threaded::ThreadedCluster;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::embedding::{init_value, shard_rows, EmbOptimizer, PsCluster, TableInfo};

/// A full copy of one node's state: per-table shards plus the per-row
/// optimizer accumulators. The unit of checkpoint capture and restore.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeSnapshot {
    pub node: usize,
    /// shards[table], local_row-major [local_rows * dim]
    pub shards: Vec<Vec<f32>>,
    /// opt[table], one f32 per local row
    pub opt: Vec<Vec<f32>>,
}

/// Point-in-time operation counters of a backend (monotone).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BackendStats {
    pub gathers: u64,
    pub applies: u64,
    pub snapshots: u64,
    pub kills: u64,
    pub respawns: u64,
    /// Completed [`PsServePlane::serve_gather`] requests.
    pub serve_reads: u64,
    /// Seqlock retries serving readers paid (torn or in-progress rows);
    /// the threaded backend's snapshot reads never retry, so it stays 0
    /// there.
    pub serve_retries: u64,
    /// Distinct `(table, row)` pairs fetched by planned gathers.
    pub unique_rows: u64,
    /// Duplicate slots planned gathers did *not* re-fetch (slots − uniques);
    /// `dedup_hits / (unique_rows + dedup_hits)` is the measured dedup
    /// ratio of the workload. Unplanned gathers leave both at 0.
    pub dedup_hits: u64,
}

/// The ONE routing definition: global row `r` of any table lives on node
/// `r % n_nodes` at local slot `r / n_nodes`. Every backend, the
/// checkpoint mirror, and the threaded router all call this — checkpoint
/// portability across backends depends on there being no second copy, so
/// implementors must not override [`PsDataPlane::route`].
#[inline]
pub fn route_row(global_row: usize, n_nodes: usize) -> (usize, usize) {
    (global_row % n_nodes, global_row / n_nodes)
}

/// Inverse of [`route_row`]: the global row id living at `node`'s `local`
/// slot. Kept next to its inverse so the ONE routing definition rule
/// covers both directions (delta capture grouping uses this pair).
#[inline]
pub fn unroute_row(node: usize, local: usize, n_nodes: usize) -> usize {
    local * n_nodes + node
}

/// Interior-mutable counters behind `&self` methods; `Clone` snapshots the
/// current values.
#[derive(Debug, Default)]
pub struct StatCounters {
    gathers: AtomicU64,
    applies: AtomicU64,
    snapshots: AtomicU64,
    kills: AtomicU64,
    respawns: AtomicU64,
    serve_reads: AtomicU64,
    serve_retries: AtomicU64,
    unique_rows: AtomicU64,
    dedup_hits: AtomicU64,
}

impl Clone for StatCounters {
    fn clone(&self) -> Self {
        let s = self.read();
        Self {
            gathers: AtomicU64::new(s.gathers),
            applies: AtomicU64::new(s.applies),
            snapshots: AtomicU64::new(s.snapshots),
            kills: AtomicU64::new(s.kills),
            respawns: AtomicU64::new(s.respawns),
            serve_reads: AtomicU64::new(s.serve_reads),
            serve_retries: AtomicU64::new(s.serve_retries),
            unique_rows: AtomicU64::new(s.unique_rows),
            dedup_hits: AtomicU64::new(s.dedup_hits),
        }
    }
}

impl StatCounters {
    pub fn bump_gather(&self) {
        self.gathers.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bump_apply(&self) {
        self.applies.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bump_snapshot(&self) {
        self.snapshots.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bump_kill(&self) {
        self.kills.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bump_respawn(&self) {
        self.respawns.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bump_serve_read(&self) {
        self.serve_reads.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_serve_retries(&self, n: u64) {
        if n > 0 {
            self.serve_retries.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn add_unique_rows(&self, n: u64) {
        if n > 0 {
            self.unique_rows.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn add_dedup_hits(&self, n: u64) {
        if n > 0 {
            self.dedup_hits.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn read(&self) -> BackendStats {
        BackendStats {
            gathers: self.gathers.load(Ordering::Relaxed),
            applies: self.applies.load(Ordering::Relaxed),
            snapshots: self.snapshots.load(Ordering::Relaxed),
            kills: self.kills.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            serve_reads: self.serve_reads.load(Ordering::Relaxed),
            serve_retries: self.serve_retries.load(Ordering::Relaxed),
            unique_rows: self.unique_rows.load(Ordering::Relaxed),
            dedup_hits: self.dedup_hits.load(Ordering::Relaxed),
        }
    }
}

/// The training **data plane** of an Emb PS cluster runtime: everything
/// the per-step hot path needs, `&self`-concurrent with interior per-node
/// synchronization. Row routing is fixed (global row `r` lives on node
/// `r % n_nodes` at local row `r / n_nodes`) so checkpoints taken on one
/// backend restore onto the other.
///
/// Concurrency contract: any number of threads may call these methods
/// simultaneously. Two `apply_grads*` calls that touch the *same* node
/// serialize on that node (in an unspecified order — callers that need
/// determinism sequence same-node updates themselves, see
/// [`ShardedPs::apply_grads_ordered`]); calls touching disjoint nodes
/// proceed in parallel.
pub trait PsDataPlane: Send + Sync {
    /// Short identifier for reports ("inproc" | "threaded").
    fn name(&self) -> &'static str;

    fn tables(&self) -> &[TableInfo];

    fn n_nodes(&self) -> usize;

    /// The backend's operation counters (interior-mutable; the sharded
    /// handle bumps these for operations it composes itself).
    fn counters(&self) -> &StatCounters;

    /// (owner node, local row) of a global row. Fixed for every backend
    /// (see [`route_row`]); do not override.
    #[inline]
    fn route(&self, global_row: usize) -> (usize, usize) {
        route_row(global_row, self.n_nodes())
    }

    /// Single-hot gather: `indices` is [B, T] row-major, `out` [B, T, dim].
    fn gather(&self, indices: &[u32], out: &mut [f32]) {
        self.gather_pooled(indices, 1, out);
    }

    /// Multi-hot gather with sum pooling: `indices` is [B, T, H] row-major,
    /// `out` is [B, T, dim] with out[b,t] = Σ_h row(idx_h).
    fn gather_pooled(&self, indices: &[u32], hotness: usize, out: &mut [f32]);

    /// Plan-driven pooled gather: same result as
    /// [`gather_pooled`](Self::gather_pooled) on `plan.indices()`,
    /// **bit-identical** (the plan's slot-placement map reproduces the
    /// exact reassembly float-op order), but backends that override it
    /// fetch each distinct `(table, row)` once and use `scratch`'s pooled
    /// buffers so the steady-state call allocates nothing (in-proc) or
    /// only bounded mpsc queue blocks (threaded). The default delegates to
    /// the unplanned path, so custom backends and the reference loop are
    /// untouched.
    fn gather_planned(&self, plan: &plan::BatchPlan, scratch: &mut plan::PlanScratch, out: &mut [f32]) {
        let _ = scratch;
        self.gather_pooled(plan.indices(), plan.hotness(), out);
    }

    /// Plan-driven sibling of [`apply_grads_node`](Self::apply_grads_node):
    /// apply only the updates owned by `node`, visiting exactly the slots
    /// the filtered full scan would, in the same ascending-slot (sample)
    /// order — duplicates deliberately still accumulate one by one, so the
    /// result is bit-identical. Overrides skip the full index scan by
    /// walking the plan's per-node slot list. Does not bump the apply
    /// counter (the composing caller does, once per logical batch).
    fn apply_grads_planned_node(
        &self,
        node: usize,
        plan: &plan::BatchPlan,
        scratch: &mut plan::PlanScratch,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let _ = scratch;
        self.apply_grads_node(node, plan.indices(), plan.hotness(), grads, lr, opt);
    }

    /// Sparse update; duplicate rows accumulate in sample order.
    fn apply_grads(
        &self,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    );

    /// Apply only the updates of `indices` owned by `node`, in sample
    /// order, holding only that node's synchronization. The unit the
    /// sharded handle sequences with per-node turnstiles — callers
    /// updating different nodes never contend. Does not bump the apply
    /// counter (the composing caller does, once per logical batch).
    fn apply_grads_node(
        &self,
        node: usize,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    );

    /// Read one row into `out` (len == dim).
    fn read_row(&self, table: usize, global_row: usize, out: &mut [f32]);

    /// Batched row fetch for priority checkpointing: returns the rows'
    /// embedding data ([rows.len() * dim], in `rows` order) and their
    /// optimizer accumulators ([rows.len()]).
    fn read_rows(&self, table: usize, rows: &[u32]) -> (Vec<f32>, Vec<f32>);

    fn total_params(&self) -> usize {
        self.tables().iter().map(|t| t.rows * t.dim).sum()
    }
}

/// The **control plane** of an Emb PS cluster runtime: checkpoint capture
/// and restore, failure injection, recovery, diagnostics. Methods take
/// `&self` (backends synchronize internally), but in the shared runtime
/// they are only reachable through the exclusive quiesce token
/// ([`ShardedPs::quiesce`]) the driver acquires at the step barrier — a
/// control operation never interleaves with an in-flight data-plane call.
pub trait PsControlPlane: PsDataPlane {
    /// Capture one node's full state (checkpoint save path).
    fn snapshot_node(&self, node: usize) -> NodeSnapshot;

    /// Dirty-set export for incremental (format-v2 delta) checkpoint
    /// capture: read `local_rows` (node-local ascending ids) of `table`
    /// on `node`, returning their embedding data ([rows.len() * dim], in
    /// `local_rows` order) and optimizer accumulators — the per-node
    /// sibling of [`PsDataPlane::read_rows`] that clones only the dirty
    /// slice instead of the whole node. The default routes through the
    /// data plane's batched read; backends with direct node storage may
    /// shortcut it.
    fn snapshot_node_rows(
        &self,
        node: usize,
        table: usize,
        local_rows: &[u32],
    ) -> (Vec<f32>, Vec<f32>) {
        let n = self.n_nodes();
        let globals: Vec<u32> = local_rows
            .iter()
            .map(|&lr| unroute_row(node, lr as usize, n) as u32)
            .collect();
        self.read_rows(table, &globals)
    }

    /// Overwrite one node's full state (checkpoint restore path).
    fn load_node(&self, node: usize, shards: &[Vec<f32>], opt: &[Vec<f32>]);

    /// Reset a node to its deterministic initial values (recovery when no
    /// checkpoint covers it).
    fn reset_node_to_init(&self, node: usize);

    /// A failure event hits this node: its state is lost. On the threaded
    /// backend the worker thread really dies; survivors keep serving.
    fn kill_node(&self, node: usize);

    /// Bring a blank replacement for a killed node back online (state at
    /// deterministic init; the recovery protocol then restores it).
    fn respawn_node(&self, node: usize);

    /// Is the node serving? `false` between a kill (or a poison-converted
    /// writer panic) and the matching respawn.
    fn alive(&self, node: usize) -> bool;

    fn stats(&self) -> BackendStats {
        self.counters().read()
    }
}

/// Why a serving read could not be satisfied. Deliberately small: the
/// serving plane's whole contract is "an answer or a typed error,
/// never a hang", so the only failure a reader can see is a node that is
/// not serving (killed, poisoned by a writer panic, or mid-revive).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The owner node of a requested row is down (or stuck mid-write
    /// beyond the reader's spin budget, which only happens when its
    /// writer died). Retry after the recovery protocol revives it.
    NodeDown { node: usize },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::NodeDown { node } => {
                write!(f, "Emb PS node {node} is down; serving read refused")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// The online **serving plane** of an Emb PS cluster runtime: read-only
/// batched gathers that stay wait-free with respect to trainers and the
/// checkpoint quiesce token. `&self`-concurrent from any number of
/// serving threads.
///
/// Consistency contract: a served row is always a value some writer
/// published *in full* — never a torn half-update — but it may be stale
/// by up to one step barrier (the threaded backend serves the view
/// republished at the last barrier; the in-process backend serves live
/// rows through a seqlock, so staleness there is bounded by the
/// in-flight update). Reads of a dead node return
/// [`ServeError::NodeDown`] instead of blocking on recovery.
pub trait PsServePlane: Send + Sync {
    /// Single-hot serving gather: `indices` is [B, T] row-major over this
    /// backend's tables, `out` is [B, T, dim]. Must not take any per-node
    /// lock or the quiesce token. On `Err`, `out` contents are
    /// unspecified.
    fn serve_gather(&self, indices: &[u32], out: &mut [f32]) -> Result<(), ServeError>;

    /// Republish the serving view (called by the coordinator at the step
    /// barrier, outside any quiesce). Backends that serve live state
    /// (seqlock) need no publication step — the default is a no-op.
    fn publish_serve_view(&self) {}
}

/// All planes — what the checkpoint store, the coordinator driver, and
/// the single-trainer reference loop bound on. Blanket-implemented; bound
/// on the narrower plane where possible.
pub trait PsBackend: PsControlPlane + PsServePlane {}

impl<T: PsControlPlane + PsServePlane + ?Sized> PsBackend for T {}

// ---------------------------------------------------------------------------
// the original in-process cluster as a backend
// ---------------------------------------------------------------------------

impl PsDataPlane for PsCluster {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn tables(&self) -> &[TableInfo] {
        &self.tables
    }

    fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    fn counters(&self) -> &StatCounters {
        &self.stats
    }

    fn gather_pooled(&self, indices: &[u32], hotness: usize, out: &mut [f32]) {
        self.stats.bump_gather();
        PsCluster::gather_pooled(self, indices, hotness, out);
    }

    fn gather_planned(
        &self,
        plan: &plan::BatchPlan,
        scratch: &mut plan::PlanScratch,
        out: &mut [f32],
    ) {
        self.stats.bump_gather();
        self.stats.add_unique_rows(plan.n_unique() as u64);
        self.stats.add_dedup_hits(plan.dedup_hits() as u64);
        PsCluster::gather_planned_impl(self, plan, scratch, out);
    }

    fn apply_grads_planned_node(
        &self,
        node: usize,
        plan: &plan::BatchPlan,
        scratch: &mut plan::PlanScratch,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        PsCluster::apply_grads_planned_node_impl(self, node, plan, scratch, grads, lr, opt);
    }

    fn apply_grads(
        &self,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        self.stats.bump_apply();
        PsCluster::apply_grads(self, indices, hotness, grads, lr, opt);
    }

    fn apply_grads_node(
        &self,
        node: usize,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        PsCluster::apply_grads_node(self, node, indices, hotness, grads, lr, opt);
    }

    fn read_row(&self, table: usize, global_row: usize, out: &mut [f32]) {
        PsCluster::read_row(self, table, global_row, out);
    }

    fn read_rows(&self, table: usize, rows: &[u32]) -> (Vec<f32>, Vec<f32>) {
        PsCluster::read_rows(self, table, rows)
    }
}

impl PsControlPlane for PsCluster {
    fn snapshot_node(&self, node: usize) -> NodeSnapshot {
        self.stats.bump_snapshot();
        let (shards, opt) = self.snapshot_parts(node);
        NodeSnapshot { node, shards, opt }
    }

    fn snapshot_node_rows(
        &self,
        node: usize,
        table: usize,
        local_rows: &[u32],
    ) -> (Vec<f32>, Vec<f32>) {
        // one read guard on the one node, instead of the default's
        // global-id routing pass
        PsCluster::snapshot_node_rows_local(self, node, table, local_rows)
    }

    fn load_node(&self, node: usize, shards: &[Vec<f32>], opt: &[Vec<f32>]) {
        PsCluster::load_node(self, node, shards, opt);
    }

    fn reset_node_to_init(&self, node: usize) {
        PsCluster::reset_node_to_init(self, node);
    }

    fn kill_node(&self, node: usize) {
        self.stats.bump_kill();
        PsCluster::kill_node(self, node);
    }

    fn respawn_node(&self, node: usize) {
        self.stats.bump_respawn();
        PsCluster::respawn_node(self, node);
    }

    fn alive(&self, node: usize) -> bool {
        PsCluster::alive(self, node)
    }
}

impl PsServePlane for PsCluster {
    fn serve_gather(&self, indices: &[u32], out: &mut [f32]) -> Result<(), ServeError> {
        PsCluster::serve_gather(self, indices, out)
    }
    // publish_serve_view: default no-op — the seqlock serves live rows.
}

/// Initial state of one node, shared by both backends so a fresh
/// `ThreadedCluster` worker is bit-identical to a fresh `PsCluster` node.
pub(crate) fn init_node_state(
    tables: &[TableInfo],
    n_nodes: usize,
    node_id: usize,
    seed: u64,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut shards = Vec::with_capacity(tables.len());
    let mut opt = Vec::with_capacity(tables.len());
    for (t, info) in tables.iter().enumerate() {
        let local_rows = shard_rows(info.rows, n_nodes, node_id);
        let mut shard = vec![0.0f32; local_rows * info.dim];
        for lr in 0..local_rows {
            let global = node_id + lr * n_nodes;
            for d in 0..info.dim {
                shard[lr * info.dim + d] = init_value(seed, t, global, d);
            }
        }
        shards.push(shard);
        opt.push(vec![0.0f32; local_rows]);
    }
    (shards, opt)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> PsCluster {
        PsCluster::new(
            vec![TableInfo { rows: 11, dim: 4 }, TableInfo { rows: 6, dim: 4 }],
            3,
            5,
        )
    }

    #[test]
    fn trait_gather_matches_inherent() {
        let c = cluster();
        let idx = vec![0u32, 1, 10, 5, 3, 2];
        let mut a = vec![0.0; 3 * 2 * 4];
        let mut b = vec![0.0; 3 * 2 * 4];
        PsCluster::gather(&c, &idx, &mut a);
        PsDataPlane::gather(&c, &idx, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn read_rows_matches_read_row() {
        let c = cluster();
        PsDataPlane::apply_grads(&c, &[4, 2], 1, &[0.3f32; 8], 1.0,
                                 EmbOptimizer::RowAdagrad { eps: 1e-8 });
        let rows = vec![4u32, 0, 7];
        let (data, opt) = PsDataPlane::read_rows(&c, 0, &rows);
        let mut want = vec![0.0; 4];
        for (i, &r) in rows.iter().enumerate() {
            c.read_row(0, r as usize, &mut want);
            assert_eq!(&data[i * 4..(i + 1) * 4], &want[..]);
            let (node, local) = PsCluster::route(&c, r as usize);
            assert_eq!(opt[i], c.opt_shard(node, 0)[local]);
        }
    }

    #[test]
    fn snapshot_load_roundtrip() {
        let c = cluster();
        PsDataPlane::apply_grads(&c, &[3, 1], 1, &[1.0f32; 8], 0.5,
                                 EmbOptimizer::Sgd);
        let snap = PsControlPlane::snapshot_node(&c, 0);
        assert_eq!(snap.node, 0);
        PsDataPlane::apply_grads(&c, &[3, 1], 1, &[1.0f32; 8], 0.5,
                                 EmbOptimizer::Sgd);
        let after = PsControlPlane::snapshot_node(&c, 0);
        assert_ne!(snap, after);
        PsControlPlane::load_node(&c, 0, &snap.shards, &snap.opt);
        assert_eq!(PsControlPlane::snapshot_node(&c, 0).shards, snap.shards);
    }

    #[test]
    fn kill_wipes_to_init_and_stats_count() {
        let c = cluster();
        PsDataPlane::apply_grads(&c, &[3, 1], 1, &[1.0f32; 8], 0.5,
                                 EmbOptimizer::Sgd);
        PsControlPlane::kill_node(&c, 0); // row 3 lives on node 0 (3 % 3)
        assert!(!PsControlPlane::alive(&c, 0));
        PsControlPlane::respawn_node(&c, 0);
        assert!(PsControlPlane::alive(&c, 0));
        let fresh = cluster();
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        c.read_row(0, 3, &mut a);
        fresh.read_row(0, 3, &mut b);
        assert_eq!(a, b);
        let s = PsControlPlane::stats(&c);
        assert_eq!((s.kills, s.respawns, s.applies), (1, 1, 1));
    }

    #[test]
    fn snapshot_node_rows_matches_read_rows_on_both_paths() {
        let c = cluster();
        PsDataPlane::apply_grads(&c, &[4, 2, 7, 5], 1, &[0.7f32; 16], 1.0,
                                 EmbOptimizer::RowAdagrad { eps: 1e-8 });
        let n = c.n_nodes;
        for node in 0..n {
            // every local row of table 0 this node owns
            let local_rows: Vec<u32> =
                (0..crate::embedding::shard_rows(11, n, node) as u32).collect();
            // the overridden fast path
            let (data, opt) =
                PsControlPlane::snapshot_node_rows(&c, node, 0, &local_rows);
            // the trait-default path (global-id routing through read_rows)
            let globals: Vec<u32> = local_rows
                .iter()
                .map(|&lr| lr * n as u32 + node as u32)
                .collect();
            let (want_data, want_opt) = PsDataPlane::read_rows(&c, 0, &globals);
            assert_eq!(data, want_data, "node {node}");
            assert_eq!(opt, want_opt, "node {node}");
            // and it agrees with the full-node snapshot slice
            let snap = PsControlPlane::snapshot_node(&c, node);
            assert_eq!(&data[..], &snap.shards[0][..local_rows.len() * 4],
                       "node {node}");
        }
    }

    #[test]
    fn planned_gather_and_apply_match_unplanned_and_count_dedup() {
        let c = cluster();
        let opt = EmbOptimizer::RowAdagrad { eps: 1e-8 };
        // hotness 2, batch 2, tables 2 — with duplicates (row 4 twice in
        // t0, row 2 across both tables).
        let idx = vec![4u32, 4, 2, 5, 2, 7, 1, 2];
        let mut arena = PlanArena::new();
        arena.build(&idx, 2, 2, c.n_nodes);
        let (plan, scratch) = arena.parts_mut();

        let mut want = vec![0.0; 2 * 2 * 4];
        PsDataPlane::gather_pooled(&c, &idx, 2, &mut want);
        let mut got = vec![0.0; 2 * 2 * 4];
        PsDataPlane::gather_planned(&c, plan, scratch, &mut got);
        assert_eq!(want, got);
        let s = PsControlPlane::stats(&c);
        assert_eq!(s.unique_rows + s.dedup_hits, idx.len() as u64);
        assert_eq!(s.unique_rows, plan.n_unique() as u64);
        assert!(s.dedup_hits >= 2);

        // Planned per-node applies ≡ full apply_grads on a twin cluster.
        let twin = cluster();
        let grads = vec![0.25f32; 2 * 2 * 4];
        PsDataPlane::apply_grads(&twin, &idx, 2, &grads, 0.7, opt);
        for node in 0..c.n_nodes {
            if plan.touched().get(node) {
                PsDataPlane::apply_grads_planned_node(&c, node, plan, scratch, &grads, 0.7, opt);
            }
        }
        c.counters().bump_apply();
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        for t in 0..2 {
            let rows = if t == 0 { 11 } else { 6 };
            for r in 0..rows {
                c.read_row(t, r, &mut a);
                twin.read_row(t, r, &mut b);
                assert_eq!(a, b, "table {t} row {r}");
            }
        }
    }

    #[test]
    fn init_node_state_matches_pscluster() {
        let c = PsCluster::new(
            vec![TableInfo { rows: 13, dim: 3 }],
            4,
            77,
        );
        for node in 0..4 {
            let (shards, opt) = init_node_state(c.tables(), 4, node, 77);
            let snap = PsControlPlane::snapshot_node(&c, node);
            assert_eq!(shards, snap.shards, "node {node}");
            assert_eq!(opt, snap.opt);
        }
    }
}
