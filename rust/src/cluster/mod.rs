//! The Emb PS cluster runtime seam: a [`PsBackend`] trait over *how* the
//! sharded embedding parameter servers execute, with two implementations:
//!
//! * [`crate::embedding::PsCluster`] — the original in-process, synchronous
//!   emulation: every gather/scatter runs inline on the coordinator thread.
//!   Fast, simple, and the reference for numerical equivalence.
//! * [`ThreadedCluster`] — a concurrent message-passing runtime: every Emb
//!   PS node is its own worker thread owning its shards, served over mpsc
//!   request/reply channels behind a sharded router. Nodes can *actually*
//!   die (worker joined) and respawn while the survivors keep serving —
//!   the systems behaviour the paper emulates (ECRM-style concurrent
//!   recovery, Check-N-Run-style decoupled checkpointing) becomes real.
//!
//! Both backends are **bit-identical**: requests are reassembled in
//! deterministic slot order and per-row updates are applied in sample
//! order, so a training run produces the same floats on either backend
//! (the integration suite asserts identical final AUC/logloss). The
//! coordinator is generic over the trait and selects the backend from
//! `JobConfig` / `--backend inproc|threaded`.

pub mod threaded;

pub use threaded::ThreadedCluster;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::embedding::{init_value, shard_rows, EmbOptimizer, PsCluster, TableInfo};

/// A full copy of one node's state: per-table shards plus the per-row
/// optimizer accumulators. The unit of checkpoint capture and restore.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeSnapshot {
    pub node: usize,
    /// shards[table], local_row-major [local_rows * dim]
    pub shards: Vec<Vec<f32>>,
    /// opt[table], one f32 per local row
    pub opt: Vec<Vec<f32>>,
}

/// Point-in-time operation counters of a backend (monotone).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BackendStats {
    pub gathers: u64,
    pub applies: u64,
    pub snapshots: u64,
    pub kills: u64,
    pub respawns: u64,
}

/// The ONE routing definition: global row `r` of any table lives on node
/// `r % n_nodes` at local slot `r / n_nodes`. Every backend, the
/// checkpoint mirror, and the threaded router all call this — checkpoint
/// portability across backends depends on there being no second copy, so
/// implementors must not override [`PsBackend::route`].
#[inline]
pub fn route_row(global_row: usize, n_nodes: usize) -> (usize, usize) {
    (global_row % n_nodes, global_row / n_nodes)
}

/// Interior-mutable counters behind `&self` methods; `Clone` snapshots the
/// current values (so `PsCluster` stays `Clone`).
#[derive(Debug, Default)]
pub struct StatCounters {
    gathers: AtomicU64,
    applies: AtomicU64,
    snapshots: AtomicU64,
    kills: AtomicU64,
    respawns: AtomicU64,
}

impl Clone for StatCounters {
    fn clone(&self) -> Self {
        let s = self.read();
        Self {
            gathers: AtomicU64::new(s.gathers),
            applies: AtomicU64::new(s.applies),
            snapshots: AtomicU64::new(s.snapshots),
            kills: AtomicU64::new(s.kills),
            respawns: AtomicU64::new(s.respawns),
        }
    }
}

impl StatCounters {
    pub fn bump_gather(&self) {
        self.gathers.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bump_apply(&self) {
        self.applies.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bump_snapshot(&self) {
        self.snapshots.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bump_kill(&self) {
        self.kills.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bump_respawn(&self) {
        self.respawns.fetch_add(1, Ordering::Relaxed);
    }

    pub fn read(&self) -> BackendStats {
        BackendStats {
            gathers: self.gathers.load(Ordering::Relaxed),
            applies: self.applies.load(Ordering::Relaxed),
            snapshots: self.snapshots.load(Ordering::Relaxed),
            kills: self.kills.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
        }
    }
}

/// What the coordinator, checkpoint store, and priority trackers need from
/// an Emb PS cluster runtime. Row routing is fixed (global row `r` lives on
/// node `r % n_nodes` at local row `r / n_nodes`) so checkpoints taken on
/// one backend restore onto the other.
///
/// `Send + Sync` because the data-parallel trainer runtime serves N
/// trainer threads from one backend through [`SharedPs`]: read-path
/// methods (`gather*`, `read_rows`, `snapshot_node`) take `&self` and run
/// under concurrent read locks, mutating methods behind a write lock.
pub trait PsBackend: Send + Sync {
    /// Short identifier for reports ("inproc" | "threaded").
    fn name(&self) -> &'static str;

    fn tables(&self) -> &[TableInfo];

    fn n_nodes(&self) -> usize;

    /// (owner node, local row) of a global row. Fixed for every backend
    /// (see [`route_row`]); do not override.
    #[inline]
    fn route(&self, global_row: usize) -> (usize, usize) {
        route_row(global_row, self.n_nodes())
    }

    /// Single-hot gather: `indices` is [B, T] row-major, `out` [B, T, dim].
    fn gather(&self, indices: &[u32], out: &mut [f32]) {
        self.gather_pooled(indices, 1, out);
    }

    /// Multi-hot gather with sum pooling: `indices` is [B, T, H] row-major,
    /// `out` is [B, T, dim] with out[b,t] = Σ_h row(idx_h).
    fn gather_pooled(&self, indices: &[u32], hotness: usize, out: &mut [f32]);

    /// Sparse update; duplicate rows accumulate in sample order.
    fn apply_grads(
        &mut self,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    );

    /// Read one row into `out` (len == dim).
    fn read_row(&self, table: usize, global_row: usize, out: &mut [f32]);

    /// Batched row fetch for priority checkpointing: returns the rows'
    /// embedding data ([rows.len() * dim], in `rows` order) and their
    /// optimizer accumulators ([rows.len()]).
    fn read_rows(&self, table: usize, rows: &[u32]) -> (Vec<f32>, Vec<f32>);

    /// Capture one node's full state (checkpoint save path).
    fn snapshot_node(&self, node: usize) -> NodeSnapshot;

    /// Overwrite one node's full state (checkpoint restore path).
    fn load_node(&mut self, node: usize, shards: &[Vec<f32>], opt: &[Vec<f32>]);

    /// Reset a node to its deterministic initial values (recovery when no
    /// checkpoint covers it).
    fn reset_node_to_init(&mut self, node: usize);

    /// A failure event hits this node: its state is lost. On the threaded
    /// backend the worker thread really dies; survivors keep serving.
    fn kill_node(&mut self, node: usize);

    /// Bring a blank replacement for a killed node back online (state at
    /// deterministic init; the recovery protocol then restores it).
    fn respawn_node(&mut self, node: usize);

    fn total_params(&self) -> usize {
        self.tables().iter().map(|t| t.rows * t.dim).sum()
    }

    fn stats(&self) -> BackendStats;
}

// ---------------------------------------------------------------------------
// shared backend handle for concurrent trainers
// ---------------------------------------------------------------------------

/// A cloneable handle that lets many trainer threads drive one
/// [`PsBackend`] concurrently: gathers (and every other `&self` method)
/// run under a shared read lock — on the threaded backend the per-node
/// workers genuinely interleave requests from different trainers — while
/// sparse updates and control-plane operations (kill / respawn / restore)
/// take the write lock. Determinism is the *caller's* contract: the
/// trainer runtime orders `apply_grads` calls by trainer rank (see
/// `crate::trainer::Turnstile`), so a run is reproducible even though the
/// load is concurrent.
pub struct SharedPs<B: PsBackend>(Arc<RwLock<B>>);

impl<B: PsBackend> Clone for SharedPs<B> {
    fn clone(&self) -> Self {
        Self(Arc::clone(&self.0))
    }
}

impl<B: PsBackend> SharedPs<B> {
    pub fn new(backend: B) -> Self {
        Self(Arc::new(RwLock::new(backend)))
    }

    /// Shared (read) access: gathers, row reads, snapshots.
    pub fn read(&self) -> RwLockReadGuard<'_, B> {
        self.0.read().unwrap_or_else(PoisonError::into_inner)
    }

    /// Exclusive (write) access: sparse updates, kill/respawn, restores.
    pub fn write(&self) -> RwLockWriteGuard<'_, B> {
        self.0.write().unwrap_or_else(PoisonError::into_inner)
    }
}

// ---------------------------------------------------------------------------
// the original in-process cluster as a backend
// ---------------------------------------------------------------------------

impl PsBackend for PsCluster {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn tables(&self) -> &[TableInfo] {
        &self.tables
    }

    fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    fn gather_pooled(&self, indices: &[u32], hotness: usize, out: &mut [f32]) {
        self.stats.bump_gather();
        PsCluster::gather_pooled(self, indices, hotness, out);
    }

    fn apply_grads(
        &mut self,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        self.stats.bump_apply();
        PsCluster::apply_grads(self, indices, hotness, grads, lr, opt);
    }

    fn read_row(&self, table: usize, global_row: usize, out: &mut [f32]) {
        PsCluster::read_row(self, table, global_row, out);
    }

    fn read_rows(&self, table: usize, rows: &[u32]) -> (Vec<f32>, Vec<f32>) {
        let dim = self.tables[table].dim;
        let mut data = vec![0.0f32; rows.len() * dim];
        let mut opt = vec![0.0f32; rows.len()];
        for (i, &row) in rows.iter().enumerate() {
            let (node, local) = PsCluster::route(self, row as usize);
            data[i * dim..(i + 1) * dim]
                .copy_from_slice(&self.shard(node, table)[local * dim..(local + 1) * dim]);
            opt[i] = self.opt_shard(node, table)[local];
        }
        (data, opt)
    }

    fn snapshot_node(&self, node: usize) -> NodeSnapshot {
        self.stats.bump_snapshot();
        NodeSnapshot {
            node,
            shards: (0..self.tables.len()).map(|t| self.shard(node, t).to_vec()).collect(),
            opt: (0..self.tables.len()).map(|t| self.opt_shard(node, t).to_vec()).collect(),
        }
    }

    fn load_node(&mut self, node: usize, shards: &[Vec<f32>], opt: &[Vec<f32>]) {
        for t in 0..self.tables.len() {
            self.shard_mut(node, t).copy_from_slice(&shards[t]);
            self.opt_shard_mut(node, t).copy_from_slice(&opt[t]);
        }
    }

    fn reset_node_to_init(&mut self, node: usize) {
        PsCluster::reset_node_to_init(self, node);
    }

    fn kill_node(&mut self, node: usize) {
        // in-process emulation of a node death: its state is wiped
        self.stats.bump_kill();
        PsCluster::reset_node_to_init(self, node);
    }

    fn respawn_node(&mut self, _node: usize) {
        self.stats.bump_respawn();
    }

    fn stats(&self) -> BackendStats {
        self.stats.read()
    }
}

/// Initial state of one node, shared by both backends so a fresh
/// `ThreadedCluster` worker is bit-identical to a fresh `PsCluster` node.
pub(crate) fn init_node_state(
    tables: &[TableInfo],
    n_nodes: usize,
    node_id: usize,
    seed: u64,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut shards = Vec::with_capacity(tables.len());
    let mut opt = Vec::with_capacity(tables.len());
    for (t, info) in tables.iter().enumerate() {
        let local_rows = shard_rows(info.rows, n_nodes, node_id);
        let mut shard = vec![0.0f32; local_rows * info.dim];
        for lr in 0..local_rows {
            let global = node_id + lr * n_nodes;
            for d in 0..info.dim {
                shard[lr * info.dim + d] = init_value(seed, t, global, d);
            }
        }
        shards.push(shard);
        opt.push(vec![0.0f32; local_rows]);
    }
    (shards, opt)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> PsCluster {
        PsCluster::new(
            vec![TableInfo { rows: 11, dim: 4 }, TableInfo { rows: 6, dim: 4 }],
            3,
            5,
        )
    }

    #[test]
    fn trait_gather_matches_inherent() {
        let c = cluster();
        let idx = vec![0u32, 1, 10, 5, 3, 2];
        let mut a = vec![0.0; 3 * 2 * 4];
        let mut b = vec![0.0; 3 * 2 * 4];
        PsCluster::gather(&c, &idx, &mut a);
        PsBackend::gather(&c, &idx, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn read_rows_matches_read_row() {
        let mut c = cluster();
        PsBackend::apply_grads(&mut c, &[4, 2], 1, &[0.3f32; 8], 1.0,
                               EmbOptimizer::RowAdagrad { eps: 1e-8 });
        let rows = vec![4u32, 0, 7];
        let (data, opt) = c.read_rows(0, &rows);
        let mut want = vec![0.0; 4];
        for (i, &r) in rows.iter().enumerate() {
            c.read_row(0, r as usize, &mut want);
            assert_eq!(&data[i * 4..(i + 1) * 4], &want[..]);
            let (node, local) = PsCluster::route(&c, r as usize);
            assert_eq!(opt[i], c.opt_shard(node, 0)[local]);
        }
    }

    #[test]
    fn snapshot_load_roundtrip() {
        let mut c = cluster();
        PsBackend::apply_grads(&mut c, &[3, 1], 1, &[1.0f32; 8], 0.5,
                               EmbOptimizer::Sgd);
        let snap = c.snapshot_node(0);
        assert_eq!(snap.node, 0);
        PsBackend::apply_grads(&mut c, &[3, 1], 1, &[1.0f32; 8], 0.5,
                               EmbOptimizer::Sgd);
        let after = c.snapshot_node(0);
        assert_ne!(snap, after);
        c.load_node(0, &snap.shards, &snap.opt);
        assert_eq!(c.snapshot_node(0).shards, snap.shards);
    }

    #[test]
    fn kill_wipes_to_init_and_stats_count() {
        let mut c = cluster();
        PsBackend::apply_grads(&mut c, &[3, 1], 1, &[1.0f32; 8], 0.5,
                               EmbOptimizer::Sgd);
        c.kill_node(0); // row 3 lives on node 0 (3 % 3)
        c.respawn_node(0);
        let fresh = cluster();
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        c.read_row(0, 3, &mut a);
        fresh.read_row(0, 3, &mut b);
        assert_eq!(a, b);
        let s = PsBackend::stats(&c);
        assert_eq!((s.kills, s.respawns, s.applies), (1, 1, 1));
    }

    #[test]
    fn shared_handle_serves_concurrent_gathers() {
        // 4 threads gather through one SharedPs handle at once; every
        // result must match the single-threaded reference, and a write
        // (sparse update) afterwards must still go through.
        let reference = cluster();
        let idx = vec![0u32, 1, 10, 5, 3, 2];
        let mut want = vec![0.0f32; 3 * 2 * 4];
        PsBackend::gather(&reference, &idx, &mut want);
        let shared = SharedPs::new(cluster());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let shared = shared.clone();
                let idx = idx.clone();
                let want = want.clone();
                s.spawn(move || {
                    for _ in 0..50 {
                        let mut out = vec![0.0f32; 3 * 2 * 4];
                        PsBackend::gather(&*shared.read(), &idx, &mut out);
                        assert_eq!(out, want);
                    }
                });
            }
        });
        PsBackend::apply_grads(&mut *shared.write(), &idx[..2], 1,
                               &[0.1f32; 8], 1.0, EmbOptimizer::Sgd);
        assert_eq!(PsBackend::stats(&*shared.read()).applies, 1);
    }

    #[test]
    fn init_node_state_matches_pscluster() {
        let c = PsCluster::new(
            vec![TableInfo { rows: 13, dim: 3 }],
            4,
            77,
        );
        for node in 0..4 {
            let (shards, opt) = init_node_state(c.tables(), 4, node, 77);
            let snap = c.snapshot_node(node);
            assert_eq!(shards, snap.shards, "node {node}");
            assert_eq!(opt, snap.opt);
        }
    }
}
