//! [`ShardedPs`] — the sharded shared handle that replaced the global
//! `SharedPs(Arc<RwLock<B>>)`.
//!
//! The old handle funneled every gather and every sparse update from all
//! N trainers through one global lock, so cross-node writes serialized
//! and the trainer-scaling benches measured lock convoying instead of PS
//! throughput. The paper's premise is the opposite: the Emb PS cluster is
//! *sharded*, node failures are independent, and per-shard concurrency is
//! what makes PS-side fault tolerance cheap (ECRM). This handle makes the
//! seam match:
//!
//! * **data plane** — `gather*` / `read_rows` / `apply_grads*` go straight
//!   to the backend's `&self` methods (per-node interior locks); two
//!   trainers touching rows owned by different PS nodes never contend.
//!   All data-plane calls hold a shared *epoch* read lock, which only
//!   excludes the control plane, never each other.
//! * **ordered updates** — [`ShardedPs::apply_grads_ordered`] sequences
//!   same-node updates across trainers with one [`Turnstile`] *per node*
//!   (the old runtime had a single global turnstile): trainer rank order
//!   is enforced within each node's queue only, so rank r+1 can be
//!   applying on node A while rank r is still applying on node B.
//!   Per-node sample-order apply keeps the floats bit-identical to the
//!   old global rank-ordered scatter — each row lives on exactly one
//!   node, so the per-row update sequence is unchanged.
//! * **control plane** — [`ShardedPs::quiesce`] hands out the exclusive
//!   epoch write lock as a [`PsQuiesce`] token. Checkpoint capture,
//!   failure injection, and restores go through the token, which the
//!   driver acquires at the step barrier (every trainer idle ⇒ the lock
//!   is free); a control operation can never interleave with an in-flight
//!   gather or scatter.

use std::ops::Deref;
use std::sync::{
    Arc, Condvar, Mutex, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

use crate::embedding::{EmbOptimizer, TableInfo};
use crate::telemetry;

use super::plan::{BatchPlan, NodeSet, PlanScratch};
use super::{PsBackend, PsDataPlane, PsServePlane, ServeError, StatCounters};

/// A monotone ticket sequencer: thread `wait_for(t)` blocks until every
/// ticket `< t` has been consumed via [`Turnstile::advance`]. The sharded
/// handle keeps one per PS node, so rank order is enforced only within a
/// node's update queue.
pub struct Turnstile {
    next: Mutex<u64>,
    cv: Condvar,
}

impl Default for Turnstile {
    fn default() -> Self {
        Self::new()
    }
}

impl Turnstile {
    pub fn new() -> Self {
        Self { next: Mutex::new(0), cv: Condvar::new() }
    }

    /// Block until `ticket` is the next to be served.
    pub fn wait_for(&self, ticket: u64) {
        let mut g = self.next.lock().unwrap_or_else(PoisonError::into_inner);
        while *g != ticket {
            g = self.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Consume the current ticket, releasing the next waiter.
    pub fn advance(&self) {
        let mut g = self.next.lock().unwrap_or_else(PoisonError::into_inner);
        *g += 1;
        self.cv.notify_all();
    }
}

struct Inner<B> {
    backend: B,
    /// Epoch lock: data-plane calls share it (read), the quiesce token is
    /// exclusive (write). Guards only the `()` — the real state sits
    /// behind the backend's per-node synchronization.
    epoch: RwLock<()>,
    /// One per PS node: sequences same-node sparse updates by ticket.
    turnstiles: Vec<Turnstile>,
}

/// Cloneable sharded handle over one [`PsBackend`] (see module docs).
pub struct ShardedPs<B: PsBackend> {
    inner: Arc<Inner<B>>,
}

impl<B: PsBackend> Clone for ShardedPs<B> {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

impl<B: PsBackend> ShardedPs<B> {
    pub fn new(backend: B) -> Self {
        let n = backend.n_nodes();
        Self {
            inner: Arc::new(Inner {
                backend,
                epoch: RwLock::new(()),
                turnstiles: (0..n).map(|_| Turnstile::new()).collect(),
            }),
        }
    }

    fn epoch_read(&self) -> RwLockReadGuard<'_, ()> {
        // the lock guards (), so std-poison carries no information
        self.inner.epoch.read().unwrap_or_else(PoisonError::into_inner)
    }

    /// Rank-ordered sparse update: same-node updates across callers apply
    /// in ascending `ticket` order (per-node turnstiles), node-disjoint
    /// updates in parallel. Tickets must be dense: every ticket below the
    /// highest ever passed must eventually reach this method or
    /// [`ShardedPs::skip_ordered`], or later tickets block forever.
    pub fn apply_grads_ordered(
        &self,
        ticket: u64,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let _epoch = self.epoch_read();
        let n = self.inner.backend.n_nodes();
        let mut touched = NodeSet::new();
        for &row in indices {
            touched.insert(row as usize % n);
        }
        for node in 0..n {
            {
                let _t = telemetry::span_node("turnstile_wait", node);
                self.inner.turnstiles[node].wait_for(ticket);
            }
            if touched.get(node) {
                let _a = telemetry::span_node("apply_node", node);
                self.inner
                    .backend
                    .apply_grads_node(node, indices, hotness, grads, lr, opt);
            }
            self.inner.turnstiles[node].advance();
        }
        self.inner.backend.counters().bump_apply();
    }

    /// Plan-driven sibling of [`ShardedPs::apply_grads_ordered`]: the
    /// same per-node turnstile sequencing and telemetry, but the touched
    /// set and each node's slot list come from the plan — no re-scan of
    /// the index list and no per-call allocation. Bit-identical: each
    /// node's planned apply visits the same slots in the same sample
    /// order as the filtered full scan.
    pub fn apply_grads_ordered_planned(
        &self,
        ticket: u64,
        plan: &BatchPlan,
        scratch: &mut PlanScratch,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let _epoch = self.epoch_read();
        let n = self.inner.backend.n_nodes();
        for node in 0..n {
            {
                let _t = telemetry::span_node("turnstile_wait", node);
                self.inner.turnstiles[node].wait_for(ticket);
            }
            if plan.touched().get(node) {
                let _a = telemetry::span_node("apply_node", node);
                self.inner
                    .backend
                    .apply_grads_planned_node(node, plan, scratch, grads, lr, opt);
            }
            self.inner.turnstiles[node].advance();
        }
        self.inner.backend.counters().bump_apply();
    }

    /// Consume `ticket` on every node without applying anything — a
    /// participant that failed to produce an update must still pass its
    /// turn through every node queue, or every later ticket deadlocks.
    pub fn skip_ordered(&self, ticket: u64) {
        let _epoch = self.epoch_read();
        for ts in &self.inner.turnstiles {
            ts.wait_for(ticket);
            ts.advance();
        }
    }

    /// Acquire the exclusive quiesce token for control-plane operations
    /// (checkpoint capture/restore, kill/respawn). Blocks until every
    /// in-flight data-plane call drains; the driver calls this at the
    /// step barrier, where the handle is idle and acquisition is free.
    pub fn quiesce(&self) -> PsQuiesce<'_, B> {
        let _q = telemetry::span("quiesce");
        PsQuiesce {
            _epoch: self.inner.epoch.write().unwrap_or_else(PoisonError::into_inner),
            backend: &self.inner.backend,
        }
    }

    /// Current backend stats — a lock-free diagnostic read straight off
    /// the atomic counters. Deliberately NOT routed through
    /// [`ShardedPs::quiesce`] or the epoch lock: serving threads poll
    /// this (e.g. for `serve_reads`/`serve_retries`) while a checkpoint
    /// capture holds the quiesce token, and a stats read must never fence
    /// against the control plane. The quiesce-fenced sibling is
    /// [`super::PsControlPlane::stats`] via the [`PsQuiesce`] token.
    pub fn stats(&self) -> super::BackendStats {
        self.inner.backend.counters().read()
    }
}

/// Serving reads bypass the epoch lock entirely — THE non-blocking
/// guarantee of the serving plane. A `serve_gather` must complete while a
/// checkpoint capture (or any control op) holds the exclusive quiesce
/// token; the backends make that safe (seqlock validation in-process,
/// immutable published views on the threaded runtime), so the handle has
/// nothing to add but the pass-through. `publish_serve_view` *does* take
/// the epoch read lock: it is called from the driver between steps and
/// must not interleave with a control op swapping node state.
impl<B: PsBackend> PsServePlane for ShardedPs<B> {
    fn serve_gather(&self, indices: &[u32], out: &mut [f32]) -> Result<(), ServeError> {
        self.inner.backend.serve_gather(indices, out)
    }

    fn publish_serve_view(&self) {
        let _epoch = self.epoch_read();
        self.inner.backend.publish_serve_view();
    }
}

/// Data-plane reads/writes go straight through the handle (shared epoch
/// lock), so evaluation and benches can treat it as a [`PsDataPlane`].
/// The trait's `apply_grads` here is *unordered* across threads — the
/// trainer runtime uses [`ShardedPs::apply_grads_ordered`] instead.
impl<B: PsBackend> PsDataPlane for ShardedPs<B> {
    fn name(&self) -> &'static str {
        self.inner.backend.name()
    }

    fn tables(&self) -> &[TableInfo] {
        self.inner.backend.tables()
    }

    fn n_nodes(&self) -> usize {
        self.inner.backend.n_nodes()
    }

    fn counters(&self) -> &StatCounters {
        self.inner.backend.counters()
    }

    fn gather_pooled(&self, indices: &[u32], hotness: usize, out: &mut [f32]) {
        let _g = telemetry::span("gather");
        let _epoch = self.epoch_read();
        self.inner.backend.gather_pooled(indices, hotness, out);
    }

    fn gather_planned(&self, plan: &BatchPlan, scratch: &mut PlanScratch, out: &mut [f32]) {
        let _g = telemetry::span("gather");
        let _epoch = self.epoch_read();
        self.inner.backend.gather_planned(plan, scratch, out);
    }

    fn apply_grads_planned_node(
        &self,
        node: usize,
        plan: &BatchPlan,
        scratch: &mut PlanScratch,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let _epoch = self.epoch_read();
        self.inner
            .backend
            .apply_grads_planned_node(node, plan, scratch, grads, lr, opt);
    }

    fn apply_grads(
        &self,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let _epoch = self.epoch_read();
        self.inner.backend.apply_grads(indices, hotness, grads, lr, opt);
    }

    fn apply_grads_node(
        &self,
        node: usize,
        indices: &[u32],
        hotness: usize,
        grads: &[f32],
        lr: f32,
        opt: EmbOptimizer,
    ) {
        let _epoch = self.epoch_read();
        self.inner.backend.apply_grads_node(node, indices, hotness, grads, lr, opt);
    }

    fn read_row(&self, table: usize, global_row: usize, out: &mut [f32]) {
        let _epoch = self.epoch_read();
        self.inner.backend.read_row(table, global_row, out);
    }

    fn read_rows(&self, table: usize, rows: &[u32]) -> (Vec<f32>, Vec<f32>) {
        let _epoch = self.epoch_read();
        self.inner.backend.read_rows(table, rows)
    }
}

/// The exclusive quiesce token: proof that no data-plane call is in
/// flight. Derefs to the backend, exposing the full [`PsBackend`] surface
/// (both planes) to checkpoint capture, restore, and failure injection.
pub struct PsQuiesce<'a, B: PsBackend> {
    _epoch: RwLockWriteGuard<'a, ()>,
    backend: &'a B,
}

impl<B: PsBackend> Deref for PsQuiesce<'_, B> {
    type Target = B;

    fn deref(&self) -> &B {
        self.backend
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{PsControlPlane, ThreadedCluster};
    use crate::embedding::PsCluster;
    use crate::prop_assert;
    use crate::testing::{forall, gen};
    use crate::util::rng::Rng;
    use std::sync::Mutex as StdMutex;

    const TABLES: [TableInfo; 2] =
        [TableInfo { rows: 23, dim: 4 }, TableInfo { rows: 11, dim: 4 }];

    #[test]
    fn turnstile_serves_tickets_in_order() {
        let t = Arc::new(Turnstile::new());
        let order = Arc::new(StdMutex::new(Vec::new()));
        std::thread::scope(|s| {
            for ticket in (0..8u64).rev() {
                let t = Arc::clone(&t);
                let order = Arc::clone(&order);
                s.spawn(move || {
                    t.wait_for(ticket);
                    order.lock().unwrap().push(ticket);
                    t.advance();
                });
            }
        });
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn handle_serves_concurrent_gathers() {
        // 4 threads gather through one handle at once; every result must
        // match the single-threaded reference, and an ordered update
        // afterwards must still go through.
        let reference = PsCluster::new(TABLES.to_vec(), 3, 5);
        let idx = vec![0u32, 1, 10, 5, 3, 2];
        let mut want = vec![0.0f32; 3 * 2 * 4];
        PsDataPlane::gather(&reference, &idx, &mut want);
        let shared = ShardedPs::new(PsCluster::new(TABLES.to_vec(), 3, 5));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let shared = shared.clone();
                let idx = idx.clone();
                let want = want.clone();
                s.spawn(move || {
                    for _ in 0..50 {
                        let mut out = vec![0.0f32; 3 * 2 * 4];
                        shared.gather(&idx, &mut out);
                        assert_eq!(out, want);
                    }
                });
            }
        });
        shared.apply_grads_ordered(0, &idx[..2], 1, &[0.1f32; 8], 1.0,
                                   EmbOptimizer::Sgd);
        assert_eq!(shared.stats().applies, 1);
    }

    #[test]
    fn quiesce_token_runs_the_recovery_protocol() {
        let shared = ShardedPs::new(PsCluster::new(TABLES.to_vec(), 3, 5));
        shared.apply_grads_ordered(0, &[3, 1], 1, &[1.0f32; 8], 0.5,
                                   EmbOptimizer::Sgd);
        let snap = {
            let q = shared.quiesce();
            q.snapshot_node(0)
        };
        shared.apply_grads_ordered(1, &[3, 1], 1, &[1.0f32; 8], 0.5,
                                   EmbOptimizer::Sgd);
        {
            let q = shared.quiesce();
            q.kill_node(0);
            assert!(!q.alive(0));
            q.respawn_node(0);
            q.load_node(0, &snap.shards, &snap.opt);
            assert_eq!(q.snapshot_node(0).shards, snap.shards);
        }
        // the handle keeps serving after the token drops
        let mut out = vec![0.0f32; 4];
        shared.read_row(0, 3, &mut out);
    }

    /// THE bit-identicality property (satellite): per-node sample-order
    /// apply under the sharded handle — N concurrent appliers sequenced
    /// only by per-node turnstiles — must produce exactly the floats of
    /// the old global rank-ordered apply, for random batches with row
    /// collisions, on both backends.
    #[test]
    fn property_sharded_apply_matches_global_rank_order() {
        forall(0x5EAD, 10, |rng| {
            let n_nodes = gen::usize_in(rng, 1, 5);
            let n_appliers = gen::usize_in(rng, 1, 4);
            let steps = gen::usize_in(rng, 1, 3);
            let b = gen::usize_in(rng, 2, 6);
            let hotness = gen::usize_in(rng, 1, 2);
            let seed = rng.next_u64();
            let dim = 4;
            // random batches, biased small so row collisions are common
            let mut batches: Vec<Vec<(Vec<u32>, Vec<f32>)>> = Vec::new();
            for _ in 0..steps {
                let mut per_rank = Vec::new();
                for _ in 0..n_appliers {
                    let idx: Vec<u32> = (0..b * 2 * hotness)
                        .enumerate()
                        .map(|(i, _)| {
                            let t = (i / hotness) % 2;
                            rng.below(TABLES[t].rows as u64) as u32
                        })
                        .collect();
                    let grads: Vec<f32> =
                        (0..b * 2 * dim).map(|_| rng.f32() - 0.5).collect();
                    per_rank.push((idx, grads));
                }
                batches.push(per_rank);
            }
            let opt = if rng.f64() < 0.5 {
                EmbOptimizer::Sgd
            } else {
                EmbOptimizer::RowAdagrad { eps: 1e-8 }
            };
            // reference: strict global rank order, single thread
            let reference = PsCluster::new(TABLES.to_vec(), n_nodes, seed);
            for per_rank in &batches {
                for (idx, grads) in per_rank {
                    PsDataPlane::apply_grads(&reference, idx, hotness, grads,
                                             0.3, opt);
                }
            }
            // sharded: N threads, per-node turnstile order only
            let run_sharded = |shared: &ShardedPs<PsCluster>| {
                std::thread::scope(|s| {
                    for rank in 0..n_appliers {
                        let shared = shared.clone();
                        let batches = &batches;
                        s.spawn(move || {
                            for (step, per_rank) in batches.iter().enumerate() {
                                let ticket =
                                    (step * n_appliers + rank) as u64;
                                let (idx, grads) = &per_rank[rank];
                                shared.apply_grads_ordered(
                                    ticket, idx, hotness, grads, 0.3, opt);
                            }
                        });
                    }
                });
            };
            let sharded = ShardedPs::new(PsCluster::new(TABLES.to_vec(),
                                                        n_nodes, seed));
            run_sharded(&sharded);
            let q = sharded.quiesce();
            for node in 0..n_nodes {
                let a = PsControlPlane::snapshot_node(&reference, node);
                let b = q.snapshot_node(node);
                prop_assert!(a.shards == b.shards,
                             "node {node} shards diverged (inproc)");
                prop_assert!(a.opt == b.opt,
                             "node {node} optimizer state diverged (inproc)");
            }
            drop(q);
            // and the threaded backend under the same handle
            let threaded = ShardedPs::new(ThreadedCluster::new(
                TABLES.to_vec(), n_nodes, seed));
            std::thread::scope(|s| {
                for rank in 0..n_appliers {
                    let shared = threaded.clone();
                    let batches = &batches;
                    s.spawn(move || {
                        for (step, per_rank) in batches.iter().enumerate() {
                            let ticket = (step * n_appliers + rank) as u64;
                            let (idx, grads) = &per_rank[rank];
                            shared.apply_grads_ordered(
                                ticket, idx, hotness, grads, 0.3, opt);
                        }
                    });
                }
            });
            let q = threaded.quiesce();
            for node in 0..n_nodes {
                let a = PsControlPlane::snapshot_node(&reference, node);
                let b = q.snapshot_node(node);
                prop_assert!(a.shards == b.shards,
                             "node {node} shards diverged (threaded)");
                prop_assert!(a.opt == b.opt,
                             "node {node} optimizer state diverged (threaded)");
            }
            Ok(())
        });
    }

    #[test]
    fn planned_ordered_apply_matches_unplanned() {
        use crate::cluster::PlanArena;
        let a = ShardedPs::new(PsCluster::new(TABLES.to_vec(), 3, 5));
        let b = ShardedPs::new(PsCluster::new(TABLES.to_vec(), 3, 5));
        let mut rng = Rng::new(6);
        let mut arena = PlanArena::new();
        let opt = EmbOptimizer::RowAdagrad { eps: 1e-8 };
        for step in 0..3u64 {
            let hotness = 1 + (step as usize) % 2;
            let idx: Vec<u32> = (0..4 * 2 * hotness)
                .enumerate()
                .map(|(i, _)| {
                    let t = (i / hotness) % 2;
                    rng.below(TABLES[t].rows as u64) as u32
                })
                .collect();
            let grads: Vec<f32> = (0..4 * 2 * 4).map(|_| rng.f32() - 0.5).collect();
            a.apply_grads_ordered(step, &idx, hotness, &grads, 0.3, opt);
            arena.build(&idx, hotness, 2, 3);
            let (plan, scratch) = arena.parts_mut();
            b.apply_grads_ordered_planned(step, plan, scratch, &grads, 0.3, opt);
        }
        assert_eq!(a.stats().applies, b.stats().applies);
        let qa = a.quiesce();
        let qb = b.quiesce();
        for node in 0..3 {
            let sa = qa.snapshot_node(node);
            let sb = qb.snapshot_node(node);
            assert_eq!(sa.shards, sb.shards, "node {node} shards diverged");
            assert_eq!(sa.opt, sb.opt, "node {node} optimizer state diverged");
        }
    }

    #[test]
    fn disjoint_node_appliers_overlap() {
        // two appliers whose rows live on different nodes must be able to
        // hold their node applies concurrently: rank 1 (later ticket on
        // every turnstile) still finishes while rank 0 is parked inside
        // its own apply. We emulate "parked" with a big batch on node 0
        // and assert rank 1's node-1 apply completes even though rank 0's
        // ticket for node 1 is consumed before its node-0 work ends — the
        // turnstile loop advances untouched nodes immediately.
        let tables = vec![TableInfo { rows: 64, dim: 8 }];
        let shared = ShardedPs::new(PsCluster::new(tables, 2, 1));
        let idx0: Vec<u32> = (0..32).map(|i| (i * 2) as u32).collect(); // node 0
        let idx1: Vec<u32> = (0..32).map(|i| (i * 2 + 1) as u32).collect(); // node 1
        let g = vec![0.01f32; 32 * 8];
        std::thread::scope(|s| {
            let sh = shared.clone();
            let (i0, g0) = (idx0.clone(), g.clone());
            s.spawn(move || {
                for step in 0..50u64 {
                    sh.apply_grads_ordered(step * 2, &i0, 1, &g0, 0.1,
                                           EmbOptimizer::Sgd);
                }
            });
            let sh = shared.clone();
            let (i1, g1) = (idx1.clone(), g.clone());
            s.spawn(move || {
                for step in 0..50u64 {
                    sh.apply_grads_ordered(step * 2 + 1, &i1, 1, &g1, 0.1,
                                           EmbOptimizer::Sgd);
                }
            });
        });
        assert_eq!(shared.stats().applies, 100);
        // node-0 rows got exactly rank 0's updates, node-1 rows rank 1's
        let reference = PsCluster::new(vec![TableInfo { rows: 64, dim: 8 }], 2, 1);
        for _ in 0..50 {
            PsDataPlane::apply_grads(&reference, &idx0, 1, &g, 0.1,
                                     EmbOptimizer::Sgd);
            PsDataPlane::apply_grads(&reference, &idx1, 1, &g, 0.1,
                                     EmbOptimizer::Sgd);
        }
        let q = shared.quiesce();
        for node in 0..2 {
            assert_eq!(PsControlPlane::snapshot_node(&reference, node).shards,
                       q.snapshot_node(node).shards);
        }
    }

    #[test]
    fn skip_ordered_unblocks_later_tickets() {
        let shared = ShardedPs::new(PsCluster::new(TABLES.to_vec(), 2, 3));
        let idx = vec![0u32, 1];
        let g = vec![0.1f32; 8];
        std::thread::scope(|s| {
            let sh = shared.clone();
            let (idx, g) = (idx.clone(), g.clone());
            // ticket 1 blocks until ticket 0 is consumed
            s.spawn(move || {
                sh.apply_grads_ordered(1, &idx, 1, &g, 0.1, EmbOptimizer::Sgd)
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            shared.skip_ordered(0); // a failed rank passes its turn
        });
        assert_eq!(shared.stats().applies, 1);
    }

    #[test]
    fn serve_gather_completes_while_quiesce_token_is_held() {
        // THE non-blocking acceptance criterion: a serving read to live
        // nodes must finish while the exclusive quiesce token is held
        // (data-plane calls would block here). Run it on both backends.
        fn check<B: PsBackend + 'static>(shared: ShardedPs<B>, tag: &str) {
            let idx = vec![0u32, 1, 10, 5, 3, 2]; // 3 samples x 2 tables
            let mut want = vec![0.0f32; 3 * 2 * 4];
            shared.gather(&idx, &mut want);
            let q = shared.quiesce(); // exclusive epoch write lock held
            let (done_tx, done_rx) = std::sync::mpsc::channel();
            std::thread::scope(|s| {
                let sh = shared.clone();
                let idx = idx.clone();
                s.spawn(move || {
                    let mut out = vec![0.0f32; 3 * 2 * 4];
                    sh.serve_gather(&idx, &mut out).unwrap();
                    done_tx.send(out).unwrap();
                });
                let out = done_rx
                    .recv_timeout(std::time::Duration::from_secs(10))
                    .unwrap_or_else(|_| {
                        panic!("{tag}: serve_gather blocked on the quiesce token")
                    });
                assert_eq!(out, want, "{tag}");
                drop(q);
            });
            assert_eq!(shared.stats().serve_reads, 1, "{tag}");
        }
        check(ShardedPs::new(PsCluster::new(TABLES.to_vec(), 3, 5)), "inproc");
        check(ShardedPs::new(ThreadedCluster::new(TABLES.to_vec(), 3, 5)),
              "threaded");
    }

    #[test]
    fn stats_read_does_not_fence_against_quiesce() {
        // satellite 2: the diagnostic stats surface serving threads poll
        // must stay reachable while the control plane holds the token
        let shared = ShardedPs::new(PsCluster::new(TABLES.to_vec(), 2, 5));
        let q = shared.quiesce();
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::scope(|s| {
            let sh = shared.clone();
            s.spawn(move || tx.send(sh.stats()).unwrap());
            let stats = rx
                .recv_timeout(std::time::Duration::from_secs(10))
                .expect("stats() blocked on the quiesce token");
            assert_eq!(stats.serve_reads, 0);
            drop(q);
        });
    }

    #[test]
    fn poisoned_node_under_the_handle_reads_as_failed() {
        // a trainer panicking mid-apply through the sharded handle must
        // fail exactly the node it was writing; the quiesce token then
        // runs kill/respawn and service resumes
        let shared = ShardedPs::new(PsCluster::new(TABLES.to_vec(), 3, 9));
        // row 9999 → node 0 with an OOB local slot; the second slot also
        // routes to node 0 (0 % 3), so ONLY node 0's guard is held at the
        // panic — a guard held at panic time conservatively fails its node
        let bogus = vec![9999u32, 0];
        let r = std::thread::scope(|s| {
            let sh = shared.clone();
            s.spawn(move || {
                sh.apply_grads_ordered(0, &bogus, 1, &[0.1f32; 8], 1.0,
                                       EmbOptimizer::Sgd)
            })
            .join()
        });
        assert!(r.is_err());
        {
            let q = shared.quiesce();
            assert!(!q.alive(0), "poisoned node must read as failed");
            assert!(q.alive(1) && q.alive(2));
            q.kill_node(0);
            q.respawn_node(0);
            assert!(q.alive(0));
        }
        // NOTE: ticket 0 died before advancing every turnstile; fresh
        // runs must re-sync the queues before ordered traffic resumes.
        // The trainer pool never reuses a handle after a wedged step, so
        // here we just verify unordered reads still work.
        let mut out = vec![0.0f32; 4];
        shared.read_row(0, 3, &mut out);
    }
}
