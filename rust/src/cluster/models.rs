//! Exhaustive model checks of the cluster's concurrency protocols
//! (`cargo test --features loom`), built on the vendored explorer in
//! [`crate::testing::model`].
//!
//! Three protocols are modeled, at one-shared-access-per-step
//! granularity, and every reachable interleaving is checked:
//!
//! * **seqlock** ([`seqlock`]) — the serving read path of
//!   `cluster::seqlock::SeqLock` against a writer, a panicking writer,
//!   and the kill → refill-while-dead → revive sequence. Properties: a
//!   validated copy is NEVER torn (always one whole publication), and a
//!   sequence stuck odd by a dead writer always converts to `NodeDown`
//!   (never an escaped copy, never a livelock terminal).
//! * **nodelock** ([`nodelock`]) — `cluster::lock::NodeLock`'s
//!   reader/writer exclusion and the poison→KILL conversion. Properties:
//!   a reader never observes half-written data; after a writer panic the
//!   node reads as dead until revived; revive waits out live guards.
//! * **turnstile** ([`turnstile`]) — `cluster::sharded::Turnstile` rank
//!   ordering. Properties: per-node applies happen in strict ticket
//!   order regardless of schedule; `skip_ordered` (modeled as a ticket
//!   that waits + advances without applying) keeps the queue dense; a
//!   ticket that never advances deadlocks every later rank — the
//!   explorer's deadlock detector must see it (that is the bug class
//!   `skip_ordered` exists to prevent).
//!
//! These models verify protocol logic over sequentially consistent
//! interleavings; the memory-ordering side (the real fences/orderings)
//! is covered by the Miri and TSan CI lanes — see
//! `testing::model` docs and DESIGN.md "Concurrency model & unsafe
//! inventory".

/// Seqlock model: mirrors `SeqLock::{write_begin,write_end,read}` and the
/// `PsCluster::{kill_node,respawn_node}` call sequence step by step.
pub mod seqlock {
    use crate::testing::model::{ModelThread, Step};

    /// Retry budget before the modeled reader polls the dead flag
    /// (the real `SPIN_CHECK_INTERVAL` is 128; 2 keeps the state space
    /// small without changing the protocol logic).
    pub const CAP: u8 = 2;

    /// The shared memory: sequence counter, two payload words (two, so a
    /// torn copy is representable), and the liveness/dead flags.
    #[derive(Clone, Debug, PartialEq, Eq, Hash)]
    pub struct Shared {
        pub seq: u8,
        pub words: [u8; 2],
        pub alive: bool,
        /// `NodeLock::is_dead()` as seen by the reader's budget poll.
        pub dead: bool,
        /// true once the writer released its guard (normally or by
        /// panic-unwind) — the revive path waits on this, mirroring
        /// `revive_with`'s drain loop.
        pub writer_done: bool,
    }

    impl Shared {
        pub fn init() -> Self {
            Self { seq: 0, words: [0, 0], alive: true, dead: false,
                   writer_done: true }
        }
    }

    /// What a finished reader observed.
    #[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
    pub enum ReadResult {
        Copy([u8; 2]),
        NodeDown,
    }

    #[derive(Clone, Debug, PartialEq, Eq, Hash)]
    pub enum Thread {
        /// `write_begin; words = [val, val]; write_end` under the node's
        /// write guard; `panics` dies between the two word stores (guard
        /// drop then marks the node dead — one step, mutex-protected).
        Writer { pc: u8, s: u8, val: u8, panics: bool },
        /// One `SeqLock::read` call copying both words.
        Reader { pc: u8, s1: u8, copy: [u8; 2], retries: u8,
                 result: Option<ReadResult> },
        /// `kill_node` then `respawn_node(init)`: alive=false; dead=true;
        /// wait writer drain; write_begin; refill words; dead=false;
        /// write_end; alive=true.
        KillRevive { pc: u8, init: u8 },
    }

    impl Thread {
        pub fn writer(val: u8, panics: bool) -> Self {
            Thread::Writer { pc: 0, s: 0, val, panics }
        }
        pub fn reader() -> Self {
            Thread::Reader { pc: 0, s1: 0, copy: [0, 0], retries: 0,
                             result: None }
        }
        pub fn kill_revive(init: u8) -> Self {
            Thread::KillRevive { pc: 0, init }
        }

        /// The reader's final observation, if it finished.
        pub fn read_result(&self) -> Option<ReadResult> {
            match self {
                Thread::Reader { result, .. } => *result,
                _ => None,
            }
        }
    }

    impl ModelThread<Shared> for Thread {
        fn step(&mut self, m: &mut Shared) -> Step {
            match self {
                Thread::Writer { pc, s, val, panics } => match *pc {
                    // write_begin: load seq
                    0 => { *s = m.seq; m.writer_done = false; *pc = 1; Step::Ran }
                    // write_begin: parity-safe bump (store)
                    1 => { m.seq = s.wrapping_add(1 + (*s & 1)); *pc = 2; Step::Ran }
                    // first word store
                    2 => { m.words[0] = *val; *pc = 3; Step::Ran }
                    // second word store, or the panic point: guard drop
                    // converts the unwind into dead=true
                    3 => {
                        if *panics {
                            m.dead = true;
                            m.writer_done = true;
                            *pc = 6;
                        } else {
                            m.words[1] = *val;
                            *pc = 4;
                        }
                        Step::Ran
                    }
                    // write_end: load seq
                    4 => { *s = m.seq; *pc = 5; Step::Ran }
                    // write_end: store even + guard release
                    5 => {
                        m.seq = s.wrapping_add(1);
                        m.writer_done = true;
                        *pc = 6;
                        Step::Ran
                    }
                    _ => Step::Done,
                },
                Thread::Reader { pc, s1, copy, retries, result } => match *pc {
                    // fast-path liveness check
                    0 => {
                        if m.alive { *pc = 1; } else {
                            *result = Some(ReadResult::NodeDown);
                            *pc = 9;
                        }
                        Step::Ran
                    }
                    // s1 = seq; odd → budget path
                    1 => {
                        *s1 = m.seq;
                        if *s1 & 1 == 0 { *pc = 2 } else { *pc = 5 }
                        Step::Ran
                    }
                    // copy word 0
                    2 => { copy[0] = m.words[0]; *pc = 3; Step::Ran }
                    // copy word 1
                    3 => { copy[1] = m.words[1]; *pc = 4; Step::Ran }
                    // validate
                    4 => {
                        if m.seq == *s1 {
                            *result = Some(ReadResult::Copy(*copy));
                            *pc = 9;
                        } else {
                            *pc = 5;
                        }
                        Step::Ran
                    }
                    // retry bookkeeping (local, but modeled as a step so
                    // the budget poll interleaves like the real yield)
                    5 => {
                        *retries = retries.saturating_add(1);
                        if *retries >= CAP { *pc = 6 } else { *pc = 1 }
                        Step::Ran
                    }
                    // budget exhausted: poll dead/alive
                    6 => {
                        if m.dead || !m.alive {
                            *result = Some(ReadResult::NodeDown);
                            *pc = 9;
                        } else {
                            *retries = 0;
                            *pc = 1;
                        }
                        Step::Ran
                    }
                    _ => Step::Done,
                },
                Thread::KillRevive { pc, init } => match *pc {
                    // kill_node: serving fast path off first
                    0 => { m.alive = false; *pc = 1; Step::Ran }
                    // NodeLock::kill
                    1 => { m.dead = true; *pc = 2; Step::Ran }
                    // respawn: write_begin once the writer guard drained
                    // (revive_with's drain loop)
                    2 => {
                        if !m.writer_done {
                            return Step::Blocked;
                        }
                        m.seq = m.seq.wrapping_add(1 + (m.seq & 1));
                        *pc = 3;
                        Step::Ran
                    }
                    // refill words while dead
                    3 => { m.words[0] = *init; *pc = 4; Step::Ran }
                    4 => { m.words[1] = *init; *pc = 5; Step::Ran }
                    // revive_with clears dead
                    5 => { m.dead = false; *pc = 6; Step::Ran }
                    // write_end
                    6 => { m.seq = m.seq.wrapping_add(1); *pc = 7; Step::Ran }
                    // alive last
                    7 => { m.alive = true; *pc = 8; Step::Ran }
                    _ => Step::Done,
                },
            }
        }
    }
}

/// NodeLock model: reader/writer exclusion + poison→KILL + revive drain.
pub mod nodelock {
    use crate::testing::model::{ModelThread, Step};

    /// `data` is the guarded payload: 0 = init, 1 = HALF-WRITTEN
    /// (the poison hazard), 2 = fully written.
    #[derive(Clone, Debug, PartialEq, Eq, Hash)]
    pub struct Shared {
        pub readers: u8,
        pub writer: bool,
        pub dead: bool,
        pub data: u8,
    }

    impl Shared {
        pub fn init() -> Self {
            Self { readers: 0, writer: false, dead: false, data: 0 }
        }
    }

    #[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
    pub enum LockResult {
        Observed(u8),
        NodeDead,
    }

    #[derive(Clone, Debug, PartialEq, Eq, Hash)]
    pub enum Thread {
        /// `write()`: wait for exclusivity, fail on dead; then two data
        /// stores (the half-written window); `panics` unwinds between
        /// them — the guard Drop marks the node dead.
        Writer { pc: u8, panics: bool, result: Option<LockResult> },
        /// `read()`: wait out the writer, fail on dead, observe data.
        Reader { pc: u8, result: Option<LockResult> },
        /// `kill()` then `revive()` (waits out live guards, resets data).
        KillRevive { pc: u8 },
    }

    impl Thread {
        pub fn writer(panics: bool) -> Self {
            Thread::Writer { pc: 0, panics, result: None }
        }
        pub fn reader() -> Self {
            Thread::Reader { pc: 0, result: None }
        }
        pub fn kill_revive() -> Self {
            Thread::KillRevive { pc: 0 }
        }

        pub fn observed(&self) -> Option<LockResult> {
            match self {
                Thread::Reader { result, .. } => *result,
                _ => None,
            }
        }
    }

    impl ModelThread<Shared> for Thread {
        fn step(&mut self, m: &mut Shared) -> Step {
            match self {
                Thread::Writer { pc, panics, result } => match *pc {
                    // acquire (one mutex-guarded decision in the real
                    // lock, so one step here)
                    0 => {
                        if m.writer || m.readers > 0 {
                            return Step::Blocked;
                        }
                        if m.dead {
                            *result = Some(LockResult::NodeDead);
                            *pc = 4;
                        } else {
                            m.writer = true;
                            *pc = 1;
                        }
                        Step::Ran
                    }
                    // first half of the mutation
                    1 => { m.data = 1; *pc = 2; Step::Ran }
                    // second half, or panic + guard drop (dead, release)
                    2 => {
                        if *panics {
                            m.dead = true;
                            m.writer = false;
                            *pc = 4;
                        } else {
                            m.data = 2;
                            *pc = 3;
                        }
                        Step::Ran
                    }
                    // normal guard drop
                    3 => { m.writer = false; *pc = 4; Step::Ran }
                    _ => Step::Done,
                },
                Thread::Reader { pc, result } => match *pc {
                    0 => {
                        if m.writer {
                            return Step::Blocked;
                        }
                        if m.dead {
                            *result = Some(LockResult::NodeDead);
                            *pc = 3;
                        } else {
                            m.readers += 1;
                            *pc = 1;
                        }
                        Step::Ran
                    }
                    1 => {
                        *result = Some(LockResult::Observed(m.data));
                        *pc = 2;
                        Step::Ran
                    }
                    2 => { m.readers -= 1; *pc = 3; Step::Ran }
                    _ => Step::Done,
                },
                Thread::KillRevive { pc } => match *pc {
                    0 => { m.dead = true; *pc = 1; Step::Ran }
                    // revive: drain live guards, then install fresh state
                    1 => {
                        if m.writer || m.readers > 0 {
                            return Step::Blocked;
                        }
                        m.data = 0;
                        m.dead = false;
                        *pc = 2;
                        Step::Ran
                    }
                    _ => Step::Done,
                },
            }
        }
    }
}

/// Turnstile model: per-node ticket sequencing (`apply_grads_ordered`)
/// and `skip_ordered`.
pub mod turnstile {
    use crate::testing::model::{ModelThread, Step};

    pub const N_NODES: usize = 2;

    #[derive(Clone, Debug, PartialEq, Eq, Hash)]
    pub struct Shared {
        /// per-node next ticket (Turnstile.next)
        pub next: [u8; N_NODES],
        /// per-node apply log: ticket ids in application order
        pub log: [Vec<u8>; N_NODES],
    }

    impl Shared {
        pub fn init() -> Self {
            Self { next: [0; N_NODES], log: Default::default() }
        }
    }

    /// One trainer running `apply_grads_ordered(ticket)`: for each node
    /// in ascending order, wait for the ticket, apply if the batch
    /// touches the node (a skip_ordered caller touches none), advance.
    #[derive(Clone, Debug, PartialEq, Eq, Hash)]
    pub struct Applier {
        pub ticket: u8,
        pub touches: [bool; N_NODES],
        /// next node to pass; phase false = waiting/applying, true =
        /// about to advance
        pub node: usize,
        pub advancing: bool,
    }

    impl Applier {
        pub fn new(ticket: u8, touches: [bool; N_NODES]) -> Self {
            Self { ticket, touches, node: 0, advancing: false }
        }

        /// `skip_ordered`: waits and advances every node, applies none.
        pub fn skipper(ticket: u8) -> Self {
            Self::new(ticket, [false; N_NODES])
        }
    }

    impl ModelThread<Shared> for Applier {
        fn step(&mut self, m: &mut Shared) -> Step {
            if self.node >= N_NODES {
                return Step::Done;
            }
            if !self.advancing {
                // wait_for(ticket) + (touched) apply under turnstile
                // exclusivity — the apply is one step because no other
                // ticket can run this node concurrently
                if m.next[self.node] != self.ticket {
                    return Step::Blocked;
                }
                if self.touches[self.node] {
                    m.log[self.node].push(self.ticket);
                }
                self.advancing = true;
                Step::Ran
            } else {
                // advance()
                m.next[self.node] += 1;
                self.advancing = false;
                self.node += 1;
                Step::Ran
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::model::explore;

    // -----------------------------------------------------------------
    // seqlock
    // -----------------------------------------------------------------

    /// Writer publishing `val` vs a concurrent reader: a validated copy
    /// is always uniform and always a real publication (old or new),
    /// never a torn mix. Exhaustive over all interleavings.
    #[test]
    fn seqlock_reader_never_returns_a_torn_copy() {
        use seqlock::{ReadResult, Shared, Thread};
        let out = explore(
            Shared::init(),
            vec![Thread::writer(7, false), Thread::reader()],
            |_, ts| {
                if let Some(ReadResult::Copy(c)) = ts[1].read_result() {
                    assert!(
                        c == [0, 0] || c == [7, 7],
                        "torn copy escaped seqlock validation: {c:?}"
                    );
                }
            },
        );
        assert!(out.terminals > 0);
        assert_eq!(out.deadlocks, 0);
        // sanity: the model is big enough to contain real interleavings
        assert!(out.states > 50, "suspiciously small state space: {out:?}");
    }

    /// A writer that panics mid-update leaves the sequence odd forever.
    /// No copy taken after `write_begin` may ever validate (only the
    /// pre-begin publication can escape), and the stuck-odd path must
    /// reach NodeDown — never a livelock, never a torn copy.
    #[test]
    fn seqlock_stuck_odd_always_yields_node_down() {
        use seqlock::{ReadResult, Shared, Thread};
        let mut down_terminals = 0u32;
        let out = explore(
            Shared::init(),
            vec![Thread::writer(7, true), Thread::reader()],
            |m, ts| {
                if let Some(r) = ts[1].read_result() {
                    match r {
                        // the only copy that can validate against a
                        // never-closed epoch is the pre-begin state
                        ReadResult::Copy(c) => assert_eq!(
                            c, [0, 0],
                            "copy validated against a dead writer's epoch"
                        ),
                        ReadResult::NodeDown => down_terminals += 1,
                    }
                }
                // the poisoned epoch is permanently odd once the writer
                // died
                if m.dead {
                    assert_eq!(m.seq & 1, 1, "dead writer left an even seq");
                }
            },
        );
        assert!(out.terminals > 0);
        assert_eq!(out.deadlocks, 0, "reader livelocked on a stuck seqlock");
        assert!(down_terminals > 0, "NodeDown path never reached");
    }

    /// kill → refill-while-dead → revive racing a writer and a reader:
    /// the reader sees old state, new state, the respawn init, or
    /// NodeDown — never a mix of two publications.
    #[test]
    fn seqlock_kill_revive_never_leaks_partial_refill() {
        use seqlock::{ReadResult, Shared, Thread};
        let out = explore(
            Shared::init(),
            vec![
                Thread::writer(7, false),
                Thread::reader(),
                Thread::kill_revive(9),
            ],
            |_, ts| {
                if let Some(ReadResult::Copy(c)) = ts[1].read_result() {
                    assert!(
                        c == [0, 0] || c == [7, 7] || c == [9, 9],
                        "mixed-publication copy escaped: {c:?}"
                    );
                }
            },
        );
        assert!(out.terminals > 0);
        assert_eq!(out.deadlocks, 0);
        assert!(out.states > 200, "suspiciously small state space: {out:?}");
    }

    // -----------------------------------------------------------------
    // nodelock
    // -----------------------------------------------------------------

    /// Readers racing a clean writer never observe the half-written
    /// payload (data == 1) — the exclusion protocol, exhaustively.
    #[test]
    fn nodelock_reader_never_sees_half_written_data() {
        use nodelock::{LockResult, Shared, Thread};
        let out = explore(
            Shared::init(),
            vec![Thread::writer(false), Thread::reader(), Thread::reader()],
            |_, ts| {
                for t in ts {
                    if let Some(LockResult::Observed(d)) = t.observed() {
                        assert_ne!(d, 1, "reader saw a half-written payload");
                    }
                }
            },
        );
        assert!(out.terminals > 0);
        assert_eq!(out.deadlocks, 0);
    }

    /// THE poison→KILL conversion: after a writer panic, every reader
    /// outcome is either the pre-write state (acquired before the
    /// writer) or NodeDead — the half-written data is unobservable, and
    /// the node stays dead at every terminal (nobody revives here).
    #[test]
    fn nodelock_poison_converts_to_kill() {
        use nodelock::{LockResult, Shared, Thread};
        let out = explore(
            Shared::init(),
            vec![Thread::writer(true), Thread::reader()],
            |m, ts| {
                if let Some(r) = ts[1].observed() {
                    match r {
                        LockResult::Observed(d) => assert_eq!(
                            d, 0,
                            "reader observed the panicked writer's data"
                        ),
                        LockResult::NodeDead => {}
                    }
                }
                let done = matches!(&ts[0], Thread::Writer { pc: 4, .. })
                    && matches!(&ts[1], Thread::Reader { pc: 3, .. });
                if done {
                    assert!(m.dead, "writer panic did not kill the node");
                }
            },
        );
        assert!(out.terminals > 0);
        assert_eq!(out.deadlocks, 0);
    }

    /// kill/revive racing a panicking writer and a reader: revive waits
    /// out live guards, the payload is reset, and readers still never
    /// see data == 1.
    #[test]
    fn nodelock_revive_waits_out_guards_and_resets() {
        use nodelock::{LockResult, Shared, Thread};
        let out = explore(
            Shared::init(),
            vec![Thread::writer(true), Thread::reader(), Thread::kill_revive()],
            |m, ts| {
                for t in ts {
                    if let Some(LockResult::Observed(d)) = t.observed() {
                        assert_ne!(d, 1, "reader saw a half-written payload");
                    }
                }
                // revive must never run while a guard is live
                if let Thread::KillRevive { pc: 2 } = ts[2] {
                    // (checked transitionally: the step itself blocks on
                    // guards, so reaching pc=2 implies they were drained)
                    assert!(!m.writer, "revive overlapped a writer");
                }
            },
        );
        assert!(out.terminals > 0);
        assert_eq!(out.deadlocks, 0);
    }

    // -----------------------------------------------------------------
    // turnstile
    // -----------------------------------------------------------------

    /// Three tickets (0 touches node 0, 1 touches both, 2 touches node
    /// 1) under every schedule: per-node apply logs come out in strict
    /// ascending ticket order and every node's queue drains.
    #[test]
    fn turnstile_applies_in_ticket_order_on_every_schedule() {
        use turnstile::{Applier, Shared};
        let out = explore(
            Shared::init(),
            vec![
                Applier::new(0, [true, false]),
                Applier::new(1, [true, true]),
                Applier::new(2, [false, true]),
            ],
            |m, _| {
                for node in 0..turnstile::N_NODES {
                    let log = &m.log[node];
                    assert!(
                        log.windows(2).all(|w| w[0] < w[1]),
                        "node {node} applied out of ticket order: {log:?}"
                    );
                }
            },
        );
        assert!(out.terminals > 0);
        assert_eq!(out.deadlocks, 0, "dense ticket queue must drain");
    }

    /// `skip_ordered` is load-bearing: a ticket whose batch touches no
    /// node still waits + advances every turnstile. Modeled as a skipper
    /// — the queue drains with the full logs intact.
    #[test]
    fn turnstile_skip_ordered_keeps_the_queue_dense() {
        use turnstile::{Applier, Shared};
        let out = explore(
            Shared::init(),
            vec![
                Applier::new(0, [true, true]),
                Applier::skipper(1),
                Applier::new(2, [true, true]),
            ],
            |_, _| {},
        );
        assert!(out.terminals > 0);
        assert_eq!(out.deadlocks, 0, "skip_ordered must keep ranks flowing");
    }

    /// The failure mode skip_ordered prevents: if ticket 1 simply never
    /// passes the turnstiles (no skip call), ticket 2 parks forever —
    /// every schedule deadlocks, none terminates.
    #[test]
    fn turnstile_missing_ticket_deadlocks_later_ranks() {
        use turnstile::{Applier, Shared};
        let out = explore(
            Shared::init(),
            vec![
                Applier::new(0, [true, true]),
                // ticket 1 crashed before reaching the turnstile: absent
                Applier::new(2, [true, true]),
            ],
            |_, _| {},
        );
        assert_eq!(out.terminals, 0, "rank 2 ran without rank 1 advancing");
        assert!(out.deadlocks > 0, "explorer missed the stuck-rank deadlock");
    }
}
