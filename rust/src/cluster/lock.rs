//! [`NodeLock`] — the per-node reader/writer lock of the sharded data
//! plane, with *failure-aware* poisoning.
//!
//! `std::sync::RwLock` poisoning is the wrong failure model for a PS
//! node: when a trainer panics mid-`apply_grads`, the node's floats are
//! half-written, and the old global `SharedPs` handle silently
//! `PoisonError::into_inner`'d that state back to every survivor. A real
//! PS cluster would declare the node *failed* and run the recovery
//! protocol. `NodeLock` encodes exactly that:
//!
//! * a writer that panics while holding the guard marks the node **dead**
//!   (detected via [`std::thread::panicking`] in the guard's `Drop`);
//! * every subsequent `read()` / `write()` returns [`NodeDead`] — the
//!   node reads as *failed*, never as corrupt;
//! * [`NodeLock::kill`] is the same transition taken deliberately (the
//!   failure-injection path), and [`NodeLock::revive`] installs a fresh
//!   state (blank respawn; the checkpoint restore then repopulates it).
//!
//! Unlike `std` poisoning, death is recoverable without `&mut` access —
//! `revive` replaces the state wholesale under the same lock, which is
//! what lets the in-process backend live behind a plain `&self` data
//! plane shared by N trainer threads.

use std::cell::UnsafeCell;
use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, PoisonError};

/// The node guarded by this lock has failed: a writer panicked while
/// mutating it (lock-level poison converted into a node kill), or
/// [`NodeLock::kill`] was called. Its state is unobservable until
/// [`NodeLock::revive`] installs a replacement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeDead;

impl std::fmt::Display for NodeDead {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Emb PS node is dead (killed or writer panicked; respawn + restore it)")
    }
}

#[derive(Debug, Default)]
struct State {
    readers: usize,
    writer: bool,
    dead: bool,
}

/// Per-node RwLock with kill/revive semantics (see module docs).
#[derive(Debug)]
pub struct NodeLock<T> {
    state: Mutex<State>,
    cv: Condvar,
    cell: UnsafeCell<T>,
}

// SAFETY: moving the lock moves the `UnsafeCell<T>` by value with no
// outstanding borrows (moving requires ownership), so `NodeLock<T>` is
// `Send` exactly when `T` is — the same bound as `std::sync::RwLock`.
unsafe impl<T: Send> Send for NodeLock<T> {}
// SAFETY: same bounds as `std::sync::RwLock`. `&NodeLock<T>` hands out
// `&T` only under reader registration and `&mut T` only under the unique
// writer flag (see the guard types below), so sharing the lock across
// threads is sound when `T: Send + Sync`. The protocol-level guarantee
// (readers and the writer flag are mutually exclusive, poison converts to
// dead) is exhaustively model-checked by `cluster::models::nodelock`
// under `--features loom` and exercised under Miri/TSan in CI.
unsafe impl<T: Send + Sync> Sync for NodeLock<T> {}

impl<T> NodeLock<T> {
    pub fn new(value: T) -> Self {
        Self {
            state: Mutex::new(State::default()),
            cv: Condvar::new(),
            cell: UnsafeCell::new(value),
        }
    }

    // The state mutex is only ever held for a few integer ops, but a
    // guard Drop runs during unwinding (that is the whole point), so the
    // mutex may observe std-poison; the State ints are always consistent.
    fn state(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Shared access, or [`NodeDead`] if the node has failed.
    pub fn read(&self) -> Result<NodeReadGuard<'_, T>, NodeDead> {
        let mut s = self.state();
        while s.writer {
            s = self.cv.wait(s).unwrap_or_else(PoisonError::into_inner);
        }
        if s.dead {
            return Err(NodeDead);
        }
        s.readers += 1;
        Ok(NodeReadGuard { lock: self })
    }

    /// Exclusive access, or [`NodeDead`] if the node has failed.
    pub fn write(&self) -> Result<NodeWriteGuard<'_, T>, NodeDead> {
        let mut s = self.state();
        while s.writer || s.readers > 0 {
            s = self.cv.wait(s).unwrap_or_else(PoisonError::into_inner);
        }
        if s.dead {
            return Err(NodeDead);
        }
        s.writer = true;
        Ok(NodeWriteGuard { lock: self })
    }

    /// Deliberately fail the node (failure injection). Readers currently
    /// holding guards finish against the pre-kill state; no new guard is
    /// handed out until [`NodeLock::revive`].
    pub fn kill(&self) {
        self.state().dead = true;
        self.cv.notify_all();
    }

    pub fn is_dead(&self) -> bool {
        self.state().dead
    }

    /// Bring a dead node back with a replacement state (blank respawn).
    /// Blocks until in-flight guards drain, then atomically installs
    /// `value` and clears the dead flag. Panics if the node is alive —
    /// reviving a serving node would discard live updates.
    pub fn revive(&self, value: T) {
        let mut s = self.state();
        assert!(s.dead, "revive() on a live node would discard its state");
        while s.writer || s.readers > 0 {
            s = self.cv.wait(s).unwrap_or_else(PoisonError::into_inner);
        }
        // SAFETY: dead + no readers/writers → no outstanding references.
        unsafe { *self.cell.get() = value };
        s.dead = false;
        drop(s);
        self.cv.notify_all();
    }

    /// [`NodeLock::revive`], but mutating the existing state **in place**
    /// instead of installing a replacement value — respawn paths that
    /// must not reallocate (or simply want to reuse) the dead node's
    /// buffers refill them through `f`, which runs with the same
    /// exclusivity as `revive` (dead + no readers/writers).
    pub fn revive_with(&self, f: impl FnOnce(&mut T)) {
        let mut s = self.state();
        assert!(s.dead, "revive_with() on a live node would discard its state");
        while s.writer || s.readers > 0 {
            s = self.cv.wait(s).unwrap_or_else(PoisonError::into_inner);
        }
        // SAFETY: dead + no readers/writers → no outstanding references.
        f(unsafe { &mut *self.cell.get() });
        s.dead = false;
        drop(s);
        self.cv.notify_all();
    }

}

pub struct NodeReadGuard<'a, T> {
    lock: &'a NodeLock<T>,
}

// NOTE: no `unsafe impl Sync` here any more. The gather fast path used to
// fan read guards out to scoped worker threads; since PR 9 the guards
// stay on the calling thread (workers read the atomic shard words
// directly), so the impl — and its proof obligation — could be deleted.

impl<T> Deref for NodeReadGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        // SAFETY: reader registered; writers excluded until drop.
        unsafe { &*self.lock.cell.get() }
    }
}

impl<T> Drop for NodeReadGuard<'_, T> {
    fn drop(&mut self) {
        let mut s = self.lock.state();
        s.readers -= 1;
        drop(s);
        self.lock.cv.notify_all();
    }
}

pub struct NodeWriteGuard<'a, T> {
    lock: &'a NodeLock<T>,
}

impl<T> Deref for NodeWriteGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        // SAFETY: unique writer until drop.
        unsafe { &*self.lock.cell.get() }
    }
}

impl<T> DerefMut for NodeWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: unique writer until drop.
        unsafe { &mut *self.lock.cell.get() }
    }
}

impl<T> Drop for NodeWriteGuard<'_, T> {
    fn drop(&mut self) {
        let mut s = self.lock.state();
        if std::thread::panicking() {
            // poison → node-kill: the writer died mid-mutation, so the
            // state is suspect. Fail the node instead of letting the
            // half-written floats leak to the next reader.
            s.dead = true;
        }
        s.writer = false;
        drop(s);
        self.lock.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Loop count for the threaded tests — shrunk under the Miri CI lane
    /// (interpreting every interleaving step is ~100× slower; exhaustive
    /// interleaving coverage is the loom models' job, Miri checks the
    /// memory model on a few real schedules).
    const SPINS: usize = if cfg!(miri) { 20 } else { 500 };

    #[test]
    fn read_write_roundtrip() {
        let l = NodeLock::new(vec![1.0f32, 2.0]);
        assert_eq!(*l.read().unwrap(), vec![1.0, 2.0]);
        l.write().unwrap()[0] = 5.0;
        assert_eq!(l.read().unwrap()[0], 5.0);
    }

    #[test]
    fn concurrent_readers_share() {
        let l = Arc::new(NodeLock::new(7u64));
        let peak = Arc::new(AtomicUsize::new(0));
        let cur = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let (l, peak, cur) = (l.clone(), peak.clone(), cur.clone());
                s.spawn(move || {
                    for _ in 0..SPINS {
                        let g = l.read().unwrap();
                        let n = cur.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(n, Ordering::SeqCst);
                        assert_eq!(*g, 7);
                        cur.fetch_sub(1, Ordering::SeqCst);
                    }
                });
            }
        });
        // overlap needs real preemption; Miri's deterministic scheduler
        // may never preempt inside the window, so only assert natively
        if !cfg!(miri) {
            assert!(peak.load(Ordering::SeqCst) >= 2, "readers never overlapped");
        }
    }

    #[test]
    fn writers_are_exclusive() {
        let l = Arc::new(NodeLock::new(0u64));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let l = l.clone();
                s.spawn(move || {
                    for _ in 0..SPINS {
                        let mut g = l.write().unwrap();
                        let v = *g;
                        *g = v + 1; // non-atomic rmw: races would lose counts
                    }
                });
            }
        });
        assert_eq!(*l.read().unwrap(), (4 * SPINS) as u64);
    }

    #[test]
    fn panicking_writer_kills_the_node() {
        // THE poison-conversion contract: a writer that panics mid-update
        // leaves the node FAILED — readers get NodeDead, never the
        // half-written state.
        let l = Arc::new(NodeLock::new(vec![0.0f32; 4]));
        let l2 = l.clone();
        let res = std::thread::spawn(move || {
            let mut g = l2.write().unwrap();
            g[0] = f32::NAN; // half-applied update
            panic!("trainer died mid-apply");
        })
        .join();
        assert!(res.is_err());
        assert!(l.is_dead());
        assert!(matches!(l.read().map(|_| ()), Err(NodeDead)));
        assert!(matches!(l.write().map(|_| ()), Err(NodeDead)));
    }

    #[test]
    fn kill_then_revive_restores_service() {
        let l = NodeLock::new(3u64);
        l.kill();
        assert!(l.read().is_err());
        l.revive(9);
        assert!(!l.is_dead());
        assert_eq!(*l.read().unwrap(), 9);
    }

    #[test]
    fn revive_after_poison_replaces_corrupt_state() {
        let l = Arc::new(NodeLock::new(1u64));
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let mut g = l2.write().unwrap();
            *g = 999;
            panic!();
        })
        .join();
        assert!(l.is_dead());
        l.revive(42);
        assert_eq!(*l.read().unwrap(), 42, "revive must install the fresh state");
    }

    #[test]
    #[should_panic(expected = "live node")]
    fn revive_on_live_node_panics() {
        let l = NodeLock::new(0u8);
        l.revive(1);
    }

    #[test]
    fn revive_with_mutates_in_place() {
        let l = NodeLock::new(vec![1.0f32, 2.0]);
        let p0 = l.read().unwrap().as_ptr();
        l.kill();
        l.revive_with(|v| v.iter_mut().for_each(|x| *x = 0.0));
        let g = l.read().unwrap();
        assert_eq!(*g, vec![0.0, 0.0]);
        // the whole point: the Vec allocation survives the respawn
        assert_eq!(g.as_ptr(), p0, "revive_with must not reallocate");
    }

    #[test]
    #[should_panic(expected = "live node")]
    fn revive_with_on_live_node_panics() {
        let l = NodeLock::new(0u8);
        l.revive_with(|_| {});
    }
}
