//! Failure injection and failure-trace synthesis (paper §3, §5.1).
//!
//! Two roles:
//!  * **schedules** for the training emulator: failure times within one
//!    job plus the set of Emb PS victims per event (the paper injects
//!    failures uniformly in time, each clearing 12.5–50% of the Emb PS);
//!  * **population traces** for the fleet analysis (Fig. 3): per-node
//!    hazard simulation of thousands of jobs, from which the gamma
//!    survival fit and the MTBF-vs-nodes trend are recovered.

use crate::util::dist::{exponential, gamma};
use crate::util::rng::Rng;

/// One failure event inside an emulated training job. A single event can
/// strike Emb PS nodes, trainer replicas, or both (the paper's fleet
/// analysis counts trainer failures alongside PS node loss).
#[derive(Clone, Debug, PartialEq)]
pub struct FailureEvent {
    /// emulated wall-clock time, hours from job start
    pub time_h: f64,
    /// Emb PS node ids cleared by this failure
    pub victims: Vec<usize>,
    /// trainer ranks killed by this failure (their dense replicas are
    /// lost; see the coordinator's trainer-failure recovery matrix)
    pub trainer_victims: Vec<usize>,
}

/// Paper-style emulation schedule: `n_failures` failures at uniform random
/// times in (0, t_total_h), each killing `victims_per_failure` distinct
/// nodes of `n_nodes`. Sorted by time.
pub fn uniform_schedule(
    rng: &mut Rng,
    n_failures: usize,
    t_total_h: f64,
    n_nodes: usize,
    victims_per_failure: usize,
) -> Vec<FailureEvent> {
    assert!(victims_per_failure >= 1 && victims_per_failure <= n_nodes);
    let mut events: Vec<FailureEvent> = (0..n_failures)
        .map(|_| FailureEvent {
            time_h: rng.f64() * t_total_h,
            victims: rng.sample_distinct(n_nodes, victims_per_failure),
            trainer_victims: vec![],
        })
        .collect();
    events.sort_by(|a, b| a.time_h.partial_cmp(&b.time_h).unwrap());
    events
}

/// Trainer-loss schedule: `n_failures` events at uniform random times,
/// each killing one uniformly-chosen trainer rank. Combine with
/// [`uniform_schedule`] (concat + let the coordinator sort) to emulate a
/// mixed PS + trainer failure mix.
pub fn trainer_schedule(
    rng: &mut Rng,
    n_failures: usize,
    t_total_h: f64,
    n_trainers: usize,
) -> Vec<FailureEvent> {
    assert!(n_trainers >= 1);
    let mut events: Vec<FailureEvent> = (0..n_failures)
        .map(|_| FailureEvent {
            time_h: rng.f64() * t_total_h,
            victims: vec![],
            trainer_victims: vec![rng.usize_below(n_trainers)],
        })
        .collect();
    events.sort_by(|a, b| a.time_h.partial_cmp(&b.time_h).unwrap());
    events
}

/// Hazard-model schedule: exponential inter-arrival with mean `t_fail_h`
/// (memoryless — matches the paper's near-uniform hazard, Fig. 3b), each
/// event killing one uniformly-chosen node.
pub fn hazard_schedule(
    rng: &mut Rng,
    t_total_h: f64,
    t_fail_h: f64,
    n_nodes: usize,
) -> Vec<FailureEvent> {
    let mut events = Vec::new();
    let mut t = exponential(rng, t_fail_h);
    while t < t_total_h {
        events.push(FailureEvent {
            time_h: t,
            victims: vec![rng.usize_below(n_nodes)],
            trainer_victims: vec![],
        });
        t += exponential(rng, t_fail_h);
    }
    events
}

/// Per-node failure model for the fleet simulation (Fig. 3): a node's
/// time-to-failure is gamma-distributed (shape 1 = memoryless, matching
/// the near-constant production hazard — and min-of-n exponentials gives
/// exactly the paper's MTBF ∝ 1/n scaling). "Infant mortality" is a
/// *job-level* mode (probability `infant_p`, very short TTF): erroneous
/// configurations fail the whole job right at the start regardless of node
/// count, reproducing the paper's elevated hazard near t = 0 (Fig. 3b).
#[derive(Clone, Copy, Debug)]
pub struct NodeHazard {
    pub gamma_shape: f64,
    /// scale such that a single node's MTBF = shape * scale (hours)
    pub gamma_scale: f64,
    pub infant_p: f64,
    pub infant_mean_h: f64,
}

impl Default for NodeHazard {
    fn default() -> Self {
        // Per-node MTBF ≈ 420 h; a 16-node job then has MTBF ≈ 26 h,
        // inside the paper's 14–30 h band, scaling linearly with 1/n.
        Self { gamma_shape: 1.0, gamma_scale: 420.0, infant_p: 0.08, infant_mean_h: 0.5 }
    }
}

impl NodeHazard {
    /// Sample one node's time-to-failure (hardware/system mode only).
    pub fn sample_node_ttf(&self, rng: &mut Rng) -> f64 {
        gamma(rng, self.gamma_shape, self.gamma_scale)
    }

    /// Time-to-first-failure of a job with `n_nodes` nodes: job-level
    /// infant mortality, else min over the nodes' independent TTFs.
    pub fn sample_job_ttf(&self, rng: &mut Rng, n_nodes: usize) -> f64 {
        if rng.bool_with(self.infant_p) {
            return exponential(rng, self.infant_mean_h);
        }
        (0..n_nodes)
            .map(|_| self.sample_node_ttf(rng))
            .fold(f64::INFINITY, f64::min)
    }

    /// Simulate a fleet: `jobs` jobs of `n_nodes` each; returns observed
    /// times-to-failure (jobs without failure inside `horizon_h` are
    /// excluded, matching the paper's methodology §3.1).
    pub fn fleet_ttfs(
        &self,
        rng: &mut Rng,
        jobs: usize,
        n_nodes: usize,
        horizon_h: f64,
    ) -> Vec<f64> {
        (0..jobs)
            .map(|_| self.sample_job_ttf(rng, n_nodes))
            .filter(|&t| t < horizon_h)
            .collect()
    }
}

/// Empirical survival curve S(t) over a grid of `points` times up to
/// `t_max`; returns (t, S(t)) pairs.
pub fn survival_curve(ttfs: &[f64], t_max: f64, points: usize) -> Vec<(f64, f64)> {
    let n = ttfs.len() as f64;
    (0..points)
        .map(|i| {
            let t = t_max * (i as f64 + 1.0) / points as f64;
            let surviving = ttfs.iter().filter(|&&x| x > t).count() as f64;
            (t, surviving / n)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::{forall, gen};
    use crate::util::stats;

    #[test]
    fn uniform_schedule_shapes() {
        forall(21, 100, |rng| {
            let n_nodes = gen::usize_in(rng, 2, 32);
            let victims = gen::usize_in(rng, 1, n_nodes);
            let k = gen::usize_in(rng, 0, 10);
            let ev = uniform_schedule(rng, k, 56.0, n_nodes, victims);
            prop_assert!(ev.len() == k);
            let mut prev = 0.0;
            for e in &ev {
                prop_assert!(e.time_h >= prev, "not sorted");
                prev = e.time_h;
                prop_assert!(e.time_h <= 56.0);
                prop_assert!(e.victims.len() == victims);
                let set: std::collections::HashSet<_> = e.victims.iter().collect();
                prop_assert!(set.len() == victims, "duplicate victims");
                prop_assert!(e.victims.iter().all(|&v| v < n_nodes));
            }
            Ok(())
        });
    }

    #[test]
    fn uniform_schedule_is_deterministic_under_fixed_seed() {
        for seed in [1u64, 99, 0xBEEF] {
            let a = uniform_schedule(&mut Rng::new(seed), 6, 56.0, 8, 2);
            let b = uniform_schedule(&mut Rng::new(seed), 6, 56.0, 8, 2);
            assert_eq!(a, b, "seed {seed}");
        }
        let a = uniform_schedule(&mut Rng::new(1), 6, 56.0, 8, 2);
        let b = uniform_schedule(&mut Rng::new(2), 6, 56.0, 8, 2);
        assert_ne!(a, b, "different seeds must give different schedules");
    }

    #[test]
    fn uniform_schedule_times_within_bounds_and_sorted() {
        let ev = uniform_schedule(&mut Rng::new(5), 50, 10.0, 4, 4);
        assert_eq!(ev.len(), 50);
        let mut prev = 0.0;
        for e in &ev {
            assert!(e.time_h >= 0.0 && e.time_h <= 10.0);
            assert!(e.time_h >= prev, "not sorted");
            prev = e.time_h;
            // killing all 4 of 4 nodes: victims must be exactly {0,1,2,3}
            let mut v = e.victims.clone();
            v.sort_unstable();
            assert_eq!(v, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn trainer_schedule_shapes_and_determinism() {
        forall(23, 100, |rng| {
            let n_trainers = gen::usize_in(rng, 1, 16);
            let k = gen::usize_in(rng, 0, 8);
            let ev = trainer_schedule(rng, k, 56.0, n_trainers);
            prop_assert!(ev.len() == k);
            let mut prev = 0.0;
            for e in &ev {
                prop_assert!(e.time_h >= prev && e.time_h <= 56.0, "not sorted");
                prev = e.time_h;
                prop_assert!(e.victims.is_empty(), "PS victims on a trainer event");
                prop_assert!(e.trainer_victims.len() == 1);
                prop_assert!(e.trainer_victims[0] < n_trainers);
            }
            Ok(())
        });
        let a = trainer_schedule(&mut Rng::new(9), 5, 56.0, 4);
        let b = trainer_schedule(&mut Rng::new(9), 5, 56.0, 4);
        assert_eq!(a, b, "trainer schedules must be seed-deterministic");
    }

    #[test]
    fn hazard_schedule_rate_is_roughly_poisson() {
        let mut rng = Rng::new(1);
        let mut total = 0usize;
        let reps = 2000;
        for _ in 0..reps {
            total += hazard_schedule(&mut rng, 56.0, 28.0, 8).len();
        }
        let mean = total as f64 / reps as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean events {mean}"); // 56/28 = 2
    }

    #[test]
    fn job_mtbf_decreases_roughly_linearly_with_nodes() {
        // paper §3.1: MTBF linear in 1/n
        let hz = NodeHazard { infant_p: 0.0, ..Default::default() };
        let mut rng = Rng::new(2);
        let mtbf = |n: usize, rng: &mut Rng| {
            let xs: Vec<f64> = (0..4000).map(|_| hz.sample_job_ttf(rng, n)).collect();
            stats::mean(&xs)
        };
        let m16 = mtbf(16, &mut rng);
        let m32 = mtbf(32, &mut rng);
        let m64 = mtbf(64, &mut rng);
        // min of iid RVs: roughly 1/n scaling for small-t gamma tail
        // (shape 2 ⇒ min-scaling ~ 1/sqrt(n)·..; just assert monotone + band)
        assert!(m32 < m16 && m64 < m32, "not monotone: {m16} {m32} {m64}");
        let r = m16 / m32;
        assert!(r > 1.2 && r < 2.5, "scaling ratio {r}");
    }

    #[test]
    fn default_hazard_mtbf_in_paper_band() {
        // paper: MTBF 14–30 h for production jobs
        let hz = NodeHazard::default();
        let mut rng = Rng::new(3);
        let ttfs = hz.fleet_ttfs(&mut rng, 8000, 16, 1e9);
        let m = stats::mean(&ttfs);
        assert!((10.0..40.0).contains(&m), "MTBF {m}");
    }

    #[test]
    fn survival_curve_monotone_from_one() {
        let mut rng = Rng::new(4);
        let hz = NodeHazard::default();
        let ttfs = hz.fleet_ttfs(&mut rng, 3000, 16, 1e9);
        let sc = survival_curve(&ttfs, 100.0, 50);
        let mut prev = 1.0;
        for &(_, s) in &sc {
            assert!(s <= prev + 1e-12 && (0.0..=1.0).contains(&s));
            prev = s;
        }
    }

    #[test]
    fn infant_mortality_raises_early_hazard() {
        let mut rng = Rng::new(5);
        let with = NodeHazard::default();
        let without = NodeHazard { infant_p: 0.0, ..Default::default() };
        let t_with = with.fleet_ttfs(&mut rng, 6000, 16, 1e9);
        let t_wo = without.fleet_ttfs(&mut rng, 6000, 16, 1e9);
        let early = |xs: &[f64]| xs.iter().filter(|&&x| x < 1.0).count() as f64
            / xs.len() as f64;
        assert!(early(&t_with) > 2.0 * early(&t_wo),
                "infant mode invisible: {} vs {}", early(&t_with), early(&t_wo));
    }
}
