//! The pluggable checkpoint-policy engine.
//!
//! The paper's contribution is really three *policies* — estimating the
//! benefit of partial recovery, selecting a save interval, and
//! prioritizing hot rows — and this module turns each into a first-class
//! trait so the coordinator's step loop stays a thin, strategy-free
//! driver (Chameleon argues fault-tolerance policy selection deserves a
//! runtime API; Check-N-Run shows checkpoint content policy composes
//! orthogonally with tracking):
//!
//! * [`PriorityTracker`] (in [`tracker`]) — the object-safe unification
//!   of the SCAR/MFU/SSU row trackers: `record_batch` / `select` /
//!   `on_saved` / `memory_bytes`. The SCAR cluster-read dependency is
//!   injected as a `&dyn PsDataPlane` argument, so `select` needs no
//!   live backend generic at the call site.
//! * [`SavePolicy`] — owns the interval math (from `pls::plan`), the
//!   minor/major save cadence, the per-save row selection, and the save
//!   side of the overhead ledger. Implementations: [`save::FullSave`],
//!   [`save::CprVanilla`], [`save::Prioritized`], and
//!   [`adaptive::AdaptiveInterval`] (the policy only expressible in this
//!   API: it re-runs the PLS planner online from the observed failure
//!   rate and widens/narrows the interval between majors).
//! * [`RecoveryPolicy`] — absorbs the PLS accounting and the
//!   kill/respawn/restore sequence behind `on_failure -> RecoveryAction`.
//!   Implementations: [`recovery::FullRewind`] and
//!   [`recovery::PartialRestore`].
//!
//! [`registry`] maps `config::Strategy` (plus string keys, for CLI-side
//! construction) to a boxed [`registry::JobPolicies`] bundle; the
//! coordinator builds the bundle up front and the step loop never
//! branches on the strategy again.
//!
//! ## Backend access: [`PsView`]
//!
//! Policies run behind the driver's exclusive quiesce token
//! (`ShardedPs::quiesce`), but the trait methods must stay object-safe,
//! so they cannot take the token's `PsQuiesce<'_, B>` generic directly.
//! Instead the driver derefs the token and hands out a [`PsView`] — one
//! `&dyn` reference per cluster plane, both pointing at the same
//! quiesced backend. (Two references because Rust before 1.86 cannot
//! upcast `&dyn PsBackend` to its supertrait objects, and this crate's
//! MSRV is 1.74.)

pub mod adaptive;
pub mod recovery;
pub mod registry;
pub mod save;
pub mod tracker;

pub use adaptive::AdaptiveInterval;
pub use recovery::{FullRewind, PartialRestore};
pub use registry::{build_policies, JobPolicies, PolicySpec};
pub use save::{CprVanilla, FullSave, Prioritized};
pub use tracker::PriorityTracker;

use crate::checkpoint::async_pipeline::CheckpointPipeline;
use crate::cluster::{PlanAccess, PsBackend, PsControlPlane, PsDataPlane};
use crate::failure::FailureEvent;
use crate::metrics::OverheadLedger;

/// The backend surface a policy may touch, split per cluster plane. Both
/// references point at the SAME backend, which the driver has quiesced
/// (no data-plane call in flight) before handing the view out — see the
/// module docs for why this is not a single `&dyn PsBackend`.
#[derive(Clone, Copy)]
pub struct PsView<'a> {
    /// gathers / batched row reads (priority-save capture, SCAR scans)
    pub data: &'a dyn PsDataPlane,
    /// snapshot / load / kill / respawn (capture + failure injection)
    pub ctl: &'a dyn PsControlPlane,
}

impl<'a> PsView<'a> {
    /// Both planes of one concrete backend (typically `&*quiesce_token`).
    pub fn new<B: PsBackend>(backend: &'a B) -> Self {
        Self { data: backend, ctl: backend }
    }
}

/// What the driver knows at a save point.
pub struct SaveCtx<'a> {
    /// global step the save is taken at
    pub step: u64,
    /// samples consumed so far (`step × batch × n_trainers`)
    pub samples: u64,
    /// emulated clock, hours
    pub clock_h: f64,
    /// the post-allreduce dense parameters (host layout)
    pub host_params: &'a [Vec<f32>],
}

/// What the driver knows when a failure event fires.
pub struct FailureCtx {
    /// emulated clock at the event, hours
    pub clock_h: f64,
    /// emulated hours per global step (for lost-computation accounting)
    pub dt_h: f64,
    /// samples consumed so far
    pub samples: u64,
    /// step of the last position-marking save
    pub marked_step: u64,
    /// samples at the last position-marking save
    pub marked_samples: u64,
}

/// A position-marking save happened: the PLS marker advanced to here.
/// The driver mirrors this into its local `marked_*` state, which feeds
/// the next [`FailureCtx`].
pub struct SaveMarker {
    /// step the marker now points at
    pub step: u64,
    /// samples the marker now points at
    pub samples: u64,
}

/// What the driver must do after a recovery policy handled a failure.
/// Everything the policy can reach through [`PsView`] + the pipeline is
/// already done (PS kills, respawns, restores, ledger charges); the
/// action carries only the driver-owned effects (dense params, step
/// counter — trainer kill/respawn is policy-independent and stays in the
/// driver).
pub enum RecoveryAction {
    /// Partial recovery: keep going from the current position. When
    /// `reload_dense_from_marker` is set (a trainer loss with no
    /// surviving replica), the driver reloads the dense params (stale)
    /// from the pipeline's position marker while the Emb PS keeps its
    /// progress.
    Continue {
        /// reload dense params from the last checkpoint marker
        reload_dense_from_marker: bool,
    },
    /// Full recovery: everyone reloads and training rewinds.
    Rewind {
        /// dense params from the checkpoint (host layout)
        mlp: Vec<Vec<f32>>,
        /// global step to rewind to
        step: u64,
    },
}

/// Decides *when* to checkpoint and *what* to capture, and owns the save
/// side of the overhead ledger. Object-safe: the registry hands the
/// driver a `Box<dyn SavePolicy>`.
pub trait SavePolicy {
    /// Short identifier for reports/diagnostics.
    fn name(&self) -> &'static str;

    /// Emulated hour of the next save. The driver captures whenever the
    /// clock reaches this (and it is still within the job).
    fn next_save_h(&self) -> f64;

    /// Observe one trainer batch's embedding access stream
    /// (`[B, num_tables, hotness]` row-major). The driver feeds every
    /// trainer's stream in rank order; tracker-less policies ignore it.
    fn on_step(&mut self, _indices: &[u32], _num_tables: usize, _hotness: usize) {}

    /// Planned variant of [`SavePolicy::on_step`]: the trainer already
    /// deduplicated the batch into `accesses` (one entry per distinct
    /// `(table, row)` with its within-batch multiplicity), so policies
    /// whose recording is multiplicity-weighted or set-based can consume
    /// the compact stream instead of rescanning `indices`. The default
    /// ignores `accesses` and falls back to the full-scan `on_step`, so
    /// order-sensitive recorders (SSU's reservoir ticks over every slot)
    /// stay bit-identical without opting in.
    fn on_step_planned(
        &mut self,
        indices: &[u32],
        accesses: &[PlanAccess],
        num_tables: usize,
        hotness: usize,
    ) {
        let _ = accesses;
        self.on_step(indices, num_tables, hotness);
    }

    /// Observe a failure event (any kind) at `clock_h`. Adaptive policies
    /// re-estimate the failure rate from these; everyone else ignores it.
    fn observe_failure(&mut self, _clock_h: f64) {}

    /// Capture one save at the driver's quiesce point: charge the ledger,
    /// select + hand content to the pipeline, advance `next_save_h`.
    /// Returns the new position marker when this save advanced it (a
    /// major), `None` for minor (content-only) saves.
    fn capture(
        &mut self,
        ps: PsView<'_>,
        pipeline: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        ctx: &SaveCtx<'_>,
    ) -> Option<SaveMarker>;
}

/// Decides what happens when a failure event fires: charges the ledger,
/// runs the PS-side recovery protocol through the quiesced backend, and
/// tells the driver what to do with its own state. Object-safe.
pub trait RecoveryPolicy {
    /// Short identifier for reports/diagnostics.
    fn name(&self) -> &'static str;

    /// Handle one failure event at the driver's quiesce point.
    fn on_failure(
        &mut self,
        ev: &FailureEvent,
        ps: PsView<'_>,
        pipeline: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        ctx: &FailureCtx,
    ) -> RecoveryAction;

    /// Accumulated PLS (Eq. 3) so far; 0 under full recovery.
    fn pls(&self) -> f64;
}
