//! [`AdaptiveInterval`] — the save policy only expressible in the policy
//! engine: Chameleon-style online re-planning of the checkpoint interval.
//!
//! The static CPR controller picks one interval up front from the
//! *configured* MTBF. Real clusters drift (off-peak windows, bad
//! hardware batches), so this policy re-estimates the MTBF from the
//! failures actually observed (`pls::estimate_mtbf`: the configured MTBF
//! acts as a one-pseudo-failure prior, converging to the empirical rate
//! as events accrue) and re-runs the PLS planner at every major save —
//! widening the interval when the job fails less than expected, and
//! narrowing it when failures come fast, while holding the same target
//! PLS. Every accepted re-plan is recorded in
//! `metrics::OverheadLedger::replans`, so the `TrainReport` ledger shows
//! the interval trajectory.

use super::save::{full_content_capture, TouchedRows};
use super::{PsView, SaveCtx, SaveMarker, SavePolicy};
use crate::cluster::PlanAccess;
use crate::checkpoint::async_pipeline::CheckpointPipeline;
use crate::config::ClusterConfig;
use crate::metrics::OverheadLedger;
use crate::pls;

/// Online-replanned CPR (full-content saves, PLS-planned cadence that
/// tracks the observed failure rate). `Strategy::CprAdaptive`.
pub struct AdaptiveInterval {
    cluster: ClusterConfig,
    target_pls: f64,
    /// false when a `t_save_override_h` sweep pinned the interval (or the
    /// caller wants static-plan behaviour): capture still saves, but the
    /// interval never moves
    replan: bool,
    interval_h: f64,
    next_save_h: f64,
    failures_seen: u64,
    delta: Option<TouchedRows>,
    byte_ratio: f64,
}

impl AdaptiveInterval {
    /// Start from `interval_h` (the static plan's choice) and re-plan at
    /// every major when `replan` is set.
    pub fn new(cluster: &ClusterConfig, target_pls: f64, interval_h: f64, replan: bool) -> Self {
        Self {
            cluster: cluster.clone(),
            target_pls,
            replan,
            interval_h,
            next_save_h: interval_h,
            failures_seen: 0,
            delta: None,
            byte_ratio: 1.0,
        }
    }

    /// Format v2: delta-capture touched rows instead of full node
    /// snapshots (see `FullSave::with_delta_capture`).
    pub fn with_delta_capture(mut self, table_rows: &[usize]) -> Self {
        self.delta = Some(TouchedRows::new(table_rows));
        self
    }

    /// Codec-scaled ledger charges (see `FullSave::with_byte_ratio`).
    pub fn with_byte_ratio(mut self, ratio: f64) -> Self {
        self.byte_ratio = ratio;
        self
    }

    /// The current (possibly re-planned) save interval, hours.
    pub fn interval_h(&self) -> f64 {
        self.interval_h
    }

    /// Failure events observed so far.
    pub fn failures_seen(&self) -> u64 {
        self.failures_seen
    }
}

impl SavePolicy for AdaptiveInterval {
    fn name(&self) -> &'static str {
        "adaptive-interval"
    }

    fn next_save_h(&self) -> f64 {
        self.next_save_h
    }

    fn observe_failure(&mut self, _clock_h: f64) {
        self.failures_seen += 1;
    }

    fn on_step(&mut self, indices: &[u32], num_tables: usize, hotness: usize) {
        if let Some(touched) = self.delta.as_mut() {
            touched.record(indices, num_tables, hotness);
        }
    }

    fn on_step_planned(
        &mut self,
        _indices: &[u32],
        accesses: &[PlanAccess],
        _num_tables: usize,
        _hotness: usize,
    ) {
        if let Some(touched) = self.delta.as_mut() {
            touched.record_planned(accesses);
        }
    }

    fn capture(
        &mut self,
        ps: PsView<'_>,
        pipeline: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        ctx: &SaveCtx<'_>,
    ) -> Option<SaveMarker> {
        let marker = full_content_capture(self.cluster.o_save_h, self.delta.as_mut(),
                                          self.byte_ratio, ps, pipeline, ledger, ctx);
        if self.replan {
            let mut c = self.cluster.clone();
            c.t_fail_h =
                pls::estimate_mtbf(self.cluster.t_fail_h, ctx.clock_h, self.failures_seen);
            let p = pls::plan(&c, self.target_pls);
            // only move while partial recovery stays beneficial under the
            // re-estimated rate; the recovery mode itself is fixed at job
            // start, so a mid-job "would fall back" just freezes the
            // interval instead of switching semantics
            if p.use_partial && (p.t_save_h - self.interval_h).abs() > 1e-9 {
                crate::telemetry::event("replan");
                ledger.replans.push((ctx.clock_h, p.t_save_h));
                self.interval_h = p.t_save_h;
            }
        }
        self.next_save_h += self.interval_h;
        Some(marker)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::CheckpointStore;
    use crate::config::preset;
    use crate::embedding::{PsCluster, TableInfo};

    fn cluster() -> PsCluster {
        PsCluster::new(vec![TableInfo { rows: 16, dim: 4 }], 2, 5)
    }

    fn pipeline(c: &PsCluster) -> CheckpointPipeline {
        CheckpointPipeline::with_options(
            CheckpointStore::initial(c, vec![]),
            &crate::checkpoint::CheckpointOptions::default(),
        )
        .unwrap()
    }

    fn capture_at(
        policy: &mut AdaptiveInterval,
        c: &PsCluster,
        p: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        clock_h: f64,
    ) {
        let ctx = SaveCtx { step: 1, samples: 128, clock_h, host_params: &[] };
        policy.capture(PsView::new(c), p, ledger, &ctx).expect("majors mark");
    }

    #[test]
    fn widens_when_failures_stay_absent() {
        let cl = preset("mini").unwrap().cluster; // T_fail = 28 h
        let p0 = pls::plan(&cl, 0.02);
        assert!(p0.use_partial);
        let c = cluster();
        let pipe = pipeline(&c);
        let mut policy = AdaptiveInterval::new(&cl, 0.02, p0.t_save_h, true);
        let mut ledger = OverheadLedger::default();
        let t1 = policy.next_save_h();
        capture_at(&mut policy, &c, &pipe, &mut ledger, t1);
        assert!(policy.interval_h() > p0.t_save_h,
                "no observed failures must stretch the interval");
        assert_eq!(ledger.replans.len(), 1);
        assert!((ledger.replans[0].0 - t1).abs() < 1e-12);
        assert!((ledger.replans[0].1 - policy.interval_h()).abs() < 1e-12);
        pipe.flush().unwrap();
    }

    #[test]
    fn narrows_when_failures_come_faster_than_planned() {
        let cl = preset("mini").unwrap().cluster;
        let p0 = pls::plan(&cl, 0.02);
        let c = cluster();
        let pipe = pipeline(&c);
        let mut policy = AdaptiveInterval::new(&cl, 0.02, p0.t_save_h, true);
        let mut ledger = OverheadLedger::default();
        // 6 failures before the first major — far above the 28-h MTBF
        for i in 0..6 {
            policy.observe_failure(i as f64);
        }
        let t1 = policy.next_save_h();
        capture_at(&mut policy, &c, &pipe, &mut ledger, t1);
        assert!(policy.interval_h() < p0.t_save_h,
                "frequent failures must shrink the interval: {} !< {}",
                policy.interval_h(), p0.t_save_h);
        assert_eq!(ledger.replans.len(), 1);
        pipe.flush().unwrap();
    }

    #[test]
    fn frozen_interval_never_replans() {
        let cl = preset("mini").unwrap().cluster;
        let c = cluster();
        let pipe = pipeline(&c);
        let mut policy = AdaptiveInterval::new(&cl, 0.02, 5.0, false);
        let mut ledger = OverheadLedger::default();
        policy.observe_failure(1.0);
        capture_at(&mut policy, &c, &pipe, &mut ledger, 5.0);
        assert_eq!(policy.interval_h(), 5.0);
        assert!(ledger.replans.is_empty());
        assert_eq!(policy.next_save_h(), 10.0);
        pipe.flush().unwrap();
    }
}
