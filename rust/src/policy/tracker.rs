//! [`PriorityTracker`] — the object-safe unification of the three
//! priority-row trackers (`checkpoint::tracker::{ScarTracker, MfuTracker,
//! SsuTracker}`).
//!
//! Before the policy engine, the coordinator held one `Option<...>` per
//! tracker type and chained `if let` over them at every save. The trait
//! collapses that to one `select`/`on_saved` surface, with SCAR's
//! cluster-read dependency injected as a `&dyn PsDataPlane` argument
//! instead of a generic bound — so `policy::save::Prioritized` works over
//! any tracker, boxed or concrete, and the trait-conformance suite below
//! runs all three through `Box<dyn PriorityTracker>`.

use crate::checkpoint::tracker::{MfuTracker, ScarTracker, SsuTracker};
use crate::cluster::{PlanAccess, PsDataPlane};

/// One priority-row tracker behind a uniform, object-safe API.
///
/// Contract (asserted by the conformance suite below):
/// * `select` is deterministic for a fixed seed and input stream;
/// * whenever `k` does not exceed the number of distinct recorded rows,
///   every selected row was previously recorded (or, for SCAR, changed);
/// * `on_saved` resets the saved rows' selection pressure (MFU clears
///   their counters; SSU's candidate list is drained by `select` itself;
///   SCAR refreshes their mirror entries);
/// * `memory_bytes` is positive for any non-empty priority table set.
pub trait PriorityTracker {
    /// Short identifier ("mfu" | "ssu" | "scar").
    fn name(&self) -> &'static str;

    /// Observe one minibatch of accesses: `indices` is
    /// `[B, num_tables, hotness]` row-major.
    fn record_batch(&mut self, indices: &[u32], num_tables: usize, hotness: usize);

    /// Planned variant: the batch arrives pre-deduplicated as `accesses`
    /// (one entry per distinct `(table, row)` with its multiplicity),
    /// alongside the raw stream. Only trackers whose recording is a pure
    /// per-row weighted count may consume the compact list (MFU does:
    /// `+= count` is bit-exact vs `count` increments). The default falls
    /// back to the full scan, which keeps order-sensitive recorders —
    /// SSU's subsample tick and eviction RNG advance per *slot* in stream
    /// order — bit-identical without opting in. SCAR's record is a no-op
    /// either way.
    fn record_batch_planned(
        &mut self,
        indices: &[u32],
        accesses: &[PlanAccess],
        num_tables: usize,
        hotness: usize,
    ) {
        let _ = accesses;
        self.record_batch(indices, num_tables, hotness);
    }

    /// The (up to) `k` rows of `table` most deserving of checkpoint
    /// bandwidth. `ps` is the quiesced cluster data plane — only SCAR
    /// reads it (its ranking is the L2 change against a mirror).
    /// May mutate internal state (SSU drains its candidate list).
    fn select(&mut self, ps: &dyn PsDataPlane, table: usize, k: usize) -> Vec<u32>;

    /// The selected `rows` of `table` were handed to the checkpoint
    /// pipeline: reset their selection pressure.
    fn on_saved(&mut self, ps: &dyn PsDataPlane, table: usize, rows: &[u32]);

    /// Tracker memory overhead in bytes (paper Table 1).
    fn memory_bytes(&self) -> usize;
}

impl PriorityTracker for MfuTracker {
    fn name(&self) -> &'static str {
        "mfu"
    }

    fn record_batch(&mut self, indices: &[u32], num_tables: usize, hotness: usize) {
        self.record_batch_hot(indices, num_tables, hotness);
    }

    fn record_batch_planned(
        &mut self,
        _indices: &[u32],
        accesses: &[PlanAccess],
        _num_tables: usize,
        _hotness: usize,
    ) {
        self.record_accesses(accesses);
    }

    fn select(&mut self, _ps: &dyn PsDataPlane, table: usize, k: usize) -> Vec<u32> {
        self.top_k(table, k)
    }

    fn on_saved(&mut self, _ps: &dyn PsDataPlane, table: usize, rows: &[u32]) {
        // paper: "when an embedding vector is saved, its counter is cleared"
        self.clear_rows(table, rows);
    }

    fn memory_bytes(&self) -> usize {
        MfuTracker::memory_bytes(self)
    }
}

impl PriorityTracker for SsuTracker {
    fn name(&self) -> &'static str {
        "ssu"
    }

    fn record_batch(&mut self, indices: &[u32], num_tables: usize, hotness: usize) {
        self.record_batch_hot(indices, num_tables, hotness);
    }

    fn select(&mut self, _ps: &dyn PsDataPlane, table: usize, _k: usize) -> Vec<u32> {
        // the bounded candidate list IS the selection (its capacity is
        // r·rows); draining doubles as the post-save reset
        self.drain(table)
    }

    fn on_saved(&mut self, _ps: &dyn PsDataPlane, _table: usize, _rows: &[u32]) {
        // nothing left to reset: select() drained the list
    }

    fn memory_bytes(&self) -> usize {
        SsuTracker::memory_bytes(self)
    }
}

impl PriorityTracker for ScarTracker {
    fn name(&self) -> &'static str {
        "scar"
    }

    fn record_batch(&mut self, _indices: &[u32], _num_tables: usize, _hotness: usize) {
        // SCAR keeps no access state: it ranks by reading the cluster
    }

    fn select(&mut self, ps: &dyn PsDataPlane, table: usize, k: usize) -> Vec<u32> {
        self.top_k(ps, table, k)
    }

    fn on_saved(&mut self, ps: &dyn PsDataPlane, table: usize, rows: &[u32]) {
        self.mark_saved(ps, table, rows);
    }

    fn memory_bytes(&self) -> usize {
        ScarTracker::memory_bytes(self)
    }
}

impl<T: PriorityTracker + ?Sized> PriorityTracker for Box<T> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn record_batch(&mut self, indices: &[u32], num_tables: usize, hotness: usize) {
        (**self).record_batch(indices, num_tables, hotness);
    }

    fn record_batch_planned(
        &mut self,
        indices: &[u32],
        accesses: &[PlanAccess],
        num_tables: usize,
        hotness: usize,
    ) {
        (**self).record_batch_planned(indices, accesses, num_tables, hotness);
    }

    fn select(&mut self, ps: &dyn PsDataPlane, table: usize, k: usize) -> Vec<u32> {
        (**self).select(ps, table, k)
    }

    fn on_saved(&mut self, ps: &dyn PsDataPlane, table: usize, rows: &[u32]) {
        (**self).on_saved(ps, table, rows);
    }

    fn memory_bytes(&self) -> usize {
        (**self).memory_bytes()
    }
}

// ---------------------------------------------------------------------------
// trait-conformance suite: all three trackers through dyn PriorityTracker
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{PsCluster, TableInfo};
    use crate::prop_assert;
    use crate::testing::{forall, gen};

    fn cluster(rows: usize, seed: u64) -> PsCluster {
        PsCluster::new(vec![TableInfo { rows, dim: 4 }], 4, seed)
    }

    /// All three trackers as trait objects over one single-table layout.
    fn tracker_set(rows: usize, c: &PsCluster, seed: u64) -> Vec<Box<dyn PriorityTracker>> {
        let mask = vec![true];
        let cap = rows.div_ceil(8).max(1); // r = 0.125
        vec![
            Box::new(MfuTracker::new(&[rows], &mask)),
            Box::new(SsuTracker::new(&[cap], &mask, 2, seed)),
            Box::new(ScarTracker::new(c, &mask)),
        ]
    }

    #[test]
    fn dyn_names_are_distinct_and_stable() {
        let c = cluster(16, 1);
        let names: Vec<&str> = tracker_set(16, &c, 1).iter().map(|t| t.name()).collect();
        assert_eq!(names, vec!["mfu", "ssu", "scar"]);
    }

    #[test]
    fn dyn_select_is_deterministic_under_a_fixed_seed() {
        forall(0xD7, 20, |rng| {
            let rows = gen::usize_in(rng, 16, 120);
            let seed = rng.next_u64();
            let n_acc = gen::usize_in(rng, 8, 200);
            let accesses: Vec<u32> =
                (0..n_acc).map(|_| rng.below(rows as u64) as u32).collect();
            let grads: Vec<f32> = (0..n_acc * 4).map(|_| rng.f32() + 0.05).collect();
            let k = gen::usize_in(rng, 1, rows);
            let run_once = || -> Vec<Vec<u32>> {
                let c = cluster(rows, 7);
                // trackers first: SCAR mirrors the pre-update state, so the
                // update below is real change for it to rank
                let mut trackers = tracker_set(rows, &c, seed);
                c.sgd_update(&accesses, &grads, 0.5);
                let mut out = Vec::new();
                for t in trackers.iter_mut() {
                    t.record_batch(&accesses, 1, 1);
                    out.push(t.select(&c, 0, k));
                }
                out
            };
            prop_assert!(run_once() == run_once(),
                         "same seed + stream must reproduce the selection");
            Ok(())
        });
    }

    #[test]
    fn dyn_select_returns_only_recorded_rows() {
        forall(0xD8, 20, |rng| {
            let rows = gen::usize_in(rng, 32, 150);
            let distinct = gen::usize_in(rng, 4, 16);
            let pool: Vec<u32> = rng
                .sample_distinct(rows, distinct)
                .into_iter()
                .map(|r| r as u32)
                .collect();
            let accesses: Vec<u32> =
                (0..100).map(|_| pool[rng.usize_below(distinct)]).collect();
            // every accessed row really changes (constant positive grads,
            // so SCAR's change-L2 is strictly positive for pool rows)
            let grads = vec![0.2f32; accesses.len() * 4];
            let c = cluster(rows, 3);
            // trackers before the update: SCAR must observe the change
            let mut trackers = tracker_set(rows, &c, 5);
            c.sgd_update(&accesses, &grads, 0.5);
            // the invariant holds for k up to the number of DISTINCT rows
            // actually recorded (beyond that, zero-count filler is fair)
            let recorded: std::collections::HashSet<u32> =
                accesses.iter().copied().collect();
            let k = gen::usize_in(rng, 1, recorded.len());
            for t in trackers.iter_mut() {
                t.record_batch(&accesses, 1, 1);
                let sel = t.select(&c, 0, k);
                prop_assert!(!sel.is_empty(), "{}: empty selection", t.name());
                for r in &sel {
                    prop_assert!(recorded.contains(r),
                                 "{}: selected unrecorded row {r}", t.name());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn on_saved_clears_mfu_counts() {
        let c = cluster(50, 9);
        let mut t: Box<dyn PriorityTracker> = Box::new(MfuTracker::new(&[50], &[true]));
        t.record_batch(&[7, 7, 7, 3], 1, 1);
        let sel = t.select(&c, 0, 1);
        assert_eq!(sel, vec![7]);
        t.on_saved(&c, 0, &sel);
        // 7's counter is gone; one more access to 3 must now win
        t.record_batch(&[3], 1, 1);
        assert_eq!(t.select(&c, 0, 1), vec![3],
                   "a cleared MFU counter must stop winning");
    }

    #[test]
    fn select_drains_ssu_candidate_list() {
        let c = cluster(50, 9);
        let mut t: Box<dyn PriorityTracker> =
            Box::new(SsuTracker::new(&[8], &[true], 1, 4));
        t.record_batch(&[1, 2, 3], 1, 1);
        let sel = t.select(&c, 0, 8);
        assert!(!sel.is_empty());
        t.on_saved(&c, 0, &sel);
        assert!(t.select(&c, 0, 8).is_empty(),
                "SSU's list must be drained after a save");
    }

    #[test]
    fn on_saved_refreshes_scar_mirror() {
        let c = cluster(50, 9);
        let mut t: Box<dyn PriorityTracker> = Box::new(ScarTracker::new(&c, &[true]));
        // big change to row 42, small to row 7
        let mut grads = vec![0.0f32; 2 * 4];
        grads[0..4].copy_from_slice(&[10.0; 4]);
        grads[4..8].copy_from_slice(&[0.1; 4]);
        c.sgd_update(&[42, 7], &grads, 1.0);
        let sel = t.select(&c, 0, 1);
        assert_eq!(sel, vec![42]);
        t.on_saved(&c, 0, &sel);
        assert_eq!(t.select(&c, 0, 1), vec![7],
                   "a refreshed SCAR mirror entry must stop winning");
    }

    #[test]
    fn memory_accounting_is_positive_for_every_tracker() {
        let c = cluster(64, 2);
        for t in tracker_set(64, &c, 1) {
            assert!(t.memory_bytes() > 0, "{}: zero memory reported", t.name());
        }
    }
}
