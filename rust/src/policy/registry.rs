//! The policy registry: maps `config::Strategy` (and its string names —
//! the CLI/TOML keys) to a boxed [`JobPolicies`] bundle.
//!
//! This is the ONE place the strategy → policy wiring lives. It
//! reproduces exactly what the coordinator's retired inline `match` did:
//! run the CPR controller (`pls::plan`) for CPR strategies, apply the
//! `t_save_override_h` sweep override, decide fallback, and construct
//! the save/recovery pair (plus the tracker for priority strategies —
//! SCAR's initial mirror is read from the quiesced backend handed in as
//! a [`PsView`]). New policies plug in here: add a `Strategy` variant
//! (or reuse an existing one), register a [`PolicySpec`] row, and wire
//! the constructor — the driver never changes.

use anyhow::Result;

use super::adaptive::AdaptiveInterval;
use super::recovery::{FullRewind, PartialRestore};
use super::save::{CprVanilla, FullSave, Prioritized};
use super::{PsView, RecoveryPolicy, SavePolicy};
use crate::checkpoint::codec;
use crate::checkpoint::table_io_bytes;
use crate::checkpoint::tracker::{priority_mask, MfuTracker, ScarTracker, SsuTracker};
use crate::config::{CkptFormat, JobConfig, Strategy};
use crate::pls::{self, CprPlan};

/// The full policy bundle one training job runs under. Built up front
/// (by `config`/CLI through this registry); the coordinator's step loop
/// drives the two boxed objects and never branches on the strategy.
pub struct JobPolicies {
    /// when to checkpoint + what to capture
    pub save: Box<dyn SavePolicy>,
    /// what happens on a failure event
    pub recovery: Box<dyn RecoveryPolicy>,
    /// the CPR controller's decision (None for full / partial-naive)
    pub plan: Option<CprPlan>,
    /// true when a CPR strategy fell back to full recovery
    pub fell_back: bool,
}

/// Static description of one registered strategy: which policy objects
/// its name resolves to (nominal wiring — a fell-back CPR strategy
/// degrades to full-content saves + full rewind at run time).
#[derive(Clone, Debug)]
pub struct PolicySpec {
    /// the registry key (== `Strategy::name()`)
    pub name: &'static str,
    /// the parsed strategy this key maps to
    pub strategy: Strategy,
    /// nominal [`SavePolicy`] implementation name
    pub save: &'static str,
    /// nominal [`RecoveryPolicy`] implementation name
    pub recovery: &'static str,
    /// priority tracker, for the prioritized strategies
    pub tracker: Option<&'static str>,
    /// one-line summary for CLI/example listings
    pub summary: &'static str,
}

/// Every registered strategy, in presentation order.
pub fn specs() -> Vec<PolicySpec> {
    vec![
        PolicySpec {
            name: "full",
            strategy: Strategy::Full,
            save: "full-save",
            recovery: "full-rewind",
            tracker: None,
            summary: "full recovery at the √(2·O_save·T_fail) optimum",
        },
        PolicySpec {
            name: "partial",
            strategy: Strategy::PartialNaive,
            save: "full-save",
            recovery: "partial-restore",
            tracker: None,
            summary: "partial recovery naively reusing the full-recovery interval",
        },
        PolicySpec {
            name: "cpr-vanilla",
            strategy: Strategy::CprVanilla,
            save: "cpr-vanilla",
            recovery: "partial-restore",
            tracker: None,
            summary: "CPR with the PLS-planned interval, no priority saving",
        },
        PolicySpec {
            name: "cpr-scar",
            strategy: Strategy::CprScar,
            save: "prioritized",
            recovery: "partial-restore",
            tracker: Some("scar"),
            summary: "CPR + SCAR update-magnitude priority (100% memory)",
        },
        PolicySpec {
            name: "cpr-mfu",
            strategy: Strategy::CprMfu,
            save: "prioritized",
            recovery: "partial-restore",
            tracker: Some("mfu"),
            summary: "CPR + most-frequently-used counters",
        },
        PolicySpec {
            name: "cpr-ssu",
            strategy: Strategy::CprSsu,
            save: "prioritized",
            recovery: "partial-restore",
            tracker: Some("ssu"),
            summary: "CPR + sub-sampled-used candidate list",
        },
        PolicySpec {
            name: "cpr-adaptive",
            strategy: Strategy::CprAdaptive,
            save: "adaptive-interval",
            recovery: "partial-restore",
            tracker: None,
            summary: "CPR re-planning its interval online from the observed MTBF",
        },
    ]
}

/// The registry keys (canonical strategy names).
pub fn names() -> Vec<&'static str> {
    specs().into_iter().map(|s| s.name).collect()
}

/// The spec a strategy resolves to.
pub fn spec(strategy: &Strategy) -> PolicySpec {
    specs()
        .into_iter()
        .find(|s| &s.strategy == strategy)
        .expect("every Strategy variant is registered")
}

/// Build the policy bundle for `cfg.checkpoint.strategy`. `ps` is the
/// quiesced backend (SCAR reads its initial mirror from it). This is the
/// exact decision procedure the coordinator used to inline: plan →
/// override → fallback → cadence/tracker construction.
pub fn build_policies(cfg: &JobConfig, ps: PsView<'_>) -> JobPolicies {
    let strategy = &cfg.checkpoint.strategy;

    // format v2: full-content policies capture touched-row deltas instead
    // of node snapshots (the persistence layer then publishes them as
    // per-node delta chains); priority policies already capture rows and
    // need no mode — their minors commit deltas and majors re-base via
    // the pipeline itself.
    let v2 = cfg.checkpoint.format == CkptFormat::V2;
    // v2 with a codec publishes *encoded* bytes: the planner's save cost
    // and the ledger's I/O charges both scale by the codec's expected
    // encoded/raw ratio (1.0 under v1 or codec `none`), so cheaper
    // checkpoints genuinely narrow the planned interval. The v2 engine's
    // compaction planner uses the same estimate.
    let byte_ratio =
        if v2 { codec::estimated_ratio(cfg.checkpoint.codec) } else { 1.0 };

    // --- effective save cost -----------------------------------------------
    // Size the checkpoint from the table layout (embedding-dominated —
    // dense params are noise at DLRM scale, and `CheckpointStore::
    // size_bytes` confirms the exact figure at run time): a configured
    // write bandwidth (`cluster.save_bw_gb_h`) turns the size into a
    // per-save cost; without one (every preset) this is exactly the
    // paper's flat `o_save_h` and every plan below is bit-identical to
    // the pre-bandwidth registry.
    let raw_ckpt_bytes: u64 = cfg
        .data
        .table_rows
        .iter()
        .map(|&r| table_io_bytes(r, cfg.model.emb_dim))
        .sum();
    let ckpt_bytes = if byte_ratio == 1.0 {
        raw_ckpt_bytes
    } else {
        (raw_ckpt_bytes as f64 * byte_ratio).ceil() as u64
    };
    let mut eff_cluster = cfg.cluster.clone();
    eff_cluster.o_save_h = cfg.cluster.o_save_eff_h(Some(ckpt_bytes));
    let o_save_h = eff_cluster.o_save_h;

    // --- the CPR controller decides the plan -------------------------------
    let (plan, use_partial, mut t_save_h) = match strategy {
        Strategy::Full => (None, false, eff_cluster.t_save_full_h()),
        Strategy::PartialNaive => (None, true, eff_cluster.t_save_full_h()),
        _ => {
            // == pls::plan_with_bytes(&cfg.cluster, target, Some(ckpt_bytes))
            let p = pls::plan(&eff_cluster, cfg.checkpoint.target_pls);
            (Some(p), p.use_partial, p.t_save_h)
        }
    };
    let forced = cfg.checkpoint.t_save_override_h;
    if let Some(t) = forced {
        t_save_h = t; // Fig. 11/12 sweeps force the interval directly
    }
    let fell_back = strategy.is_cpr() && !use_partial;
    let priority = strategy.priority() && use_partial;
    let r = cfg.checkpoint.r;

    // --- save policy (+ tracker for the priority schemes) ------------------
    let save: Box<dyn SavePolicy> = if priority {
        let mask = priority_mask(&cfg.data.table_rows, cfg.checkpoint.priority_tables);
        match strategy {
            Strategy::CprMfu => Box::new(
                Prioritized::new(
                    MfuTracker::new(&cfg.data.table_rows, &mask),
                    mask,
                    r,
                    o_save_h,
                    t_save_h,
                )
                .with_byte_ratio(byte_ratio),
            ),
            Strategy::CprSsu => {
                let caps: Vec<usize> = cfg
                    .data
                    .table_rows
                    .iter()
                    .map(|&n| ((n as f64 * r).ceil() as usize).max(1))
                    .collect();
                Box::new(
                    Prioritized::new(
                        SsuTracker::new(&caps, &mask, cfg.checkpoint.ssu_period,
                                        cfg.data.seed ^ 0x55),
                        mask,
                        r,
                        o_save_h,
                        t_save_h,
                    )
                    .with_byte_ratio(byte_ratio),
                )
            }
            Strategy::CprScar => Box::new(
                Prioritized::new(
                    ScarTracker::new(ps.data, &mask),
                    mask,
                    r,
                    o_save_h,
                    t_save_h,
                )
                .with_byte_ratio(byte_ratio),
            ),
            _ => unreachable!("priority() holds only for SCAR/MFU/SSU"),
        }
    } else if matches!(strategy, Strategy::CprAdaptive) && use_partial {
        // re-plan only when the interval is not pinned by a sweep
        // override; re-plans run against the bandwidth-derived save cost
        let a = AdaptiveInterval::new(&eff_cluster, cfg.checkpoint.target_pls,
                                      t_save_h, forced.is_none())
            .with_byte_ratio(byte_ratio);
        Box::new(if v2 { a.with_delta_capture(&cfg.data.table_rows) } else { a })
    } else {
        match strategy {
            Strategy::Full | Strategy::PartialNaive => {
                let p = FullSave::new(o_save_h, t_save_h).with_byte_ratio(byte_ratio);
                Box::new(if v2 { p.with_delta_capture(&cfg.data.table_rows) } else { p })
                    as Box<dyn SavePolicy>
            }
            // fell-back CPR strategies degrade to planned full-content saves
            _ => {
                let p = CprVanilla::new(o_save_h, t_save_h).with_byte_ratio(byte_ratio);
                Box::new(if v2 { p.with_delta_capture(&cfg.data.table_rows) } else { p })
            }
        }
    };

    // --- recovery policy ----------------------------------------------------
    let recovery: Box<dyn RecoveryPolicy> = if use_partial {
        Box::new(PartialRestore::new(&cfg.cluster, cfg.data.train_samples as u64))
    } else {
        Box::new(FullRewind::new(&cfg.cluster))
    };

    JobPolicies { save, recovery, plan, fell_back }
}

/// String-keyed entry point: resolve `name` through the registry and
/// build the bundle for it (the rest of `cfg` is used as-is).
pub fn build_by_name(name: &str, cfg: &JobConfig, ps: PsView<'_>) -> Result<JobPolicies> {
    let strategy = Strategy::parse(name)?;
    let mut cfg = cfg.clone();
    cfg.checkpoint.strategy = strategy;
    Ok(build_policies(&cfg, ps))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::embedding::{PsCluster, TableInfo};
    use crate::prop_assert;
    use crate::testing::forall;

    fn backend(cfg: &JobConfig) -> PsCluster {
        let tables: Vec<TableInfo> = cfg
            .data
            .table_rows
            .iter()
            .map(|&rows| TableInfo { rows, dim: cfg.model.emb_dim })
            .collect();
        PsCluster::new(tables, cfg.cluster.n_emb_ps, cfg.data.seed ^ 0xEB)
    }

    #[test]
    fn every_registered_name_round_trips_through_parse() {
        for s in specs() {
            let parsed = Strategy::parse(s.name).expect(s.name);
            assert_eq!(parsed.name(), s.name, "parse↔name must round-trip");
            assert_eq!(parsed, s.strategy);
            assert_eq!(spec(&parsed).name, s.name);
        }
        // the shorthand alias resolves to vanilla's canonical name
        assert_eq!(Strategy::parse("cpr").unwrap().name(), "cpr-vanilla");
    }

    #[test]
    fn unknown_strategy_is_an_error_listing_every_valid_name() {
        forall(0xE1, 50, |rng| {
            // random lowercase gibberish (length 9 — never a valid key)
            let s: String = (0..9)
                .map(|_| (b'a' + rng.below(26) as u8) as char)
                .collect();
            if names().contains(&s.as_str()) {
                return Ok(()); // astronomically unlikely; skip if hit
            }
            let err = match Strategy::parse(&s) {
                Err(e) => format!("{e:#}"),
                Ok(_) => return Err(format!("{s:?} parsed unexpectedly")),
            };
            for name in names() {
                prop_assert!(err.contains(name),
                             "error must list {name:?}, got: {err}");
            }
            Ok(())
        });
    }

    #[test]
    fn builds_the_documented_bundle_for_every_strategy() {
        let base = preset("mini").unwrap();
        let c = backend(&base);
        for s in specs() {
            let mut cfg = base.clone();
            cfg.checkpoint.strategy = s.strategy.clone();
            let p = build_policies(&cfg, PsView::new(&c));
            assert!(p.save.next_save_h() > 0.0, "{}", s.name);
            assert!(!p.fell_back, "{} must not fall back on the paper cluster",
                    s.name);
            assert_eq!(p.recovery.name(), s.recovery, "{}", s.name);
            assert_eq!(p.save.name(), s.save, "{}", s.name);
            assert_eq!(p.plan.is_some(), s.strategy.is_cpr(), "{}", s.name);
            assert_eq!(p.recovery.pls(), 0.0, "no failures seen yet");
        }
    }

    #[test]
    fn string_keyed_construction_matches_strategy_construction() {
        let base = preset("mini").unwrap();
        let c = backend(&base);
        let by_name = build_by_name("cpr-ssu", &base, PsView::new(&c)).unwrap();
        assert_eq!(by_name.save.name(), "prioritized");
        assert_eq!(by_name.recovery.name(), "partial-restore");
        assert!(build_by_name("bogus", &base, PsView::new(&c)).is_err());
    }

    #[test]
    fn cpr_falls_back_to_full_policies_when_not_beneficial() {
        let mut cfg = preset("mini").unwrap();
        cfg.cluster.t_fail_h = 0.05; // absurd failure rate
        cfg.checkpoint.target_pls = 0.01;
        let c = backend(&cfg);
        for strategy in [Strategy::CprVanilla, Strategy::CprScar,
                         Strategy::CprMfu, Strategy::CprSsu,
                         Strategy::CprAdaptive] {
            cfg.checkpoint.strategy = strategy.clone();
            let p = build_policies(&cfg, PsView::new(&c));
            assert!(p.fell_back, "{strategy:?}");
            assert_eq!(p.recovery.name(), "full-rewind", "{strategy:?}");
            assert_eq!(p.save.name(), "cpr-vanilla",
                       "fell-back CPR degrades to planned full-content saves");
        }
    }

    #[test]
    fn v2_format_keeps_every_strategys_cadence_and_wiring() {
        // the on-disk format changes what hits disk, never the policy
        // cadence or the bundle wiring
        let base = preset("mini").unwrap();
        let c = backend(&base);
        for s in specs() {
            let mut v1 = base.clone();
            v1.checkpoint.strategy = s.strategy.clone();
            let mut v2 = v1.clone();
            v2.checkpoint.format = crate::config::CkptFormat::V2;
            let p1 = build_policies(&v1, PsView::new(&c));
            let p2 = build_policies(&v2, PsView::new(&c));
            assert_eq!(p1.save.name(), p2.save.name(), "{}", s.name);
            assert_eq!(p1.recovery.name(), p2.recovery.name(), "{}", s.name);
            assert_eq!(p1.save.next_save_h(), p2.save.next_save_h(),
                       "{}: v2 must not move the save cadence", s.name);
            assert_eq!(p1.fell_back, p2.fell_back, "{}", s.name);
        }
    }

    #[test]
    fn bandwidth_derived_cost_scales_the_planned_interval() {
        let base = preset("mini").unwrap();
        let c = backend(&base);
        let p0 = build_policies(&base, PsView::new(&c));
        // a crawling checkpoint store (1 MB/h) makes each save expensive:
        // the full-recovery optimum √(2·O_save·T_fail) must stretch
        let mut slow = base.clone();
        slow.cluster.save_bw_gb_h = Some(0.001);
        let p1 = build_policies(&slow, PsView::new(&c));
        assert!(p1.save.next_save_h() > p0.save.next_save_h(),
                "bandwidth-derived save cost must stretch the interval: \
                 {} !> {}", p1.save.next_save_h(), p0.save.next_save_h());
        // and an absurdly fast store shrinks it
        let mut fast = base.clone();
        fast.cluster.save_bw_gb_h = Some(1e6);
        let p2 = build_policies(&fast, PsView::new(&c));
        assert!(p2.save.next_save_h() < p0.save.next_save_h());
    }

    #[test]
    fn codec_scaled_save_cost_narrows_the_planned_interval() {
        // under a bandwidth-derived save cost, a v2+q8 job publishes
        // ~3.5× fewer bytes per save, so the planner can afford to save
        // more often; v1 ignores the codec knob entirely
        let mut base = preset("mini").unwrap();
        base.cluster.save_bw_gb_h = Some(0.001); // make bytes matter
        base.checkpoint.format = crate::config::CkptFormat::V2;
        let c = backend(&base);
        let p_raw = build_policies(&base, PsView::new(&c));
        let mut q8 = base.clone();
        q8.checkpoint.codec = crate::config::CkptCodec::Q8;
        let p_q8 = build_policies(&q8, PsView::new(&c));
        assert!(p_q8.save.next_save_h() < p_raw.save.next_save_h(),
                "cheaper encoded checkpoints must narrow the interval: \
                 {} !< {}", p_q8.save.next_save_h(), p_raw.save.next_save_h());
        // q4 encodes smaller still → saves more often than q8
        let mut q4 = base.clone();
        q4.checkpoint.codec = crate::config::CkptCodec::Q4;
        let p_q4 = build_policies(&q4, PsView::new(&c));
        assert!(p_q4.save.next_save_h() < p_q8.save.next_save_h());
        // v1 publishes raw monoliths: the codec knob must not move it
        let mut v1 = base.clone();
        v1.checkpoint.format = crate::config::CkptFormat::V1;
        let mut v1_q8 = v1.clone();
        v1_q8.checkpoint.codec = crate::config::CkptCodec::Q8;
        let a = build_policies(&v1, PsView::new(&c));
        let b = build_policies(&v1_q8, PsView::new(&c));
        assert_eq!(a.save.next_save_h(), b.save.next_save_h(),
                   "v1 ignores the codec knob");
    }

    #[test]
    fn override_pins_the_interval_for_every_strategy() {
        let base = preset("mini").unwrap();
        let c = backend(&base);
        for s in specs() {
            let mut cfg = base.clone();
            cfg.checkpoint.strategy = s.strategy.clone();
            cfg.checkpoint.t_save_override_h = Some(4.0);
            let p = build_policies(&cfg, PsView::new(&c));
            // priority schemes save minors every r·T_save
            let want = if s.tracker.is_some() { cfg.checkpoint.r * 4.0 } else { 4.0 };
            assert!((p.save.next_save_h() - want).abs() < 1e-12, "{}", s.name);
        }
    }
}
