//! [`RecoveryPolicy`] implementations: what happens when a failure event
//! fires.
//!
//! Both absorb what the coordinator's old `if use_partial { … } else
//! { … }` block did, op for op (golden-equivalence suite):
//!
//! * [`PartialRestore`] — CPR's partial recovery: accrue PLS for the
//!   lost Emb PS slices (Eq. 3), kill/respawn each victim behind the
//!   quiesce token and repopulate it from the checkpoint mirror while
//!   survivors keep their progress; no rewind. A trainer loss with no
//!   surviving replica (N = 1) asks the driver to reload the dense
//!   params (stale) from the position marker.
//! * [`FullRewind`] — classic full recovery: charge the lost
//!   computation, restore every node from the mirror, and rewind the
//!   driver to the checkpointed step.

use super::{FailureCtx, PsView, RecoveryAction, RecoveryPolicy};
use crate::checkpoint::async_pipeline::CheckpointPipeline;
use crate::checkpoint::{full_content_io_bytes, node_content_io_bytes};
use crate::cluster::PsControlPlane;
use crate::config::ClusterConfig;
use crate::failure::FailureEvent;
use crate::metrics::OverheadLedger;
use crate::pls::PlsAccumulator;

/// Partial recovery: victims restore from the mirror, survivors keep
/// serving, PLS accrues (paper §2.3 / §4.1).
pub struct PartialRestore {
    o_load_h: f64,
    o_res_h: f64,
    n_emb: usize,
    n_trainers: usize,
    total_samples: u64,
    pls: PlsAccumulator,
}

impl PartialRestore {
    /// `total_samples` is the job's planned sample count (the PLS
    /// denominator).
    pub fn new(cluster: &ClusterConfig, total_samples: u64) -> Self {
        Self {
            o_load_h: cluster.o_load_h,
            o_res_h: cluster.o_res_h,
            n_emb: cluster.n_emb_ps,
            n_trainers: cluster.n_trainers.max(1),
            total_samples,
            pls: PlsAccumulator::new(),
        }
    }
}

impl RecoveryPolicy for PartialRestore {
    fn name(&self) -> &'static str {
        "partial-restore"
    }

    fn on_failure(
        &mut self,
        ev: &FailureEvent,
        ps: PsView<'_>,
        pipeline: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        ctx: &FailureCtx,
    ) -> RecoveryAction {
        ledger.n_failures += 1;
        ledger.load_h += self.o_load_h;
        ledger.reschedule_h += self.o_res_h;
        if !ev.victims.is_empty() {
            self.pls.on_failure(
                ctx.samples,
                ctx.marked_samples,
                self.total_samples,
                self.n_emb,
                ev.victims.len(),
            );
            // live partial recovery: the victim dies (on the threaded
            // backend its worker is joined), a blank node respawns, and
            // the checkpoint mirror repopulates it — survivors keep their
            // progress and keep serving. All behind the driver's quiesce
            // token, so no gather can observe a half-restored node.
            // Restore I/O = each victim's slice only (on disk: that
            // node's base+delta chain), never the whole store.
            for &v in &ev.victims {
                ledger.bytes_restored +=
                    node_content_io_bytes(ps.data.tables(), ps.data.n_nodes(), v);
                {
                    let _t = crate::telemetry::span_node("recovery_kill", v);
                    ps.ctl.kill_node(v);
                }
                {
                    let _t = crate::telemetry::span_node("recovery_respawn", v);
                    ps.ctl.respawn_node(v);
                }
                pipeline.restore_node(ps.ctl, v);
            }
        }
        // trainer loss: dense params are replicated, so with survivors the
        // respawned trainer re-joins from the replica at the next barrier;
        // with a single trainer the driver must reload (stale) dense
        // params from the marker while the Emb PS keeps its progress.
        RecoveryAction::Continue {
            reload_dense_from_marker: !ev.trainer_victims.is_empty()
                && self.n_trainers == 1,
        }
    }

    fn pls(&self) -> f64 {
        self.pls.value()
    }
}

/// Full recovery: everyone reloads from the checkpoint and training
/// rewinds; the computation since the marker is charged as lost.
pub struct FullRewind {
    o_load_h: f64,
    o_res_h: f64,
}

impl FullRewind {
    /// Reads the load/reschedule overhead constants from the cluster.
    pub fn new(cluster: &ClusterConfig) -> Self {
        Self { o_load_h: cluster.o_load_h, o_res_h: cluster.o_res_h }
    }
}

impl RecoveryPolicy for FullRewind {
    fn name(&self) -> &'static str {
        "full-rewind"
    }

    fn on_failure(
        &mut self,
        _ev: &FailureEvent,
        ps: PsView<'_>,
        pipeline: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        ctx: &FailureCtx,
    ) -> RecoveryAction {
        ledger.n_failures += 1;
        ledger.load_h += self.o_load_h;
        ledger.reschedule_h += self.o_res_h;
        let t_last = ctx.marked_step as f64 * ctx.dt_h;
        ledger.lost_h += (ctx.clock_h - t_last).max(0.0);
        let (mlp, ckpt_step, _samples) = {
            let _t = crate::telemetry::span("restore_all");
            pipeline.restore_all(ps.ctl)
        };
        // a rewind reads everything back: every table + the dense params
        ledger.bytes_restored += full_content_io_bytes(ps.data.tables(), &mlp);
        RecoveryAction::Rewind { mlp, step: ckpt_step }
    }

    fn pls(&self) -> f64 {
        0.0 // full recovery loses no updates
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::CheckpointStore;
    use crate::cluster::PsControlPlane;
    use crate::config::preset;
    use crate::embedding::{PsCluster, TableInfo};

    fn cluster() -> PsCluster {
        PsCluster::new(vec![TableInfo { rows: 24, dim: 4 }], 3, 11)
    }

    fn pipeline(c: &PsCluster, mlp: Vec<Vec<f32>>) -> CheckpointPipeline {
        CheckpointPipeline::with_options(
            CheckpointStore::initial(c, mlp),
            &crate::checkpoint::CheckpointOptions::default(),
        )
        .unwrap()
    }

    fn event(victims: Vec<usize>, trainer_victims: Vec<usize>) -> FailureEvent {
        FailureEvent { time_h: 10.0, victims, trainer_victims }
    }

    #[test]
    fn partial_restores_victim_and_accrues_pls() {
        let c = cluster();
        let p = pipeline(&c, vec![]);
        let golden = c.snapshot_node(1);
        // train past the checkpoint, then lose node 1
        c.sgd_update(&[1, 4, 7], &[0.5f32; 12], 1.0);
        let mut cfg = preset("mini").unwrap().cluster;
        cfg.n_emb_ps = 3;
        let mut policy = PartialRestore::new(&cfg, 10_000);
        let mut ledger = OverheadLedger::default();
        let ctx = FailureCtx {
            clock_h: 10.0,
            dt_h: 0.1,
            samples: 5_000,
            marked_step: 0,
            marked_samples: 4_000,
        };
        let action = policy.on_failure(&event(vec![1], vec![]), PsView::new(&c),
                                       &p, &mut ledger, &ctx);
        assert!(matches!(
            action,
            RecoveryAction::Continue { reload_dense_from_marker: false }
        ));
        // victim back at the checkpointed (initial) state
        assert_eq!(c.snapshot_node(1).shards, golden.shards);
        // Eq. 3: 1 victim, 1000 lost samples, 3 nodes
        assert!((policy.pls() - 1_000.0 / (10_000.0 * 3.0)).abs() < 1e-15);
        assert_eq!(ledger.n_failures, 1);
        assert_eq!(ledger.lost_h, 0.0, "partial recovery loses no time");
        p.flush().unwrap();
    }

    #[test]
    fn partial_single_trainer_loss_asks_for_dense_reload() {
        let c = cluster();
        let p = pipeline(&c, vec![]);
        let mut cfg = preset("mini").unwrap().cluster;
        cfg.n_trainers = 1;
        let mut policy = PartialRestore::new(&cfg, 10_000);
        let mut ledger = OverheadLedger::default();
        let ctx = FailureCtx {
            clock_h: 1.0,
            dt_h: 0.1,
            samples: 100,
            marked_step: 0,
            marked_samples: 0,
        };
        let action = policy.on_failure(&event(vec![], vec![0]), PsView::new(&c),
                                       &p, &mut ledger, &ctx);
        assert!(matches!(
            action,
            RecoveryAction::Continue { reload_dense_from_marker: true }
        ));
        assert_eq!(policy.pls(), 0.0, "trainer loss accrues no embedding PLS");
        p.flush().unwrap();
    }

    #[test]
    fn full_rewind_restores_everything_and_charges_lost_time() {
        let c = cluster();
        let p = pipeline(&c, vec![vec![1.0, 2.0]]);
        let golden: Vec<_> = (0..3).map(|n| c.snapshot_node(n)).collect();
        c.sgd_update(&[1, 4, 7], &[0.5f32; 12], 1.0);
        let cfg = preset("mini").unwrap().cluster;
        let mut policy = FullRewind::new(&cfg);
        let mut ledger = OverheadLedger::default();
        let ctx = FailureCtx {
            clock_h: 10.0,
            dt_h: 0.5,
            samples: 2_560,
            marked_step: 12, // marker at 6.0 h
            marked_samples: 1_536,
        };
        let action = policy.on_failure(&event(vec![0], vec![]), PsView::new(&c),
                                       &p, &mut ledger, &ctx);
        match action {
            RecoveryAction::Rewind { mlp, step } => {
                assert_eq!(mlp, vec![vec![1.0, 2.0]]);
                assert_eq!(step, 0, "initial store marks step 0");
            }
            _ => panic!("full recovery must rewind"),
        }
        for (n, g) in golden.iter().enumerate() {
            assert_eq!(c.snapshot_node(n).shards, g.shards, "node {n}");
        }
        assert!((ledger.lost_h - 4.0).abs() < 1e-12, "10 h - 12·0.5 h lost");
        assert_eq!(policy.pls(), 0.0);
        p.flush().unwrap();
    }
}
