//! [`SavePolicy`] implementations: when to checkpoint and what to
//! capture.
//!
//! All three reproduce, op for op, what the coordinator's old inlined
//! save block did — the golden-equivalence integration suite asserts a
//! policy-driven run is bit-identical (AUC, logloss, PLS, loss curve,
//! ledger) to the preserved pre-refactor loop:
//!
//! * [`FullSave`] — full-content saves at a caller-chosen interval (full
//!   recovery's √(2·O_save·T_fail) optimum, or partial-naive's reuse of
//!   it). Cost `O_save` per save, marker advances every save.
//! * [`CprVanilla`] — the same capture shape at the PLS-planned interval
//!   (`pls::plan`). Kept distinct so reports/registry name the policy the
//!   paper names.
//! * [`Prioritized<T>`] — CPR's priority checkpointing over any
//!   [`PriorityTracker`]: saves `r·N` selected rows of each priority
//!   table every `r·T_save` (cost `r·O_save` per minor), whole tiny
//!   tables alongside, and advances the PLS marker once per full
//!   `T_save` (every `1/r` minors).

use super::tracker::PriorityTracker;
use super::{PsView, SaveCtx, SaveMarker, SavePolicy};
use crate::cluster::PlanAccess;
use crate::checkpoint::async_pipeline::CheckpointPipeline;
use crate::checkpoint::{full_content_io_bytes, mlp_io_bytes, rows_io_bytes};
use crate::cluster::PsDataPlane;
use crate::metrics::OverheadLedger;

/// Capture-side dirty set for format-v2 **delta captures** by the
/// full-content policies: a per-table bitmap of rows touched by the
/// access stream since the last capture.
///
/// Why touched ⊇ changed: a row's cluster value only ever changes through
/// a trainer's sparse update, every update uses the same indices as the
/// gather, and the driver feeds every trainer's access stream to
/// [`SavePolicy::on_step`] in rank order. Rows absent from this set are
/// therefore byte-identical to the mirror copy from the previous capture
/// (restores only ever copy mirror values *into* the cluster), so a
/// capture of just the touched rows builds exactly the mirror a full
/// node-snapshot capture would — the v1-vs-v2 golden-equivalence suite
/// asserts this end to end. Over-approximation (rows touched then
/// restored back) costs bytes, never correctness.
pub(super) struct TouchedRows {
    tables: Vec<Vec<bool>>,
    counts: Vec<usize>,
}

impl TouchedRows {
    pub(super) fn new(table_rows: &[usize]) -> Self {
        Self {
            tables: table_rows.iter().map(|&r| vec![false; r]).collect(),
            counts: vec![0; table_rows.len()],
        }
    }

    /// Observe one batch's access stream (`[B, num_tables, hotness]`).
    pub(super) fn record(&mut self, indices: &[u32], num_tables: usize, hotness: usize) {
        for chunk in indices.chunks_exact(num_tables * hotness) {
            for (slot, &row) in chunk.iter().enumerate() {
                let t = slot / hotness;
                let flag = &mut self.tables[t][row as usize];
                if !*flag {
                    *flag = true;
                    self.counts[t] += 1;
                }
            }
        }
    }

    /// Observe one batch as a deduplicated access list. Set semantics
    /// make this trivially equivalent to [`TouchedRows::record`] over the
    /// raw stream: a flag ends up set iff the `(table, row)` pair appears
    /// at least once, and multiplicity is irrelevant.
    pub(super) fn record_planned(&mut self, accesses: &[PlanAccess]) {
        for a in accesses {
            let flag = &mut self.tables[a.table as usize][a.row as usize];
            if !*flag {
                *flag = true;
                self.counts[a.table as usize] += 1;
            }
        }
    }

    /// Drain `table`'s touched rows (ascending), clearing the set.
    pub(super) fn take(&mut self, table: usize) -> Vec<u32> {
        let flags = &mut self.tables[table];
        let mut rows = Vec::with_capacity(self.counts[table]);
        for (i, f) in flags.iter_mut().enumerate() {
            if *f {
                rows.push(i as u32);
                *f = false;
            }
        }
        self.counts[table] = 0;
        rows
    }
}

/// Scale a raw fp32 payload size by a codec's expected encoded/raw byte
/// ratio. The ledger models I/O volume analytically (it never stats real
/// files), so encoded publishes charge `raw × ratio`, rounded up — the
/// same estimate the v2 engine's compaction planner uses, keeping the
/// ledger, the planner, and the adaptive re-planner on one cost model.
/// `ratio == 1.0` (v1, or codec `none`/`rle`-as-configured) is exact
/// pass-through so pre-codec golden ledgers stay bit-identical.
pub(super) fn scaled_bytes(bytes: u64, ratio: f64) -> u64 {
    if ratio == 1.0 {
        bytes
    } else {
        (bytes as f64 * ratio).ceil() as u64
    }
}

/// Full-content checkpointing at a fixed interval (the non-priority,
/// non-planned cadence: `Strategy::Full` and `Strategy::PartialNaive`).
pub struct FullSave {
    o_save_h: f64,
    interval_h: f64,
    next_save_h: f64,
    delta: Option<TouchedRows>,
    byte_ratio: f64,
}

impl FullSave {
    /// Save everything every `interval_h`, charging `o_save_h` per save.
    pub fn new(o_save_h: f64, interval_h: f64) -> Self {
        Self { o_save_h, interval_h, next_save_h: interval_h, delta: None, byte_ratio: 1.0 }
    }

    /// Charge the ledger at `ratio ×` the raw fp32 size — the registry
    /// sets this to the configured codec's estimated encoded/raw ratio
    /// when format v2 publishes encoded files.
    pub fn with_byte_ratio(mut self, ratio: f64) -> Self {
        self.byte_ratio = ratio;
        self
    }

    /// Format v2: capture only the rows touched since the last save
    /// (delta capture) instead of full node snapshots — the mirror ends
    /// up byte-identical (touched ⊇ changed, since updates use exactly
    /// the gather indices this policy observes via `on_step`), but
    /// capture clones and the ledger's I/O volume shrink to the working
    /// set.
    pub fn with_delta_capture(mut self, table_rows: &[usize]) -> Self {
        self.delta = Some(TouchedRows::new(table_rows));
        self
    }

    /// The fixed save interval, hours.
    pub fn interval_h(&self) -> f64 {
        self.interval_h
    }
}

/// One full-content capture: charge the ledger (time + I/O volume),
/// capture content + the dense params, advance the marker. Shared by the
/// fixed-interval, planned, and adaptive policies. With `delta` set
/// (format v2) the content capture is the touched-row set exported
/// through the control plane's `snapshot_node_rows`; otherwise every
/// node is snapshotted whole.
pub(super) fn full_content_capture(
    o_save_h: f64,
    delta: Option<&mut TouchedRows>,
    byte_ratio: f64,
    ps: PsView<'_>,
    pipeline: &CheckpointPipeline,
    ledger: &mut OverheadLedger,
    ctx: &SaveCtx<'_>,
) -> SaveMarker {
    ledger.save_h += o_save_h;
    ledger.n_saves += 1;
    match delta {
        None => {
            ledger.bytes_written += scaled_bytes(
                full_content_io_bytes(ps.data.tables(), ctx.host_params),
                byte_ratio,
            );
            pipeline.full_save(ps.ctl, ctx.host_params.to_vec(), ctx.step, ctx.samples);
        }
        Some(touched) => {
            let tables = ps.data.tables();
            for t in 0..tables.len() {
                let rows = touched.take(t);
                if rows.is_empty() {
                    continue;
                }
                ledger.bytes_written +=
                    scaled_bytes(rows_io_bytes(rows.len(), tables[t].dim), byte_ratio);
                pipeline.delta_save(ps.ctl, t, &rows);
            }
            ledger.bytes_written +=
                scaled_bytes(mlp_io_bytes(ctx.host_params), byte_ratio);
            pipeline.mark_position(ctx.host_params.to_vec(), ctx.step, ctx.samples);
        }
    }
    SaveMarker { step: ctx.step, samples: ctx.samples }
}

impl SavePolicy for FullSave {
    fn name(&self) -> &'static str {
        "full-save"
    }

    fn next_save_h(&self) -> f64 {
        self.next_save_h
    }

    fn on_step(&mut self, indices: &[u32], num_tables: usize, hotness: usize) {
        if let Some(touched) = self.delta.as_mut() {
            touched.record(indices, num_tables, hotness);
        }
    }

    fn on_step_planned(
        &mut self,
        _indices: &[u32],
        accesses: &[PlanAccess],
        _num_tables: usize,
        _hotness: usize,
    ) {
        if let Some(touched) = self.delta.as_mut() {
            touched.record_planned(accesses);
        }
    }

    fn capture(
        &mut self,
        ps: PsView<'_>,
        pipeline: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        ctx: &SaveCtx<'_>,
    ) -> Option<SaveMarker> {
        let marker = full_content_capture(self.o_save_h, self.delta.as_mut(),
                                          self.byte_ratio, ps, pipeline, ledger, ctx);
        self.next_save_h += self.interval_h;
        Some(marker)
    }
}

/// CPR without priority saving: full-content saves at the PLS-planned
/// interval (`Strategy::CprVanilla`, and the capture shape every
/// fell-back CPR strategy degrades to).
pub struct CprVanilla(FullSave);

impl CprVanilla {
    /// `interval_h` is the planner's `t_save_h` (already fallback- and
    /// override-adjusted by the registry).
    pub fn new(o_save_h: f64, interval_h: f64) -> Self {
        Self(FullSave::new(o_save_h, interval_h))
    }

    /// Format v2: delta-capture touched rows (see
    /// [`FullSave::with_delta_capture`]).
    pub fn with_delta_capture(self, table_rows: &[usize]) -> Self {
        Self(self.0.with_delta_capture(table_rows))
    }

    /// Codec-scaled ledger charges (see [`FullSave::with_byte_ratio`]).
    pub fn with_byte_ratio(self, ratio: f64) -> Self {
        Self(self.0.with_byte_ratio(ratio))
    }

    /// The planned save interval, hours.
    pub fn interval_h(&self) -> f64 {
        self.0.interval_h()
    }
}

impl SavePolicy for CprVanilla {
    fn name(&self) -> &'static str {
        "cpr-vanilla"
    }

    fn next_save_h(&self) -> f64 {
        self.0.next_save_h()
    }

    fn on_step(&mut self, indices: &[u32], num_tables: usize, hotness: usize) {
        self.0.on_step(indices, num_tables, hotness);
    }

    fn on_step_planned(
        &mut self,
        indices: &[u32],
        accesses: &[PlanAccess],
        num_tables: usize,
        hotness: usize,
    ) {
        self.0.on_step_planned(indices, accesses, num_tables, hotness);
    }

    fn capture(
        &mut self,
        ps: PsView<'_>,
        pipeline: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        ctx: &SaveCtx<'_>,
    ) -> Option<SaveMarker> {
        self.0.capture(ps, pipeline, ledger, ctx)
    }
}

/// CPR priority checkpointing (paper §4.2) over any tracker: minor saves
/// capture the tracker-selected `r·N` rows of each priority table (plus
/// the whole tiny tables) every `r·T_save` at cost `r·O_save`; every
/// `1/r`-th minor is a major that also advances the PLS position marker.
pub struct Prioritized<T: PriorityTracker> {
    tracker: T,
    mask: Vec<bool>,
    r: f64,
    o_save_h: f64,
    /// the minor interval, `r · t_save_h`
    interval_h: f64,
    minors_per_major: u64,
    minor_count: u64,
    next_save_h: f64,
    byte_ratio: f64,
}

impl<T: PriorityTracker> Prioritized<T> {
    /// `mask` flags the priority tables (see
    /// `checkpoint::tracker::priority_mask`), `r` the priority fraction,
    /// `t_save_h` the PLS-planned full interval.
    pub fn new(tracker: T, mask: Vec<bool>, r: f64, o_save_h: f64, t_save_h: f64) -> Self {
        let interval_h = r * t_save_h;
        Self {
            tracker,
            mask,
            r,
            o_save_h,
            interval_h,
            minors_per_major: ((1.0 / r).round() as u64).max(1),
            minor_count: 0,
            next_save_h: interval_h,
            byte_ratio: 1.0,
        }
    }

    /// Codec-scaled ledger charges (see [`FullSave::with_byte_ratio`]).
    pub fn with_byte_ratio(mut self, ratio: f64) -> Self {
        self.byte_ratio = ratio;
        self
    }

    /// The underlying tracker (diagnostics: name, memory accounting).
    pub fn tracker(&self) -> &T {
        &self.tracker
    }
}

impl<T: PriorityTracker> SavePolicy for Prioritized<T> {
    fn name(&self) -> &'static str {
        "prioritized"
    }

    fn next_save_h(&self) -> f64 {
        self.next_save_h
    }

    fn on_step(&mut self, indices: &[u32], num_tables: usize, hotness: usize) {
        self.tracker.record_batch(indices, num_tables, hotness);
    }

    fn on_step_planned(
        &mut self,
        indices: &[u32],
        accesses: &[PlanAccess],
        num_tables: usize,
        hotness: usize,
    ) {
        self.tracker.record_batch_planned(indices, accesses, num_tables, hotness);
    }

    fn capture(
        &mut self,
        ps: PsView<'_>,
        pipeline: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        ctx: &SaveCtx<'_>,
    ) -> Option<SaveMarker> {
        self.minor_count += 1;
        ledger.save_h += self.r * self.o_save_h;
        let n_tables = ps.data.tables().len();
        for t in 0..n_tables {
            let dim = ps.data.tables()[t].dim;
            if self.mask[t] {
                let rows_in_table = ps.data.tables()[t].rows;
                let k = ((rows_in_table as f64 * self.r).ceil() as usize).max(1);
                let rows = self.tracker.select(ps.data, t, k);
                ledger.bytes_written +=
                    scaled_bytes(rows_io_bytes(rows.len(), dim), self.byte_ratio);
                pipeline.save_rows(ps.data, t, &rows);
                self.tracker.on_saved(ps.data, t, &rows);
            } else {
                // tiny non-priority tables ride along whole
                ledger.bytes_written += scaled_bytes(
                    rows_io_bytes(ps.data.tables()[t].rows, dim),
                    self.byte_ratio,
                );
                pipeline.save_table(ps.data, t);
            }
        }
        let marker = if self.minor_count % self.minors_per_major == 0 {
            // a MAJOR: the marker advances, and under format v2 every
            // node chain re-bases (the minors' deltas fold in); identical
            // to mark_position under v1
            ledger.bytes_written +=
                scaled_bytes(mlp_io_bytes(ctx.host_params), self.byte_ratio);
            pipeline.mark_position_base(ctx.host_params.to_vec(), ctx.step, ctx.samples);
            ledger.n_saves += 1;
            Some(SaveMarker { step: ctx.step, samples: ctx.samples })
        } else {
            // a MINOR: under format v2 the captured rows become durable
            // per-node delta files right now (v1 only persists at marks,
            // where this is a no-op)
            pipeline.commit_save();
            None
        };
        self.next_save_h += self.interval_h;
        marker
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::tracker::MfuTracker;
    use crate::checkpoint::CheckpointStore;
    use crate::embedding::{PsCluster, TableInfo};

    fn cluster() -> PsCluster {
        PsCluster::new(
            vec![TableInfo { rows: 40, dim: 4 }, TableInfo { rows: 8, dim: 4 }],
            2,
            3,
        )
    }

    fn pipeline(c: &PsCluster) -> CheckpointPipeline {
        CheckpointPipeline::with_options(
            CheckpointStore::initial(c, vec![]),
            &crate::checkpoint::CheckpointOptions::default(),
        )
        .unwrap()
    }

    #[test]
    fn full_save_charges_ledger_and_marks_every_save() {
        let c = cluster();
        let p = pipeline(&c);
        let mut policy = FullSave::new(0.1, 2.0);
        assert_eq!(policy.next_save_h(), 2.0);
        let mut ledger = OverheadLedger::default();
        let ctx = SaveCtx { step: 5, samples: 640, clock_h: 2.1, host_params: &[] };
        let m = policy
            .capture(PsView::new(&c), &p, &mut ledger, &ctx)
            .expect("full saves always mark");
        assert_eq!((m.step, m.samples), (5, 640));
        assert_eq!(policy.next_save_h(), 4.0);
        assert_eq!(ledger.n_saves, 1);
        assert!((ledger.save_h - 0.1).abs() < 1e-12);
        p.flush().unwrap();
    }

    #[test]
    fn delta_capture_builds_the_same_mirror_as_full_snapshots() {
        use crate::cluster::PsDataPlane;
        use crate::embedding::EmbOptimizer;
        let c = cluster();
        let p_full = pipeline(&c);
        let p_delta = pipeline(&c);
        let mut full = FullSave::new(0.1, 2.0);
        let mut delta = FullSave::new(0.1, 2.0).with_delta_capture(&[40, 8]);
        // one training step: updates + the matching access stream
        let idx = [1u32, 0, 5, 2, 9, 7]; // 3 samples × 2 tables
        let grads = [0.25f32; 3 * 2 * 4];
        PsDataPlane::apply_grads(&c, &idx, 1, &grads, 1.0, EmbOptimizer::Sgd);
        full.on_step(&idx, 2, 1); // no-op without delta mode
        delta.on_step(&idx, 2, 1);
        let mut lf = OverheadLedger::default();
        let mut ld = OverheadLedger::default();
        let ctx = SaveCtx { step: 1, samples: 128, clock_h: 2.0, host_params: &[] };
        full.capture(PsView::new(&c), &p_full, &mut lf, &ctx).unwrap();
        delta.capture(PsView::new(&c), &p_delta, &mut ld, &ctx).unwrap();
        // identical time charges, strictly smaller I/O volume
        assert_eq!(lf.save_h, ld.save_h);
        assert_eq!((lf.n_saves, ld.n_saves), (1, 1));
        assert!(ld.bytes_written < lf.bytes_written,
                "delta capture ({}) must move fewer bytes than full ({})",
                ld.bytes_written, lf.bytes_written);
        assert!(ld.bytes_written > 0);
        // both mirrors restore to identical cluster state
        let ca = PsCluster::new(
            vec![TableInfo { rows: 40, dim: 4 }, TableInfo { rows: 8, dim: 4 }],
            2, 999,
        );
        let cb = PsCluster::new(
            vec![TableInfo { rows: 40, dim: 4 }, TableInfo { rows: 8, dim: 4 }],
            2, 999,
        );
        p_full.restore_all(&ca);
        p_delta.restore_all(&cb);
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        for (t, rows) in [(0usize, 40usize), (1, 8)] {
            for r in 0..rows {
                PsDataPlane::read_row(&ca, t, r, &mut a);
                PsDataPlane::read_row(&cb, t, r, &mut b);
                assert_eq!(a, b, "table {t} row {r} diverged");
            }
        }
        // the delta mirror marked the touched rows dirty for node-level
        // dirty publication, and the capture drained the touched set —
        // a second capture with no new accesses moves only the marker
        let marker =
            delta.capture(PsView::new(&c), &p_delta, &mut ld, &ctx).unwrap();
        assert_eq!(marker.step, 1);
        p_full.flush().unwrap();
        p_delta.flush().unwrap();
    }

    #[test]
    fn byte_ratio_scales_ledger_charges_not_cadence() {
        let c = cluster();
        let p_raw = pipeline(&c);
        let p_enc = pipeline(&c);
        let ratio = 0.3;
        let mut raw = FullSave::new(0.1, 2.0);
        let mut enc = FullSave::new(0.1, 2.0).with_byte_ratio(ratio);
        let mut lr = OverheadLedger::default();
        let mut le = OverheadLedger::default();
        let ctx = SaveCtx { step: 1, samples: 128, clock_h: 2.0, host_params: &[] };
        raw.capture(PsView::new(&c), &p_raw, &mut lr, &ctx).unwrap();
        enc.capture(PsView::new(&c), &p_enc, &mut le, &ctx).unwrap();
        assert_eq!(le.bytes_written, scaled_bytes(lr.bytes_written, ratio),
                   "encoded publishes charge ratio × raw, rounded up");
        assert!(le.bytes_written < lr.bytes_written);
        // time charges and cadence are codec-independent
        assert_eq!(le.save_h, lr.save_h);
        assert_eq!(enc.next_save_h(), raw.next_save_h());
        // ratio 1.0 is exact pass-through (golden-ledger safety)
        assert_eq!(scaled_bytes(12_345, 1.0), 12_345);
        assert_eq!(scaled_bytes(10, 0.31), 4, "ceil, never undercharge");
        p_raw.flush().unwrap();
        p_enc.flush().unwrap();
    }

    #[test]
    fn prioritized_minor_major_cadence_matches_r() {
        let c = cluster();
        let p = pipeline(&c);
        let r = 0.25; // 4 minors per major
        let mask = vec![true, false];
        let tracker = MfuTracker::new(&[40, 8], &mask);
        let mut policy = Prioritized::new(tracker, mask, r, 0.1, 8.0);
        assert!((policy.next_save_h() - 2.0).abs() < 1e-12, "minor = r·T_save");
        let mut ledger = OverheadLedger::default();
        policy.on_step(&[1, 0, 1, 0, 2, 0], 2, 1);
        let mut marks = 0;
        for minor in 1..=8u64 {
            let ctx = SaveCtx {
                step: minor,
                samples: minor * 128,
                clock_h: minor as f64 * 2.0,
                host_params: &[],
            };
            if let Some(m) = policy.capture(PsView::new(&c), &p, &mut ledger, &ctx) {
                marks += 1;
                assert_eq!(m.step % 4, 0, "majors land every 1/r minors");
            }
        }
        assert_eq!(marks, 2, "8 minors at r=0.25 give 2 majors");
        assert_eq!(ledger.n_saves, 2, "only majors count as saves");
        // 8 minors each charging r·O_save
        assert!((ledger.save_h - 8.0 * r * 0.1).abs() < 1e-12);
        p.flush().unwrap();
    }
}
