//! [`SavePolicy`] implementations: when to checkpoint and what to
//! capture.
//!
//! All three reproduce, op for op, what the coordinator's old inlined
//! save block did — the golden-equivalence integration suite asserts a
//! policy-driven run is bit-identical (AUC, logloss, PLS, loss curve,
//! ledger) to the preserved pre-refactor loop:
//!
//! * [`FullSave`] — full-content saves at a caller-chosen interval (full
//!   recovery's √(2·O_save·T_fail) optimum, or partial-naive's reuse of
//!   it). Cost `O_save` per save, marker advances every save.
//! * [`CprVanilla`] — the same capture shape at the PLS-planned interval
//!   (`pls::plan`). Kept distinct so reports/registry name the policy the
//!   paper names.
//! * [`Prioritized<T>`] — CPR's priority checkpointing over any
//!   [`PriorityTracker`]: saves `r·N` selected rows of each priority
//!   table every `r·T_save` (cost `r·O_save` per minor), whole tiny
//!   tables alongside, and advances the PLS marker once per full
//!   `T_save` (every `1/r` minors).

use super::tracker::PriorityTracker;
use super::{PsView, SaveCtx, SaveMarker, SavePolicy};
use crate::checkpoint::async_pipeline::CheckpointPipeline;
use crate::cluster::PsDataPlane;
use crate::metrics::OverheadLedger;

/// Full-content checkpointing at a fixed interval (the non-priority,
/// non-planned cadence: `Strategy::Full` and `Strategy::PartialNaive`).
pub struct FullSave {
    o_save_h: f64,
    interval_h: f64,
    next_save_h: f64,
}

impl FullSave {
    /// Save everything every `interval_h`, charging `o_save_h` per save.
    pub fn new(o_save_h: f64, interval_h: f64) -> Self {
        Self { o_save_h, interval_h, next_save_h: interval_h }
    }

    /// The fixed save interval, hours.
    pub fn interval_h(&self) -> f64 {
        self.interval_h
    }
}

/// One full-content capture: charge the ledger, snapshot every node +
/// the dense params, advance the marker. Shared by the fixed-interval,
/// planned, and adaptive policies.
pub(super) fn full_content_capture(
    o_save_h: f64,
    ps: PsView<'_>,
    pipeline: &CheckpointPipeline,
    ledger: &mut OverheadLedger,
    ctx: &SaveCtx<'_>,
) -> SaveMarker {
    ledger.save_h += o_save_h;
    ledger.n_saves += 1;
    pipeline.full_save(ps.ctl, ctx.host_params.to_vec(), ctx.step, ctx.samples);
    SaveMarker { step: ctx.step, samples: ctx.samples }
}

impl SavePolicy for FullSave {
    fn name(&self) -> &'static str {
        "full-save"
    }

    fn next_save_h(&self) -> f64 {
        self.next_save_h
    }

    fn capture(
        &mut self,
        ps: PsView<'_>,
        pipeline: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        ctx: &SaveCtx<'_>,
    ) -> Option<SaveMarker> {
        let marker = full_content_capture(self.o_save_h, ps, pipeline, ledger, ctx);
        self.next_save_h += self.interval_h;
        Some(marker)
    }
}

/// CPR without priority saving: full-content saves at the PLS-planned
/// interval (`Strategy::CprVanilla`, and the capture shape every
/// fell-back CPR strategy degrades to).
pub struct CprVanilla(FullSave);

impl CprVanilla {
    /// `interval_h` is the planner's `t_save_h` (already fallback- and
    /// override-adjusted by the registry).
    pub fn new(o_save_h: f64, interval_h: f64) -> Self {
        Self(FullSave::new(o_save_h, interval_h))
    }

    /// The planned save interval, hours.
    pub fn interval_h(&self) -> f64 {
        self.0.interval_h()
    }
}

impl SavePolicy for CprVanilla {
    fn name(&self) -> &'static str {
        "cpr-vanilla"
    }

    fn next_save_h(&self) -> f64 {
        self.0.next_save_h()
    }

    fn capture(
        &mut self,
        ps: PsView<'_>,
        pipeline: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        ctx: &SaveCtx<'_>,
    ) -> Option<SaveMarker> {
        self.0.capture(ps, pipeline, ledger, ctx)
    }
}

/// CPR priority checkpointing (paper §4.2) over any tracker: minor saves
/// capture the tracker-selected `r·N` rows of each priority table (plus
/// the whole tiny tables) every `r·T_save` at cost `r·O_save`; every
/// `1/r`-th minor is a major that also advances the PLS position marker.
pub struct Prioritized<T: PriorityTracker> {
    tracker: T,
    mask: Vec<bool>,
    r: f64,
    o_save_h: f64,
    /// the minor interval, `r · t_save_h`
    interval_h: f64,
    minors_per_major: u64,
    minor_count: u64,
    next_save_h: f64,
}

impl<T: PriorityTracker> Prioritized<T> {
    /// `mask` flags the priority tables (see
    /// `checkpoint::tracker::priority_mask`), `r` the priority fraction,
    /// `t_save_h` the PLS-planned full interval.
    pub fn new(tracker: T, mask: Vec<bool>, r: f64, o_save_h: f64, t_save_h: f64) -> Self {
        let interval_h = r * t_save_h;
        Self {
            tracker,
            mask,
            r,
            o_save_h,
            interval_h,
            minors_per_major: ((1.0 / r).round() as u64).max(1),
            minor_count: 0,
            next_save_h: interval_h,
        }
    }

    /// The underlying tracker (diagnostics: name, memory accounting).
    pub fn tracker(&self) -> &T {
        &self.tracker
    }
}

impl<T: PriorityTracker> SavePolicy for Prioritized<T> {
    fn name(&self) -> &'static str {
        "prioritized"
    }

    fn next_save_h(&self) -> f64 {
        self.next_save_h
    }

    fn on_step(&mut self, indices: &[u32], num_tables: usize, hotness: usize) {
        self.tracker.record_batch(indices, num_tables, hotness);
    }

    fn capture(
        &mut self,
        ps: PsView<'_>,
        pipeline: &CheckpointPipeline,
        ledger: &mut OverheadLedger,
        ctx: &SaveCtx<'_>,
    ) -> Option<SaveMarker> {
        self.minor_count += 1;
        ledger.save_h += self.r * self.o_save_h;
        let n_tables = ps.data.tables().len();
        for t in 0..n_tables {
            if self.mask[t] {
                let rows_in_table = ps.data.tables()[t].rows;
                let k = ((rows_in_table as f64 * self.r).ceil() as usize).max(1);
                let rows = self.tracker.select(ps.data, t, k);
                pipeline.save_rows(ps.data, t, &rows);
                self.tracker.on_saved(ps.data, t, &rows);
            } else {
                // tiny non-priority tables ride along whole
                pipeline.save_table(ps.data, t);
            }
        }
        let marker = if self.minor_count % self.minors_per_major == 0 {
            pipeline.mark_position(ctx.host_params.to_vec(), ctx.step, ctx.samples);
            ledger.n_saves += 1;
            Some(SaveMarker { step: ctx.step, samples: ctx.samples })
        } else {
            None
        };
        self.next_save_h += self.interval_h;
        marker
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::tracker::MfuTracker;
    use crate::checkpoint::CheckpointStore;
    use crate::embedding::{PsCluster, TableInfo};

    fn cluster() -> PsCluster {
        PsCluster::new(
            vec![TableInfo { rows: 40, dim: 4 }, TableInfo { rows: 8, dim: 4 }],
            2,
            3,
        )
    }

    fn pipeline(c: &PsCluster) -> CheckpointPipeline {
        CheckpointPipeline::new(
            CheckpointStore::initial(c, vec![]),
            None,
            2,
            std::time::Duration::ZERO,
        )
        .unwrap()
    }

    #[test]
    fn full_save_charges_ledger_and_marks_every_save() {
        let c = cluster();
        let p = pipeline(&c);
        let mut policy = FullSave::new(0.1, 2.0);
        assert_eq!(policy.next_save_h(), 2.0);
        let mut ledger = OverheadLedger::default();
        let ctx = SaveCtx { step: 5, samples: 640, clock_h: 2.1, host_params: &[] };
        let m = policy
            .capture(PsView::new(&c), &p, &mut ledger, &ctx)
            .expect("full saves always mark");
        assert_eq!((m.step, m.samples), (5, 640));
        assert_eq!(policy.next_save_h(), 4.0);
        assert_eq!(ledger.n_saves, 1);
        assert!((ledger.save_h - 0.1).abs() < 1e-12);
        p.flush().unwrap();
    }

    #[test]
    fn prioritized_minor_major_cadence_matches_r() {
        let c = cluster();
        let p = pipeline(&c);
        let r = 0.25; // 4 minors per major
        let mask = vec![true, false];
        let tracker = MfuTracker::new(&[40, 8], &mask);
        let mut policy = Prioritized::new(tracker, mask, r, 0.1, 8.0);
        assert!((policy.next_save_h() - 2.0).abs() < 1e-12, "minor = r·T_save");
        let mut ledger = OverheadLedger::default();
        policy.on_step(&[1, 0, 1, 0, 2, 0], 2, 1);
        let mut marks = 0;
        for minor in 1..=8u64 {
            let ctx = SaveCtx {
                step: minor,
                samples: minor * 128,
                clock_h: minor as f64 * 2.0,
                host_params: &[],
            };
            if let Some(m) = policy.capture(PsView::new(&c), &p, &mut ledger, &ctx) {
                marks += 1;
                assert_eq!(m.step % 4, 0, "majors land every 1/r minors");
            }
        }
        assert_eq!(marks, 2, "8 minors at r=0.25 give 2 majors");
        assert_eq!(ledger.n_saves, 2, "only majors count as saves");
        // 8 minors each charging r·O_save
        assert!((ledger.save_h - 8.0 * r * 0.1).abs() < 1e-12);
        p.flush().unwrap();
    }
}
