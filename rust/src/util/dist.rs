//! Probability distributions, built on [`crate::util::rng::Rng`].
//!
//! The failure model of the paper (§3.1) is a gamma distribution over
//! inter-failure times; embedding accesses follow a Zipf power law; the
//! synthetic teacher uses normals. All implemented from scratch (no `rand`
//! crates in the offline image).

use super::rng::Rng;

/// Standard normal via Marsaglia polar (no trig, no tables).
pub fn normal(rng: &mut Rng) -> f64 {
    loop {
        let u = 2.0 * rng.f64() - 1.0;
        let v = 2.0 * rng.f64() - 1.0;
        let s = u * u + v * v;
        if s > 0.0 && s < 1.0 {
            return u * (-2.0 * s.ln() / s).sqrt();
        }
    }
}

pub fn normal_with(rng: &mut Rng, mean: f64, std: f64) -> f64 {
    mean + std * normal(rng)
}

/// Exponential with given mean (inverse-CDF).
pub fn exponential(rng: &mut Rng, mean: f64) -> f64 {
    let u = 1.0 - rng.f64(); // avoid ln(0)
    -mean * u.ln()
}

/// Gamma(shape k, scale theta) via Marsaglia–Tsang (2000); the k < 1 case
/// uses the standard boost `U^{1/k}` trick.
pub fn gamma(rng: &mut Rng, shape: f64, scale: f64) -> f64 {
    assert!(shape > 0.0 && scale > 0.0);
    if shape < 1.0 {
        let u = 1.0 - rng.f64();
        return gamma(rng, shape + 1.0, scale) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = normal(rng);
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v3 = v * v * v;
        let u = 1.0 - rng.f64();
        if u < 1.0 - 0.0331 * x.powi(4)
            || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
        {
            return d * v3 * scale;
        }
    }
}

/// Gamma survival function S(t) = 1 - CDF(t), via the regularized lower
/// incomplete gamma function P(k, t/theta) (series + continued fraction,
/// Numerical Recipes style).
pub fn gamma_survival(t: f64, shape: f64, scale: f64) -> f64 {
    1.0 - reg_lower_gamma(shape, t / scale)
}

/// Regularized lower incomplete gamma P(a, x).
pub fn reg_lower_gamma(a: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // series
        let mut ap = a;
        let mut sum = 1.0 / a;
        let mut del = sum;
        for _ in 0..500 {
            ap += 1.0;
            del *= x / ap;
            sum += del;
            if del.abs() < sum.abs() * 1e-14 {
                break;
            }
        }
        sum * (-x + a * x.ln() - ln_gamma(a)).exp()
    } else {
        // continued fraction for Q, then P = 1 - Q
        let mut b = x + 1.0 - a;
        let mut c = 1.0 / 1e-300;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..500 {
            let an = -(i as f64) * (i as f64 - a);
            b += 2.0;
            d = an * d + b;
            if d.abs() < 1e-300 {
                d = 1e-300;
            }
            c = b + an / c;
            if c.abs() < 1e-300 {
                c = 1e-300;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 1e-14 {
                break;
            }
        }
        1.0 - (-x + a * x.ln() - ln_gamma(a)).exp() * h
    }
}

/// Lanczos ln(Gamma(x)).
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 6] = [
        76.18009172947146,
        -86.50532032941677,
        24.01409824083091,
        -1.231739572450155,
        0.1208650973866179e-2,
        -0.5395239384953e-5,
    ];
    let mut y = x;
    let tmp = x + 5.5;
    let tmp = tmp - (x + 0.5) * tmp.ln();
    let mut ser = 1.000000000190015;
    for g in G {
        y += 1.0;
        ser += g / y;
    }
    -tmp + (2.5066282746310005 * ser / x).ln()
}

/// Zipf sampler over {0, .., n-1} with exponent s (rank-frequency
/// p(rank) ∝ 1/rank^s), by rejection-inversion (W. Hörmann / G. Derflinger),
/// O(1) per sample after O(1) setup; exact for all n and s > 0, s != 1 or
/// s == 1 both handled through the generalized harmonic integral.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: f64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    cutoff: f64,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1 && s > 0.0);
        let nf = n as f64;
        let h_x1 = Self::h_integral(1.5, s) - 1.0;
        let h_n = Self::h_integral(nf + 0.5, s);
        let cutoff =
            2.0 - Self::h_integral_inverse(Self::h_integral(2.5, s) - Self::h(2.0, s), s);
        Self { n: nf, s, h_x1, h_n, cutoff }
    }

    /// H(x) = ((x^(1-s)) - 1) / (1 - s)   (→ ln x as s → 1), increasing.
    fn h_integral(x: f64, s: f64) -> f64 {
        let log_x = x.ln();
        helper2((1.0 - s) * log_x) * log_x
    }

    /// h(x) = x^-s (the unnormalized pmf).
    fn h(x: f64, s: f64) -> f64 {
        (-s * x.ln()).exp()
    }

    /// H^-1(x)
    fn h_integral_inverse(x: f64, s: f64) -> f64 {
        let mut t = x * (1.0 - s);
        if t < -1.0 {
            t = -1.0; // numerical guard, as in Commons
        }
        (helper1(t) * x).exp()
    }

    /// Sample a rank in [0, n) (rank 0 is the most frequent).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        loop {
            let u = self.h_n + rng.f64() * (self.h_x1 - self.h_n);
            let x = Self::h_integral_inverse(u, self.s);
            let mut k = (x + 0.5).floor();
            if k < 1.0 {
                k = 1.0;
            } else if k > self.n {
                k = self.n;
            }
            if k - x <= self.cutoff
                || u >= Self::h_integral(k + 0.5, self.s) - Self::h(k, self.s)
            {
                return (k as usize) - 1;
            }
        }
    }
}

/// helper1(x) = log1p(x)/x, stable near 0.
fn helper1(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.ln_1p() / x
    } else {
        1.0 - x * (0.5 - x * (1.0 / 3.0 - 0.25 * x))
    }
}

/// helper2(x) = expm1(x)/x, stable near 0.
fn helper2(x: f64) -> f64 {
    if x.abs() > 1e-8 {
        x.exp_m1() / x
    } else {
        1.0 + x * 0.5 * (1.0 + x / 3.0 * (1.0 + 0.25 * x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(1);
        let xs: Vec<f64> = (0..200_000).map(|_| normal(&mut rng)).collect();
        let m = stats::mean(&xs);
        let v = stats::variance(&xs);
        assert!(m.abs() < 0.01, "mean {m}");
        assert!((v - 1.0).abs() < 0.02, "var {v}");
    }

    #[test]
    fn exponential_mean_and_variance() {
        // Exp(mean m): E[X] = m, Var[X] = m²
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..100_000).map(|_| exponential(&mut rng, 3.0)).collect();
        assert!((stats::mean(&xs) - 3.0).abs() < 0.05);
        let v = stats::variance(&xs);
        assert!((v - 9.0).abs() / 9.0 < 0.05, "var {v} want 9");
    }

    #[test]
    fn samplers_are_deterministic_under_fixed_seed() {
        // the whole evaluation depends on seeded reproducibility: the same
        // seed must give the same draw sequence for every sampler
        for seed in [1u64, 42, 0xDEAD] {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let z = Zipf::new(1000, 1.1);
            for _ in 0..200 {
                assert_eq!(normal(&mut a), normal(&mut b));
                assert_eq!(exponential(&mut a, 3.0), exponential(&mut b, 3.0));
                assert_eq!(gamma(&mut a, 2.0, 14.0), gamma(&mut b, 2.0, 14.0));
                assert_eq!(z.sample(&mut a), z.sample(&mut b));
            }
        }
        // and different seeds diverge
        let mut a = Rng::new(7);
        let mut b = Rng::new(8);
        let same = (0..100)
            .filter(|_| gamma(&mut a, 2.0, 14.0) == gamma(&mut b, 2.0, 14.0))
            .count();
        assert_eq!(same, 0);
    }

    #[test]
    fn gamma_shape1_matches_exponential_moments() {
        // shape 1 gamma IS the exponential (the memoryless hazard used for
        // failure schedules): mean = scale, var = scale²
        let mut rng = Rng::new(12);
        let xs: Vec<f64> = (0..200_000).map(|_| gamma(&mut rng, 1.0, 28.0)).collect();
        let m = stats::mean(&xs);
        let v = stats::variance(&xs);
        assert!((m - 28.0).abs() / 28.0 < 0.02, "mean {m}");
        assert!((v - 28.0 * 28.0).abs() / (28.0 * 28.0) < 0.06, "var {v}");
    }

    #[test]
    fn gamma_moments() {
        // mean = k*theta, var = k*theta^2
        for (k, th) in [(0.5, 2.0), (2.0, 3.0), (7.5, 0.5)] {
            let mut rng = Rng::new(3);
            let xs: Vec<f64> = (0..200_000).map(|_| gamma(&mut rng, k, th)).collect();
            let m = stats::mean(&xs);
            let v = stats::variance(&xs);
            assert!((m - k * th).abs() / (k * th) < 0.02, "k={k} mean {m}");
            assert!((v - k * th * th).abs() / (k * th * th) < 0.06, "k={k} var {v}");
        }
    }

    #[test]
    fn gamma_survival_matches_empirical() {
        let (k, th) = (2.0, 14.0);
        let mut rng = Rng::new(4);
        let xs: Vec<f64> = (0..100_000).map(|_| gamma(&mut rng, k, th)).collect();
        for t in [5.0, 14.0, 28.0, 56.0] {
            let emp = xs.iter().filter(|&&x| x > t).count() as f64 / xs.len() as f64;
            let ana = gamma_survival(t, k, th);
            assert!((emp - ana).abs() < 0.01, "t={t} emp={emp} ana={ana}");
        }
    }

    #[test]
    fn ln_gamma_known_values() {
        // Gamma(5) = 24, Gamma(0.5) = sqrt(pi)
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }

    #[test]
    fn zipf_rank_frequencies_follow_power_law() {
        let n = 1000;
        let s = 1.1;
        let z = Zipf::new(n, s);
        let mut rng = Rng::new(5);
        let mut counts = vec![0u64; n];
        let draws = 500_000;
        for _ in 0..draws {
            let k = z.sample(&mut rng);
            assert!(k < n);
            counts[k] += 1;
        }
        // rank-0 must dominate; check ratio of rank0/rank9 ≈ 10^s
        let r = counts[0] as f64 / counts[9] as f64;
        let want = 10f64.powf(s);
        assert!((r / want - 1.0).abs() < 0.15, "ratio {r} want {want}");
        // heavy skew: top 1% of rows take a large share
        let top: u64 = counts[..n / 100].iter().sum();
        assert!(top as f64 / draws as f64 > 0.3);
    }

    #[test]
    fn zipf_n1_always_zero() {
        let z = Zipf::new(1, 1.2);
        let mut rng = Rng::new(6);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut rng), 0);
        }
    }
}
