//! Self-contained substrates the offline image forced us to build:
//! RNG, probability distributions, statistics, JSON, CLI parsing, and a
//! scoped thread-pool helper. See DESIGN.md §Offline-dependency note.

pub mod cli;
pub mod dist;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threads;
