//! Scoped parallel-for over index ranges (no rayon in the offline image).
//!
//! The Emb PS cluster fans gather/scatter work out across emulated nodes;
//! `parallel_chunks` runs a closure per contiguous chunk on std scoped
//! threads. Falls back to inline execution for small inputs where thread
//! spawn cost would dominate.

/// Run `f(chunk_start, chunk_end)` over `[0, n)` split into up to
/// `max_threads` contiguous chunks. `f` must be Sync; chunks are disjoint.
pub fn parallel_chunks<F>(n: usize, max_threads: usize, min_per_thread: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    // available_parallelism() is a syscall — cache it (it cost ~30 µs per
    // gather on the hot path before this; EXPERIMENTS.md §Perf #5)
    static HW: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    let hw = *HW.get_or_init(|| {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    });
    let threads = max_threads.min(hw).min(n / min_per_thread.max(1)).max(1);
    if threads == 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let fref = &f;
            scope.spawn(move || fref(lo, hi));
        }
    });
}

/// [`parallel_chunks`], but additionally hands every worker the
/// **disjoint** `&mut` sub-slice of `data` its item range owns: `data`
/// is `n` items of `per_item` elements each, and the worker for
/// `[lo, hi)` receives `data[lo*per_item .. hi*per_item]`. This is the
/// safe replacement for the old `SendPtr` raw-pointer fan-out in the
/// gather path — `split_at_mut` proves disjointness to the compiler, so
/// no `unsafe` is needed to write output chunks from scoped threads.
pub fn parallel_chunks_mut<T, F>(
    data: &mut [T],
    n: usize,
    per_item: usize,
    max_threads: usize,
    min_per_thread: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    assert_eq!(data.len(), n * per_item, "data is not n items of per_item");
    if n == 0 {
        return;
    }
    static HW: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    let hw = *HW.get_or_init(|| {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    });
    let threads = max_threads.min(hw).min(n / min_per_thread.max(1)).max(1);
    if threads == 1 {
        f(0, n, data);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut lo = 0;
        while lo < n {
            let hi = (lo + chunk).min(n);
            let (head, tail) = rest.split_at_mut((hi - lo) * per_item);
            rest = tail;
            let fref = &f;
            scope.spawn(move || fref(lo, hi, head));
            lo = hi;
        }
    });
}

/// Map `f(i)` over `[0, n)` in parallel, collecting results in order.
pub fn parallel_map<T, F>(n: usize, max_threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    let slots: Vec<std::sync::Mutex<&mut T>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    parallel_chunks(n, max_threads, 1, |lo, hi| {
        for i in lo..hi {
            **slots[i].lock().unwrap() = f(i);
        }
    });
    drop(slots);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_every_index_exactly_once() {
        let n = 10_000;
        let counters: Vec<AtomicUsize> =
            (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(n, 8, 1, |lo, hi| {
            for i in lo..hi {
                counters[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(counters.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_input_ok() {
        parallel_chunks(0, 4, 1, |_, _| panic!("must not run"));
    }

    #[test]
    fn single_thread_fallback_for_small_n() {
        let hits = AtomicUsize::new(0);
        parallel_chunks(3, 8, 100, |lo, hi| {
            assert_eq!((lo, hi), (0, 3));
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chunks_mut_partitions_exactly() {
        let n = 5_000;
        let per = 3;
        let mut data = vec![0u32; n * per];
        parallel_chunks_mut(&mut data, n, per, 8, 1, |lo, hi, chunk| {
            assert_eq!(chunk.len(), (hi - lo) * per);
            for (k, v) in chunk.iter_mut().enumerate() {
                *v += (lo * per + k) as u32 + 1;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1, "element {i} written wrong or twice");
        }
    }

    #[test]
    fn chunks_mut_single_thread_fallback() {
        let mut data = vec![0u8; 6];
        let hits = AtomicUsize::new(0);
        parallel_chunks_mut(&mut data, 3, 2, 8, 100, |lo, hi, chunk| {
            assert_eq!((lo, hi, chunk.len()), (0, 3, 6));
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chunks_mut_empty_ok() {
        let mut data: Vec<u8> = vec![];
        parallel_chunks_mut(&mut data, 0, 4, 4, 1, |_, _, _| panic!("must not run"));
    }

    #[test]
    #[should_panic(expected = "per_item")]
    fn chunks_mut_length_mismatch_panics() {
        let mut data = vec![0u8; 5];
        parallel_chunks_mut(&mut data, 3, 2, 4, 1, |_, _, _| {});
    }

    #[test]
    fn parallel_map_ordered() {
        let v = parallel_map(100, 8, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }
}
