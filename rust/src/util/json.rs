//! Minimal JSON: a recursive-descent parser (for the artifact
//! `manifest.json` ABI emitted by `python/compile/aot.py`) and a writer
//! (for experiment result files). No serde in the offline image.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected EOF"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    while self.i < self.b.len() && self.b[self.i] & 0xC0 == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }
}

/// Streaming JSON writer for result files (pretty, deterministic ordering).
#[derive(Default)]
pub struct JsonWriter {
    out: String,
}

impl JsonWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn write(v: &Json) -> String {
        let mut w = Self::new();
        w.value(v, 0);
        w.out
    }

    fn value(&mut self, v: &Json, indent: usize) {
        match v {
            Json::Null => self.out.push_str("null"),
            Json::Bool(b) => self.out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(self.out, "{}", *n as i64);
                } else {
                    let _ = write!(self.out, "{n}");
                }
            }
            Json::Str(s) => self.string(s),
            Json::Arr(xs) => {
                if xs.is_empty() {
                    self.out.push_str("[]");
                    return;
                }
                self.out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        self.out.push_str(", ");
                    }
                    self.value(x, indent);
                }
                self.out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    self.out.push_str("{}");
                    return;
                }
                self.out.push_str("{\n");
                let pad = "  ".repeat(indent + 1);
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        self.out.push_str(",\n");
                    }
                    self.out.push_str(&pad);
                    self.string(k);
                    self.out.push_str(": ");
                    self.value(x, indent + 1);
                }
                self.out.push('\n');
                self.out.push_str(&"  ".repeat(indent));
                self.out.push('}');
            }
        }
    }

    fn string(&mut self, s: &str) {
        self.out.push('"');
        for c in s.chars() {
            match c {
                '"' => self.out.push_str("\\\""),
                '\\' => self.out.push_str("\\\\"),
                '\n' => self.out.push_str("\\n"),
                '\t' => self.out.push_str("\\t"),
                '\r' => self.out.push_str("\\r"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(self.out, "\\u{:04x}", c as u32);
                }
                c => self.out.push(c),
            }
        }
        self.out.push('"');
    }
}

/// Convenience: build a Json object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
          "name": "mini", "batch": 128,
          "params": [{"name": "bot0.w", "shape": [13, 64]}],
          "train_step": {"inputs": ["dense", "emb"], "file": "t.hlo.txt"}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "mini");
        assert_eq!(j.get("batch").unwrap().as_usize().unwrap(), 128);
        let p0 = &j.get("params").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = p0.get("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![13, 64]);
    }

    #[test]
    fn roundtrip_through_writer() {
        let v = obj(vec![
            ("a", num(1.5)),
            ("b", arr(vec![num(1.0), s("x"), Json::Bool(true), Json::Null])),
            ("c", obj(vec![("nested", s("q\"uote"))])),
        ]);
        let text = JsonWriter::write(&v);
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn escapes_and_unicode() {
        let j = Json::parse(r#""a\nA\\""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nA\\");
        let j = Json::parse("\"héllo\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo");
    }

    #[test]
    fn negative_and_float_numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64().unwrap(), -350.0);
    }
}
