//! Tiny CLI argument parser (clap is unavailable in the offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generates usage text from registered options.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative option set + parsed values.
#[derive(Debug, Default)]
pub struct Cli {
    program: String,
    about: String,
    specs: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Self { program: program.into(), about: about.into(), ..Default::default() }
    }

    /// Register `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(OptSpec {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            default: Some(default.into()),
        });
        self
    }

    /// Register a boolean `--name` flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(OptSpec {
            name: name.into(),
            help: help.into(),
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut u = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for s in &self.specs {
            let lhs = if s.takes_value {
                format!("--{} <v>", s.name)
            } else {
                format!("--{}", s.name)
            };
            let def = s.default.as_deref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            u.push_str(&format!("  {lhs:<24} {}{def}\n", s.help));
        }
        u
    }

    /// Parse the given args (exclusive of argv[0]).
    pub fn parse(mut self, args: &[String]) -> Result<Self> {
        for s in &self.specs {
            if let Some(d) = &s.default {
                self.values.insert(s.name.clone(), d.clone());
            }
            if !s.takes_value {
                self.flags.insert(s.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self.specs.iter().find(|s| s.name == key);
                match spec {
                    Some(s) if s.takes_value => {
                        let v = match inline {
                            Some(v) => v,
                            None => {
                                i += 1;
                                if i >= args.len() {
                                    bail!("--{key} expects a value");
                                }
                                args[i].clone()
                            }
                        };
                        self.values.insert(key, v);
                    }
                    Some(_) => {
                        if inline.is_some() {
                            bail!("--{key} is a flag, no value allowed");
                        }
                        self.flags.insert(key, true);
                    }
                    None => bail!("unknown option --{key}\n\n{}", self.usage()),
                }
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    pub fn parse_env(self) -> Result<Self> {
        let args: Vec<String> = std::env::args().skip(1).collect();
        self.parse(&args)
    }

    pub fn get(&self, name: &str) -> &str {
        self.values.get(name)
            .unwrap_or_else(|| panic!("option --{name} not registered"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        *self.flags.get(name)
            .unwrap_or_else(|| panic!("flag --{name} not registered"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        Ok(self.get(name).parse()?)
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        Ok(self.get(name).parse()?)
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        Ok(self.get(name).parse()?)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let c = Cli::new("t", "test")
            .opt("steps", "100", "")
            .opt("preset", "mini", "")
            .flag("verbose", "")
            .parse(&args(&["--steps", "500", "--verbose"]))
            .unwrap();
        assert_eq!(c.get_usize("steps").unwrap(), 500);
        assert_eq!(c.get("preset"), "mini");
        assert!(c.get_flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_positionals() {
        let c = Cli::new("t", "test")
            .opt("k", "1", "")
            .parse(&args(&["fig7", "--k=9", "extra"]))
            .unwrap();
        assert_eq!(c.get_usize("k").unwrap(), 9);
        assert_eq!(c.positionals(), &["fig7".to_string(), "extra".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(Cli::new("t", "")
            .parse(&args(&["--nope"]))
            .is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Cli::new("t", "").opt("k", "1", "")
            .parse(&args(&["--k"]))
            .is_err());
    }
}
