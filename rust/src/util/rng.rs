//! Deterministic pseudo-random number generation.
//!
//! The offline build image has no `rand` crate, so this module implements
//! the generators the system needs from scratch: SplitMix64 for seeding and
//! xoshiro256++ (Blackman & Vigna) as the workhorse generator. Every
//! stochastic component in the repo (data synthesis, failure schedules,
//! eviction, experiments) takes an explicit seed so runs are reproducible.

/// SplitMix64: used to expand a single u64 seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent child generator (for per-node / per-table
    /// streams that must not correlate with the parent).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased
    /// enough for simulation; exact rejection not needed here).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm: O(k) expected, no O(n) allocation.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.usize_below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        self.shuffle(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let n = 1 + r.usize_below(100);
            let k = r.usize_below(n + 1);
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::new(5);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
