//! Descriptive statistics, correlation, regression, and gamma fitting —
//! the analysis substrate behind Figs. 3, 4, 6, 11, 12.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile by linear interpolation (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx * dy).sqrt()
}

/// Least-squares line fit y = a + b x; returns (intercept a, slope b).
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let b = if den == 0.0 { 0.0 } else { num / den };
    (my - b * mx, b)
}

/// Root-mean-square error between two series.
pub fn rmse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    (a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>()
        / a.len() as f64)
        .sqrt()
}

/// Fit a gamma distribution by the method of moments:
/// shape k = mean^2 / var, scale theta = var / mean.
/// (The paper fits observed time-to-failure data with a gamma and reports
/// an RMSE of 4.4% against the empirical survival curve — Fig. 3a.)
pub fn gamma_fit_moments(xs: &[f64]) -> (f64, f64) {
    let m = mean(xs);
    let v = variance(xs);
    assert!(m > 0.0 && v > 0.0, "gamma fit needs positive data");
    (m * m / v, v / m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::dist;
    use crate::util::rng::Rng;

    #[test]
    fn mean_var_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert_eq!(percentile(&xs, 50.0), 25.0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [6.0, 4.0, 2.0];
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_series_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linreg(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9 && (b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gamma_fit_recovers_parameters() {
        let (k, th) = (2.0, 14.0);
        let mut rng = Rng::new(1);
        let xs: Vec<f64> =
            (0..200_000).map(|_| dist::gamma(&mut rng, k, th)).collect();
        let (kf, thf) = gamma_fit_moments(&xs);
        assert!((kf - k).abs() / k < 0.03, "k {kf}");
        assert!((thf - th).abs() / th < 0.03, "theta {thf}");
    }

    #[test]
    fn rmse_zero_for_identical() {
        let xs = [1.0, 2.0];
        assert_eq!(rmse(&xs, &xs), 0.0);
    }
}
