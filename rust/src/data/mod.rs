//! Synthetic Criteo-style click log (substitution for the Kaggle/Terabyte
//! datasets — DESIGN.md §Substitutions #2).
//!
//! Requirements the generator satisfies:
//!  * **deterministic random access**: sample `i` is a pure function of
//!    `(seed, i)`, so a full-recovery rollback replays exactly the same
//!    samples it lost;
//!  * **Zipf-skewed categorical features**: production embedding access is
//!    heavily skewed — the property CPR-MFU/SSU exploit (paper Fig. 6);
//!  * **learnable labels**: a hidden teacher assigns every embedding row a
//!    latent score; the label is Bernoulli(sigmoid(dense term + sum of row
//!    scores + noise)), so frequent rows carry real, learnable signal and
//!    test AUC meaningfully degrades when their updates are lost.

use crate::config::DataConfig;
use crate::util::dist::{normal, Zipf};
use crate::util::rng::{Rng, SplitMix64};

/// One minibatch, layout matching the AOT artifact ABI:
/// dense row-major [B, num_dense], indices [B, num_sparse, hotness]
/// (global row ids per table), labels [B]. The PS pools the hotness axis
/// before the dense compute sees it.
#[derive(Clone, Debug)]
pub struct Batch {
    pub dense: Vec<f32>,
    pub indices: Vec<u32>,
    pub labels: Vec<f32>,
    pub batch: usize,
    pub hotness: usize,
}

impl Batch {
    pub fn zeros(batch: usize, num_dense: usize, num_sparse: usize) -> Self {
        Self::zeros_hot(batch, num_dense, num_sparse, 1)
    }

    pub fn zeros_hot(batch: usize, num_dense: usize, num_sparse: usize,
                     hotness: usize) -> Self {
        Self {
            dense: vec![0.0; batch * num_dense],
            indices: vec![0; batch * num_sparse * hotness],
            labels: vec![0.0; batch],
            batch,
            hotness,
        }
    }
}

/// The generator. Cheap to clone; all sampling state is per-call.
#[derive(Clone)]
pub struct SyntheticDataset {
    cfg: DataConfig,
    num_dense: usize,
    zipf: Vec<Zipf>,
    /// teacher weights for the dense features
    teacher_dense: Vec<f64>,
    /// per-table hash salt for row scores
    table_salt: Vec<u64>,
    emb_scale: f64,
}

impl SyntheticDataset {
    pub fn new(num_dense: usize, cfg: &DataConfig) -> Self {
        assert_eq!(cfg.table_rows.len(), cfg.zipf_s.len());
        let mut seeder = Rng::new(cfg.seed ^ 0xD1CE_BA5E);
        // Dense weights deliberately weak relative to the embedding score
        // sum: model quality must *depend* on the embedding state, or
        // partial-recovery damage would be invisible (the whole point of
        // Figs 2/7/11 is that lost embedding updates cost AUC).
        let teacher_dense: Vec<f64> =
            (0..num_dense).map(|_| normal(&mut seeder) * 0.12).collect();
        let table_salt: Vec<u64> =
            (0..cfg.table_rows.len()).map(|_| seeder.next_u64()).collect();
        let zipf = cfg
            .table_rows
            .iter()
            .zip(&cfg.zipf_s)
            .map(|(&n, &s)| Zipf::new(n, s))
            .collect();
        let emb_scale = cfg.teacher_emb_scale / (cfg.table_rows.len() as f64).sqrt();
        Self { cfg: cfg.clone(), num_dense, zipf, teacher_dense, table_salt, emb_scale }
    }

    pub fn num_tables(&self) -> usize {
        self.cfg.table_rows.len()
    }

    pub fn train_samples(&self) -> usize {
        self.cfg.train_samples
    }

    pub fn eval_samples(&self) -> usize {
        self.cfg.eval_samples
    }

    /// The hidden teacher's latent score for (table, row) — deterministic,
    /// in [-1, 1], independent of row frequency.
    pub fn row_score(&self, table: usize, row: u32) -> f64 {
        let mut h = SplitMix64::new(self.table_salt[table] ^ (row as u64));
        (h.next_u64() >> 11) as f64 * (2.0 / (1u64 << 53) as f64) - 1.0
    }

    /// Generate sample `id` (train ids: 0..train_samples; eval ids are
    /// offset internally so the eval split never overlaps train).
    fn gen(&self, id: u64, dense: &mut [f32], idx: &mut [u32]) -> f32 {
        let mut rng = Rng::new(self.cfg.seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let h = self.cfg.hotness;
        let mut logit = 0.0f64;
        for (d, w) in dense.iter_mut().zip(&self.teacher_dense) {
            let x = normal(&mut rng);
            *d = x as f32;
            logit += w * x;
        }
        for t in 0..self.num_tables() {
            // H lookups per feature; the teacher sees the mean row score,
            // matching the sum-pooled representation the model learns on
            let mut score = 0.0;
            for slot in 0..h {
                let row = self.zipf[t].sample(&mut rng) as u32;
                idx[t * h + slot] = row;
                score += self.row_score(t, row);
            }
            logit += self.emb_scale * score / h as f64;
        }
        logit += normal(&mut rng) * self.cfg.label_noise;
        let p = 1.0 / (1.0 + (-logit).exp());
        (rng.f64() < p) as u32 as f32
    }

    /// Fill `batch` with consecutive train samples starting at `start`
    /// (wrapping at train_samples — single-epoch training never wraps).
    pub fn fill_train_batch(&self, start: u64, out: &mut Batch) {
        self.fill(start, 0, out);
    }

    /// Fill with eval samples (disjoint id space).
    pub fn fill_eval_batch(&self, start: u64, out: &mut Batch) {
        self.fill(start, 1 << 62, out);
    }

    fn fill(&self, start: u64, offset: u64, out: &mut Batch) {
        let nd = self.num_dense;
        let ns = self.num_tables();
        let h = self.cfg.hotness;
        debug_assert_eq!(out.hotness, h, "batch hotness mismatch");
        for b in 0..out.batch {
            let id = offset + start + b as u64;
            let dense = &mut out.dense[b * nd..(b + 1) * nd];
            let idx = &mut out.indices[b * ns * h..(b + 1) * ns * h];
            out.labels[b] = self.gen(id, dense, idx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::util::stats;

    fn mini_ds() -> SyntheticDataset {
        let cfg = preset("mini").unwrap();
        SyntheticDataset::new(cfg.model.num_dense, &cfg.data)
    }

    #[test]
    fn deterministic_by_sample_id() {
        let ds = mini_ds();
        let mut a = Batch::zeros(64, 13, 26);
        let mut b = Batch::zeros(64, 13, 26);
        ds.fill_train_batch(1000, &mut a);
        ds.fill_train_batch(1000, &mut b);
        assert_eq!(a.dense, b.dense);
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn overlapping_windows_agree_per_sample() {
        // sample id k must be identical no matter which batch start reads it
        let ds = mini_ds();
        let mut a = Batch::zeros(8, 13, 26);
        let mut b = Batch::zeros(8, 13, 26);
        ds.fill_train_batch(100, &mut a);
        ds.fill_train_batch(104, &mut b);
        assert_eq!(a.indices[4 * 26..8 * 26], b.indices[0..4 * 26]);
        assert_eq!(a.labels[4..8], b.labels[0..4]);
    }

    #[test]
    fn eval_split_disjoint_from_train() {
        let ds = mini_ds();
        let mut tr = Batch::zeros(32, 13, 26);
        let mut ev = Batch::zeros(32, 13, 26);
        ds.fill_train_batch(0, &mut tr);
        ds.fill_eval_batch(0, &mut ev);
        assert_ne!(tr.labels, ev.labels); // astronomically unlikely to match
    }

    #[test]
    fn indices_within_table_bounds() {
        let ds = mini_ds();
        let rows = ds.cfg.table_rows.clone();
        let mut b = Batch::zeros(256, 13, 26);
        ds.fill_train_batch(0, &mut b);
        for s in 0..256 {
            for t in 0..26 {
                assert!((b.indices[s * 26 + t] as usize) < rows[t],
                        "table {t} idx {} rows {}", b.indices[s * 26 + t], rows[t]);
            }
        }
    }

    #[test]
    fn labels_are_balanced_ish_and_binary() {
        let ds = mini_ds();
        let mut b = Batch::zeros(4096, 13, 26);
        ds.fill_train_batch(0, &mut b);
        let pos: f64 = b.labels.iter().map(|&x| x as f64).sum::<f64>() / 4096.0;
        assert!(b.labels.iter().all(|&l| l == 0.0 || l == 1.0));
        assert!(pos > 0.25 && pos < 0.75, "positive rate {pos}");
    }

    #[test]
    fn access_frequency_is_zipf_skewed() {
        let ds = mini_ds();
        let mut b = Batch::zeros(4096, 13, 26);
        ds.fill_train_batch(0, &mut b);
        // table 0 is large; rank-0 row should dominate uniform share
        let rows0 = ds.cfg.table_rows[0];
        let mut counts = vec![0u32; rows0];
        for s in 0..4096 {
            counts[b.indices[s * 26] as usize] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        assert!(max / 4096.0 > 20.0 / rows0 as f64, "no skew detected");
    }

    #[test]
    fn multi_hot_batches_fill_all_slots_in_bounds() {
        let mut cfg = preset("mini").unwrap();
        cfg.data.hotness = 4;
        let ds = SyntheticDataset::new(13, &cfg.data);
        let mut b = Batch::zeros_hot(64, 13, 26, 4);
        ds.fill_train_batch(0, &mut b);
        assert_eq!(b.indices.len(), 64 * 26 * 4);
        for s in 0..64 {
            for t in 0..26 {
                for h in 0..4 {
                    let idx = b.indices[(s * 26 + t) * 4 + h] as usize;
                    assert!(idx < cfg.data.table_rows[t]);
                }
            }
        }
        // deterministic under hotness too
        let mut c = Batch::zeros_hot(64, 13, 26, 4);
        ds.fill_train_batch(0, &mut c);
        assert_eq!(b.indices, c.indices);
        assert_eq!(b.labels, c.labels);
    }

    #[test]
    fn labels_correlate_with_teacher_logit() {
        // sanity: the teacher signal must be recoverable (AUC of the
        // *oracle* predictor well above 0.5)
        let ds = mini_ds();
        let mut b = Batch::zeros(8192, 13, 26);
        ds.fill_train_batch(0, &mut b);
        let mut logits = Vec::with_capacity(8192);
        for s in 0..8192 {
            let mut l = 0.0;
            for d in 0..13 {
                l += ds.teacher_dense[d] * b.dense[s * 13 + d] as f64;
            }
            for t in 0..26 {
                l += ds.emb_scale * ds.row_score(t, b.indices[s * 26 + t]);
            }
            logits.push(l);
        }
        let labels: Vec<f64> = b.labels.iter().map(|&x| x as f64).collect();
        let corr = stats::pearson(&logits, &labels);
        assert!(corr > 0.3, "teacher signal too weak: corr={corr}");
    }
}
