//! Checkpoint storage + save/restore for full and partial recovery.
//!
//! [`CheckpointStore`] is the emulated persistent store: a mirror of every
//! Emb PS shard plus the MLP parameters and the training position (step /
//! sample count). Full recovery restores everything and rewinds the data
//! iterator; partial recovery restores only the failed nodes' shards and
//! keeps everyone else's progress (paper §2.3).
//!
//! Priority checkpointing (CPR-SCAR/MFU/SSU) saves selected *rows* into the
//! mirror at a higher cadence instead of whole tables, so after a failure
//! the hot rows come back much fresher than T_save-old (paper §4.2).
//!
//! All save/restore paths are generic over [`crate::cluster::PsBackend`],
//! so the same store serves the in-process and the threaded cluster
//! runtimes (checkpoints taken on one restore onto the other — row routing
//! is part of the trait contract).
//!
//! ## Asynchronous pipeline
//!
//! The coordinator no longer applies saves to the mirror inline. Row and
//! node snapshots are *captured* synchronously at the save point (cheap
//! memcpy — this is the consistency point) and handed to
//! [`async_pipeline::CheckpointPipeline`], whose writer thread applies
//! them to the mirror and persists to disk while training proceeds
//! (Check-N-Run-style decoupled checkpointing). Restores go through the
//! same FIFO channel, so a restore always observes every save submitted
//! before it.
//!
//! **Crash-consistency rule:** a durable checkpoint is only *published*
//! after the writer thread has fsynced the data file and then the `LATEST`
//! manifest (see [`disk`]); a crash mid-write leaves the previous
//! checkpoint as the published one, never a torn file.

pub mod async_pipeline;
pub mod disk;
pub mod tracker;

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cluster::{PsControlPlane, PsDataPlane};

/// Snapshot store (the emulated persistent checkpoint target).
#[derive(Clone, Debug)]
pub struct CheckpointStore {
    /// mirror[node][table], identical layout to the cluster shards
    shards: Vec<Vec<Vec<f32>>>,
    /// optimizer-state mirror[node][table] (row-wise accumulators);
    /// paper §2.2: checkpoints include the optimizer state
    opt: Vec<Vec<Vec<f32>>>,
    /// MLP parameters at the last save
    pub mlp: Vec<Vec<f32>>,
    /// training position at the last save that updated the PLS marker
    pub step: u64,
    pub samples: u64,
}

impl CheckpointStore {
    /// Initial checkpoint = the cluster's initial state (epoch 0).
    pub fn initial<B: PsControlPlane + ?Sized>(cluster: &B, mlp: Vec<Vec<f32>>) -> Self {
        let mut shards = Vec::with_capacity(cluster.n_nodes());
        let mut opt = Vec::with_capacity(cluster.n_nodes());
        for n in 0..cluster.n_nodes() {
            let snap = cluster.snapshot_node(n);
            shards.push(snap.shards);
            opt.push(snap.opt);
        }
        Self { shards, opt, mlp, step: 0, samples: 0 }
    }

    /// Full checkpoint: mirror every shard + MLP params + position.
    /// (Synchronous path — the coordinator's async equivalent is
    /// [`async_pipeline::CheckpointPipeline::full_save`].)
    pub fn full_save<B: PsControlPlane + ?Sized>(
        &mut self,
        cluster: &B,
        mlp: Vec<Vec<f32>>,
        step: u64,
        samples: u64,
    ) {
        for n in 0..cluster.n_nodes() {
            let snap = cluster.snapshot_node(n);
            self.shards[n] = snap.shards;
            self.opt[n] = snap.opt;
        }
        self.mlp = mlp;
        self.step = step;
        self.samples = samples;
    }

    /// Apply one captured node snapshot to the mirror (writer-thread path).
    pub fn apply_node(&mut self, snap: crate::cluster::NodeSnapshot) {
        self.shards[snap.node] = snap.shards;
        self.opt[snap.node] = snap.opt;
    }

    /// Priority (partial-content) save: copy only `rows` of `table` into
    /// the mirror. Does NOT move the PLS position marker.
    pub fn save_rows<B: PsDataPlane + ?Sized>(&mut self, cluster: &B, table: usize, rows: &[u32]) {
        let dim = cluster.tables()[table].dim;
        let (data, opt) = cluster.read_rows(table, rows);
        self.apply_rows(table, rows, dim, &data, &opt);
    }

    /// Apply captured row data (`data` in `rows` order, [rows.len() * dim])
    /// to the mirror (writer-thread path).
    pub fn apply_rows(
        &mut self,
        table: usize,
        rows: &[u32],
        dim: usize,
        data: &[f32],
        opt: &[f32],
    ) {
        let n_nodes = self.shards.len();
        for (i, &row) in rows.iter().enumerate() {
            let (node, local) = crate::cluster::route_row(row as usize, n_nodes);
            self.shards[node][table][local * dim..(local + 1) * dim]
                .copy_from_slice(&data[i * dim..(i + 1) * dim]);
            self.opt[node][table][local] = opt[i];
        }
    }

    /// Save one whole table. Row-at-a-time through `read_rows`, which is
    /// fine for its only callers — the tiny (≤64-row) non-priority tables
    /// of the skewed layout; large tables go through `snapshot_node`.
    pub fn save_table<B: PsDataPlane + ?Sized>(&mut self, cluster: &B, table: usize) {
        let rows: Vec<u32> = (0..cluster.tables()[table].rows as u32).collect();
        self.save_rows(cluster, table, &rows);
    }

    /// Record MLP params + advance the PLS position marker (done at every
    /// interval boundary, for all strategies).
    pub fn mark_position(&mut self, mlp: Vec<Vec<f32>>, step: u64, samples: u64) {
        self.mlp = mlp;
        self.step = step;
        self.samples = samples;
    }

    /// PARTIAL recovery: restore only `node`'s shards; everyone else keeps
    /// their progress.
    pub fn restore_node<B: PsControlPlane + ?Sized>(&self, cluster: &B, node: usize) {
        cluster.load_node(node, &self.shards[node], &self.opt[node]);
    }

    /// FULL recovery: restore every shard; returns (mlp, step, samples) for
    /// the trainer to rewind to.
    pub fn restore_all<B: PsControlPlane + ?Sized>(&self, cluster: &B) -> (Vec<Vec<f32>>, u64, u64) {
        for n in 0..cluster.n_nodes() {
            cluster.load_node(n, &self.shards[n], &self.opt[n]);
        }
        (self.mlp.clone(), self.step, self.samples)
    }

    /// Bytes a full checkpoint occupies (tables + MLP).
    pub fn size_bytes(&self) -> usize {
        let t: usize = self.shards.iter()
            .flat_map(|n| n.iter().map(|s| s.len() * 4)).sum();
        t + self.mlp.iter().map(|p| p.len() * 4).sum::<usize>()
    }

    // -- on-disk persistence ------------------------------------------------

    const MAGIC: u32 = 0x4350_5232; // "CPR2"

    pub fn write_file(&self, path: &Path) -> Result<()> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut f = std::io::BufWriter::new(file);
        w32(&mut f, Self::MAGIC)?;
        w64(&mut f, self.step)?;
        w64(&mut f, self.samples)?;
        w32(&mut f, self.shards.len() as u32)?;
        w32(&mut f, self.shards.first().map_or(0, |n| n.len()) as u32)?;
        for node in &self.shards {
            for shard in node {
                w32(&mut f, shard.len() as u32)?;
                wf32s(&mut f, shard)?;
            }
        }
        for node in &self.opt {
            for st in node {
                w32(&mut f, st.len() as u32)?;
                wf32s(&mut f, st)?;
            }
        }
        w32(&mut f, self.mlp.len() as u32)?;
        for p in &self.mlp {
            w32(&mut f, p.len() as u32)?;
            wf32s(&mut f, p)?;
        }
        // crash-consistency: the data must be durable BEFORE the caller
        // publishes a manifest pointing at it
        f.flush()?;
        f.get_ref().sync_all().context("fsync checkpoint data")?;
        Ok(())
    }

    pub fn read_file(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening {}", path.display()))?,
        );
        if r32(&mut f)? != Self::MAGIC {
            bail!("{} is not a CPR checkpoint", path.display());
        }
        let step = r64(&mut f)?;
        let samples = r64(&mut f)?;
        let n_nodes = r32(&mut f)? as usize;
        let n_tables = r32(&mut f)? as usize;
        let mut shards = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let mut node = Vec::with_capacity(n_tables);
            for _ in 0..n_tables {
                let len = r32(&mut f)? as usize;
                node.push(rf32s(&mut f, len)?);
            }
            shards.push(node);
        }
        let mut opt = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let mut node = Vec::with_capacity(n_tables);
            for _ in 0..n_tables {
                let len = r32(&mut f)? as usize;
                node.push(rf32s(&mut f, len)?);
            }
            opt.push(node);
        }
        let n_mlp = r32(&mut f)? as usize;
        let mut mlp = Vec::with_capacity(n_mlp);
        for _ in 0..n_mlp {
            let len = r32(&mut f)? as usize;
            mlp.push(rf32s(&mut f, len)?);
        }
        Ok(Self { shards, opt, mlp, step, samples })
    }
}

fn w32<W: Write>(w: &mut W, v: u32) -> Result<()> {
    Ok(w.write_all(&v.to_le_bytes())?)
}

fn w64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    Ok(w.write_all(&v.to_le_bytes())?)
}

fn wf32s<W: Write>(w: &mut W, v: &[f32]) -> Result<()> {
    // SAFETY: f32 slice reinterpreted as bytes (little-endian hosts only,
    // which is all this image targets)
    let bytes = unsafe {
        std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
    };
    Ok(w.write_all(bytes)?)
}

fn r32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn rf32s<R: Read>(r: &mut R, len: usize) -> Result<Vec<f32>> {
    let mut v = vec![0f32; len];
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut u8, len * 4)
    };
    r.read_exact(bytes)?;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ThreadedCluster;
    use crate::embedding::{PsCluster, TableInfo};
    use crate::prop_assert;
    use crate::testing::{forall, gen};

    fn cluster() -> PsCluster {
        PsCluster::new(
            vec![TableInfo { rows: 50, dim: 4 }, TableInfo { rows: 11, dim: 4 }],
            3,
            9,
        )
    }

    fn perturb(c: &PsCluster, seed: u64) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let idx: Vec<u32> = (0..20)
            .flat_map(|_| vec![rng.below(50) as u32, rng.below(11) as u32])
            .collect();
        let grads: Vec<f32> = (0..20 * 2 * 4).map(|_| rng.f32() - 0.5).collect();
        c.sgd_update(&idx, &grads, 0.5);
    }

    #[test]
    fn full_save_restore_roundtrip() {
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![vec![1.0, 2.0]]);
        perturb(&c, 1);
        store.full_save(&c, vec![vec![3.0, 4.0]], 10, 1280);
        let golden: Vec<f32> = c.shard(0, 0);
        perturb(&c, 2);
        assert_ne!(c.shard(0, 0), golden);
        let (mlp, step, samples) = store.restore_all(&c);
        assert_eq!(c.shard(0, 0), golden);
        assert_eq!(mlp, vec![vec![3.0, 4.0]]);
        assert_eq!((step, samples), (10, 1280));
    }

    #[test]
    fn partial_restore_touches_only_failed_node() {
        let c = cluster();
        let store = CheckpointStore::initial(&c, vec![]);
        perturb(&c, 3);
        let survivor: Vec<f32> = c.shard(1, 0);
        store.restore_node(&c, 0);
        // node 0 back to init, node 1 untouched
        let fresh = cluster();
        assert_eq!(c.shard(0, 0), fresh.shard(0, 0));
        assert_eq!(c.shard(1, 0), survivor);
    }

    #[test]
    fn save_rows_updates_only_those_rows() {
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        perturb(&c, 4);
        let trained_row5: Vec<f32> = {
            let mut v = vec![0.0; 4];
            c.read_row(0, 5, &mut v);
            v
        };
        store.save_rows(&c, 0, &[5]);
        perturb(&c, 5);
        // restore the node that owns row 5 (5 % 3 == 2)
        store.restore_node(&c, 2);
        let mut after = vec![0.0; 4];
        c.read_row(0, 5, &mut after);
        assert_eq!(after, trained_row5, "saved row must come back fresh");
        // a different row on the same node must come back as INIT (stale)
        let fresh = cluster();
        let mut got = vec![0.0; 4];
        let mut want = vec![0.0; 4];
        c.read_row(0, 8, &mut got); // 8 % 3 == 2, same node, not saved
        fresh.read_row(0, 8, &mut want);
        assert_eq!(got, want, "unsaved row must be stale");
    }

    #[test]
    fn save_table_saves_all_its_rows() {
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        perturb(&c, 6);
        store.save_table(&c, 1);
        let golden: Vec<Vec<f32>> =
            (0..3).map(|n| c.shard(n, 1)).collect();
        perturb(&c, 7);
        for n in 0..3 {
            store.restore_node(&c, n);
        }
        for n in 0..3 {
            assert_eq!(c.shard(n, 1), golden[n]);
        }
    }

    #[test]
    fn disk_roundtrip_preserves_everything() {
        let c = cluster();
        perturb(&c, 8);
        let mut store = CheckpointStore::initial(&c, vec![vec![1.5; 7]]);
        store.full_save(&c, vec![vec![2.5; 7]], 42, 5376);
        let dir = std::env::temp_dir().join("cpr_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("store.bin");
        store.write_file(&path).unwrap();
        let back = CheckpointStore::read_file(&path).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.samples, 5376);
        assert_eq!(back.mlp, store.mlp);
        assert_eq!(back.shards, store.shards);
        assert_eq!(back.opt, store.opt);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn read_rejects_garbage() {
        let dir = std::env::temp_dir().join("cpr_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(CheckpointStore::read_file(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn optimizer_state_rides_with_rows() {
        use crate::embedding::EmbOptimizer;
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        let opt = EmbOptimizer::RowAdagrad { eps: 1e-8 };
        // accumulate state on row 5 (node 5 % 3 == 2), checkpoint it
        c.apply_grads(&[5, 2], 1, &[1.0f32; 8], 1.0, opt);
        store.full_save(&c, vec![], 1, 128);
        let (node, local) = c.route(5);
        let saved_acc = c.opt_shard(node, 0)[local];
        // more training, then fail the node and restore
        c.apply_grads(&[5, 2], 1, &[1.0f32; 8], 1.0, opt);
        assert!(c.opt_shard(node, 0)[local] > saved_acc);
        store.restore_node(&c, node);
        assert_eq!(c.opt_shard(node, 0)[local], saved_acc,
                   "optimizer state must revert with the rows");
    }

    #[test]
    fn store_restores_across_backends() {
        // a checkpoint taken on the in-process backend restores onto the
        // threaded backend (and vice versa): routing is part of the trait
        let c = cluster();
        perturb(&c, 12);
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 5, 640);
        let t = ThreadedCluster::new(
            vec![TableInfo { rows: 50, dim: 4 }, TableInfo { rows: 11, dim: 4 }],
            3,
            999, // different seed: state must come fully from the store
        );
        store.restore_all(&t);
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        for table in 0..2 {
            for row in 0..c.tables[table].rows {
                c.read_row(table, row, &mut a);
                PsDataPlane::read_row(&t, table, row, &mut b);
                assert_eq!(a, b, "table {table} row {row}");
            }
        }
    }

    #[test]
    fn property_partial_restore_preserves_survivors() {
        forall(41, 30, |rng| {
            let n_nodes = gen::usize_in(rng, 2, 6);
            let c = PsCluster::new(
                vec![TableInfo { rows: gen::usize_in(rng, 8, 40), dim: 4 }],
                n_nodes,
                rng.next_u64(),
            );
            let mut store = CheckpointStore::initial(&c, vec![]);
            // train a bit, checkpoint, train more, fail a random node
            let rows = c.tables[0].rows;
            let idx: Vec<u32> =
                (0..16).map(|_| rng.below(rows as u64) as u32).collect();
            let grads: Vec<f32> = (0..16 * 4).map(|_| rng.f32()).collect();
            c.sgd_update(&idx, &grads, 0.1);
            store.full_save(&c, vec![], 1, 128);
            c.sgd_update(&idx, &grads, 0.1);
            let victim = rng.usize_below(n_nodes);
            let survivors: Vec<Vec<f32>> = (0..n_nodes)
                .filter(|&n| n != victim)
                .map(|n| c.shard(n, 0))
                .collect();
            store.restore_node(&c, victim);
            let after: Vec<Vec<f32>> = (0..n_nodes)
                .filter(|&n| n != victim)
                .map(|n| c.shard(n, 0))
                .collect();
            prop_assert!(survivors == after, "survivor state changed");
            Ok(())
        });
    }
}
