//! Checkpoint storage + save/restore for full and partial recovery.
//!
//! [`CheckpointStore`] is the emulated persistent store: a mirror of every
//! Emb PS shard plus the MLP parameters and the training position (step /
//! sample count). Full recovery restores everything and rewinds the data
//! iterator; partial recovery restores only the failed nodes' shards and
//! keeps everyone else's progress (paper §2.3).
//!
//! Priority checkpointing (CPR-SCAR/MFU/SSU) saves selected *rows* into the
//! mirror at a higher cadence instead of whole tables, so after a failure
//! the hot rows come back much fresher than T_save-old (paper §4.2).
//!
//! All save/restore paths are generic over [`crate::cluster::PsBackend`],
//! so the same store serves the in-process and the threaded cluster
//! runtimes (checkpoints taken on one restore onto the other — row routing
//! is part of the trait contract).
//!
//! Quiesce contract: every control-plane call issued here (`snapshot_node`
//! for capture, `load_node` for restore) runs from coordinator code at a
//! step barrier, with the trainers parked behind the coordinator's
//! [`crate::cluster::PsQuiesce`] token — captures are consistency points,
//! never mid-batch tearings. The `invariant-lint` workspace tool enforces
//! that files making these calls document this contract.
//!
//! ## Sharded mirror + dirty tracking
//!
//! The mirror is a vector of per-node [`ShardState`] units — the same
//! shard-granular layout the cluster itself uses. Every row-level or
//! node-level application marks the touched local rows *dirty*; the dirty
//! sets are what checkpoint **format v2** ([`v2`]) turns into per-node
//! delta files, so an incremental publish writes only what changed since
//! the last durable publish instead of rewriting every node's mirror.
//!
//! ## Asynchronous pipeline
//!
//! The coordinator no longer applies saves to the mirror inline. Row and
//! node snapshots are *captured* synchronously at the save point (cheap
//! memcpy — this is the consistency point) and handed to
//! [`async_pipeline::CheckpointPipeline`], whose writer thread applies
//! them to the mirror and persists to disk while training proceeds
//! (Check-N-Run-style decoupled checkpointing). Restores go through the
//! same FIFO channel, so a restore always observes every save submitted
//! before it.
//!
//! **Crash-consistency rule:** a durable checkpoint is only *published*
//! after the writer thread has fsynced the data file(s) and then the
//! `LATEST` manifest (v1) / `MANIFEST` chain index (v2) — see [`disk`]
//! and [`v2`]; a crash mid-write leaves the previous checkpoint as the
//! published one, never a torn file.

pub mod async_pipeline;
pub mod codec;
pub mod disk;
pub mod tracker;
pub mod v2;
pub mod writer_pool;

use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cluster::{PsControlPlane, PsDataPlane};
use crate::config::{CheckpointConfig, CkptCodec, CkptFormat, DEFAULT_COMPACT_FRAC};
use crate::embedding::TableInfo;

// ---------------------------------------------------------------------------
// typed load/replay errors
// ---------------------------------------------------------------------------

/// What went wrong reading a checkpoint back (the v2 load/replay path
/// and the codec layer). Public APIs still return `anyhow::Result`, so
/// callers that care match on the variant via
/// `err.downcast_ref::<CkptError>()` instead of substring-grepping the
/// message (ISSUE 7).
#[derive(Debug)]
pub enum CkptError {
    /// A file or encoded blob ended before its declared payload.
    Truncated { what: String },
    /// The leading magic does not name any checkpoint file kind this
    /// build knows (or names the *wrong* kind for the read path).
    BadMagic { what: String, found: u32 },
    /// Chain geometry disagrees with its base: node ids, table counts,
    /// dims, or local row ranges.
    GeometryMismatch { what: String },
    /// An encoded file names a codec this build does not register, or
    /// a blob's framing is inconsistent with its codec.
    CodecMismatch { what: String },
    /// An encoded blob's FNV-1a checksum does not match its bytes.
    ChecksumMismatch { what: String },
    /// An underlying I/O failure that is not a clean truncation.
    Io(std::io::Error),
}

impl std::fmt::Display for CkptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CkptError::Truncated { what } => write!(f, "truncated checkpoint data: {what}"),
            CkptError::BadMagic { what, found } => {
                write!(f, "bad checkpoint magic {found:#010x}: {what}")
            }
            CkptError::GeometryMismatch { what } => {
                write!(f, "checkpoint geometry mismatch: {what}")
            }
            CkptError::CodecMismatch { what } => write!(f, "checkpoint codec mismatch: {what}"),
            CkptError::ChecksumMismatch { what } => {
                write!(f, "checkpoint checksum mismatch: {what}")
            }
            CkptError::Io(e) => write!(f, "checkpoint i/o error: {e}"),
        }
    }
}

impl std::error::Error for CkptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CkptError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CkptError {
    /// A clean EOF mid-record is [`CkptError::Truncated`] (the torn-file
    /// shape crash tests produce); everything else is real I/O trouble.
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            CkptError::Truncated { what: "file ended mid-record".into() }
        } else {
            CkptError::Io(e)
        }
    }
}

// ---------------------------------------------------------------------------
// checkpoint construction options
// ---------------------------------------------------------------------------

/// Everything a checkpoint writer needs to know, in one place — the
/// construction API for [`disk::DiskCheckpointer`] and
/// [`async_pipeline::CheckpointPipeline`] (ISSUE 7). Replaces the old
/// positional-argument constructor pairs: build one via
/// [`CheckpointOptions::from_config`] (the production path) or
/// `CheckpointOptions::default()` plus struct update syntax in tests.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointOptions {
    /// Durable-publication directory (`None` = in-memory mirror only).
    pub dir: Option<String>,
    /// v1 rotation depth: how many `ckpt-*.bin` generations to keep.
    pub keep: usize,
    /// On-disk layout: v1 monolithic files or v2 base+delta chains.
    pub format: CkptFormat,
    /// v2 chain-compaction threshold (re-base when pending delta bytes
    /// exceed `compact_frac × base_bytes`).
    pub compact_frac: f64,
    /// Payload codec for v2 files (ignored under v1).
    pub codec: CkptCodec,
    /// Artificial per-write delay — a test knob for exercising the
    /// async pipeline's backpressure; always zero in production.
    pub write_delay: std::time::Duration,
}

impl Default for CheckpointOptions {
    fn default() -> Self {
        Self {
            dir: None,
            keep: 2,
            format: CkptFormat::V1,
            compact_frac: DEFAULT_COMPACT_FRAC,
            codec: CkptCodec::None,
            write_delay: std::time::Duration::ZERO,
        }
    }
}

impl CheckpointOptions {
    /// The production mapping from job config to writer options.
    pub fn from_config(cfg: &CheckpointConfig) -> Self {
        Self {
            dir: cfg.dir.clone(),
            format: cfg.format,
            compact_frac: cfg.compact_frac,
            codec: cfg.codec,
            ..Self::default()
        }
    }

    /// Builder-style override for the publication directory.
    pub fn dir(mut self, dir: Option<&str>) -> Self {
        self.dir = dir.map(str::to_string);
        self
    }
}

/// Fsync a checkpoint directory — renames are directory-metadata updates,
/// so every publish path (v1 and v2) must make them durable before a
/// manifest can name the renamed files. The ONE copy of this primitive,
/// shared so the two formats' crash-consistency guarantees cannot drift.
pub(crate) fn fsync_dir(dir: &Path) -> Result<()> {
    let _t = crate::telemetry::span("ckpt_fsync_dir");
    std::fs::File::open(dir)
        .and_then(|d| d.sync_all())
        .with_context(|| format!("fsync checkpoint dir {}", dir.display()))
}

/// Write `name` durably: temp file → fsync → atomic rename. The caller
/// fsyncs the directory before any manifest/pointer names the file.
/// Returns the file's byte length. Shared by v1's `LATEST` pointer and
/// every v2 file, so the write half of the crash-consistency discipline
/// has one copy too.
pub(crate) fn write_durable<F>(dir: &Path, name: &str, write: F) -> Result<u64>
where
    F: FnOnce(&mut BufWriter<std::fs::File>) -> Result<()>,
{
    let tmp = dir.join(format!(".{name}.tmp"));
    let file = std::fs::File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    let mut w = BufWriter::new(file);
    write(&mut w)?;
    w.flush()?;
    {
        let _t = crate::telemetry::span("ckpt_fsync");
        w.get_ref()
            .sync_all()
            .with_context(|| format!("fsync {}", tmp.display()))?;
    }
    let path = dir.join(name);
    {
        let _t = crate::telemetry::span("ckpt_rename");
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {}", path.display()))?;
    }
    Ok(std::fs::metadata(&path)?.len())
}

// ---------------------------------------------------------------------------
// logical checkpoint I/O volume
// ---------------------------------------------------------------------------
//
// One shared set of byte formulas so the overhead ledger, the PLS cost
// model, and the v2 on-disk encoder agree on what a save/restore moves.
// These count *content* bytes (row payload + per-row bookkeeping), not
// file headers — headers are O(tables) noise next to O(rows·dim) payload.

/// Bytes one delta row record occupies: local row id + `dim` f32 values +
/// one f32 optimizer accumulator (the v2 delta record shape).
pub fn row_io_bytes(dim: usize) -> u64 {
    4 + 4 * dim as u64 + 4
}

/// Bytes a `n_rows`-row delta of a `dim`-wide table occupies.
pub fn rows_io_bytes(n_rows: usize, dim: usize) -> u64 {
    n_rows as u64 * row_io_bytes(dim)
}

/// Content bytes of one whole table (values + opt state, no row ids —
/// base files store rows positionally).
pub fn table_io_bytes(rows: usize, dim: usize) -> u64 {
    (rows * (dim + 1) * 4) as u64
}

/// Content bytes of the dense (MLP) parameters.
pub fn mlp_io_bytes(mlp: &[Vec<f32>]) -> u64 {
    mlp.iter().map(|p| p.len() as u64 * 4).sum()
}

/// Content bytes of a full checkpoint: every table + the dense params.
pub fn full_content_io_bytes(tables: &[TableInfo], mlp: &[Vec<f32>]) -> u64 {
    tables.iter().map(|t| table_io_bytes(t.rows, t.dim)).sum::<u64>() + mlp_io_bytes(mlp)
}

/// Content bytes of one node's slice of the mirror (what a partial
/// restore of that node moves).
pub fn node_content_io_bytes(tables: &[TableInfo], n_nodes: usize, node: usize) -> u64 {
    tables
        .iter()
        .map(|t| table_io_bytes(crate::embedding::shard_rows(t.rows, n_nodes, node), t.dim))
        .sum()
}

// ---------------------------------------------------------------------------
// per-node shard state
// ---------------------------------------------------------------------------

/// One node's slice of the checkpoint mirror: per-table shards + optimizer
/// accumulators, plus the *dirty set* — which local rows changed since the
/// last durable publish. The unit of incremental persistence: format v2
/// writes a node's dirty rows as a delta file and a fully-dirty (or
/// chain-less) node as a fresh base file.
#[derive(Clone, Debug)]
pub struct ShardState {
    /// shards[table], local_row-major [local_rows * dim]
    shards: Vec<Vec<f32>>,
    /// opt[table], one f32 per local row
    opt: Vec<Vec<f32>>,
    /// dirty[table][local_row]: changed since the last publish
    dirty: Vec<Vec<bool>>,
    /// dirty-row count per table (kept in sync with `dirty`)
    dirty_count: Vec<usize>,
}

impl PartialEq for ShardState {
    /// Content equality only — dirty bookkeeping is publication state,
    /// not checkpoint content (a store read back from disk is clean).
    fn eq(&self, other: &Self) -> bool {
        self.shards == other.shards && self.opt == other.opt
    }
}

impl ShardState {
    /// Build one node's state from its shard/opt parts (clean).
    pub fn from_parts(shards: Vec<Vec<f32>>, opt: Vec<Vec<f32>>) -> Self {
        let dirty = opt.iter().map(|o| vec![false; o.len()]).collect();
        let dirty_count = vec![0; opt.len()];
        Self { shards, opt, dirty, dirty_count }
    }

    /// Per-table shard data, local_row-major.
    pub fn shards(&self) -> &[Vec<f32>] {
        &self.shards
    }

    /// Per-table optimizer accumulators (one f32 per local row).
    pub fn opt(&self) -> &[Vec<f32>] {
        &self.opt
    }

    /// Mutable shard data WITHOUT dirty tracking — only for the async
    /// pipeline's restore path, which round-trips a *cloned* snapshot
    /// through the configured codec (checkpoint fidelity, not content
    /// mutation). Never call this on the live mirror.
    pub(crate) fn shards_mut(&mut self) -> &mut [Vec<f32>] {
        &mut self.shards
    }

    fn mark_row_dirty(&mut self, table: usize, local: usize) {
        if !self.dirty[table][local] {
            self.dirty[table][local] = true;
            self.dirty_count[table] += 1;
        }
    }

    fn mark_all_dirty(&mut self) {
        for (t, d) in self.dirty.iter_mut().enumerate() {
            for f in d.iter_mut() {
                *f = true;
            }
            self.dirty_count[t] = d.len();
        }
    }

    /// Total dirty rows across tables.
    pub fn dirty_row_count(&self) -> usize {
        self.dirty_count.iter().sum()
    }

    /// True when every local row of every table is dirty (a delta would
    /// be as large as a base).
    pub fn fully_dirty(&self) -> bool {
        self.dirty_count
            .iter()
            .zip(&self.dirty)
            .all(|(&c, d)| c == d.len())
    }

    /// The dirty local rows of `table`, ascending.
    pub fn dirty_rows(&self, table: usize) -> Vec<u32> {
        self.dirty[table]
            .iter()
            .enumerate()
            .filter_map(|(i, &d)| d.then_some(i as u32))
            .collect()
    }

    /// Forget this node's dirty marks (called after a successful durable
    /// publish — the chain now covers everything).
    pub fn clear_dirty(&mut self) {
        for (t, d) in self.dirty.iter_mut().enumerate() {
            for f in d.iter_mut() {
                *f = false;
            }
            self.dirty_count[t] = 0;
        }
    }

    /// Content bytes a delta of the current dirty set would occupy.
    pub fn dirty_io_bytes(&self) -> u64 {
        self.dirty_count
            .iter()
            .zip(&self.shards)
            .zip(&self.opt)
            .map(|((&c, s), o)| {
                let dim = if o.is_empty() { 0 } else { s.len() / o.len() };
                rows_io_bytes(c, dim)
            })
            .sum()
    }

    /// Content bytes of this node's full state (a base file's payload).
    pub fn content_io_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.len() as u64 * 4).sum::<u64>()
            + self.opt.iter().map(|o| o.len() as u64 * 4).sum::<u64>()
    }
}

/// Snapshot store (the emulated persistent checkpoint target), sharded
/// into per-node [`ShardState`] units.
#[derive(Clone, Debug)]
pub struct CheckpointStore {
    /// mirror[node], identical layout to the cluster shards
    nodes: Vec<ShardState>,
    /// MLP parameters at the last save
    pub mlp: Vec<Vec<f32>>,
    /// training position at the last save that updated the PLS marker
    pub step: u64,
    pub samples: u64,
}

impl PartialEq for CheckpointStore {
    fn eq(&self, other: &Self) -> bool {
        self.nodes == other.nodes
            && self.mlp == other.mlp
            && self.step == other.step
            && self.samples == other.samples
    }
}

impl CheckpointStore {
    /// Initial checkpoint = the cluster's initial state (epoch 0).
    pub fn initial<B: PsControlPlane + ?Sized>(cluster: &B, mlp: Vec<Vec<f32>>) -> Self {
        let nodes = (0..cluster.n_nodes())
            .map(|n| {
                let snap = cluster.snapshot_node(n);
                ShardState::from_parts(snap.shards, snap.opt)
            })
            .collect();
        Self { nodes, mlp, step: 0, samples: 0 }
    }

    /// Assemble a store from already-loaded per-node states (the v2 chain
    /// loader's constructor).
    pub fn from_node_states(
        nodes: Vec<ShardState>,
        mlp: Vec<Vec<f32>>,
        step: u64,
        samples: u64,
    ) -> Self {
        Self { nodes, mlp, step, samples }
    }

    /// The per-node mirror units.
    pub fn node_states(&self) -> &[ShardState] {
        &self.nodes
    }

    /// Mutable access for the publish path (dirty-set export/clear).
    pub(crate) fn node_states_mut(&mut self) -> &mut [ShardState] {
        &mut self.nodes
    }

    /// Forget every node's dirty marks. The incremental-submit contract
    /// of `disk::DiskCheckpointer` (format v2) needs this: a caller
    /// keeping its own store snapshot resets the dirty sets after each
    /// submit so the next submit carries only "changes since then".
    /// (The pipeline/engine clear dirty themselves on publish.)
    pub fn clear_dirty(&mut self) {
        for st in &mut self.nodes {
            st.clear_dirty();
        }
    }

    /// Full checkpoint: mirror every shard + MLP params + position.
    /// (Synchronous path — the coordinator's async equivalent is
    /// [`async_pipeline::CheckpointPipeline::full_save`].) Marks every
    /// node fully dirty: the next incremental publish re-bases it.
    pub fn full_save<B: PsControlPlane + ?Sized>(
        &mut self,
        cluster: &B,
        mlp: Vec<Vec<f32>>,
        step: u64,
        samples: u64,
    ) {
        for n in 0..cluster.n_nodes() {
            let snap = cluster.snapshot_node(n);
            self.apply_node(snap);
        }
        self.mlp = mlp;
        self.step = step;
        self.samples = samples;
    }

    /// Apply one captured node snapshot to the mirror (writer-thread path).
    pub fn apply_node(&mut self, snap: crate::cluster::NodeSnapshot) {
        let node = &mut self.nodes[snap.node];
        node.shards = snap.shards;
        node.opt = snap.opt;
        node.mark_all_dirty();
    }

    /// Priority (partial-content) save: copy only `rows` of `table` into
    /// the mirror. Does NOT move the PLS position marker.
    pub fn save_rows<B: PsDataPlane + ?Sized>(&mut self, cluster: &B, table: usize, rows: &[u32]) {
        let dim = cluster.tables()[table].dim;
        let (data, opt) = cluster.read_rows(table, rows);
        self.apply_rows(table, rows, dim, &data, &opt);
    }

    /// Apply captured row data (`data` in `rows` order, [rows.len() * dim])
    /// to the mirror (writer-thread path). Touched rows become dirty.
    pub fn apply_rows(
        &mut self,
        table: usize,
        rows: &[u32],
        dim: usize,
        data: &[f32],
        opt: &[f32],
    ) {
        let n_nodes = self.nodes.len();
        for (i, &row) in rows.iter().enumerate() {
            let (node, local) = crate::cluster::route_row(row as usize, n_nodes);
            let st = &mut self.nodes[node];
            st.shards[table][local * dim..(local + 1) * dim]
                .copy_from_slice(&data[i * dim..(i + 1) * dim]);
            st.opt[table][local] = opt[i];
            st.mark_row_dirty(table, local);
        }
    }

    /// Save one whole table. Row-at-a-time through `read_rows`, which is
    /// fine for its only callers — the tiny (≤64-row) non-priority tables
    /// of the skewed layout; large tables go through `snapshot_node`.
    pub fn save_table<B: PsDataPlane + ?Sized>(&mut self, cluster: &B, table: usize) {
        let rows: Vec<u32> = (0..cluster.tables()[table].rows as u32).collect();
        self.save_rows(cluster, table, &rows);
    }

    /// Record MLP params + advance the PLS position marker (done at every
    /// interval boundary, for all strategies).
    pub fn mark_position(&mut self, mlp: Vec<Vec<f32>>, step: u64, samples: u64) {
        self.mlp = mlp;
        self.step = step;
        self.samples = samples;
    }

    /// PARTIAL recovery: restore only `node`'s shards; everyone else keeps
    /// their progress.
    pub fn restore_node<B: PsControlPlane + ?Sized>(&self, cluster: &B, node: usize) {
        cluster.load_node(node, &self.nodes[node].shards, &self.nodes[node].opt);
    }

    /// FULL recovery: restore every shard; returns (mlp, step, samples) for
    /// the trainer to rewind to.
    pub fn restore_all<B: PsControlPlane + ?Sized>(&self, cluster: &B) -> (Vec<Vec<f32>>, u64, u64) {
        for n in 0..self.nodes.len() {
            self.restore_node(cluster, n);
        }
        (self.mlp.clone(), self.step, self.samples)
    }

    /// Exact byte length of the v1 file [`CheckpointStore::write_file`]
    /// emits: the 28-byte header (magic + position marker + table/node
    /// counts), every shard/opt/MLP vector's payload AND its 4-byte
    /// length prefix. The PLS cost model sizes saves off this, so it must
    /// match what actually hits disk (asserted by a unit test).
    pub fn size_bytes(&self) -> usize {
        let mut b = 4 + 8 + 8 + 4 + 4; // magic, step, samples, n_nodes, n_tables
        for node in &self.nodes {
            for s in &node.shards {
                b += 4 + s.len() * 4;
            }
            for o in &node.opt {
                b += 4 + o.len() * 4;
            }
        }
        b += 4; // MLP vector count
        for p in &self.mlp {
            b += 4 + p.len() * 4;
        }
        b
    }

    // -- on-disk persistence (format v1: one monolithic file) ----------------

    const MAGIC: u32 = 0x4350_5232; // "CPR2"

    pub fn write_file(&self, path: &Path) -> Result<()> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut f = std::io::BufWriter::new(file);
        w32(&mut f, Self::MAGIC)?;
        w64(&mut f, self.step)?;
        w64(&mut f, self.samples)?;
        w32(&mut f, self.nodes.len() as u32)?;
        w32(&mut f, self.nodes.first().map_or(0, |n| n.shards.len()) as u32)?;
        for node in &self.nodes {
            for shard in &node.shards {
                w32(&mut f, shard.len() as u32)?;
                wf32s(&mut f, shard)?;
            }
        }
        for node in &self.nodes {
            for st in &node.opt {
                w32(&mut f, st.len() as u32)?;
                wf32s(&mut f, st)?;
            }
        }
        w32(&mut f, self.mlp.len() as u32)?;
        for p in &self.mlp {
            w32(&mut f, p.len() as u32)?;
            wf32s(&mut f, p)?;
        }
        // crash-consistency: the data must be durable BEFORE the caller
        // publishes a manifest pointing at it
        f.flush()?;
        let _t = crate::telemetry::span("ckpt_fsync");
        f.get_ref().sync_all().context("fsync checkpoint data")?;
        Ok(())
    }

    pub fn read_file(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("opening {}", path.display()))?,
        );
        if r32(&mut f)? != Self::MAGIC {
            bail!("{} is not a CPR checkpoint", path.display());
        }
        let step = r64(&mut f)?;
        let samples = r64(&mut f)?;
        let n_nodes = r32(&mut f)? as usize;
        let n_tables = r32(&mut f)? as usize;
        let mut shards = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let mut node = Vec::with_capacity(n_tables);
            for _ in 0..n_tables {
                let len = r32(&mut f)? as usize;
                node.push(rf32s(&mut f, len)?);
            }
            shards.push(node);
        }
        let mut opt = Vec::with_capacity(n_nodes);
        for _ in 0..n_nodes {
            let mut node = Vec::with_capacity(n_tables);
            for _ in 0..n_tables {
                let len = r32(&mut f)? as usize;
                node.push(rf32s(&mut f, len)?);
            }
            opt.push(node);
        }
        let n_mlp = r32(&mut f)? as usize;
        let mut mlp = Vec::with_capacity(n_mlp);
        for _ in 0..n_mlp {
            let len = r32(&mut f)? as usize;
            mlp.push(rf32s(&mut f, len)?);
        }
        let nodes = shards
            .into_iter()
            .zip(opt)
            .map(|(s, o)| ShardState::from_parts(s, o))
            .collect();
        Ok(Self { nodes, mlp, step, samples })
    }
}

pub(crate) fn w32<W: Write>(w: &mut W, v: u32) -> Result<()> {
    Ok(w.write_all(&v.to_le_bytes())?)
}

pub(crate) fn w64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    Ok(w.write_all(&v.to_le_bytes())?)
}

/// f32 count per stack chunk of [`wf32s`]/[`rf32s`] (4 KiB of bytes):
/// big enough to amortize the `Write`/`Read` call, small enough to stay
/// comfortably on the stack.
const F32_IO_CHUNK: usize = 1024;

pub(crate) fn wf32s<W: Write>(w: &mut W, v: &[f32]) -> Result<()> {
    // Explicit little-endian serialization in fixed stack chunks. This
    // replaced a `from_raw_parts` byte reinterpretation (PR 9): no
    // unsafe, the on-disk format is now explicitly LE on every host, and
    // the bytes written are identical on the LE hosts the old cast
    // targeted — golden checkpoint digests are unchanged.
    let mut buf = [0u8; F32_IO_CHUNK * 4];
    for chunk in v.chunks(F32_IO_CHUNK) {
        for (i, x) in chunk.iter().enumerate() {
            buf[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf[..chunk.len() * 4])?;
    }
    Ok(())
}

pub(crate) fn r32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn r64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn rf32s<R: Read>(r: &mut R, len: usize) -> Result<Vec<f32>> {
    // mirror of `wf32s`: chunked explicit-LE decode, no byte cast
    let mut v = Vec::with_capacity(len);
    let mut buf = [0u8; F32_IO_CHUNK * 4];
    let mut remaining = len;
    while remaining > 0 {
        let n = remaining.min(F32_IO_CHUNK);
        r.read_exact(&mut buf[..n * 4])?;
        for i in 0..n {
            let b = [buf[i * 4], buf[i * 4 + 1], buf[i * 4 + 2], buf[i * 4 + 3]];
            v.push(f32::from_le_bytes(b));
        }
        remaining -= n;
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ThreadedCluster;
    use crate::embedding::{PsCluster, TableInfo};
    use crate::prop_assert;
    use crate::testing::{forall, gen};

    #[test]
    fn f32_bytes_roundtrip_exact() {
        // crosses the F32_IO_CHUNK boundary and covers non-finite bit
        // patterns; also runs under the Miri CI lane (pure in-memory IO)
        let vals: Vec<f32> = [0.0f32, -0.0, 1.5, f32::NAN, f32::INFINITY,
                              f32::NEG_INFINITY, f32::MIN_POSITIVE, -3.25e-7]
            .into_iter()
            .chain((0..3000).map(|i| i as f32 * 0.37 - 55.0))
            .collect();
        let mut bytes = Vec::new();
        wf32s(&mut bytes, &vals).unwrap();
        assert_eq!(bytes.len(), vals.len() * 4);
        // the format is explicitly little-endian on every host
        assert_eq!(&bytes[..4], &vals[0].to_le_bytes());
        assert_eq!(&bytes[8..12], &1.5f32.to_le_bytes());
        let back = rf32s(&mut bytes.as_slice(), vals.len()).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    fn cluster() -> PsCluster {
        PsCluster::new(
            vec![TableInfo { rows: 50, dim: 4 }, TableInfo { rows: 11, dim: 4 }],
            3,
            9,
        )
    }

    fn perturb(c: &PsCluster, seed: u64) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let idx: Vec<u32> = (0..20)
            .flat_map(|_| vec![rng.below(50) as u32, rng.below(11) as u32])
            .collect();
        let grads: Vec<f32> = (0..20 * 2 * 4).map(|_| rng.f32() - 0.5).collect();
        c.sgd_update(&idx, &grads, 0.5);
    }

    #[test]
    fn full_save_restore_roundtrip() {
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![vec![1.0, 2.0]]);
        perturb(&c, 1);
        store.full_save(&c, vec![vec![3.0, 4.0]], 10, 1280);
        let golden: Vec<f32> = c.shard(0, 0);
        perturb(&c, 2);
        assert_ne!(c.shard(0, 0), golden);
        let (mlp, step, samples) = store.restore_all(&c);
        assert_eq!(c.shard(0, 0), golden);
        assert_eq!(mlp, vec![vec![3.0, 4.0]]);
        assert_eq!((step, samples), (10, 1280));
    }

    #[test]
    fn partial_restore_touches_only_failed_node() {
        let c = cluster();
        let store = CheckpointStore::initial(&c, vec![]);
        perturb(&c, 3);
        let survivor: Vec<f32> = c.shard(1, 0);
        store.restore_node(&c, 0);
        // node 0 back to init, node 1 untouched
        let fresh = cluster();
        assert_eq!(c.shard(0, 0), fresh.shard(0, 0));
        assert_eq!(c.shard(1, 0), survivor);
    }

    #[test]
    fn save_rows_updates_only_those_rows() {
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        perturb(&c, 4);
        let trained_row5: Vec<f32> = {
            let mut v = vec![0.0; 4];
            c.read_row(0, 5, &mut v);
            v
        };
        store.save_rows(&c, 0, &[5]);
        perturb(&c, 5);
        // restore the node that owns row 5 (5 % 3 == 2)
        store.restore_node(&c, 2);
        let mut after = vec![0.0; 4];
        c.read_row(0, 5, &mut after);
        assert_eq!(after, trained_row5, "saved row must come back fresh");
        // a different row on the same node must come back as INIT (stale)
        let fresh = cluster();
        let mut got = vec![0.0; 4];
        let mut want = vec![0.0; 4];
        c.read_row(0, 8, &mut got); // 8 % 3 == 2, same node, not saved
        fresh.read_row(0, 8, &mut want);
        assert_eq!(got, want, "unsaved row must be stale");
    }

    #[test]
    fn save_table_saves_all_its_rows() {
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        perturb(&c, 6);
        store.save_table(&c, 1);
        let golden: Vec<Vec<f32>> =
            (0..3).map(|n| c.shard(n, 1)).collect();
        perturb(&c, 7);
        for n in 0..3 {
            store.restore_node(&c, n);
        }
        for n in 0..3 {
            assert_eq!(c.shard(n, 1), golden[n]);
        }
    }

    #[test]
    fn disk_roundtrip_preserves_everything() {
        let c = cluster();
        perturb(&c, 8);
        let mut store = CheckpointStore::initial(&c, vec![vec![1.5; 7]]);
        store.full_save(&c, vec![vec![2.5; 7]], 42, 5376);
        let dir = std::env::temp_dir().join("cpr_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("store.bin");
        store.write_file(&path).unwrap();
        let back = CheckpointStore::read_file(&path).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.samples, 5376);
        assert_eq!(back.mlp, store.mlp);
        assert_eq!(back, store, "content equality across the disk roundtrip");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn size_bytes_matches_written_file_exactly() {
        // the PLS save-cost estimate sizes checkpoints off size_bytes; it
        // must equal what write_file actually emits (header + length
        // prefixes + payload — previously the mark position, the length
        // prefixes, and the whole optimizer mirror were missing)
        let c = cluster();
        perturb(&c, 20);
        let mut store = CheckpointStore::initial(&c, vec![vec![0.5; 13], vec![]]);
        store.full_save(&c, vec![vec![1.0; 9], vec![2.0; 3]], 7, 896);
        let dir = std::env::temp_dir().join("cpr_ckpt_size");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sized.bin");
        store.write_file(&path).unwrap();
        let on_disk = std::fs::metadata(&path).unwrap().len() as usize;
        assert_eq!(store.size_bytes(), on_disk,
                   "size_bytes must match the emitted file length");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn read_rejects_garbage() {
        let dir = std::env::temp_dir().join("cpr_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(CheckpointStore::read_file(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dirty_tracking_follows_row_and_node_applications() {
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        assert_eq!(store.node_states().iter()
                       .map(ShardState::dirty_row_count).sum::<usize>(),
                   0, "initial mirror is clean");
        perturb(&c, 15);
        store.save_rows(&c, 0, &[5, 8, 2]); // 5,8 → node 2; 2 → node 2? 2%3==2
        let n2 = &store.node_states()[2];
        assert_eq!(n2.dirty_rows(0), vec![0, 1, 2],
                   "locals 5/3=1, 8/3=2, 2/3=0 of node 2");
        assert_eq!(n2.dirty_row_count(), 3);
        assert!(!n2.fully_dirty());
        // a full node application marks everything dirty
        store.apply_node(PsControlPlane::snapshot_node(&c, 1));
        assert!(store.node_states()[1].fully_dirty());
        // clearing resets the delta unit
        store.node_states_mut()[2].clear_dirty();
        assert_eq!(store.node_states()[2].dirty_row_count(), 0);
        assert_eq!(store.node_states()[1].dirty_io_bytes(),
                   store.node_states()[1].content_io_bytes()
                       + 4 * store.node_states()[1].opt()
                             .iter().map(Vec::len).sum::<usize>() as u64,
                   "fully dirty delta = content + one row id per row");
    }

    #[test]
    fn optimizer_state_rides_with_rows() {
        use crate::embedding::EmbOptimizer;
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        let opt = EmbOptimizer::RowAdagrad { eps: 1e-8 };
        // accumulate state on row 5 (node 5 % 3 == 2), checkpoint it
        c.apply_grads(&[5, 2], 1, &[1.0f32; 8], 1.0, opt);
        store.full_save(&c, vec![], 1, 128);
        let (node, local) = c.route(5);
        let saved_acc = c.opt_shard(node, 0)[local];
        // more training, then fail the node and restore
        c.apply_grads(&[5, 2], 1, &[1.0f32; 8], 1.0, opt);
        assert!(c.opt_shard(node, 0)[local] > saved_acc);
        store.restore_node(&c, node);
        assert_eq!(c.opt_shard(node, 0)[local], saved_acc,
                   "optimizer state must revert with the rows");
    }

    #[test]
    fn store_restores_across_backends() {
        // a checkpoint taken on the in-process backend restores onto the
        // threaded backend (and vice versa): routing is part of the trait
        let c = cluster();
        perturb(&c, 12);
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 5, 640);
        let t = ThreadedCluster::new(
            vec![TableInfo { rows: 50, dim: 4 }, TableInfo { rows: 11, dim: 4 }],
            3,
            999, // different seed: state must come fully from the store
        );
        store.restore_all(&t);
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        for table in 0..2 {
            for row in 0..c.tables[table].rows {
                c.read_row(table, row, &mut a);
                PsDataPlane::read_row(&t, table, row, &mut b);
                assert_eq!(a, b, "table {table} row {row}");
            }
        }
    }

    #[test]
    fn property_partial_restore_preserves_survivors() {
        forall(41, 30, |rng| {
            let n_nodes = gen::usize_in(rng, 2, 6);
            let c = PsCluster::new(
                vec![TableInfo { rows: gen::usize_in(rng, 8, 40), dim: 4 }],
                n_nodes,
                rng.next_u64(),
            );
            let mut store = CheckpointStore::initial(&c, vec![]);
            // train a bit, checkpoint, train more, fail a random node
            let rows = c.tables[0].rows;
            let idx: Vec<u32> =
                (0..16).map(|_| rng.below(rows as u64) as u32).collect();
            let grads: Vec<f32> = (0..16 * 4).map(|_| rng.f32()).collect();
            c.sgd_update(&idx, &grads, 0.1);
            store.full_save(&c, vec![], 1, 128);
            c.sgd_update(&idx, &grads, 0.1);
            let victim = rng.usize_below(n_nodes);
            let survivors: Vec<Vec<f32>> = (0..n_nodes)
                .filter(|&n| n != victim)
                .map(|n| c.shard(n, 0))
                .collect();
            store.restore_node(&c, victim);
            let after: Vec<Vec<f32>> = (0..n_nodes)
                .filter(|&n| n != victim)
                .map(|n| c.shard(n, 0))
                .collect();
            prop_assert!(survivors == after, "survivor state changed");
            Ok(())
        });
    }
}
