//! Durable on-disk checkpoint publication.
//!
//! Two formats share this entry point:
//!
//! * **v1** — [`publish`] writes the whole [`CheckpointStore`] into one
//!   monolithic `ckpt-<step>.bin` file and flips the `LATEST` pointer:
//!   1. data is written to a temp file and fsynced
//!      ([`CheckpointStore::write_file`] syncs before returning);
//!   2. the temp file is atomically renamed and the directory is fsynced
//!      (renames are directory metadata — without this the manifest
//!      rename could survive a crash that loses the data one);
//!   3. the `LATEST` manifest (a text pointer; symlinks are not portable)
//!      is written to a temp file, fsynced, atomically renamed over the
//!      old manifest, and the directory is fsynced again.
//! * **v2** — [`super::v2`]: per-node base+delta chains behind a
//!   `MANIFEST`, written in parallel by the writer pool, with chain
//!   compaction and reference-safe GC. Same discipline, sharded files.
//!
//! A crash at any point leaves the previously published checkpoint intact
//! and observable; readers never see a torn file. [`DiskCheckpointer::load_latest`]
//! auto-detects the directory's format (a `MANIFEST` marks v2), so a v1
//! directory keeps loading after the engine switches to v2, and
//! [`DiskCheckpointer::load_latest_node`] restores one node by reading
//! only that node's chain (v2) — the partial-restore read path.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{ensure, Context, Result};

use super::v2::{self, V2Engine};
use super::writer_pool::WriterPool;
use super::{fsync_dir, write_durable, CheckpointOptions, CheckpointStore};
use crate::cluster::NodeSnapshot;
use crate::config::{CkptCodec, CkptFormat};

/// Durably publish `store` into `dir` as format v1 (see module docs for
/// the ordering guarantees), then rotate old checkpoints down to `keep`.
pub fn publish(dir: &Path, store: &CheckpointStore, keep: usize) -> Result<()> {
    let path = dir.join(format!("ckpt-{}.bin", store.step));
    let tmp = dir.join(format!(".ckpt-{}.tmp", store.step));
    {
        let _t = crate::telemetry::span("ckpt_write");
        store.write_file(&tmp)?; // writes + fsyncs the data
    }
    {
        let _t = crate::telemetry::span("ckpt_rename");
        std::fs::rename(&tmp, &path)?; // atomic data publish
    }
    // renames are directory-metadata updates: without a directory fsync
    // the LATEST rename below could become durable while the data rename
    // is lost, leaving a manifest pointing at nothing
    fsync_dir(dir)?;
    // manifest: write-fsync-rename (the shared `write_durable` dance) so
    // LATEST is never torn and only ever points at fully durable data
    write_durable(dir, "LATEST", |w| {
        use std::io::Write;
        Ok(w.write_all(format!("ckpt-{}.bin\n", store.step).as_bytes())?)
    })?;
    fsync_dir(dir)?;
    // a v1 publish reclaims the directory from format v2: readers prefer
    // a MANIFEST, so a stale one left by an earlier v2 run would
    // permanently shadow every newer v1 checkpoint after a format
    // switch-back. Remove it only now that LATEST is durable — and with
    // the manifest gone the chain files are unreadable dead weight (a
    // v2 base set can be the full model's size), so reclaim them too;
    // v1's own gc() only rotates ckpt-*.bin and would leak them forever.
    if dir.join(v2::MANIFEST).exists() {
        reclaim_v2_files(dir);
        fsync_dir(dir).ok();
    }
    gc(dir, keep.max(1))
}

/// Best-effort removal of every v2 artifact in `dir` after a v1 publish
/// reclaimed the directory. Failures are NOT silent: each one is logged
/// and counted (and reported as `ckpt_reclaim_errors` telemetry) — an
/// unremovable chain file is dead weight that can be the full model's
/// size, so the operator needs to hear about it, but it never threatens
/// the already-durable v1 checkpoint, so publication still succeeds.
/// Returns the number of failed removals.
fn reclaim_v2_files(dir: &Path) -> usize {
    let mut errors = 0usize;
    let manifest = dir.join(v2::MANIFEST);
    if let Err(e) = std::fs::remove_file(&manifest) {
        errors += 1;
        eprintln!("[ckpt] failed to remove stale {}: {e}", manifest.display());
    }
    match std::fs::read_dir(dir) {
        Err(e) => {
            errors += 1;
            eprintln!("[ckpt] failed to scan {} for v2 debris: {e}", dir.display());
        }
        Ok(entries) => {
            for e in entries.flatten() {
                let Ok(name) = e.file_name().into_string() else { continue };
                if !v2::is_v2_data_file(&name) {
                    continue;
                }
                if let Err(err) = std::fs::remove_file(e.path()) {
                    errors += 1;
                    eprintln!(
                        "[ckpt] failed to reclaim v2 file {}: {err}",
                        e.path().display()
                    );
                }
            }
        }
    }
    if errors > 0 {
        crate::telemetry::observe("ckpt_reclaim_errors", errors as u64);
    }
    errors
}

enum Msg {
    Write(Box<CheckpointStore>),
    Stop,
}

/// Standalone background checkpoint-to-disk writer (the coordinator now
/// uses the richer `CheckpointPipeline`; this stays as the minimal
/// submit-a-snapshot API and the format-detecting reader).
///
/// With [`CkptFormat::V2`] the worker owns a [`V2Engine`]: each submitted
/// store's **dirty sets** decide what hits disk — a fully-dirty or
/// chain-less node gets a base, a row-dirty node a delta, a clean node
/// nothing — so callers that submit incremental snapshots get
/// incremental publishes (call [`CheckpointStore::clear_dirty`] on your
/// copy after each submit so the next one carries only changes since
/// then). `keep` only applies to v1 rotation; a v2 directory holds
/// exactly one live chain per node (plus nothing unreferenced, by GC).
pub struct DiskCheckpointer {
    dir: PathBuf,
    tx: mpsc::Sender<Msg>,
    /// the worker returns its v2 engine on drain, so a flush/respawn
    /// cycle keeps the chain state (incremental submits stay incremental)
    worker: Option<JoinHandle<Result<Option<V2Engine>>>>,
    keep: usize,
    format: CkptFormat,
    compact_frac: f64,
    codec: CkptCodec,
}

impl DiskCheckpointer {
    /// Build a checkpointer from one options struct — the constructor
    /// everything routes through ([`CheckpointOptions::from_config`] is
    /// the production path). Requires `opts.dir`; `opts.write_delay` is
    /// a pipeline knob and is ignored here.
    pub fn with_options(opts: &CheckpointOptions) -> Result<Self> {
        let Some(dir) = opts.dir.as_deref() else {
            anyhow::bail!("DiskCheckpointer needs a directory (CheckpointOptions::dir)");
        };
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let keep = opts.keep.max(1);
        let (tx, worker) = Self::spawn_worker(
            dir.clone(),
            keep,
            opts.format,
            opts.compact_frac,
            opts.codec,
            None,
        );
        Ok(Self {
            dir,
            tx,
            worker: Some(worker),
            keep,
            format: opts.format,
            compact_frac: opts.compact_frac,
            codec: opts.codec,
        })
    }

    /// A v1 (monolithic-file) checkpointer — the historical default.
    #[deprecated(note = "build a `CheckpointOptions` and call `with_options`")]
    pub fn new(dir: &str, keep: usize) -> Result<Self> {
        Self::with_options(&CheckpointOptions {
            dir: Some(dir.to_string()),
            keep,
            ..CheckpointOptions::default()
        })
    }

    /// A checkpointer publishing in the given format. `compact_frac` is
    /// the v2 chain-compaction threshold (ignored for v1).
    #[deprecated(note = "build a `CheckpointOptions` and call `with_options`")]
    pub fn new_with_format(
        dir: &str,
        keep: usize,
        format: CkptFormat,
        compact_frac: f64,
    ) -> Result<Self> {
        Self::with_options(&CheckpointOptions {
            dir: Some(dir.to_string()),
            keep,
            format,
            compact_frac,
            ..CheckpointOptions::default()
        })
    }

    /// `engine` carries the v2 chain state across a flush's drain/respawn
    /// cycle (None on first spawn, or for v1).
    fn spawn_worker(
        dir: PathBuf,
        keep: usize,
        format: CkptFormat,
        compact_frac: f64,
        codec: CkptCodec,
        engine: Option<V2Engine>,
    ) -> (mpsc::Sender<Msg>, JoinHandle<Result<Option<V2Engine>>>) {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::spawn(move || -> Result<Option<V2Engine>> {
            let mut engine = match (format, engine) {
                (CkptFormat::V1, _) => None,
                (CkptFormat::V2, Some(e)) => Some(e),
                (CkptFormat::V2, None) => Some(V2Engine::open(
                    &dir,
                    WriterPool::for_nodes(usize::MAX),
                    compact_frac,
                    codec,
                )?),
            };
            while let Ok(Msg::Write(mut store)) = rx.recv() {
                match engine.as_mut() {
                    None => publish(&dir, &store, keep)?,
                    Some(e) => {
                        e.publish(&mut store, true, false)?;
                    }
                }
            }
            Ok(engine)
        });
        (tx, worker)
    }

    /// Enqueue a snapshot for writing; returns immediately.
    pub fn submit(&self, snapshot: CheckpointStore) -> Result<()> {
        self.tx
            .send(Msg::Write(Box::new(snapshot)))
            .map_err(|_| anyhow::anyhow!("checkpoint writer thread died"))
    }

    /// Wait for all queued writes to land (checkpoint barrier).
    pub fn flush(&mut self) -> Result<()> {
        // drain by restarting the worker: send Stop, join (recovering the
        // v2 engine so its chains keep extending), respawn
        self.tx.send(Msg::Stop).ok();
        let mut engine = None;
        if let Some(w) = self.worker.take() {
            engine = w.join().map_err(|_| anyhow::anyhow!("writer panicked"))??;
        }
        let (tx, worker) = Self::spawn_worker(
            self.dir.clone(),
            self.keep,
            self.format,
            self.compact_frac,
            self.codec,
            engine,
        );
        self.worker = Some(worker);
        self.tx = tx;
        Ok(())
    }

    /// Load the most recent checkpoint in `dir`, if any. Auto-detects the
    /// format: a `MANIFEST` marks a v2 chain directory, a `LATEST`
    /// pointer the v1 layout — so readers survive a format migration.
    pub fn load_latest(dir: &str) -> Result<Option<CheckpointStore>> {
        let dir_path = Path::new(dir);
        if dir_path.join(v2::MANIFEST).exists() {
            return v2::load_store(dir_path);
        }
        let latest = dir_path.join("LATEST");
        if !latest.exists() {
            return Ok(None);
        }
        let name = std::fs::read_to_string(&latest)?;
        let path = dir_path.join(name.trim());
        Ok(Some(CheckpointStore::read_file(&path)?))
    }

    /// Load ONE node's latest durable state (plus the marker position it
    /// was published under). On a v2 directory this reads only that
    /// node's base+delta chain — the whole point of the sharded layout;
    /// on v1 it falls back to reading the monolithic file and slicing the
    /// node out.
    pub fn load_latest_node(
        dir: &str,
        node: usize,
    ) -> Result<Option<(NodeSnapshot, u64, u64)>> {
        let dir_path = Path::new(dir);
        if dir_path.join(v2::MANIFEST).exists() {
            return Ok(v2::load_node(dir_path, node)?.map(
                |((shards, opt), step, samples)| {
                    (NodeSnapshot { node, shards, opt }, step, samples)
                },
            ));
        }
        match Self::load_latest(dir)? {
            None => Ok(None),
            Some(store) => {
                ensure!(
                    node < store.node_states().len(),
                    "checkpoint covers {} nodes, asked for node {node}",
                    store.node_states().len()
                );
                let st = &store.node_states()[node];
                Ok(Some((
                    NodeSnapshot {
                        node,
                        shards: st.shards().to_vec(),
                        opt: st.opt().to_vec(),
                    },
                    store.step,
                    store.samples,
                )))
            }
        }
    }
}

fn gc(dir: &Path, keep: usize) -> Result<()> {
    let mut ckpts: Vec<(u64, PathBuf)> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            let name = e.file_name().into_string().ok()?;
            let step: u64 = name.strip_prefix("ckpt-")?
                .strip_suffix(".bin")?.parse().ok()?;
            Some((step, e.path()))
        })
        .collect();
    ckpts.sort_by_key(|(s, _)| std::cmp::Reverse(*s));
    for (_, path) in ckpts.into_iter().skip(keep) {
        std::fs::remove_file(path).ok();
    }
    Ok(())
}

impl Drop for DiskCheckpointer {
    fn drop(&mut self) {
        self.tx.send(Msg::Stop).ok();
        if let Some(w) = self.worker.take() {
            w.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{PsCluster, TableInfo};

    fn tmpdir(tag: &str) -> String {
        let d = std::env::temp_dir().join(format!("cpr_disk_{tag}"));
        std::fs::remove_dir_all(&d).ok();
        d.to_str().unwrap().to_string()
    }

    fn opts(dir: &str, keep: usize) -> CheckpointOptions {
        CheckpointOptions {
            dir: Some(dir.to_string()),
            keep,
            ..CheckpointOptions::default()
        }
    }

    fn v2_opts(dir: &str, keep: usize) -> CheckpointOptions {
        CheckpointOptions { format: CkptFormat::V2, ..opts(dir, keep) }
    }

    fn store(step: u64) -> CheckpointStore {
        let c = PsCluster::new(vec![TableInfo { rows: 12, dim: 4 }], 2, 1);
        let mut s = CheckpointStore::initial(&c, vec![vec![step as f32]]);
        s.mark_position(vec![vec![step as f32]], step, step * 128);
        s
    }

    #[test]
    fn writes_and_loads_latest() {
        let dir = tmpdir("a");
        let mut w = DiskCheckpointer::with_options(&opts(&dir, 3)).unwrap();
        w.submit(store(10)).unwrap();
        w.submit(store(20)).unwrap();
        w.flush().unwrap();
        let latest = DiskCheckpointer::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.step, 20);
        assert_eq!(latest.mlp, vec![vec![20.0]]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v2_writes_chains_and_load_latest_autodetects() {
        let dir = tmpdir("v2");
        let mut w = DiskCheckpointer::with_options(&v2_opts(&dir, 3)).unwrap();
        // first submit: fresh dir → bases; second: fully-dirty snapshot
        // (independent full snapshots re-base, like v1 full saves)
        let c = PsCluster::new(vec![TableInfo { rows: 12, dim: 4 }], 2, 1);
        let mut s = CheckpointStore::initial(&c, vec![vec![1.0]]);
        s.full_save(&c, vec![vec![1.0]], 1, 128);
        w.submit(s.clone()).unwrap();
        // a flush must NOT lose the engine's chain state: the next
        // incremental submit still publishes a delta, not a re-base
        w.flush().unwrap();
        // incremental submit: only row 3 dirty relative to the last one
        // (the submitted clone kept its own dirty flags; reset ours to
        // model "changes since the previous submit" — the public half of
        // the incremental-submit contract)
        s.clear_dirty();
        s.save_rows(&c, 0, &[3]);
        s.mark_position(vec![vec![2.0]], 2, 256);
        w.submit(s.clone()).unwrap();
        w.flush().unwrap();
        assert!(Path::new(&dir).join(super::v2::MANIFEST).exists());
        let latest = DiskCheckpointer::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest, s, "v2 chain replay through the auto-detecting loader");
        assert_eq!(latest.step, 2);
        assert_eq!(latest.mlp, vec![vec![2.0]]);
        // row 3 lives on node 1 (3 % 2): its chain gained a delta
        let m = super::v2::read_manifest(Path::new(&dir)).unwrap().unwrap();
        assert_eq!(m.chains[1].deltas.len(), 1);
        assert!(m.chains[0].deltas.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_latest_node_reads_one_chain_on_v2_and_slices_on_v1() {
        // v1 directory
        let dir1 = tmpdir("node_v1");
        let mut w = DiskCheckpointer::with_options(&opts(&dir1, 2)).unwrap();
        w.submit(store(5)).unwrap();
        w.flush().unwrap();
        let (snap, step, samples) =
            DiskCheckpointer::load_latest_node(&dir1, 1).unwrap().unwrap();
        assert_eq!(snap.node, 1);
        assert_eq!((step, samples), (5, 640));
        let full = DiskCheckpointer::load_latest(&dir1).unwrap().unwrap();
        assert_eq!(snap.shards, full.node_states()[1].shards());
        assert!(DiskCheckpointer::load_latest_node(&dir1, 9).is_err(),
                "out-of-range node must be an error, not a panic");
        // v2 directory: corrupt node 0's base; node 1 must still load
        let dir2 = tmpdir("node_v2");
        let mut w2 = DiskCheckpointer::with_options(&v2_opts(&dir2, 2)).unwrap();
        w2.submit(store(7)).unwrap();
        w2.flush().unwrap();
        let m = super::v2::read_manifest(Path::new(&dir2)).unwrap().unwrap();
        let base0 = Path::new(&dir2).join(&m.chains[0].base);
        let bytes = std::fs::read(&base0).unwrap();
        std::fs::write(&base0, &bytes[..bytes.len() / 2]).unwrap();
        let (snap1, _, _) =
            DiskCheckpointer::load_latest_node(&dir2, 1).unwrap().unwrap();
        assert_eq!(snap1.node, 1);
        assert!(DiskCheckpointer::load_latest_node(&dir2, 0).is_err(),
                "node 0's torn chain fails its own load");
        std::fs::remove_dir_all(&dir1).ok();
        std::fs::remove_dir_all(&dir2).ok();
    }

    #[test]
    fn v1_publish_reclaims_a_v2_directory() {
        // switch v2 → v1 on the same dir: the stale MANIFEST must not
        // shadow the newer v1 checkpoint (readers prefer MANIFEST)
        let dir = tmpdir("reclaim");
        let mut w2 = DiskCheckpointer::with_options(&v2_opts(&dir, 2)).unwrap();
        w2.submit(store(3)).unwrap();
        w2.flush().unwrap();
        drop(w2);
        assert!(Path::new(&dir).join(super::v2::MANIFEST).exists());
        let mut w1 = DiskCheckpointer::with_options(&opts(&dir, 2)).unwrap();
        w1.submit(store(9)).unwrap();
        w1.flush().unwrap();
        assert!(!Path::new(&dir).join(super::v2::MANIFEST).exists(),
                "the v1 publish must reclaim the directory");
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok()?.file_name().into_string().ok())
            .filter(|n| super::v2::is_v2_data_file(n))
            .collect();
        assert!(leftovers.is_empty(),
                "orphaned v2 chain files must be reclaimed: {leftovers:?}");
        let latest = DiskCheckpointer::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.step, 9, "the NEWER v1 checkpoint must win");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotation_keeps_only_newest() {
        let dir = tmpdir("b");
        let mut w = DiskCheckpointer::with_options(&opts(&dir, 2)).unwrap();
        for step in [1, 2, 3, 4, 5] {
            w.submit(store(step)).unwrap();
        }
        w.flush().unwrap();
        let files: Vec<String> = std::fs::read_dir(&dir).unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().into_string().unwrap())
            .filter(|n| n.starts_with("ckpt-"))
            .collect();
        assert_eq!(files.len(), 2, "{files:?}");
        assert!(files.contains(&"ckpt-4.bin".to_string()));
        assert!(files.contains(&"ckpt-5.bin".to_string()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_latest_empty_dir_is_none() {
        let dir = tmpdir("c");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(DiskCheckpointer::load_latest(&dir).unwrap().is_none());
        assert!(DiskCheckpointer::load_latest_node(&dir, 0).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn submit_does_not_block_on_io() {
        let dir = tmpdir("d");
        let w = DiskCheckpointer::with_options(&opts(&dir, 2)).unwrap();
        let t0 = std::time::Instant::now();
        for step in 0..20 {
            w.submit(store(step)).unwrap();
        }
        // 20 submits must return near-instantly (writes happen behind)
        assert!(t0.elapsed().as_millis() < 200);
        drop(w); // drains on drop
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_constructor_shims_still_work() {
        // examples and downstream code may still call the positional
        // constructors; they must route through with_options unchanged
        let dir = tmpdir("shim");
        let mut w = DiskCheckpointer::new(&dir, 2).unwrap();
        w.submit(store(4)).unwrap();
        w.flush().unwrap();
        assert_eq!(DiskCheckpointer::load_latest(&dir).unwrap().unwrap().step, 4);
        drop(w);
        std::fs::remove_dir_all(&dir).ok();
        let dir2 = tmpdir("shim2");
        let mut w2 =
            DiskCheckpointer::new_with_format(&dir2, 2, CkptFormat::V2, 0.5).unwrap();
        w2.submit(store(6)).unwrap();
        w2.flush().unwrap();
        assert!(Path::new(&dir2).join(super::v2::MANIFEST).exists());
        drop(w2);
        std::fs::remove_dir_all(&dir2).ok();
    }

    #[test]
    fn with_options_without_a_dir_is_an_error() {
        assert!(DiskCheckpointer::with_options(&CheckpointOptions::default()).is_err(),
                "a disk checkpointer cannot run without a directory");
    }

    #[cfg(unix)]
    #[test]
    fn reclaim_failures_are_counted_not_silent() {
        use std::os::unix::fs::PermissionsExt;
        // a MANIFEST + one chain file in a directory made read-only:
        // every removal fails, and each failure must be COUNTED (the
        // old code swallowed them with `.ok()`)
        let dir = tmpdir("ro");
        std::fs::create_dir_all(&dir).unwrap();
        let p = Path::new(&dir);
        std::fs::write(p.join(super::v2::MANIFEST), "CPR-MANIFEST-V2\nseq 1\n").unwrap();
        std::fs::write(p.join("meta-1.bin"), b"x").unwrap();
        std::fs::set_permissions(p, std::fs::Permissions::from_mode(0o555)).unwrap();
        // unlink permission lives on the directory; root bypasses it —
        // probe first and skip when perms are not enforced (CI is non-root)
        if std::fs::remove_file(p.join("meta-1.bin")).is_ok() {
            std::fs::set_permissions(p, std::fs::Permissions::from_mode(0o755)).unwrap();
            std::fs::remove_dir_all(&dir).ok();
            eprintln!("skipping: privileged process, read-only dir not enforced");
            return;
        }
        let errors = reclaim_v2_files(p);
        assert_eq!(errors, 2,
                   "manifest + chain-file removal failures must both be counted");
        std::fs::set_permissions(p, std::fs::Permissions::from_mode(0o755)).unwrap();
        // writable again: the same reclaim succeeds and reports zero
        assert_eq!(reclaim_v2_files(p), 0);
        assert!(!p.join(super::v2::MANIFEST).exists());
        assert!(!p.join("meta-1.bin").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn publish_leaves_no_temp_files() {
        let dir = tmpdir("e");
        std::fs::create_dir_all(&dir).unwrap();
        publish(Path::new(&dir), &store(7), 2).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir).unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().into_string().unwrap())
            .collect();
        assert!(names.contains(&"ckpt-7.bin".to_string()), "{names:?}");
        assert!(names.contains(&"LATEST".to_string()));
        assert!(!names.iter().any(|n| n.ends_with(".tmp")), "{names:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
