//! Asynchronous on-disk checkpoint writer.
//!
//! Production checkpointing overlaps serialization/IO with training
//! (DeepFreeze, ai-ckpt — paper §7.1); the emulated O_save constant models
//! that cost, but the system should also *really* persist. A
//! [`DiskCheckpointer`] owns a writer thread: `submit` hands it a cloned
//! [`CheckpointStore`] snapshot and returns immediately; the trainer never
//! blocks on IO. Files rotate as `ckpt-<step>.bin` with a `latest` symlink
//! equivalent (a `LATEST` text file — symlinks are not portable), keeping
//! the most recent `keep` checkpoints.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::CheckpointStore;

enum Msg {
    Write(Box<CheckpointStore>),
    Stop,
}

/// Background checkpoint-to-disk writer.
pub struct DiskCheckpointer {
    dir: PathBuf,
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<Result<()>>>,
    keep: usize,
}

impl DiskCheckpointer {
    pub fn new(dir: &str, keep: usize) -> Result<Self> {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let (tx, rx) = mpsc::channel::<Msg>();
        let wdir = dir.clone();
        let keep_n = keep.max(1);
        let worker = std::thread::spawn(move || -> Result<()> {
            while let Ok(Msg::Write(store)) = rx.recv() {
                let path = wdir.join(format!("ckpt-{}.bin", store.step));
                let tmp = wdir.join(format!(".ckpt-{}.tmp", store.step));
                store.write_file(&tmp)?;
                std::fs::rename(&tmp, &path)?; // atomic publish
                std::fs::write(wdir.join("LATEST"),
                               format!("ckpt-{}.bin\n", store.step))?;
                Self::gc(&wdir, keep_n)?;
            }
            Ok(())
        });
        Ok(Self { dir, tx, worker: Some(worker), keep: keep_n })
    }

    /// Enqueue a snapshot for writing; returns immediately.
    pub fn submit(&self, snapshot: CheckpointStore) -> Result<()> {
        self.tx
            .send(Msg::Write(Box::new(snapshot)))
            .map_err(|_| anyhow::anyhow!("checkpoint writer thread died"))
    }

    /// Wait for all queued writes to land (checkpoint barrier).
    pub fn flush(&mut self) -> Result<()> {
        // drain by restarting the worker: send Stop, join, respawn
        self.tx.send(Msg::Stop).ok();
        if let Some(w) = self.worker.take() {
            w.join().map_err(|_| anyhow::anyhow!("writer panicked"))??;
        }
        let (tx, rx) = mpsc::channel::<Msg>();
        let wdir = self.dir.clone();
        let keep_n = self.keep;
        self.worker = Some(std::thread::spawn(move || -> Result<()> {
            while let Ok(Msg::Write(store)) = rx.recv() {
                let path = wdir.join(format!("ckpt-{}.bin", store.step));
                let tmp = wdir.join(format!(".ckpt-{}.tmp", store.step));
                store.write_file(&tmp)?;
                std::fs::rename(&tmp, &path)?;
                std::fs::write(wdir.join("LATEST"),
                               format!("ckpt-{}.bin\n", store.step))?;
                Self::gc(&wdir, keep_n)?;
            }
            Ok(())
        }));
        self.tx = tx;
        Ok(())
    }

    /// Load the most recent checkpoint in `dir`, if any.
    pub fn load_latest(dir: &str) -> Result<Option<CheckpointStore>> {
        let latest = Path::new(dir).join("LATEST");
        if !latest.exists() {
            return Ok(None);
        }
        let name = std::fs::read_to_string(&latest)?;
        let path = Path::new(dir).join(name.trim());
        Ok(Some(CheckpointStore::read_file(&path)?))
    }

    fn gc(dir: &Path, keep: usize) -> Result<()> {
        let mut ckpts: Vec<(u64, PathBuf)> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                let step: u64 = name.strip_prefix("ckpt-")?
                    .strip_suffix(".bin")?.parse().ok()?;
                Some((step, e.path()))
            })
            .collect();
        ckpts.sort_by_key(|(s, _)| std::cmp::Reverse(*s));
        for (_, path) in ckpts.into_iter().skip(keep) {
            std::fs::remove_file(path).ok();
        }
        Ok(())
    }
}

impl Drop for DiskCheckpointer {
    fn drop(&mut self) {
        self.tx.send(Msg::Stop).ok();
        if let Some(w) = self.worker.take() {
            w.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{PsCluster, TableInfo};

    fn tmpdir(tag: &str) -> String {
        let d = std::env::temp_dir().join(format!("cpr_disk_{tag}"));
        std::fs::remove_dir_all(&d).ok();
        d.to_str().unwrap().to_string()
    }

    fn store(step: u64) -> CheckpointStore {
        let c = PsCluster::new(vec![TableInfo { rows: 12, dim: 4 }], 2, 1);
        let mut s = CheckpointStore::initial(&c, vec![vec![step as f32]]);
        s.mark_position(vec![vec![step as f32]], step, step * 128);
        s
    }

    #[test]
    fn writes_and_loads_latest() {
        let dir = tmpdir("a");
        let mut w = DiskCheckpointer::new(&dir, 3).unwrap();
        w.submit(store(10)).unwrap();
        w.submit(store(20)).unwrap();
        w.flush().unwrap();
        let latest = DiskCheckpointer::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.step, 20);
        assert_eq!(latest.mlp, vec![vec![20.0]]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotation_keeps_only_newest() {
        let dir = tmpdir("b");
        let mut w = DiskCheckpointer::new(&dir, 2).unwrap();
        for step in [1, 2, 3, 4, 5] {
            w.submit(store(step)).unwrap();
        }
        w.flush().unwrap();
        let files: Vec<String> = std::fs::read_dir(&dir).unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().into_string().unwrap())
            .filter(|n| n.starts_with("ckpt-"))
            .collect();
        assert_eq!(files.len(), 2, "{files:?}");
        assert!(files.contains(&"ckpt-4.bin".to_string()));
        assert!(files.contains(&"ckpt-5.bin".to_string()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_latest_empty_dir_is_none() {
        let dir = tmpdir("c");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(DiskCheckpointer::load_latest(&dir).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn submit_does_not_block_on_io() {
        let dir = tmpdir("d");
        let w = DiskCheckpointer::new(&dir, 2).unwrap();
        let t0 = std::time::Instant::now();
        for step in 0..20 {
            w.submit(store(step)).unwrap();
        }
        // 20 submits must return near-instantly (writes happen behind)
        assert!(t0.elapsed().as_millis() < 200);
        drop(w); // drains on drop
        std::fs::remove_dir_all(&dir).ok();
    }
}
