//! Durable on-disk checkpoint publication.
//!
//! [`publish`] is the single write path (used by the asynchronous
//! [`super::async_pipeline::CheckpointPipeline`] writer and by the
//! standalone [`DiskCheckpointer`]). It enforces the crash-consistency
//! rule: **a checkpoint is only published after the writer thread fsyncs
//! the manifest** —
//!
//! 1. data is written to a temp file and fsynced
//!    ([`CheckpointStore::write_file`] syncs before returning);
//! 2. the temp file is atomically renamed to `ckpt-<step>.bin` and the
//!    directory is fsynced (renames are directory metadata — without this
//!    the manifest rename could survive a crash that loses the data one);
//! 3. the `LATEST` manifest (a text pointer; symlinks are not portable) is
//!    written to a temp file, fsynced, atomically renamed over the old
//!    manifest, and the directory is fsynced again.
//!
//! A crash at any point leaves the previously published checkpoint intact
//! and observable; readers never see a torn file. Files rotate, keeping
//! the most recent `keep` checkpoints.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::CheckpointStore;

/// Durably publish `store` into `dir` (see module docs for the ordering
/// guarantees), then rotate old checkpoints down to `keep`.
pub fn publish(dir: &Path, store: &CheckpointStore, keep: usize) -> Result<()> {
    let path = dir.join(format!("ckpt-{}.bin", store.step));
    let tmp = dir.join(format!(".ckpt-{}.tmp", store.step));
    store.write_file(&tmp)?; // writes + fsyncs the data
    std::fs::rename(&tmp, &path)?; // atomic data publish
    // renames are directory-metadata updates: without a directory fsync
    // the LATEST rename below could become durable while the data rename
    // is lost, leaving a manifest pointing at nothing
    fsync_dir(dir)?;
    // manifest: write-fsync-rename so LATEST is never torn and only ever
    // points at fully durable data
    let latest_tmp = dir.join(".LATEST.tmp");
    {
        let mut f = std::fs::File::create(&latest_tmp)
            .with_context(|| format!("creating {}", latest_tmp.display()))?;
        use std::io::Write;
        f.write_all(format!("ckpt-{}.bin\n", store.step).as_bytes())?;
        f.sync_all().context("fsync LATEST manifest")?;
    }
    std::fs::rename(&latest_tmp, dir.join("LATEST"))?;
    fsync_dir(dir)?;
    gc(dir, keep.max(1))
}

fn fsync_dir(dir: &Path) -> Result<()> {
    std::fs::File::open(dir)
        .and_then(|d| d.sync_all())
        .with_context(|| format!("fsync checkpoint dir {}", dir.display()))
}

enum Msg {
    Write(Box<CheckpointStore>),
    Stop,
}

/// Standalone background checkpoint-to-disk writer (the coordinator now
/// uses the richer `CheckpointPipeline`; this stays as the minimal
/// submit-a-snapshot API and the `load_latest` reader).
pub struct DiskCheckpointer {
    dir: PathBuf,
    tx: mpsc::Sender<Msg>,
    worker: Option<JoinHandle<Result<()>>>,
    keep: usize,
}

impl DiskCheckpointer {
    pub fn new(dir: &str, keep: usize) -> Result<Self> {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let keep_n = keep.max(1);
        let (tx, worker) = Self::spawn_worker(dir.clone(), keep_n);
        Ok(Self { dir, tx, worker: Some(worker), keep: keep_n })
    }

    fn spawn_worker(
        dir: PathBuf,
        keep: usize,
    ) -> (mpsc::Sender<Msg>, JoinHandle<Result<()>>) {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = std::thread::spawn(move || -> Result<()> {
            while let Ok(Msg::Write(store)) = rx.recv() {
                publish(&dir, &store, keep)?;
            }
            Ok(())
        });
        (tx, worker)
    }

    /// Enqueue a snapshot for writing; returns immediately.
    pub fn submit(&self, snapshot: CheckpointStore) -> Result<()> {
        self.tx
            .send(Msg::Write(Box::new(snapshot)))
            .map_err(|_| anyhow::anyhow!("checkpoint writer thread died"))
    }

    /// Wait for all queued writes to land (checkpoint barrier).
    pub fn flush(&mut self) -> Result<()> {
        // drain by restarting the worker: send Stop, join, respawn
        self.tx.send(Msg::Stop).ok();
        if let Some(w) = self.worker.take() {
            w.join().map_err(|_| anyhow::anyhow!("writer panicked"))??;
        }
        let (tx, worker) = Self::spawn_worker(self.dir.clone(), self.keep);
        self.worker = Some(worker);
        self.tx = tx;
        Ok(())
    }

    /// Load the most recent checkpoint in `dir`, if any.
    pub fn load_latest(dir: &str) -> Result<Option<CheckpointStore>> {
        let latest = Path::new(dir).join("LATEST");
        if !latest.exists() {
            return Ok(None);
        }
        let name = std::fs::read_to_string(&latest)?;
        let path = Path::new(dir).join(name.trim());
        Ok(Some(CheckpointStore::read_file(&path)?))
    }
}

fn gc(dir: &Path, keep: usize) -> Result<()> {
    let mut ckpts: Vec<(u64, PathBuf)> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            let name = e.file_name().into_string().ok()?;
            let step: u64 = name.strip_prefix("ckpt-")?
                .strip_suffix(".bin")?.parse().ok()?;
            Some((step, e.path()))
        })
        .collect();
    ckpts.sort_by_key(|(s, _)| std::cmp::Reverse(*s));
    for (_, path) in ckpts.into_iter().skip(keep) {
        std::fs::remove_file(path).ok();
    }
    Ok(())
}

impl Drop for DiskCheckpointer {
    fn drop(&mut self) {
        self.tx.send(Msg::Stop).ok();
        if let Some(w) = self.worker.take() {
            w.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{PsCluster, TableInfo};

    fn tmpdir(tag: &str) -> String {
        let d = std::env::temp_dir().join(format!("cpr_disk_{tag}"));
        std::fs::remove_dir_all(&d).ok();
        d.to_str().unwrap().to_string()
    }

    fn store(step: u64) -> CheckpointStore {
        let c = PsCluster::new(vec![TableInfo { rows: 12, dim: 4 }], 2, 1);
        let mut s = CheckpointStore::initial(&c, vec![vec![step as f32]]);
        s.mark_position(vec![vec![step as f32]], step, step * 128);
        s
    }

    #[test]
    fn writes_and_loads_latest() {
        let dir = tmpdir("a");
        let mut w = DiskCheckpointer::new(&dir, 3).unwrap();
        w.submit(store(10)).unwrap();
        w.submit(store(20)).unwrap();
        w.flush().unwrap();
        let latest = DiskCheckpointer::load_latest(&dir).unwrap().unwrap();
        assert_eq!(latest.step, 20);
        assert_eq!(latest.mlp, vec![vec![20.0]]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotation_keeps_only_newest() {
        let dir = tmpdir("b");
        let mut w = DiskCheckpointer::new(&dir, 2).unwrap();
        for step in [1, 2, 3, 4, 5] {
            w.submit(store(step)).unwrap();
        }
        w.flush().unwrap();
        let files: Vec<String> = std::fs::read_dir(&dir).unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().into_string().unwrap())
            .filter(|n| n.starts_with("ckpt-"))
            .collect();
        assert_eq!(files.len(), 2, "{files:?}");
        assert!(files.contains(&"ckpt-4.bin".to_string()));
        assert!(files.contains(&"ckpt-5.bin".to_string()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_latest_empty_dir_is_none() {
        let dir = tmpdir("c");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(DiskCheckpointer::load_latest(&dir).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn submit_does_not_block_on_io() {
        let dir = tmpdir("d");
        let w = DiskCheckpointer::new(&dir, 2).unwrap();
        let t0 = std::time::Instant::now();
        for step in 0..20 {
            w.submit(store(step)).unwrap();
        }
        // 20 submits must return near-instantly (writes happen behind)
        assert!(t0.elapsed().as_millis() < 200);
        drop(w); // drains on drop
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn publish_leaves_no_temp_files() {
        let dir = tmpdir("e");
        std::fs::create_dir_all(&dir).unwrap();
        publish(Path::new(&dir), &store(7), 2).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir).unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().into_string().unwrap())
            .collect();
        assert!(names.contains(&"ckpt-7.bin".to_string()), "{names:?}");
        assert!(names.contains(&"LATEST".to_string()));
        assert!(!names.iter().any(|n| n.ends_with(".tmp")), "{names:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
