//! Checkpoint payload codecs (ISSUE 7: quantized + compressed
//! checkpoints, Check-N-Run style).
//!
//! A [`Codec`] turns one logical f32 payload — a base shard, a delta
//! table's packed rows, or optimizer / dense state — into bytes and
//! back. Codecs are split by payload class:
//!
//! * [`Payload::Rows`] is embedding-row content and MAY be lossy: the
//!   quantizers (`q8`/`q4`) store per-chunk `min`/`scale` headers plus
//!   one fixed-width code per value, bounding the absolute
//!   reconstruction error by `chunk_range / (2·levels)`.
//! * [`Payload::State`] is optimizer state and dense (MLP) parameters
//!   and MUST round-trip bit-exactly — Check-N-Run keeps optimizer
//!   state at full precision because its dynamic range defeats uniform
//!   quantization. The quantizers fall back to byte-RLE'd raw fp32
//!   here; `rle` and `none` are lossless for both classes.
//!
//! Codecs are stateless: [`codec`] hands out `'static` instances so
//! the v2 engine can capture one inside each [`super::writer_pool`]
//! write job and encode per-node files in parallel. File framing
//! (magics, per-blob lengths and FNV-1a checksums) lives in
//! [`super::v2`]; this module only maps `f32`s ⇄ bytes.

use super::CkptError;
use crate::config::CkptCodec;

/// Values per quantization chunk. Each chunk carries an 8-byte
/// `min`/`scale` header, so the header overhead is 8/`CHUNK` bytes per
/// value — at 256 that is ~0.8% of the raw fp32 size, small enough to
/// keep `q8` delta publishes under ~30% of fp32 (the ISSUE 7
/// acceptance bar) while chunk ranges stay local enough for tight
/// error bounds.
pub const QUANT_CHUNK: usize = 256;

/// Which kind of payload a blob holds; see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Payload {
    /// Embedding-row values — lossy encodings allowed.
    Rows,
    /// Optimizer state / dense params — must round-trip bit-exactly.
    State,
}

/// One checkpoint payload codec. Implementations are stateless and
/// shared (`Send + Sync`) so write jobs on the pool can encode
/// concurrently.
pub trait Codec: Send + Sync {
    /// The config name this codec registers under.
    fn kind(&self) -> CkptCodec;

    /// Encode one payload into a self-contained blob.
    fn encode(&self, class: Payload, vals: &[f32]) -> Vec<u8>;

    /// Decode a blob produced by [`Codec::encode`] back into exactly
    /// `n` values. `n` comes from the file framing, not the blob.
    fn decode(&self, class: Payload, bytes: &[u8], n: usize) -> Result<Vec<f32>, CkptError>;

    /// Expected encoded-size : raw-size ratio for embedding-dominated
    /// checkpoint content. The policy engine and `cpr plan` scale the
    /// bandwidth-derived save cost by this, so the PLS planner narrows
    /// intervals when checkpoints get cheaper; actual file sizes (what
    /// `bytes_per_publish` reports) come from the written files.
    fn estimated_ratio(&self) -> f64;
}

/// Look up the `'static` codec instance for a config kind.
pub fn codec(kind: CkptCodec) -> &'static dyn Codec {
    match kind {
        CkptCodec::None => &NoneCodec,
        CkptCodec::Q8 => &Quant::<255>,
        CkptCodec::Q4 => &Quant::<15>,
        CkptCodec::Rle => &RleCodec,
    }
}

/// [`Codec::estimated_ratio`] by config kind (planner convenience).
pub fn estimated_ratio(kind: CkptCodec) -> f64 {
    codec(kind).estimated_ratio()
}

/// Round-trip row values through `kind`, in place. This is what a
/// restore from an encoded checkpoint would reconstruct: the async
/// pipeline applies it to embedding rows handed back to recovery so
/// training under a lossy codec sees checkpoint-fidelity values even
/// though the mirror itself stays fp32. A no-op for lossless codecs.
pub fn roundtrip_rows(kind: CkptCodec, vals: &mut Vec<f32>) {
    if kind == CkptCodec::None || kind == CkptCodec::Rle {
        return;
    }
    let c = codec(kind);
    let blob = c.encode(Payload::Rows, vals);
    *vals = c
        .decode(Payload::Rows, &blob, vals.len())
        .expect("in-memory codec round-trip cannot fail");
}

/// FNV-1a over a blob — the per-blob checksum the v2 framing appends
/// to encoded payloads (raw fp32 blobs are covered by their length
/// alone, exactly as in format v2 before codecs existed).
pub fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

fn f32s_to_le(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn le_to_f32s(bytes: &[u8], n: usize, what: &str) -> Result<Vec<f32>, CkptError> {
    if bytes.len() != n * 4 {
        return Err(CkptError::Truncated {
            what: format!("{what}: {} bytes for {n} f32 values", bytes.len()),
        });
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

// ---------------------------------------------------------------------------
// none: raw little-endian fp32 (the pre-codec v2 byte layout)
// ---------------------------------------------------------------------------

struct NoneCodec;

impl Codec for NoneCodec {
    fn kind(&self) -> CkptCodec {
        CkptCodec::None
    }
    fn encode(&self, _class: Payload, vals: &[f32]) -> Vec<u8> {
        f32s_to_le(vals)
    }
    fn decode(&self, _class: Payload, bytes: &[u8], n: usize) -> Result<Vec<f32>, CkptError> {
        le_to_f32s(bytes, n, "raw fp32 blob")
    }
    fn estimated_ratio(&self) -> f64 {
        1.0
    }
}

// ---------------------------------------------------------------------------
// q8 / q4: per-chunk uniform quantization (Check-N-Run style)
// ---------------------------------------------------------------------------

/// `LEVELS` is the maximum code value: 255 for 8-bit, 15 for 4-bit
/// (two codes packed per byte, low nibble first).
struct Quant<const LEVELS: u32>;

impl<const LEVELS: u32> Quant<LEVELS> {
    const PACKED: bool = LEVELS < 16;

    fn encode_rows(vals: &[f32]) -> Vec<u8> {
        let chunks = vals.len().div_ceil(QUANT_CHUNK);
        let mut out = Vec::with_capacity(chunks * 8 + vals.len());
        for chunk in vals.chunks(QUANT_CHUNK) {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &v in chunk {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            // degenerate chunks (all equal, or non-finite garbage)
            // collapse to scale 0: every code decodes to `lo`
            if !(lo.is_finite() && hi.is_finite()) {
                lo = 0.0;
                hi = 0.0;
            }
            let scale = if hi > lo { (hi - lo) / LEVELS as f32 } else { 0.0 };
            out.extend_from_slice(&lo.to_le_bytes());
            out.extend_from_slice(&scale.to_le_bytes());
            let code = |v: f32| -> u8 {
                if scale == 0.0 {
                    0
                } else {
                    (((v - lo) / scale).round() as u32).min(LEVELS) as u8
                }
            };
            if Self::PACKED {
                for pair in chunk.chunks(2) {
                    let a = code(pair[0]);
                    let b = if pair.len() == 2 { code(pair[1]) } else { 0 };
                    out.push(a | (b << 4));
                }
            } else {
                out.extend(chunk.iter().map(|&v| code(v)));
            }
        }
        out
    }

    fn decode_rows(bytes: &[u8], n: usize) -> Result<Vec<f32>, CkptError> {
        let mut out = Vec::with_capacity(n);
        let mut at = 0usize;
        while out.len() < n {
            let take = (n - out.len()).min(QUANT_CHUNK);
            let body = if Self::PACKED { take.div_ceil(2) } else { take };
            let end = at + 8 + body;
            if end > bytes.len() {
                return Err(CkptError::Truncated {
                    what: format!(
                        "quantized blob: {} bytes, need {end} for {n} values",
                        bytes.len()
                    ),
                });
            }
            let lo = f32::from_le_bytes(bytes[at..at + 4].try_into().unwrap());
            let scale = f32::from_le_bytes(bytes[at + 4..at + 8].try_into().unwrap());
            let codes = &bytes[at + 8..end];
            if Self::PACKED {
                for i in 0..take {
                    let byte = codes[i / 2];
                    let c = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                    out.push(lo + c as f32 * scale);
                }
            } else {
                out.extend(codes.iter().map(|&c| lo + c as f32 * scale));
            }
            at = end;
        }
        if at != bytes.len() {
            return Err(CkptError::CodecMismatch {
                what: format!(
                    "quantized blob has {} trailing bytes after {n} values",
                    bytes.len() - at
                ),
            });
        }
        Ok(out)
    }
}

impl<const LEVELS: u32> Codec for Quant<LEVELS> {
    fn kind(&self) -> CkptCodec {
        if Self::PACKED {
            CkptCodec::Q4
        } else {
            CkptCodec::Q8
        }
    }
    fn encode(&self, class: Payload, vals: &[f32]) -> Vec<u8> {
        match class {
            Payload::Rows => Self::encode_rows(vals),
            // fp32 fallback for optimizer / dense state, byte-RLE'd so
            // the (often sparse) accumulators still shrink losslessly
            Payload::State => rle_encode(&f32s_to_le(vals)),
        }
    }
    fn decode(&self, class: Payload, bytes: &[u8], n: usize) -> Result<Vec<f32>, CkptError> {
        match class {
            Payload::Rows => Self::decode_rows(bytes, n),
            Payload::State => le_to_f32s(&rle_decode(bytes)?, n, "quantized state blob"),
        }
    }
    fn estimated_ratio(&self) -> f64 {
        // per value: header 8/CHUNK + code bytes, against 4 raw bytes;
        // the (dim+1)-th optimizer value per row stays ~fp32
        let code = if Self::PACKED { 0.5 } else { 1.0 };
        let per_val = (code + 8.0 / QUANT_CHUNK as f64) / 4.0;
        // embedding dims dominate rows (dim ≥ 8 everywhere we run), so
        // weight the fp32 state tail at ~1/16 of the content
        per_val * (15.0 / 16.0) + 1.0 / 16.0
    }
}

// ---------------------------------------------------------------------------
// rle: lossless byte-level run-length coding (PackBits framing)
// ---------------------------------------------------------------------------

struct RleCodec;

impl Codec for RleCodec {
    fn kind(&self) -> CkptCodec {
        CkptCodec::Rle
    }
    fn encode(&self, _class: Payload, vals: &[f32]) -> Vec<u8> {
        rle_encode(&f32s_to_le(vals))
    }
    fn decode(&self, _class: Payload, bytes: &[u8], n: usize) -> Result<Vec<f32>, CkptError> {
        le_to_f32s(&rle_decode(bytes)?, n, "rle blob")
    }
    fn estimated_ratio(&self) -> f64 {
        // lossless and data-dependent; fresh optimizer state and cold
        // rows crush, trained embeddings barely move — stay conservative
        0.9
    }
}

/// PackBits-style byte RLE: a control byte `c ≤ 127` is followed by
/// `c + 1` literal bytes; `c ≥ 128` repeats the next byte `c - 126`
/// times (runs of 2..=129).
pub(crate) fn rle_encode(bytes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(bytes.len() / 4 + 16);
    let mut i = 0usize;
    while i < bytes.len() {
        // measure the run starting here
        let b = bytes[i];
        let mut run = 1usize;
        while run < 129 && i + run < bytes.len() && bytes[i + run] == b {
            run += 1;
        }
        if run >= 2 {
            out.push((run + 126) as u8);
            out.push(b);
            i += run;
            continue;
        }
        // literal stretch: scan until a run of ≥ 3 starts (a 2-run is
        // cheaper kept literal than breaking the block) or 128 bytes
        let start = i;
        i += 1;
        while i < bytes.len() && i - start < 128 {
            let b = bytes[i];
            let mut run = 1usize;
            while run < 3 && i + run < bytes.len() && bytes[i + run] == b {
                run += 1;
            }
            if run >= 3 {
                break;
            }
            i += 1;
        }
        out.push((i - start - 1) as u8);
        out.extend_from_slice(&bytes[start..i]);
    }
    out
}

pub(crate) fn rle_decode(bytes: &[u8]) -> Result<Vec<u8>, CkptError> {
    let mut out = Vec::with_capacity(bytes.len() * 2);
    let mut i = 0usize;
    let truncated = |need: usize| CkptError::Truncated {
        what: format!("rle blob: control at {i} needs {need} more bytes"),
    };
    while i < bytes.len() {
        let c = bytes[i];
        if c <= 127 {
            let len = c as usize + 1;
            if i + 1 + len > bytes.len() {
                return Err(truncated(len));
            }
            out.extend_from_slice(&bytes[i + 1..i + 1 + len]);
            i += 1 + len;
        } else {
            if i + 1 >= bytes.len() {
                return Err(truncated(1));
            }
            out.resize(out.len() + (c as usize - 126), bytes[i + 1]);
            i += 2;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, gen};

    const ALL: [CkptCodec; 4] = [CkptCodec::None, CkptCodec::Q8, CkptCodec::Q4, CkptCodec::Rle];

    fn random_vals(rng: &mut crate::util::rng::Rng, n: usize) -> Vec<f32> {
        // mix of smooth values, exact zeros, and repeated constants —
        // the shapes optimizer state and embedding rows actually take
        (0..n)
            .map(|_| match rng.usize_below(4) {
                0 => 0.0,
                1 => 0.25,
                _ => rng.f32() * 2.0 - 1.0,
            })
            .collect()
    }

    /// Per-chunk error bound for a `levels`-code uniform quantizer:
    /// half a quantization step, plus float-rounding slack.
    fn quant_bound(chunk: &[f32], levels: f32) -> f32 {
        let lo = chunk.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let range = hi - lo;
        range / (2.0 * levels) + range.abs() * 1e-5 + 1e-6
    }

    #[test]
    fn lossless_codecs_round_trip_bit_exactly() {
        forall(0xC0DE, 64, |rng| {
            let n = gen::usize_in(rng, 0, 2_000);
            let vals = random_vals(rng, n);
            for kind in [CkptCodec::None, CkptCodec::Rle] {
                for class in [Payload::Rows, Payload::State] {
                    let c = codec(kind);
                    let got = c
                        .decode(class, &c.encode(class, &vals), n)
                        .map_err(|e| format!("{kind:?}/{class:?}: {e}"))?;
                    crate::prop_assert!(
                        got.iter().zip(&vals).all(|(a, b)| a.to_bits() == b.to_bits()),
                        "{kind:?}/{class:?}: lossless codec changed values"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quantizers_bound_max_abs_error_per_chunk() {
        forall(0x84A, 64, |rng| {
            let n = gen::usize_in(rng, 1, 3 * QUANT_CHUNK + 7);
            let vals = random_vals(rng, n);
            for (kind, levels) in [(CkptCodec::Q8, 255.0f32), (CkptCodec::Q4, 15.0f32)] {
                let c = codec(kind);
                let got = c
                    .decode(Payload::Rows, &c.encode(Payload::Rows, &vals), n)
                    .map_err(|e| format!("{kind:?}: {e}"))?;
                crate::prop_assert!(got.len() == n, "{kind:?}: length changed");
                for (ci, chunk) in vals.chunks(QUANT_CHUNK).enumerate() {
                    let bound = quant_bound(chunk, levels);
                    for (i, (&a, &b)) in
                        chunk.iter().zip(&got[ci * QUANT_CHUNK..]).enumerate()
                    {
                        crate::prop_assert!(
                            (a - b).abs() <= bound,
                            "{kind:?}: chunk {ci} value {i}: |{a} - {b}| > {bound}"
                        );
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn quantizer_state_payloads_stay_fp32_exact() {
        forall(0xF32, 48, |rng| {
            let n = gen::usize_in(rng, 0, 1_000);
            let vals = random_vals(rng, n);
            for kind in [CkptCodec::Q8, CkptCodec::Q4] {
                let c = codec(kind);
                let got = c
                    .decode(Payload::State, &c.encode(Payload::State, &vals), n)
                    .map_err(|e| format!("{kind:?}: {e}"))?;
                crate::prop_assert!(
                    got.iter().zip(&vals).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{kind:?}: optimizer-state fallback must be lossless"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn constant_and_empty_payloads_round_trip() {
        for kind in ALL {
            let c = codec(kind);
            for vals in [vec![], vec![0.0f32; 700], vec![-3.25f32; 17]] {
                let got = c
                    .decode(Payload::Rows, &c.encode(Payload::Rows, &vals), vals.len())
                    .unwrap();
                assert_eq!(got, vals, "{kind:?}: degenerate payload");
            }
        }
    }

    #[test]
    fn truncated_blobs_are_typed_errors_not_panics() {
        let vals: Vec<f32> = (0..600).map(|i| i as f32 * 0.125).collect();
        for kind in ALL {
            let c = codec(kind);
            let blob = c.encode(Payload::Rows, &vals);
            let err = c
                .decode(Payload::Rows, &blob[..blob.len() - 3], vals.len())
                .unwrap_err();
            assert!(
                matches!(err, CkptError::Truncated { .. } | CkptError::CodecMismatch { .. }),
                "{kind:?}: {err}"
            );
        }
    }

    #[test]
    fn rle_crushes_zero_runs_and_survives_incompressible_bytes() {
        let zeros = vec![0u8; 4096];
        let enc = rle_encode(&zeros);
        assert!(enc.len() < zeros.len() / 50, "zero run barely shrank: {}", enc.len());
        assert_eq!(rle_decode(&enc).unwrap(), zeros);
        let noise: Vec<u8> = (0..4096u32)
            .map(|i| (i.wrapping_mul(2654435761) >> 13) as u8)
            .collect();
        let enc = rle_encode(&noise);
        assert!(enc.len() <= noise.len() + noise.len() / 64 + 2,
                "literal framing overhead too high: {}", enc.len());
        assert_eq!(rle_decode(&enc).unwrap(), noise);
    }

    #[test]
    fn quantizer_shrinks_row_payloads_by_its_advertised_ratio() {
        // dim-16 rows, the checkpoint_io bench shape: q8 must land
        // under ~30% of raw fp32 (the ISSUE 7 acceptance bar), q4 lower
        let mut rng = crate::util::rng::Rng::new(7);
        let vals: Vec<f32> = (0..16 * 4096).map(|_| rng.f32() - 0.5).collect();
        let raw = vals.len() * 4;
        let q8 = codec(CkptCodec::Q8).encode(Payload::Rows, &vals).len();
        let q4 = codec(CkptCodec::Q4).encode(Payload::Rows, &vals).len();
        assert!((q8 as f64) < raw as f64 * 0.30, "q8: {q8} / raw {raw}");
        assert!((q4 as f64) < raw as f64 * 0.16, "q4: {q4} / raw {raw}");
        assert!(estimated_ratio(CkptCodec::Q8) < 0.31);
        assert!(estimated_ratio(CkptCodec::Q4) < 0.20);
        assert_eq!(estimated_ratio(CkptCodec::None), 1.0);
    }

    #[test]
    fn roundtrip_rows_is_identity_for_lossless_and_bounded_for_lossy() {
        let vals: Vec<f32> = (0..500).map(|i| (i as f32).sin()).collect();
        let mut kept = vals.clone();
        roundtrip_rows(CkptCodec::None, &mut kept);
        assert_eq!(kept, vals);
        roundtrip_rows(CkptCodec::Rle, &mut kept);
        assert_eq!(kept, vals);
        let mut q = vals.clone();
        roundtrip_rows(CkptCodec::Q8, &mut q);
        assert_ne!(q, vals, "q8 round-trip should actually quantize");
        for (ci, chunk) in vals.chunks(QUANT_CHUNK).enumerate() {
            let bound = quant_bound(chunk, 255.0);
            for (a, b) in chunk.iter().zip(&q[ci * QUANT_CHUNK..]) {
                assert!((a - b).abs() <= bound);
            }
        }
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        assert_eq!(fnv1a(b""), 0x811c_9dc5);
        assert_eq!(fnv1a(b"a"), 0xe40c_292c);
        assert_eq!(fnv1a(b"foobar"), 0xbf9c_f968);
    }
}
