//! Checkpoint **format v2**: per-node base + delta chains behind a
//! `MANIFEST`.
//!
//! Format v1 ([`super::disk::publish`]) rewrites the *whole*
//! [`CheckpointStore`] into one monolithic file on every position-marking
//! save — a CPR-MFU minor save that refreshed the top-k rows of each
//! table still pays for every node's full dense mirror, and restoring one
//! failed node reads everything. v2 makes the durable layout match the
//! sharded mirror (Check-N-Run-style differential checkpointing, ECRM's
//! per-shard durability unit):
//!
//! ```text
//! dir/
//!   MANIFEST                  text index: the LIVE chain per node + meta
//!   meta-<seq>.bin            position marker (step/samples) + MLP params
//!   node<N>-base-<seq>.bin    full state of node N (positional rows)
//!   node<N>-delta-<seq>.bin   dirty rows of node N: ids + values + opt
//! ```
//!
//! * A node's durable state is its **chain**: one base plus the ordered
//!   deltas after it; replaying the chain reproduces the node's mirror
//!   slice exactly (row ids are node-local, so a chain never references
//!   another node's files — restoring node N reads only node N's chain).
//! * A publish writes, per node, either nothing (clean), a **delta** of
//!   the mirror's dirty rows, or a fresh **base** — when the node has no
//!   chain yet, is fully dirty, the caller forces a re-base (priority
//!   majors), or the chain would exceed the **compaction** threshold
//!   (`delta_bytes > compact_frac × base_bytes` — bounding both restore
//!   replay length and dead bytes on disk).
//! * Node files are written in parallel by the
//!   [`super::writer_pool::WriterPool`] (one job per node), each with the
//!   same durability discipline as v1: temp file → fsync → atomic rename,
//!   then one directory fsync for the batch, then the `MANIFEST` is
//!   written (temp → fsync → rename → dir fsync). **A file becomes part
//!   of the checkpoint only when a durable manifest names it**, so a
//!   crash at any point — mid-delta, mid-meta, mid-manifest — leaves the
//!   previous manifest's chains fully intact and readable.
//! * **GC** runs only after the new manifest is durable and removes only
//!   v2 files the live manifest does not reference (plus stale `.tmp`
//!   files); it can never break a referenced chain, and it never touches
//!   v1 files (`ckpt-*.bin` / `LATEST`). A later **v1** publish reclaims
//!   a shared directory by deleting the `MANIFEST` (readers prefer it)
//!   and the now-unreadable chain files, so switching formats leaves
//!   neither a stale shadow nor leaked disk.
//! * An **inherited** manifest (left by a previous process) is only used
//!   to continue the `seq` numbering: a new engine's mirror need not
//!   match the old chains' content or shape, so its first publish
//!   re-bases every node from the current mirror and GC reclaims the old
//!   run's files — chains are only ever extended by the engine that
//!   wrote them.
//! * **Codecs** ([`super::codec`], ISSUE 7): every f32 payload block can
//!   be written through a [`CkptCodec`] — per-chunk quantized rows,
//!   RLE'd bytes, or raw fp32. Encoded files are self-describing: they
//!   lead with the `CPRE` container magic, the codec id, and then the
//!   file-kind magic, and every encoded blob carries its length and an
//!   FNV-1a checksum. Readers detect the codec **per file**, so a chain
//!   mixing codecs (a mid-run codec switch, manually stitched chains)
//!   restores correctly, and pre-codec files — which are byte-identical
//!   to `codec = none` output — keep loading. Load failures are typed
//!   [`CkptError`]s; match on the variant, not the message.

use std::collections::{HashMap, HashSet};
use std::io::{BufReader, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::codec::{self, Payload};
use super::writer_pool::{WriteJob, WriterPool};
use super::{
    fsync_dir, r32, r64, rf32s, w32, w64, wf32s, write_durable, CheckpointStore,
    CkptError, ShardState,
};
use crate::config::CkptCodec;

const MAGIC_BASE: u32 = 0x4350_5242; // "CPRB"
const MAGIC_DELTA: u32 = 0x4350_5244; // "CPRD"
const MAGIC_META: u32 = 0x4350_524D; // "CPRM"
/// Container magic of an encoded file: `CPRE`, then the codec id, then
/// the inner file-kind magic (base/delta/meta). `codec = none` files
/// skip the container and lead with the kind magic directly — exactly
/// the pre-codec v2 byte layout.
const MAGIC_ENC: u32 = 0x4350_5245; // "CPRE"
const MANIFEST_HEADER: &str = "CPR-MANIFEST-V2";

fn codec_id(c: CkptCodec) -> u32 {
    match c {
        CkptCodec::None => 0,
        CkptCodec::Q8 => 1,
        CkptCodec::Q4 => 2,
        CkptCodec::Rle => 3,
    }
}

fn codec_from_id(id: u32) -> Result<CkptCodec, CkptError> {
    Ok(match id {
        0 => CkptCodec::None,
        1 => CkptCodec::Q8,
        2 => CkptCodec::Q4,
        3 => CkptCodec::Rle,
        _ => {
            return Err(CkptError::CodecMismatch {
                what: format!("encoded file names codec id {id}, which this build does not register"),
            })
        }
    })
}

/// The manifest file name (presence of this file is how
/// [`super::disk::DiskCheckpointer::load_latest`] detects a v2 directory).
pub const MANIFEST: &str = "MANIFEST";

/// The live chain of one node: a base file plus the deltas to replay on
/// top, oldest first. File names are bare (no directory components).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeChain {
    pub base: String,
    pub deltas: Vec<String>,
}

/// The durable index: which files ARE the checkpoint right now.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// monotone publish sequence number (also embedded in file names)
    pub seq: u64,
    /// position marker + MLP params file
    pub meta: String,
    /// chains[node]
    pub chains: Vec<NodeChain>,
}

impl Manifest {
    fn to_text(&self) -> String {
        let mut s = format!("{MANIFEST_HEADER}\nseq {}\nmeta {}\n", self.seq, self.meta);
        for (n, c) in self.chains.iter().enumerate() {
            s.push_str(&format!("node {n} {}", c.base));
            for d in &c.deltas {
                s.push(' ');
                s.push_str(d);
            }
            s.push('\n');
        }
        s
    }

    fn parse(text: &str) -> Result<Manifest> {
        // a field that stops mid-line is the torn-write shape → Truncated;
        // a present-but-malformed field is structural → GeometryMismatch
        let cut = |what: &str| CkptError::Truncated { what: format!("manifest: {what}") };
        let malformed =
            |what: String| CkptError::GeometryMismatch { what: format!("manifest: {what}") };
        let mut lines = text.lines();
        if lines.next() != Some(MANIFEST_HEADER) {
            return Err(CkptError::BadMagic {
                what: "not a v2 checkpoint manifest".into(),
                found: 0,
            }
            .into());
        }
        let mut seq = None;
        let mut meta = None;
        let mut chains: Vec<NodeChain> = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("seq") => {
                    seq = Some(
                        parts
                            .next()
                            .ok_or_else(|| cut("seq value missing"))?
                            .parse::<u64>()
                            .map_err(|_| malformed("bad seq".into()))?,
                    );
                }
                Some("meta") => {
                    meta = Some(
                        parts.next().ok_or_else(|| cut("meta name missing"))?.to_string(),
                    );
                }
                Some("node") => {
                    let idx: usize = parts
                        .next()
                        .ok_or_else(|| cut("node id missing"))?
                        .parse()
                        .map_err(|_| malformed("bad node id".into()))?;
                    if idx != chains.len() {
                        return Err(malformed(format!(
                            "node lines out of order ({idx} after {})",
                            chains.len()
                        ))
                        .into());
                    }
                    let base =
                        parts.next().ok_or_else(|| cut("base name missing"))?.to_string();
                    let deltas = parts.map(str::to_string).collect();
                    chains.push(NodeChain { base, deltas });
                }
                other => return Err(malformed(format!("unknown line kind {other:?}")).into()),
            }
        }
        Ok(Manifest {
            seq: seq.ok_or_else(|| cut("seq line missing"))?,
            meta: meta.ok_or_else(|| cut("meta line missing"))?,
            chains,
        })
    }
}

/// One node's reconstructed state in cluster layout:
/// (shards[table], opt[table]) — what [`CheckpointStore`]'s `ShardState`
/// and the control plane's `load_node` both speak.
pub type NodeStateParts = (Vec<Vec<f32>>, Vec<Vec<f32>>);

/// One table's slice of a delta file: `locals[i]` holds row
/// `data[i*dim..(i+1)*dim]` with optimizer accumulator `opt[i]`.
#[derive(Clone, Debug, PartialEq)]
pub struct DeltaTable {
    pub dim: usize,
    pub locals: Vec<u32>,
    pub data: Vec<f32>,
    pub opt: Vec<f32>,
}

/// Extract the dirty rows of one node as delta payloads (one per table).
pub(crate) fn delta_tables(state: &ShardState) -> Vec<DeltaTable> {
    (0..state.shards().len())
        .map(|t| {
            let shard = &state.shards()[t];
            let opt = &state.opt()[t];
            let dim = if opt.is_empty() { 0 } else { shard.len() / opt.len() };
            let locals = state.dirty_rows(t);
            let mut data = Vec::with_capacity(locals.len() * dim);
            let mut od = Vec::with_capacity(locals.len());
            for &lr in &locals {
                let lr = lr as usize;
                data.extend_from_slice(&shard[lr * dim..(lr + 1) * dim]);
                od.push(opt[lr]);
            }
            DeltaTable { dim, locals, data, opt: od }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// durable file primitives (write_durable/fsync_dir live in super — one
// copy of the crash-consistency discipline for both formats)
// ---------------------------------------------------------------------------

fn open_reader(path: &Path) -> Result<BufReader<std::fs::File>> {
    Ok(BufReader::new(std::fs::File::open(path).with_context(|| {
        format!("opening {}", path.display())
    })?))
}

/// Map a raw read failure onto the typed error surface: a clean EOF is
/// [`CkptError::Truncated`] naming `what`, any other I/O failure is
/// [`CkptError::Io`]; already-typed errors pass through untouched.
fn typed(e: anyhow::Error, what: impl FnOnce() -> String) -> anyhow::Error {
    if e.downcast_ref::<CkptError>().is_some() {
        return e;
    }
    match e.downcast::<std::io::Error>() {
        Ok(io) if io.kind() == std::io::ErrorKind::UnexpectedEof => {
            CkptError::Truncated { what: what() }.into()
        }
        Ok(io) => CkptError::Io(io).into(),
        Err(e) => e,
    }
}

/// Write the file header: the kind magic alone under `codec = none`
/// (the pre-codec layout, byte for byte), or the `CPRE` container magic
/// + codec id + kind magic for encoded files.
fn write_header<W: Write>(w: &mut W, kind: u32, codec: CkptCodec) -> Result<()> {
    if codec != CkptCodec::None {
        w32(w, MAGIC_ENC)?;
        w32(w, codec_id(codec))?;
    }
    w32(w, kind)
}

/// Read a file header, auto-detecting the codec: returns the codec the
/// file was written with and its kind magic. `expect` rejects the wrong
/// file kind with a typed [`CkptError::BadMagic`].
fn read_header<R: Read>(r: &mut R, expect: u32, what: &str) -> Result<CkptCodec> {
    let mut magic = r32(r)?;
    let mut codec = CkptCodec::None;
    if magic == MAGIC_ENC {
        codec = codec_from_id(r32(r)?)?;
        magic = r32(r)?;
    }
    if magic != expect {
        return Err(CkptError::BadMagic { what: what.to_string(), found: magic }.into());
    }
    Ok(codec)
}

/// Write one f32 payload block through `codec`. Raw (`none`) blocks are
/// `len + values`, exactly the pre-codec layout; encoded blocks are
/// `n_values + blob_len + blob + fnv1a(blob)` so a reader can verify the
/// blob before decoding it.
fn write_f32_block<W: Write>(
    w: &mut W,
    codec: CkptCodec,
    class: Payload,
    vals: &[f32],
) -> Result<()> {
    w32(w, vals.len() as u32)?;
    if codec == CkptCodec::None {
        return wf32s(w, vals);
    }
    let blob = codec::codec(codec).encode(class, vals);
    w32(w, blob.len() as u32)?;
    w.write_all(&blob)?;
    w32(w, codec::fnv1a(&blob))
}

/// Read one f32 payload block written by [`write_f32_block`].
fn read_f32_block<R: Read>(
    r: &mut R,
    codec: CkptCodec,
    class: Payload,
    what: impl Fn() -> String,
) -> Result<Vec<f32>> {
    let n = r32(r)? as usize;
    if codec == CkptCodec::None {
        return rf32s(r, n).map_err(|e| typed(e, &what));
    }
    let blob_len = r32(r)? as usize;
    let mut blob = vec![0u8; blob_len];
    r.read_exact(&mut blob)
        .map_err(|e| typed(e.into(), &what))?;
    let sum = r32(r)?;
    if codec::fnv1a(&blob) != sum {
        return Err(CkptError::ChecksumMismatch { what: what() }.into());
    }
    codec::codec(codec)
        .decode(class, &blob, n)
        .map_err(anyhow::Error::from)
}

/// Write one node's full state as a base file (through `codec`).
pub fn write_base(
    dir: &Path,
    name: &str,
    node: usize,
    state: &ShardState,
    codec: CkptCodec,
) -> Result<u64> {
    write_durable(dir, name, |w| {
        write_header(w, MAGIC_BASE, codec)?;
        w32(w, node as u32)?;
        w32(w, state.shards().len() as u32)?;
        for shard in state.shards() {
            write_f32_block(w, codec, Payload::Rows, shard)?;
        }
        for opt in state.opt() {
            write_f32_block(w, codec, Payload::State, opt)?;
        }
        Ok(())
    })
}

/// Read a base file back as (node, (shards, opt)), auto-detecting the
/// codec it was written with. A truncated or foreign file is a typed
/// error, never a partial result.
pub fn read_base(path: &Path) -> Result<(usize, NodeStateParts)> {
    let mut r = open_reader(path)?;
    let what = || format!("base file {}", path.display());
    let codec = read_header(&mut r, MAGIC_BASE, &format!("{} is not a v2 base file", path.display()))
        .map_err(|e| typed(e, what))?;
    let node = r32(&mut r).map_err(|e| typed(e, what))? as usize;
    let n_tables = r32(&mut r).map_err(|e| typed(e, what))? as usize;
    let mut shards = Vec::with_capacity(n_tables);
    for _ in 0..n_tables {
        shards.push(read_f32_block(&mut r, codec, Payload::Rows, what)?);
    }
    let mut opt = Vec::with_capacity(n_tables);
    for _ in 0..n_tables {
        opt.push(read_f32_block(&mut r, codec, Payload::State, what)?);
    }
    Ok((node, (shards, opt)))
}

/// Write one node's dirty rows as a delta file (through `codec`).
pub fn write_delta(
    dir: &Path,
    name: &str,
    node: usize,
    tables: &[DeltaTable],
    codec: CkptCodec,
) -> Result<u64> {
    write_durable(dir, name, |w| {
        write_header(w, MAGIC_DELTA, codec)?;
        w32(w, node as u32)?;
        w32(w, tables.len() as u32)?;
        for t in tables {
            w32(w, t.locals.len() as u32)?;
            w32(w, t.dim as u32)?;
            for &lr in &t.locals {
                w32(w, lr)?;
            }
            write_f32_block(w, codec, Payload::Rows, &t.data)?;
            write_f32_block(w, codec, Payload::State, &t.opt)?;
        }
        Ok(())
    })
}

/// Read a delta file back as (node, per-table payloads), auto-detecting
/// its codec. Truncation is an error (the manifest only ever references
/// fully-fsynced files, so a torn delta means external corruption, not a
/// crash artifact).
pub fn read_delta(path: &Path) -> Result<(usize, Vec<DeltaTable>)> {
    let mut r = open_reader(path)?;
    let what = || format!("delta file {}", path.display());
    let codec =
        read_header(&mut r, MAGIC_DELTA, &format!("{} is not a v2 delta file", path.display()))
            .map_err(|e| typed(e, what))?;
    let node = r32(&mut r).map_err(|e| typed(e, what))? as usize;
    let n_tables = r32(&mut r).map_err(|e| typed(e, what))? as usize;
    let mut tables = Vec::with_capacity(n_tables);
    for _ in 0..n_tables {
        let n_rows = r32(&mut r).map_err(|e| typed(e, what))? as usize;
        let dim = r32(&mut r).map_err(|e| typed(e, what))? as usize;
        let mut locals = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            locals.push(r32(&mut r).map_err(|e| typed(e, what))?);
        }
        let data = read_f32_block(&mut r, codec, Payload::Rows, what)?;
        if data.len() != n_rows * dim {
            return Err(CkptError::GeometryMismatch {
                what: format!(
                    "{}: {} row values for {n_rows} rows × dim {dim}",
                    what(),
                    data.len()
                ),
            }
            .into());
        }
        let opt = read_f32_block(&mut r, codec, Payload::State, what)?;
        tables.push(DeltaTable { dim, locals, data, opt });
    }
    Ok((node, tables))
}

/// Write the position marker + MLP params (through `codec`; the dense
/// params ride the lossless state path under every codec).
pub fn write_meta(
    dir: &Path,
    name: &str,
    mlp: &[Vec<f32>],
    step: u64,
    samples: u64,
    codec: CkptCodec,
) -> Result<u64> {
    write_durable(dir, name, |w| {
        write_header(w, MAGIC_META, codec)?;
        w64(w, step)?;
        w64(w, samples)?;
        w32(w, mlp.len() as u32)?;
        for p in mlp {
            write_f32_block(w, codec, Payload::State, p)?;
        }
        Ok(())
    })
}

/// Read a meta file back as (mlp, step, samples), auto-detecting its
/// codec.
pub fn read_meta(path: &Path) -> Result<(Vec<Vec<f32>>, u64, u64)> {
    let mut r = open_reader(path)?;
    let what = || format!("meta file {}", path.display());
    let codec =
        read_header(&mut r, MAGIC_META, &format!("{} is not a v2 meta file", path.display()))
            .map_err(|e| typed(e, what))?;
    let step = r64(&mut r).map_err(|e| typed(e, what))?;
    let samples = r64(&mut r).map_err(|e| typed(e, what))?;
    let n_mlp = r32(&mut r).map_err(|e| typed(e, what))? as usize;
    let mut mlp = Vec::with_capacity(n_mlp);
    for _ in 0..n_mlp {
        mlp.push(read_f32_block(&mut r, codec, Payload::State, what)?);
    }
    Ok((mlp, step, samples))
}

/// Read the live manifest, if the directory has one.
pub fn read_manifest(dir: &Path) -> Result<Option<Manifest>> {
    let path = dir.join(MANIFEST);
    if !path.exists() {
        return Ok(None);
    }
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    Manifest::parse(&text).map(Some)
}

fn write_manifest(dir: &Path, m: &Manifest) -> Result<()> {
    write_durable(dir, MANIFEST, |w| Ok(w.write_all(m.to_text().as_bytes())?))?;
    fsync_dir(dir)
}

// ---------------------------------------------------------------------------
// chain loading
// ---------------------------------------------------------------------------

/// Reconstruct one node's state by replaying its chain: read the base,
/// apply each delta in order. Touches ONLY this node's files.
pub fn load_node_chain(
    dir: &Path,
    chain: &NodeChain,
    expect_node: usize,
) -> Result<NodeStateParts> {
    let (node, (mut shards, mut opt)) = read_base(&dir.join(&chain.base))?;
    if node != expect_node {
        return Err(CkptError::GeometryMismatch {
            what: format!(
                "chain base {} belongs to node {node}, expected {expect_node}",
                chain.base
            ),
        }
        .into());
    }
    for d in &chain.deltas {
        let (dnode, tables) = read_delta(&dir.join(d))?;
        if dnode != expect_node {
            return Err(CkptError::GeometryMismatch {
                what: format!("chain delta {d} belongs to node {dnode}, expected {expect_node}"),
            }
            .into());
        }
        if tables.len() != shards.len() {
            return Err(CkptError::GeometryMismatch {
                what: format!(
                    "chain delta {d} has {} tables, base has {}",
                    tables.len(),
                    shards.len()
                ),
            }
            .into());
        }
        for (t, dt) in tables.iter().enumerate() {
            if dt.locals.is_empty() {
                continue;
            }
            // a structurally-valid delta can still disagree with its
            // base (bit corruption, a chain stitched across layouts):
            // reject it as an error, never index out of bounds or write
            // rows at wrong offsets
            let rows = opt[t].len();
            let base_dim = if rows == 0 { 0 } else { shards[t].len() / rows };
            if dt.dim != base_dim {
                return Err(CkptError::GeometryMismatch {
                    what: format!(
                        "chain delta {d} table {t}: dim {} != base dim {base_dim}",
                        dt.dim
                    ),
                }
                .into());
            }
            for (i, &lr) in dt.locals.iter().enumerate() {
                let lr = lr as usize;
                if lr >= rows {
                    return Err(CkptError::GeometryMismatch {
                        what: format!(
                            "chain delta {d} table {t}: local row {lr} out of range \
                             ({rows} rows)"
                        ),
                    }
                    .into());
                }
                shards[t][lr * dt.dim..(lr + 1) * dt.dim]
                    .copy_from_slice(&dt.data[i * dt.dim..(i + 1) * dt.dim]);
                opt[t][lr] = dt.opt[i];
            }
        }
    }
    Ok((shards, opt))
}

/// Load the full store from a v2 directory (every node's chain + meta).
/// `Ok(None)` when no manifest exists.
pub fn load_store(dir: &Path) -> Result<Option<CheckpointStore>> {
    let Some(m) = read_manifest(dir)? else {
        return Ok(None);
    };
    let (mlp, step, samples) = read_meta(&dir.join(&m.meta))?;
    let mut nodes = Vec::with_capacity(m.chains.len());
    for (n, chain) in m.chains.iter().enumerate() {
        let (shards, opt) = load_node_chain(dir, chain, n)?;
        nodes.push(ShardState::from_parts(shards, opt));
    }
    Ok(Some(CheckpointStore::from_node_states(nodes, mlp, step, samples)))
}

/// Load ONE node's state (plus the marker position) by reading only that
/// node's chain — the partial-restore read path: restoring a failed node
/// does not touch any other node's files. `Ok(None)` when no manifest.
pub fn load_node(
    dir: &Path,
    node: usize,
) -> Result<Option<(NodeStateParts, u64, u64)>> {
    let Some(m) = read_manifest(dir)? else {
        return Ok(None);
    };
    if node >= m.chains.len() {
        return Err(CkptError::GeometryMismatch {
            what: format!(
                "manifest covers {} nodes, asked for node {node}",
                m.chains.len()
            ),
        }
        .into());
    }
    let (_, step, samples) = read_meta(&dir.join(&m.meta))?;
    let parts = load_node_chain(dir, &m.chains[node], node)?;
    Ok(Some((parts, step, samples)))
}

// ---------------------------------------------------------------------------
// the publish engine
// ---------------------------------------------------------------------------

/// What the engine decided to write for one node this publish.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Action {
    /// chain unchanged (node has no dirty rows)
    Keep,
    /// append a delta of the dirty rows
    Delta,
    /// start a fresh chain with a full base (no chain / fully dirty /
    /// forced / compaction due)
    Base,
}

/// The v2 publish engine: owns the manifest state of one checkpoint
/// directory and turns a [`CheckpointStore`]'s dirty sets into durable
/// base/delta chains. Single-owner — lives on the pipeline writer thread
/// (or a bench/tool loop); the parallelism is inside
/// [`V2Engine::publish`], which fans node files out over the
/// [`WriterPool`].
pub struct V2Engine {
    dir: PathBuf,
    pool: WriterPool,
    compact_frac: f64,
    codec: CkptCodec,
    manifest: Option<Manifest>,
    /// false until this engine's first successful publish: an inherited
    /// manifest (from a previous process) is used only to continue the
    /// `seq` numbering — its chains are NEVER extended, because this
    /// engine's mirror need not match the old chains' content or shape.
    /// The first publish re-bases every node from the current mirror and
    /// GC reclaims the previous run's files.
    synced: bool,
    /// byte length of every chain/meta file THIS engine wrote, so
    /// compaction planning never re-stats the directory (chains are only
    /// ever extended within one engine's lifetime — see `synced`).
    sizes: HashMap<String, u64>,
}

impl V2Engine {
    /// Open (or create) a v2 checkpoint directory, resuming its manifest
    /// sequence if one exists. `compact_frac` is the chain-compaction
    /// threshold (re-base a node when its pending chain's delta bytes
    /// exceed `compact_frac × base_bytes`); `codec` is applied to every
    /// file THIS engine writes — files already in the directory keep
    /// whatever codec their headers declare, so a mid-run codec switch
    /// yields a mixed chain that still restores (readers auto-detect
    /// per file).
    pub fn open(
        dir: &Path,
        pool: WriterPool,
        compact_frac: f64,
        codec: CkptCodec,
    ) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        let manifest = read_manifest(dir)?;
        Ok(Self {
            dir: dir.to_path_buf(),
            pool,
            compact_frac,
            codec,
            manifest,
            synced: false,
            sizes: HashMap::new(),
        })
    }

    /// The live manifest (None before the first publish into a fresh dir).
    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    /// Durably publish the store's dirty state: per-node base/delta files
    /// in parallel, then meta (when `update_meta`, or when none exists
    /// yet), then the manifest, then GC. `force_base` re-bases every node
    /// (priority majors). On success the store's dirty sets are cleared;
    /// on error the previous manifest stays live and dirty sets stay set,
    /// so the next publish retries the same content. Returns total bytes
    /// written (node files + meta + manifest).
    pub fn publish(
        &mut self,
        store: &mut CheckpointStore,
        update_meta: bool,
        force_base: bool,
    ) -> Result<u64> {
        let n_nodes = store.node_states().len();
        // chains are only extendable when THIS engine published them
        // (`synced`) for this cluster shape; an inherited or
        // shape-mismatched manifest only continues the seq numbering —
        // everything re-bases from the current mirror and the old files
        // become garbage for GC
        let prev = self
            .manifest
            .as_ref()
            .filter(|m| self.synced && m.chains.len() == n_nodes);
        let seq = self.manifest.as_ref().map_or(0, |m| m.seq) + 1;

        // --- plan per-node actions (compaction needs chain sizes) ------
        let plan_span = crate::telemetry::span("ckpt_plan");
        let mut actions = Vec::with_capacity(n_nodes);
        for (n, st) in store.node_states().iter().enumerate() {
            let action = match prev.map(|m| &m.chains[n]) {
                None => Action::Base,
                Some(_) if force_base || st.fully_dirty() => Action::Base,
                Some(_) if st.dirty_row_count() == 0 => Action::Keep,
                Some(chain) => {
                    let base_bytes = self.file_size(&chain.base)?;
                    // the pending delta hasn't been encoded yet: scale its
                    // logical bytes by the codec's expected ratio so the
                    // compaction decision compares on-disk apples to apples
                    let pending = (st.dirty_io_bytes() as f64
                        * codec::estimated_ratio(self.codec)) as u64;
                    let mut delta_bytes = pending;
                    for d in &chain.deltas {
                        delta_bytes += self.file_size(d)?;
                    }
                    if delta_bytes as f64 > self.compact_frac * base_bytes as f64 {
                        Action::Base
                    } else {
                        Action::Delta
                    }
                }
            };
            actions.push(action);
        }
        drop(plan_span);

        // --- build the new chain set + one write job per dirty node ----
        let mut chains = Vec::with_capacity(n_nodes);
        let mut jobs: Vec<WriteJob<'_>> = Vec::new();
        let mut job_names: Vec<String> = Vec::new();
        let dir = self.dir.clone();
        // Copy — each pool job captures its own; encoding runs inside the
        // jobs, so it parallelizes across nodes with the file writes
        let job_codec = self.codec;
        for (n, st) in store.node_states().iter().enumerate() {
            match actions[n] {
                Action::Keep => {
                    chains.push(prev.expect("Keep implies a previous chain").chains[n].clone());
                }
                Action::Base => {
                    let name = format!("node{n}-base-{seq}.bin");
                    chains.push(NodeChain { base: name.clone(), deltas: Vec::new() });
                    job_names.push(name.clone());
                    let dir = dir.clone();
                    jobs.push(Box::new(move || {
                        let _t = crate::telemetry::span_node("ckpt_write_base", n);
                        write_base(&dir, &name, n, st, job_codec)
                    }));
                }
                Action::Delta => {
                    let name = format!("node{n}-delta-{seq}.bin");
                    let mut chain = prev.expect("Delta implies a previous chain").chains[n].clone();
                    chain.deltas.push(name.clone());
                    chains.push(chain);
                    job_names.push(name.clone());
                    let dir = dir.clone();
                    jobs.push(Box::new(move || {
                        let _t = crate::telemetry::span_node("ckpt_write_delta", n);
                        let tables = delta_tables(st);
                        write_delta(&dir, &name, n, &tables, job_codec)
                    }));
                }
            }
        }
        let byte_counts = self.pool.run(jobs)?;
        let mut total: u64 = byte_counts.iter().sum();
        for (name, &bytes) in job_names.iter().zip(&byte_counts) {
            self.sizes.insert(name.clone(), bytes);
        }

        // --- meta ------------------------------------------------------
        let meta = if update_meta || prev.is_none() {
            let _t = crate::telemetry::span("ckpt_meta");
            let name = format!("meta-{seq}.bin");
            let bytes = write_meta(
                &self.dir,
                &name,
                &store.mlp,
                store.step,
                store.samples,
                self.codec,
            )?;
            total += bytes;
            self.sizes.insert(name.clone(), bytes);
            name
        } else {
            prev.expect("checked above").meta.clone()
        };

        // renames are directory-metadata updates: make every node/meta
        // file durable before the manifest can name them
        fsync_dir(&self.dir)?;

        // --- manifest: the publish point -------------------------------
        let manifest = Manifest { seq, meta, chains };
        {
            let _t = crate::telemetry::span("ckpt_manifest");
            write_manifest(&self.dir, &manifest)?;
        }
        total += std::fs::metadata(self.dir.join(MANIFEST))?.len();
        self.manifest = Some(manifest);
        self.synced = true;
        for st in store.node_states_mut() {
            st.clear_dirty();
        }

        // --- GC: only after the new manifest is durable ----------------
        {
            let _t = crate::telemetry::span("ckpt_gc");
            self.gc()?;
        }
        crate::telemetry::observe("bytes_per_publish", total);
        Ok(total)
    }

    /// Byte length of a chain/meta file: from the engine's write cache
    /// (every extendable chain file was written by this engine), falling
    /// back to a stat for robustness.
    fn file_size(&self, name: &str) -> Result<u64> {
        if let Some(&b) = self.sizes.get(name) {
            return Ok(b);
        }
        Ok(std::fs::metadata(self.dir.join(name))
            .with_context(|| format!("sizing {name}"))?
            .len())
    }

    /// Remove v2 files the live manifest does not reference (and stale
    /// temp files), and bound the size cache to the live chain set.
    /// Referenced chains are never touched; neither are v1 files sharing
    /// the directory.
    fn gc(&mut self) -> Result<()> {
        let Some(m) = &self.manifest else {
            return Ok(());
        };
        let mut referenced: HashSet<&str> = HashSet::new();
        referenced.insert(m.meta.as_str());
        for c in &m.chains {
            referenced.insert(c.base.as_str());
            for d in &c.deltas {
                referenced.insert(d.as_str());
            }
        }
        for entry in std::fs::read_dir(&self.dir)? {
            let Ok(entry) = entry else { continue };
            let Ok(name) = entry.file_name().into_string() else { continue };
            // stale temp files are crash debris by definition: gc runs
            // strictly after this publish's renames, so no live .tmp exists
            let stale_tmp = name.ends_with(".tmp");
            let unreferenced = is_v2_data_file(&name) && !referenced.contains(name.as_str());
            if stale_tmp || unreferenced {
                std::fs::remove_file(entry.path()).ok();
            }
        }
        self.sizes.retain(|k, _| referenced.contains(k.as_str()));
        Ok(())
    }
}

/// Does `name` follow the v2 data-file naming scheme? (GC — and the v1
/// publish path's directory reclaim — only ever consider these, so v1
/// files and foreign files are never collected.)
pub(crate) fn is_v2_data_file(name: &str) -> bool {
    if !name.ends_with(".bin") {
        return false;
    }
    if name.starts_with("meta-") {
        return true;
    }
    name.starts_with("node") && (name.contains("-base-") || name.contains("-delta-"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{PsCluster, TableInfo};

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("cpr_v2_{tag}"));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn cluster() -> PsCluster {
        PsCluster::new(
            vec![TableInfo { rows: 24, dim: 4 }, TableInfo { rows: 7, dim: 4 }],
            3,
            17,
        )
    }

    fn perturb(c: &PsCluster, seed: u64) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let idx: Vec<u32> = (0..10)
            .flat_map(|_| vec![rng.below(24) as u32, rng.below(7) as u32])
            .collect();
        let grads: Vec<f32> = (0..10 * 2 * 4).map(|_| rng.f32() - 0.5).collect();
        c.sgd_update(&idx, &grads, 0.5);
    }

    fn engine(dir: &Path) -> V2Engine {
        V2Engine::open(dir, WriterPool::new(3), 0.5, CkptCodec::None).unwrap()
    }

    fn engine_with(dir: &Path, codec: CkptCodec) -> V2Engine {
        V2Engine::open(dir, WriterPool::new(3), 0.5, codec).unwrap()
    }

    #[test]
    fn base_file_roundtrip_and_foreign_rejection() {
        let dir = tmpdir("base");
        let c = cluster();
        perturb(&c, 1);
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 1, 128);
        let st = &store.node_states()[1];
        let bytes = write_base(&dir, "node1-base-1.bin", 1, st, CkptCodec::None).unwrap();
        assert_eq!(bytes, std::fs::metadata(dir.join("node1-base-1.bin")).unwrap().len());
        let (node, (shards, opt)) = read_base(&dir.join("node1-base-1.bin")).unwrap();
        assert_eq!(node, 1);
        assert_eq!(shards, st.shards());
        assert_eq!(opt, st.opt());
        // a v1 checkpoint is not a base file
        store.write_file(&dir.join("v1.bin")).unwrap();
        assert!(read_base(&dir.join("v1.bin")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn delta_file_roundtrip() {
        let dir = tmpdir("delta");
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        perturb(&c, 2);
        store.save_rows(&c, 0, &[0, 3, 9]); // node 0 locals 0,1,3
        let st = &store.node_states()[0];
        let tables = delta_tables(st);
        assert_eq!(tables[0].locals, vec![0, 1, 3]);
        assert!(tables[1].locals.is_empty());
        write_delta(&dir, "node0-delta-1.bin", 0, &tables, CkptCodec::None).unwrap();
        let (node, back) = read_delta(&dir.join("node0-delta-1.bin")).unwrap();
        assert_eq!(node, 0);
        assert_eq!(back, tables);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_delta_and_base_files_are_rejected() {
        // extends `read_rejects_garbage` to the v2 record types: a file
        // cut mid-payload must fail loudly, never yield partial rows
        let dir = tmpdir("trunc");
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        perturb(&c, 3);
        store.save_rows(&c, 0, &[0, 3, 9, 12]);
        let st = &store.node_states()[0];
        write_delta(&dir, "d.bin", 0, &delta_tables(st), CkptCodec::None).unwrap();
        write_base(&dir, "b.bin", 0, st, CkptCodec::None).unwrap();
        for name in ["d.bin", "b.bin"] {
            let path = dir.join(name);
            let full = std::fs::read(&path).unwrap();
            std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        }
        assert!(read_delta(&dir.join("d.bin")).is_err(), "truncated delta must fail");
        assert!(read_base(&dir.join("b.bin")).is_err(), "truncated base must fail");
        // and garbage bytes are rejected by magic, not parsed
        std::fs::write(dir.join("g.bin"), b"junkjunkjunk").unwrap();
        assert!(read_delta(&dir.join("g.bin")).is_err());
        assert!(read_base(&dir.join("g.bin")).is_err());
        assert!(read_meta(&dir.join("g.bin")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_text_roundtrip_and_rejection() {
        let m = Manifest {
            seq: 12,
            meta: "meta-9.bin".into(),
            chains: vec![
                NodeChain {
                    base: "node0-base-3.bin".into(),
                    deltas: vec!["node0-delta-5.bin".into(), "node0-delta-9.bin".into()],
                },
                NodeChain { base: "node1-base-9.bin".into(), deltas: vec![] },
            ],
        };
        assert_eq!(Manifest::parse(&m.to_text()).unwrap(), m);
        assert!(Manifest::parse("LATEST-style pointer\n").is_err());
        assert!(Manifest::parse("CPR-MANIFEST-V2\nseq 1\n").is_err(), "meta missing");
    }

    #[test]
    fn publish_then_load_store_roundtrips() {
        let dir = tmpdir("pub");
        let c = cluster();
        perturb(&c, 4);
        let mut store = CheckpointStore::initial(&c, vec![vec![1.0, 2.0]]);
        store.full_save(&c, vec![vec![3.5]], 10, 1280);
        let mut eng = engine(&dir);
        let bytes = eng.publish(&mut store, true, false).unwrap();
        assert!(bytes > 0);
        let back = load_store(&dir).unwrap().expect("manifest published");
        assert_eq!(back, store);
        assert_eq!((back.step, back.samples), (10, 1280));
        assert_eq!(back.mlp, vec![vec![3.5]]);
        // dirty sets are consumed by the publish
        assert!(store.node_states().iter().all(|n| n.dirty_row_count() == 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn delta_publish_writes_only_dirty_rows_and_replays() {
        let dir = tmpdir("inc");
        // tables big enough that a 3-row delta sits far below both the
        // base size and the compaction threshold
        let c = PsCluster::new(
            vec![TableInfo { rows: 240, dim: 4 }, TableInfo { rows: 70, dim: 4 }],
            3,
            17,
        );
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 1, 128);
        let mut eng = engine(&dir);
        let base_bytes = eng.publish(&mut store, true, false).unwrap();
        // re-save three rows of node 0 (0, 3, 9 ≡ 0 mod 3), publish again:
        // one small delta on node 0's chain, nothing for clean nodes
        store.save_rows(&c, 0, &[0, 3, 9]);
        store.mark_position(vec![], 2, 256);
        let delta_bytes = eng.publish(&mut store, true, false).unwrap();
        assert!(delta_bytes * 4 < base_bytes,
                "delta publish ({delta_bytes} B) must be far below the base \
                 publish ({base_bytes} B)");
        let m = eng.manifest().unwrap();
        let chain0 = &m.chains[0];
        assert_eq!(chain0.deltas.len(), 1, "node 0 chain gained one delta");
        assert!(m.chains[1].deltas.is_empty(), "clean node keeps its bare base");
        let back = load_store(&dir).unwrap().unwrap();
        assert_eq!(back, store, "chain replay must reproduce the mirror");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_rebases_when_deltas_outgrow_the_base() {
        let dir = tmpdir("compact");
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 1, 128);
        // tiny threshold: the second delta must trigger a re-base
        let mut eng = V2Engine::open(&dir, WriterPool::new(2), 0.05, CkptCodec::None).unwrap();
        eng.publish(&mut store, true, false).unwrap();
        for i in 0..6u64 {
            perturb(&c, 10 + i);
            store.save_rows(&c, 0, &[0, 3, 6, 9, 12]);
            store.mark_position(vec![], 2 + i, 256);
            eng.publish(&mut store, true, false).unwrap();
            let chain = &eng.manifest().unwrap().chains[0];
            assert!(chain.deltas.len() <= 2,
                    "compaction must bound the chain, got {:?}", chain);
        }
        let back = load_store(&dir).unwrap().unwrap();
        assert_eq!(back, store, "compacted chain still replays exactly");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gc_drops_unreferenced_files_but_never_referenced_chains() {
        let dir = tmpdir("gc");
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 1, 128);
        let mut eng = engine(&dir);
        eng.publish(&mut store, true, false).unwrap();
        // force a full re-base: the old bases + meta become garbage
        perturb(&c, 20);
        store.full_save(&c, vec![], 2, 256);
        eng.publish(&mut store, true, false).unwrap();
        let m = eng.manifest().unwrap().clone();
        let on_disk: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok()?.file_name().into_string().ok())
            .filter(|n| is_v2_data_file(n))
            .collect();
        let mut referenced: Vec<String> = vec![m.meta.clone()];
        for ch in &m.chains {
            referenced.push(ch.base.clone());
            referenced.extend(ch.deltas.iter().cloned());
        }
        let mut a = on_disk.clone();
        let mut b = referenced.clone();
        a.sort();
        b.sort();
        assert_eq!(a, b, "disk must hold exactly the referenced v2 files");
        // every referenced file is readable (the chain is unbroken)
        assert!(load_store(&dir).unwrap().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn interrupted_publish_leaves_previous_chain_readable() {
        // simulate a writer killed mid-publish: new-seq node files land
        // (renamed) but the manifest update never happens, plus a torn
        // temp file — load_store must return the last DURABLE state
        let dir = tmpdir("crash");
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 1, 128);
        let mut eng = engine(&dir);
        eng.publish(&mut store, true, false).unwrap();
        let durable = load_store(&dir).unwrap().unwrap();
        // "crash": orphan delta with a plausible name + torn tmp manifest
        perturb(&c, 30);
        store.save_rows(&c, 0, &[0, 3]);
        let st = &store.node_states()[0];
        write_delta(&dir, "node0-delta-99.bin", 0, &delta_tables(st), CkptCodec::None).unwrap();
        let orphan = std::fs::read(dir.join("node0-delta-99.bin")).unwrap();
        std::fs::write(dir.join("node0-delta-98.bin"), &orphan[..orphan.len() / 3]).unwrap();
        std::fs::write(dir.join(".MANIFEST.tmp"), b"CPR-MANIFEST-V2\nseq ").unwrap();
        let back = load_store(&dir).unwrap().unwrap();
        assert_eq!(back, durable,
                   "unreferenced files must be invisible to readers");
        // the next successful publish GCs the crash debris
        store.mark_position(vec![], 2, 256);
        let mut store2 = store.clone();
        eng.publish(&mut store2, true, false).unwrap();
        assert!(!dir.join("node0-delta-98.bin").exists(), "debris not GC'd");
        assert!(!dir.join(".MANIFEST.tmp").exists(), "stale tmp not GC'd");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_node_reads_only_that_nodes_chain() {
        let dir = tmpdir("node");
        let c = cluster();
        perturb(&c, 6);
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 5, 640);
        let mut eng = engine(&dir);
        eng.publish(&mut store, true, false).unwrap();
        // corrupt node 1's base: nodes 0/2 must still load, node 1 must not
        let m = eng.manifest().unwrap().clone();
        let victim = dir.join(&m.chains[1].base);
        let full = std::fs::read(&victim).unwrap();
        std::fs::write(&victim, &full[..full.len() / 2]).unwrap();
        let ((shards, opt), step, samples) =
            load_node(&dir, 0).unwrap().expect("manifest exists");
        assert_eq!(shards, store.node_states()[0].shards());
        assert_eq!(opt, store.node_states()[0].opt());
        assert_eq!((step, samples), (5, 640));
        assert!(load_node(&dir, 2).unwrap().is_some());
        assert!(load_node(&dir, 1).is_err(),
                "node 1's torn chain must fail its own load");
        assert!(load_store(&dir).is_err(),
                "the full-store load does read node 1's chain");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn inherited_manifests_are_never_extended() {
        // a new engine (new process) must not append deltas to chains it
        // did not write: its mirror need not match the old chains, so the
        // first publish re-bases everything from the current mirror
        let dir = tmpdir("inherit");
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 1, 128);
        {
            let mut eng1 = engine(&dir);
            eng1.publish(&mut store, true, false).unwrap();
            perturb(&c, 40);
            store.save_rows(&c, 0, &[0, 3]);
            store.mark_position(vec![], 2, 256);
            eng1.publish(&mut store, true, false).unwrap();
            assert_eq!(eng1.manifest().unwrap().chains[0].deltas.len(), 1);
        }
        // a DIFFERENT mirror in a new process: row 6 diverged, and the
        // new mirror never saw the old run's row 0/3 deltas
        let c2 = cluster();
        perturb(&c2, 41);
        let mut store2 = CheckpointStore::initial(&c2, vec![]);
        store2.save_rows(&c2, 0, &[6]);
        store2.mark_position(vec![], 7, 896);
        let mut eng2 = engine(&dir);
        eng2.publish(&mut store2, true, false).unwrap();
        let m = eng2.manifest().unwrap();
        assert!(m.chains.iter().all(|ch| ch.deltas.is_empty()),
                "first publish of a new engine must re-base, got {m:?}");
        let back = load_store(&dir).unwrap().unwrap();
        assert_eq!(back, store2,
                   "no stale chain data may leak into the new run's checkpoint");
        // within the same engine, chains extend again
        store2.save_rows(&c2, 0, &[0]);
        store2.mark_position(vec![], 8, 1024);
        eng2.publish(&mut store2, true, false).unwrap();
        assert_eq!(eng2.manifest().unwrap().chains[0].deltas.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn delta_disagreeing_with_its_base_is_an_error_not_a_panic() {
        // structurally-valid delta, wrong geometry: local row id past the
        // base's shard — replay must bail, never index out of bounds
        let dir = tmpdir("geom");
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 1, 128);
        let st = &store.node_states()[0];
        write_base(&dir, "b.bin", 0, st, CkptCodec::None).unwrap();
        let bad = vec![
            DeltaTable { dim: 4, locals: vec![999], data: vec![0.0; 4], opt: vec![0.0] },
            DeltaTable { dim: 4, locals: vec![], data: vec![], opt: vec![] },
        ];
        write_delta(&dir, "d.bin", 0, &bad, CkptCodec::None).unwrap();
        let chain = NodeChain { base: "b.bin".into(), deltas: vec!["d.bin".into()] };
        let err = load_node_chain(&dir, &chain, 0).unwrap_err();
        // typed, not stringly: callers match the variant
        assert!(
            matches!(err.downcast_ref::<CkptError>(),
                     Some(CkptError::GeometryMismatch { .. })),
            "{err:#}"
        );
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        // and a dim mismatch is rejected the same way
        let bad_dim = vec![
            DeltaTable { dim: 2, locals: vec![0], data: vec![0.0; 2], opt: vec![0.0] },
            DeltaTable { dim: 4, locals: vec![], data: vec![], opt: vec![] },
        ];
        write_delta(&dir, "d2.bin", 0, &bad_dim, CkptCodec::None).unwrap();
        let chain2 = NodeChain { base: "b.bin".into(), deltas: vec!["d2.bin".into()] };
        let err2 = load_node_chain(&dir, &chain2, 0).unwrap_err();
        assert!(
            matches!(err2.downcast_ref::<CkptError>(),
                     Some(CkptError::GeometryMismatch { .. })),
            "{err2:#}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reopen_resumes_the_manifest_sequence() {
        let dir = tmpdir("reopen");
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 1, 128);
        {
            let mut eng = engine(&dir);
            eng.publish(&mut store, true, false).unwrap();
        }
        let mut eng2 = engine(&dir);
        let seq0 = eng2.manifest().unwrap().seq;
        perturb(&c, 7);
        store.save_rows(&c, 0, &[0]);
        store.mark_position(vec![], 2, 256);
        eng2.publish(&mut store, true, false).unwrap();
        assert_eq!(eng2.manifest().unwrap().seq, seq0 + 1);
        assert_eq!(load_store(&dir).unwrap().unwrap(), store);
        std::fs::remove_dir_all(&dir).ok();
    }

    // -- codec coverage -----------------------------------------------------

    /// What reading a codec'd file must yield: exactly what the codec's
    /// own encode→decode produces (bit-exact for lossless codecs,
    /// quantized values for lossy ones — file I/O adds no drift of its
    /// own on top of the codec).
    fn expect_rows(codec: CkptCodec, vals: &[f32]) -> Vec<f32> {
        let c = codec::codec(codec);
        c.decode(Payload::Rows, &c.encode(Payload::Rows, vals), vals.len()).unwrap()
    }

    #[test]
    fn every_codec_roundtrips_base_delta_and_meta_files() {
        for k in CkptCodec::all() {
            let dir = tmpdir(&format!("codec_{}", k.name()));
            let c = cluster();
            perturb(&c, 50);
            let mut store = CheckpointStore::initial(&c, vec![vec![0.25, -1.5]]);
            store.full_save(&c, vec![vec![0.25, -1.5]], 3, 384);
            let st = &store.node_states()[0];
            write_base(&dir, "b.bin", 0, st, k).unwrap();
            let (node, (shards, opt)) = read_base(&dir.join("b.bin")).unwrap();
            assert_eq!(node, 0);
            for (t, shard) in shards.iter().enumerate() {
                assert_eq!(shard, &expect_rows(k, &st.shards()[t]), "codec {k:?}");
            }
            // optimizer state rides the lossless path under EVERY codec
            assert_eq!(opt, st.opt(), "codec {k:?} must keep opt state fp32-exact");

            let tables = delta_tables(st);
            write_delta(&dir, "d.bin", 0, &tables, k).unwrap();
            let (_, back) = read_delta(&dir.join("d.bin")).unwrap();
            for (t, bt) in back.iter().enumerate() {
                assert_eq!(bt.locals, tables[t].locals);
                assert_eq!(bt.data, expect_rows(k, &tables[t].data), "codec {k:?}");
                assert_eq!(bt.opt, tables[t].opt, "codec {k:?} delta opt must be exact");
            }

            write_meta(&dir, "m.bin", &store.mlp, 3, 384, k).unwrap();
            let (mlp, step, samples) = read_meta(&dir.join("m.bin")).unwrap();
            assert_eq!(mlp, store.mlp, "codec {k:?} must keep MLP params fp32-exact");
            assert_eq!((step, samples), (3, 384));
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn mixed_codec_chains_restore_per_file() {
        // a mid-run codec switch stitches chains whose base and deltas
        // carry different codecs; the reader must auto-detect each file
        let dir = tmpdir("mixed");
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 1, 128);
        let st = store.node_states()[0].clone();
        write_base(&dir, "b.bin", 0, &st, CkptCodec::None).unwrap();
        perturb(&c, 51);
        let mut store2 = CheckpointStore::initial(&c, vec![]);
        store2.save_rows(&c, 0, &[0, 3, 9]);
        let tables = delta_tables(&store2.node_states()[0]);
        write_delta(&dir, "d.bin", 0, &tables, CkptCodec::Q8).unwrap();
        let chain = NodeChain { base: "b.bin".into(), deltas: vec!["d.bin".into()] };
        let (shards, opt) = load_node_chain(&dir, &chain, 0).unwrap();
        // expected: the fp32 base with the delta's rows replayed through q8
        let mut want = st.shards().to_vec();
        let mut want_opt = st.opt().to_vec();
        for (t, dt) in tables.iter().enumerate() {
            let dec = expect_rows(CkptCodec::Q8, &dt.data);
            for (i, &lr) in dt.locals.iter().enumerate() {
                let lr = lr as usize;
                want[t][lr * dt.dim..(lr + 1) * dt.dim]
                    .copy_from_slice(&dec[i * dt.dim..(i + 1) * dt.dim]);
                want_opt[t][lr] = dt.opt[i];
            }
        }
        assert_eq!(shards, want);
        assert_eq!(opt, want_opt);
        // the reverse stitch (quantized base, raw delta) restores too
        write_base(&dir, "b2.bin", 0, &st, CkptCodec::Q4).unwrap();
        let chain2 = NodeChain { base: "b2.bin".into(), deltas: vec![] };
        let (shards2, _) = load_node_chain(&dir, &chain2, 0).unwrap();
        for (t, shard) in shards2.iter().enumerate() {
            assert_eq!(shard, &expect_rows(CkptCodec::Q4, &st.shards()[t]));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_encoded_files_fail_with_typed_errors() {
        let dir = tmpdir("enc_corrupt");
        let c = cluster();
        let mut store = CheckpointStore::initial(&c, vec![]);
        store.full_save(&c, vec![], 1, 128);
        let st = &store.node_states()[0];
        write_base(&dir, "b.bin", 0, st, CkptCodec::Q8).unwrap();
        let full = std::fs::read(dir.join("b.bin")).unwrap();
        // a bit flip inside an encoded blob trips the blob checksum
        let mut flipped = full.clone();
        let last = flipped.len();
        flipped[last - 6] ^= 0x40;
        std::fs::write(dir.join("flip.bin"), &flipped).unwrap();
        let err = read_base(&dir.join("flip.bin")).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<CkptError>(),
                     Some(CkptError::ChecksumMismatch { .. })),
            "{err:#}"
        );
        // truncation mid-blob is Truncated, same as raw files
        std::fs::write(dir.join("cut.bin"), &full[..full.len() / 2]).unwrap();
        let err = read_base(&dir.join("cut.bin")).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<CkptError>(), Some(CkptError::Truncated { .. })),
            "{err:#}"
        );
        // an unknown codec id in the container header is a CodecMismatch
        let mut unknown = Vec::new();
        unknown.extend_from_slice(&MAGIC_ENC.to_le_bytes());
        unknown.extend_from_slice(&99u32.to_le_bytes());
        unknown.extend_from_slice(&MAGIC_BASE.to_le_bytes());
        std::fs::write(dir.join("odd.bin"), &unknown).unwrap();
        let err = read_base(&dir.join("odd.bin")).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<CkptError>(),
                     Some(CkptError::CodecMismatch { .. })),
            "{err:#}"
        );
        // the wrong kind of file is BadMagic carrying the found magic
        write_meta(&dir, "m.bin", &[], 1, 128, CkptCodec::None).unwrap();
        let err = read_base(&dir.join("m.bin")).unwrap_err();
        match err.downcast_ref::<CkptError>() {
            Some(CkptError::BadMagic { found, .. }) => assert_eq!(*found, MAGIC_META),
            other => panic!("expected BadMagic, got {other:?} ({err:#})"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quantized_publish_shrinks_bytes_and_still_loads() {
        // bigger tables so codes dominate headers
        let mk = || {
            let c = PsCluster::new(
                vec![TableInfo { rows: 240, dim: 16 }, TableInfo { rows: 70, dim: 16 }],
                3,
                17,
            );
            let mut store = CheckpointStore::initial(&c, vec![vec![0.5; 32]]);
            store.full_save(&c, vec![vec![0.5; 32]], 1, 128);
            (c, store)
        };
        let dir_f = tmpdir("pub_f32");
        let dir_q = tmpdir("pub_q8");
        let (_, mut store_f) = mk();
        let (_, mut store_q) = mk();
        let mut eng_f = engine(&dir_f);
        let mut eng_q = engine_with(&dir_q, CkptCodec::Q8);
        let bytes_f = eng_f.publish(&mut store_f, true, false).unwrap();
        let bytes_q = eng_q.publish(&mut store_q, true, false).unwrap();
        assert!(
            (bytes_q as f64) < 0.6 * bytes_f as f64,
            "q8 publish ({bytes_q} B) must be well below fp32 ({bytes_f} B)"
        );
        // partial restore reads the quantized chain back within its bound
        let ((shards, opt), step, _) = load_node(&dir_q, 0).unwrap().unwrap();
        assert_eq!(step, 1);
        assert_eq!(opt, store_q.node_states()[0].opt());
        for (t, shard) in shards.iter().enumerate() {
            assert_eq!(shard, &expect_rows(CkptCodec::Q8, &store_q.node_states()[0].shards()[t]));
        }
        std::fs::remove_dir_all(&dir_f).ok();
        std::fs::remove_dir_all(&dir_q).ok();
    }

    #[test]
    fn crash_debris_is_invisible_under_every_codec() {
        // the PR-5 interrupted-publish guarantee must hold for encoded
        // files too: orphans, torn encoded files, stale tmp — all invisible
        for k in CkptCodec::all() {
            let dir = tmpdir(&format!("debris_{}", k.name()));
            let c = cluster();
            let mut store = CheckpointStore::initial(&c, vec![]);
            store.full_save(&c, vec![], 1, 128);
            let mut eng = engine_with(&dir, k);
            eng.publish(&mut store, true, false).unwrap();
            let durable = load_store(&dir).unwrap().unwrap();
            perturb(&c, 60);
            store.save_rows(&c, 0, &[0, 3]);
            let st = &store.node_states()[0];
            write_delta(&dir, "node0-delta-99.bin", 0, &delta_tables(st), k).unwrap();
            let orphan = std::fs::read(dir.join("node0-delta-99.bin")).unwrap();
            std::fs::write(dir.join("node0-delta-98.bin"), &orphan[..orphan.len() / 3])
                .unwrap();
            std::fs::write(dir.join(".MANIFEST.tmp"), b"CPR-MANIFEST-V2\nseq ").unwrap();
            let back = load_store(&dir).unwrap().unwrap();
            assert_eq!(back, durable, "codec {k:?}: debris must be invisible");
            store.mark_position(vec![], 2, 256);
            eng.publish(&mut store, true, false).unwrap();
            assert!(!dir.join("node0-delta-98.bin").exists(), "codec {k:?}: debris not GC'd");
            assert!(!dir.join(".MANIFEST.tmp").exists(), "codec {k:?}: stale tmp not GC'd");
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}
