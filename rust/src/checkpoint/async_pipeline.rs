//! Asynchronous checkpoint pipeline: saves overlap training.
//!
//! The inline `full_save` of the original coordinator stalled the step loop
//! for the whole mirror copy + disk write. Production systems decouple
//! these (Check-N-Run): a snapshot is *captured* at the consistency point
//! and *persisted* in the background. [`CheckpointPipeline`] does exactly
//! that:
//!
//! * capture is synchronous and cheap — node snapshots / priority-row reads
//!   taken from the live backend at the save step;
//! * a writer thread owns the [`CheckpointStore`] mirror, applies captured
//!   data, and publishes durable files, while the trainer keeps stepping;
//! * full-node snapshot captures are **double-buffered**: at most two are
//!   in flight, so a slow writer exerts backpressure instead of letting
//!   snapshots pile up in memory;
//! * restores are request/reply over the same FIFO channel, so a restore
//!   observes every save submitted before it — the recovery protocol needs
//!   no extra synchronization.
//!
//! Crash-consistency rule (see [`super::disk`]): a checkpoint is only
//! *published* after the writer thread fsyncs the data file and then the
//! `LATEST` manifest; an interrupted save can never be observed.
//!
//! Quiesce contract: the synchronous captures (`snapshot_node`) and the
//! restore replies (`load_node`) run on the coordinator thread at a step
//! barrier, trainers parked behind the coordinator's
//! [`crate::cluster::PsQuiesce`] token; only the mirror application and
//! disk IO overlap training. The writer thread never touches the cluster.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::v2::V2Engine;
use super::writer_pool::WriterPool;
use super::{codec, disk, CheckpointOptions, CheckpointStore};
use crate::cluster::{NodeSnapshot, PsControlPlane, PsDataPlane};
use crate::config::{CkptCodec, CkptFormat};

/// How many full-cluster snapshot captures may be in flight at once.
const FULL_BUFFERS: usize = 2;

enum Msg {
    /// full-content save: captured snapshots of every node
    Nodes(Vec<NodeSnapshot>),
    /// priority-row save: captured rows of one table
    Rows { table: usize, rows: Vec<u32>, dim: usize, data: Vec<f32>, opt: Vec<f32> },
    /// advance the PLS position marker; publishes to disk when configured.
    /// `force_base` re-bases every node chain under format v2 (priority
    /// majors) and is a no-op under v1.
    Mark { mlp: Vec<Vec<f32>>, step: u64, samples: u64, force_base: bool },
    /// format v2: publish the mirror's dirty rows as deltas WITHOUT
    /// moving the position marker (a minor save's durability point).
    /// No-op under v1 / in-memory-only runs.
    Commit,
    GetNode { node: usize, reply: mpsc::Sender<NodeSnapshot> },
    GetStore { reply: mpsc::Sender<CheckpointStore> },
    /// position marker + dense params only — no mirror clone
    GetMark { reply: mpsc::Sender<(Vec<Vec<f32>>, u64, u64)> },
    Flush { ack: mpsc::Sender<()> },
}

/// Background checkpoint writer (see module docs).
pub struct CheckpointPipeline {
    tx: Option<SyncSender<Msg>>,
    worker: Option<JoinHandle<()>>,
    /// content saves submitted but not yet applied by the writer
    in_flight: Arc<AtomicUsize>,
    /// free full-snapshot buffers (double buffering)
    full_slots: Arc<(Mutex<usize>, Condvar)>,
    /// first IO error hit by the writer, surfaced by `flush`
    io_error: Arc<Mutex<Option<String>>>,
}

struct WriterCtx {
    store: CheckpointStore,
    /// v1 publication target (None = in-memory only or v2)
    dir: Option<PathBuf>,
    /// v2 publication engine (None = in-memory only or v1)
    engine: Option<V2Engine>,
    /// the engine's payload codec ([`CkptCodec::None`] when there is no
    /// engine): restores must reconstruct what a durable reload would —
    /// under a lossy codec that means quantized rows, so GetNode/GetStore
    /// replies round-trip embedding rows through the codec
    codec: CkptCodec,
    keep: usize,
    write_delay: Duration,
    in_flight: Arc<AtomicUsize>,
    full_slots: Arc<(Mutex<usize>, Condvar)>,
    io_error: Arc<Mutex<Option<String>>>,
}

impl WriterCtx {
    fn record_io_error(&self, e: anyhow::Error) {
        self.io_error
            .lock()
            .unwrap()
            .get_or_insert_with(|| format!("{e:#}"));
    }
}

fn writer_loop(mut ctx: WriterCtx, rx: Receiver<Msg>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Nodes(snaps) => {
                let _t = crate::telemetry::span("ckpt_apply");
                if !ctx.write_delay.is_zero() {
                    std::thread::sleep(ctx.write_delay);
                }
                for snap in snaps {
                    ctx.store.apply_node(snap);
                }
                ctx.in_flight.fetch_sub(1, Ordering::SeqCst);
                let (lock, cvar) = &*ctx.full_slots;
                *lock.lock().unwrap() += 1;
                cvar.notify_one();
            }
            Msg::Rows { table, rows, dim, data, opt } => {
                let _t = crate::telemetry::span("ckpt_apply");
                if !ctx.write_delay.is_zero() {
                    std::thread::sleep(ctx.write_delay);
                }
                ctx.store.apply_rows(table, &rows, dim, &data, &opt);
                ctx.in_flight.fetch_sub(1, Ordering::SeqCst);
            }
            Msg::Mark { mlp, step, samples, force_base } => {
                let _t = crate::telemetry::span("ckpt_publish");
                ctx.store.mark_position(mlp, step, samples);
                if let Some(engine) = ctx.engine.as_mut() {
                    if let Err(e) = engine.publish(&mut ctx.store, true, force_base) {
                        ctx.record_io_error(e);
                    }
                } else if let Some(dir) = &ctx.dir {
                    if let Err(e) = disk::publish(dir, &ctx.store, ctx.keep) {
                        ctx.record_io_error(e);
                    }
                }
            }
            Msg::Commit => {
                // minor-save durability point: dirty rows go out as
                // deltas, the marker (and its meta file) stay put
                let any_dirty = ctx
                    .store
                    .node_states()
                    .iter()
                    .any(|n| n.dirty_row_count() > 0);
                if let Some(engine) = ctx.engine.as_mut() {
                    if any_dirty {
                        let _t = crate::telemetry::span("ckpt_publish");
                        if let Err(e) = engine.publish(&mut ctx.store, false, false) {
                            ctx.record_io_error(e);
                        }
                    }
                }
            }
            Msg::GetNode { node, reply } => {
                let mut shards = ctx.store.node_shards(node).to_vec();
                // a restore from an encoded checkpoint reconstructs
                // quantized rows: hand recovery checkpoint-fidelity
                // values, not the fp32 mirror (opt state is lossless
                // under every codec, so it passes through untouched)
                if ctx.codec.lossy() {
                    for s in &mut shards {
                        codec::roundtrip_rows(ctx.codec, s);
                    }
                }
                let _ = reply.send(NodeSnapshot {
                    node,
                    shards,
                    opt: ctx.store.node_opt(node).to_vec(),
                });
            }
            Msg::GetStore { reply } => {
                let mut store = ctx.store.clone();
                if ctx.codec.lossy() {
                    for st in store.node_states_mut() {
                        for s in st.shards_mut() {
                            codec::roundtrip_rows(ctx.codec, s);
                        }
                    }
                }
                let _ = reply.send(store);
            }
            Msg::GetMark { reply } => {
                let _ = reply.send((ctx.store.mlp.clone(), ctx.store.step,
                                    ctx.store.samples));
            }
            Msg::Flush { ack } => {
                // a flush is the export barrier: push the writer thread's
                // buffered spans to the journal before acking, so an
                // export right after flush() sees them
                crate::telemetry::flush_thread();
                let _ = ack.send(());
            }
        }
    }
    crate::telemetry::flush_thread();
}

impl CheckpointPipeline {
    /// `store` is the initial mirror (epoch-0 state); everything else —
    /// publication dir, on-disk format, compaction threshold, payload
    /// codec, v1 rotation depth, test-only write delay — rides in one
    /// [`CheckpointOptions`] ([`CheckpointOptions::from_config`] is the
    /// production path). Under [`CkptFormat::V2`] the writer owns a
    /// [`V2Engine`]: position-marking saves publish the mirror's dirty
    /// rows as per-node delta files (bases when forced / chain-less /
    /// compaction-due), written — and codec-encoded — in parallel by the
    /// writer pool; [`CheckpointPipeline::commit_save`] publishes minors
    /// without moving the marker.
    pub fn with_options(store: CheckpointStore, opts: &CheckpointOptions) -> Result<Self> {
        let dir = match opts.dir.as_deref() {
            Some(d) => {
                let p = PathBuf::from(d);
                std::fs::create_dir_all(&p)?;
                Some(p)
            }
            None => None,
        };
        let (dir, engine) = match (opts.format, dir) {
            (_, None) => (None, None),
            (CkptFormat::V1, d) => (d, None),
            (CkptFormat::V2, Some(d)) => {
                let pool = WriterPool::for_nodes(store.node_states().len());
                (None, Some(V2Engine::open(&d, pool, opts.compact_frac, opts.codec)?))
            }
        };
        // the codec only shapes restores when something durable is
        // actually encoded with it: v1 publishes and in-memory-only runs
        // ignore the knob entirely
        let codec = if engine.is_some() { opts.codec } else { CkptCodec::None };
        let in_flight = Arc::new(AtomicUsize::new(0));
        let full_slots = Arc::new((Mutex::new(FULL_BUFFERS), Condvar::new()));
        let io_error = Arc::new(Mutex::new(None));
        let ctx = WriterCtx {
            store,
            dir,
            engine,
            codec,
            keep: opts.keep.max(1),
            write_delay: opts.write_delay,
            in_flight: Arc::clone(&in_flight),
            full_slots: Arc::clone(&full_slots),
            io_error: Arc::clone(&io_error),
        };
        let (tx, rx) = mpsc::sync_channel(64);
        let worker = std::thread::Builder::new()
            .name("ckpt-writer".into())
            .spawn(move || writer_loop(ctx, rx))
            .expect("spawning checkpoint writer");
        Ok(Self { tx: Some(tx), worker: Some(worker), in_flight, full_slots, io_error })
    }

    /// Positional v1 constructor, kept for downstream code.
    #[deprecated(note = "build a `CheckpointOptions` and call `with_options`")]
    pub fn new(
        store: CheckpointStore,
        dir: Option<&str>,
        keep: usize,
        write_delay: Duration,
    ) -> Result<Self> {
        Self::with_options(
            store,
            &CheckpointOptions {
                dir: dir.map(str::to_string),
                keep,
                write_delay,
                ..CheckpointOptions::default()
            },
        )
    }

    /// Positional format-selecting constructor, kept for downstream code.
    #[deprecated(note = "build a `CheckpointOptions` and call `with_options`")]
    pub fn with_format(
        store: CheckpointStore,
        dir: Option<&str>,
        keep: usize,
        write_delay: Duration,
        format: CkptFormat,
        compact_frac: f64,
    ) -> Result<Self> {
        Self::with_options(
            store,
            &CheckpointOptions {
                dir: dir.map(str::to_string),
                keep,
                write_delay,
                format,
                compact_frac,
                ..CheckpointOptions::default()
            },
        )
    }

    fn tx(&self) -> &SyncSender<Msg> {
        self.tx.as_ref().expect("pipeline already shut down")
    }

    fn send(&self, msg: Msg) {
        self.tx().send(msg).expect("checkpoint writer thread died");
    }

    /// Capture every node + the position marker and hand both to the
    /// writer. Blocks only if both snapshot buffers are still in flight
    /// (backpressure), never on the disk write itself.
    pub fn full_save<B: PsControlPlane + ?Sized>(
        &self,
        backend: &B,
        mlp: Vec<Vec<f32>>,
        step: u64,
        samples: u64,
    ) {
        let (lock, cvar) = &*self.full_slots;
        {
            let _w = crate::telemetry::span("ckpt_backpressure_wait");
            let mut slots = lock.lock().unwrap();
            while *slots == 0 {
                slots = cvar.wait(slots).unwrap();
            }
            *slots -= 1;
        }
        let snaps: Vec<NodeSnapshot> = {
            let _t = crate::telemetry::span("ckpt_capture");
            (0..backend.n_nodes()).map(|n| backend.snapshot_node(n)).collect()
        };
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.send(Msg::Nodes(snaps));
        self.send(Msg::Mark { mlp, step, samples, force_base: false });
    }

    /// Capture `rows` of `table` (priority save) and hand them to the
    /// writer. Does not move the position marker.
    pub fn save_rows<B: PsDataPlane + ?Sized>(&self, backend: &B, table: usize, rows: &[u32]) {
        let _t = crate::telemetry::span("ckpt_capture_rows");
        let dim = backend.tables()[table].dim;
        let (data, opt) = backend.read_rows(table, rows);
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.send(Msg::Rows { table, rows: rows.to_vec(), dim, data, opt });
    }

    /// Delta capture: read `rows` (global ids) of `table` grouped by
    /// owning node through the control plane's dirty-set export
    /// ([`PsControlPlane::snapshot_node_rows`]) — one per-node message,
    /// one node read guard each, never a full node clone. Content-wise
    /// identical to [`CheckpointPipeline::save_rows`]; the per-node
    /// grouping is what lets format v2 turn the capture into per-node
    /// delta files without re-routing.
    pub fn delta_save<B: PsControlPlane + ?Sized>(
        &self,
        backend: &B,
        table: usize,
        rows: &[u32],
    ) {
        let _t = crate::telemetry::span("ckpt_capture_rows");
        let dim = backend.tables()[table].dim;
        let n = backend.n_nodes();
        // carry (locals, globals) together so the mirror application uses
        // the caller's own ids — no inverse-routing pass to drift
        let mut per_node: Vec<(Vec<u32>, Vec<u32>)> = vec![(Vec::new(), Vec::new()); n];
        for &r in rows {
            let (node, local) = crate::cluster::route_row(r as usize, n);
            per_node[node].0.push(local as u32);
            per_node[node].1.push(r);
        }
        for (node, (locals, globals)) in per_node.into_iter().enumerate() {
            if locals.is_empty() {
                continue;
            }
            let (data, opt) = backend.snapshot_node_rows(node, table, &locals);
            self.in_flight.fetch_add(1, Ordering::SeqCst);
            self.send(Msg::Rows { table, rows: globals, dim, data, opt });
        }
    }

    /// Capture one whole (small) table.
    pub fn save_table<B: PsDataPlane + ?Sized>(&self, backend: &B, table: usize) {
        let rows: Vec<u32> = (0..backend.tables()[table].rows as u32).collect();
        self.save_rows(backend, table, &rows);
    }

    /// Advance the position marker (and publish, when a dir is configured).
    pub fn mark_position(&self, mlp: Vec<Vec<f32>>, step: u64, samples: u64) {
        self.send(Msg::Mark { mlp, step, samples, force_base: false });
    }

    /// Advance the position marker AND re-base every node chain (a
    /// priority *major* under format v2: deltas accumulated by the minors
    /// are folded into fresh bases). Identical to
    /// [`CheckpointPipeline::mark_position`] under v1.
    pub fn mark_position_base(&self, mlp: Vec<Vec<f32>>, step: u64, samples: u64) {
        self.send(Msg::Mark { mlp, step, samples, force_base: true });
    }

    /// Publish the mirror's dirty rows as per-node deltas without moving
    /// the position marker (a priority *minor*'s durability point under
    /// format v2). No-op under v1 or without a checkpoint dir.
    pub fn commit_save(&self) {
        self.send(Msg::Commit);
    }

    /// Partial recovery: fetch `node`'s mirror state (after all previously
    /// submitted saves have been applied — FIFO) and load it into the
    /// backend.
    pub fn restore_node<B: PsControlPlane + ?Sized>(&self, backend: &B, node: usize) {
        let _t = crate::telemetry::span_node("restore_node", node);
        let (reply_tx, reply_rx) = mpsc::channel();
        self.send(Msg::GetNode { node, reply: reply_tx });
        let snap = reply_rx.recv().expect("checkpoint writer died");
        backend.load_node(node, &snap.shards, &snap.opt);
    }

    /// Full recovery: restore every node from the mirror; returns
    /// (mlp, step, samples) for the trainer to rewind to.
    pub fn restore_all<B: PsControlPlane + ?Sized>(&self, backend: &B) -> (Vec<Vec<f32>>, u64, u64) {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.send(Msg::GetStore { reply: reply_tx });
        let store = reply_rx.recv().expect("checkpoint writer died");
        store.restore_all(backend)
    }

    /// The last marked position (mlp, step, samples) — read from the
    /// writer's mirror without touching the cluster and without cloning
    /// the (potentially huge) embedding mirror. Used by trainer-loss
    /// recovery when only the dense replica must reload (the Emb PS keeps
    /// its progress).
    pub fn marked_state(&self) -> (Vec<Vec<f32>>, u64, u64) {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.send(Msg::GetMark { reply: reply_tx });
        reply_rx.recv().expect("checkpoint writer died")
    }

    /// Content saves submitted but not yet applied by the writer.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Barrier: wait until every queued save is applied and published;
    /// surfaces the first writer IO error, if any.
    pub fn flush(&self) -> Result<()> {
        let (ack_tx, ack_rx) = mpsc::channel();
        self.send(Msg::Flush { ack: ack_tx });
        ack_rx.recv().map_err(|_| anyhow!("checkpoint writer died"))?;
        match self.io_error.lock().unwrap().take() {
            Some(e) => Err(anyhow!("checkpoint writer IO error: {e}")),
            None => Ok(()),
        }
    }
}

impl Drop for CheckpointPipeline {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; the writer drains and exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl CheckpointStore {
    /// Writer-thread accessors for request/reply restores.
    pub(crate) fn node_shards(&self, node: usize) -> &[Vec<f32>] {
        self.node_states()[node].shards()
    }

    pub(crate) fn node_opt(&self, node: usize) -> &[Vec<f32>] {
        self.node_states()[node].opt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{EmbOptimizer, PsCluster, TableInfo};

    fn cluster() -> PsCluster {
        PsCluster::new(
            vec![TableInfo { rows: 24, dim: 4 }, TableInfo { rows: 9, dim: 4 }],
            3,
            21,
        )
    }

    fn perturb(c: &PsCluster, seed: u64) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let idx: Vec<u32> = (0..12)
            .flat_map(|_| vec![rng.below(24) as u32, rng.below(9) as u32])
            .collect();
        let grads: Vec<f32> = (0..12 * 2 * 4).map(|_| rng.f32() - 0.5).collect();
        PsDataPlane::apply_grads(c, &idx, 1, &grads, 0.5, EmbOptimizer::Sgd);
    }

    fn pipeline(c: &PsCluster, delay_ms: u64) -> CheckpointPipeline {
        CheckpointPipeline::with_options(
            CheckpointStore::initial(c, vec![]),
            &CheckpointOptions {
                write_delay: Duration::from_millis(delay_ms),
                ..CheckpointOptions::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn restore_sees_state_at_capture_time_not_later_mutations() {
        let c = cluster();
        let p = pipeline(&c, 0);
        perturb(&c, 1);
        let at_capture = c.snapshot_node(0);
        p.full_save(&c, vec![], 1, 128);
        perturb(&c, 2); // training continues while the save is applied
        assert_ne!(c.snapshot_node(0).shards, at_capture.shards);
        p.restore_node(&c, 0);
        assert_eq!(c.snapshot_node(0).shards, at_capture.shards,
                   "restore must return the captured state");
    }

    #[test]
    fn row_saves_apply_in_submission_order() {
        let c = cluster();
        let p = pipeline(&c, 0);
        perturb(&c, 3);
        let older = c.snapshot_node(0);
        p.save_rows(&c, 0, &[0, 3, 6]); // rows on node 0
        perturb(&c, 4);
        p.save_rows(&c, 0, &[0]); // fresher save of row 0 queued after
        let fresh_row0 = {
            let (data, _) = c.read_rows(0, &[0]);
            data
        };
        perturb(&c, 5);
        p.restore_node(&c, 0);
        let (got0, _) = c.read_rows(0, &[0]);
        assert_eq!(got0, fresh_row0, "later save must win");
        let (got3, _) = c.read_rows(0, &[3]);
        assert_eq!(&got3[..], &older.shards[0][4..8], "row 3 from older save");
    }

    #[test]
    fn restore_all_returns_marked_position() {
        let c = cluster();
        let p = pipeline(&c, 0);
        perturb(&c, 6);
        p.full_save(&c, vec![vec![7.0, 8.0]], 40, 5120);
        perturb(&c, 7);
        let golden = c.snapshot_node(1);
        p.full_save(&c, vec![vec![9.0]], 80, 10240);
        perturb(&c, 8);
        let (mlp, step, samples) = p.restore_all(&c);
        assert_eq!(mlp, vec![vec![9.0]]);
        assert_eq!((step, samples), (80, 10240));
        assert_eq!(c.snapshot_node(1).shards, golden.shards);
    }

    #[test]
    fn marked_state_reads_position_without_touching_cluster() {
        let c = cluster();
        let p = pipeline(&c, 0);
        perturb(&c, 10);
        let live = c.snapshot_node(0);
        p.full_save(&c, vec![vec![4.25]], 7, 896);
        let (mlp, step, samples) = p.marked_state();
        assert_eq!(mlp, vec![vec![4.25]]);
        assert_eq!((step, samples), (7, 896));
        assert_eq!(c.snapshot_node(0).shards, live.shards,
                   "marked_state must not mutate the cluster");
    }

    #[test]
    fn save_overlaps_other_work_without_blocking() {
        let c = cluster();
        let p = pipeline(&c, 300);
        let t0 = std::time::Instant::now();
        p.full_save(&c, vec![], 1, 128);
        assert!(t0.elapsed() < Duration::from_millis(250),
                "submit must not block on the write");
        assert!(p.in_flight() > 0, "save should still be in flight");
        p.flush().unwrap();
        assert_eq!(p.in_flight(), 0);
    }

    #[test]
    fn third_full_save_backpressures_on_double_buffer() {
        let c = cluster();
        let p = pipeline(&c, 120);
        let t0 = std::time::Instant::now();
        p.full_save(&c, vec![], 1, 128);
        p.full_save(&c, vec![], 2, 256);
        p.full_save(&c, vec![], 3, 384); // must wait for a free buffer
        assert!(t0.elapsed() >= Duration::from_millis(100),
                "third capture should have waited for the writer");
        p.flush().unwrap();
    }

    #[test]
    fn delta_save_captures_like_save_rows() {
        let c = cluster();
        let p = pipeline(&c, 0);
        perturb(&c, 20);
        let rows = [0u32, 3, 6, 1, 4]; // nodes 0 and 1
        let (want, want_opt) = c.read_rows(0, &rows);
        p.delta_save(&c, 0, &rows);
        perturb(&c, 21);
        for node in 0..3 {
            p.restore_node(&c, node);
        }
        let (got, got_opt) = c.read_rows(0, &rows);
        assert_eq!(got, want, "delta capture must mirror the captured rows");
        assert_eq!(got_opt, want_opt, "optimizer state rides with delta rows");
        p.flush().unwrap();
    }

    #[test]
    fn v2_minors_publish_deltas_and_majors_rebase() {
        let dir = std::env::temp_dir().join("cpr_pipeline_v2");
        std::fs::remove_dir_all(&dir).ok();
        let c = cluster();
        let p = CheckpointPipeline::with_options(
            CheckpointStore::initial(&c, vec![]),
            &CheckpointOptions {
                dir: Some(dir.to_str().unwrap().to_string()),
                format: CkptFormat::V2,
                ..CheckpointOptions::default()
            },
        )
        .unwrap();
        // minor #1: first durable publish → every node gets a base
        perturb(&c, 30);
        p.delta_save(&c, 0, &[0, 3]);
        p.commit_save();
        p.flush().unwrap();
        let m1 = crate::checkpoint::v2::read_manifest(&dir).unwrap().unwrap();
        assert_eq!(m1.chains.len(), 3);
        assert!(m1.chains.iter().all(|ch| ch.deltas.is_empty()));
        // minor #2: only node 0's rows dirty → one delta, marker untouched
        perturb(&c, 31);
        p.delta_save(&c, 0, &[0, 3]);
        p.commit_save();
        p.flush().unwrap();
        let m2 = crate::checkpoint::v2::read_manifest(&dir).unwrap().unwrap();
        assert_eq!(m2.chains[0].deltas.len(), 1, "minor publishes a delta");
        assert!(m2.chains[1].deltas.is_empty(), "clean nodes publish nothing");
        assert_eq!(m2.meta, m1.meta, "minors do not move the position marker");
        // major: marker advances AND every chain folds into a fresh base
        p.mark_position_base(vec![vec![5.0]], 9, 1152);
        p.flush().unwrap();
        let m3 = crate::checkpoint::v2::read_manifest(&dir).unwrap().unwrap();
        assert!(m3.chains.iter().all(|ch| ch.deltas.is_empty()),
                "a major re-bases every chain");
        assert_ne!(m3.meta, m2.meta, "majors move the marker");
        let latest = super::disk::DiskCheckpointer::load_latest(dir.to_str().unwrap())
            .unwrap()
            .expect("published v2 checkpoint");
        assert_eq!(latest.step, 9);
        assert_eq!(latest.mlp, vec![vec![5.0]]);
        // the delta-saved rows came back through the chain
        let (cur, _) = c.read_rows(0, &[0, 3]);
        assert_eq!(&latest.node_states()[0].shards()[0][0..4], &cur[0..4]);
        assert_eq!(&latest.node_states()[0].shards()[0][4..8], &cur[4..8]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lossy_codec_restores_quantized_rows_exact_opt_state() {
        // with a q8 engine, a restore must reproduce what a durable
        // reload of the encoded chain would: quantized embedding rows,
        // bit-exact optimizer state and marker. Without a lossy codec
        // the same sequence is bit-identical to the mirror (the golden
        // suites rely on that).
        let dir = std::env::temp_dir().join("cpr_pipeline_q8");
        std::fs::remove_dir_all(&dir).ok();
        let c = cluster();
        let p = CheckpointPipeline::with_options(
            CheckpointStore::initial(&c, vec![]),
            &CheckpointOptions {
                dir: Some(dir.to_str().unwrap().to_string()),
                format: CkptFormat::V2,
                codec: CkptCodec::Q8,
                ..CheckpointOptions::default()
            },
        )
        .unwrap();
        perturb(&c, 44);
        let at_capture = c.snapshot_node(0);
        p.full_save(&c, vec![], 1, 128);
        p.flush().unwrap();
        p.restore_node(&c, 0);
        let got = c.snapshot_node(0);
        assert_eq!(got.opt, at_capture.opt, "opt state is lossless under q8");
        for (t, shard) in got.shards.iter().enumerate() {
            let mut want = at_capture.shards[t].clone();
            codec::roundtrip_rows(CkptCodec::Q8, &mut want);
            assert_eq!(shard, &want, "restored rows carry checkpoint fidelity");
            assert_ne!(shard, &at_capture.shards[t],
                       "q8 restore must actually differ from the fp32 mirror");
        }
        // and the durable chain agrees with what the restore handed back
        let durable = super::disk::DiskCheckpointer::load_latest(dir.to_str().unwrap())
            .unwrap()
            .unwrap();
        assert_eq!(durable.node_states()[0].shards(), got.shards.as_slice());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_constructor_shims_still_work() {
        let c = cluster();
        let p = CheckpointPipeline::new(
            CheckpointStore::initial(&c, vec![]),
            None,
            2,
            Duration::ZERO,
        )
        .unwrap();
        p.full_save(&c, vec![], 1, 128);
        p.flush().unwrap();
        let dir = std::env::temp_dir().join("cpr_pipeline_shim");
        std::fs::remove_dir_all(&dir).ok();
        let p2 = CheckpointPipeline::with_format(
            CheckpointStore::initial(&c, vec![]),
            Some(dir.to_str().unwrap()),
            2,
            Duration::ZERO,
            CkptFormat::V2,
            0.5,
        )
        .unwrap();
        p2.full_save(&c, vec![], 2, 256);
        p2.flush().unwrap();
        assert!(dir.join(crate::checkpoint::v2::MANIFEST).exists());
        drop(p2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn publishes_durable_checkpoint_on_mark() {
        let dir = std::env::temp_dir().join("cpr_pipeline_pub");
        std::fs::remove_dir_all(&dir).ok();
        let c = cluster();
        let p = CheckpointPipeline::with_options(
            CheckpointStore::initial(&c, vec![]),
            &CheckpointOptions {
                dir: Some(dir.to_str().unwrap().to_string()),
                ..CheckpointOptions::default()
            },
        )
        .unwrap();
        perturb(&c, 9);
        p.full_save(&c, vec![vec![1.0]], 10, 1280);
        p.flush().unwrap();
        let latest = super::disk::DiskCheckpointer::load_latest(dir.to_str().unwrap())
            .unwrap()
            .expect("published checkpoint missing");
        assert_eq!(latest.step, 10);
        assert_eq!(latest.mlp, vec![vec![1.0]]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
