//! [`WriterPool`] — the parallel per-node checkpoint writer.
//!
//! Format v2 publishes one file *per node* (a base or a delta — see
//! [`super::v2`]), and node files are independent until the manifest
//! names them, so there is no reason to serialize their encoding + fsync
//! behind the single pipeline writer thread. The pool runs one write job
//! per node with up to `threads` workers: **one in-flight publish per
//! node, nodes in parallel** — the publish batch's jobs never contain two
//! jobs for the same node, and [`WriterPool::run`] is a barrier, so the
//! next publish cannot overlap the previous one.
//!
//! Jobs borrow the caller's data (the pipeline's mirror [`super::ShardState`]s)
//! via scoped threads — no node state is cloned to cross the pool
//! boundary. Each job returns the bytes it wrote; the first error wins
//! and fails the whole batch (the caller then skips the manifest update,
//! leaving the previous durable chain published — the crash-consistency
//! rule holds for IO errors exactly as for crashes).
//!
//! The codec stage (ISSUE 7) runs *inside* these jobs: when the engine
//! carries a payload codec ([`super::codec`]), each job quantizes /
//! compresses its own node's payload before writing, so encoding
//! parallelizes across nodes exactly like the raw fp32 serialization it
//! replaces. The job's returned byte count is the **encoded** size —
//! that is what reaches `bytes_written` telemetry and the compaction
//! ledger.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

/// One write job: encode + durably write one node's base/delta file,
/// returning the bytes written. Borrows from the caller (`'a`).
pub type WriteJob<'a> = Box<dyn FnOnce() -> Result<u64> + Send + 'a>;

/// Bounded pool of checkpoint write workers (see module docs).
pub struct WriterPool {
    threads: usize,
}

impl WriterPool {
    /// A pool running at most `threads` jobs concurrently (min 1).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// A pool sized for `n_nodes` node files on this host: one worker per
    /// node, capped at the parallelism the machine offers.
    pub fn for_nodes(n_nodes: usize) -> Self {
        let cap = std::thread::available_parallelism()
            .map(std::num::NonZeroUsize::get)
            .unwrap_or(4);
        Self::new(n_nodes.clamp(1, cap))
    }

    /// Worker cap.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every job to completion (a barrier), up to `threads` at a
    /// time, and return the per-job bytes written **in job order**. The
    /// first job error fails the batch (remaining jobs still run — a
    /// failed batch must not leave half the pool's work silently
    /// unattempted when the caller retries).
    pub fn run(&self, jobs: Vec<WriteJob<'_>>) -> Result<Vec<u64>> {
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        let n_workers = self.threads.min(jobs.len());
        if n_workers == 1 {
            return jobs.into_iter().map(|j| j()).collect();
        }
        let n_jobs = jobs.len();
        let queue: Vec<Mutex<Option<WriteJob<'_>>>> =
            jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
        let results: Vec<Mutex<Option<Result<u64>>>> =
            (0..n_jobs).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..n_workers {
                s.spawn(|| {
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n_jobs {
                            break;
                        }
                        let job = queue[i]
                            .lock()
                            .unwrap()
                            .take()
                            .expect("each job is claimed exactly once");
                        let out = {
                            let _t = crate::telemetry::span("ckpt_pool_job");
                            job()
                        };
                        *results[i].lock().unwrap() = Some(out);
                    }
                    // pool workers are short-lived scoped threads: push
                    // their buffered spans to the journal before exit
                    crate::telemetry::flush_thread();
                });
            }
        });
        results
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap()
                    .expect("every claimed job stores its result")
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn results_come_back_in_job_order() {
        let pool = WriterPool::new(3);
        let jobs: Vec<WriteJob<'_>> = (0..10u64)
            .map(|i| Box::new(move || Ok(i * 100)) as WriteJob<'_>)
            .collect();
        let got = pool.run(jobs).unwrap();
        assert_eq!(got, (0..10u64).map(|i| i * 100).collect::<Vec<_>>());
    }

    #[test]
    fn empty_batch_is_a_noop() {
        assert_eq!(WriterPool::new(4).run(Vec::new()).unwrap(), Vec::<u64>::new());
    }

    #[test]
    fn first_error_fails_the_batch() {
        let pool = WriterPool::new(2);
        let jobs: Vec<WriteJob<'_>> = vec![
            Box::new(|| Ok(1)),
            Box::new(|| anyhow::bail!("disk full")),
            Box::new(|| Ok(3)),
        ];
        let err = pool.run(jobs).unwrap_err();
        assert!(format!("{err:#}").contains("disk full"));
    }

    #[test]
    fn jobs_overlap_across_workers() {
        // 4 × 60 ms jobs on 4 workers must beat the 240 ms serial time by
        // a wide margin
        let pool = WriterPool::new(4);
        let jobs: Vec<WriteJob<'_>> = (0..4)
            .map(|_| {
                Box::new(|| {
                    std::thread::sleep(Duration::from_millis(60));
                    Ok(0)
                }) as WriteJob<'_>
            })
            .collect();
        let t0 = Instant::now();
        pool.run(jobs).unwrap();
        assert!(t0.elapsed() < Duration::from_millis(200),
                "pool must run node writes in parallel");
    }

    #[test]
    fn jobs_borrow_caller_state_without_cloning() {
        let data: Vec<u64> = (0..100).collect();
        let pool = WriterPool::new(4);
        let jobs: Vec<WriteJob<'_>> = data
            .chunks(25)
            .map(|chunk| Box::new(move || Ok(chunk.iter().sum())) as WriteJob<'_>)
            .collect();
        let got = pool.run(jobs).unwrap();
        assert_eq!(got.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn pool_never_exceeds_its_worker_cap() {
        use std::sync::atomic::AtomicUsize;
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let pool = WriterPool::new(2);
        let jobs: Vec<WriteJob<'_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(10));
                    live.fetch_sub(1, Ordering::SeqCst);
                    Ok(0)
                }) as WriteJob<'_>
            })
            .collect();
        pool.run(jobs).unwrap();
        assert!(peak.load(Ordering::SeqCst) <= 2,
                "observed {} concurrent jobs on a 2-worker pool",
                peak.load(Ordering::SeqCst));
    }
}
