//! Priority trackers: decide *which* embedding rows deserve checkpoint
//! bandwidth (paper §4.2).
//!
//! * [`ScarTracker`] — prior work's heuristic (Qiao et al. 2019): rank rows
//!   by the L2 norm of their accumulated change since last save. Faithful
//!   implementation: keeps a full mirror of the last-saved values of every
//!   priority table — the 100% memory overhead the paper criticizes
//!   (Table 1).
//! * [`MfuTracker`] — CPR-MFU: a 4-byte access counter per row (0.78–6.25%
//!   of table memory), cleared when a row is saved. Access frequency is an
//!   excellent proxy for update magnitude (corr ≈ 0.983, Fig. 6).
//! * [`SsuTracker`] — CPR-SSU: sub-sample every `period`-th access into a
//!   bounded candidate list with random eviction (memory r× MFU's, time
//!   O(N)); the subsampling acts as a high-pass filter on access frequency.
//!
//! Top-k selection uses `select_nth_unstable` — O(N) rather than the
//! O(N log N) the paper budgets for SCAR/MFU (a free improvement, see
//! EXPERIMENTS.md §Perf).

use std::collections::HashSet;

use crate::cluster::{PlanAccess, PsDataPlane};
use crate::util::rng::Rng;

/// Which tables a tracker prioritizes: the `priority_tables` largest ones
/// (paper: 7 of 26, ≈99.6% of rows). Returns a mask over table ids.
pub fn priority_mask(table_rows: &[usize], priority_tables: usize) -> Vec<bool> {
    let mut order: Vec<usize> = (0..table_rows.len()).collect();
    order.sort_by_key(|&t| std::cmp::Reverse(table_rows[t]));
    let mut mask = vec![false; table_rows.len()];
    for &t in order.iter().take(priority_tables.min(order.len())) {
        mask[t] = true;
    }
    mask
}

// ---------------------------------------------------------------------------
// MFU
// ---------------------------------------------------------------------------

/// CPR-MFU: per-row u32 access counters on priority tables.
pub struct MfuTracker {
    /// counters[table] — empty vec for non-priority tables
    counters: Vec<Vec<u32>>,
    mask: Vec<bool>,
}

impl MfuTracker {
    pub fn new(table_rows: &[usize], mask: &[bool]) -> Self {
        let counters = table_rows
            .iter()
            .zip(mask)
            .map(|(&rows, &on)| if on { vec![0u32; rows] } else { Vec::new() })
            .collect();
        Self { counters, mask: mask.to_vec() }
    }

    /// Record one minibatch of accesses. `indices` is [B, T] row-major.
    pub fn record_batch(&mut self, indices: &[u32], num_tables: usize) {
        self.record_batch_hot(indices, num_tables, 1);
    }

    /// Multi-hot variant: `indices` is [B, T, H] row-major.
    pub fn record_batch_hot(&mut self, indices: &[u32], num_tables: usize,
                            hotness: usize) {
        for chunk in indices.chunks_exact(num_tables * hotness) {
            for (slot, &row) in chunk.iter().enumerate() {
                let t = slot / hotness;
                if self.mask[t] {
                    self.counters[t][row as usize] += 1;
                }
            }
        }
    }

    /// Planned variant of [`MfuTracker::record_batch_hot`]: consume the
    /// batch plan's deduplicated access list, bumping each row's counter
    /// by its within-batch multiplicity. `counter += count` is bit-exact
    /// against `count` repetitions of `counter += 1` (u32 addition), so
    /// MFU selections under planned recording match the full scan — the
    /// plan-equivalence suite pins this through a whole training run.
    pub fn record_accesses(&mut self, accesses: &[PlanAccess]) {
        for a in accesses {
            let t = a.table as usize;
            if self.mask[t] {
                self.counters[t][a.row as usize] += a.count;
            }
        }
    }

    /// The `k` most-frequently-used rows of `table` (arbitrary order).
    pub fn top_k(&self, table: usize, k: usize) -> Vec<u32> {
        debug_assert!(self.mask[table]);
        let c = &self.counters[table];
        let mut rows: Vec<u32> = (0..c.len() as u32).collect();
        if k >= rows.len() {
            return rows;
        }
        // O(N) selection of the k largest by count
        rows.select_nth_unstable_by_key(k, |&r| {
            std::cmp::Reverse(c[r as usize])
        });
        rows.truncate(k);
        rows
    }

    /// Paper: "when an embedding vector is saved, its counter is cleared."
    pub fn clear_rows(&mut self, table: usize, rows: &[u32]) {
        for &r in rows {
            self.counters[table][r as usize] = 0;
        }
    }

    pub fn count(&self, table: usize, row: u32) -> u32 {
        self.counters[table][row as usize]
    }

    /// Tracker memory (Table 1): 4 bytes per priority-table row.
    pub fn memory_bytes(&self) -> usize {
        self.counters.iter().map(|c| c.len() * 4).sum()
    }
}

// ---------------------------------------------------------------------------
// SSU
// ---------------------------------------------------------------------------

/// CPR-SSU: bounded candidate list per priority table.
pub struct SsuTracker {
    lists: Vec<SsuList>,
    mask: Vec<bool>,
    period: usize,
    tick: usize,
    rng: Rng,
}

struct SsuList {
    set: HashSet<u32>,
    vec: Vec<u32>,
    cap: usize,
}

impl SsuList {
    fn insert(&mut self, row: u32, rng: &mut Rng) {
        if self.cap == 0 || !self.set.insert(row) {
            return;
        }
        if self.vec.len() < self.cap {
            self.vec.push(row);
        } else {
            // random eviction of an existing entry (paper: "randomly
            // discards the overflowing entries")
            let slot = rng.usize_below(self.vec.len());
            let evicted = self.vec[slot];
            self.set.remove(&evicted);
            self.vec[slot] = row;
        }
    }
}

impl SsuTracker {
    /// `caps[t]` = list capacity for table t (≈ r·rows); `period` = the
    /// access subsampling period (paper uses 2).
    pub fn new(caps: &[usize], mask: &[bool], period: usize, seed: u64) -> Self {
        assert!(period >= 1);
        let lists = caps
            .iter()
            .zip(mask)
            .map(|(&cap, &on)| SsuList {
                set: HashSet::new(),
                vec: Vec::new(),
                cap: if on { cap } else { 0 },
            })
            .collect();
        Self { lists, mask: mask.to_vec(), period, tick: 0, rng: Rng::new(seed) }
    }

    pub fn record_batch(&mut self, indices: &[u32], num_tables: usize) {
        self.record_batch_hot(indices, num_tables, 1);
    }

    /// Multi-hot variant: `indices` is [B, T, H] row-major.
    pub fn record_batch_hot(&mut self, indices: &[u32], num_tables: usize,
                            hotness: usize) {
        for chunk in indices.chunks_exact(num_tables * hotness) {
            for (slot, &row) in chunk.iter().enumerate() {
                let t = slot / hotness;
                if !self.mask[t] {
                    continue;
                }
                self.tick += 1;
                if self.tick % self.period == 0 {
                    let list = &mut self.lists[t];
                    // borrow dance: rng and lists are disjoint fields
                    let rng = &mut self.rng;
                    list.insert(row, rng);
                }
            }
        }
    }

    /// Take the current candidate list for `table`, clearing it.
    pub fn drain(&mut self, table: usize) -> Vec<u32> {
        let list = &mut self.lists[table];
        list.set.clear();
        std::mem::take(&mut list.vec)
    }

    pub fn len(&self, table: usize) -> usize {
        self.lists[table].vec.len()
    }

    /// Tracker memory (Table 1): 4 bytes per list slot (+ set, counted at
    /// 4 bytes too for the analytic table).
    pub fn memory_bytes(&self) -> usize {
        self.lists.iter().map(|l| l.cap * 4).sum()
    }
}

// ---------------------------------------------------------------------------
// SCAR
// ---------------------------------------------------------------------------

/// SCAR (prior work): rank rows by L2 change since their last save.
/// Holds a full mirror of priority tables — 100% memory overhead.
pub struct ScarTracker {
    /// last_saved[table] — full row-major mirror, empty for non-priority
    last_saved: Vec<Vec<f32>>,
    mask: Vec<bool>,
    dims: Vec<usize>,
}

impl ScarTracker {
    // Reads go through the batched `PsDataPlane::read_rows` (one message per
    // PS node), never per-row `read_row` — on the threaded backend the
    // latter would be a channel round trip per row of every priority table.

    pub fn new<B: PsDataPlane + ?Sized>(cluster: &B, mask: &[bool]) -> Self {
        let tables = cluster.tables();
        let mut last_saved = Vec::with_capacity(tables.len());
        let dims: Vec<usize> = tables.iter().map(|t| t.dim).collect();
        for (t, info) in tables.iter().enumerate() {
            if mask[t] {
                last_saved.push(read_full_table(cluster, t, info.rows));
            } else {
                last_saved.push(Vec::new());
            }
        }
        Self { last_saved, mask: mask.to_vec(), dims }
    }

    /// The `k` rows of `table` with the largest change-L2 since last save.
    pub fn top_k<B: PsDataPlane + ?Sized>(&self, cluster: &B, table: usize, k: usize) -> Vec<u32> {
        debug_assert!(self.mask[table]);
        let dim = self.dims[table];
        let mirror = &self.last_saved[table];
        let rows = mirror.len() / dim;
        let cur = read_full_table(cluster, table, rows);
        let mut scored: Vec<(f32, u32)> = (0..rows)
            .map(|r| {
                let now = &cur[r * dim..(r + 1) * dim];
                let base = &mirror[r * dim..(r + 1) * dim];
                let norm2: f32 = now.iter().zip(base)
                    .map(|(a, b)| (a - b) * (a - b)).sum();
                (norm2, r as u32)
            })
            .collect();
        if k >= scored.len() {
            return scored.into_iter().map(|(_, r)| r).collect();
        }
        scored.select_nth_unstable_by(k, |a, b| b.0.partial_cmp(&a.0).unwrap());
        scored.truncate(k);
        scored.into_iter().map(|(_, r)| r).collect()
    }

    /// After saving `rows` of `table`, refresh their mirror entries.
    pub fn mark_saved<B: PsDataPlane + ?Sized>(&mut self, cluster: &B, table: usize, rows: &[u32]) {
        let dim = self.dims[table];
        let mirror = &mut self.last_saved[table];
        let (data, _) = cluster.read_rows(table, rows);
        for (i, &r) in rows.iter().enumerate() {
            mirror[r as usize * dim..(r as usize + 1) * dim]
                .copy_from_slice(&data[i * dim..(i + 1) * dim]);
        }
    }

    /// Table 1: full mirror = 100% of priority-table memory.
    pub fn memory_bytes(&self) -> usize {
        self.last_saved.iter().map(|m| m.len() * 4).sum()
    }
}

/// All of `table`'s rows in row-major order via one batched read.
fn read_full_table<B: PsDataPlane + ?Sized>(cluster: &B, table: usize, rows: usize) -> Vec<f32> {
    let ids: Vec<u32> = (0..rows as u32).collect();
    cluster.read_rows(table, &ids).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{PsCluster, TableInfo};
    use crate::prop_assert;
    use crate::testing::{forall, gen};

    fn cluster2() -> PsCluster {
        PsCluster::new(
            vec![TableInfo { rows: 100, dim: 4 }, TableInfo { rows: 10, dim: 4 }],
            4,
            7,
        )
    }

    #[test]
    fn priority_mask_picks_largest() {
        let mask = priority_mask(&[10, 500, 20, 400, 5], 2);
        assert_eq!(mask, vec![false, true, false, true, false]);
    }

    #[test]
    fn mfu_counts_and_selects() {
        let mask = vec![true, false];
        let mut t = MfuTracker::new(&[100, 10], &mask);
        // batch of 3 samples, 2 tables; table-0 rows 5,5,9
        t.record_batch(&[5, 0, 5, 1, 9, 2], 2);
        assert_eq!(t.count(0, 5), 2);
        assert_eq!(t.count(0, 9), 1);
        let top = t.top_k(0, 1);
        assert_eq!(top, vec![5]);
        let top2 = t.top_k(0, 2);
        assert!(top2.contains(&5) && top2.contains(&9));
        t.clear_rows(0, &[5]);
        assert_eq!(t.count(0, 5), 0);
        assert_eq!(t.top_k(0, 1), vec![9]);
    }

    #[test]
    fn mfu_planned_recording_matches_the_full_scan() {
        let mask = vec![true, false];
        let mut scan = MfuTracker::new(&[100, 10], &mask);
        let mut planned = MfuTracker::new(&[100, 10], &mask);
        // 3 samples × 2 tables: table-0 rows {5:2, 9:1}, table-1 masked off
        scan.record_batch(&[5, 0, 5, 1, 9, 2], 2);
        planned.record_accesses(&[
            PlanAccess { table: 0, row: 5, count: 2 },
            PlanAccess { table: 0, row: 9, count: 1 },
            PlanAccess { table: 1, row: 0, count: 1 },
            PlanAccess { table: 1, row: 1, count: 1 },
            PlanAccess { table: 1, row: 2, count: 1 },
        ]);
        for r in 0..100 {
            assert_eq!(scan.count(0, r), planned.count(0, r), "row {r}");
        }
    }

    #[test]
    fn mfu_memory_is_4_bytes_per_priority_row() {
        let t = MfuTracker::new(&[100, 10], &[true, false]);
        assert_eq!(t.memory_bytes(), 400);
    }

    #[test]
    fn mfu_top_k_is_truly_the_top() {
        forall(31, 50, |rng| {
            let rows = gen::usize_in(rng, 10, 200);
            let mut t = MfuTracker::new(&[rows], &[true]);
            let accesses: Vec<u32> =
                (0..500).map(|_| rng.below(rows as u64) as u32).collect();
            t.record_batch(&accesses, 1);
            let k = gen::usize_in(rng, 1, rows);
            let top = t.top_k(0, k);
            prop_assert!(top.len() == k.min(rows));
            let min_top = top.iter().map(|&r| t.count(0, r)).min().unwrap();
            // every non-selected row must not beat the weakest selected
            let sel: std::collections::HashSet<u32> = top.iter().copied().collect();
            for r in 0..rows as u32 {
                if !sel.contains(&r) {
                    prop_assert!(t.count(0, r) <= min_top,
                                 "row {r} beat the selection");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ssu_subsamples_and_bounds() {
        let mask = vec![true];
        let mut t = SsuTracker::new(&[5], &mask, 2, 1);
        // 20 accesses to distinct rows; period 2 → ~10 inserts; cap 5
        let idx: Vec<u32> = (0..20).collect();
        t.record_batch(&idx, 1);
        assert!(t.len(0) <= 5);
        let drained = t.drain(0);
        assert!(drained.len() <= 5);
        assert_eq!(t.len(0), 0);
        // no duplicates
        let set: std::collections::HashSet<_> = drained.iter().collect();
        assert_eq!(set.len(), drained.len());
    }

    #[test]
    fn ssu_prefers_frequent_rows() {
        // row 0 is accessed 50% of the time; it should essentially always
        // be present in the drained list
        let mut present = 0;
        for seed in 0..20 {
            let mut t = SsuTracker::new(&[8], &[true], 2, seed);
            let mut rng = Rng::new(seed ^ 0xABC);
            let idx: Vec<u32> = (0..400)
                .map(|_| if rng.bool_with(0.5) { 0 } else { 1 + rng.below(200) as u32 })
                .collect();
            t.record_batch(&idx, 1);
            if t.drain(0).contains(&0) {
                present += 1;
            }
        }
        assert!(present >= 18, "hot row present in only {present}/20 runs");
    }

    #[test]
    fn ssu_ignores_non_priority_tables() {
        let mut t = SsuTracker::new(&[5, 5], &[false, true], 1, 1);
        t.record_batch(&[1, 2], 2);
        assert_eq!(t.len(0), 0);
        assert_eq!(t.len(1), 1);
    }

    #[test]
    fn scar_ranks_by_change_magnitude() {
        let c = cluster2();
        let mask = vec![true, false];
        let mut scar = ScarTracker::new(&c, &mask);
        // change row 42 a lot, row 7 a little
        let idx = vec![42, 0, 7, 0];
        let mut grads = vec![0.0f32; 2 * 2 * 4];
        grads[0..4].copy_from_slice(&[10.0, 10.0, 10.0, 10.0]); // row 42
        grads[8..12].copy_from_slice(&[0.1, 0.1, 0.1, 0.1]); // row 7
        c.sgd_update(&idx, &grads, 1.0);
        let top = scar.top_k(&c, 0, 1);
        assert_eq!(top, vec![42]);
        let top2 = scar.top_k(&c, 0, 2);
        assert!(top2.contains(&42) && top2.contains(&7));
        // after saving row 42, its change resets; row 7 should rank first
        scar.mark_saved(&c, 0, &[42]);
        assert_eq!(scar.top_k(&c, 0, 1), vec![7]);
    }

    #[test]
    fn scar_memory_is_full_mirror() {
        let c = cluster2();
        let scar = ScarTracker::new(&c, &[true, false]);
        assert_eq!(scar.memory_bytes(), 100 * 4 * 4); // rows*dim*sizeof(f32)
    }

    #[test]
    fn tracker_memory_ordering_matches_table1() {
        // SCAR (100%) > MFU (1/dim) > SSU (r/dim)
        let c = PsCluster::new(vec![TableInfo { rows: 1000, dim: 16 }], 2, 1);
        let mask = vec![true];
        let scar = ScarTracker::new(&c, &mask);
        let mfu = MfuTracker::new(&[1000], &mask);
        let ssu = SsuTracker::new(&[125], &mask, 2, 0);
        let table_bytes = 1000 * 16 * 4;
        assert_eq!(scar.memory_bytes(), table_bytes);
        assert_eq!(mfu.memory_bytes() * 16, table_bytes); // 6.25% at dim 16
        assert!(ssu.memory_bytes() * 8 == mfu.memory_bytes()); // r = 0.125
    }
}
