//! `cpr` — launcher CLI for the CPR training system.
//!
//! Subcommands:
//!   train   run one emulated training job under a recovery strategy
//!   plan    print the CPR controller's decision for a cluster config
//!   fleet   run the production-fleet overhead simulation (Fig. 4)
//!   scale   print the scalability projection (Fig. 13)
//!
//! Examples:
//!   cpr train --preset mini --strategy cpr-ssu --failures 2 --fail-frac 0.25
//!   cpr train --config job.toml
//!   cpr plan --preset kaggle_like --target-pls 0.1
//!   cpr fleet --jobs 17000
//!   cpr scale --model linear

use anyhow::{bail, Result};

use cpr::config::{preset, CkptCodec, CkptFormat, JobConfig, PsBackendKind, Strategy};
use cpr::coordinator::{run_training, RunOptions, TrainReport};
use cpr::failure::{trainer_schedule, uniform_schedule};
use cpr::runtime::Runtime;
use cpr::util::cli::Cli;
use cpr::util::rng::Rng;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().map(String::as_str) else {
        bail!("usage: cpr <train|plan|fleet|scale> [options]  (--help per command)");
    };
    let rest = &args[1..];
    match cmd {
        "train" => cmd_train(rest),
        "plan" => cmd_plan(rest),
        "fleet" => cmd_fleet(rest),
        "scale" => cmd_scale(rest),
        other => bail!("unknown command {other:?} (train|plan|fleet|scale)"),
    }
}

fn job_config_from(cli: &Cli) -> Result<JobConfig> {
    let mut cfg = if cli.get("config").is_empty() {
        preset(cli.get("preset"))?
    } else {
        JobConfig::from_toml_file(cli.get("config"))?
    };
    if !cli.get("strategy").is_empty() {
        cfg.checkpoint.strategy = Strategy::parse(cli.get("strategy"))?;
    }
    if !cli.get("target-pls").is_empty() {
        cfg.checkpoint.target_pls = cli.get_f64("target-pls")?;
    }
    if !cli.get("n-emb").is_empty() {
        cfg.cluster.n_emb_ps = cli.get_usize("n-emb")?;
    }
    if !cli.get("trainers").is_empty() {
        cfg.cluster.n_trainers = cli.get_usize("trainers")?.max(1);
    }
    if !cli.get("train-samples").is_empty() {
        cfg.data.train_samples = cli.get_usize("train-samples")?;
    }
    if !cli.get("eval-samples").is_empty() {
        cfg.data.eval_samples = cli.get_usize("eval-samples")?;
    }
    Ok(cfg)
}

fn cmd_train(args: &[String]) -> Result<()> {
    let cli = Cli::new("cpr train", "run one emulated training job")
        .opt("preset", "mini", "model preset (mini|kaggle_like|terabyte_like|large_100m)")
        .opt("config", "", "TOML job config (overrides preset)")
        .opt("strategy", "",
             "full|partial|cpr-vanilla|cpr-scar|cpr-mfu|cpr-ssu|cpr-adaptive")
        .opt("backend", "", "Emb PS cluster runtime: inproc|threaded")
        .opt("ckpt-format", "",
             "on-disk checkpoint layout: v1 (monolithic) | v2 (incremental chains)")
        .opt("ckpt-codec", "",
             "v2 payload codec: none | q8 | q4 (quantized rows) | rle")
        .opt("ckpt-dir", "", "durable checkpoint directory (enables publication)")
        .opt("target-pls", "", "CPR target PLS (default from config: 0.1)")
        .opt("n-emb", "", "number of Emb PS nodes")
        .opt("trainers", "", "data-parallel trainer count (default from config: 1)")
        .opt("train-samples", "", "override training samples")
        .opt("eval-samples", "", "override eval samples")
        .opt("failures", "0", "number of injected Emb PS failures")
        .opt("fail-frac", "0.125", "fraction of Emb PS nodes lost per failure")
        .opt("trainer-failures", "0", "number of injected trainer failures")
        .opt("seed", "7", "failure schedule seed")
        .opt("eval-every", "0", "eval AUC every n steps (0 = final only)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .flag("telemetry", "enable the telemetry plane (in-memory spans + metrics)")
        .opt("telemetry-dir", "",
             "export chrome trace + metrics snapshots here (implies --telemetry)")
        .opt("serve-qps", "",
             "run the online serving load generator at this aggregate QPS \
              concurrently with training (enables the serving plane)")
        .opt("serve-clients", "", "serving client threads (default from config: 2)")
        .parse(args)?;
    let mut cfg = job_config_from(&cli)?;
    cfg.artifacts_dir = cli.get("artifacts").to_string();
    if !cli.get("backend").is_empty() {
        cfg.cluster.backend = PsBackendKind::parse(cli.get("backend"))?;
    }
    if !cli.get("ckpt-format").is_empty() {
        cfg.checkpoint.format = CkptFormat::parse(cli.get("ckpt-format"))?;
    }
    if !cli.get("ckpt-codec").is_empty() {
        cfg.checkpoint.codec = CkptCodec::parse(cli.get("ckpt-codec"))?;
    }
    if !cli.get("ckpt-dir").is_empty() {
        cfg.checkpoint.dir = Some(cli.get("ckpt-dir").to_string());
    }
    if cli.get_flag("telemetry") {
        cfg.telemetry.enabled = true;
    }
    if !cli.get("telemetry-dir").is_empty() {
        cfg.telemetry.dir = Some(cli.get("telemetry-dir").to_string());
        cfg.telemetry.enabled = true;
    }
    if !cli.get("serve-qps").is_empty() {
        cfg.serving.qps = cli.get_f64("serve-qps")?;
        cfg.serving.enabled = true;
    }
    if !cli.get("serve-clients").is_empty() {
        cfg.serving.clients = cli.get_usize("serve-clients")?.max(1);
    }

    let n_failures = cli.get_usize("failures")?;
    let frac = cli.get_f64("fail-frac")?;
    let victims = ((cfg.cluster.n_emb_ps as f64 * frac).round() as usize)
        .clamp(1, cfg.cluster.n_emb_ps);
    let mut rng = Rng::new(cli.get_u64("seed")?);
    let mut schedule = uniform_schedule(&mut rng, n_failures, cfg.cluster.t_total_h,
                                        cfg.cluster.n_emb_ps, victims);
    schedule.extend(trainer_schedule(&mut rng, cli.get_usize("trainer-failures")?,
                                     cfg.cluster.t_total_h, cfg.cluster.n_trainers));

    let rt = Runtime::cpu()?;
    eprintln!("[cpr] PJRT platform: {}", rt.platform());
    let model = rt.load_model(&cfg.artifacts_dir, &cfg.model.preset)?;
    eprintln!("[cpr] model {} loaded: {} MLP params, {} embedding rows",
              cfg.model.preset, model.manifest.mlp_params(),
              cfg.data.total_rows());
    let spec = cpr::policy::registry::spec(&cfg.checkpoint.strategy);
    eprintln!("[cpr] policy bundle: save={} recovery={} tracker={}",
              spec.save, spec.recovery, spec.tracker.unwrap_or("none"));

    let opts = RunOptions {
        schedule,
        eval_every: cli.get_usize("eval-every")?,
        ..Default::default()
    };
    let report = run_training(&model, &cfg, &opts)?;
    print_report(&report, cfg.cluster.t_total_h);
    Ok(())
}

fn print_report(r: &TrainReport, t_total_h: f64) {
    println!("strategy            {}", r.strategy);
    println!("ps backend          {}", r.backend);
    println!("trainers            {}", r.n_trainers);
    if let Some(p) = &r.plan {
        println!("cpr plan            t_save={:.2}h use_partial={} E[PLS]={:.4} \
                  est_overhead={:.2}% (full-recovery optimum: {:.2}%)",
                 p.t_save_h, p.use_partial, p.expected_pls,
                 100.0 * p.est_overhead_h / t_total_h,
                 100.0 * p.est_full_overhead_h / t_total_h);
    }
    if r.fell_back {
        println!("NOTE: CPR fell back to full recovery (no expected benefit)");
    }
    println!("failures seen       {}", r.failures_seen);
    println!("final PLS           {:.5}", r.pls);
    println!("final test AUC      {:.5}", r.final_auc);
    println!("final test logloss  {:.5}", r.final_logloss);
    println!("steps executed      {}", r.steps_executed);
    let planned_slots = r.ps_stats.unique_rows + r.ps_stats.dedup_hits;
    if planned_slots > 0 {
        println!("gather dedup        {:.1}% of batch slots ({} unique rows / {} slots)",
                 100.0 * r.ps_stats.dedup_hits as f64 / planned_slots as f64,
                 r.ps_stats.unique_rows, planned_slots);
    }
    println!("overhead            {:.3}% of training time", 100.0 * r.overhead_frac);
    println!("  save              {:.3} h ({} saves)", r.ledger.save_h, r.ledger.n_saves);
    println!("  load              {:.3} h", r.ledger.load_h);
    println!("  lost computation  {:.3} h", r.ledger.lost_h);
    println!("  reschedule        {:.3} h", r.ledger.reschedule_h);
    println!("  ckpt io           {:.2} MB written, {:.2} MB restored",
             r.ledger.bytes_written as f64 / 1e6,
             r.ledger.bytes_restored as f64 / 1e6);
    if !r.ledger.replans.is_empty() {
        let track: Vec<String> = r.ledger.replans.iter()
            .map(|(at, t)| format!("{at:.1}h→{t:.2}h"))
            .collect();
        println!("  interval re-plans {}", track.join(", "));
    }
    println!("wall time           {:.1} s", r.wall_secs);
    if let Some(s) = &r.serving {
        println!("serving             target {:.0} qps, achieved {:.0} qps \
                  ({} clients, zipf s={})",
                 s.target_qps, s.achieved_qps, s.clients, s.zipf_s);
        println!("  {:>9} {:>9} {:>9} {:>8} {:>8} {:>8} {:>8}",
                 "regime", "requests", "nodedown", "p50us", "p95us", "p99us",
                 "p999us");
        for reg in &s.regimes {
            println!("  {:>9} {:>9} {:>9} {:>8} {:>8} {:>8} {:>8}",
                     reg.regime, reg.requests, reg.node_down, reg.p50_us,
                     reg.p95_us, reg.p99_us, reg.p999_us);
        }
    }
}

fn cmd_plan(args: &[String]) -> Result<()> {
    let cli = Cli::new("cpr plan", "print the CPR controller decision")
        .opt("preset", "mini", "config preset")
        .opt("config", "", "TOML job config")
        .opt("strategy", "", "(accepted for symmetry; unused)")
        .opt("target-pls", "", "target PLS")
        .opt("ckpt-format", "", "v1 | v2 (v2 enables codec-scaled sizing)")
        .opt("ckpt-codec", "", "v2 payload codec: none | q8 | q4 | rle")
        .opt("n-emb", "", "number of Emb PS nodes")
        .opt("trainers", "", "data-parallel trainer count (failure-share term)")
        .opt("train-samples", "", "")
        .opt("eval-samples", "", "")
        .parse(args)?;
    let mut cfg = job_config_from(&cli)?;
    if !cli.get("ckpt-format").is_empty() {
        cfg.checkpoint.format = CkptFormat::parse(cli.get("ckpt-format"))?;
    }
    if !cli.get("ckpt-codec").is_empty() {
        cfg.checkpoint.codec = CkptCodec::parse(cli.get("ckpt-codec"))?;
    }
    // size the checkpoint like the policy registry does, so a configured
    // write bandwidth (cluster.save_bw_gb_h) shapes the plan here too —
    // including the codec's expected encoded/raw ratio under format v2
    // (the planner must see *encoded* sizes to narrow the interval)
    let raw_bytes: u64 = cfg
        .data
        .table_rows
        .iter()
        .map(|&r| cpr::checkpoint::table_io_bytes(r, cfg.model.emb_dim))
        .sum();
    let ratio = if cfg.checkpoint.format == CkptFormat::V2 {
        cpr::checkpoint::codec::estimated_ratio(cfg.checkpoint.codec)
    } else {
        1.0
    };
    let ckpt_bytes =
        if ratio == 1.0 { raw_bytes } else { (raw_bytes as f64 * ratio).ceil() as u64 };
    if ratio != 1.0 {
        println!("ckpt codec          {} (~{:.0}% of raw fp32 bytes)",
                 cfg.checkpoint.codec.name(), 100.0 * ratio);
    }
    let p = cpr::pls::plan_with_bytes(&cfg.cluster, cfg.checkpoint.target_pls,
                                      Some(ckpt_bytes));
    let t = cfg.cluster.t_total_h;
    if let Some(bw) = cfg.cluster.save_bw_gb_h {
        println!("save bandwidth      {bw} GB/h → O_save={:.4} h for the \
                  {:.1} MB checkpoint",
                 cfg.cluster.o_save_eff_h(Some(ckpt_bytes)),
                 ckpt_bytes as f64 / 1e6);
    }
    println!("cluster: N_emb={} N_tr={} T_total={:.0}h T_fail={:.1}h O_save={:.3}h \
              O_load={:.3}h O_res={:.3}h",
             cfg.cluster.n_emb_ps, cfg.cluster.n_trainers, t, cfg.cluster.t_fail_h,
             cfg.cluster.o_save_h, cfg.cluster.o_load_h, cfg.cluster.o_res_h);
    println!("target PLS          {:.3}", cfg.checkpoint.target_pls);
    println!("full-recovery opt   T_save={:.2}h overhead={:.2}%",
             cfg.cluster.t_save_full_h(), 100.0 * p.est_full_overhead_h / t);
    println!("decision            {}",
             if p.use_partial { "PARTIAL (CPR)" } else { "FULL (fallback)" });
    println!("chosen interval     {:.2} h", p.t_save_h);
    println!("expected PLS        {:.4}", p.expected_pls);
    println!("expected overhead   {:.2}%", 100.0 * p.est_overhead_h / t);
    Ok(())
}

fn cmd_fleet(args: &[String]) -> Result<()> {
    let cli = Cli::new("cpr fleet", "production fleet overhead simulation (Fig. 4)")
        .opt("jobs", "17000", "number of jobs to simulate")
        .opt("seed", "4", "rng seed")
        .parse(args)?;
    let cfg = cpr::sim::FleetSimConfig {
        jobs: cli.get_usize("jobs")?,
        ..Default::default()
    };
    let mut rng = Rng::new(cli.get_u64("seed")?);
    let rep = cpr::sim::simulate_fleet(&mut rng, &cfg);
    println!("jobs                 {}", cfg.jobs);
    println!("mean overhead        {:.1}%", 100.0 * rep.mean_overhead_frac);
    println!("machine-years wasted {:.0}", rep.machine_years_wasted);
    println!("{:>5} {:>8} {:>8} {:>8} {:>10} {:>8}",
             "pct", "save", "load", "lost", "reschedule", "total");
    for (p, s, l, lost, res, tot) in &rep.breakdown {
        println!("{:>4.0}% {:>7.1}% {:>7.1}% {:>7.1}% {:>9.1}% {:>7.1}%",
                 p, 100.0 * s, 100.0 * l, 100.0 * lost, 100.0 * res, 100.0 * tot);
    }
    Ok(())
}

fn cmd_scale(args: &[String]) -> Result<()> {
    let cli = Cli::new("cpr scale", "scalability projection (Fig. 13)")
        .opt("preset", "mini", "base cluster preset")
        .opt("model", "linear", "failure model: linear|independent")
        .opt("target-pls", "0.1", "target PLS")
        .opt("p", "0.002", "per-node hourly failure prob (independent model)")
        .parse(args)?;
    let base = preset(cli.get("preset"))?.cluster;
    let model = match cli.get("model") {
        "linear" => cpr::analysis::FailureModel::LinearMtbf,
        "independent" => cpr::analysis::FailureModel::IndependentP,
        m => bail!("unknown failure model {m:?}"),
    };
    let pts = cpr::analysis::scalability_sweep(
        &base, cli.get_f64("target-pls")?, model, cli.get_f64("p")?,
        &[4, 8, 16, 32, 64, 128, 256]);
    println!("{:>7} {:>12} {:>12}", "nodes", "full", "cpr");
    for p in pts {
        println!("{:>7} {:>11.2}% {:>11.2}%", p.n_nodes,
                 100.0 * p.full_overhead_frac, 100.0 * p.cpr_overhead_frac);
    }
    Ok(())
}
