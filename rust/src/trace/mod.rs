//! Failure-trace import/export and trace-driven schedules.
//!
//! The paper's analysis starts from production logs of 17k–20k jobs
//! (§3.1). Users with their own cluster logs can replay them here: a
//! trace is a CSV of failure events (`time_h,victims`), loadable into the
//! coordinator's schedule, and job-level time-to-failure series round-trip
//! for the Fig. 3 fitting pipeline. The synthetic [`NodeHazard`] fleet can
//! be exported in the same format, so the analysis code paths are
//! identical for real and synthetic data.

use anyhow::{bail, Context, Result};

use crate::failure::{FailureEvent, NodeHazard};
use crate::util::rng::Rng;

/// Serialize a failure schedule as CSV
/// (`time_h,victims,trainer_victims` with ids separated by `;`; the
/// third column is omitted for schedules without trainer failures, which
/// keeps pre-trainer-layer traces byte-identical).
pub fn schedule_to_csv(events: &[FailureEvent]) -> String {
    let any_trainers = events.iter().any(|e| !e.trainer_victims.is_empty());
    let mut s = if any_trainers {
        String::from("time_h,victims,trainer_victims\n")
    } else {
        String::from("time_h,victims\n")
    };
    for ev in events {
        let victims: Vec<String> =
            ev.victims.iter().map(|v| v.to_string()).collect();
        s.push_str(&format!("{},{}", ev.time_h, victims.join(";")));
        if any_trainers {
            let tv: Vec<String> =
                ev.trainer_victims.iter().map(|v| v.to_string()).collect();
            s.push_str(&format!(",{}", tv.join(";")));
        }
        s.push('\n');
    }
    s
}

fn parse_ids(field: &str, line_no: usize) -> Result<Vec<usize>> {
    field
        .split(';')
        .filter(|v| !v.trim().is_empty())
        .map(|v| v.trim().parse::<usize>()
             .with_context(|| format!("line {line_no}: bad victim id")))
        .collect()
}

/// Parse a schedule CSV produced by [`schedule_to_csv`] (or by hand).
/// Both the 2-column (Emb PS only) and 3-column (with trainer victims)
/// formats are accepted.
pub fn schedule_from_csv(text: &str) -> Result<Vec<FailureEvent>> {
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || (i == 0 && line.starts_with("time_h")) {
            continue;
        }
        let (time, rest) = line.split_once(',')
            .with_context(|| format!("line {}: expected time,victims", i + 1))?;
        let time_h: f64 = time.trim().parse()
            .with_context(|| format!("line {}: bad time", i + 1))?;
        if time_h < 0.0 {
            bail!("line {}: negative time", i + 1);
        }
        let (ps_field, trainer_field) = match rest.split_once(',') {
            Some((a, b)) => (a, b),
            None => (rest, ""),
        };
        let victims = parse_ids(ps_field, i + 1)?;
        let trainer_victims = parse_ids(trainer_field, i + 1)?;
        if victims.is_empty() && trainer_victims.is_empty() {
            bail!("line {}: no victims", i + 1);
        }
        events.push(FailureEvent { time_h, victims, trainer_victims });
    }
    events.sort_by(|a, b| a.time_h.partial_cmp(&b.time_h).unwrap());
    Ok(events)
}

/// Job-level time-to-failure series (one float per job, hours) — the
/// Fig. 3 input format.
pub fn ttfs_to_csv(ttfs: &[f64]) -> String {
    let mut s = String::from("ttf_h\n");
    for t in ttfs {
        s.push_str(&format!("{t}\n"));
    }
    s
}

pub fn ttfs_from_csv(text: &str) -> Result<Vec<f64>> {
    text.lines()
        .skip(1)
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.trim().parse::<f64>().context("bad ttf value"))
        .collect()
}

/// Generate and export a synthetic fleet trace (the shipped stand-in for
/// production logs; same consumer code paths as a real trace).
pub fn synthesize_fleet_trace(
    seed: u64,
    jobs: usize,
    n_nodes: usize,
    horizon_h: f64,
) -> Vec<f64> {
    let hz = NodeHazard::default();
    let mut rng = Rng::new(seed);
    hz.fleet_ttfs(&mut rng, jobs, n_nodes, horizon_h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_roundtrip() {
        let events = vec![
            FailureEvent { time_h: 7.25, victims: vec![3], trainer_victims: vec![] },
            FailureEvent { time_h: 41.0, victims: vec![0, 5, 2], trainer_victims: vec![] },
        ];
        let csv = schedule_to_csv(&events);
        assert!(csv.starts_with("time_h,victims\n"),
                "PS-only schedules keep the legacy 2-column format");
        let back = schedule_from_csv(&csv).unwrap();
        assert_eq!(events, back);
    }

    #[test]
    fn schedule_roundtrip_with_trainer_victims() {
        let events = vec![
            FailureEvent { time_h: 3.5, victims: vec![1], trainer_victims: vec![0, 2] },
            FailureEvent { time_h: 20.0, victims: vec![], trainer_victims: vec![7] },
        ];
        let csv = schedule_to_csv(&events);
        assert!(csv.starts_with("time_h,victims,trainer_victims\n"));
        let back = schedule_from_csv(&csv).unwrap();
        assert_eq!(events, back);
        // 2-column legacy input still parses (no trainer victims)
        let legacy = schedule_from_csv("time_h,victims\n5,1;2\n").unwrap();
        assert_eq!(legacy[0].trainer_victims, Vec::<usize>::new());
    }

    #[test]
    fn schedule_sorts_by_time() {
        let back = schedule_from_csv("time_h,victims\n40,1\n7,0\n").unwrap();
        assert!(back[0].time_h < back[1].time_h);
    }

    #[test]
    fn schedule_rejects_garbage() {
        assert!(schedule_from_csv("time_h,victims\nxx,1\n").is_err());
        assert!(schedule_from_csv("time_h,victims\n5,\n").is_err());
        assert!(schedule_from_csv("time_h,victims\n-3,1\n").is_err());
        assert!(schedule_from_csv("time_h,victims\n5,a;b\n").is_err());
    }

    #[test]
    fn ttfs_roundtrip() {
        let ttfs = vec![1.5, 28.0, 0.25];
        let back = ttfs_from_csv(&ttfs_to_csv(&ttfs)).unwrap();
        assert_eq!(ttfs, back);
    }

    #[test]
    fn synthetic_trace_is_deterministic_and_plausible() {
        let a = synthesize_fleet_trace(9, 2000, 16, 500.0);
        let b = synthesize_fleet_trace(9, 2000, 16, 500.0);
        assert_eq!(a, b);
        let mean = a.iter().sum::<f64>() / a.len() as f64;
        assert!((5.0..60.0).contains(&mean), "mean ttf {mean}");
    }
}
