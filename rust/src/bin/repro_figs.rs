//! `repro_figs` — regenerate every table and figure of the CPR paper's
//! evaluation on the emulation framework (DESIGN.md experiment index).
//!
//!     cargo run --release --bin repro_figs -- <exp> [--scale 1.0] [--out results]
//!
//! <exp> ∈ fig2 fig3 fig4 fig6 fig7 fig8 fig9 fig10 fig11 fig12 fig13
//!         table1 all
//!
//! Each experiment prints the paper-comparable rows/series to stdout and
//! writes CSV into --out. `--scale` multiplies training-sample counts
//! (accuracy experiments only; the overhead math is in emulated hours and
//! does not depend on it).

use anyhow::{bail, Result};

use cpr::analysis::{fit_survival, hazard_curve, scalability_sweep, FailureModel};
use cpr::config::{preset, JobConfig, Strategy};
use cpr::coordinator::{run_training, RunOptions};
use cpr::failure::{uniform_schedule, FailureEvent, NodeHazard};
use cpr::runtime::{ModelExe, Runtime};
use cpr::sim::{simulate_fleet, FleetSimConfig};
use cpr::util::cli::Cli;
use cpr::util::rng::Rng;
use cpr::util::stats;

struct Ctx {
    rt: Runtime,
    scale: f64,
    out_dir: String,
}

impl Ctx {
    fn model(&self, preset_name: &str) -> Result<ModelExe> {
        self.rt.load_model("artifacts", preset_name)
    }

    fn cfg(&self, preset_name: &str) -> Result<JobConfig> {
        let mut cfg = preset(preset_name)?;
        let b = cfg.model.batch;
        let scale = |n: usize| ((n as f64 * self.scale) as usize / b).max(1) * b;
        cfg.data.train_samples = scale(cfg.data.train_samples);
        cfg.data.eval_samples = scale(cfg.data.eval_samples);
        Ok(cfg)
    }

    /// Write the CSV and a JSON mirror (`<name>.json`) of the same table —
    /// CI uploads the JSON files as per-PR workflow artifacts.
    fn write_csv(&self, name: &str, content: &str) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = format!("{}/{}", self.out_dir, name);
        std::fs::write(&path, content)?;
        eprintln!("[repro] wrote {path}");
        let json_name = name.strip_suffix(".csv").unwrap_or(name);
        let json_path = format!("{}/{json_name}.json", self.out_dir);
        std::fs::write(&json_path, csv_to_json(content))?;
        eprintln!("[repro] wrote {json_path}");
        Ok(())
    }
}

/// Minimal CSV → JSON table conversion: `{"columns": [...], "rows": [[...]]}`.
/// Numeric cells become JSON numbers, everything else a string (our CSVs
/// contain no quotes/commas inside cells).
fn csv_to_json(csv: &str) -> String {
    let mut lines = csv.lines();
    let columns: Vec<&str> = lines.next().unwrap_or("").split(',').collect();
    let mut out = String::from("{\"columns\":[");
    for (i, c) in columns.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{c}\""));
    }
    out.push_str("],\"rows\":[");
    let mut first_row = true;
    for line in lines.filter(|l| !l.is_empty()) {
        if !first_row {
            out.push(',');
        }
        first_row = false;
        out.push('[');
        for (i, cell) in line.split(',').enumerate() {
            if i > 0 {
                out.push(',');
            }
            match cell.parse::<f64>() {
                Ok(v) if v.is_finite() => out.push_str(cell),
                _ => out.push_str(&format!("\"{cell}\"")),
            }
        }
        out.push(']');
    }
    out.push_str("]}");
    out
}

fn sched(seed: u64, n: usize, t_total: f64, n_nodes: usize, victims: usize)
         -> Vec<FailureEvent> {
    let mut rng = Rng::new(seed);
    uniform_schedule(&mut rng, n, t_total, n_nodes, victims)
}

fn main() {
    if let Err(e) = real_main() {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::new("repro_figs", "regenerate the paper's tables and figures")
        .opt("scale", "1.0", "training-sample multiplier for accuracy runs")
        .opt("out", "results", "output directory for CSV")
        .parse(&args)?;
    let Some(exp) = cli.positionals().first().cloned() else {
        bail!("usage: repro_figs <fig2|fig3|fig4|fig6|fig7|fig8|fig9|fig10|fig11|fig12|fig13|table1|trainers|all>");
    };
    let ctx = Ctx {
        rt: Runtime::cpu()?,
        scale: cli.get_f64("scale")?,
        out_dir: cli.get("out").to_string(),
    };
    match exp.as_str() {
        "fig2" => fig2(&ctx)?,
        "fig3" => fig3(&ctx)?,
        "fig4" => fig4(&ctx)?,
        "fig6" => fig6(&ctx)?,
        "fig7" => fig7(&ctx)?,
        "fig8" => fig8(&ctx)?,
        "fig9" => fig9(&ctx)?,
        "fig10" => fig10(&ctx)?,
        "fig11" => fig11(&ctx, Strategy::PartialNaive, "fig11")?,
        "fig12" => fig12(&ctx)?,
        "fig13" => fig13(&ctx)?,
        "table1" => table1(&ctx)?,
        "trainers" => trainers(&ctx)?,
        "ablate" => ablate(&ctx)?,
        "all" => {
            fig2(&ctx)?;
            fig3(&ctx)?;
            fig4(&ctx)?;
            fig6(&ctx)?;
            fig7(&ctx)?;
            fig8(&ctx)?;
            fig9(&ctx)?;
            fig10(&ctx)?;
            fig11(&ctx, Strategy::PartialNaive, "fig11")?;
            fig12(&ctx)?;
            fig13(&ctx)?;
            table1(&ctx)?;
            trainers(&ctx)?;
            ablate(&ctx)?;
        }
        other => bail!("unknown experiment {other:?}"),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 2 — motivation: naive partial recovery never reaches the no-failure AUC
// ---------------------------------------------------------------------------

fn fig2(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 2 — naive partial recovery vs no-failure (AUC over time) ==");
    let model = ctx.model("mini")?;
    let mut cfg = ctx.cfg("mini")?;
    cfg.data.eval_samples *= 2; // tighter AUC error bars for the motivation plot
    let eval_every = (cfg.data.train_samples / cfg.model.batch / 12).max(1);
    let clean = run_training(&model, &cfg, &RunOptions {
        eval_every, ..Default::default() })?;
    cfg.checkpoint.strategy = Strategy::PartialNaive;
    // the motivating scenario: infrequent checkpoints (an 8-hour cadence,
    // typical when saving is expensive) + repeated failures through the
    // second half of the job — the lost updates can no longer be relearned
    // and the best-ever AUC stays below the no-failure run (paper Fig. 2)
    cfg.checkpoint.t_save_override_h = Some(8.0);
    let n = cfg.cluster.n_emb_ps;
    let mut rng = Rng::new(2020);
    let schedule: Vec<FailureEvent> = [0.45, 0.62, 0.77, 0.93]
        .iter()
        .map(|&f| FailureEvent {
            time_h: f * cfg.cluster.t_total_h,
            victims: rng.sample_distinct(n, n / 2),
            trainer_victims: vec![],
        })
        .collect();
    let failed = run_training(&model, &cfg, &RunOptions {
        schedule: schedule.clone(), eval_every, ..Default::default() })?;

    println!("{:>7} {:>12} {:>14}", "step", "no-failure", "partial(naive)");
    let mut csv = String::from("step,auc_clean,auc_partial\n");
    for ((s, a), (_, b)) in clean.eval_auc.points.iter()
        .zip(failed.eval_auc.points.iter()) {
        println!("{s:>7} {a:>12.5} {b:>14.5}");
        csv.push_str(&format!("{s},{a},{b}\n"));
    }
    for ev in &schedule {
        println!("   (failure at {:.1} h, victims {:?})", ev.time_h, ev.victims);
    }
    println!("best AUC: clean {:.5} vs partial {:.5} (gap {:+.5})",
             clean.eval_auc.best_max().unwrap(),
             failed.eval_auc.best_max().unwrap(),
             clean.eval_auc.best_max().unwrap()
                 - failed.eval_auc.best_max().unwrap());
    ctx.write_csv("fig2.csv", &csv)
}

// ---------------------------------------------------------------------------
// Fig. 3 — failure-trace survival analysis
// ---------------------------------------------------------------------------

fn fig3(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 3 — survival distribution + gamma fit (20k jobs) ==");
    let hz = NodeHazard::default();
    let mut rng = Rng::new(3);
    let mut csv = String::from("nodes,t_h,survival_emp,survival_fit\n");
    for nodes in [16, 32, 64, 128] {
        let ttfs = hz.fleet_ttfs(&mut rng, 20_000, nodes, 500.0);
        let fit = fit_survival(&ttfs, 120.0, 48);
        println!("nodes={nodes:<4} MTBF={:>6.1} h  median={:>5.1} h  \
                  gamma(k={:.2}, θ={:.1})  fit RMSE={:.1}%",
                 fit.mtbf_h, fit.median_ttf_h, fit.shape, fit.scale,
                 100.0 * fit.rmse);
        for (t, emp, fitted) in &fit.curve {
            csv.push_str(&format!("{nodes},{t},{emp},{fitted}\n"));
        }
    }
    println!("(paper: MTBF 14–30 h, median 8–17 h, gamma fit RMSE 4.4%, \
              MTBF linear in nodes)");
    let ttfs = hz.fleet_ttfs(&mut rng, 20_000, 16, 500.0);
    let hc = hazard_curve(&ttfs, 60.0, 24);
    let mut csv2 = String::from("t_h,hazard\n");
    for (t, h) in hc {
        csv2.push_str(&format!("{t},{h}\n"));
    }
    ctx.write_csv("fig3a_survival.csv", &csv)?;
    ctx.write_csv("fig3b_hazard.csv", &csv2)
}

// ---------------------------------------------------------------------------
// Fig. 4 — checkpoint overhead breakdown in the fleet
// ---------------------------------------------------------------------------

fn fig4(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 4 — overhead breakdown over 17k jobs ==");
    let mut rng = Rng::new(4);
    let rep = simulate_fleet(&mut rng, &FleetSimConfig::default());
    println!("mean overhead {:.1}% (paper: 12%) | machine-years {:.0} \
              (paper: 1,156)",
             100.0 * rep.mean_overhead_frac, rep.machine_years_wasted);
    println!("{:>5} {:>8} {:>8} {:>8} {:>10} {:>8}",
             "pct", "save", "load", "lost", "reschedule", "total");
    let mut csv = String::from("pct,save,load,lost,reschedule,total\n");
    for (p, s, l, lost, res, tot) in &rep.breakdown {
        println!("{:>4.0}% {:>7.1}% {:>7.1}% {:>7.1}% {:>9.1}% {:>7.1}%",
                 p, 100.0 * s, 100.0 * l, 100.0 * lost, 100.0 * res,
                 100.0 * tot);
        csv.push_str(&format!("{p},{s},{l},{lost},{res},{tot}\n"));
    }
    println!("(paper: save-dominated at p75 ≈ 8.8%, lost at p90 ≈ 13.2%, \
              rescheduling at p95 ≈ 23.3%)");
    ctx.write_csv("fig4.csv", &csv)
}

// ---------------------------------------------------------------------------
// Fig. 6 — access frequency vs update magnitude correlation
// ---------------------------------------------------------------------------

fn fig6(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 6 — access count vs update-L2 correlation ==");
    let model = ctx.model("mini")?;
    let mut cfg = ctx.cfg("mini")?;
    // The paper measures after 4096 iterations — *early* training, where
    // per-access updates have near-constant magnitude so total change
    // accumulates ∝ access count. Late in training rows converge and the
    // relationship saturates. Match the early-training regime: a short
    // prefix and a pre-convergence embedding learning rate.
    cfg.data.train_samples = (256.0 * ctx.scale) as usize * cfg.model.batch;
    cfg.train.emb_lr = 0.1;
    let r = run_training(&model, &cfg, &RunOptions {
        collect_row_stats: true, ..Default::default() })?;
    let stats_rows = r.row_stats.unwrap().rows;
    // correlate over accessed rows (paper measures after 4096 iterations)
    let accessed: Vec<&(usize, u32, u32, f64)> =
        stats_rows.iter().filter(|r| r.2 > 0).collect();
    let counts: Vec<f64> = accessed.iter().map(|r| r.2 as f64).collect();
    let changes: Vec<f64> = accessed.iter().map(|r| r.3).collect();
    let corr = stats::pearson(&counts, &changes);
    println!("rows (priority tables) = {}, accessed = {}",
             stats_rows.len(), accessed.len());
    println!("Pearson corr(access count, update L2) = {corr:.4} \
              (paper: 0.9832)");
    let mut csv = String::from("table,row,count,update_l2\n");
    for (t, row, c, l2) in accessed.iter().take(50_000) {
        csv.push_str(&format!("{t},{row},{c},{l2}\n"));
    }
    ctx.write_csv("fig6.csv", &csv)
}

// ---------------------------------------------------------------------------
// Fig. 7 — the headline: overhead + AUC across strategies, both datasets
// ---------------------------------------------------------------------------

fn fig7(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 7 — overhead + AUC, all strategies ==");
    let mut csv = String::from(
        "dataset,strategy,overhead_pct,auc,dauc,pls,ckpt_mb_written,ckpt_mb_restored\n");
    for preset_name in ["kaggle_like", "terabyte_like"] {
        let model = ctx.model(preset_name)?;
        let mut cfg = ctx.cfg(preset_name)?;
        if preset_name == "terabyte_like" {
            // bound wall-clock: terabyte-like steps are ~4x kaggle cost
            cfg.data.train_samples = (cfg.data.train_samples / 2
                / cfg.model.batch).max(1) * cfg.model.batch;
        }
        let n = cfg.cluster.n_emb_ps;
        let schedule = sched(7, 2, cfg.cluster.t_total_h, n, 1); // 12.5%
        let clean = run_training(&model, &cfg, &RunOptions::default())?;
        println!("[{preset_name}] no-failure AUC {:.5}", clean.final_auc);
        println!("{:<14} {:>10} {:>10} {:>9} {:>8}",
                 "strategy", "overhead%", "AUC", "dAUC", "PLS");
        for strategy in [Strategy::Full, Strategy::PartialNaive,
                         Strategy::CprVanilla, Strategy::CprScar,
                         Strategy::CprMfu, Strategy::CprSsu] {
            cfg.checkpoint.strategy = strategy;
            let r = run_training(&model, &cfg, &RunOptions {
                schedule: schedule.clone(), ..Default::default() })?;
            println!("{:<14} {:>9.2}% {:>10.5} {:>9.5} {:>8.4}  ({:.1} MB saved)",
                     r.strategy, 100.0 * r.overhead_frac, r.final_auc,
                     clean.final_auc - r.final_auc, r.pls,
                     r.ledger.bytes_written as f64 / 1e6);
            csv.push_str(&format!("{preset_name},{},{},{},{},{},{},{}\n",
                                  r.strategy, 100.0 * r.overhead_frac,
                                  r.final_auc, clean.final_auc - r.final_auc,
                                  r.pls,
                                  r.ledger.bytes_written as f64 / 1e6,
                                  r.ledger.bytes_restored as f64 / 1e6));
        }
        println!("(paper {preset_name}: full 8.5/8.2% → CPR 0.53/0.68%, \
                  AUC parity with priority schemes)");
    }
    ctx.write_csv("fig7.csv", &csv)
}

// ---------------------------------------------------------------------------
// Fig. 8 — production-scale cluster emulation (18 Emb PS, 10 h, 1 failure)
// ---------------------------------------------------------------------------

fn fig8(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 8 — production-scale setup (20 trainers + 18 Emb PS, 10 h) ==");
    let model = ctx.model("mini")?;
    let mut cfg = ctx.cfg("mini")?;
    // the paper's production run: 20 trainers + 18 Emb PS, 10 h job,
    // full saves every 2 h, CPR-vanilla target PLS 0.05; one failure near
    // the end killing 25% of the Emb PS. The 20 trainers are REAL here —
    // 20 data-parallel worker threads hammering the shared PS.
    cfg.cluster.n_emb_ps = 18;
    cfg.cluster.n_trainers = 20;
    // one global step consumes batch × 20 samples; round the epoch down
    // to a whole number of global steps
    let global = cfg.model.batch * cfg.cluster.n_trainers;
    cfg.data.train_samples = (cfg.data.train_samples / global).max(1) * global;
    cfg.cluster.t_total_h = 10.0;
    cfg.cluster.t_fail_h = 10.0;
    // paper's decomposition of the 12.5%: ~10% lost computation, ~2%
    // saving (2-h cadence), ~0.5% load+reschedule
    cfg.cluster.o_save_h = 0.04;
    cfg.cluster.o_load_h = 0.015;
    cfg.cluster.o_res_h = 0.015;
    cfg.checkpoint.target_pls = 0.05;
    let schedule = vec![FailureEvent {
        time_h: 9.0, // just before the 10-h mark; last full ckpt at 8 h
        victims: (0..18).step_by(4).take(4).collect(), // ~25% of 18
        trainer_victims: vec![],
    }];
    let log_every = (cfg.data.train_samples / global / 20).max(1);
    let mut csv = String::from("strategy,step,loss\n");
    for strategy in [Strategy::Full, Strategy::CprVanilla] {
        cfg.checkpoint.strategy = strategy.clone();
        // full saves every 2 h (the paper's production cadence); the CPR
        // plan resolved to a 4-h interval in the paper's run
        cfg.checkpoint.t_save_override_h =
            Some(if strategy == Strategy::Full { 2.0 } else { 4.0 });
        let r = run_training(&model, &cfg, &RunOptions {
            schedule: schedule.clone(), log_every, ..Default::default() })?;
        println!("{:<12} overhead {:>5.2}% (save {:.2} load {:.2} lost {:.2} \
                  res {:.2} h) final loss {:.5}",
                 r.strategy, 100.0 * r.overhead_frac, r.ledger.save_h,
                 r.ledger.load_h, r.ledger.lost_h, r.ledger.reschedule_h,
                 r.final_logloss);
        for (s, l) in &r.train_loss.points {
            csv.push_str(&format!("{},{s},{l}\n", r.strategy));
        }
    }
    println!("(paper: 12.5% → 1% overhead, loss parity)");
    ctx.write_csv("fig8.csv", &csv)
}

// ---------------------------------------------------------------------------
// Fig. 9 — target-PLS sensitivity
// ---------------------------------------------------------------------------

fn fig9(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 9 — target PLS sensitivity (Kaggle-like emulation) ==");
    let model = ctx.model("mini")?;
    let mut cfg = ctx.cfg("mini")?;
    let n = cfg.cluster.n_emb_ps;
    let schedule = sched(9, 2, cfg.cluster.t_total_h, n, n / 4);
    let mut csv = String::from("strategy,target_pls,overhead_pct,auc,replans\n");
    println!("{:<13} {:>10} {:>10} {:>10} {:>8}", "strategy", "targetPLS",
             "overhead%", "AUC", "replans");
    // cpr-adaptive rides along: same sweep, interval re-planned online
    // from the observed failure rate (re-plan count in the last column)
    for strategy in [Strategy::CprVanilla, Strategy::CprSsu, Strategy::CprAdaptive] {
        for target in [0.02, 0.1, 0.2] {
            cfg.checkpoint.strategy = strategy.clone();
            cfg.checkpoint.target_pls = target;
            let r = run_training(&model, &cfg, &RunOptions {
                schedule: schedule.clone(), ..Default::default() })?;
            println!("{:<13} {:>10.2} {:>9.2}% {:>10.5} {:>8}",
                     r.strategy, target, 100.0 * r.overhead_frac, r.final_auc,
                     r.ledger.replans.len());
            csv.push_str(&format!("{},{target},{},{},{}\n", r.strategy,
                                  100.0 * r.overhead_frac, r.final_auc,
                                  r.ledger.replans.len()));
        }
    }
    println!("(paper: vanilla 2.9%→0.3% overhead, AUC .8028→.8021; \
              SSU AUC .8028→.8027)");
    ctx.write_csv("fig9.csv", &csv)
}

// ---------------------------------------------------------------------------
// Fig. 10 — sensitivity to failure count / failed fraction
// ---------------------------------------------------------------------------

fn fig10(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 10 — failures sensitivity (overhead normalized to full) ==");
    let model = ctx.model("mini")?;
    let base = ctx.cfg("mini")?;
    let n = base.cluster.n_emb_ps;
    let mut csv = String::from(
        "failures,fail_frac,full_overhead,ssu_overhead,normalized,beneficial\n");
    println!("{:>9} {:>7} {:>11} {:>11} {:>11} {:>11}",
             "failures", "frac", "full%", "cpr-ssu%", "normalized", "hatch");
    for n_failures in [2usize, 20, 40] {
        for frac in [0.125, 0.25, 0.5] {
            let mut cfg = base.clone();
            // more failures = proportionally lower MTBF (off-peak training
            // scenario, paper §6.4); target PLS fixed at 0.02
            cfg.cluster.t_fail_h = cfg.cluster.t_total_h / n_failures as f64;
            cfg.checkpoint.target_pls = 0.02;
            let victims = ((n as f64 * frac).round() as usize).clamp(1, n);
            let schedule = sched(10 + n_failures as u64, n_failures,
                                 cfg.cluster.t_total_h, n, victims);
            cfg.checkpoint.strategy = Strategy::Full;
            let full = run_training(&model, &cfg, &RunOptions {
                schedule: schedule.clone(), ..Default::default() })?;
            cfg.checkpoint.strategy = Strategy::CprSsu;
            let ssu = run_training(&model, &cfg, &RunOptions {
                schedule, ..Default::default() })?;
            let norm = ssu.overhead_frac / full.overhead_frac;
            let hatch = if ssu.fell_back { "RED(fb)" } else { "" };
            println!("{:>9} {:>7.3} {:>10.2}% {:>10.2}% {:>11.3} {:>11}",
                     n_failures, frac, 100.0 * full.overhead_frac,
                     100.0 * ssu.overhead_frac, norm, hatch);
            csv.push_str(&format!("{n_failures},{frac},{},{},{norm},{}\n",
                                  full.overhead_frac, ssu.overhead_frac,
                                  !ssu.fell_back));
        }
    }
    println!("(paper: CPR speedup shrinks with more failures; non-beneficial \
              configs correctly predicted — red hatch)");
    ctx.write_csv("fig10.csv", &csv)
}

// ---------------------------------------------------------------------------
// Fig. 11/12 — PLS ↔ accuracy-degradation linearity
// ---------------------------------------------------------------------------

fn fig11(ctx: &Ctx, strategy: Strategy, name: &str) -> Result<()> {
    println!("\n== {} — PLS vs accuracy degradation ({}) ==",
             if name == "fig11" { "Fig. 11" } else { "Fig. 12" },
             strategy.name());
    let model = ctx.model("mini")?;
    let base = ctx.cfg("mini")?;
    let clean = run_training(&model, &base, &RunOptions::default())?;
    println!("no-failure AUC {:.5}", clean.final_auc);
    let n = base.cluster.n_emb_ps;
    let mut rng = Rng::new(1111);
    let mut pls_v = Vec::new();
    let mut dauc_v = Vec::new();
    let mut csv = String::from("run,failures,frac,t_save_h,pls,dauc\n");
    let runs = (16.0 * ctx.scale).ceil().max(8.0) as usize;
    for run_i in 0..runs {
        let n_failures = 1 + rng.usize_below(32);
        let frac = [0.0625, 0.125, 0.25, 0.5][rng.usize_below(4)];
        let victims = ((n as f64 * frac).round() as usize).clamp(1, n);
        let t_save = rng.range_f64(1.0, base.cluster.t_total_h);
        let mut cfg = base.clone();
        cfg.checkpoint.strategy = strategy.clone();
        cfg.checkpoint.t_save_override_h = Some(t_save);
        cfg.cluster.t_fail_h = cfg.cluster.t_total_h / n_failures as f64;
        let schedule = sched(rng.next_u64(), n_failures,
                             cfg.cluster.t_total_h, n, victims);
        let r = run_training(&model, &cfg, &RunOptions {
            schedule, ..Default::default() })?;
        let dauc = clean.final_auc - r.final_auc;
        println!("run {run_i:>2}: failures={n_failures:>2} frac={frac:.3} \
                  T_save={t_save:>5.1}h  PLS={:.4}  dAUC={dauc:+.5}", r.pls);
        csv.push_str(&format!("{run_i},{n_failures},{frac},{t_save},{},{dauc}\n",
                              r.pls));
        pls_v.push(r.pls);
        dauc_v.push(dauc);
    }
    let corr = stats::pearson(&pls_v, &dauc_v);
    let (a, b) = stats::linreg(&pls_v, &dauc_v);
    println!("corr(PLS, dAUC) = {corr:.4} (paper: 0.8764 Kaggle / 0.8175 TB)");
    println!("linear fit: dAUC = {a:.5} + {b:.5} * PLS");
    ctx.write_csv(&format!("{name}.csv"), &csv)?;
    Ok(())
}

fn fig12(ctx: &Ctx) -> Result<()> {
    // Fig. 12 = Fig. 11's sweep under CPR-SSU: the slope must flatten.
    fig11(ctx, Strategy::CprSsu, "fig12")?;
    println!("(paper: SSU reduces the PLS-accuracy slope vs vanilla, \
              expanding the useful PLS range)");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 13 — scalability projection
// ---------------------------------------------------------------------------

fn fig13(ctx: &Ctx) -> Result<()> {
    println!("\n== Fig. 13 — overhead vs cluster size (analytic) ==");
    let base = preset("mini")?.cluster;
    let mut csv = String::from("model,nodes,full,cpr\n");
    for (name, model) in [("linear", FailureModel::LinearMtbf),
                          ("independent", FailureModel::IndependentP)] {
        println!("failure model: {name}");
        println!("{:>7} {:>10} {:>10}", "nodes", "full", "cpr");
        for p in scalability_sweep(&base, 0.1, model, 0.002,
                                   &[4, 8, 16, 32, 64, 128, 256]) {
            println!("{:>7} {:>9.2}% {:>9.2}%", p.n_nodes,
                     100.0 * p.full_overhead_frac, 100.0 * p.cpr_overhead_frac);
            csv.push_str(&format!("{name},{},{},{}\n", p.n_nodes,
                                  p.full_overhead_frac, p.cpr_overhead_frac));
        }
    }
    println!("(paper: full recovery overhead grows with nodes, CPR's shrinks)");
    ctx.write_csv("fig13.csv", &csv)
}

// ---------------------------------------------------------------------------
// Trainer scaling — steps/sec vs trainer count on both PS backends
// ---------------------------------------------------------------------------

/// Data-parallel trainer scaling: the same (scaled) mini job at 1/2/4
/// trainers on the inproc and threaded backends, reporting global
/// steps/sec and samples/sec. This is the run CI uploads per-PR
/// (`trainer_scaling.json`); `cargo bench` has the denser
/// `trainer_scaling[...]` rows at 1/2/4/8.
fn trainers(ctx: &Ctx) -> Result<()> {
    use cpr::config::PsBackendKind;
    println!("\n== trainers — data-parallel scaling (mini, both backends) ==");
    let model = ctx.model("mini")?;
    let base = ctx.cfg("mini")?;
    let mut csv = String::from(
        "backend,n_trainers,global_steps,samples,steps_per_sec,samples_per_sec,auc,\
         ckpt_mb_written,ckpt_mb_restored\n");
    println!("{:<9} {:>9} {:>7} {:>9} {:>11} {:>13} {:>8}",
             "backend", "trainers", "steps", "samples", "steps/s", "samples/s", "AUC");
    for backend in [PsBackendKind::InProc, PsBackendKind::Threaded] {
        for n in [1usize, 2, 4] {
            let mut cfg = base.clone();
            cfg.cluster.backend = backend;
            cfg.cluster.n_trainers = n;
            // every trainer count must divide the stream: round to a
            // multiple of batch × 4 (covers 1/2/4)
            let unit = cfg.model.batch * 4;
            cfg.data.train_samples = (cfg.data.train_samples / unit).max(1) * unit;
            // keep the run training-dominated: wall_secs includes the
            // final evaluation, which is constant in n and would compress
            // the scaling curve if it were comparable to the train phase
            cfg.data.eval_samples = cfg.model.batch * 2;
            let r = run_training(&model, &cfg, &RunOptions::default())?;
            let steps_per_sec = r.steps_executed as f64 / r.wall_secs;
            let samples = r.steps_executed * (cfg.model.batch * n) as u64;
            let samples_per_sec = samples as f64 / r.wall_secs;
            println!("{:<9} {:>9} {:>7} {:>9} {:>11.2} {:>13.0} {:>8.5}",
                     r.backend, n, r.steps_executed, samples, steps_per_sec,
                     samples_per_sec, r.final_auc);
            let mb_w = r.ledger.bytes_written as f64 / 1e6;
            let mb_r = r.ledger.bytes_restored as f64 / 1e6;
            csv.push_str(&format!(
                "{},{n},{},{samples},{steps_per_sec},{samples_per_sec},{},{mb_w},{mb_r}\n",
                r.backend, r.steps_executed, r.final_auc));
        }
    }
    println!("(the N = 1 rows are bit-identical to the pre-refactor \
              single-trainer path; see tests/integration.rs)");
    ctx.write_csv("trainer_scaling.csv", &csv)
}

// ---------------------------------------------------------------------------
// Table 1 — tracker memory overhead (time overhead: `cargo bench`)
// ---------------------------------------------------------------------------

fn table1(ctx: &Ctx) -> Result<()> {
    use cpr::checkpoint::tracker::{MfuTracker, ScarTracker, SsuTracker};
    use cpr::embedding::{PsCluster, TableInfo};
    println!("\n== Table 1 — tracker memory overhead (r = 0.125) ==");
    let mut csv = String::from("emb_bytes,scar_pct,mfu_pct,ssu_pct\n");
    println!("{:>10} {:>10} {:>10} {:>10}",
             "vec bytes", "SCAR", "MFU", "SSU");
    for dim in [16usize, 64, 128] {
        let rows = 100_000usize;
        let cluster = PsCluster::new(vec![TableInfo { rows, dim }], 4, 1);
        let mask = vec![true];
        let scar = ScarTracker::new(&cluster, &mask);
        let mfu = MfuTracker::new(&[rows], &mask);
        let ssu = SsuTracker::new(&[rows / 8], &mask, 2, 0);
        let table_bytes = rows * dim * 4;
        let pct = |b: usize| 100.0 * b as f64 / table_bytes as f64;
        println!("{:>10} {:>9.2}% {:>9.3}% {:>9.3}%",
                 dim * 4, pct(scar.memory_bytes()), pct(mfu.memory_bytes()),
                 pct(ssu.memory_bytes()));
        csv.push_str(&format!("{},{},{},{}\n", dim * 4,
                              pct(scar.memory_bytes()), pct(mfu.memory_bytes()),
                              pct(ssu.memory_bytes())));
    }
    println!("(paper: SCAR 100%, MFU 0.78–6.25%, SSU 0.097–0.78%; \
              time overhead: `cargo bench` table1_* rows)");
    ctx.write_csv("table1.csv", &csv)
}

// ---------------------------------------------------------------------------
// Ablations — design choices DESIGN.md calls out (not in the paper's
// evaluation, but the knobs it fixes: r, SSU period, #priority tables)
// ---------------------------------------------------------------------------

fn ablate(ctx: &Ctx) -> Result<()> {
    println!("\n== Ablations — CPR design knobs (CPR-SSU unless noted) ==");
    let model = ctx.model("mini")?;
    let base = ctx.cfg("mini")?;
    let n = base.cluster.n_emb_ps;
    let schedule = sched(77, 2, base.cluster.t_total_h, n, n / 4);
    let mut csv = String::from("knob,value,overhead_pct,auc,pls\n");

    let mut run_one = |cfg: &JobConfig, knob: &str, value: String,
                       csv: &mut String| -> Result<()> {
        let r = run_training(&model, cfg, &RunOptions {
            schedule: schedule.clone(), ..Default::default() })?;
        println!("{knob:<18} {value:>8}  overhead {:>5.2}%  AUC {:.5}  PLS {:.4}",
                 100.0 * r.overhead_frac, r.final_auc, r.pls);
        csv.push_str(&format!("{knob},{value},{},{},{}\n",
                              100.0 * r.overhead_frac, r.final_auc, r.pls));
        Ok(())
    };

    // r: the priority fraction (paper fixes 0.125)
    for r in [0.0625, 0.125, 0.25, 0.5] {
        let mut cfg = base.clone();
        cfg.checkpoint.strategy = Strategy::CprSsu;
        cfg.checkpoint.r = r;
        run_one(&cfg, "r", format!("{r}"), &mut csv)?;
    }
    // SSU sampling period (paper fixes 2)
    for period in [1usize, 2, 4, 8] {
        let mut cfg = base.clone();
        cfg.checkpoint.strategy = Strategy::CprSsu;
        cfg.checkpoint.ssu_period = period;
        run_one(&cfg, "ssu_period", format!("{period}"), &mut csv)?;
    }
    // number of priority tables (paper fixes 7)
    for tables in [1usize, 3, 7, 26] {
        let mut cfg = base.clone();
        cfg.checkpoint.strategy = Strategy::CprMfu;
        cfg.checkpoint.priority_tables = tables;
        run_one(&cfg, "priority_tables", format!("{tables}"), &mut csv)?;
    }
    // embedding optimizer: checkpointed state consistency (sgd vs adagrad)
    for opt in ["sgd", "adagrad"] {
        let mut cfg = base.clone();
        cfg.checkpoint.strategy = Strategy::CprSsu;
        cfg.train.emb_optimizer =
            cpr::embedding::EmbOptimizer::parse(opt).unwrap();
        if opt == "adagrad" {
            cfg.train.emb_lr = 1.0; // adagrad normalizes per-row scale
        }
        run_one(&cfg, "emb_optimizer", opt.to_string(), &mut csv)?;
    }
    ctx.write_csv("ablations.csv", &csv)
}
